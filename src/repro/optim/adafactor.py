"""Adafactor (Shazeer & Stern): factored second moment, no first moment by
default, no fp32 master copy — the HBM-fitting optimizer for the ≥70B
architectures (arctic-480b, qwen2-vl-72b) on 16 GB/chip meshes.

For a parameter of shape [..., R, C] the second moment is kept as row/col
running means [..., R] and [..., C] (4·(R+C) bytes instead of 4·R·C);
vectors/scalars keep a full vector moment.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from .adamw import Optimizer


def adafactor(lr=1e-3, decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0, schedule=None, min_dim_factored=128
              ) -> Optimizer:
    def factored(p) -> bool:
        return (p.ndim >= 2 and p.shape[-1] >= min_dim_factored
                and p.shape[-2] >= min_dim_factored)

    def init(params):
        def per(p):
            if factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"slots": jax.tree.map(per, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step_lr=None):
        step = state["step"] + 1
        cur_lr = (schedule(step) if schedule is not None
                  else jnp.asarray(step_lr if step_lr is not None else lr,
                                   jnp.float32))
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(g, slot, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in slot:
                vr = beta * slot["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * slot["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., :, None] * vc[..., None, :]
                         / jnp.maximum(
                             vr.mean(-1)[..., None, None], eps))
                u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_slot = {"vr": vr, "vc": vc}
            else:
                v = beta * slot["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_slot = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            pf = p.astype(jnp.float32)
            new_p = pf - cur_lr * (u + weight_decay * pf)
            return new_p.astype(p.dtype), new_slot

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["slots"])
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = treedef.unflatten([o[0] for o in outs])
        new_state = {"slots": treedef.unflatten([o[1] for o in outs]),
                     "step": step}
        return new_params, new_state

    def state_shardings(param_shardings, params_abstract, mesh):
        def per(sh, p):
            # normalise the PartitionSpec to the param rank
            spec = tuple(sh.spec) + (None,) * (p.ndim - len(sh.spec))
            if factored(p):
                return {
                    "vr": NamedSharding(mesh, PartitionSpec(*spec[:-1])),
                    "vc": NamedSharding(
                        mesh, PartitionSpec(*(spec[:-2] + spec[-1:]))),
                }
            return {"v": NamedSharding(mesh, PartitionSpec(*spec))}

        slots = jax.tree.map(per, param_shardings, params_abstract)
        return {"slots": slots,
                "step": NamedSharding(mesh, PartitionSpec())}

    return Optimizer(init=init, update=update,
                     state_shardings=state_shardings)
