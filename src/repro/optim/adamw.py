"""AdamW with fp32 moments + fp32 master weights over bf16 params.

State layout mirrors the param tree so optimizer state inherits the params'
NamedShardings (ZeRO-style: state lives wherever its param shard lives).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Any], Tuple[Any, Any]]
    # (param_shardings, params_abstract, mesh) -> sharding tree matching init
    state_shardings: Callable[[Any, Any, Any], Any]


def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          schedule=None, keep_master=True) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        st = {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }
        if keep_master:
            # copy=True: f32 params would otherwise alias the master buffer
            # and break double-donation in the jitted step
            st["master"] = jax.tree.map(
                lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
        return st

    def update(grads, state, params, step_lr=None):
        step = state["step"] + 1
        cur_lr = (schedule(step) if schedule is not None
                  else jnp.asarray(step_lr if step_lr is not None else lr,
                                   jnp.float32))
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p, master):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            base = master if master is not None else p.astype(jnp.float32)
            step_vec = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            new_master = base - cur_lr * (step_vec + weight_decay * base)
            return new_master.astype(p.dtype), m, v, new_master

        masters = state.get("master",
                            jax.tree.map(lambda _: None, params))
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_ma = (treedef.flatten_up_to(state["master"])
                   if keep_master else [None] * len(flat_p))
        outs = [upd(g, m, v, p, ma) for g, m, v, p, ma
                in zip(flat_g, flat_m, flat_v, flat_p, flat_ma)]
        new_params = treedef.unflatten([o[0] for o in outs])
        new_state = {
            "m": treedef.unflatten([o[1] for o in outs]),
            "v": treedef.unflatten([o[2] for o in outs]),
            "step": step,
        }
        if keep_master:
            new_state["master"] = treedef.unflatten([o[3] for o in outs])
        return new_params, new_state

    def state_shardings(param_shardings, params_abstract, mesh):
        del params_abstract
        st = {"m": param_shardings, "v": param_shardings,
              "step": NamedSharding(mesh, PartitionSpec())}
        if keep_master:
            st["master"] = param_shardings
        return st

    return Optimizer(init=init, update=update,
                     state_shardings=state_shardings)
