"""Gradient compression for cross-pod all-reduce (distributed-opt trick).

``ef_int8``: error-feedback int8 compression — quantise (grad + residual)
to int8 with a per-tensor scale, keep the quantisation error as residual
for the next step.  Used around the *pod-axis* gradient reduction where ICI
bandwidth is scarcest (cross-pod links), via ``compressed_psum`` under
shard_map, or as a pure-jit transform on the gradient tree.

bf16 compression (half the f32 payload, no state) is the default production
setting; int8-EF quarters it at some convergence cost.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_int8_compress(grads: Any, residual: Optional[Any]):
    """→ (quantised tree, scales tree, new residual tree)."""
    if residual is None:
        residual = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def per(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        new_r = x - dequantize_int8(q, s)
        return q, s, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [per(g, r) for g, r in zip(flat_g, flat_r)]
    qs = treedef.unflatten([o[0] for o in outs])
    ss = treedef.unflatten([o[1] for o in outs])
    rs = treedef.unflatten([o[2] for o in outs])
    return qs, ss, rs


def ef_int8_decompress(qs: Any, ss: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(
        lambda q, s: dequantize_int8(q, s).astype(dtype), qs, ss)


def compressed_psum(x: jax.Array, axis_name: str,
                    mode: str = "bf16") -> jax.Array:
    """psum with payload compression (use inside shard_map).

    'bf16': cast → psum → cast back (halves f32 payload; exact for bf16
    grads).  'int8': per-shard int8 quantisation with a max-scale psum —
    payload ≈ ¼; pair with error feedback at the caller for convergence.
    """
    if mode == "bf16":
        return jax.lax.psum(x.astype(jnp.bfloat16), axis_name).astype(x.dtype)
    if mode == "int8":
        scale = jax.lax.pmax(jnp.max(jnp.abs(x)) / 127.0 + 1e-12, axis_name)
        q = jnp.clip(jnp.round(x / scale), -127, 127)
        # int8 payload on the wire; accumulate in f32 to avoid overflow
        tot = jax.lax.psum(q, axis_name)
        return (tot * scale).astype(x.dtype)
    return jax.lax.psum(x, axis_name)
