"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup_steps: int = 100,
                    total_steps: int = 10_000, min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup_steps, 1), 1.0)
        t = jnp.clip((step - warmup_steps)
                     / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, base_lr * cos)

    return lr


def constant_schedule(base_lr: float):
    def lr(step):
        del step
        return jnp.asarray(base_lr, jnp.float32)

    return lr
