from .adamw import adamw
from .adafactor import adafactor
from .schedule import cosine_schedule
from .clip import clip_by_global_norm


def make_optimizer(cfg, lr=3e-4, **kw):
    """Optimizer factory keyed off the architecture config."""
    if cfg.optimizer == "adafactor":
        return adafactor(lr=lr, **kw)
    return adamw(lr=lr, **kw)
