"""Sharded checkpointing with atomic commits and elastic restore.

Layout (one directory per step):

    <root>/step_000400/
        manifest.json            # tree structure, shapes, dtypes, step, meta
        arr_00000.npy ...        # one file per leaf (host-local shards in a
                                 # real multi-host run; full arrays here)
        COMMITTED                # written last — partial checkpoints are
                                 # never visible to restore()

Elastic restore: arrays are loaded host-side and then device_put with the
*target* shardings, so a checkpoint written on one mesh restores onto any
other mesh (the re-shard happens on load) — this is what lets the FT
supervisor restart on a smaller/larger slice after failures.

Writes run on a background thread (async checkpointing): ``save`` snapshots
to host memory synchronously (cheap vs. HBM→host DMA on real hardware) and
persists asynchronously; ``wait`` joins outstanding writes.
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pending: List[threading.Thread] = []

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             blocking: bool = False) -> Path:
        flat, treedef = _flatten_with_paths(tree)
        # copy=True: np.asarray of a CPU jax array is zero-copy and would
        # alias buffers that the next jitted step donates/frees
        host = [np.array(x, copy=True) for x in flat]
        d = self.root / f"step_{step:08d}"
        tmp = self.root / f".tmp_step_{step:08d}"

        def write():
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = dict(
                step=step,
                treedef=str(treedef),
                leaves=[dict(file=f"arr_{i:05d}.npy",
                             shape=list(a.shape), dtype=str(a.dtype))
                        for i, a in enumerate(host)],
                extra=extra or {},
            )
            for i, a in enumerate(host):
                if a.dtype.kind not in "fiub":       # ml_dtypes (bf16, fp8)
                    a = a.view(np.dtype(f"u{a.dtype.itemsize}"))
                np.save(tmp / f"arr_{i:05d}.npy", a)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            (tmp / "COMMITTED").write_text("ok")
            if d.exists():
                shutil.rmtree(d)
            tmp.rename(d)
            self._gc()

        t = threading.Thread(target=write, daemon=True)
        t.start()
        self._pending.append(t)
        if blocking:
            self.wait()
        return d

    def wait(self):
        for t in self._pending:
            t.join()
        self._pending.clear()

    def _gc(self):
        steps = sorted(self.available())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def available(self) -> List[int]:
        out = []
        for p in self.root.glob("step_*"):
            if (p / "COMMITTED").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, Dict]:
        """Restore into the structure of ``tree_like``; if ``shardings`` is
        given, device_put each leaf with its target sharding (elastic
        re-shard onto the current mesh)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.root}")
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat, treedef = _flatten_with_paths(tree_like)
        assert len(flat) == len(manifest["leaves"]), \
            f"leaf count mismatch: {len(flat)} vs {len(manifest['leaves'])}"
        shard_flat = (treedef.flatten_up_to(shardings)
                      if shardings is not None else [None] * len(flat))
        out = []
        for leaf, meta, sh in zip(flat, manifest["leaves"], shard_flat):
            a = np.load(d / meta["file"])
            if a.dtype.kind == "u" and meta["dtype"] not in (
                    str(a.dtype), "bool"):
                import ml_dtypes
                a = a.view(np.dtype(getattr(
                    ml_dtypes, meta["dtype"], meta["dtype"])))
            target_dtype = getattr(leaf, "dtype", a.dtype)
            a = a.astype(target_dtype)
            if sh is not None:
                out.append(jax.device_put(a, sh))
            else:
                out.append(jax.numpy.asarray(a))
        return jax.tree.unflatten(treedef, out), manifest["extra"]
