"""qwen2-vl-72b [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
M-RoPE (3-section rotary over t/h/w); dynamic-resolution vision frontend is
a stub — ``input_specs`` feeds precomputed patch embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    vision_tokens=1024,
    optimizer="adafactor",
    microbatches=16,
)
