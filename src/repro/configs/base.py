"""Architecture configuration (one instance per assigned architecture).

Every field corresponds to a published value; configs/<arch>.py files carry
the exact numbers from the assignment table.  ``smoke()`` derives a reduced
config of the same family for CPU smoke tests (small widths/depths, tiny
vocab) — the full configs are exercised only through the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int                   # 0 for attention-free families
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 → d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rms"              # rms | ln
    act: str = "swiglu"            # swiglu | gelu
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False   # Arctic: dense MLP in parallel
    dense_residual_ff: int = 0
    capacity_factor: float = 1.25

    # --- VLM (M-RoPE backbone; frontend stubbed) ----------------------------
    mrope_sections: Tuple[int, int, int] = ()
    vision_tokens: int = 0             # precomputed patch embeddings fed in

    # --- audio (encoder-decoder; conv frontend stubbed) ----------------------
    n_encoder_layers: int = 0
    n_audio_frames: int = 0            # precomputed frame embeddings fed in

    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_groups: int = 1
    conv_kernel: int = 4

    # --- hybrid (RecurrentGemma / Griffin) ------------------------------------
    lru_width: int = 0
    attn_window: int = 0
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")

    # --- training -------------------------------------------------------------
    remat: bool = True
    optimizer: str = "adamw"           # adamw | adafactor (giant models)
    microbatches: int = 1              # gradient-accumulation steps/batch

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the 500k-token long-context decode shape."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encdec(self) -> bool:
        return self.family == "audio"

    @property
    def ssm_heads(self) -> int:
        return (self.d_model * self.ssm_expand) // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline bookkeeping)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di = d * self.ssm_expand
            per = (d * (2 * di + 2 * self.ssm_groups * self.ssm_state
                        + self.ssm_heads)
                   + di * d + di)          # in/out proj + dt + conv-ish
            return emb + L * per
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        mlp_mult = 3 if self.act == "swiglu" else 2
        if self.family == "moe":
            per_expert = mlp_mult * d * f
            mlp = (self.n_experts * per_expert
                   + self.n_shared_experts * per_expert
                   + (mlp_mult * d * self.dense_residual_ff
                      if self.moe_dense_residual else 0)
                   + d * self.n_experts)   # router
        else:
            mlp = mlp_mult * d * f
        if self.family == "hybrid":
            # pattern-weighted mix of recurrent and attention blocks
            n_attn = sum(1 for i in range(L)
                         if self.block_pattern[i % len(self.block_pattern)]
                         == "attn")
            w = self.lru_width or d
            rec = d * w * 2 + w * d + 3 * w * d // 1 + w * 4   # proj + gates
            return emb + n_attn * (attn + mlp) + (L - n_attn) * (rec + mlp)
        total = emb + L * (attn + mlp)
        if self.family == "audio":
            total += self.n_encoder_layers * (attn + mlp) + L * attn  # cross
        return total

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        mlp_mult = 3 if self.act == "swiglu" else 2
        per_expert = mlp_mult * d * f
        active_mlp = (self.top_k * per_expert
                      + self.n_shared_experts * per_expert
                      + (mlp_mult * d * self.dense_residual_ff
                         if self.moe_dense_residual else 0)
                      + d * self.n_experts)
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return emb + L * (attn + active_mlp)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 * max(1, len(self.block_pattern))),
            d_model=64,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 8),
            n_shared_experts=min(self.n_shared_experts, 2),
            top_k=min(self.top_k, 2),
            dense_residual_ff=64 if self.moe_dense_residual else 0,
            mrope_sections=(2, 3, 3) if self.mrope_sections else (),
            n_encoder_layers=min(self.n_encoder_layers, 2),
            n_audio_frames=16 if self.n_audio_frames else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            lru_width=64 if self.lru_width else 0,
            attn_window=16 if self.attn_window else 0,
            vision_tokens=8 if self.vision_tokens else 0,
        )
