"""recurrentgemma-9b [arXiv:2402.19427] — RG-LRU + local attention, 1:2.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window 2048.
Block pattern: (recurrent, recurrent, local-attention) repeating.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    act="gelu",
    lru_width=4096,
    attn_window=2048,
    block_pattern=("rec", "rec", "attn"),
    microbatches=8,
)
