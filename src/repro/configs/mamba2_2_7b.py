"""mamba2-2.7b [arXiv:2405.21060] — SSD (state-space duality).

64L d_model=2560, attention-free, ssm_state=128, expand=2, head_dim=64.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50304,   # 50280 padded to 128-multiple so 'vocab' shards cleanly
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_groups=1,
    conv_kernel=4,
    microbatches=4,
)
