"""Architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

from typing import Dict

from .base import ModelConfig
from . import (arctic_480b, mamba2_2_7b, phi3_mini_3_8b, qwen2_0_5b,
               qwen2_5_32b, qwen2_moe_a2_7b, qwen2_vl_72b,
               recurrentgemma_9b, stablelm_1_6b, whisper_base)

ARCHS: Dict[str, ModelConfig] = {
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b.CONFIG,
    "arctic-480b": arctic_480b.CONFIG,
    "qwen2-vl-72b": qwen2_vl_72b.CONFIG,
    "whisper-base": whisper_base.CONFIG,
    "mamba2-2.7b": mamba2_2_7b.CONFIG,
    "recurrentgemma-9b": recurrentgemma_9b.CONFIG,
    "qwen2.5-32b": qwen2_5_32b.CONFIG,
    "stablelm-1.6b": stablelm_1_6b.CONFIG,
    "phi3-mini-3.8b": phi3_mini_3_8b.CONFIG,
    "qwen2-0.5b": qwen2_0_5b.CONFIG,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]


# The assigned input-shape set (seq_len, global_batch, kind).
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason).  long_500k only for sub-quadratic archs."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention architecture: 524k dense-KV "
                       "decode is the quadratic regime this shape excludes "
                       "(see DESIGN.md §Arch-applicability)")
    return True, ""
