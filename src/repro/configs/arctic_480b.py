"""arctic-480b [hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE: 128 experts top-2 + dense residual MLP.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    head_dim=128,
    n_experts=128,
    n_shared_experts=0,
    top_k=2,
    moe_dense_residual=True,
    dense_residual_ff=4864,
    optimizer="adafactor",   # 480B params: factored second moment to fit HBM
    microbatches=8,
)
