"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) d_ff=1408(routed) vocab=151936,
MoE: 4 shared + 60 routed experts, top-4.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    n_experts=60,
    n_shared_experts=4,
    top_k=4,
    microbatches=2,
)
