"""whisper-base [arXiv:2212.04356].

Encoder-decoder, 6L+6L, d_model=512 8H d_ff=2048 vocab=51865.
Conv/mel frontend is a stub — ``input_specs`` feeds precomputed frame
embeddings (1500 frames = 30 s at 50 Hz).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,                 # decoder layers
    n_encoder_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51968,   # 51865 padded to 128-multiple so 'vocab' shards cleanly
    head_dim=64,
    norm="ln",
    act="gelu",
    n_audio_frames=1500,
)
