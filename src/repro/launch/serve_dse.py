"""DSE-service launcher: drive the fault-tolerant co-design query server.

Builds a :class:`repro.serving.dse_service.DSEService` over a design space
(paper 150-point grid by default), submits a seeded synthetic mix of
best-config / best-chip / Pareto queries, drains the queue, and prints the
health snapshot.  ``--chaos SEED`` overlays a deterministic
:class:`repro.ft.faults.FaultPlan` on the streaming engine while serving —
the service must still answer everything (exactly or degraded).
``--fault-event`` then reports a hardware fault (one core lost) on the
first served best-chip answer and drains the resulting re-schedule query
through the same loop — the chip's layers re-map across the survivors
without a service restart.

``--state-dir DIR`` makes the service durable: requests journal to disk
before admission, warm tiers and answers persist in the store, and a
re-launch over the same directory replays whatever an earlier (killed)
launch accepted but never answered — those replayed queries drain FIRST.
SIGTERM/SIGINT trigger a graceful drain: admission closes, the queue is
served to completion, and the journal is closed before exit.
``--scrub`` runs a full durable-store audit after draining — cached
stream payloads are re-derived through the numpy reference path and
poisoned entries quarantined-with-reason + recomputed; ``--no-verify``
disables the in-stream silent-corruption defense (see
:mod:`repro.ft.verify`).

    PYTHONPATH=src python -m repro.launch.serve_dse --requests 12
    PYTHONPATH=src python -m repro.launch.serve_dse --chaos 0 --deadline-s 5
    PYTHONPATH=src python -m repro.launch.serve_dse --fault-event
    PYTHONPATH=src python -m repro.launch.serve_dse --state-dir /tmp/dse
"""

from __future__ import annotations

import argparse
import contextlib
import json
import signal
import time

import numpy as np

from repro.core import topology
from repro.core.accelerator import ConfigGrid, extended_grid
from repro.ft.faults import FaultPlan, inject_chunk_faults
from repro.ft.hw_faults import all_single_core_failures
from repro.serving.dse_service import DSEService

KINDS = ("best_config", "best_chip", "pareto")


def install_graceful(svc, *, signals=(signal.SIGTERM, signal.SIGINT)):
    """Graceful-drain handler: on signal, close admission (``max_queue=0``
    rejects everything), serve the queue to completion, close the journal,
    and exit 0 — accepted work is answered, not re-queued for a replay.
    Returns the handler so tests can invoke it without a real signal."""
    def handler(signum, frame):
        svc.max_queue = 0
        svc.run_until_drained()
        svc.close()
        raise SystemExit(0)
    for s in signals:
        signal.signal(s, handler)
    return handler


def main(argv=None, *, clock=None, sleep=None, grid=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--networks", nargs="*",
                    default=["AlexNet", "VGG16", "MobileNet", "ResNet50"])
    ap.add_argument("--extended", action="store_true",
                    help="5,400-point extended grid (default: paper 150)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-queue", type=int, default=32)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall budget (default: unbounded)")
    ap.add_argument("--chunk-size", type=int, default=64)
    ap.add_argument("--degrade-stride", type=int, default=8)
    ap.add_argument("--backend", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--state-dir", default=None,
                    help="durable state root (journal + cache + "
                    "checkpoints); re-launching over it replays "
                    "unanswered requests")
    ap.add_argument("--chaos", type=int, default=None,
                    help="inject a seeded fault plan while serving")
    ap.add_argument("--fault-event", action="store_true",
                    help="after draining, report a single-core loss on "
                    "the first best-chip answer and re-schedule")
    ap.add_argument("--no-verify", action="store_true",
                    help="disable the silent-corruption defense "
                    "(invariant checks + shadow recompute + idle scrub)")
    ap.add_argument("--verify-fraction", type=float, default=1.0 / 16.0,
                    help="seeded fraction of chunks shadow-recomputed "
                    "on the numpy reference (default 1/16)")
    ap.add_argument("--scrub", action="store_true",
                    help="after draining, run a FULL store scrub "
                    "(audit + quarantine + recompute) and print its "
                    "counters; requires --state-dir")
    args = ap.parse_args(argv)

    if grid is None:
        grid = extended_grid() if args.extended else ConfigGrid.product()
    nets = {n: topology.get_network(n) for n in args.networks}
    extra = {}
    if clock is not None:
        extra["clock"] = clock
    if sleep is not None:
        extra["sleep"] = sleep
    svc = DSEService(grid, nets, max_queue=args.max_queue,
                     chunk_size=args.chunk_size,
                     degrade_stride=args.degrade_stride,
                     backend=args.backend, state_dir=args.state_dir,
                     verify=not args.no_verify,
                     verify_fraction=args.verify_fraction,
                     **extra)
    prev_handlers = {s: signal.getsignal(s)
                     for s in (signal.SIGTERM, signal.SIGINT)}
    install_graceful(svc)
    if svc.stats["replayed"]:
        print(f"replayed {svc.stats['replayed']} unanswered requests "
              f"from {args.state_dir}")

    rng = np.random.default_rng(args.seed)
    names = list(nets)
    rejected = 0
    for _ in range(args.requests):
        kind = KINDS[int(rng.integers(len(KINDS)))]
        sub = svc.submit(
            kind,
            network=(names[int(rng.integers(len(names)))]
                     if kind != "best_config" else None),
            deadline=float(rng.choice([1.2, 1.5, 2.0, 3.0])),
            deadline_s=args.deadline_s)
        rejected += int(not sub.accepted)

    n_chunks = -(-grid.n // max(1, min(args.chunk_size, grid.n)))

    def chaos():
        if args.chaos is None:
            return contextlib.nullcontext()
        return inject_chunk_faults(FaultPlan.random(args.chaos, n_chunks))

    t0 = time.time()
    with chaos():
        responses, drained = svc.run_until_drained()
    dt = time.time() - t0

    n_deg = sum(r.degraded for r in responses)
    print(f"served {len(responses)} responses in {dt:.2f}s "
          f"({len(responses) / max(dt, 1e-9):.1f} q/s), "
          f"{n_deg} degraded, {rejected} rejected, drained={drained}")

    if args.fault_event:
        chip = next((r.answer for r in responses
                     if r.kind == "best_chip" and r.ok
                     and r.answer.get("feasible")), None)
        if chip is None:
            # the seeded mix served no feasible chip — ask for one
            svc.submit("best_chip", deadline=2.0)
            with chaos():
                extra, _ = svc.run_until_drained()
            responses.extend(extra)
            chip = next((r.answer for r in extra
                         if r.ok and r.answer.get("feasible")), None)
        if chip is None:
            print("fault-event: no feasible best-chip answer to break")
        else:
            scen = all_single_core_failures(chip["chip_counts"])[0]
            svc.fault_event(chip["chip_types"], chip["chip_counts"],
                            scen, deadline_s=args.deadline_s)
            with chaos():
                resched, _ = svc.run_until_drained()
            responses.extend(resched)
            for r in resched:
                a = r.answer
                print(f"fault-event {scen.name} on chip "
                      f"{chip['chip_types']}×{chip['chip_counts']}: "
                      f"ok={r.ok} degraded={r.degraded} "
                      f"feasible={a.get('feasible')} "
                      f"counts_after={a.get('counts_after')}")

    if args.scrub:
        if svc.store is None:
            print("scrub: no --state-dir, nothing to audit")
        else:
            res = svc.scrub()
            print(f"scrub: scanned {res['scanned']} entries, "
                  f"{res['bad']} quarantined, "
                  f"{res['recomputed']} recomputed")

    print(json.dumps(svc.health(), indent=2, default=str))
    svc.close()
    for s, h in prev_handlers.items():   # leave no handler behind (tests
        signal.signal(s, h)              # call main() in-process)
    return responses


if __name__ == "__main__":
    main()
