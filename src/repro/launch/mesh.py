"""Production meshes.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the `pod` axis
is outer data parallelism by default and the pipeline-stage axis for the
B&B pipeline runtime.

A function, not a module constant: importing this module must never touch
jax device state (device count is locked at first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None):
    """Tiny mesh over whatever devices exist (tests on CPU)."""
    n = n_devices or len(jax.devices())
    model = 2 if n % 2 == 0 and n > 1 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))
