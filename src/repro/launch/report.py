"""Generate the §Dry-run and §Roofline markdown tables from the dry-run
JSON cells and inject them into EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def load_cells(d: Path, policy_tag: str = ""):
    cells = {}
    for f in sorted(d.glob("*.json")):
        parts = f.stem.split("__")
        if len(parts) == 3 and not policy_tag:
            arch, shape, mesh = parts
        elif len(parts) == 4 and policy_tag and parts[3] == policy_tag:
            arch, shape, mesh = parts[:3]
        else:
            continue
        cells[(arch, shape, mesh)] = json.loads(f.read_text())
    return cells


def dryrun_table(cells) -> str:
    lines = [
        "| arch | shape | mesh | status | GiB/dev | lower s | compile s |",
        "|---|---|---|---|---:|---:|---:|",
    ]
    for (arch, shape, mesh), r in sorted(cells.items()):
        if r["status"] == "ok":
            lines.append(
                f"| {arch} | {shape} | {mesh} | ok "
                f"| {r['per_device_gib']:.2f} | {r['lower_s']:.1f} "
                f"| {r['compile_s']:.1f} |")
        elif r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | {mesh} | skipped "
                         f"(sub-quadratic-only shape) | — | — | — |")
        else:
            lines.append(f"| {arch} | {shape} | {mesh} | ERROR: "
                         f"{r['error'][:60]} | — | — | — |")
    ok = sum(1 for r in cells.values() if r["status"] == "ok")
    sk = sum(1 for r in cells.values() if r["status"] == "skipped")
    er = sum(1 for r in cells.values() if r["status"] == "error")
    lines.append("")
    lines.append(f"**{ok} compiled, {sk} skipped by rule, {er} errors.**")
    return "\n".join(lines)


def roofline_table(cells) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck "
        "| useful FLOPs | MFU @ roofline |",
        "|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for (arch, shape, mesh), r in sorted(cells.items()):
        if mesh != "single" or r["status"] != "ok":
            continue
        rl = r["roofline"]
        lines.append(
            f"| {arch} | {shape} | {rl['compute_s']:.4f} "
            f"| {rl['memory_s']:.4f} | {rl['collective_s']:.4f} "
            f"| {rl['bottleneck']} | {rl['useful_flops_ratio']:.3f} "
            f"| {rl['mfu']:.4f} |")
    return "\n".join(lines)


def inject(md_path: Path, marker: str, content: str):
    text = md_path.read_text()
    tag = f"<!-- {marker} -->"
    assert tag in text, marker
    # replace the tag (keep it so re-runs re-inject)
    new = text.split(tag)
    # content replaces everything until the next section header after tag
    tail = new[1]
    nxt = tail.find("\n## ")
    tail_keep = tail[nxt:] if nxt >= 0 else ""
    md_path.write_text(new[0] + tag + "\n\n" + content + "\n" + tail_keep)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--md", default="EXPERIMENTS.md")
    args = ap.parse_args()
    cells = load_cells(Path(args.dir))
    md = Path(args.md)
    inject(md, "DRYRUN_TABLE", dryrun_table(cells))
    inject(md, "ROOFLINE_TABLE", roofline_table(cells))
    print(f"injected {len(cells)} cells into {md}")


if __name__ == "__main__":
    main()
