"""End-to-end trainer: data pipeline → sharded train step → checkpoint/FT.

CPU-debug scale by default (``--smoke``) so the driver itself is testable;
the same code path launches on a real mesh (the dry-run proves the sharding
configs compile for the production meshes).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataPipeline, SyntheticLM
from repro.ft import FaultInjector, Supervisor
from repro.launch import steps as ST
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import model_zoo as Z
from repro.models import params as P


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + debug mesh (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        import dataclasses
        cfg = dataclasses.replace(cfg, microbatches=2)
        mesh = make_debug_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    step_fn, (ins, outs), _, opt = ST.build_train_step(
        cfg, mesh, seq_len=args.seq_len, global_batch=args.global_batch,
        lr=args.lr)
    jitted = jax.jit(step_fn, in_shardings=ins, out_shardings=outs,
                     donate_argnums=(0, 1))

    key = jax.random.key(args.seed)
    params = jax.device_put(Z.init(cfg, key), ins[0])
    opt_state = jax.device_put(opt.init(params), ins[1])

    extras = {}
    if cfg.family == "vlm":
        extras["vision_embeds"] = lambda s, b: np.random.default_rng(s) \
            .normal(size=(b, cfg.vision_tokens, cfg.d_model)) \
            .astype(np.float32)
    if cfg.family == "audio":
        extras["frames"] = lambda s, b: np.random.default_rng(s) \
            .normal(size=(b, cfg.n_audio_frames, cfg.d_model)) \
            .astype(np.float32)
    pipe = DataPipeline(SyntheticLM(cfg.vocab, args.seed),
                        global_batch=args.global_batch,
                        seq_len=args.seq_len, extras=extras)

    ckpt_dir = args.ckpt_dir or f"/tmp/repro_ckpt_{args.arch}"
    manager = CheckpointManager(ckpt_dir)
    sup = Supervisor(manager, checkpoint_every=args.ckpt_every,
                     reexecute_stragglers=False)    # step donates buffers

    losses = []

    def one_step(state, step):
        params, opt_state = state
        batch = pipe._make_batch(step)        # deterministic per step
        batch = {k: jax.device_put(v) for k, v in batch.items()}
        params, opt_state, metrics = jitted(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % 10 == 0:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        return (params, opt_state)

    injector = None
    if args.inject_failure_at >= 0:
        injector = FaultInjector({args.inject_failure_at: "fail"})

    t0 = time.time()
    state = sup.run(state=(params, opt_state), step_fn=one_step,
                    num_steps=args.steps, injector=injector)
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}", flush=True)
    pipe.close()
    return losses


if __name__ == "__main__":
    main()
