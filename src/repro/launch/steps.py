"""Jittable train / serve steps with full sharding plumbing.

``build_train_step`` returns (step_fn, in_shardings, out_shardings,
abstract inputs) ready for ``jax.jit(...).lower(...)`` — used identically by
the real trainer and the allocation-free dry-run.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..configs.base import ModelConfig
from ..models import model_zoo as Z
from ..models import params as P
from ..optim import make_optimizer
from ..optim.clip import clip_by_global_norm
from ..parallel import shardings as S


def batch_shardings(cfg: ModelConfig, batch_specs, mesh: Mesh,
                    rules=None) -> Dict[str, Any]:
    def mk(leaf):
        # dim 0 is always the (global) batch; shard it over (pod, data).
        axes = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return S.named_sharding(leaf.shape, axes, mesh, rules)

    return jax.tree.map(mk, batch_specs)


def model_shardings(cfg: ModelConfig, mesh: Mesh, rules=None):
    spec_tree = Z.spec(cfg)
    axes = P.axes_tree(spec_tree)
    flat_s, treedef = jax.tree.flatten(spec_tree,
                                       is_leaf=P.is_spec)
    flat_a = treedef.flatten_up_to(axes)
    out = [S.named_sharding(s.shape, a, mesh, rules)
           for s, a in zip(flat_s, flat_a)]
    return treedef.unflatten(out)


def cache_shardings(cfg: ModelConfig, batch: int, seq_len: int, mesh: Mesh,
                    rules=None):
    spec_tree = Z.cache_spec(cfg, batch, seq_len)
    axes = P.axes_tree(spec_tree)
    flat_s, treedef = jax.tree.flatten(spec_tree, is_leaf=P.is_spec)
    flat_a = treedef.flatten_up_to(axes)
    out = [S.named_sharding(s.shape, a, mesh, rules)
           for s, a in zip(flat_s, flat_a)]
    return treedef.unflatten(out)


def build_train_step(cfg: ModelConfig, mesh: Mesh, *, seq_len: int,
                     global_batch: int, rules=None, lr: float = 3e-4,
                     microbatches: Optional[int] = None):
    """Returns (step_fn, (in_shardings, out_shardings), abstract_args).

    ``microbatches`` > 1 runs gradient accumulation: the global batch is
    split on dim 0 and scanned, bounding the per-microbatch activation /
    remat-carry footprint.  Accumulation is f32 (bf16 above 100B params to
    fit HBM).
    """
    opt = make_optimizer(cfg, lr=lr)
    m = microbatches if microbatches is not None else cfg.microbatches
    acc_dtype = (jnp.bfloat16 if cfg.param_count() > 100e9 else jnp.float32)

    def step(params, opt_state, batch):
        with S.sharding_context(mesh, rules):
            if m <= 1:
                (loss, aux), grads = jax.value_and_grad(
                    Z.loss_fn, has_aux=True)(params, cfg, batch)
            else:
                mb = jax.tree.map(
                    lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]),
                    batch)

                def micro(carry, one):
                    gacc, lsum = carry
                    (l, _), g = jax.value_and_grad(
                        Z.loss_fn, has_aux=True)(params, cfg, one)
                    gacc = jax.tree.map(
                        lambda a, b: a + b.astype(acc_dtype), gacc, g)
                    return (gacc, lsum + l), None

                gacc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, acc_dtype), params)
                (gacc, lsum), _ = jax.lax.scan(
                    micro, (gacc0, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda g: g / m, gacc)
                loss = lsum / m
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            new_params, new_state = opt.update(grads, opt_state, params)
            metrics = dict(loss=loss, grad_norm=gnorm)
            return new_params, new_state, metrics

    params_abs = P.abstract_tree(Z.spec(cfg))
    p_shard = model_shardings(cfg, mesh, rules)
    state_abs = jax.eval_shape(opt.init, params_abs)
    s_shard = opt.state_shardings(p_shard, params_abs, mesh)
    batch_abs = Z.input_specs(cfg, seq_len=seq_len,
                              global_batch=global_batch, kind="train")
    b_shard = batch_shardings(cfg, batch_abs, mesh, rules)

    rep = NamedSharding(mesh, PartitionSpec())
    in_shardings = (p_shard, s_shard, b_shard)
    out_shardings = (p_shard, s_shard, dict(loss=rep, grad_norm=rep))
    abstract_args = (params_abs, state_abs, batch_abs)
    return step, (in_shardings, out_shardings), abstract_args, opt


def build_serve_step(cfg: ModelConfig, mesh: Mesh, *, seq_len: int,
                     global_batch: int, rules=None):
    """Single-token decode step against a seq_len cache."""

    def step(params, tokens, cache):
        with S.sharding_context(mesh, rules):
            logits, new_cache = Z.decode_step(params, cfg, tokens, cache)
            # greedy next token (serving returns ids + updated cache)
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return next_tok, new_cache

    params_abs = P.abstract_tree(Z.spec(cfg))
    p_shard = model_shardings(cfg, mesh, rules)
    inputs = Z.input_specs(cfg, seq_len=seq_len, global_batch=global_batch,
                           kind="decode")
    tok_shard = S.named_sharding(inputs["tokens"].shape, ("batch", None),
                                 mesh, rules)
    c_shard = cache_shardings(cfg, global_batch, seq_len, mesh, rules)

    in_shardings = (p_shard, tok_shard, c_shard)
    out_shardings = (S.named_sharding((global_batch,), ("batch",), mesh,
                                      rules), c_shard)
    abstract_args = (params_abs, inputs["tokens"], inputs["cache"])
    return step, (in_shardings, out_shardings), abstract_args


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, *, seq_len: int,
                       global_batch: int, rules=None):
    """Forward over the full prompt (logits only — cache fill fused in real
    serving; the dry-run exercises the compute/collective pattern)."""

    def step(params, batch):
        with S.sharding_context(mesh, rules):
            return Z.forward(params, cfg, batch)

    params_abs = P.abstract_tree(Z.spec(cfg))
    p_shard = model_shardings(cfg, mesh, rules)
    batch_abs = Z.input_specs(cfg, seq_len=seq_len,
                              global_batch=global_batch, kind="prefill")
    b_shard = batch_shardings(cfg, batch_abs, mesh, rules)
    extra = (cfg.vision_tokens if cfg.family == "vlm" else 0)
    out_shape = (global_batch, seq_len + extra, cfg.vocab)
    out_shardings = S.named_sharding(out_shape, ("batch", None, "vocab"),
                                     mesh, rules)
    return step, ((p_shard, b_shard), out_shardings), (params_abs, batch_abs)
