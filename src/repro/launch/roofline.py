"""Roofline-term extraction from compiled dry-run artifacts (§Roofline).

    compute term    = FLOPs / peak_FLOPs            (per chip)
    memory term     = bytes accessed / HBM_bw       (per chip)
    collective term = Σ collective bytes × algo factor / link_bw (per chip)

FLOPs / bytes come from ``compiled.cost_analysis()`` of the SPMD-partitioned
per-device module.  Collective bytes are NOT in cost_analysis: we parse the
optimized post-partitioning HLO text and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
weighted by the standard ring-algorithm traffic factor.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
}

# ring-algorithm traffic factor (bytes crossing links per payload byte)
_ALGO_FACTOR = {
    "all-reduce": 2.0,            # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OP_HEAD_RE = re.compile(r"([\w\-]+)\(")


def _parse_instr(line: str):
    """→ (name, result_type, op, rest) or None.

    Instructions are ``%name = TYPE op(operands...), attrs`` where TYPE is
    either ``dtype[dims]{layout}`` or a parenthesised tuple type.  The type
    is consumed structurally (balanced parens for tuples) rather than by
    guessing where the op token starts, so tuple-typed results/operands —
    ``while((s32[], f32[2,2]) %tuple)`` — and operand-typed dialects parse.
    """
    m = _NAME_RE.match(line)
    if not m:
        return None
    tail = line[m.end():]
    if tail.startswith("("):                   # tuple result type
        depth = 0
        end = -1
        for i, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        result_type, rest = tail[:end + 1], tail[end + 1:].lstrip()
    else:                                      # plain dtype[dims]{layout}
        sp = tail.find(" ")
        if sp < 0:
            return None
        result_type, rest = tail[:sp], tail[sp + 1:].lstrip()
    om = _OP_HEAD_RE.match(rest)
    if not om:
        return None
    return m.group(1), result_type, om.group(1), rest[om.end():]
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:body|calls|to_apply)=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "call", "conditional", "after-all", "partition-id",
    "replica-id", "iota", "broadcast",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class HloCosts:
    """Trip-count-aware cost extraction from optimized HLO text.

    XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE —
    with scan-over-layers + microbatch scans that understates flops by 2–3
    orders of magnitude.  This parser walks the computation graph, scales
    every while body by its ``known_trip_count`` backend config, counts dot
    flops from operand shapes, fusion bytes as operands+result (the same
    convention XLA uses), and collective payload bytes per kind.
    """

    def __init__(self, hlo_text: str):
        self.comps: Dict[str, list] = {}
        self.entry = None
        name = None
        cur: list = []
        hdr = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")

        def flush():
            if name is not None and cur:
                self.comps[name].append(" ".join(cur))
                cur.clear()

        for raw in hlo_text.splitlines():
            line = raw.rstrip()
            if (not line.startswith(" ") and line.endswith("{")
                    and "->" in line):
                m = hdr.match(line)
                if m:
                    flush()
                    name = m.group(1)
                    self.comps[name] = []
                    if line.startswith("ENTRY"):
                        self.entry = name
                    continue
            s = line.strip()
            if name is None:
                continue
            if s.startswith(("%", "ROOT")) and " = " in s:
                flush()                          # new logical instruction
                cur.append(s)
            elif s == "}":
                flush()
                name_done = True
            elif cur:
                cur.append(s)                    # continuation (wrapped line)
        flush()
        self._memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def _line_shapes(self, comp: str) -> Dict[str, str]:
        table = {}
        for line in self.comps.get(comp, ()):
            pi = _parse_instr(line)
            if pi:
                table[pi[0]] = pi[1]
        return table

    def comp_costs(self, comp: str) -> Tuple[float, float, Dict[str, float]]:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = (0.0, 0.0, {})      # break recursion defensively
        flops = 0.0
        mem = 0.0
        coll: Dict[str, float] = {}
        shapes = self._line_shapes(comp)
        for line in self.comps.get(comp, ()):
            pi = _parse_instr(line)
            if not pi:
                continue
            _, result_type, op, rest = pi

            if op == "while":
                body = _CALL_RE.search(line)
                trips = _TRIP_RE.search(line)
                n = int(trips.group(1)) if trips else 1
                if body:
                    f, b, c = self.comp_costs(body.group(1))
                    flops += n * f
                    mem += n * b
                    for k, v in c.items():
                        coll[k] = coll.get(k, 0.0) + n * v
                continue
            if op in ("call", "conditional"):
                for callee in _CALL_RE.findall(line):
                    f, b, c = self.comp_costs(callee)
                    flops += f
                    mem += b
                    for k, v in c.items():
                        coll[k] = coll.get(k, 0.0) + v
                continue

            base_op = op
            for ck in _COLLECTIVES:
                if op == ck or op == ck + "-start":
                    coll[ck] = coll.get(ck, 0.0) + _shape_bytes(result_type)
                    base_op = ck
                    break
            if op.endswith("-done"):
                continue

            # dot flops (also inside fusions via calls= handled above for
            # CPU; on this backend dots appear at top level)
            if op == "dot":
                cm = _CONTRACT_RE.search(line)
                k = 1
                ops = _OPERAND_RE.findall(rest)
                lhs = ops[0] if ops else None
                lhs_dims = _shape_dims(shapes.get(lhs, "")) if lhs else []
                if cm and lhs_dims:
                    for idx in cm.group(1).split(","):
                        if idx:
                            i = int(idx)
                            if i < len(lhs_dims):
                                k *= lhs_dims[i]
                out_elems = 1
                for d in _shape_dims(result_type):
                    out_elems *= d
                flops += 2.0 * out_elems * k
            elif op == "fusion":
                callee = _CALL_RE.search(line)
                if callee:
                    f, b, c = self.comp_costs(callee.group(1))
                    flops += f
                    for k2, v in c.items():
                        coll[k2] = coll.get(k2, 0.0) + v

            if op not in _SKIP_BYTES_OPS:
                nbytes = _shape_bytes(result_type)
                for oname in _OPERAND_RE.findall(rest)[:8]:
                    if oname in shapes:
                        nbytes += _shape_bytes(shapes[oname])
                mem += nbytes

        self._memo[comp] = (flops, mem, coll)
        return self._memo[comp]

    def totals(self) -> Tuple[float, float, Dict[str, float]]:
        return self.comp_costs(self.entry)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Trip-count-aware collective payload bytes per kind."""
    return HloCosts(hlo_text).totals()[2]


def hlo_costs(hlo_text: str) -> Dict[str, Any]:
    f, b, c = HloCosts(hlo_text).totals()
    return dict(flops=f, bytes=b, coll=c)


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-chip HLO flops
    hbm_bytes: float             # per-chip bytes accessed
    coll_bytes: Dict[str, float]
    n_chips: int
    model_flops: float = 0.0     # 6·N·D (or 6·N_active·D) per chip

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return sum(_ALGO_FACTOR[k] * v for k, v in
                   self.coll_bytes.items()) / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time estimate: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilisation at the roofline step time."""
        if self.step_time_s == 0:
            return 0.0
        return self.model_flops / PEAK_FLOPS / self.step_time_s

    def as_dict(self) -> dict:
        return dict(
            flops=self.flops, hbm_bytes=self.hbm_bytes,
            coll_bytes=self.coll_bytes, n_chips=self.n_chips,
            model_flops=self.model_flops,
            compute_s=self.compute_s, memory_s=self.memory_s,
            collective_s=self.collective_s, bottleneck=self.bottleneck,
            step_time_s=self.step_time_s,
            useful_flops_ratio=self.useful_flops_ratio, mfu=self.mfu)


def model_flops_per_chip(cfg, *, seq_len: int, global_batch: int,
                         kind: str, n_chips: int) -> float:
    """6·N·D bookkeeping (N_active for MoE); decode counts one new token
    per sequence (2·N per token for inference)."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = seq_len * global_batch
        total = 6.0 * n_active * tokens
    elif kind == "prefill":
        tokens = seq_len * global_batch
        total = 2.0 * n_active * tokens
    else:                        # decode: one token per sequence
        total = 2.0 * n_active * global_batch
    return total / n_chips
