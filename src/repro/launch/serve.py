"""Serving launcher: batched prefill/decode with continuous batching.

CPU-debug scale by default (``--smoke``); the production-mesh decode path
is proven by the dry-run's serve_step cells.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --requests 6 --max-new-tokens 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model_zoo as Z
from repro.serving import ServeEngine
from repro.serving.engine import Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = Z.init(cfg, jax.random.key(args.seed))
    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         max_seq=args.max_seq)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              size=int(rng.integers(3, 9))).astype(np.int32)
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.max_new_tokens))

    t0 = time.time()
    done = engine.run_until_drained()
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s "
          f"({total / max(dt, 1e-9):.1f} tok/s, {engine.slots} slots)")
    return done


if __name__ == "__main__":
    main()
