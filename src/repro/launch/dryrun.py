import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell with 512 placeholder host devices, prove the sharding config is
coherent, and extract memory/cost/collective analyses for §Roofline.

The two XLA_FLAGS lines above MUST stay the first statements in this module
(before any jax-importing import): jax locks the device count on first init.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh both --out experiments/dryrun

Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>.json
incrementally; existing files are skipped (resumable).
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch import roofline as R
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: Path,
             force: bool = False, rules=None, tag: str = "") -> dict:
    out_path = out_dir / f"{arch}__{shape}__{mesh_kind}{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    sh = SHAPES[shape]
    ok, reason = shape_applicable(arch, shape)
    rec = dict(arch=arch, shape=shape, mesh=mesh_kind,
               seq_len=sh["seq_len"], global_batch=sh["global_batch"],
               kind=sh["kind"])
    if not ok:
        rec.update(status="skipped", reason=reason)
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        if sh["kind"] == "train":
            step, (ins, outs), args, _ = ST.build_train_step(
                cfg, mesh, seq_len=sh["seq_len"],
                global_batch=sh["global_batch"], rules=rules)
            jitted = jax.jit(step, in_shardings=ins, out_shardings=outs,
                             donate_argnums=(0, 1))
        elif sh["kind"] == "prefill":
            step, (ins, outs), args = ST.build_prefill_step(
                cfg, mesh, seq_len=sh["seq_len"],
                global_batch=sh["global_batch"], rules=rules)
            jitted = jax.jit(step, in_shardings=ins, out_shardings=outs)
        else:
            step, (ins, outs), args = ST.build_serve_step(
                cfg, mesh, seq_len=sh["seq_len"],
                global_batch=sh["global_batch"], rules=rules)
            jitted = jax.jit(step, in_shardings=ins, out_shardings=outs,
                             donate_argnums=(2,))

        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        # trip-count-aware HLO walk (XLA's cost_analysis counts each while
        # body once — useless under scan-over-layers; see roofline.HloCosts)
        hc = R.hlo_costs(hlo)

        rl = R.Roofline(
            flops=hc["flops"], hbm_bytes=hc["bytes"], coll_bytes=hc["coll"],
            n_chips=n_chips,
            model_flops=R.model_flops_per_chip(
                cfg, seq_len=sh["seq_len"], global_batch=sh["global_batch"],
                kind=sh["kind"], n_chips=n_chips))

        mem_rec = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem_rec[k] = int(getattr(mem, k, 0) or 0)
        per_dev_bytes = (mem_rec["argument_size_in_bytes"]
                         + mem_rec["temp_size_in_bytes"]
                         + mem_rec["output_size_in_bytes"]
                         - mem_rec["alias_size_in_bytes"])

        rec.update(
            status="ok", n_chips=n_chips,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory=mem_rec, per_device_bytes=per_dev_bytes,
            per_device_gib=round(per_dev_bytes / 2**30, 3),
            roofline=rl.as_dict(),
            xla_cost_analysis=dict(        # cross-check (per-body, unscaled)
                flops=float(cost.get("flops", 0.0)),
                bytes=float(cost.get("bytes accessed", 0.0))),
        )
    except Exception as e:                                  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--policy", default="baseline",
                    help="sharding-policy override (parallel.policies)")
    args = ap.parse_args()

    from repro.parallel.policies import get_policy
    rules = get_policy(args.policy)
    tag = "" if args.policy == "baseline" else f"__{args.policy}"

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (["single", "multi"] if args.mesh == "both"
              else [args.mesh])
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape, mesh_kind, out_dir,
                               force=args.force, rules=rules, tag=tag)
                dt = time.time() - t0
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
                extra = ""
                if st == "ok":
                    rl = rec["roofline"]
                    extra = (f" {rec['per_device_gib']:.2f}GiB/dev "
                             f"bottleneck={rl['bottleneck']}"
                             f" mfu={rl['mfu']:.3f}")
                elif st == "error":
                    extra = " " + rec["error"][:120]
                print(f"[{st:7s}] {arch} × {shape} × {mesh_kind}"
                      f" ({dt:.0f}s){extra}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} error={n_err}", flush=True)


if __name__ == "__main__":
    main()
