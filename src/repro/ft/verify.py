"""Silent-data-corruption defense for the streamed DSE engine.

The chunk guard (:func:`repro.core.energymodel._guard_chunk`) only trips
on *loud* corruption — NaN/inf.  A bit-flip or kernel miscompile that
yields a plausible **finite** wrong value sails through it, poisons the
streamed fold, gets faithfully checksummed by the durable store, and is
then served as a cached "exact" answer forever.  This module is the
defense-in-depth ladder against exactly that:

* :class:`StreamVerifier` — threaded through
  :func:`repro.core.energymodel.stream_networks` /
  :func:`~repro.core.energymodel.stream_layer_topk` via ``verify=``:

  1. **Fold-invariant checks** after every chunk, BEFORE the new state
     commits: running minima are monotone non-increasing, top-k rows
     stay (value, flat-index)-lex sorted with no duplicate indices,
     per-layer sums reproduce the aggregate metric, and boundary hits
     respect ``bound`` against the updated running minimum.  A violation
     raises :class:`FoldInvariantError` with chunk/row provenance — the
     poisoned state never commits, so a retry resumes from the last good
     chunk.  These catch corruption of the CARRIED state (and of resumed
     checkpoint payloads, which carry no checksum); corruption of a raw
     chunk evaluation is usually self-consistent and sails through.

  2. **Sampled dual-backend shadow recompute** — a seeded, deterministic
     fraction of chunks (``verify_fraction``, default 1/16) is
     re-evaluated through the numpy reference kernel and compared to the
     fast-path result: bit-exactly when the fast path IS numpy, within
     ``SHADOW_RTOL`` (1e-12, ~4 decades above the measured ≤3e-16
     cross-backend ulp noise and ~6 decades below any injected
     perturbation) for jax/pallas.  A mismatch raises
     :class:`ShadowMismatchError` with provenance down to (grid row,
     network, term).  This is the layer that catches finite wrong chunk
     evaluations.

* :func:`check_layer_topk_result` / :func:`scrub_layer_topk` — the
  at-rest rung: structural invariants plus a sampled re-derivation of a
  completed (possibly store-loaded) :class:`~repro.core.energymodel.
  LayerTopK`'s rows through the reference path.
  :meth:`repro.serving.store.DurableStore.scrub` walks cached entries
  through these and quarantines-with-reason on mismatch — the store's
  checksum only protects against damage AFTER the write; the scrubber
  catches entries that were poisoned BEFORE it.

Everything is deterministic: chunk sampling derives from
``(seed, chunk_index)`` alone, so a resumed stream samples the same
chunks as an uninterrupted one.  When ``REPRO_VERIFY_EVIDENCE_DIR`` is
set, every mismatch dumps its full provenance as JSON there before
raising — CI uploads the directory as a failure artifact.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import energymodel

#: Relative tolerance for cross-backend shadow comparisons.  The
#: backends agree to ≤3e-16 relative (last-ulp rounding differences in
#: sums); 1e-12 keeps zero false positives while still catching any
#: perturbation large enough to change a reduction.  When the fast path
#: is the numpy reference itself the comparison is bit-exact (rtol 0).
SHADOW_RTOL = 1e-12

#: Relative tolerance for "per-layer sums reproduce the aggregate": the
#: fold computed the aggregate with the backend's summation order, the
#: checker re-sums on the host — last-ulp noise only.
SUM_RTOL = 1e-9


class FoldInvariantError(RuntimeError):
    """A streamed fold state violates a structural invariant.

    Raised BEFORE the offending state commits (or, for resumed states,
    before any chunk folds into it), so the in-memory fold is never
    poisoned; carries the violated ``invariant`` name plus chunk / grid
    row / network provenance."""

    def __init__(self, msg: str, *, invariant: str, chunk: int | None = None,
                 start: int | None = None, stop: int | None = None,
                 network: str | None = None, row: int | None = None):
        super().__init__(msg)
        self.invariant = invariant
        self.chunk = chunk
        self.start = start
        self.stop = stop
        self.network = network
        self.row = row


class ShadowMismatchError(RuntimeError):
    """The fast-path chunk evaluation diverges from the numpy reference.

    ``mismatches`` holds one provenance dict per diverging element —
    ``{"row": <flat grid row>, "network": <name>, "term": "energy" |
    "latency" (with the layer index in per-layer streams), "got": ...,
    "want": ...}`` — capped at ``MAX_MISMATCH_RECORDS``."""

    MAX_MISMATCH_RECORDS = 32

    def __init__(self, msg: str, *, chunk: int, start: int, stop: int,
                 mismatches: Sequence[Dict[str, Any]] = ()):
        super().__init__(msg)
        self.chunk = int(chunk)
        self.start = int(start)
        self.stop = int(stop)
        self.mismatches = list(mismatches)[:self.MAX_MISMATCH_RECORDS]


def _dump_evidence(kind: str, payload: Dict[str, Any]) -> None:
    """Persist mismatch provenance for the CI failure artifact."""
    root = os.environ.get("REPRO_VERIFY_EVIDENCE_DIR")
    if not root:
        return
    try:
        os.makedirs(root, exist_ok=True)
        n = len(os.listdir(root))
        path = os.path.join(root, f"{kind}_{n:04d}.json")
        with open(path, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2, default=str)
    except OSError:                                    # pragma: no cover
        pass          # evidence is best-effort, never masks the raise


@dataclasses.dataclass
class VerifyConfig:
    """Knobs of one :class:`StreamVerifier`.

    ``verify_fraction`` is the seeded share of chunks shadow-recomputed
    on the reference backend (1.0 = every chunk, 0.0 = invariants only);
    ``rtol=None`` auto-selects 0.0 (bit-exact) when the stream's fast
    path is numpy and :data:`SHADOW_RTOL` otherwise."""

    verify_fraction: float = 1.0 / 16.0
    seed: int = 0
    invariants: bool = True
    shadow: bool = True
    rtol: Optional[float] = None
    sum_rtol: float = SUM_RTOL


class StreamVerifier:
    """Per-stream verification hooks; pass as ``verify=`` to the engines.

    The engine calls :meth:`bind` once at stream start (handing over the
    reduction parameters and a numpy-reference chunk evaluator), then
    :meth:`check_chunk` (shadow) and :meth:`check_fold` (invariants) per
    chunk and :meth:`check_resume` on resumed states.  ``stats`` counts
    checks and violations; violations also raise."""

    def __init__(self, config: VerifyConfig | None = None, **kw):
        self.cfg = config if config is not None else VerifyConfig(**kw)
        self.stats: Dict[str, int] = dict(
            shadow_checks=0, shadow_mismatches=0,
            invariant_checks=0, invariant_violations=0)
        self._kind: Optional[str] = None
        self._names: Tuple[str, ...] = ()
        self._metric = "edp"
        self._topk = 0
        self._bound: Optional[float] = None
        self._rtol = 0.0
        self._ref_eval: Optional[Callable] = None

    # -- engine contract ---------------------------------------------------

    def bind(self, *, kind: str, names: Sequence[str], metric: str,
             topk: int, bound: Optional[float], backend: str,
             ref_eval: Optional[Callable] = None) -> None:
        """Called by the engine at stream start.  ``ref_eval(fc)`` must
        return the numpy-reference ``(e, t)`` of one padded chunk."""
        self._kind = kind
        self._names = tuple(names)
        self._metric = metric
        self._topk = int(topk)
        self._bound = None if bound is None else float(bound)
        self._ref_eval = ref_eval
        self._rtol = (self.cfg.rtol if self.cfg.rtol is not None
                      else (0.0 if backend == "numpy" else SHADOW_RTOL))

    def sampled(self, ci: int) -> bool:
        """Deterministic per-chunk sampling from ``(seed, chunk)`` alone
        — independent of the chunk count and of any resume point."""
        f = self.cfg.verify_fraction
        if f >= 1.0:
            return True
        if f <= 0.0:
            return False
        return bool(np.random.default_rng(
            (int(self.cfg.seed), int(ci))).random() < f)

    # -- shadow recompute --------------------------------------------------

    def check_chunk(self, ci: int, start: int, stop: int, fc, e, t) -> None:
        """Sampled dual-backend shadow recompute of one chunk."""
        if not self.cfg.shadow or self._ref_eval is None:
            return
        if not self.sampled(ci):
            return
        self.stats["shadow_checks"] += 1
        e = np.asarray(e)
        t = np.asarray(t)
        e_ref, t_ref = self._ref_eval(fc)
        # compare the FULL padded chunk: padded rows are deterministic
        # duplicates of the chunk's first row (see _pad_rows), so the
        # reference reproduces them too and corruption landing in the
        # padding is still caught
        m = stop - start
        mism: List[Dict[str, Any]] = []
        for term, got, want in (("energy", e, np.asarray(e_ref)),
                                ("latency", t, np.asarray(t_ref))):
            if self._rtol == 0.0:
                bad = (got != want) & ~(np.isnan(got) & np.isnan(want))
            else:
                bad = ~np.isclose(got, want, rtol=self._rtol, atol=0.0,
                                  equal_nan=True)
            for pos in np.argwhere(bad):
                r, j = int(pos[0]), int(pos[1])
                layer = f"[layer {int(pos[2])}]" if len(pos) > 2 else ""
                pad = " (padding dup of first row)" if r >= m else ""
                mism.append(dict(
                    row=(start + r if r < m else start),
                    network=self._names[j],
                    term=f"{term}{layer}{pad}",
                    got=float(got[tuple(pos)]),
                    want=float(want[tuple(pos)])))
        if not mism:
            return
        self.stats["shadow_mismatches"] += 1
        worst = mism[0]
        err = ShadowMismatchError(
            f"shadow recompute mismatch in streamed chunk {ci} (grid rows "
            f"{start}:{stop}): {len(mism)} element(s) diverge from the "
            f"numpy reference beyond rtol={self._rtol:g}; first at grid "
            f"row {worst['row']}, network {worst['network']}, term "
            f"{worst['term']} (got {worst['got']!r}, want "
            f"{worst['want']!r}).  The fold state was NOT updated with "
            f"this chunk — retry the chunk or resume from the last "
            f"exported state", chunk=ci, start=start, stop=stop,
            mismatches=mism)
        _dump_evidence("shadow_mismatch", dict(
            chunk=ci, start=start, stop=stop, rtol=self._rtol,
            kind=self._kind, metric=self._metric,
            mismatches=err.mismatches))
        raise err

    # -- fold invariants ---------------------------------------------------

    def check_fold(self, ci: int, start: int, stop: int, prev_state,
                   new_state, *, es=None, ts=None, mask=None) -> None:
        """Invariant-check the post-chunk state BEFORE it commits."""
        if not self.cfg.invariants:
            return
        self.stats["invariant_checks"] += 1
        try:
            prov = dict(chunk=ci, start=start, stop=stop)
            if self._kind == "networks":
                self._check_networks_state(prev_state, new_state, prov)
            else:
                self._check_layer_state(prev_state, new_state, prov)
            if mask is not None and self._bound is not None:
                self._check_boundary_hits(new_state, es, ts, mask, start,
                                          prov)
        except FoldInvariantError as err:
            self.stats["invariant_violations"] += 1
            _dump_evidence("invariant_violation", dict(
                chunk=ci, start=start, stop=stop, kind=self._kind,
                invariant=err.invariant, network=err.network, row=err.row,
                message=str(err)))
            raise

    def check_resume(self, state, cand) -> None:
        """Invariant-check a RESUMED fold state before any chunk folds
        into it — checkpoint files carry no checksum, so a finite
        corruption of the npz payload is only caught here."""
        if not self.cfg.invariants:
            return
        self.stats["invariant_checks"] += 1
        try:
            prov: Dict[str, Any] = dict(chunk=None, start=None, stop=None)
            self._check_finite_state(state, prov)
            if self._kind == "networks":
                self._check_networks_state(None, state, prov)
                min_m = np.asarray(state[2])
            else:
                self._check_layer_state(None, state, prov)
                min_m = np.asarray(state[7])
            self._check_cand(cand, min_m, prov)
        except FoldInvariantError as err:
            self.stats["invariant_violations"] += 1
            _dump_evidence("invariant_violation", dict(
                where="resume", kind=self._kind,
                invariant=err.invariant, network=err.network, row=err.row,
                message=str(err)))
            raise

    # -- invariant internals -----------------------------------------------

    def _raise(self, invariant: str, detail: str, prov: Dict[str, Any],
               *, network: str | None = None, row: int | None = None):
        where = ("resumed fold state" if prov.get("chunk") is None else
                 f"streamed chunk {prov['chunk']} (grid rows "
                 f"{prov['start']}:{prov['stop']})")
        raise FoldInvariantError(
            f"fold invariant {invariant!r} violated after {where}: "
            f"{detail}; the poisoned state was NOT committed",
            invariant=invariant, chunk=prov.get("chunk"),
            start=prov.get("start"), stop=prov.get("stop"),
            network=network, row=row)

    def _check_finite_state(self, state, prov):
        for i, s in enumerate(state):
            a = np.asarray(s)
            if a.dtype.kind == "f" and np.isnan(a).any():
                self._raise("state_finite",
                            f"state array {i} contains NaN", prov)

    def _check_monotone(self, label, prev, new, prov):
        """Running minima may only move down (or stay)."""
        p = np.asarray(prev)
        worse = np.asarray(new) > p
        # +inf "not seen yet" sentinels compare equal, never worse
        if worse.any():
            pos = np.argwhere(worse)[0]
            j = int(pos[0]) if pos.size else None
            self._raise(
                "monotone_min",
                f"running {label} increased at position {tuple(pos)} "
                f"(network {self._names[j] if j is not None and j < len(self._names) else j})",
                prov, network=(self._names[j]
                               if j is not None and j < len(self._names)
                               else None))

    def _check_topk(self, top_v, top_i, prov):
        """Top-k rows must be (value, flat-index)-lex sorted per network
        with no duplicate valid indices; -1 sentinels (unfilled slots)
        carry +inf and may repeat."""
        top_v = np.asarray(top_v)
        top_i = np.asarray(top_i)
        for j, nm in enumerate(self._names):
            v, i = top_v[:, j], top_i[:, j]
            if np.isnan(v).any():
                self._raise("topk_sorted", f"NaN in top-k values of {nm}",
                            prov, network=nm)
            with np.errstate(invalid="ignore"):   # inf-inf on sentinels
                dv, di = np.diff(v), np.diff(i)
                bad = (dv < 0) | ((dv == 0) & (di < 0) & (i[1:] >= 0))
            if bad.any():
                k = int(np.nonzero(bad)[0][0])
                self._raise(
                    "topk_sorted",
                    f"top-k rows {k}..{k + 1} of network {nm} are not "
                    f"(value, flat-index)-lex sorted: "
                    f"({v[k]!r}, {i[k]}) then ({v[k + 1]!r}, {i[k + 1]})",
                    prov, network=nm, row=int(i[k + 1]))
            valid = i[i >= 0]
            if valid.size != np.unique(valid).size:
                dup = valid[np.nonzero(np.diff(np.sort(valid)) == 0)[0][0]]
                self._raise(
                    "topk_unique",
                    f"duplicate flat grid index {int(dup)} in the top-k "
                    f"of network {nm}", prov, network=nm, row=int(dup))

    def _check_min_is_top(self, min_m, top_v, prov):
        """The running metric minimum IS the best top-k value — they fold
        the same chunk values, so they must agree exactly."""
        min_m = np.asarray(min_m)
        best = np.asarray(top_v)[0]
        bad = (min_m != best) & ~(np.isinf(min_m) & np.isinf(best))
        if bad.any():
            j = int(np.nonzero(bad)[0][0])
            self._raise(
                "min_equals_top",
                f"running min_metric {min_m[j]!r} != best top-k value "
                f"{best[j]!r} for network {self._names[j]}",
                prov, network=self._names[j])

    def _check_networks_state(self, prev, new, prov):
        min_e, min_t, min_m, argm, top_v, top_i = new
        if prev is not None:
            for label, p, q in (("min_energy", prev[0], min_e),
                                ("min_latency", prev[1], min_t),
                                ("min_metric", prev[2], min_m)):
                self._check_monotone(label, p, q, prov)
        self._check_topk(top_v, top_i, prov)
        self._check_min_is_top(min_m, top_v, prov)

    def _check_layer_state(self, prev, new, prov):
        (top_v, top_i, top_e, top_t, min_e, min_t, min_edp, min_m, argm,
         lmin, larg) = new
        if prev is not None:
            for label, p, q in (("min_energy", prev[4], min_e),
                                ("min_latency", prev[5], min_t),
                                ("min_edp", prev[6], min_edp),
                                ("min_metric", prev[7], min_m),
                                ("layer_min_metric", prev[9], lmin)):
                self._check_monotone(label, p, q, prov)
        self._check_topk(top_v, top_i, prov)
        self._check_min_is_top(min_m, top_v, prov)
        # per-layer sums reproduce the aggregate the row was ranked by
        top_v = np.asarray(top_v)
        top_i = np.asarray(top_i)
        with np.errstate(invalid="ignore"):       # inf*0 on -1 sentinels
            agg = energymodel._metric_of(
                self._metric, np.asarray(top_e).sum(-1),
                np.asarray(top_t).sum(-1))
        valid = top_i >= 0
        if valid.any():
            with np.errstate(invalid="ignore"):   # inf-inf on -1 sentinels
                err = (np.abs(agg - top_v)
                       > self.cfg.sum_rtol * np.abs(top_v))
            bad = valid & err
            if bad.any():
                k, j = (int(x) for x in np.argwhere(bad)[0])
                self._raise(
                    "layer_sum_aggregate",
                    f"per-layer rows of top-{k} config (grid row "
                    f"{int(top_i[k, j])}, network {self._names[j]}) sum "
                    f"to metric {agg[k, j]!r} but the fold ranked it at "
                    f"{top_v[k, j]!r}", prov, network=self._names[j],
                    row=int(top_i[k, j]))

    def _check_boundary_hits(self, new_state, es, ts, mask, start, prov):
        """This chunk's boundary hits respect ``bound`` against the
        updated running minimum — and none beats the minimum itself
        (every hit also folded into it)."""
        if es is None or ts is None:
            return
        mask = np.asarray(mask)
        if not mask.any():
            return
        min_m = np.asarray(new_state[2] if self._kind == "networks"
                           else new_state[7])
        v = energymodel._metric_of(self._metric, np.asarray(es),
                                   np.asarray(ts))
        thresh = min_m[None, :] * (1.0 + self._bound)
        bad = mask & ((v < min_m[None, :]) | (v > thresh))
        if bad.any():
            r, j = (int(x) for x in np.argwhere(bad)[0])
            self._raise(
                "boundary_bound",
                f"boundary hit at grid row {start + r} of network "
                f"{self._names[j]} has metric {v[r, j]!r} outside "
                f"[min, min*(1+bound)] = [{min_m[j]!r}, {thresh[0, j]!r}]",
                prov, network=self._names[j], row=start + r)

    def _check_cand(self, cand, min_m, prov):
        """Resumed boundary candidates: finite, and none beats the fold
        minimum (every candidate was folded into it when collected)."""
        for j, nm in enumerate(self._names):
            for idx, ee, tt in cand.get(nm, ()):
                v = energymodel._metric_of(self._metric, np.asarray(ee),
                                           np.asarray(tt))
                if np.isnan(v).any():
                    self._raise("boundary_bound",
                                f"NaN boundary candidate in network {nm}",
                                prov, network=nm)
                bad = v < min_m[j]
                if bad.any():
                    r = int(np.nonzero(bad)[0][0])
                    self._raise(
                        "boundary_bound",
                        f"boundary candidate at grid row "
                        f"{int(np.asarray(idx)[r])} of network {nm} has "
                        f"metric {v[r]!r} BELOW the running minimum "
                        f"{min_m[j]!r} — the fold missed an update",
                        prov, network=nm, row=int(np.asarray(idx)[r]))


# ---------------------------------------------------------------------------
# At-rest verification: completed LayerTopK results and store payloads
# ---------------------------------------------------------------------------


def check_layer_topk_result(st, *, sum_rtol: float = SUM_RTOL
                            ) -> Optional[str]:
    """Structural invariants of a completed (possibly store-loaded)
    :class:`~repro.core.energymodel.LayerTopK`; returns a reason string
    on the first violation, ``None`` when clean."""
    top_v = np.asarray(st.topk_metric)
    top_i = np.asarray(st.topk_idx)
    for j, nm in enumerate(st.networks):
        v, i = top_v[:, j], top_i[:, j]
        if np.isnan(v).any():
            return f"NaN in top-k metrics of network {nm}"
        with np.errstate(invalid="ignore"):       # inf-inf on sentinels
            dv, di = np.diff(v), np.diff(i)
            unsorted = (dv < 0) | ((dv == 0) & (di < 0) & (i[1:] >= 0))
        if unsorted.any():
            return (f"top-k of network {nm} is not (value, flat-index)-"
                    f"lex sorted")
        valid = i[i >= 0]
        if valid.size != np.unique(valid).size:
            return f"duplicate flat grid index in the top-k of network {nm}"
        if st.min_metric is not None and v.size:
            mm = float(np.asarray(st.min_metric)[j])
            if mm != float(v[0]) and not (np.isinf(mm) and np.isinf(v[0])):
                return (f"min_metric {mm!r} != best top-k value "
                        f"{float(v[0])!r} for network {nm}")
    # per-layer rows reproduce the ranking aggregate
    with np.errstate(invalid="ignore"):           # inf*0 on -1 sentinels
        agg = energymodel._metric_of(
            st.metric, np.asarray(st.layer_energy).sum(-1),
            np.asarray(st.layer_latency).sum(-1))
    with np.errstate(invalid="ignore"):           # inf-inf on -1 sentinels
        bad = ((top_i >= 0)
               & (np.abs(agg - top_v) > sum_rtol * np.abs(top_v)))
    if bad.any():
        k, j = (int(x) for x in np.argwhere(bad)[0])
        return (f"per-layer rows of top-{k} config (grid row "
                f"{int(top_i[k, j])}, network {st.networks[j]}) sum to "
                f"{agg[k, j]!r} but were ranked at {top_v[k, j]!r}")
    if st.bound is not None:
        for j, nm in enumerate(st.networks):
            bv = energymodel._metric_of(st.metric,
                                        np.asarray(st.boundary_energy[nm]),
                                        np.asarray(st.boundary_latency[nm]))
            if np.isnan(bv).any():
                return f"NaN in the boundary set of network {nm}"
            if bv.size:
                mm = float(np.asarray(st.min_metric)[j])
                if (bv < mm).any():
                    return (f"boundary entry of network {nm} beats the "
                            f"minimum {mm!r} — the fold missed an update")
                if (bv > mm * (1.0 + float(st.bound))).any():
                    return (f"boundary entry of network {nm} exceeds "
                            f"min*(1+bound)")
                if (np.diff(bv) < 0).any():
                    return (f"boundary set of network {nm} is not "
                            f"metric-sorted")
    return None


def scrub_layer_topk(st, grid, networks, *, rows: int = 2, seed: int = 0,
                     rtol: float = SHADOW_RTOL,
                     sum_rtol: float = SUM_RTOL) -> Optional[str]:
    """At-rest audit of one stream payload: structural invariants plus a
    seeded sample of its top-k rows re-derived through the numpy
    reference path (`evaluate_networks(per_layer=True)` of exactly those
    grid rows) and compared within ``rtol``.  Returns a quarantine
    reason, or ``None`` when the payload checks out."""
    reason = check_layer_topk_result(st, sum_rtol=sum_rtol)
    if reason is not None:
        return reason
    top_i = np.asarray(st.topk_idx)
    cells = np.argwhere(top_i >= 0)
    if not cells.size or rows <= 0:
        return None
    rng = np.random.default_rng(seed)
    pick = cells[rng.choice(len(cells), size=min(int(rows), len(cells)),
                            replace=False)]
    rows_idx = np.unique(top_i[pick[:, 0], pick[:, 1]])
    e_ref, t_ref = energymodel.evaluate_networks(
        grid.take(rows_idx), networks, backend="numpy", per_layer=True)
    pos = {int(r): i for i, r in enumerate(rows_idx)}
    for k, j in pick:
        k, j = int(k), int(j)
        gi = int(top_i[k, j])
        i = pos[gi]
        nm = st.networks[j]
        for term, stored, ref in (
                ("energy", np.asarray(st.layer_energy)[k, j],
                 np.asarray(e_ref)[i, j]),
                ("latency", np.asarray(st.layer_latency)[k, j],
                 np.asarray(t_ref)[i, j])):
            bad = ~np.isclose(stored, ref, rtol=rtol, atol=0.0)
            if bad.any():
                li = int(np.nonzero(bad)[0][0])
                _dump_evidence("scrub_mismatch", dict(
                    grid_row=gi, network=nm, term=f"{term}[layer {li}]",
                    got=float(stored[li]), want=float(ref[li]),
                    rtol=rtol))
                return (f"stored per-layer {term} of grid row {gi}, "
                        f"network {nm} diverges from the reference "
                        f"recompute at layer {li} (got {stored[li]!r}, "
                        f"want {ref[li]!r}, rtol {rtol:g}) — the entry "
                        f"was poisoned before it was written")
    return None
