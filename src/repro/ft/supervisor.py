"""Fault-tolerance supervisor: checkpoint/restart, straggler mitigation,
preemption handling, elastic restart.

On a real fleet every worker runs the same program under this supervisor;
coordination state (who is alive, who is slow) comes from the cluster
scheduler.  The control logic is hardware-independent and is what we test:

* **checkpoint/restart** — periodic async checkpoints; on any step failure
  the loop restores the last committed step (params + optimizer + data
  position) and replays.  Deterministic data indexing makes the replay
  bit-exact.
* **straggler mitigation** — per-step deadline = ``straggler_factor`` × a
  running p50 of step times.  A step exceeding it is treated as a failed
  worker: the step is re-dispatched (on TPU pods: to a hot spare slice;
  here: re-executed).  Persistent stragglers trigger a restart-with-
  exclusion callback.
* **preemption** — SIGTERM-style preemption requests checkpoint-then-exit
  with a restartable state file.
* **elastic restart** — ``restart(new_mesh)`` restores the same checkpoint
  re-sharded onto a different device count (CheckpointManager re-shards on
  load).

``FaultInjector`` drives all of this in tests: it raises synthetic worker
failures / delays at configured steps.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

from ..checkpoint import CheckpointManager


class WorkerFailure(RuntimeError):
    pass


class Preemption(RuntimeError):
    pass


@dataclasses.dataclass
class FaultInjector:
    """Deterministic fault plan for tests: {step: 'fail'|'slow'|'preempt'}.

    ``sleep`` is injectable (a fake clock's ``sleep`` in tests) so "slow"
    steps don't depend on host timing; the default is wall-clock."""

    plan: Dict[int, str] = dataclasses.field(default_factory=dict)
    slow_s: float = 0.3
    fired: List[int] = dataclasses.field(default_factory=list)
    sleep: Callable[[float], None] = time.sleep

    def check(self, step: int):
        kind = self.plan.get(step)
        if kind is None or step in self.fired:
            return
        self.fired.append(step)
        if kind == "fail":
            raise WorkerFailure(f"injected worker failure at step {step}")
        if kind == "slow":
            self.sleep(self.slow_s)
        if kind == "preempt":
            raise Preemption(f"injected preemption at step {step}")


@dataclasses.dataclass
class Supervisor:
    ckpt: CheckpointManager
    checkpoint_every: int = 50
    straggler_factor: float = 5.0
    max_retries_per_step: int = 3
    min_timing_samples: int = 5
    # in-process re-execution needs a non-donating step_fn; steps that
    # donate device buffers (the production trainer) can only re-dispatch
    # to a hot spare holding its own replica — here we log the event and
    # carry on with the (successfully computed) result.
    reexecute_stragglers: bool = True
    # injectable time source (a deterministic fake in tests, like
    # DSEService's clock=); the default is wall-clock
    clock: Callable[[], float] = time.perf_counter

    def run(self, *, state: Any, step_fn: Callable[[Any, int], Any],
            num_steps: int, start_step: int = 0,
            injector: Optional[FaultInjector] = None,
            on_metrics: Optional[Callable[[int, Any], None]] = None) -> Any:
        """Run ``state = step_fn(state, step)`` with full FT semantics.

        ``state`` must be a pytree (params, opt state, data position, ...).
        Returns the final state.  Raises Preemption after a committed
        checkpoint when preempted.
        """
        times: List[float] = []
        step = start_step
        retries = 0
        events: List[str] = []
        self.events = events
        # (step, slow_dt, reexec_dt | None) per detected straggler
        stragglers: List[tuple] = []
        self.stragglers = stragglers

        while step < num_steps:
            t0 = self.clock()
            try:
                if injector is not None:
                    injector.check(step)
                new_state = step_fn(state, step)
                dt = self.clock() - t0

                # straggler detection (p50-based deadline); the slow
                # sample is NEVER appended to the p50 window — a burst of
                # stragglers must not inflate the deadline they are
                # measured against
                straggled = False
                if len(times) >= self.min_timing_samples:
                    med = sorted(times)[len(times) // 2]
                    if dt > self.straggler_factor * med:
                        straggled = True
                        dt2 = None
                        if self.reexecute_stragglers:
                            # re-dispatch once; deterministic step_fn makes
                            # the re-execution a hot-spare replay
                            t1 = self.clock()
                            new_state = step_fn(state, step)
                            dt2 = self.clock() - t1
                            times.append(dt2)
                        stragglers.append((step, dt, dt2))
                        events.append(
                            f"straggler@{step}:{dt:.3f}s"
                            + (f"->{dt2:.3f}s" if dt2 is not None else ""))
                if not straggled:
                    times.append(dt)
                state = new_state
                retries = 0

                if (step + 1) % self.checkpoint_every == 0:
                    self.ckpt.save(step + 1, state)
                    events.append(f"checkpoint@{step + 1}")
                step += 1

            except Preemption:
                self.ckpt.save(step, state, blocking=True)
                events.append(f"preempt-checkpoint@{step}")
                raise
            except WorkerFailure as e:
                retries += 1
                events.append(f"failure@{step}:{e}")
                if retries > self.max_retries_per_step:
                    raise
                # join in-flight async saves: a checkpoint written moments
                # before the failure must be visible to the restore
                self.ckpt.wait()
                restore_step = self.ckpt.latest_step()
                if restore_step is not None:
                    state, _ = self.ckpt.restore(state)
                    events.append(f"restore@{restore_step}")
                    step = restore_step
                # else: replay from current in-memory state (failure before
                # first checkpoint) — deterministic data makes this exact.
        self.ckpt.wait()
        return state
