"""Declarative hardware-fault scenarios for the modeled chip.

PR 6 made the *service* crash-safe; this module makes the *chip* faulty.
Array-based accelerators lose whole cores and individual PE rows/columns
(SCALE-Sim models exactly the ``rows × cols`` geometry our
``GRID_COLUMNS`` carries), and re-mapping a network's layers across the
survivors is a scheduling problem, not a restart — so a fault scenario
is declared as data and handed to the same batched solver that placed
the layers in the first place:

* :class:`CoreFailure` — a core type loses ``n`` whole cores (its count
  decrements, clamped at 0; a chip whose every count hits 0 is reported
  *infeasible*, not an error — ``batch_schedule_hetero(strict=False)``);
* :class:`DegradedArray` — ``k`` disabled PE rows/columns ⇒ the SAME
  config row with a shrunk ``rows``/``cols`` column (clamped at 1; a
  fully-dead array is a :class:`CoreFailure`, declare it as one).

:func:`expand_scenarios` turns a chip (flat grid rows + per-type core
counts) and a scenario list into a :class:`ScenarioBatch`: one union
:class:`~repro.core.accelerator.ConfigGrid` of nominal + degraded type
rows (deduped), a ``[n_scenario, n_types]`` row map into it, and the
``[n_scenario, n_types]`` surviving counts — i.e. a ``[n_scenario]``
batch of perturbed (counts, grid-rows) instances that ONE
``per_layer=True`` engine call and ONE batched schedule solve consume
(:func:`scenario_problems` builds the solver tensors in the scenario-
major / network-minor layout the co-design stack uses everywhere).

Seeded generators — :func:`all_single_core_failures` (the exhaustive
"what if core type t loses a core" sweep) and
:func:`random_degradations` (reproducible random PE-row/column loss) —
keep the CI chaos matrix deterministic.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple, Union

import numpy as np

from ..core.accelerator import ConfigGrid

FaultEvent = Union["CoreFailure", "DegradedArray"]


@dataclasses.dataclass(frozen=True)
class CoreFailure:
    """Whole-core loss: core type ``type_idx`` loses ``n`` cores."""

    type_idx: int
    n: int = 1

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"CoreFailure.n must be >= 1, got {self.n}")


@dataclasses.dataclass(frozen=True)
class DegradedArray:
    """``rows_lost`` PE rows / ``cols_lost`` PE columns of core type
    ``type_idx`` are disabled — the type's config row shrinks (never
    below a 1×1 array: a fully-dead array is a :class:`CoreFailure`)."""

    type_idx: int
    rows_lost: int = 0
    cols_lost: int = 0

    def __post_init__(self):
        if self.rows_lost < 0 or self.cols_lost < 0:
            raise ValueError("DegradedArray losses must be >= 0")
        if self.rows_lost == 0 and self.cols_lost == 0:
            raise ValueError("DegradedArray must disable >= 1 row or col")


@dataclasses.dataclass(frozen=True)
class FaultScenario:
    """A named set of simultaneous hardware faults on one chip."""

    name: str
    events: Tuple[FaultEvent, ...]

    def key(self) -> tuple:
        """Hashable identity (the service's re-schedule cache key)."""
        return tuple(
            (type(e).__name__,) + dataclasses.astuple(e)
            for e in self.events)


def scenario_to_json(scenario: FaultScenario) -> dict:
    """Plain-JSON form of a scenario (the request journal's wire format)."""
    return {
        "name": scenario.name,
        "events": [
            dict(kind=type(e).__name__, **dataclasses.asdict(e))
            for e in scenario.events
        ],
    }


def scenario_from_json(obj: dict) -> FaultScenario:
    """Inverse of :func:`scenario_to_json`; round-trips :meth:`key`."""
    events: List[FaultEvent] = []
    for ev in obj["events"]:
        ev = dict(ev)
        kind = ev.pop("kind")
        if kind == "CoreFailure":
            events.append(CoreFailure(**ev))
        elif kind == "DegradedArray":
            events.append(DegradedArray(**ev))
        else:
            raise ValueError(f"unknown fault-event kind {kind!r}")
    return FaultScenario(name=obj["name"], events=tuple(events))


def apply_counts(counts: Sequence[int], scenario: FaultScenario
                 ) -> np.ndarray:
    """Surviving per-type core counts under ``scenario`` (clamped at 0)."""
    out = np.asarray(counts, dtype=np.int64).copy()
    for ev in scenario.events:
        if not 0 <= ev.type_idx < out.shape[0]:
            raise ValueError(
                f"scenario {scenario.name!r}: type_idx {ev.type_idx} out "
                f"of range for a {out.shape[0]}-type chip")
        if isinstance(ev, CoreFailure):
            out[ev.type_idx] = max(int(out[ev.type_idx]) - ev.n, 0)
    return out


def degrade_rows(grid: ConfigGrid, rows_lost: int, cols_lost: int
                 ) -> ConfigGrid:
    """Every row of ``grid`` with ``rows_lost``/``cols_lost`` PEs
    disabled: the ``rows``/``cols`` columns shrink, clamped at 1."""
    f = grid.fields
    return grid.with_columns(
        rows=np.maximum(f["rows"] - rows_lost, 1.0),
        cols=np.maximum(f["cols"] - cols_lost, 1.0))


@dataclasses.dataclass(frozen=True)
class ScenarioBatch:
    """A chip expanded into a ``[n_scenario]`` batch of perturbed
    (counts, grid-rows) instances over ONE union grid."""

    names: Tuple[str, ...]         # scenario names (nominal first if kept)
    grid: ConfigGrid               # nominal type rows + degraded variants
    type_rows: np.ndarray          # [S, T] row into grid per (scen, type)
    counts: np.ndarray             # [S, T] surviving cores
    nominal_first: bool            # row 0 is the fault-free chip

    @property
    def n_scenarios(self) -> int:
        return len(self.names)

    @property
    def n_types(self) -> int:
        return int(self.type_rows.shape[1])


def expand_scenarios(grid: ConfigGrid, chip_types: Sequence[int],
                     chip_counts: Sequence[int],
                     scenarios: Sequence[FaultScenario],
                     *, include_nominal: bool = True) -> ScenarioBatch:
    """Chip × scenario list → the batched (counts, grid-rows) instances.

    ``chip_types`` are flat rows of ``grid`` (the co-design result
    format), ``chip_counts`` the matching core counts.  Degraded rows are
    deduped on their full config-row columns, so two scenarios degrading
    the same type the same way share one union row (and one engine
    evaluation)."""
    chip_types = [int(c) for c in chip_types]
    n_t = len(chip_types)
    if len(chip_counts) != n_t:
        raise ValueError(f"{n_t} chip types but {len(chip_counts)} counts")
    nominal = grid.take(chip_types)
    union = [nominal]
    row_keys = {tuple(float(nominal.fields[k][t])
                      for k in sorted(nominal.fields)): t
                for t in range(n_t)}
    next_row = n_t

    names: List[str] = []
    rows_l: List[np.ndarray] = []
    counts_l: List[np.ndarray] = []
    if include_nominal:
        names.append("nominal")
        rows_l.append(np.arange(n_t, dtype=np.intp))
        counts_l.append(np.asarray(chip_counts, dtype=np.int64))
    for sc in scenarios:
        rows = np.arange(n_t, dtype=np.intp)
        for ev in sc.events:
            if isinstance(ev, DegradedArray):
                if not 0 <= ev.type_idx < n_t:
                    raise ValueError(
                        f"scenario {sc.name!r}: type_idx {ev.type_idx} "
                        f"out of range for a {n_t}-type chip")
                deg = degrade_rows(nominal.take([ev.type_idx]),
                                   ev.rows_lost, ev.cols_lost)
                key = tuple(float(deg.fields[k][0])
                            for k in sorted(deg.fields))
                if key not in row_keys:
                    row_keys[key] = next_row
                    union.append(deg)
                    next_row += 1
                rows = rows.copy()
                rows[ev.type_idx] = row_keys[key]
        names.append(sc.name)
        rows_l.append(rows)
        counts_l.append(apply_counts(chip_counts, sc))
    return ScenarioBatch(
        names=tuple(names), grid=ConfigGrid.concat(union),
        type_rows=np.stack(rows_l) if rows_l else
        np.zeros((0, n_t), np.intp),
        counts=np.stack(counts_l) if counts_l else
        np.zeros((0, n_t), np.int64),
        nominal_first=include_nominal)


def scenario_problems(batch: ScenarioBatch, e_layer: np.ndarray,
                      t_layer: np.ndarray, lens: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray]:
    """Solver tensors for one expanded chip, scenario-major network-minor
    (the co-design stack's chip-major layout with scenarios as chips).

    ``e_layer``/``t_layer`` are the union grid's ``per_layer=True``
    outputs ``[batch.grid.n, n_net, L]``; ``lens`` the true per-network
    layer counts.  Returns ``(lat [S·n_net, T, L], counts [S·n_net, T],
    n_layers [S·n_net], energy [S·n_net, T, L])`` — ready for ONE
    ``batch_schedule_hetero(strict=False)`` call (problem ``s·n_net + j``
    is scenario ``s`` × network ``j``)."""
    S, T = batch.type_rows.shape
    n_net, L = t_layer.shape[1], t_layer.shape[2]
    lat = t_layer[batch.type_rows]           # [S, T, n_net, L]
    en = e_layer[batch.type_rows]
    lat = lat.transpose(0, 2, 1, 3).reshape(S * n_net, T, L)
    en = en.transpose(0, 2, 1, 3).reshape(S * n_net, T, L)
    counts = np.repeat(batch.counts, n_net, axis=0)
    n_layers = np.tile(np.asarray(lens, dtype=np.int64), S)
    return lat, counts, n_layers, en


def all_single_core_failures(counts: Sequence[int],
                             ) -> List[FaultScenario]:
    """One scenario per populated core type: that type loses one core —
    the exhaustive first-order whole-core fault sweep."""
    return [FaultScenario(name=f"core_loss_t{t}",
                          events=(CoreFailure(type_idx=t),))
            for t, c in enumerate(counts) if int(c) > 0]


def random_degradations(seed: int, grid: ConfigGrid,
                        chip_types: Sequence[int], *,
                        n_scenarios: int = 4,
                        max_frac: float = 0.5) -> List[FaultScenario]:
    """``n_scenarios`` reproducible degraded-array scenarios: each picks
    one chip type and disables a seeded-random number of PE rows and/or
    columns, at most ``max_frac`` of the type's array in each dimension
    (and always ≥ 1 PE line total, never the whole array)."""
    rng = np.random.default_rng(seed)
    chip_types = [int(c) for c in chip_types]
    out: List[FaultScenario] = []
    for i in range(int(n_scenarios)):
        t = int(rng.integers(len(chip_types)))
        rows = int(grid.fields["rows"][chip_types[t]])
        cols = int(grid.fields["cols"][chip_types[t]])
        max_r = max(int(rows * max_frac), 0)
        max_c = max(int(cols * max_frac), 0)
        r = int(rng.integers(0, max_r + 1))
        c = int(rng.integers(0, max_c + 1))
        if r == 0 and c == 0:
            r = 1 if max_r else 0
            c = 0 if max_r else 1
        out.append(FaultScenario(
            name=f"degrade_s{seed}_{i}_t{t}_r{r}c{c}",
            events=(DegradedArray(type_idx=t, rows_lost=r, cols_lost=c),)))
    return out
