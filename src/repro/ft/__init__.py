from .supervisor import Supervisor, FaultInjector  # noqa: F401
from .faults import (BackendFault, FaultPlan, ProcessKill,  # noqa: F401
                     StreamKill, inject_chunk_faults)
from .verify import (FoldInvariantError, ShadowMismatchError,  # noqa: F401
                     StreamVerifier, VerifyConfig,
                     check_layer_topk_result, scrub_layer_topk)
from .hw_faults import (CoreFailure, DegradedArray,  # noqa: F401
                        FaultScenario, ScenarioBatch,
                        all_single_core_failures, apply_counts,
                        degrade_rows, expand_scenarios,
                        random_degradations, scenario_problems)
