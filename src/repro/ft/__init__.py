from .supervisor import Supervisor, FaultInjector  # noqa: F401
from .faults import (BackendFault, FaultPlan, StreamKill,  # noqa: F401
                     inject_chunk_faults)
