from .supervisor import Supervisor, FaultInjector  # noqa: F401
