from .supervisor import Supervisor, FaultInjector  # noqa: F401
from .faults import (BackendFault, FaultPlan, StreamKill,  # noqa: F401
                     inject_chunk_faults)
from .hw_faults import (CoreFailure, DegradedArray,  # noqa: F401
                        FaultScenario, ScenarioBatch,
                        all_single_core_failures, apply_counts,
                        degrade_rows, expand_scenarios,
                        random_degradations, scenario_problems)
