"""Deterministic fault injection for the streamed DSE engine.

:class:`FaultPlan` is the chunk-level analogue of
:class:`repro.ft.supervisor.FaultInjector` (which drives the training-loop
supervisor): it installs into the streaming engine's per-chunk seam
(``repro.core.energymodel._CHUNK_HOOK``, applied to every chunk's raw
``(e, t)`` evaluation right before the fold) and fires three fault kinds at
chosen chunk indices:

* ``fail_at``   — raise :class:`BackendFault` (a transient backend death;
  the service layer's retry/backoff path rides this),
* ``corrupt_at`` — overwrite one seeded-random element of the chunk's
  energies (``target="e"``) or latencies (``target="t"``) with NaN or
  +inf (silent data corruption; the engine's NaN/inf guard checks BOTH
  tensors and must detect it BEFORE the fold commits, raising
  :class:`repro.core.energymodel.ChunkCorruption` with chunk provenance),
* ``kill_at``   — raise :class:`StreamKill` (a simulated process death
  mid-stream; recovery resumes from the last exported
  :class:`repro.core.energymodel.StreamFoldState` and must be bit-exact),
* ``perturb_at`` — multiply one seeded-random element of the chunk's
  energies or latencies by ``1 + perturb_rel`` (a FINITE silent data
  corruption — the bit-flip / kernel-miscompile model; the NaN/inf guard
  can NOT see it, only :class:`repro.ft.verify.StreamVerifier`'s shadow
  recompute catches it).

Everything is deterministic given (plan, seed): ``FaultPlan.random`` builds
a reproducible plan from a seed, and corruption positions derive from
``(seed, chunk_index)`` — the CI chaos job replays a fixed seed matrix.
``fail_at`` counts down (a chunk can fail N times then succeed) and
``corrupt_at``/``kill_at`` fire once, so retry loops terminate; ``fired``
records every injection for assertions.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import energymodel


class BackendFault(RuntimeError):
    """Injected transient backend failure (retryable)."""


class StreamKill(RuntimeError):
    """Injected mid-stream kill (simulated process death).

    The service retry ladder treats this as recoverable: it resumes from
    the last checkpoint inside the same process."""


class ProcessKill(BaseException):
    """Injected whole-PROCESS death (kill -9 analogue).

    Deliberately a :class:`BaseException` so generic ``except Exception``
    retry/backoff paths do NOT swallow it — it must propagate all the way
    out of ``DSEService.step()``, leaving queues, caches and half-written
    state exactly as the kill found them.  The durable-service chaos tests
    then construct a FRESH service over the same ``state_dir`` and assert
    journal replay + checkpoint recovery drain to bit-identical answers."""


@dataclasses.dataclass
class FaultPlan:
    """Seeded, chunk-indexed fault schedule; callable as the chunk hook."""

    fail_at: Dict[int, int] = dataclasses.field(default_factory=dict)
    corrupt_at: Dict[int, str] = dataclasses.field(default_factory=dict)
    kill_at: Optional[int] = None
    pkill_at: Optional[int] = None   # whole-process kill (ProcessKill)
    # finite corruption: chunk -> relative perturbation of one element
    perturb_at: Dict[int, float] = dataclasses.field(default_factory=dict)
    perturb_rel: float = 1e-3
    seed: int = 0
    target: str = "e"              # corruption tensor: "e" | "t"
    fired: List[Tuple[int, str]] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if self.target not in ("e", "t"):
            raise ValueError(f"FaultPlan.target must be 'e' or 't', got "
                             f"{self.target!r}")

    @classmethod
    def random(cls, seed: int, n_chunks: int, *, p_fail: float = 0.2,
               p_corrupt: float = 0.1, max_fails: int = 2,
               p_perturb: float = 0.0,
               perturb_rel: float = 1e-3) -> "FaultPlan":
        """Reproducible random plan over ``n_chunks`` chunk indices.

        Per-chunk fail counts stay ≤ ``max_fails`` so any retry budget
        > ``max_fails`` is guaranteed to converge.  The corruption target
        is a seeded coin flip between the energy and latency tensors, so
        the chaos matrix exercises the latency-side guard path too.
        ``p_perturb`` adds seeded FINITE corruptions (``perturb_at``);
        its draws come after all existing ones so plans built with
        ``p_perturb=0`` are bit-identical to plans built before the knob
        existed."""
        rng = np.random.default_rng(seed)
        target = "e" if rng.random() < 0.5 else "t"
        fail_at = {ci: int(rng.integers(1, max_fails + 1))
                   for ci in range(n_chunks) if rng.random() < p_fail}
        corrupt_at = {ci: ("nan" if rng.random() < 0.5 else "inf")
                      for ci in range(n_chunks)
                      if rng.random() < p_corrupt}
        perturb_at = {ci: perturb_rel for ci in range(n_chunks)
                      if rng.random() < p_perturb and ci not in corrupt_at}
        return cls(fail_at=fail_at, corrupt_at=corrupt_at,
                   perturb_at=perturb_at, perturb_rel=perturb_rel,
                   seed=seed, target=target)

    def __call__(self, ci: int, e, t):
        if self.pkill_at is not None and ci == self.pkill_at:
            self.pkill_at = None
            self.fired.append((ci, "pkill"))
            raise ProcessKill(f"injected process kill at chunk {ci}")
        if self.kill_at is not None and ci == self.kill_at:
            self.kill_at = None
            self.fired.append((ci, "kill"))
            raise StreamKill(f"injected kill at chunk {ci}")
        left = self.fail_at.get(ci, 0)
        if left > 0:
            self.fail_at[ci] = left - 1
            self.fired.append((ci, "fail"))
            raise BackendFault(f"injected backend failure at chunk {ci}")
        kind = self.corrupt_at.pop(ci, None)
        if kind is not None:
            self.fired.append((ci, kind))
            victim = e if self.target == "e" else t
            victim = np.array(np.asarray(victim), dtype=np.float64,
                              copy=True)
            rng = np.random.default_rng(self.seed * 1_000_003 + ci)
            flat = int(rng.integers(victim.size))
            victim.reshape(-1)[flat] = np.nan if kind == "nan" else np.inf
            if self.target == "e":
                e = victim
            else:
                t = victim
        rel = self.perturb_at.pop(ci, None)
        if rel is not None:
            # finite silent corruption: scale ONE element by (1 + rel) —
            # stays finite and plausible, so only the shadow recompute
            # (never the NaN/inf guard) can catch it.  Pop-once, so the
            # service's retry of the failed chunk is clean.
            self.fired.append((ci, "perturb"))
            victim = e if self.target == "e" else t
            victim = np.array(np.asarray(victim), dtype=np.float64,
                              copy=True)
            rng = np.random.default_rng(self.seed * 1_000_003 + ci)
            # pick among NONZERO finite elements: scaling a zero (the
            # per-layer tensors zero-pad each network's layer tail) would
            # be a no-op, not a corruption
            flat_v = victim.reshape(-1)
            eligible = np.nonzero(np.isfinite(flat_v) & (flat_v != 0.0))[0]
            flat = int(eligible[rng.integers(eligible.size)]
                       if eligible.size else rng.integers(flat_v.size))
            flat_v[flat] *= (1.0 + rel)
            if self.target == "e":
                e = victim
            else:
                t = victim
        return e, t


@contextlib.contextmanager
def inject_chunk_faults(plan: FaultPlan):
    """Install ``plan`` as the streaming engine's chunk hook for the block.

    Nesting restores the previous hook on exit, so a test can layer a kill
    plan over a service's own instrumentation."""
    prev = energymodel._CHUNK_HOOK
    energymodel._CHUNK_HOOK = plan
    try:
        yield plan
    finally:
        energymodel._CHUNK_HOOK = prev
