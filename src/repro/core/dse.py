"""Design-space exploration (§III–§IV): sweeps, and the statistics of
Tables 1–4 (equations (2)–(5)).

The search space is the paper's: GB_psum × GB_ifmap ∈ {13, 27, 54, 108,
216}KB² and six array sizes — 150 points per network.  The whole space is
evaluated in one vectorised call to the Tool.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .accelerator import (ARRAY_SIZES, GB_SIZES_KB, AcceleratorConfig)
from . import energymodel
from .topology import Layer


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Energy / latency over the full (array × psum × ifmap) grid."""

    network: str
    arrays: Tuple[Tuple[int, int], ...]
    psum_kb: Tuple[int, ...]
    ifmap_kb: Tuple[int, ...]
    energy: np.ndarray      # [n_array, n_psum, n_ifmap]
    latency: np.ndarray     # [n_array, n_psum, n_ifmap]

    @property
    def edp(self) -> np.ndarray:
        return self.energy * self.latency

    def argmin_cell(self, metric: str = "edp") -> Tuple[int, int, int]:
        arr = getattr(self, metric) if metric != "edp" else self.edp
        return tuple(np.unravel_index(int(np.argmin(arr)), arr.shape))

    def cell_label(self, cell: Tuple[int, int, int]) -> str:
        a, p, i = cell
        return (f"({self.psum_kb[p]}/{self.ifmap_kb[i]}, "
                f"[{self.arrays[a][0]},{self.arrays[a][1]}])")


def sweep_network(layers: Sequence[Layer], network: str = "net",
                  arrays: Sequence[Tuple[int, int]] = ARRAY_SIZES,
                  psum_kb: Sequence[int] = GB_SIZES_KB,
                  ifmap_kb: Sequence[int] = GB_SIZES_KB,
                  base: AcceleratorConfig | None = None,
                  use_jax: bool = False) -> SweepResult:
    base = base or AcceleratorConfig()
    cfgs: List[AcceleratorConfig] = []
    for (r, c) in arrays:
        for p in psum_kb:
            for i in ifmap_kb:
                cfgs.append(base.replace(array_rows=r, array_cols=c,
                                         gb_psum_kb=float(p),
                                         gb_ifmap_kb=float(i)))
    e, t = energymodel.simulate_grid(cfgs, layers, use_jax=use_jax)
    shape = (len(arrays), len(psum_kb), len(ifmap_kb))
    return SweepResult(network=network, arrays=tuple(arrays),
                       psum_kb=tuple(psum_kb), ifmap_kb=tuple(ifmap_kb),
                       energy=e.reshape(shape), latency=t.reshape(shape))


# ---------------------------------------------------------------------------
# Tables 1–2: sweep one GB partition with the other held at the 25-point
# minimum's value (equations (2) and (3)).
# ---------------------------------------------------------------------------

def mu_delta(sweep: SweepResult, swept: str = "ifmap"
             ) -> Dict[Tuple[int, int], Tuple[float, float]]:
    """μ^p_min and δ^max_min per array size, for the swept partition.

    ``swept='ifmap'`` reproduces Table 1 (GB_psum held at the value of the
    per-array minimum); ``swept='psum'`` reproduces Table 2.
    """
    out = {}
    for a, arr in enumerate(sweep.arrays):
        plane = sweep.energy[a]               # [psum, ifmap]
        pi_min = np.unravel_index(int(np.argmin(plane)), plane.shape)
        if swept == "ifmap":
            line = plane[pi_min[0], :]
        else:
            line = plane[:, pi_min[1]]
        e_min = float(line.min())
        others = line[line != line.min()] if line.size > 1 else line
        n = line.size
        mu = float(((line - e_min) / e_min * 100.0).sum() / (n - 1))
        delta = float((line.max() - e_min) / e_min * 100.0)
        out[arr] = (mu, delta)
    return out


def delta_whole_space(sweep: SweepResult) -> Dict[Tuple[int, int], float]:
    """Table 3: Δ^max_min over the 25 (psum × ifmap) points per array."""
    out = {}
    for a, arr in enumerate(sweep.arrays):
        plane = sweep.energy[a]
        out[arr] = float((plane.max() - plane.min()) / plane.min() * 100.0)
    return out


def edp_spread(sweep: SweepResult) -> Tuple[float, float]:
    """Table 4: mean and max of (EDP_i − EDP_min)/EDP_min over all points."""
    edp = sweep.edp.ravel()
    edp_min = float(edp.min())
    rel = (edp - edp_min) / edp_min * 100.0
    return float(rel.mean()), float(rel.max())


def boundary_configs(sweep: SweepResult, bound: float = 0.05,
                     metric: str = "edp") -> List[Tuple[int, int, int]]:
    """Table 5: all cells within ``bound`` of the minimum (min cell first)."""
    arr = sweep.edp if metric == "edp" else getattr(sweep, metric)
    mn = float(arr.min())
    cells = [tuple(map(int, c))
             for c in np.argwhere(arr <= mn * (1.0 + bound))]
    cells.sort(key=lambda c: float(arr[c]))
    return cells
