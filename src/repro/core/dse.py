"""Design-space exploration (§III–§IV): sweeps, and the statistics of
Tables 1–4 (equations (2)–(5)).

The search space is the paper's by default: GB_psum × GB_ifmap ∈ {13, 27,
54, 108, 216}KB² and six array sizes — 150 points per network — but the
engine is built for much larger spaces (finer GB grids, RF sizes, NoC
widths; see :func:`repro.core.accelerator.extended_grid`).  Grids are
constructed directly as arrays (:class:`ConfigGrid`), never as per-point
config objects, and :func:`sweep_networks` evaluates every network against
the full grid in ONE batched, jit-cached call to the Tool.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence, Tuple

import numpy as np

from .accelerator import (ARRAY_SIZES, GB_SIZES_KB, AcceleratorConfig,
                          ConfigGrid)
from . import energymodel
from .topology import Layer


def _use_jax_default() -> bool:
    return energymodel.jax_available()


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Energy / latency over the full (array × psum × ifmap) grid."""

    network: str
    arrays: Tuple[Tuple[int, int], ...]
    psum_kb: Tuple[int, ...]
    ifmap_kb: Tuple[int, ...]
    energy: np.ndarray      # [n_array, n_psum, n_ifmap]
    latency: np.ndarray     # [n_array, n_psum, n_ifmap]

    @property
    def edp(self) -> np.ndarray:
        return self.energy * self.latency

    def metric(self, name: str = "edp") -> np.ndarray:
        return self.edp if name == "edp" else getattr(self, name)

    def argmin_cell(self, metric: str = "edp") -> Tuple[int, int, int]:
        arr = self.metric(metric)
        return tuple(np.unravel_index(int(np.argmin(arr)), arr.shape))

    def cell_label(self, cell: Tuple[int, int, int]) -> str:
        a, p, i = cell
        return (f"({self.psum_kb[p]}/{self.ifmap_kb[i]}, "
                f"[{self.arrays[a][0]},{self.arrays[a][1]}])")


def _paper_grid(arrays, psum_kb, ifmap_kb,
                base: AcceleratorConfig | None) -> ConfigGrid:
    return ConfigGrid.product(arrays=arrays, gb_psum_kb=psum_kb,
                              gb_ifmap_kb=ifmap_kb, base=base)


def sweep_networks(networks: Mapping[str, Sequence[Layer]],
                   arrays: Sequence[Tuple[int, int]] = ARRAY_SIZES,
                   psum_kb: Sequence[int] = GB_SIZES_KB,
                   ifmap_kb: Sequence[int] = GB_SIZES_KB,
                   base: AcceleratorConfig | None = None,
                   use_jax: bool | None = None,
                   backend: str | None = None,
                   shard: bool = False,
                   chunk_size: int | None = None) -> Dict[str, SweepResult]:
    """Sweep EVERY network over the whole grid in one compiled call.

    This is the batched entry point: the config cross product is built as
    arrays, all networks' layers share one padded trace, and the jitted
    kernel is cached at module level — repeated sweeps never retrace.
    ``backend`` picks the heavy-stage kernel (``"pallas"`` routes through
    the fused count-terms kernel, with auto-fallback to jax/numpy);
    ``shard=True`` spreads the config axis over all host devices (see
    :func:`energymodel.request_host_devices`); ``chunk_size`` bounds the
    engine's per-dispatch intermediates on large grids.
    """
    grid = _paper_grid(arrays, psum_kb, ifmap_kb, base)
    e, t = energymodel.evaluate_networks(grid, networks, use_jax=use_jax,
                                         backend=backend, shard=shard,
                                         chunk_size=chunk_size)
    shape = (len(arrays), len(psum_kb), len(ifmap_kb))
    out = {}
    for j, name in enumerate(networks):
        out[name] = SweepResult(
            network=name, arrays=tuple(arrays), psum_kb=tuple(psum_kb),
            ifmap_kb=tuple(ifmap_kb), energy=e[:, j].reshape(shape),
            latency=t[:, j].reshape(shape))
    return out


def layer_metrics(networks: Mapping[str, Sequence[Layer]],
                  grid: ConfigGrid | None = None,
                  **kwargs) -> Tuple[np.ndarray, np.ndarray]:
    """Per-layer energy/latency tensors over a grid (default: the paper's
    150-point space): ``evaluate_networks(..., per_layer=True)`` →
    ``[n_cfg, n_net, n_layer]`` pairs, zero-padded past each network's
    length (:func:`energymodel.network_layer_counts`).  These are the
    operands of the heterogeneous co-design stack
    (:func:`repro.core.hetero.co_design` /
    :func:`repro.core.partition.batch_schedule_hetero`); keyword
    arguments forward to :func:`energymodel.evaluate_networks`
    (``backend``, ``shard``, ``chunk_size``, ``use_jax``)."""
    if grid is None:
        grid = _paper_grid(ARRAY_SIZES, GB_SIZES_KB, GB_SIZES_KB, None)
    return energymodel.evaluate_networks(grid, networks, per_layer=True,
                                         **kwargs)


def stream_layer_grid(networks: Mapping[str, Sequence[Layer]],
                      grid: ConfigGrid,
                      **kwargs) -> "energymodel.LayerTopK":
    """Streaming PER-LAYER sweep of an arbitrary ConfigGrid: one chunked
    pass folds every chunk into on-device running reductions — per-network
    top-k configs WITH their ``[n_layer]`` energy/latency rows, aggregate
    and per-(network, layer) minima, and (with ``bound=``) the ≤bound
    boundary candidate sets that
    :func:`repro.core.hetero.codesign_problems_streaming` builds the
    co-design pool from.  The ``[n_cfg, n_net, n_layer]`` tensors are
    never materialised, so mega-scale grids stream at bounded memory.
    Keyword arguments forward to
    :func:`repro.core.energymodel.stream_layer_topk` (``topk``, ``bound``,
    ``chunk_size``, ``shard``, ``metric``, ``use_jax``, ``backend``)."""
    return energymodel.stream_layer_topk(grid, networks, **kwargs)


def stream_grid(networks: Mapping[str, Sequence[Layer]],
                grid: ConfigGrid,
                **kwargs) -> "energymodel.StreamResult":
    """Streaming sweep of an arbitrary ConfigGrid: chunked evaluation with
    on-device running reductions (per-network minima, top-k cells, and the
    ≤bound boundary sets that :func:`repro.core.hetero.design_chip_streaming`
    consumes) — the full [n_cfg, n_net] matrices are never materialised.
    Keyword arguments forward to :func:`energymodel.stream_networks`
    (``chunk_size``, ``shard``, ``bound``, ``metric``, ``topk``,
    ``use_jax``, ``backend``)."""
    return energymodel.stream_networks(grid, networks, **kwargs)


def sweep_network(layers: Sequence[Layer], network: str = "net",
                  arrays: Sequence[Tuple[int, int]] = ARRAY_SIZES,
                  psum_kb: Sequence[int] = GB_SIZES_KB,
                  ifmap_kb: Sequence[int] = GB_SIZES_KB,
                  base: AcceleratorConfig | None = None,
                  use_jax: bool | None = None,
                  backend: str | None = None) -> SweepResult:
    """Single-network sweep (thin wrapper over :func:`sweep_networks`)."""
    return sweep_networks({network: layers}, arrays=arrays, psum_kb=psum_kb,
                          ifmap_kb=ifmap_kb, base=base,
                          use_jax=use_jax, backend=backend)[network]


# ---------------------------------------------------------------------------
# Tables 1–2: sweep one GB partition with the other held at the 25-point
# minimum's value (equations (2) and (3)).  All statistics below are
# vectorised over the array axis — no per-cell Python loops — so they stay
# cheap when the grid grows to thousands of points.
# ---------------------------------------------------------------------------

def mu_delta(sweep: SweepResult, swept: str = "ifmap"
             ) -> Dict[Tuple[int, int], Tuple[float, float]]:
    """μ^p_min and δ^max_min per array size, for the swept partition.

    ``swept='ifmap'`` reproduces Table 1 (GB_psum held at the value of the
    per-array minimum); ``swept='psum'`` reproduces Table 2.
    """
    e = sweep.energy                              # [nA, nP, nI]
    n_a, n_p, n_i = e.shape
    flat = e.reshape(n_a, -1)
    p_min, i_min = np.unravel_index(np.argmin(flat, axis=1), (n_p, n_i))
    ar = np.arange(n_a)
    if swept == "ifmap":
        lines = e[ar, p_min, :]                   # [nA, nI]
    else:
        lines = e[ar, :, i_min]                   # [nA, nP]
    e_min = lines.min(axis=1, keepdims=True)
    n = lines.shape[1]
    mu = ((lines - e_min) / e_min * 100.0).sum(axis=1) / (n - 1)
    delta = ((lines.max(axis=1, keepdims=True) - e_min)
             / e_min * 100.0)[:, 0]
    return {arr: (float(mu[a]), float(delta[a]))
            for a, arr in enumerate(sweep.arrays)}


def delta_whole_space(sweep: SweepResult) -> Dict[Tuple[int, int], float]:
    """Table 3: Δ^max_min over the (psum × ifmap) points per array."""
    flat = sweep.energy.reshape(len(sweep.arrays), -1)
    mn, mx = flat.min(axis=1), flat.max(axis=1)
    d = (mx - mn) / mn * 100.0
    return {arr: float(d[a]) for a, arr in enumerate(sweep.arrays)}


def edp_spread(sweep: SweepResult) -> Tuple[float, float]:
    """Table 4: mean and max of (EDP_i − EDP_min)/EDP_min over all points."""
    edp = sweep.edp.ravel()
    edp_min = float(edp.min())
    rel = (edp - edp_min) / edp_min * 100.0
    return float(rel.mean()), float(rel.max())


def boundary_configs(sweep: SweepResult, bound: float = 0.05,
                     metric: str = "edp") -> List[Tuple[int, int, int]]:
    """Table 5: all cells within ``bound`` of the minimum (min cell first)."""
    arr = sweep.metric(metric)
    mn = float(arr.min())
    cells = np.argwhere(arr <= mn * (1.0 + bound))
    vals = arr[tuple(cells.T)]
    order = np.argsort(vals, kind="stable")
    return [tuple(int(x) for x in cells[k]) for k in order]
