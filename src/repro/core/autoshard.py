"""Sharding-policy DSE — the paper's §III/§IV search, TPU edition.

The paper sweeps (GB_psum, GB_ifmap, array) per network, finds per-network
near-optimal configurations within a 5% boundary, and groups networks onto a
few heterogeneous core types (Table 5 → chip design).  Here the search space
is the *sharding policy* on a fixed fabric: (dp × tp) factorizations of the
mesh, fsdp depth, microbatch count.  The objective is the cost-model step
time (EDP-like trade-offs available via the ``metric`` argument: TPU "energy"
is approximated as chip-seconds, so EDP ∝ step_s²·chips).

``design_fleet`` is the Table-5 analogue: per-architecture candidate sets
within a boundary of each arch's optimum, covered greedily by a few common
policies → a fleet runs every model near-optimally with a handful of
launch configs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from ..configs.base import ModelConfig
from .tpu_costmodel import ShardingPolicy, step_time


def candidate_policies(n_chips: int, max_tp: int = 64,
                       microbatch_opts: Sequence[int] = (1, 2, 4, 8, 16),
                       ) -> List[ShardingPolicy]:
    out = []
    tp = 1
    while tp <= min(max_tp, n_chips):
        dp = n_chips // tp
        if dp * tp == n_chips:
            for m in microbatch_opts:
                for fsdp in {1, dp}:
                    out.append(ShardingPolicy(
                        name=f"dp{dp}_tp{tp}_fsdp{fsdp}_m{m}",
                        dp=dp, tp=tp, fsdp=fsdp, microbatches=m))
        tp *= 2
    return out


def score(cfg: ModelConfig, pol: ShardingPolicy, *, seq_len: int,
          global_batch: int, training: bool = True,
          metric: str = "step") -> float:
    st = step_time(cfg, pol, seq_len=seq_len, global_batch=global_batch,
                   training=training)
    if metric == "step":
        return st["step_s"]
    if metric == "edp":                   # chip-seconds × seconds
        return st["step_s"] ** 2 * pol.chips
    if metric == "energy":                # ∝ chip-seconds
        return st["step_s"] * pol.chips
    raise ValueError(metric)


def sweep(cfg: ModelConfig, *, n_chips: int, seq_len: int, global_batch: int,
          training: bool = True, metric: str = "step"
          ) -> List[Tuple[ShardingPolicy, float]]:
    cands = candidate_policies(n_chips)
    # batch divisibility constraint
    cands = [p for p in cands
             if global_batch % (p.dp * p.microbatches // p.dp if p.dp else 1)
             == 0 and global_batch % p.dp == 0]
    scored = [(p, score(cfg, p, seq_len=seq_len, global_batch=global_batch,
                        training=training, metric=metric)) for p in cands]
    scored.sort(key=lambda x: x[1])
    return scored


def boundary_set(cfg: ModelConfig, *, n_chips: int, seq_len: int,
                 global_batch: int, bound: float = 0.05,
                 metric: str = "step") -> List[str]:
    """Table-5 analogue: policy names within ``bound`` of this arch's best."""
    scored = sweep(cfg, n_chips=n_chips, seq_len=seq_len,
                   global_batch=global_batch, metric=metric)
    best = scored[0][1]
    return [p.name for p, s in scored if s <= best * (1 + bound)]


def design_fleet(archs: Dict[str, ModelConfig], *, n_chips: int,
                 seq_len: int, global_batch: int, bound: float = 0.05,
                 max_policies: int = 3, metric: str = "step"
                 ) -> Dict[str, object]:
    """Greedy common-policy cover over per-arch 5% boundary sets."""
    cand = {name: set(boundary_set(c, n_chips=n_chips, seq_len=seq_len,
                                   global_batch=global_batch, bound=bound,
                                   metric=metric))
            for name, c in archs.items()}
    uncovered = set(cand)
    chosen: List[str] = []
    assignment: Dict[str, str] = {}
    while uncovered and len(chosen) < max_policies:
        counts: Dict[str, List[str]] = {}
        for a in uncovered:
            for p in cand[a]:
                counts.setdefault(p, []).append(a)
        if not counts:
            break
        pol, archs_cov = max(counts.items(), key=lambda kv: len(kv[1]))
        chosen.append(pol)
        for a in archs_cov:
            assignment[a] = pol
        uncovered -= set(archs_cov)
    for a in sorted(uncovered):
        # fall back: best already-chosen policy for this arch
        scored = sweep(archs[a], n_chips=n_chips, seq_len=seq_len,
                       global_batch=global_batch, metric=metric)
        by_name = {p.name: s for p, s in scored}
        assignment[a] = min(chosen, key=lambda p: by_name.get(p, 1e30))
    return dict(policies=chosen, assignment=assignment, candidates=cand)
