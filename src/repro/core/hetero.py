"""Heterogeneous multi-core chip scheme (§IV.A).

Procedure, as the paper describes it:

1.  For every network, evaluate the target metric (EDP by default) over the
    whole search space and keep every configuration within a boundary (5%)
    of that network's minimum → candidate sets (Table 5).
2.  Select a small number of *common* configurations such that the maximum
    number of networks runs near-optimally → the chip's core types (greedy
    set cover over the candidate sets).
3.  Every network is assigned to the core type that covers it (or, if none
    covers it within the boundary, the type with the least penalty).

``cross_penalty`` reproduces Table 6: the increase in energy, delay, and EDP
when a network runs on a non-corresponding core type.

Array-shape conventions: dense chip design (``design_chip``) works on the
``[n_array, n_psum, n_ifmap]`` metric cubes of :class:`SweepResult`, with
candidate sets as ``(array_idx, psum_idx, ifmap_idx)`` cells; the
streaming variant (``design_chip_streaming``) works on FLAT grid indices
into a :class:`repro.core.accelerator.ConfigGrid` (the boundary sets a
``StreamResult`` carries — the full ``[n_cfg, n_net]`` matrices are never
materialised), and ``StreamChip.core_cells`` converts back to cells.
Both share ``_greedy_cover`` over per-network candidate-index sets, so
they provably pick identical core types.

``co_design`` goes one level deeper than ``design_chip``: instead of
assigning each network WHOLE to one core type, it searches over candidate
multi-core chips (a type multiset drawn from the boundary-set pool) and
schedules every network's LAYERS across the chip's heterogeneous cores —
the per-layer tensors come from the engine's ``per_layer=True`` path and
all (chip × network) schedules are solved by ONE call to the batched
:func:`repro.core.partition.batch_schedule_hetero` solver.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Mapping, Sequence, Tuple

import numpy as np

from . import energymodel
from . import partition
from .accelerator import ConfigGrid
from .dse import SweepResult, boundary_configs
from .topology import Layer

Cell = Tuple[int, int, int]     # (array_idx, psum_idx, ifmap_idx)


@dataclasses.dataclass
class HeteroChip:
    core_types: List[Cell]                    # chosen configurations
    assignment: Dict[str, int]                # network -> core-type index
    candidate_sets: Dict[str, List[Cell]]     # Table 5 per network
    sweeps: Dict[str, SweepResult]

    def core_label(self, idx: int) -> str:
        any_sweep = next(iter(self.sweeps.values()))
        return any_sweep.cell_label(self.core_types[idx])


def _greedy_cover(cand: np.ndarray, rel: np.ndarray, max_cores: int):
    """Shared greedy set-cover core of both design_chip paths.

    ``cand``/``rel`` are [n_net, n_pts]; each round picks the point
    covering the most uncovered networks (ties → lower total relative
    metric across covered networks, then lower point index).  Returns
    (selected point columns, {net row → core index}, uncovered mask)."""
    uncovered = np.ones(cand.shape[0], dtype=bool)
    cols: List[int] = []
    assign: Dict[int, int] = {}
    while uncovered.any() and len(cols) < max_cores:
        counts = cand[uncovered].sum(axis=0)
        best_count = counts.max() if counts.size else 0
        if best_count == 0:
            break
        rel_sum = np.where(cand[uncovered], rel[uncovered], 0.0).sum(axis=0)
        tied = np.flatnonzero(counts == best_count)
        col = int(tied[np.argmin(rel_sum[tied])])

        idx = len(cols)
        cols.append(col)
        covered_now = cand[:, col] & uncovered
        for i in np.flatnonzero(covered_now):
            assign[int(i)] = idx
        uncovered &= ~covered_now
    return cols, assign, uncovered


def design_chip(sweeps: Dict[str, SweepResult], bound: float = 0.05,
                metric: str = "edp", max_cores: int = 4) -> HeteroChip:
    """Greedy common-configuration cover → heterogeneous core types.

    Fully vectorised: the per-network metric cubes are flattened into a
    [n_net, n_points] matrix once, and each greedy round is a handful of
    masked reductions — no per-cell Python loops — so the cover stays
    interactive on multi-thousand-point grids.
    """
    names = list(sweeps)
    candidates = {name: boundary_configs(sweeps[name], bound, metric)
                  for name in names}

    mats = np.stack([sweeps[n].metric(metric).ravel() for n in names])
    shape = next(iter(sweeps.values())).metric(metric).shape
    mins = mats.min(axis=1, keepdims=True)
    cand = mats <= mins * (1.0 + bound)           # [n_net, n_pts] bool
    rel = mats / mins                             # metric / per-net minimum

    core_flat, assign, uncovered = _greedy_cover(cand, rel, max_cores)
    assignment = {names[i]: idx for i, idx in assign.items()}

    core_types: List[Cell] = [
        tuple(int(x) for x in np.unravel_index(c, shape)) for c in core_flat]

    # Networks not covered within the boundary: assign to the least-penalty
    # existing core type.
    if uncovered.any() and core_flat:
        vals = mats[:, core_flat]                 # [n_net, n_cores]
        best = np.argmin(vals, axis=1)
        for i in np.flatnonzero(uncovered):
            assignment[names[i]] = int(best[i])

    return HeteroChip(core_types=core_types, assignment=assignment,
                      candidate_sets=candidates, sweeps=sweeps)


@dataclasses.dataclass
class StreamChip:
    """Heterogeneous chip designed from a streamed sweep: core types are
    FLAT grid indices (mega grids are not 3-D cubes)."""

    core_types: List[int]
    assignment: Dict[str, int]                # network -> core-type index
    candidate_sets: Dict[str, List[int]]      # flat indices, best first
    stream: "energymodel.StreamResult"

    def core_label(self, idx: int, grid: ConfigGrid) -> str:
        return grid.config_at(self.core_types[idx]).label()

    def core_cells(self, shape: Tuple[int, ...]) -> List[Cell]:
        """Unravel the flat core indices onto a sweep cube shape."""
        return [tuple(int(x) for x in np.unravel_index(c, shape))
                for c in self.core_types]


def design_chip_streaming(stream: "energymodel.StreamResult",
                          grid: ConfigGrid,
                          networks: Mapping[str, Sequence[Layer]],
                          max_cores: int = 4,
                          use_jax: bool | None = None) -> StreamChip:
    """Greedy cover over a StreamResult's boundary sets — no full cubes.

    Exactly reproduces :func:`design_chip`'s choices: any point that can
    cover a network lies in that network's boundary set, so the greedy
    only ever needs the union of the streamed candidate sets.  Networks
    left uncovered are assigned by evaluating just the chosen core cells
    (a ≤max_cores-point grid) exactly.
    """
    names = list(stream.networks)
    union = np.unique(np.concatenate(
        [stream.boundary_idx[nm] for nm in names]))
    cand = np.zeros((len(names), union.size), dtype=bool)
    rel = np.zeros((len(names), union.size))
    for i, nm in enumerate(names):
        pos = np.searchsorted(union, stream.boundary_idx[nm])
        cand[i, pos] = True
        rel[i, pos] = stream.boundary_metric(nm) / stream.min_metric[i]

    cols, assign, uncovered = _greedy_cover(cand, rel, max_cores)
    core_flat = [int(union[c]) for c in cols]
    assignment = {names[i]: idx for i, idx in assign.items()}

    if uncovered.any() and core_flat:
        # exact evaluation of the few chosen cells for every network
        e, t = energymodel.evaluate_networks(
            grid.take(core_flat), {nm: networks[nm] for nm in names},
            use_jax=use_jax)
        vals = energymodel._metric_of(stream.metric, e, t).T
        best = np.argmin(vals, axis=1)
        for i in np.flatnonzero(uncovered):
            assignment[names[i]] = int(best[i])

    candidate_sets = {nm: [int(c) for c in stream.boundary_idx[nm]]
                      for nm in names}
    return StreamChip(core_types=core_flat, assignment=assignment,
                      candidate_sets=candidate_sets, stream=stream)


def cross_penalty(chip: HeteroChip, network: str, other_core: int
                  ) -> Dict[str, float]:
    """Table 6: Δ_E, Δ_D, Δ_EDP (%) of running ``network`` on a
    non-corresponding core type instead of its own."""
    sw = chip.sweeps[network]
    own = chip.core_types[chip.assignment[network]]
    oth = chip.core_types[other_core]
    d_e = (sw.energy[oth] - sw.energy[own]) / sw.energy[own] * 100.0
    d_d = (sw.latency[oth] - sw.latency[own]) / sw.latency[own] * 100.0
    d_edp = (sw.edp[oth] - sw.edp[own]) / sw.edp[own] * 100.0
    return dict(dE=float(d_e), dD=float(d_d), dEDP=float(d_edp))


# ---------------------------------------------------------------------------
# Batched per-layer co-design (§IV.A × §IV.B fused): which multi-core chip,
# and which layer→core schedule on it, for every network at once.
# ---------------------------------------------------------------------------


def _compositions(n: int, k: int):
    """Positive integer k-tuples summing to n (core counts per type)."""
    if k == 1:
        yield (n,)
        return
    for first in range(1, n - k + 2):
        for rest in _compositions(n - first, k - 1):
            yield (first,) + rest


def _enumerate_chips(pool_size: int, max_types: int, m_cores: int
                     ) -> List[Tuple[Tuple[int, ...], Tuple[int, ...]]]:
    """All candidate chips: (pool positions, per-type core counts)."""
    chips = []
    for k in range(1, min(max_types, m_cores, pool_size) + 1):
        for combo in itertools.combinations(range(pool_size), k):
            for comp in _compositions(m_cores, k):
                chips.append((combo, comp))
    return chips


def _expand_pool_tensor(tensor: np.ndarray, chips, n_net: int,
                        t_max: int) -> np.ndarray:
    """[pool, n_net, L] per-layer pool tensor → the chip-major problem
    block [n_chips · n_net, t_max, L]: each chip's type rows gathered and
    laid out network-major within the chip (unused type slots stay 0).
    Both solver latencies and the energy attribution go through THIS
    layout, so they can never desynchronise."""
    n_layer = tensor.shape[2]
    out = np.zeros((len(chips) * n_net, t_max, n_layer))
    for ci, (ty, _) in enumerate(chips):
        out[ci * n_net:(ci + 1) * n_net, :len(ty)] = \
            tensor[list(ty)].transpose(1, 0, 2)           # [n_net, k, L]
    return out


@dataclasses.dataclass
class CoDesign:
    """Result of the batched chip + layer-schedule co-design search."""

    core_types: List[int]                 # winning chip: flat grid indices
    core_counts: List[int]                # cores per type (Σ == m_cores)
    schedules: Dict[str, "partition.HeteroSchedule"]   # per network
    energy: Dict[str, float]              # Σ per-layer energy as scheduled
    latency: Dict[str, float]             # pipeline bottleneck (ns)
    score: float                          # winning chip's mean norm. metric
    homogeneous_score: float              # best single-type chip's score
    metric: str
    m_cores: int
    pool: List[int]                       # candidate type pool (flat idx)
    chip_types: List[Tuple[int, ...]]     # every candidate: pool positions
    chip_counts: List[Tuple[int, ...]]
    chip_scores: np.ndarray               # [n_chips]

    @property
    def n_chips(self) -> int:
        return len(self.chip_types)

    def edp(self, name: str) -> float:
        return self.energy[name] * self.latency[name]

    def core_label(self, idx: int, grid: ConfigGrid) -> str:
        return grid.config_at(self.core_types[idx]).label()

    def summary(self, grid: ConfigGrid) -> str:
        parts = [f"{c}x {self.core_label(i, grid)}"
                 for i, c in enumerate(self.core_counts)]
        return " + ".join(parts)


@dataclasses.dataclass
class CoDesignProblems:
    """The materialised (chip × network) schedule problem set — step 1–3
    of :func:`co_design` without the solve, so benchmarks can time the
    batched solver against the per-(chip, network) loop it replaces on
    the exact same problems."""

    names: List[str]
    pool: List[int]                        # candidate types (flat idx)
    chips: List[Tuple[Tuple[int, ...], Tuple[int, ...]]]  # (types, counts)
    lat_dense: np.ndarray                  # [B, t_max, n_layer] solver input
    n_layers_b: np.ndarray                 # [B] true lengths per problem
    counts: np.ndarray                     # [B, t_max]
    e_layer: np.ndarray                    # [pool, n_net, n_layer]
    t_layer: np.ndarray
    e: np.ndarray                          # dense sweep [n, n_net]
    t: np.ndarray
    lens: np.ndarray                       # [n_net] true layer counts

    @property
    def n_problems(self) -> int:
        return int(self.lat_dense.shape[0])

    @property
    def lats(self) -> List[np.ndarray]:
        """Per-problem [n_types, n_layers] views (the scalar-oracle loop's
        input format)."""
        return [self.lat_dense[i, :, :self.n_layers_b[i]]
                for i in range(self.n_problems)]


def codesign_problems(grid: ConfigGrid,
                      networks: Mapping[str, Sequence[Layer]],
                      m_cores: int = 4,
                      *,
                      max_types: int = 3,
                      pool_size: int = 6,
                      bound: float = 0.05,
                      metric: str = "edp",
                      backend: str | None = None,
                      use_jax: bool | None = None) -> CoDesignProblems:
    """Build the co-design problem set: dense sweep → boundary-set pool →
    per-layer pool tensors → every (chip candidate × network) problem."""
    names = list(networks)
    n_net = len(names)
    e, t = energymodel.evaluate_networks(grid, networks, use_jax=use_jax,
                                         backend=backend)

    # ---- pool from the boundary sets (greedy cover, then top-up) ---------
    val = energymodel._metric_of(metric, e, t)            # [n, n_net]
    mins = val.min(axis=0)
    cand = (val <= mins[None, :] * (1.0 + bound)).T       # [n_net, n]
    rel = (val / mins[None, :]).T
    pool_size = min(pool_size, grid.n)
    cols, _, _ = _greedy_cover(cand, rel, pool_size)
    pool = [int(c) for c in cols]
    if len(pool) < pool_size:
        for c in np.argsort(rel.min(axis=0), kind="stable"):
            if int(c) not in pool:
                pool.append(int(c))
            if len(pool) == pool_size:
                break

    # ---- per-layer tensors of the pool (ONE compiled call) ---------------
    e_l, t_l = energymodel.evaluate_networks(
        grid.take(pool), networks, use_jax=use_jax, backend=backend,
        per_layer=True)                                   # [P, n_net, L]
    lens = energymodel.network_layer_counts(networks)

    # ---- candidate chips × networks (dense solver tensors) ---------------
    chips = _enumerate_chips(len(pool), max_types, m_cores)
    t_max = max(len(ty) for ty, _ in chips)
    lat_b = _expand_pool_tensor(t_l, chips, n_net, t_max)
    counts_b = np.zeros((len(chips) * n_net, t_max), dtype=np.int64)
    for ci, (ty, cn) in enumerate(chips):
        counts_b[ci * n_net:(ci + 1) * n_net, :len(cn)] = cn
    return CoDesignProblems(names=names, pool=pool, chips=chips,
                            lat_dense=lat_b,
                            n_layers_b=np.tile(lens, len(chips)),
                            counts=counts_b,
                            e_layer=e_l, t_layer=t_l, e=e, t=t, lens=lens)


def co_design(grid: ConfigGrid,
              networks: Mapping[str, Sequence[Layer]],
              m_cores: int = 4,
              *,
              max_types: int = 3,
              pool_size: int = 6,
              bound: float = 0.05,
              metric: str = "edp",
              backend: str | None = None,
              use_jax: bool | None = None) -> CoDesign:
    """Batched heterogeneous chip + per-layer schedule co-design (§IV).

    1. One dense sweep ranks every grid point per network; the candidate
       core-type POOL is the greedy-cover prefix of the ≤``bound``
       boundary sets (the same cover ``design_chip`` runs), topped up
       with the best near-optimal cells.
    2. ONE ``per_layer=True`` engine call evaluates the pool → the
       ``[pool, n_net, n_layer]`` per-layer energy/latency tensors.
    3. Every chip candidate — type subsets of the pool (≤ ``max_types``)
       × core-count compositions of ``m_cores`` — is scheduled for every
       network by ONE :func:`repro.core.partition.batch_schedule_hetero`
       call over all (chip × network) problems.
    4. Chips are scored by the per-network scheduled metric (energy as
       assigned / pipeline bottleneck / their product for ``"edp"``),
       normalised by that network's single-core optimum and averaged;
       the arg-min chip wins and only ITS schedules are materialised.

    The ``homogeneous_score`` of the best single-type candidate (the
    §IV.B baseline: ``m_cores`` identical cores) is kept for the savings
    headline — heterogeneous wins exactly when ``score`` beats it.
    """
    probs = codesign_problems(grid, networks, m_cores,
                              max_types=max_types, pool_size=pool_size,
                              bound=bound, metric=metric, backend=backend,
                              use_jax=use_jax)
    res = partition.batch_schedule_hetero(probs.lat_dense, probs.counts,
                                          n_layers=probs.n_layers_b,
                                          use_jax=use_jax)
    return score_codesign(probs, res, metric=metric, m_cores=m_cores)


def score_codesign(probs: CoDesignProblems,
                   res: "partition.BatchHeteroResult",
                   *, metric: str = "edp", m_cores: int = 4) -> CoDesign:
    """Step 4 of :func:`co_design`: fold a solved problem set into chip
    scores and materialise the winning chip's schedules."""
    names, chips, pool = probs.names, probs.chips, probs.pool
    n_net, n_chips = len(names), len(chips)
    t_max = probs.counts.shape[1]
    n_layer = probs.e_layer.shape[2]

    # ---- energy of every problem as scheduled ----------------------------
    # same chip-major expansion the solver latencies used (one helper,
    # one layout), then one take_along_axis gather over assigned types
    en_b = _expand_pool_tensor(probs.e_layer, chips, n_net, t_max)
    tt = res.layer_type[:, :n_layer]
    energy_b = np.take_along_axis(
        en_b, tt[:, None, :], axis=1)[:, 0, :].sum(-1)    # [B]

    # ---- score chips ------------------------------------------------------
    bott = res.bottleneck.reshape(n_chips, n_net)
    energy = energy_b.reshape(n_chips, n_net)
    if metric == "energy":
        cell, ref = energy, probs.e.min(axis=0)
    elif metric == "latency":
        cell, ref = bott, probs.t.min(axis=0)
    else:
        cell, ref = energy * bott, (probs.e * probs.t).min(axis=0)
    chip_scores = (cell / ref[None, :]).mean(axis=1)      # [n_chips]
    best = int(np.argmin(chip_scores))
    homog = min(chip_scores[ci] for ci, (ty, _) in enumerate(chips)
                if len(ty) == 1)

    ty, cn = chips[best]
    schedules = {nm: res.schedule(best * n_net + j)
                 for j, nm in enumerate(names)}
    return CoDesign(
        core_types=[pool[p] for p in ty],
        core_counts=list(cn),
        schedules=schedules,
        energy={nm: float(energy[best, j]) for j, nm in enumerate(names)},
        latency={nm: float(bott[best, j]) for j, nm in enumerate(names)},
        score=float(chip_scores[best]),
        homogeneous_score=float(homog),
        metric=metric, m_cores=m_cores, pool=pool,
        chip_types=[c[0] for c in chips],
        chip_counts=[c[1] for c in chips],
        chip_scores=chip_scores)


def savings_summary(chip: HeteroChip) -> Dict[str, Dict[str, float]]:
    """Per-network savings of the heterogeneous assignment vs. the worst
    single-core-type choice (the paper's headline: up to 36% energy / 67%
    EDP saved by running on the near-optimal core).

    One gather per metric: the core cells are flattened to indices once
    and every (network × core) value is pulled with array indexing — no
    per-network/per-core Python loops."""
    names = list(chip.assignment)
    shape = next(iter(chip.sweeps.values())).energy.shape
    core_flat = np.ravel_multi_index(
        np.asarray(chip.core_types, dtype=np.intp).T, shape)
    energy = np.stack([chip.sweeps[n].energy.ravel()[core_flat]
                       for n in names])            # [n_net, n_cores]
    edp = np.stack([chip.sweeps[n].edp.ravel()[core_flat] for n in names])
    own = np.asarray([chip.assignment[n] for n in names], dtype=np.intp)
    rows = np.arange(len(names))
    worst_e, worst_edp = energy.max(axis=1), edp.max(axis=1)
    e_saved = (worst_e - energy[rows, own]) / worst_e * 100.0
    edp_saved = (worst_edp - edp[rows, own]) / worst_edp * 100.0
    return {n: dict(energy_saved=float(e_saved[i]),
                    edp_saved=float(edp_saved[i]))
            for i, n in enumerate(names)}
