"""Heterogeneous multi-core chip scheme (§IV.A).

Procedure, as the paper describes it:

1.  For every network, evaluate the target metric (EDP by default) over the
    whole search space and keep every configuration within a boundary (5%)
    of that network's minimum → candidate sets (Table 5).
2.  Select a small number of *common* configurations such that the maximum
    number of networks runs near-optimally → the chip's core types (greedy
    set cover over the candidate sets).
3.  Every network is assigned to the core type that covers it (or, if none
    covers it within the boundary, the type with the least penalty).

``cross_penalty`` reproduces Table 6: the increase in energy, delay, and EDP
when a network runs on a non-corresponding core type.

Array-shape conventions: dense chip design (``design_chip``) works on the
``[n_array, n_psum, n_ifmap]`` metric cubes of :class:`SweepResult`, with
candidate sets as ``(array_idx, psum_idx, ifmap_idx)`` cells; the
streaming variant (``design_chip_streaming``) works on FLAT grid indices
into a :class:`repro.core.accelerator.ConfigGrid` (the boundary sets a
``StreamResult`` carries — the full ``[n_cfg, n_net]`` matrices are never
materialised), and ``StreamChip.core_cells`` converts back to cells.
Both share ``_greedy_cover`` over per-network candidate-index sets, so
they provably pick identical core types.

``co_design`` goes one level deeper than ``design_chip``: instead of
assigning each network WHOLE to one core type, it searches over candidate
multi-core chips (a type multiset drawn from the boundary-set pool) and
schedules every network's LAYERS across the chip's heterogeneous cores —
the per-layer tensors come from the engine's ``per_layer=True`` path and
all (chip × network) schedules are solved by ONE call to the batched
:func:`repro.core.partition.batch_schedule_hetero` solver.

Both co-design constructors route through ONE pool builder
(:func:`_candidate_pool`: greedy cover + (rel, index)-ordered top-up,
deduped on identical config rows): ``codesign_problems`` feeds it a dense
sweep, ``codesign_problems_streaming`` the boundary sets / top-k /
running minima of one chunked
:func:`repro.core.energymodel.stream_layer_topk` pass — so a mega-scale
grid co-designs at bounded memory and, on spaces where both fit, the
streamed pool reproduces the dense one exactly.  ``pareto_codesign``
rescores a solved problem block against a whole deadline axis at once
(via :func:`repro.core.partition.batch_pareto_scores`), returning the
non-dominated (energy, latency) frontier per network and per chip —
the latency-bound view the paper's savings headline implies.
"""

from __future__ import annotations

import dataclasses
import itertools
import warnings
from typing import Dict, List, Mapping, Sequence, Tuple

import numpy as np

from . import energymodel
from . import partition
from .accelerator import ConfigGrid, GRID_COLUMNS
from .dse import SweepResult, boundary_configs
from .topology import Layer

Cell = Tuple[int, int, int]     # (array_idx, psum_idx, ifmap_idx)


@dataclasses.dataclass
class HeteroChip:
    core_types: List[Cell]                    # chosen configurations
    assignment: Dict[str, int]                # network -> core-type index
    candidate_sets: Dict[str, List[Cell]]     # Table 5 per network
    sweeps: Dict[str, SweepResult]

    def core_label(self, idx: int) -> str:
        any_sweep = next(iter(self.sweeps.values()))
        return any_sweep.cell_label(self.core_types[idx])


def _greedy_cover(cand: np.ndarray, rel: np.ndarray, max_cores: int):
    """Shared greedy set-cover core of both design_chip paths.

    ``cand``/``rel`` are [n_net, n_pts]; each round picks the point
    covering the most uncovered networks (ties → lower total relative
    metric across covered networks, then lower point index).  Returns
    (selected point columns, {net row → core index}, uncovered mask)."""
    uncovered = np.ones(cand.shape[0], dtype=bool)
    cols: List[int] = []
    assign: Dict[int, int] = {}
    while uncovered.any() and len(cols) < max_cores:
        counts = cand[uncovered].sum(axis=0)
        best_count = counts.max() if counts.size else 0
        if best_count == 0:
            break
        rel_sum = np.where(cand[uncovered], rel[uncovered], 0.0).sum(axis=0)
        tied = np.flatnonzero(counts == best_count)
        col = int(tied[np.argmin(rel_sum[tied])])

        idx = len(cols)
        cols.append(col)
        covered_now = cand[:, col] & uncovered
        for i in np.flatnonzero(covered_now):
            assign[int(i)] = idx
        uncovered &= ~covered_now
    return cols, assign, uncovered


def _row_key(grid: ConfigGrid, i: int) -> Tuple[float, ...]:
    """Hashable config-row key of one grid point: two grid points with
    identical columns are the SAME core type, whatever their flat index."""
    return tuple(float(grid.fields[k][i]) for k in GRID_COLUMNS)


def _candidate_pool(cand: np.ndarray, rel: np.ndarray, pool_size: int,
                    ids: np.ndarray, key_fn) -> List[int]:
    """THE pool builder of the co-design path — dense and streamed alike.

    ``cand``/``rel`` are [n_net, n_pts] over candidate point columns
    (``ids[c]`` is column ``c``'s flat grid index, ascending); the pool is
    the :func:`_greedy_cover` prefix of the boundary sets topped up with
    the best near-optimal points in (rel.min over networks, flat index)
    lex order.  Unknown ``rel`` entries are +inf (a streamed column
    outside a network's boundary/top-k sets): the cover never reads them
    (``cand``-masked) and +inf can only push a column DOWN the top-up
    ranking, so dense and streamed pools cannot drift.  Points whose
    config row duplicates one already pooled (``key_fn(column)`` — flat
    indices of identical grid rows differ, the core type does not) are
    skipped, so a duplicated grid row can never occupy two pool slots."""
    pool: List[int] = []
    seen: set = set()

    def add(c: int) -> None:
        key = key_fn(int(c))
        if key not in seen:
            seen.add(key)
            pool.append(int(ids[c]))

    cols, _, _ = _greedy_cover(cand, rel, pool_size)
    for c in cols:
        add(c)
    if len(pool) < pool_size:
        for c in np.lexsort((ids, rel.min(axis=0))):
            add(int(c))
            if len(pool) == pool_size:
                break
    return pool


def design_chip(sweeps: Dict[str, SweepResult], bound: float = 0.05,
                metric: str = "edp", max_cores: int = 4) -> HeteroChip:
    """Greedy common-configuration cover → heterogeneous core types.

    Fully vectorised: the per-network metric cubes are flattened into a
    [n_net, n_points] matrix once, and each greedy round is a handful of
    masked reductions — no per-cell Python loops — so the cover stays
    interactive on multi-thousand-point grids.
    """
    names = list(sweeps)
    candidates = {name: boundary_configs(sweeps[name], bound, metric)
                  for name in names}

    mats = np.stack([sweeps[n].metric(metric).ravel() for n in names])
    shape = next(iter(sweeps.values())).metric(metric).shape
    mins = mats.min(axis=1, keepdims=True)
    cand = mats <= mins * (1.0 + bound)           # [n_net, n_pts] bool
    rel = mats / mins                             # metric / per-net minimum

    core_flat, assign, uncovered = _greedy_cover(cand, rel, max_cores)
    assignment = {names[i]: idx for i, idx in assign.items()}

    core_types: List[Cell] = [
        tuple(int(x) for x in np.unravel_index(c, shape)) for c in core_flat]

    # Networks not covered within the boundary: assign to the least-penalty
    # existing core type.
    if uncovered.any() and core_flat:
        vals = mats[:, core_flat]                 # [n_net, n_cores]
        best = np.argmin(vals, axis=1)
        for i in np.flatnonzero(uncovered):
            assignment[names[i]] = int(best[i])

    return HeteroChip(core_types=core_types, assignment=assignment,
                      candidate_sets=candidates, sweeps=sweeps)


@dataclasses.dataclass
class StreamChip:
    """Heterogeneous chip designed from a streamed sweep: core types are
    FLAT grid indices (mega grids are not 3-D cubes)."""

    core_types: List[int]
    assignment: Dict[str, int]                # network -> core-type index
    candidate_sets: Dict[str, List[int]]      # flat indices, best first
    stream: "energymodel.StreamResult"

    def core_label(self, idx: int, grid: ConfigGrid) -> str:
        return grid.config_at(self.core_types[idx]).label()

    def core_cells(self, shape: Tuple[int, ...]) -> List[Cell]:
        """Unravel the flat core indices onto a sweep cube shape."""
        return [tuple(int(x) for x in np.unravel_index(c, shape))
                for c in self.core_types]


def design_chip_streaming(stream: "energymodel.StreamResult",
                          grid: ConfigGrid,
                          networks: Mapping[str, Sequence[Layer]],
                          max_cores: int = 4,
                          use_jax: bool | None = None) -> StreamChip:
    """Greedy cover over a StreamResult's boundary sets — no full cubes.

    Exactly reproduces :func:`design_chip`'s choices: any point that can
    cover a network lies in that network's boundary set, so the greedy
    only ever needs the union of the streamed candidate sets.  Networks
    left uncovered are assigned by evaluating just the chosen core cells
    (a ≤max_cores-point grid) exactly.
    """
    names = list(stream.networks)
    union = np.unique(np.concatenate(
        [stream.boundary_idx[nm] for nm in names]))
    cand = np.zeros((len(names), union.size), dtype=bool)
    rel = np.zeros((len(names), union.size))
    for i, nm in enumerate(names):
        pos = np.searchsorted(union, stream.boundary_idx[nm])
        cand[i, pos] = True
        rel[i, pos] = stream.boundary_metric(nm) / stream.min_metric[i]

    cols, assign, uncovered = _greedy_cover(cand, rel, max_cores)
    core_flat = [int(union[c]) for c in cols]
    assignment = {names[i]: idx for i, idx in assign.items()}

    if uncovered.any() and core_flat:
        # exact evaluation of the few chosen cells for every network
        e, t = energymodel.evaluate_networks(
            grid.take(core_flat), {nm: networks[nm] for nm in names},
            use_jax=use_jax)
        vals = energymodel._metric_of(stream.metric, e, t).T
        best = np.argmin(vals, axis=1)
        for i in np.flatnonzero(uncovered):
            assignment[names[i]] = int(best[i])

    candidate_sets = {nm: [int(c) for c in stream.boundary_idx[nm]]
                      for nm in names}
    return StreamChip(core_types=core_flat, assignment=assignment,
                      candidate_sets=candidate_sets, stream=stream)


def cross_penalty(chip: HeteroChip, network: str, other_core: int
                  ) -> Dict[str, float]:
    """Table 6: Δ_E, Δ_D, Δ_EDP (%) of running ``network`` on a
    non-corresponding core type instead of its own."""
    sw = chip.sweeps[network]
    own = chip.core_types[chip.assignment[network]]
    oth = chip.core_types[other_core]
    d_e = (sw.energy[oth] - sw.energy[own]) / sw.energy[own] * 100.0
    d_d = (sw.latency[oth] - sw.latency[own]) / sw.latency[own] * 100.0
    d_edp = (sw.edp[oth] - sw.edp[own]) / sw.edp[own] * 100.0
    return dict(dE=float(d_e), dD=float(d_d), dEDP=float(d_edp))


# ---------------------------------------------------------------------------
# Batched per-layer co-design (§IV.A × §IV.B fused): which multi-core chip,
# and which layer→core schedule on it, for every network at once.
# ---------------------------------------------------------------------------


def _compositions(n: int, k: int):
    """Positive integer k-tuples summing to n (core counts per type)."""
    if k == 1:
        yield (n,)
        return
    for first in range(1, n - k + 2):
        for rest in _compositions(n - first, k - 1):
            yield (first,) + rest


def _enumerate_chips(pool_size: int, max_types: int, m_cores: int
                     ) -> List[Tuple[Tuple[int, ...], Tuple[int, ...]]]:
    """All candidate chips: (pool positions, per-type core counts)."""
    chips = []
    for k in range(1, min(max_types, m_cores, pool_size) + 1):
        for combo in itertools.combinations(range(pool_size), k):
            for comp in _compositions(m_cores, k):
                chips.append((combo, comp))
    return chips


def _expand_pool_tensor(tensor: np.ndarray, chips, n_net: int,
                        t_max: int) -> np.ndarray:
    """[pool, n_net, L] per-layer pool tensor → the chip-major problem
    block [n_chips · n_net, t_max, L]: each chip's type rows gathered and
    laid out network-major within the chip (unused type slots stay 0).
    Both solver latencies and the energy attribution go through THIS
    layout, so they can never desynchronise.  One fancy-index gather over
    a [n_chips, t_max] type map — no per-chip python copies."""
    n_layer = tensor.shape[2]
    n_chips = len(chips)
    tmap = np.zeros((n_chips, t_max), dtype=np.intp)
    tuse = np.zeros((n_chips, t_max), dtype=bool)
    for ci, (ty, _) in enumerate(chips):
        tmap[ci, :len(ty)] = ty
        tuse[ci, :len(ty)] = True
    out = np.where(tuse[:, :, None, None], tensor[tmap], 0.0)
    return out.transpose(0, 2, 1, 3).reshape(n_chips * n_net, t_max,
                                             n_layer)


@dataclasses.dataclass
class CoDesign:
    """Result of the batched chip + layer-schedule co-design search."""

    core_types: List[int]                 # winning chip: flat grid indices
    core_counts: List[int]                # cores per type (Σ == m_cores)
    schedules: Dict[str, "partition.HeteroSchedule"]   # per network
    energy: Dict[str, float]              # Σ per-layer energy as scheduled
    latency: Dict[str, float]             # pipeline bottleneck (ns)
    score: float                          # winning chip's mean norm. metric
    homogeneous_score: float              # best single-type chip's score
    metric: str
    m_cores: int
    pool: List[int]                       # candidate type pool (flat idx)
    chip_types: List[Tuple[int, ...]]     # every candidate: pool positions
    chip_counts: List[Tuple[int, ...]]
    chip_scores: np.ndarray               # [n_chips]

    @property
    def n_chips(self) -> int:
        return len(self.chip_types)

    def edp(self, name: str) -> float:
        return self.energy[name] * self.latency[name]

    def core_label(self, idx: int, grid: ConfigGrid) -> str:
        return grid.config_at(self.core_types[idx]).label()

    def summary(self, grid: ConfigGrid) -> str:
        parts = [f"{c}x {self.core_label(i, grid)}"
                 for i, c in enumerate(self.core_counts)]
        return " + ".join(parts)


@dataclasses.dataclass
class CoDesignProblems:
    """The materialised (chip × network) schedule problem set — step 1–3
    of :func:`co_design` without the solve, so benchmarks can time the
    batched solver against the per-(chip, network) loop it replaces on
    the exact same problems."""

    names: List[str]
    pool: List[int]                        # candidate types (flat idx)
    chips: List[Tuple[Tuple[int, ...], Tuple[int, ...]]]  # (types, counts)
    lat_dense: np.ndarray                  # [B, t_max, n_layer] solver input
    n_layers_b: np.ndarray                 # [B] true lengths per problem
    counts: np.ndarray                     # [B, t_max]
    e_layer: np.ndarray                    # [pool, n_net, n_layer]
    t_layer: np.ndarray
    # per-network sweep minima — the chip-scoring references.  The dense
    # path reduces its full [n, n_net] matrices to these; the streaming
    # path carries them straight out of the running reductions, so the
    # full matrices never need to exist.
    min_energy: np.ndarray                 # [n_net]
    min_latency: np.ndarray                # [n_net]
    min_edp: np.ndarray                    # [n_net]
    lens: np.ndarray                       # [n_net] true layer counts

    @property
    def n_problems(self) -> int:
        return int(self.lat_dense.shape[0])

    @property
    def lats(self) -> List[np.ndarray]:
        """Per-problem [n_types, n_layers] views (the scalar-oracle loop's
        input format)."""
        return [self.lat_dense[i, :, :self.n_layers_b[i]]
                for i in range(self.n_problems)]


def _problems_from_pool(grid: ConfigGrid,
                        networks: Mapping[str, Sequence[Layer]],
                        pool: List[int], m_cores: int, max_types: int,
                        refs: Tuple[np.ndarray, np.ndarray, np.ndarray],
                        backend: str | None,
                        use_jax: bool | None) -> CoDesignProblems:
    """Pool → problem set (steps 2–3 of :func:`co_design`): ONE
    ``per_layer=True`` engine call on the pool, then the dense
    (chip candidate × network) solver tensors.  Shared verbatim by the
    dense and streaming constructors — only the pool discovery and the
    reference minima (``refs``) differ between them."""
    names = list(networks)
    n_net = len(names)
    e_l, t_l = energymodel.evaluate_networks(
        grid.take(pool), networks, use_jax=use_jax, backend=backend,
        per_layer=True)                                   # [P, n_net, L]
    lens = energymodel.network_layer_counts(networks)

    chips = _enumerate_chips(len(pool), max_types, m_cores)
    t_max = max(len(ty) for ty, _ in chips)
    lat_b = _expand_pool_tensor(t_l, chips, n_net, t_max)
    counts_b = np.zeros((len(chips) * n_net, t_max), dtype=np.int64)
    for ci, (ty, cn) in enumerate(chips):
        counts_b[ci * n_net:(ci + 1) * n_net, :len(cn)] = cn
    return CoDesignProblems(names=names, pool=pool, chips=chips,
                            lat_dense=lat_b,
                            n_layers_b=np.tile(lens, len(chips)),
                            counts=counts_b,
                            e_layer=e_l, t_layer=t_l,
                            min_energy=np.asarray(refs[0], dtype=float),
                            min_latency=np.asarray(refs[1], dtype=float),
                            min_edp=np.asarray(refs[2], dtype=float),
                            lens=lens)


def codesign_problems(grid: ConfigGrid,
                      networks: Mapping[str, Sequence[Layer]],
                      m_cores: int = 4,
                      *,
                      max_types: int = 3,
                      pool_size: int = 6,
                      bound: float = 0.05,
                      metric: str = "edp",
                      backend: str | None = None,
                      use_jax: bool | None = None) -> CoDesignProblems:
    """Build the co-design problem set: dense sweep → boundary-set pool →
    per-layer pool tensors → every (chip candidate × network) problem."""
    e, t = energymodel.evaluate_networks(grid, networks, use_jax=use_jax,
                                         backend=backend)

    # ---- pool from the boundary sets (shared greedy cover + top-up) ------
    val = energymodel._metric_of(metric, e, t)            # [n, n_net]
    mins = val.min(axis=0)
    cand = (val <= mins[None, :] * (1.0 + bound)).T       # [n_net, n]
    rel = (val / mins[None, :]).T
    pool = _candidate_pool(cand, rel, min(pool_size, grid.n),
                           np.arange(grid.n),
                           lambda c: _row_key(grid, c))
    refs = (e.min(axis=0), t.min(axis=0), (e * t).min(axis=0))
    return _problems_from_pool(grid, networks, pool, m_cores, max_types,
                               refs, backend, use_jax)


def codesign_problems_streaming(grid: ConfigGrid,
                                networks: Mapping[str, Sequence[Layer]],
                                m_cores: int = 4,
                                *,
                                max_types: int = 3,
                                pool_size: int = 6,
                                bound: float = 0.05,
                                metric: str = "edp",
                                backend: str | None = None,
                                use_jax: bool | None = None,
                                chunk_size: int = 2048,
                                shard: bool = False,
                                topk: int | None = None,
                                stream: "energymodel.LayerTopK | None" = None,
                                resume_from=None,
                                on_chunk=None,
                                nan_guard: bool = True,
                                ) -> CoDesignProblems:
    """Streamed twin of :func:`codesign_problems`: the candidate pool and
    the scoring references come from ONE chunked
    :func:`repro.core.energymodel.stream_layer_topk` pass (boundary sets
    + top-k + running minima), so the full ``[n_cfg, n_net]`` — let alone
    ``[n_cfg, n_net, n_layer]`` — matrices are never materialised and a
    49,000-point mega grid feeds the pool at bounded memory.

    Reproduces the dense pool exactly: the greedy cover only ever reads
    boundary-set points (all streamed), and the top-up ranking by
    ``rel.min`` over networks is covered by the per-network top-k —
    any point in the top-up's first ``pool_size`` positions is, via its
    arg-min network, inside that network's (metric, index)-ordered
    top-``pool_size``, and unknown entries (+inf) only push non-winners
    further down.  One caveat: a grid whose rows are DUPLICATED many
    times over can saturate a network's top-k with copies of one row,
    hiding distinct rows the dense top-up would reach — the function
    warns whenever a network's top-k holds fewer distinct config rows
    than the pool needs (pass a larger ``topk=`` then).
    Pass ``stream=`` to reuse an existing sweep (it must cover the same
    grid with the same bound/metric and ``topk ≥ pool_size``).

    ``resume_from`` / ``on_chunk`` / ``nan_guard`` forward to the
    underlying :func:`repro.core.energymodel.stream_layer_topk` pass
    (ignored when ``stream=`` is supplied), so a pool build killed
    mid-sweep restarts from its last exported
    :class:`repro.core.energymodel.StreamFoldState` and yields the same
    pool bit-for-bit."""
    names = list(networks)
    n_net = len(names)
    if stream is None:
        stream = energymodel.stream_layer_topk(
            grid, networks,
            topk=max(int(pool_size if topk is None else topk), 1),
            bound=bound, metric=metric, chunk_size=chunk_size,
            shard=shard, backend=backend, use_jax=use_jax,
            resume_from=resume_from, on_chunk=on_chunk,
            nan_guard=nan_guard)
    if stream.n_cfg != grid.n:
        raise ValueError(
            f"stream was built over a {stream.n_cfg}-point grid but the "
            f"pool was requested on a {grid.n}-point one — its flat "
            "indices would be looked up against the wrong grid")
    if stream.bound is None:
        raise ValueError("stream must carry boundary sets — run "
                         "stream_layer_topk with bound=")
    if stream.bound != bound or stream.metric != metric:
        raise ValueError(
            "stream was built with (bound, metric)="
            f"({stream.bound}, {stream.metric!r}) but the pool was "
            f"requested with ({bound}, {metric!r}) — pass matching "
            "arguments, or rebuild the stream (the dense-equivalence "
            "contract holds only when they agree)")
    if stream.topk_idx.shape[0] < min(pool_size, grid.n):
        raise ValueError("stream top-k too small for the pool: need "
                         f"topk >= {min(pool_size, grid.n)}, got "
                         f"{stream.topk_idx.shape[0]}")

    # candidate columns: union of every boundary set and every top-k hit
    tk = stream.topk_idx[stream.topk_idx >= 0]
    pts = np.unique(np.concatenate(
        [stream.boundary_idx[nm] for nm in names] + [tk.ravel()]))
    cand = np.zeros((n_net, pts.size), dtype=bool)
    rel = np.full((n_net, pts.size), np.inf)
    for j, nm in enumerate(names):
        pos = np.searchsorted(pts, stream.boundary_idx[nm])
        cand[j, pos] = True
        rel[j, pos] = stream.boundary_metric(nm) / stream.min_metric[j]
        tkj = stream.topk_idx[:, j]
        valid = tkj >= 0
        pos = np.searchsorted(pts, tkj[valid])
        rel[j, pos] = np.minimum(
            rel[j, pos], stream.topk_metric[valid, j] / stream.min_metric[j])

    # The dense-equivalence proof needs each network's top-k to expose
    # its top-`pool_size` DISTINCT config rows.  On duplicate-free grids
    # distinct indices are distinct rows and this always holds; heavily
    # duplicated rows can saturate a top-k with copies and silently hide
    # rows the dense top-up would reach — warn on exactly that
    # precondition (it covers full-length-but-divergent pools too).
    limit = min(pool_size, grid.n)
    for j in range(n_net):
        tkj = stream.topk_idx[:, j]
        keys = {_row_key(grid, int(i)) for i in tkj[tkj >= 0]}
        if len(keys) < limit:
            warnings.warn(
                f"network {names[j]!r}: top-{stream.topk_idx.shape[0]} "
                f"holds only {len(keys)} distinct config rows (< "
                f"{limit}): duplicated grid rows can saturate the "
                "streamed top-k with copies, so the pool may diverge "
                "from the dense codesign_problems pool — rebuild with "
                "a larger topk= to restore dense-pool equivalence",
                RuntimeWarning, stacklevel=2)
            break
    pool = _candidate_pool(cand, rel, limit, pts,
                           lambda c: _row_key(grid, int(pts[c])))
    refs = (stream.min_energy, stream.min_latency, stream.min_edp)
    return _problems_from_pool(grid, networks, pool, m_cores, max_types,
                               refs, backend, use_jax)


def co_design(grid: ConfigGrid,
              networks: Mapping[str, Sequence[Layer]],
              m_cores: int = 4,
              *,
              max_types: int = 3,
              pool_size: int = 6,
              bound: float = 0.05,
              metric: str = "edp",
              backend: str | None = None,
              use_jax: bool | None = None) -> CoDesign:
    """Batched heterogeneous chip + per-layer schedule co-design (§IV).

    1. One dense sweep ranks every grid point per network; the candidate
       core-type POOL is the greedy-cover prefix of the ≤``bound``
       boundary sets (the same cover ``design_chip`` runs), topped up
       with the best near-optimal cells.
    2. ONE ``per_layer=True`` engine call evaluates the pool → the
       ``[pool, n_net, n_layer]`` per-layer energy/latency tensors.
    3. Every chip candidate — type subsets of the pool (≤ ``max_types``)
       × core-count compositions of ``m_cores`` — is scheduled for every
       network by ONE :func:`repro.core.partition.batch_schedule_hetero`
       call over all (chip × network) problems.
    4. Chips are scored by the per-network scheduled metric (energy as
       assigned / pipeline bottleneck / their product for ``"edp"``),
       normalised by that network's single-core optimum and averaged;
       the arg-min chip wins and only ITS schedules are materialised.

    The ``homogeneous_score`` of the best single-type candidate (the
    §IV.B baseline: ``m_cores`` identical cores) is kept for the savings
    headline — heterogeneous wins exactly when ``score`` beats it.
    """
    probs = codesign_problems(grid, networks, m_cores,
                              max_types=max_types, pool_size=pool_size,
                              bound=bound, metric=metric, backend=backend,
                              use_jax=use_jax)
    res = partition.batch_schedule_hetero(probs.lat_dense, probs.counts,
                                          n_layers=probs.n_layers_b,
                                          use_jax=use_jax)
    return score_codesign(probs, res, metric=metric, m_cores=m_cores)


def co_design_streaming(grid: ConfigGrid,
                        networks: Mapping[str, Sequence[Layer]],
                        m_cores: int = 4,
                        *,
                        max_types: int = 3,
                        pool_size: int = 6,
                        bound: float = 0.05,
                        metric: str = "edp",
                        backend: str | None = None,
                        use_jax: bool | None = None,
                        chunk_size: int = 2048,
                        shard: bool = False,
                        topk: int | None = None,
                        stream: "energymodel.LayerTopK | None" = None,
                        ) -> CoDesign:
    """:func:`co_design` fed by the streaming engine: the candidate pool
    comes from ONE chunked :func:`repro.core.energymodel.stream_layer_topk`
    pass over ``grid`` (boundary sets + top-k + running minima) instead of
    a dense sweep, so mega-scale spaces
    (:func:`repro.core.accelerator.mega_grid`, 49,000 points) co-design at
    bounded memory.  Steps 2–4 — the ONE per-layer pool call, the ONE
    batched schedule solve, the chip scoring — are byte-for-byte the dense
    path's; on spaces where both fit, the streamed pool (and hence the
    winning chip and every schedule) reproduces dense :func:`co_design`."""
    probs = codesign_problems_streaming(
        grid, networks, m_cores, max_types=max_types, pool_size=pool_size,
        bound=bound, metric=metric, backend=backend, use_jax=use_jax,
        chunk_size=chunk_size, shard=shard, topk=topk, stream=stream)
    res = partition.batch_schedule_hetero(probs.lat_dense, probs.counts,
                                          n_layers=probs.n_layers_b,
                                          use_jax=use_jax)
    return score_codesign(probs, res, metric=metric, m_cores=m_cores)


def _scheduled_energy(probs: CoDesignProblems,
                      res: "partition.BatchHeteroResult") -> np.ndarray:
    """[B] total energy of every problem as scheduled: the same
    chip-major expansion the solver latencies used (one helper, one
    layout — they can never desynchronise), then one take_along_axis
    gather over the assigned types."""
    n_net = len(probs.names)
    t_max = probs.counts.shape[1]
    n_layer = probs.e_layer.shape[2]
    en_b = _expand_pool_tensor(probs.e_layer, probs.chips, n_net, t_max)
    tt = res.layer_type[:, :n_layer]
    return np.take_along_axis(
        en_b, tt[:, None, :], axis=1)[:, 0, :].sum(-1)    # [B]


def score_codesign(probs: CoDesignProblems,
                   res: "partition.BatchHeteroResult",
                   *, metric: str = "edp", m_cores: int = 4,
                   deadline: float | None = None) -> CoDesign:
    """Step 4 of :func:`co_design`: fold a solved problem set into chip
    scores and materialise the winning chip's schedules.

    ``deadline`` (RELATIVE, in units of each network's sweep-minimum
    latency, like :class:`ParetoCoDesign`) switches every schedule to
    the energy-aware slack pass: layers migrate to lower-energy types as
    long as the pipeline still meets ``deadline · min_latency[net]``,
    chips that cannot meet it on every network score +inf, and the
    winner's materialised schedules are the slack ones.  Raises if NO
    chip meets the deadline on every network."""
    names, chips, pool = probs.names, probs.chips, probs.pool
    n_net, n_chips = len(names), len(chips)

    # ---- score chips ------------------------------------------------------
    sl = None
    if deadline is None:
        bott = res.bottleneck.reshape(n_chips, n_net)
        energy = _scheduled_energy(probs, res).reshape(n_chips, n_net)
        feas_all = np.ones(n_chips, dtype=bool)
    else:
        t_max = probs.counts.shape[1]
        en_dense = _expand_pool_tensor(probs.e_layer, chips, n_net, t_max)
        dl_rows = np.tile(probs.min_latency * float(deadline),
                          n_chips)[:, None]               # [B, 1]
        sl = partition.batch_slack_schedule(
            probs.lat_dense, en_dense, probs.counts, dl_rows,
            n_layers=probs.n_layers_b, base=res)
        bott = sl.bottleneck[:, 0].reshape(n_chips, n_net)
        energy = sl.energy[:, 0].reshape(n_chips, n_net)
        feas_all = sl.feasible[:, 0].reshape(n_chips, n_net).all(axis=1)
        if not feas_all.any():
            raise ValueError(
                f"no candidate chip meets deadline {deadline} x "
                "min_latency on every network — loosen the deadline")
    if metric == "energy":
        cell, ref = energy, probs.min_energy
    elif metric == "latency":
        cell, ref = bott, probs.min_latency
    else:
        cell, ref = energy * bott, probs.min_edp
    chip_scores = np.where(feas_all,
                           (cell / ref[None, :]).mean(axis=1), np.inf)
    best = int(np.argmin(chip_scores))
    homog = min(chip_scores[ci] for ci, (ty, _) in enumerate(chips)
                if len(ty) == 1)

    ty, cn = chips[best]
    schedules = {nm: (res.schedule(best * n_net + j) if sl is None
                      else sl.schedule(best * n_net + j, 0))
                 for j, nm in enumerate(names)}
    return CoDesign(
        core_types=[pool[p] for p in ty],
        core_counts=list(cn),
        schedules=schedules,
        energy={nm: float(energy[best, j]) for j, nm in enumerate(names)},
        latency={nm: float(bott[best, j]) for j, nm in enumerate(names)},
        score=float(chip_scores[best]),
        homogeneous_score=float(homog),
        metric=metric, m_cores=m_cores, pool=pool,
        chip_types=[c[0] for c in chips],
        chip_counts=[c[1] for c in chips],
        chip_scores=chip_scores)


# ---------------------------------------------------------------------------
# Latency-bound Pareto co-design: the same solved (chip × network) problem
# block, scored against a whole DEADLINE AXIS at once.  Stream-style DSE is
# only credible as a latency/energy frontier — a chip that wins on EDP may
# be useless under a deadline, and the cheapest deadline-feasible chip
# changes as the bound tightens.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ParetoCoDesign:
    """Result of the batched latency-bound sweep (:func:`pareto_codesign`).

    ``deadlines`` are RELATIVE: deadline ``d`` for network ``j`` means a
    pipeline bottleneck of at most ``d · min_latency[j]`` (the network's
    best single-core latency from the sweep) — absolute bounds would be
    meaningless across networks whose latencies differ by orders of
    magnitude.  ``energy`` is normalised by each network's sweep-minimum
    energy, so chip scores are comparable across networks too."""

    names: List[str]
    deadlines: np.ndarray          # [D] in units of min_latency per net
    energy: np.ndarray             # [n_chips, n_net] scheduled energy (raw)
    latency: np.ndarray            # [n_chips, n_net] pipeline bottleneck
    norm_energy: np.ndarray        # [n_chips, n_net] / per-net min energy
    norm_latency: np.ndarray       # [n_chips, n_net] / per-net min latency
    scores: np.ndarray             # [n_chips, D] mean norm energy, +inf
    best_chip: np.ndarray          # [D] argmin chip (-1: none feasible)
    best_chip_net: np.ndarray      # [n_net, D] per-network best chip
    net_frontier: np.ndarray       # [n_chips, n_net] bool non-dominated
    chip_frontier: np.ndarray      # [n_chips] bool, network-mean plane
    pool: List[int]
    chip_types: List[Tuple[int, ...]]
    chip_counts: List[Tuple[int, ...]]
    # Energy-aware slack fields (pareto_codesign(slack=True); else None).
    # Each (chip, net, deadline) cell is the energy-greedy re-assignment
    # of partition.batch_slack_schedule — energy never above the
    # latency-only point, bottleneck never above the deadline.
    slack_energy: np.ndarray | None = None   # [n_chips, n_net, D] raw
    slack_latency: np.ndarray | None = None  # [n_chips, n_net, D]
    norm_slack_energy: np.ndarray | None = None  # / per-net min energy
    slack_scores: np.ndarray | None = None   # [n_chips, D] mean, +inf
    best_chip_slack: np.ndarray | None = None    # [D] argmin (-1: none)
    slack_moves: np.ndarray | None = None    # [n_chips, n_net, D]

    @property
    def n_chips(self) -> int:
        return len(self.chip_types)

    def slack_frontier(self, name: str,
                       deadline_index: int | None = None,
                       ) -> List[Tuple[int, float, float]]:
        """One network's non-dominated ``(chip, latency, energy)`` points
        over the UNION of the latency-only points and the slack points —
        the widened front.  Falls back to :meth:`frontier` when the sweep
        ran without ``slack=True``.

        With ``deadline_index`` the slack union is restricted to that one
        deadline column, making the answer a function of (problem, that
        deadline) only — required wherever the result must not depend on
        which OTHER deadlines happened to share the sweep (e.g. the DSE
        service's coalesced batches and its persistent answer cache).
        ``None`` keeps the historical all-deadlines union."""
        if self.slack_energy is None:
            return self.frontier(name)
        j = self.names.index(name)
        n_c = self.n_chips
        if deadline_index is None:
            cols = np.arange(self.slack_energy.shape[2])
        else:
            cols = np.array([int(deadline_index)])
        n_d = cols.size
        lat = np.concatenate([self.latency[:, j],
                              self.slack_latency[:, j, cols].ravel()])
        en = np.concatenate([self.energy[:, j],
                             self.slack_energy[:, j, cols].ravel()])
        chip = np.concatenate([np.arange(n_c),
                               np.repeat(np.arange(n_c), n_d)])
        ok = np.isfinite(lat) & np.isfinite(en)
        lat, en, chip = lat[ok], en[ok], chip[ok]
        dom = ((lat[None, :] <= lat[:, None]) & (en[None, :] <= en[:, None])
               & ((lat[None, :] < lat[:, None]) | (en[None, :] < en[:, None])))
        keep = np.flatnonzero(~dom.any(axis=1))
        pts = sorted({(float(lat[i]), float(en[i]), int(chip[i]))
                      for i in keep})
        return [(c, l, e) for l, e, c in pts]

    def frontier(self, name: str) -> List[Tuple[int, float, float]]:
        """One network's non-dominated ``(chip index, latency, energy)``
        points, fastest first."""
        j = self.names.index(name)
        idx = np.flatnonzero(self.net_frontier[:, j])
        order = np.lexsort((self.energy[idx, j], self.latency[idx, j]))
        return [(int(c), float(self.latency[c, j]), float(self.energy[c, j]))
                for c in idx[order]]

    def chip_summary(self, ci: int, grid: ConfigGrid) -> str:
        ty, cn = self.chip_types[ci], self.chip_counts[ci]
        return " + ".join(
            f"{c}x {grid.config_at(self.pool[p]).label()}"
            for p, c in zip(ty, cn))


def pareto_codesign(probs: CoDesignProblems,
                    res: "partition.BatchHeteroResult | None" = None,
                    *,
                    deadlines=None,
                    n_deadlines: int = 8,
                    points: Tuple[np.ndarray, np.ndarray] | None = None,
                    use_jax: bool | None = None,
                    slack: bool = False) -> ParetoCoDesign:
    """Latency-bound Pareto sweep over a co-design problem set.

    One :func:`repro.core.partition.batch_schedule_hetero` solve (reused
    via ``res=`` if the caller already has it) gives every
    (chip candidate × network) pair its scheduled (energy, bottleneck)
    point; ONE :func:`repro.core.partition.batch_pareto_scores` call then
    scores every chip against EVERY deadline — infeasible schedules
    masked to +inf — and extracts the per-deadline winners plus both
    non-dominated (energy, latency) fronts.  No python loop over
    deadlines anywhere.  ``deadlines`` defaults to ``n_deadlines`` points
    spanning the observed normalised-bottleneck range (so the tightest
    grid point is exactly reachable and the loosest admits every chip);
    the problem set may come from :func:`codesign_problems` or
    :func:`codesign_problems_streaming` — the sweep is agnostic.

    Re-sweeping the SAME problem set against a new deadline grid is the
    hot re-run path: pass ``points=(energy, latency)`` from a previous
    :class:`ParetoCoDesign` (both [n_chips, n_net], raw) and the solve
    and energy attribution are skipped entirely — only the compiled
    deadline scoring runs (``slack=True`` still needs the solve, so it
    re-solves when ``res`` is absent).

    ``slack=True`` additionally runs the energy-aware deadline-slack
    pass (:func:`repro.core.partition.batch_slack_schedule`) over the
    SAME (chip × network × deadline) axes in one more jitted call and
    fills the ``slack_*`` fields: per-deadline energy-optimal points
    that weakly dominate the latency-only front (asserted — a slack
    point can never cost more energy than its base point, nor exceed
    its deadline)."""
    names = probs.names
    n_net, n_chips = len(names), len(probs.chips)
    if points is not None:
        energy = np.asarray(points[0], dtype=np.float64)
        lat = np.asarray(points[1], dtype=np.float64)
        if energy.shape != (n_chips, n_net):
            raise ValueError(f"points must be [{n_chips}, {n_net}], got "
                             f"{energy.shape}")
        if slack and res is None:
            res = partition.batch_schedule_hetero(
                probs.lat_dense, probs.counts, n_layers=probs.n_layers_b,
                use_jax=use_jax)
    else:
        if res is None:
            res = partition.batch_schedule_hetero(
                probs.lat_dense, probs.counts, n_layers=probs.n_layers_b,
                use_jax=use_jax)
        energy = _scheduled_energy(probs, res).reshape(n_chips, n_net)
        lat = res.bottleneck.reshape(n_chips, n_net)
    norm_e = energy / probs.min_energy[None, :]
    norm_l = lat / probs.min_latency[None, :]
    if deadlines is None:
        # tightest: the best chip's worst-network bottleneck (the first
        # deadline some chip meets for EVERY network); loosest: every
        # chip feasible everywhere.  Feasibility is re-checked in
        # ABSOLUTE space (min_latency · d), and the normalise→rescale
        # round trip can round 1 ulp below the defining latency — widen
        # both endpoints by a relative epsilon so the invariant survives
        deadlines = np.linspace(norm_l.max(axis=1).min(), norm_l.max(),
                                int(n_deadlines)) * (1.0 + 1e-12)
    deadlines = np.asarray(deadlines, dtype=np.float64)
    dl_abs = probs.min_latency[:, None] * deadlines[None, :]   # [N, D]

    _, scores, best, best_net, net_front, chip_front = \
        partition.batch_pareto_scores(norm_e, lat, dl_abs,
                                      norm_latency=norm_l, use_jax=use_jax)

    slack_kw: Dict[str, np.ndarray] = {}
    if slack:
        t_max = probs.counts.shape[1]
        en_dense = _expand_pool_tensor(probs.e_layer, probs.chips, n_net,
                                       t_max)
        dl_prob = np.tile(dl_abs, (n_chips, 1))           # [B, D] rows
        sl = partition.batch_slack_schedule(
            probs.lat_dense, en_dense, probs.counts, dl_prob,
            n_layers=probs.n_layers_b, use_jax=use_jax, base=res)
        n_d = dl_prob.shape[1]
        s_en = sl.energy.reshape(n_chips, n_net, n_d)
        s_lat = sl.bottleneck.reshape(n_chips, n_net, n_d)
        s_feas = sl.feasible.reshape(n_chips, n_net, n_d)
        # guardrail (the frontier must WIDEN, never regress): each slack
        # point spends no more energy than its latency-only base point
        # (rtol: the sequential slack energy sum vs the pairwise base
        # attribution differ by ulps) and meets its deadline bit-exactly
        assert (s_en <= energy[:, :, None] * (1.0 + 1e-9)).all(), \
            "slack pass increased energy — dominance guardrail violated"
        assert np.where(s_feas, s_lat, 0.0).max() < np.inf and \
            (np.where(s_feas, s_lat, -np.inf)
             <= dl_abs[None, :, :]).all(), \
            "slack schedule exceeds its deadline — guardrail violated"
        norm_se = s_en / probs.min_energy[None, :, None]
        feas_all = s_feas.all(axis=1)                     # [n_chips, D]
        with np.errstate(invalid="ignore"):
            s_scores = np.where(feas_all, norm_se.mean(axis=1), np.inf)
        assert (s_scores <= scores * (1.0 + 1e-9)).all(), \
            "slack scores regressed vs latency-only scores"
        any_feas = np.isfinite(s_scores).any(axis=0)
        s_best = np.where(any_feas, np.argmin(s_scores, axis=0), -1)
        slack_kw = dict(
            slack_energy=s_en, slack_latency=s_lat,
            norm_slack_energy=norm_se, slack_scores=s_scores,
            best_chip_slack=s_best,
            slack_moves=sl.n_moves.reshape(n_chips, n_net, n_d))

    return ParetoCoDesign(
        names=list(names), deadlines=deadlines,
        energy=energy, latency=lat,
        norm_energy=norm_e, norm_latency=norm_l,
        scores=scores, best_chip=best, best_chip_net=best_net,
        net_frontier=net_front, chip_frontier=chip_front,
        pool=probs.pool,
        chip_types=[c[0] for c in probs.chips],
        chip_counts=[c[1] for c in probs.chips],
        **slack_kw)


# ---------------------------------------------------------------------------
# Resilience-aware co-design: the same candidate-chip enumeration, scored by
# nominal metric AND by what happens when the hardware breaks.  Every chip ×
# network × fault-scenario re-schedule is solved by ONE
# batch_schedule_hetero(strict=False) call over a 4-D [B, S, T, L] block —
# scenario 0 is the fault-free chip, the rest are slot-parameterised faults
# (core loss / degraded PE arrays per type slot), so the whole resilience
# picture costs one compiled solve instead of a chips × scenarios python
# loop.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ResilienceCoDesign:
    """Result of :func:`resilience_codesign`.

    Scores follow :func:`score_codesign`'s convention (per-network
    scheduled metric normalised by the sweep minimum, averaged over
    networks); ``+inf`` marks a scenario that killed every core of a
    chip (infeasible — reported, never raised).  The ``front`` is the
    weak-dominance front on the (nominal, worst-case) plane: it always
    contains the nominal-only winner, and typically also chips that give
    up a little nominal score for a much better worst case."""

    names: List[str]
    pool: List[int]
    chip_types: List[Tuple[int, ...]]
    chip_counts: List[Tuple[int, ...]]
    scenario_names: List[str]          # [S], "nominal" first
    degradations: List[Tuple[int, int]]
    valid: np.ndarray                  # [n_chips, S] scenario applies
    feasible: np.ndarray               # [n_chips, n_net, S]
    bottleneck: np.ndarray             # [n_chips, n_net, S] (+inf dead)
    energy: np.ndarray                 # [n_chips, n_net, S] (+inf dead)
    scores: np.ndarray                 # [n_chips, S] mean norm metric
    nominal_score: np.ndarray          # [n_chips] == scores[:, 0]
    worst_score: np.ndarray            # [n_chips] max over valid faults
    expected_score: np.ndarray         # [n_chips] mean over valid faults
    front: np.ndarray                  # [n_chips] bool (nominal, worst)
    best_nominal: int                  # argmin nominal_score
    best_robust: int                   # lexicographic (worst, nominal) min
    metric: str
    # deadline mode (resilience_codesign(deadline=...)): every cell above
    # reflects the ENERGY-AWARE slack schedule under that (relative)
    # deadline — feasible means "meets the deadline", energy is +inf
    # where it cannot, and slack_moves counts accepted energy moves
    deadline: float | None = None
    slack_moves: np.ndarray | None = None   # [n_chips, n_net, S]

    @property
    def n_chips(self) -> int:
        return len(self.chip_types)

    @property
    def worst_overhead(self) -> np.ndarray:
        """[n_chips] worst-case score relative to the chip's own nominal."""
        return self.worst_score / self.nominal_score

    def frontier(self) -> List[Tuple[int, float, float]]:
        """Front chips as ``(chip index, nominal, worst)``, best nominal
        first."""
        idx = np.flatnonzero(self.front)
        order = np.lexsort((self.worst_score[idx],
                            self.nominal_score[idx]))
        return [(int(c), float(self.nominal_score[c]),
                 float(self.worst_score[c])) for c in idx[order]]


def resilience_codesign(grid: ConfigGrid,
                        networks: Mapping[str, Sequence[Layer]],
                        m_cores: int = 4,
                        *,
                        max_types: int = 3,
                        pool_size: int = 6,
                        bound: float = 0.05,
                        metric: str = "edp",
                        backend: str | None = None,
                        use_jax: bool | None = None,
                        degradations: Sequence[Tuple[int, int]] = ((4, 4),),
                        probs: CoDesignProblems | None = None,
                        deadline: float | None = None,
                        ) -> ResilienceCoDesign:
    """Co-design under hardware faults: every candidate chip is scored by
    its nominal metric AND by its worst-case / expected metric when a
    core dies or a PE array degrades.

    The scenario set is slot-parameterised so all chips share one
    scenario axis: scenario 0 is nominal; then one whole-core-loss
    scenario per type slot (that slot's count decrements — a single-core
    single-type chip becomes INFEASIBLE, scored +inf); then, for each
    ``(rows_lost, cols_lost)`` in ``degradations``, one scenario per
    type slot where that slot's pool row is replaced by its degraded
    variant (shrunk ``rows``/``cols``, re-evaluated per layer — the
    layers re-balance onto the slower arrays).  Scenarios that name a
    slot a chip does not use are marked invalid for that chip and
    excluded from its worst/expected reductions.

    ONE ``batch_schedule_hetero(strict=False)`` call solves the whole
    ``[chips · networks, scenarios]`` block; the returned
    :class:`ResilienceCoDesign` carries the (nominal, worst-case)
    weak-dominance front, which by construction contains the
    nominal-only winner (nothing can dominate it on the nominal axis).
    Pass ``probs=`` to reuse an existing problem set (e.g. the service's
    cached one); it must come from this ``grid``/``networks``.

    ``deadline`` (RELATIVE, x each network's sweep-minimum latency)
    switches every scenario cell to the energy-aware slack schedule of
    :func:`repro.core.partition.batch_slack_schedule` — the energy the
    chip spends under each fault while still meeting the deadline;
    cells that cannot meet it are infeasible (+inf energy/score)."""
    from ..ft import hw_faults

    if probs is None:
        probs = codesign_problems(grid, networks, m_cores,
                                  max_types=max_types, pool_size=pool_size,
                                  bound=bound, metric=metric,
                                  backend=backend, use_jax=use_jax)
    names, chips = probs.names, probs.chips
    n_net, n_chips = len(names), len(chips)
    B = n_chips * n_net
    t_max = probs.counts.shape[1]
    n_layer = probs.lat_dense.shape[2]
    degradations = [(int(r), int(c)) for r, c in degradations]
    n_deg = len(degradations)
    S = 1 + t_max * (1 + n_deg)

    lat4 = np.repeat(probs.lat_dense[:, None], S, axis=1)
    e4 = np.repeat(
        _expand_pool_tensor(probs.e_layer, chips, n_net,
                            t_max)[:, None], S, axis=1)
    counts4 = np.repeat(probs.counts[:, None], S, axis=1)

    scen_names = ["nominal"]
    n_used = np.asarray([len(ty) for ty, _ in chips])
    valid = np.zeros((n_chips, S), dtype=bool)
    slot_valid = (np.arange(t_max)[None, :] < n_used[:, None])
    for s in range(t_max):
        scen_names.append(f"core_loss@slot{s}")
        counts4[:, 1 + s, s] = np.maximum(counts4[:, 1 + s, s] - 1, 0)
        valid[:, 1 + s] = slot_valid[:, s]
    for di, (r, c) in enumerate(degradations):
        deg_grid = hw_faults.degrade_rows(grid.take(probs.pool), r, c)
        e_d, t_d = energymodel.evaluate_networks(
            deg_grid, networks, use_jax=use_jax, backend=backend,
            per_layer=True)
        lat_deg = _expand_pool_tensor(t_d, chips, n_net, t_max)
        en_deg = _expand_pool_tensor(e_d, chips, n_net, t_max)
        for s in range(t_max):
            sidx = 1 + t_max * (1 + di) + s
            scen_names.append(f"degrade_r{r}c{c}@slot{s}")
            lat4[:, sidx, s, :] = lat_deg[:, s, :]
            e4[:, sidx, s, :] = en_deg[:, s, :]
            valid[:, sidx] = slot_valid[:, s]

    labels = [f"{names[b % n_net]}@chip{b // n_net}:{scen_names[s]}"
              for b in range(B) for s in range(S)]
    res = partition.batch_schedule_hetero(
        lat4, counts4, n_layers=probs.n_layers_b, use_jax=use_jax,
        strict=False, labels=labels)

    slack_moves = None
    if deadline is None:
        tt = res.layer_type[:, :n_layer]
        energy = np.take_along_axis(
            e4.reshape(B * S, t_max, n_layer),
            tt[:, None, :], axis=1)[:, 0, :].sum(-1)
        feas = res.feasible.reshape(n_chips, n_net, S)
        bott = res.bottleneck.reshape(n_chips, n_net, S)
        energy = np.where(feas, energy.reshape(n_chips, n_net, S), np.inf)
    else:
        # per-row absolute deadline: flat row b·S + s belongs to network
        # (row // S) % n_net
        dl_rows = np.tile(np.repeat(probs.min_latency * float(deadline),
                                    S), n_chips)[:, None]
        sl = partition.batch_slack_schedule(
            lat4, e4, counts4, dl_rows, n_layers=probs.n_layers_b,
            use_jax=use_jax, base=res)
        feas = sl.feasible[:, 0].reshape(n_chips, n_net, S)
        bott = sl.bottleneck[:, 0].reshape(n_chips, n_net, S)
        energy = np.where(feas, sl.energy[:, 0].reshape(n_chips, n_net, S),
                          np.inf)
        slack_moves = sl.n_moves[:, 0].reshape(n_chips, n_net, S)

    if metric == "energy":
        cell, ref = energy, probs.min_energy
    elif metric == "latency":
        cell, ref = np.where(feas, bott, np.inf), probs.min_latency
    else:
        cell, ref = energy * np.where(feas, bott, 1.0), probs.min_edp
    scores = (cell / ref[None, :, None]).mean(axis=1)       # [n_chips, S]

    fault = valid.copy()
    fault[:, 0] = False
    worst = np.where(fault, scores, -np.inf).max(axis=1)
    with np.errstate(invalid="ignore"):
        expected = (np.where(fault, scores, 0.0).sum(axis=1)
                    / np.maximum(fault.sum(axis=1), 1))
    nominal = scores[:, 0]

    a1, a2 = nominal[:, None], nominal[None, :]
    b1, b2 = worst[:, None], worst[None, :]
    dom = (a2 <= a1) & (b2 <= b1) & ((a2 < a1) | (b2 < b1))
    front = ~dom.any(axis=1)
    best_nominal = int(np.argmin(nominal))
    best_robust = int(np.lexsort((nominal, worst))[0])
    return ResilienceCoDesign(
        names=list(names), pool=list(probs.pool),
        chip_types=[c[0] for c in chips],
        chip_counts=[c[1] for c in chips],
        scenario_names=scen_names, degradations=degradations,
        valid=valid, feasible=feas, bottleneck=bott, energy=energy,
        scores=scores, nominal_score=nominal, worst_score=worst,
        expected_score=expected, front=front,
        best_nominal=best_nominal, best_robust=best_robust,
        metric=metric,
        deadline=None if deadline is None else float(deadline),
        slack_moves=slack_moves)


def savings_summary(chip: HeteroChip) -> Dict[str, Dict[str, float]]:
    """Per-network savings of the heterogeneous assignment vs. the worst
    single-core-type choice (the paper's headline: up to 36% energy / 67%
    EDP saved by running on the near-optimal core).

    One gather per metric: the core cells are flattened to indices once
    and every (network × core) value is pulled with array indexing — no
    per-network/per-core Python loops."""
    names = list(chip.assignment)
    shape = next(iter(chip.sweeps.values())).energy.shape
    core_flat = np.ravel_multi_index(
        np.asarray(chip.core_types, dtype=np.intp).T, shape)
    energy = np.stack([chip.sweeps[n].energy.ravel()[core_flat]
                       for n in names])            # [n_net, n_cores]
    edp = np.stack([chip.sweeps[n].edp.ravel()[core_flat] for n in names])
    own = np.asarray([chip.assignment[n] for n in names], dtype=np.intp)
    rows = np.arange(len(names))
    worst_e, worst_edp = energy.max(axis=1), edp.max(axis=1)
    e_saved = (worst_e - energy[rows, own]) / worst_e * 100.0
    edp_saved = (worst_edp - edp[rows, own]) / worst_edp * 100.0
    return {n: dict(energy_saved=float(e_saved[i]),
                    edp_saved=float(edp_saved[i]))
            for i, n in enumerate(names)}
