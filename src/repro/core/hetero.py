"""Heterogeneous multi-core chip scheme (§IV.A).

Procedure, as the paper describes it:

1.  For every network, evaluate the target metric (EDP by default) over the
    whole search space and keep every configuration within a boundary (5%)
    of that network's minimum → candidate sets (Table 5).
2.  Select a small number of *common* configurations such that the maximum
    number of networks runs near-optimally → the chip's core types (greedy
    set cover over the candidate sets).
3.  Every network is assigned to the core type that covers it (or, if none
    covers it within the boundary, the type with the least penalty).

``cross_penalty`` reproduces Table 6: the increase in energy, delay, and EDP
when a network runs on a non-corresponding core type.

Array-shape conventions: dense chip design (``design_chip``) works on the
``[n_array, n_psum, n_ifmap]`` metric cubes of :class:`SweepResult`, with
candidate sets as ``(array_idx, psum_idx, ifmap_idx)`` cells; the
streaming variant (``design_chip_streaming``) works on FLAT grid indices
into a :class:`repro.core.accelerator.ConfigGrid` (the boundary sets a
``StreamResult`` carries — the full ``[n_cfg, n_net]`` matrices are never
materialised), and ``StreamChip.core_cells`` converts back to cells.
Both share ``_greedy_cover`` over per-network candidate-index sets, so
they provably pick identical core types.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence, Tuple

import numpy as np

from . import energymodel
from .accelerator import ConfigGrid
from .dse import SweepResult, boundary_configs
from .topology import Layer

Cell = Tuple[int, int, int]     # (array_idx, psum_idx, ifmap_idx)


@dataclasses.dataclass
class HeteroChip:
    core_types: List[Cell]                    # chosen configurations
    assignment: Dict[str, int]                # network -> core-type index
    candidate_sets: Dict[str, List[Cell]]     # Table 5 per network
    sweeps: Dict[str, SweepResult]

    def core_label(self, idx: int) -> str:
        any_sweep = next(iter(self.sweeps.values()))
        return any_sweep.cell_label(self.core_types[idx])


def _greedy_cover(cand: np.ndarray, rel: np.ndarray, max_cores: int):
    """Shared greedy set-cover core of both design_chip paths.

    ``cand``/``rel`` are [n_net, n_pts]; each round picks the point
    covering the most uncovered networks (ties → lower total relative
    metric across covered networks, then lower point index).  Returns
    (selected point columns, {net row → core index}, uncovered mask)."""
    uncovered = np.ones(cand.shape[0], dtype=bool)
    cols: List[int] = []
    assign: Dict[int, int] = {}
    while uncovered.any() and len(cols) < max_cores:
        counts = cand[uncovered].sum(axis=0)
        best_count = counts.max() if counts.size else 0
        if best_count == 0:
            break
        rel_sum = np.where(cand[uncovered], rel[uncovered], 0.0).sum(axis=0)
        tied = np.flatnonzero(counts == best_count)
        col = int(tied[np.argmin(rel_sum[tied])])

        idx = len(cols)
        cols.append(col)
        covered_now = cand[:, col] & uncovered
        for i in np.flatnonzero(covered_now):
            assign[int(i)] = idx
        uncovered &= ~covered_now
    return cols, assign, uncovered


def design_chip(sweeps: Dict[str, SweepResult], bound: float = 0.05,
                metric: str = "edp", max_cores: int = 4) -> HeteroChip:
    """Greedy common-configuration cover → heterogeneous core types.

    Fully vectorised: the per-network metric cubes are flattened into a
    [n_net, n_points] matrix once, and each greedy round is a handful of
    masked reductions — no per-cell Python loops — so the cover stays
    interactive on multi-thousand-point grids.
    """
    names = list(sweeps)
    candidates = {name: boundary_configs(sweeps[name], bound, metric)
                  for name in names}

    mats = np.stack([sweeps[n].metric(metric).ravel() for n in names])
    shape = next(iter(sweeps.values())).metric(metric).shape
    mins = mats.min(axis=1, keepdims=True)
    cand = mats <= mins * (1.0 + bound)           # [n_net, n_pts] bool
    rel = mats / mins                             # metric / per-net minimum

    core_flat, assign, uncovered = _greedy_cover(cand, rel, max_cores)
    assignment = {names[i]: idx for i, idx in assign.items()}

    core_types: List[Cell] = [
        tuple(int(x) for x in np.unravel_index(c, shape)) for c in core_flat]

    # Networks not covered within the boundary: assign to the least-penalty
    # existing core type.
    if uncovered.any() and core_flat:
        vals = mats[:, core_flat]                 # [n_net, n_cores]
        best = np.argmin(vals, axis=1)
        for i in np.flatnonzero(uncovered):
            assignment[names[i]] = int(best[i])

    return HeteroChip(core_types=core_types, assignment=assignment,
                      candidate_sets=candidates, sweeps=sweeps)


@dataclasses.dataclass
class StreamChip:
    """Heterogeneous chip designed from a streamed sweep: core types are
    FLAT grid indices (mega grids are not 3-D cubes)."""

    core_types: List[int]
    assignment: Dict[str, int]                # network -> core-type index
    candidate_sets: Dict[str, List[int]]      # flat indices, best first
    stream: "energymodel.StreamResult"

    def core_label(self, idx: int, grid: ConfigGrid) -> str:
        return grid.config_at(self.core_types[idx]).label()

    def core_cells(self, shape: Tuple[int, ...]) -> List[Cell]:
        """Unravel the flat core indices onto a sweep cube shape."""
        return [tuple(int(x) for x in np.unravel_index(c, shape))
                for c in self.core_types]


def design_chip_streaming(stream: "energymodel.StreamResult",
                          grid: ConfigGrid,
                          networks: Mapping[str, Sequence[Layer]],
                          max_cores: int = 4,
                          use_jax: bool | None = None) -> StreamChip:
    """Greedy cover over a StreamResult's boundary sets — no full cubes.

    Exactly reproduces :func:`design_chip`'s choices: any point that can
    cover a network lies in that network's boundary set, so the greedy
    only ever needs the union of the streamed candidate sets.  Networks
    left uncovered are assigned by evaluating just the chosen core cells
    (a ≤max_cores-point grid) exactly.
    """
    names = list(stream.networks)
    union = np.unique(np.concatenate(
        [stream.boundary_idx[nm] for nm in names]))
    cand = np.zeros((len(names), union.size), dtype=bool)
    rel = np.zeros((len(names), union.size))
    for i, nm in enumerate(names):
        pos = np.searchsorted(union, stream.boundary_idx[nm])
        cand[i, pos] = True
        rel[i, pos] = stream.boundary_metric(nm) / stream.min_metric[i]

    cols, assign, uncovered = _greedy_cover(cand, rel, max_cores)
    core_flat = [int(union[c]) for c in cols]
    assignment = {names[i]: idx for i, idx in assign.items()}

    if uncovered.any() and core_flat:
        # exact evaluation of the few chosen cells for every network
        e, t = energymodel.evaluate_networks(
            grid.take(core_flat), {nm: networks[nm] for nm in names},
            use_jax=use_jax)
        vals = energymodel._metric_of(stream.metric, e, t).T
        best = np.argmin(vals, axis=1)
        for i in np.flatnonzero(uncovered):
            assignment[names[i]] = int(best[i])

    candidate_sets = {nm: [int(c) for c in stream.boundary_idx[nm]]
                      for nm in names}
    return StreamChip(core_types=core_flat, assignment=assignment,
                      candidate_sets=candidate_sets, stream=stream)


def cross_penalty(chip: HeteroChip, network: str, other_core: int
                  ) -> Dict[str, float]:
    """Table 6: Δ_E, Δ_D, Δ_EDP (%) of running ``network`` on a
    non-corresponding core type instead of its own."""
    sw = chip.sweeps[network]
    own = chip.core_types[chip.assignment[network]]
    oth = chip.core_types[other_core]
    d_e = (sw.energy[oth] - sw.energy[own]) / sw.energy[own] * 100.0
    d_d = (sw.latency[oth] - sw.latency[own]) / sw.latency[own] * 100.0
    d_edp = (sw.edp[oth] - sw.edp[own]) / sw.edp[own] * 100.0
    return dict(dE=float(d_e), dD=float(d_d), dEDP=float(d_edp))


def savings_summary(chip: HeteroChip) -> Dict[str, Dict[str, float]]:
    """Per-network savings of the heterogeneous assignment vs. the worst
    single-core-type choice (the paper's headline: up to 36% energy / 67%
    EDP saved by running on the near-optimal core).

    One gather per metric: the core cells are flattened to indices once
    and every (network × core) value is pulled with array indexing — no
    per-network/per-core Python loops."""
    names = list(chip.assignment)
    shape = next(iter(chip.sweeps.values())).energy.shape
    core_flat = np.ravel_multi_index(
        np.asarray(chip.core_types, dtype=np.intp).T, shape)
    energy = np.stack([chip.sweeps[n].energy.ravel()[core_flat]
                       for n in names])            # [n_net, n_cores]
    edp = np.stack([chip.sweeps[n].edp.ravel()[core_flat] for n in names])
    own = np.asarray([chip.assignment[n] for n in names], dtype=np.intp)
    rows = np.arange(len(names))
    worst_e, worst_edp = energy.max(axis=1), edp.max(axis=1)
    e_saved = (worst_e - energy[rows, own]) / worst_e * 100.0
    edp_saved = (worst_edp - edp[rows, own]) / worst_edp * 100.0
    return {n: dict(energy_saved=float(e_saved[i]),
                    edp_saved=float(edp_saved[i]))
            for i, n in enumerate(names)}
