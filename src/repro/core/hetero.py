"""Heterogeneous multi-core chip scheme (§IV.A).

Procedure, as the paper describes it:

1.  For every network, evaluate the target metric (EDP by default) over the
    whole search space and keep every configuration within a boundary (5%)
    of that network's minimum → candidate sets (Table 5).
2.  Select a small number of *common* configurations such that the maximum
    number of networks runs near-optimally → the chip's core types (greedy
    set cover over the candidate sets).
3.  Every network is assigned to the core type that covers it (or, if none
    covers it within the boundary, the type with the least penalty).

``cross_penalty`` reproduces Table 6: the increase in energy, delay, and EDP
when a network runs on a non-corresponding core type.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .dse import SweepResult, boundary_configs

Cell = Tuple[int, int, int]     # (array_idx, psum_idx, ifmap_idx)


@dataclasses.dataclass
class HeteroChip:
    core_types: List[Cell]                    # chosen configurations
    assignment: Dict[str, int]                # network -> core-type index
    candidate_sets: Dict[str, List[Cell]]     # Table 5 per network
    sweeps: Dict[str, SweepResult]

    def core_label(self, idx: int) -> str:
        any_sweep = next(iter(self.sweeps.values()))
        return any_sweep.cell_label(self.core_types[idx])


def design_chip(sweeps: Dict[str, SweepResult], bound: float = 0.05,
                metric: str = "edp", max_cores: int = 4) -> HeteroChip:
    """Greedy common-configuration cover → heterogeneous core types.

    Fully vectorised: the per-network metric cubes are flattened into a
    [n_net, n_points] matrix once, and each greedy round is a handful of
    masked reductions — no per-cell Python loops — so the cover stays
    interactive on multi-thousand-point grids.
    """
    names = list(sweeps)
    candidates = {name: boundary_configs(sweeps[name], bound, metric)
                  for name in names}

    mats = np.stack([sweeps[n].metric(metric).ravel() for n in names])
    shape = next(iter(sweeps.values())).metric(metric).shape
    mins = mats.min(axis=1, keepdims=True)
    cand = mats <= mins * (1.0 + bound)           # [n_net, n_pts] bool
    rel = mats / mins                             # metric / per-net minimum

    uncovered = np.ones(len(names), dtype=bool)
    core_flat: List[int] = []
    assignment: Dict[str, int] = {}

    while uncovered.any() and len(core_flat) < max_cores:
        # cell covering the most uncovered networks; ties → lower total
        # relative metric across covered networks.
        counts = cand[uncovered].sum(axis=0)
        best_count = counts.max()
        if best_count == 0:
            break
        rel_sum = np.where(cand[uncovered], rel[uncovered], 0.0).sum(axis=0)
        tied = np.flatnonzero(counts == best_count)
        cell_flat = int(tied[np.argmin(rel_sum[tied])])

        idx = len(core_flat)
        core_flat.append(cell_flat)
        covered_now = cand[:, cell_flat] & uncovered
        for i in np.flatnonzero(covered_now):
            assignment[names[i]] = idx
        uncovered &= ~covered_now

    core_types: List[Cell] = [
        tuple(int(x) for x in np.unravel_index(c, shape)) for c in core_flat]

    # Networks not covered within the boundary: assign to the least-penalty
    # existing core type.
    if uncovered.any() and core_flat:
        vals = mats[:, core_flat]                 # [n_net, n_cores]
        best = np.argmin(vals, axis=1)
        for i in np.flatnonzero(uncovered):
            assignment[names[i]] = int(best[i])

    return HeteroChip(core_types=core_types, assignment=assignment,
                      candidate_sets=candidates, sweeps=sweeps)


def cross_penalty(chip: HeteroChip, network: str, other_core: int
                  ) -> Dict[str, float]:
    """Table 6: Δ_E, Δ_D, Δ_EDP (%) of running ``network`` on a
    non-corresponding core type instead of its own."""
    sw = chip.sweeps[network]
    own = chip.core_types[chip.assignment[network]]
    oth = chip.core_types[other_core]
    d_e = (sw.energy[oth] - sw.energy[own]) / sw.energy[own] * 100.0
    d_d = (sw.latency[oth] - sw.latency[own]) / sw.latency[own] * 100.0
    d_edp = (sw.edp[oth] - sw.edp[own]) / sw.edp[own] * 100.0
    return dict(dE=float(d_e), dD=float(d_d), dEDP=float(d_edp))


def savings_summary(chip: HeteroChip) -> Dict[str, Dict[str, float]]:
    """Per-network savings of the heterogeneous assignment vs. the worst
    single-core-type choice (the paper's headline: up to 36% energy / 67%
    EDP saved by running on the near-optimal core)."""
    out = {}
    for name in chip.assignment:
        sw = chip.sweeps[name]
        own = chip.core_types[chip.assignment[name]]
        worst_e = max(float(sw.energy[c]) for c in chip.core_types)
        worst_edp = max(float(sw.edp[c]) for c in chip.core_types)
        out[name] = dict(
            energy_saved=(worst_e - float(sw.energy[own])) / worst_e * 100.0,
            edp_saved=(worst_edp - float(sw.edp[own])) / worst_edp * 100.0)
    return out
