"""Model parallelism on homogeneous cores (§IV.B, Algorithm II).

A network's layers are distributed *contiguously* over k identical cores
forming a processing pipeline through off-chip DRAM (Fig. 11).  The pipeline
latency is the maximum per-core latency; the speedup of eq. (6) is

    speedup = sum(latencies) / max(core latency).

``bb_partition`` is the paper's branch-and-bound: walk layers accumulating
latency until the running sum crosses the balanced average, branch on
including/excluding the crossing layer, and bound any branch whose current
core latency already exceeds the best pipeline latency found so far.

``dp_partition`` is an exact oracle (classic linear-partition DP) and
``brute_force_partition`` enumerates all splits — both used by the tests to
verify the B&B lands on (near-)optimal pipelines, and by the TPU adaptation
(`parallel/pipeline.py`) to place transformer layers on pipeline stages.

``batch_partition`` is the production hot path: a vectorized parametric
search that solves ALL (network × core-count) splits in one call — binary
search on the bottleneck latency T, with a ``searchsorted``-style greedy
feasibility check over prefix sums, batched over every (network, k) pair.
Segment sums are evaluated as prefix differences, the same arithmetic
``dp_partition`` uses, so the two agree exactly.

Array-shape conventions: per-network layer latencies arrive as 1-D
``[n_layers]`` vectors (``NetworkReport.layer_latencies`` from
:mod:`repro.core.energymodel`, in ns); the batch solver pads them to one
``[n_networks, n_pad]`` matrix (bucketed like the DSE engine's layer
axis, so repeated zoo-sized calls share one trace) with a validity mask,
and broadcasts the bisection over a ``[n_networks, n_k]`` problem grid.
A :class:`Partition` stores ``boundaries`` as the k+1 split indices into
the layer axis (``boundaries[0] == 0``, contiguous, monotone) and
``loads`` as the per-core latency sums — ``pipeline_latency =
max(loads)`` and eq. (6)'s ``speedup = sum / max``.

``batch_schedule_hetero`` generalises the solver beyond same-type cores
(the heterogeneous-chip co-design of :func:`repro.core.hetero.co_design`):
each problem is a (chip, network) pair with per-layer latencies on every
core TYPE (``[n_types, n_layers]``, from the DSE engine's
``per_layer=True`` path) and a core count per type.  The schedule is
defined in two exact stages — (1) every layer goes to the available type
that runs it fastest (per-layer argmin, ties → lower type index); (2)
each type's layer subsequence is split contiguously over that type's
cores, all types balanced against ONE shared pipeline bottleneck.
Feasibility of a bottleneck T is the conjunction of the per-type greedy
coverings (each monotone in T), so a single bisection per problem drives
every (problem × type) greedy row at once, and the optimum is exactly
``max over types of dp_partition(type's subsequence, type's cores)`` —
the oracle :func:`schedule_hetero_oracle` the tests compare against.
With one type and count k this degenerates to ``batch_partition``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .energymodel import _bucketed, jax_available


@dataclasses.dataclass(frozen=True)
class Partition:
    """Contiguous layer → core assignment."""

    boundaries: Tuple[int, ...]   # start index of each core's slice
    loads: Tuple[float, ...]      # per-core total latency
    pipeline_latency: float       # max(loads)
    speedup: float                # eq. (6)
    n_layers: int = 0

    @property
    def n_cores(self) -> int:
        return len(self.loads)

    def table_row(self) -> List[Tuple[int, int]]:
        """(l_initial, n_C) tuples, 1-indexed like Tables 7–8."""
        bounds = list(self.boundaries) + [self.n_layers]
        return [(bounds[i] + 1, bounds[i + 1] - bounds[i])
                for i in range(len(self.boundaries))]


def _mk_partition(lat: Sequence[float], bounds: Sequence[int]) -> Partition:
    lat = np.asarray(lat, dtype=np.float64)
    prefix = np.concatenate([[0.0], np.cumsum(lat)])
    starts = np.asarray(bounds, dtype=np.intp)
    ends = np.concatenate([starts[1:], [lat.size]])
    loads = prefix[ends] - prefix[starts]        # O(k), not O(k·n)
    total = float(prefix[-1])
    pipe = float(loads.max())
    return Partition(boundaries=tuple(int(b) for b in starts),
                     loads=tuple(float(x) for x in loads),
                     pipeline_latency=pipe,
                     speedup=total / pipe if pipe > 0 else float("inf"),
                     n_layers=int(lat.size))


def bb_partition(latencies: Sequence[float], n_cores: int) -> Partition:
    """Algorithm II: branch-and-bound layer distribution."""
    lat = [float(x) for x in latencies]
    n = len(lat)
    if n_cores <= 1 or n <= n_cores:
        bounds = list(range(min(n, n_cores)))
        return _mk_partition(lat, bounds)

    total = sum(lat)
    avg = total / n_cores
    suffix = np.concatenate([np.cumsum(lat[::-1])[::-1], [0.0]])

    best = {"pipe": float("inf"), "bounds": None}

    def rec(i: int, cores_left: int, cur_max: float, bounds: List[int]):
        # Assign layers [i:] to the remaining cores; bounds holds the start
        # index of every core opened so far.
        if cur_max >= best["pipe"]:
            return                      # bound condition
        if cores_left == 1:
            seg = float(suffix[i])
            pipe = max(cur_max, seg)
            if pipe < best["pipe"]:
                best["pipe"] = pipe
                best["bounds"] = bounds + [i]
            return
        # accumulate from layer i until the running sum crosses the average
        s = 0.0
        j = i
        while j < n - (cores_left - 1) and s + lat[j] < avg:
            s += lat[j]
            j += 1
        j = min(j, n - (cores_left - 1))
        # branch 1: include the crossing layer (segment sum ≥ avg)
        hi = min(j + 1, n - (cores_left - 1))
        s_hi = float(sum(lat[i:hi]))
        rec(hi, cores_left - 1, max(cur_max, s_hi), bounds + [i])
        # branch 2: exclude it (segment sum < avg)
        if j > i and j != hi:
            s_lo = float(sum(lat[i:j]))
            rec(j, cores_left - 1, max(cur_max, s_lo), bounds + [i])

    rec(0, n_cores, 0.0, [])
    assert best["bounds"] is not None
    return _mk_partition(lat, best["bounds"])


def dp_partition(latencies: Sequence[float], n_cores: int) -> Partition:
    """Exact minimal-bottleneck contiguous partition (DP oracle)."""
    lat = [float(x) for x in latencies]
    n = len(lat)
    k = min(n_cores, n) if n else 1
    prefix = np.concatenate([[0.0], np.cumsum(lat)])

    # dp[c][i] = minimal pipeline latency splitting lat[:i] into c cores.
    # The inner minimisation over the cut point j is vectorised with numpy
    # over prefix sums (argmin keeps the first minimum, matching the
    # original scalar loop's strict-improvement tie-breaking).
    NEG = float("inf")
    dp = np.full((k + 1, n + 1), NEG)
    cut = np.zeros((k + 1, n + 1), dtype=int)
    dp[0][0] = 0.0
    for c in range(1, k + 1):
        prev = dp[c - 1]
        for i in range(c, n + 1):
            j0 = c - 1
            cand = np.maximum(prev[j0:i], prefix[i] - prefix[j0:i])
            bj = int(np.argmin(cand))
            dp[c][i] = cand[bj]
            cut[c][i] = j0 + bj
    bounds: List[int] = []
    i = n
    for c in range(k, 0, -1):
        j = int(cut[c][i])
        bounds.append(j)
        i = j
    bounds.reverse()
    return _mk_partition(lat, bounds)


def brute_force_partition(latencies: Sequence[float], n_cores: int
                          ) -> Partition:
    """Enumerate every contiguous split (tests only; exponential)."""
    lat = [float(x) for x in latencies]
    n = len(lat)
    k = min(n_cores, n)
    best = None
    for cuts in itertools.combinations(range(1, n), k - 1):
        bounds = [0] + list(cuts)
        p = _mk_partition(lat, bounds)
        if best is None or p.pipeline_latency < best.pipeline_latency:
            best = p
    return best if best is not None else _mk_partition(lat, [0])


# ---------------------------------------------------------------------------
# Batched parametric search: all (network × k) splits in one vectorized call.
#
# Feasibility of a bottleneck T is monotone (feasible ⟺ T ≥ T*), so a
# bisection on T converges to the optimum; every bisection step runs ONE
# greedy maximal-jump segmentation for ALL (network, k) pairs at once, each
# jump a vectorized binary search over the per-network prefix-sum rows.
# _BISECT_ITERS halvings shrink the bracket below one ulp of T* (see the
# constant's note), and segment sums are prefix DIFFERENCES throughout
# (never ``prefix + T`` sums), so the final bottleneck is bit-identical to
# ``dp_partition``'s.
# ---------------------------------------------------------------------------

#: Bisection steps: the initial bracket is at most ~one bottleneck wide
#: (see the lb/hi seeding in batch_partition), so 56 halvings push the
#: bracket below one ulp of the optimum — the greedy segmentation at the
#: upper end then lands on it exactly.
_BISECT_ITERS = 56

#: Static-shape buckets for the jitted solver: padding the prefix axis and
#: the (network × k) row axis to these multiples keeps the module-level
#: compile cache warm across calls with nearby problem sizes.
_N_BUCKET = 64
_ROW_BUCKET = 32
_K_MAX = 8


def _row_searchsorted(P: np.ndarray, net: np.ndarray, pos: np.ndarray,
                      thr: np.ndarray) -> np.ndarray:
    """Per-row maximal jump: largest j with P[net, j] − P[net, pos] ≤ thr.

    ``P`` rows are non-decreasing (prefix sums padded with +inf), so the
    predicate is monotone in j and a batched binary search finds the last
    true position.  Comparisons subtract prefixes — the exact arithmetic of
    the DP oracle — rather than pre-adding ``thr`` to the base (which would
    round and admit off-by-one-ulp jumps)."""
    base = P[net, pos]
    lo = pos.copy()                       # predicate holds at pos (0 ≤ thr)
    hi = np.full_like(pos, P.shape[1] - 1)
    steps = int(np.ceil(np.log2(P.shape[1]))) + 1
    for _ in range(steps):
        mid = (lo + hi + 1) >> 1
        ok = P[net, mid] - base <= thr
        lo = np.where(ok, mid, lo)
        hi = np.where(ok, hi, mid - 1)
    return lo


def _batch_greedy(P: np.ndarray, net: np.ndarray, n_arr: np.ndarray,
                  thr: np.ndarray, kk: np.ndarray, k_max: int,
                  exact: bool):
    """Greedy maximal-jump segmentation at threshold ``thr`` for every row.

    ``exact=False``: feasibility — True where ≤ kk segments cover all
    layers with every segment sum ≤ thr.  ``exact=True``: returns the
    [rows, k_max] start indices of an exactly-kk segmentation (each of the
    remaining segments is guaranteed ≥ 1 layer), valid when thr ≥ T*.
    """
    rows = net.shape[0]
    pos = np.zeros(rows, dtype=np.intp)
    viol = np.zeros(rows, dtype=bool)
    starts = np.full((rows, k_max), 0, dtype=np.intp) if exact else None
    for s in range(k_max):
        active = (s < kk) & (pos < n_arr)
        j = _row_searchsorted(P, net, pos, thr)
        if exact:
            rem = kk - s                      # segments still to open
            j = np.minimum(j, n_arr - np.maximum(rem - 1, 0))
        j = np.maximum(j, pos + 1)            # force progress …
        j = np.minimum(j, n_arr)              # … but stay in bounds
        viol |= active & (P[net, j] - P[net, pos] > thr)
        if exact:
            starts[:, s] = np.where(s < kk, np.minimum(pos, n_arr), n_arr)
        pos = np.where(active, j, pos)
    if exact:
        return starts
    return (pos >= n_arr) & ~viol


_jitted_solver = None          # built lazily on first jax dispatch


def _jax_solver():
    """One fused XLA program for the whole parametric search: the bisection
    on the bottleneck latency (each step one greedy maximal-jump
    feasibility over all (network, k) rows) plus the final exact-k
    segmentation.  The inner binary search and the greedy segment loop are
    UNROLLED (static bs_steps / _K_MAX) so each bisection step is one
    straight-line fused body; only the bisection itself is a sequential
    device loop.  Jitted at module level, so the all-pairs solve is ONE
    device dispatch instead of thousands of tiny numpy ops."""
    global _jitted_solver
    if _jitted_solver is None:
        import jax
        import jax.numpy as jnp
        from jax import lax

        def solve(P, net, n_arr, kk, lo, hi, k_max, bs_steps):
            def rowsearch(pos, thr):
                base = P[net, pos]
                blo = pos
                bhi = jnp.full_like(pos, P.shape[1] - 1)
                for _ in range(bs_steps):
                    mid = (blo + bhi + 1) >> 1
                    ok = P[net, mid] - base <= thr
                    blo = jnp.where(ok, mid, blo)
                    bhi = jnp.where(ok, bhi, mid - 1)
                return blo

            def feasible(thr):
                pos = jnp.zeros_like(net)
                viol = jnp.zeros(net.shape, bool)
                for s in range(k_max):
                    active = (s < kk) & (pos < n_arr)
                    j = rowsearch(pos, thr)
                    j = jnp.minimum(jnp.maximum(j, pos + 1), n_arr)
                    viol = viol | (active & (P[net, j] - P[net, pos] > thr))
                    pos = jnp.where(active, j, pos)
                return (pos >= n_arr) & ~viol

            def bisect(_, lh):
                blo, bhi = lh
                mid = 0.5 * (blo + bhi)
                feas = feasible(mid)
                return (jnp.where(feas, blo, mid),
                        jnp.where(feas, mid, bhi))
            lo_f, hi_f = lax.fori_loop(0, _BISECT_ITERS, bisect, (lo, hi))

            starts = []
            pos = jnp.zeros_like(net)
            for s in range(k_max):            # static unroll; kk masks
                starts.append(jnp.where(s < kk,
                                        jnp.minimum(pos, n_arr), n_arr))
                j = rowsearch(pos, hi_f)
                j = jnp.minimum(j, n_arr - jnp.maximum(kk - s - 1, 0))
                j = jnp.minimum(jnp.maximum(j, pos + 1), n_arr)
                pos = jnp.where((s < kk) & (pos < n_arr), j, pos)
            return jnp.stack(starts, axis=1)

        _jitted_solver = jax.jit(solve, static_argnums=(6, 7))
    return _jitted_solver


def batch_partition(latencies: Sequence[Sequence[float]],
                    n_cores: Sequence[int] | int,
                    use_jax: bool | None = None,
                    ) -> List[Dict[int, Partition]]:
    """Solve every (network, k) minimal-bottleneck split in one call.

    ``latencies`` is a sequence of per-network layer-latency sequences and
    ``n_cores`` an int or sequence of core counts; returns one
    ``{k: Partition}`` dict per network.  Pipeline latencies are exactly
    ``dp_partition``'s (same prefix-difference arithmetic): the
    ``_BISECT_ITERS``-step bisection shrinks the bracket below one ulp of
    the optimum, so the greedy segmentation at the upper bracket lands on
    it exactly.  With
    jax available the whole search is one jitted dispatch; the numpy body
    is the reference fallback.
    """
    lats = [np.asarray(l, dtype=np.float64) for l in latencies]
    ks = ((int(n_cores),) if isinstance(n_cores, (int, np.integer))
          else tuple(int(k) for k in n_cores))
    if not lats or not ks:
        return [dict() for _ in lats]
    if max(ks) > _K_MAX and use_jax is not False:
        use_jax = False                    # solver unrolls _K_MAX segments
    use_jax = (jax_available() if use_jax is None else use_jax)
    n_lens = np.array([l.size for l in lats], dtype=np.int64)
    n_max = int(n_lens.max())
    n_net = len(lats)

    n_pad = _bucketed(n_max, _N_BUCKET) if use_jax else n_max
    P = np.full((n_net, n_pad + 1), np.inf)
    mx = np.zeros(n_net)
    for i, l in enumerate(lats):
        P[i, 0] = 0.0
        P[i, 1:l.size + 1] = np.cumsum(l)
        mx[i] = l.max() if l.size else 0.0

    # one row per (network, requested k), clamped like dp_partition
    net = np.repeat(np.arange(n_net, dtype=np.int64), len(ks))
    k_req = np.tile(np.asarray(ks, dtype=np.int64), n_net)
    kk = np.minimum(np.maximum(k_req, 1), np.maximum(n_lens[net], 1))
    k_max = int(kk.max())
    n_arr = n_lens[net]
    n_rows = net.size

    total = P[net, n_arr]
    # Tight initial bracket: any bottleneck is ≥ max(max layer, total/k),
    # and the greedy bound gives T* ≤ total/k + max layer.  The tiny
    # relative slack absorbs the rounding of the bound itself; the
    # bisection count then only has to cover the ~2^53 floats inside.
    lb = np.maximum(mx[net], total / np.maximum(kk, 1))
    lo = np.nextafter(lb, -np.inf)
    hi = np.minimum(total, (total / np.maximum(kk, 1) + mx[net])
                    * (1.0 + 1e-12))

    if use_jax:
        r_pad = _bucketed(n_rows, _ROW_BUCKET)
        pad = r_pad - n_rows
        netp = np.concatenate([net, np.zeros(pad, np.int64)])
        n_ap = np.concatenate([n_arr, np.full(pad, n_lens[0], np.int64)])
        kkp = np.concatenate([kk, np.ones(pad, np.int64)])
        lop = np.concatenate([lo, np.full(pad, lo[0] if n_rows else 0.0)])
        hip = np.concatenate([hi, np.full(pad, hi[0] if n_rows else 1.0)])
        from jax.experimental import enable_x64
        with enable_x64():
            bs_steps = int(np.ceil(np.log2(n_pad + 1))) + 1
            starts = np.asarray(_jax_solver()(
                P, netp, n_ap, kkp, lop, hip, _K_MAX, bs_steps))[:n_rows]
    else:
        for _ in range(_BISECT_ITERS):
            mid = 0.5 * (lo + hi)
            feas = _batch_greedy(P, net, n_arr, mid, kk, k_max,
                                 exact=False)
            hi = np.where(feas, mid, hi)
            lo = np.where(feas, lo, mid)
        starts = _batch_greedy(P, net, n_arr, hi, kk, k_max, exact=True)

    # Vectorised load extraction, then plain-Python object construction
    # (no per-row numpy calls — they would dominate at 126 rows).
    ends = np.concatenate([starts[:, 1:],
                           np.full((n_rows, 1), 0, np.int64)], axis=1)
    ends[:, -1] = n_arr
    ends = np.minimum(np.maximum(ends, starts), n_arr[:, None])
    loads_all = (P[net[:, None], ends] - P[net[:, None], starts]).tolist()
    starts_l = starts.tolist()
    totals = total.tolist()
    out: List[Dict[int, Partition]] = [dict() for _ in lats]
    for r in range(n_rows):
        i, k, kr = int(net[r]), int(k_req[r]), int(kk[r])
        loads = loads_all[r][:kr]
        pipe = max(loads)
        out[i][k] = Partition(
            boundaries=tuple(starts_l[r][:kr]), loads=tuple(loads),
            pipeline_latency=pipe,
            speedup=totals[r] / pipe if pipe > 0 else float("inf"),
            n_layers=int(n_lens[i]))
    return out


# ---------------------------------------------------------------------------
# Latency-bound Pareto scoring: batch_schedule_hetero's chip scoring
# vectorised over a deadline axis.  A solved problem set gives every
# (chip, network) pair a scheduled (energy, latency) point; under a latency
# bound the score of a chip is its energy *subject to* the pipeline
# bottleneck meeting the deadline — infeasible schedules mask to +inf, so
# per-deadline argmins and the whole (chips × networks × deadlines) score
# block come out of ONE compiled call, with no python loop over deadlines.
# The (energy, latency) dominance masks (the Pareto fronts) ride along in
# the same program.
# ---------------------------------------------------------------------------


def _pareto_body(xp, value, latency, norm_latency, deadlines):
    """Traced body shared by the numpy and jitted paths.

    ``value``/``latency``/``norm_latency``: [C, N] per-(chip, network)
    score (normalised energy by convention), raw pipeline bottleneck, and
    normalised bottleneck; ``deadlines``: [N, D] absolute per-network
    latency bounds.  Returns

    * ``masked``  [C, N, D] — ``value`` where the schedule meets the
      deadline, +inf where it misses,
    * ``scores``  [C, D]   — per-chip mean over networks (one infeasible
      network poisons the chip: +inf propagates through the mean),
    * ``best``    [D]      — argmin chip per deadline (-1: none feasible),
    * ``best_net`` [N, D]  — per-network argmin chip per deadline,
    * ``net_front`` [C, N] — non-dominated (value, latency) chips per
      network (weak dominance: a point falls only to another that is ≤ in
      both coordinates and < in at least one),
    * ``chip_front`` [C]   — non-dominated chips on the network-mean
      (value, norm_latency) plane."""
    feas = latency[:, :, None] <= deadlines[None, :, :]
    masked = xp.where(feas, value[:, :, None], np.inf)
    scores = masked.mean(axis=1)                              # [C, D]
    best = xp.where(xp.isfinite(scores).any(axis=0),
                    xp.argmin(scores, axis=0), -1)
    best_net = xp.where(xp.isfinite(masked).any(axis=0),
                        xp.argmin(masked, axis=0), -1)        # [N, D]

    e1, e2 = value[:, None, :], value[None, :, :]
    l1, l2 = latency[:, None, :], latency[None, :, :]
    dom = (e2 <= e1) & (l2 <= l1) & ((e2 < e1) | (l2 < l1))
    net_front = ~dom.any(axis=1)                              # [C, N]

    mv, ml = value.mean(axis=1), norm_latency.mean(axis=1)
    domc = ((mv[None, :] <= mv[:, None]) & (ml[None, :] <= ml[:, None])
            & ((mv[None, :] < mv[:, None]) | (ml[None, :] < ml[:, None])))
    chip_front = ~domc.any(axis=1)                            # [C]
    return masked, scores, best, best_net, net_front, chip_front


_jitted_pareto = None


def _jax_pareto():
    global _jitted_pareto
    if _jitted_pareto is None:
        import jax
        import jax.numpy as jnp

        def kernel(value, latency, norm_latency, deadlines):
            return _pareto_body(jnp, value, latency, norm_latency,
                                deadlines)

        _jitted_pareto = jax.jit(kernel)
    return _jitted_pareto


def batch_pareto_scores(value, latency, deadlines,
                        norm_latency=None,
                        use_jax: bool | None = None):
    """Score a solved (chip × network) block against ALL deadlines at once.

    ``value``/``latency`` are [C, N] (scheduled score — normalised energy
    by convention — and pipeline bottleneck); ``deadlines`` is [N, D]
    absolute per-network bounds or [D] (broadcast to every network);
    ``norm_latency`` defaults to ``latency`` and only feeds the
    network-mean chip front.  Returns the 6-tuple of
    :func:`_pareto_body` as numpy arrays.  With jax available the whole
    block — masking, per-deadline argmins, both dominance fronts — is ONE
    jitted dispatch; the numpy body is the reference fallback."""
    value = np.asarray(value, dtype=np.float64)
    latency = np.asarray(latency, dtype=np.float64)
    deadlines = np.asarray(deadlines, dtype=np.float64)
    if deadlines.ndim == 1:
        deadlines = np.broadcast_to(deadlines[None, :],
                                    (value.shape[1], deadlines.shape[0]))
    norm_latency = (latency if norm_latency is None
                    else np.asarray(norm_latency, dtype=np.float64))
    use_jax = jax_available() if use_jax is None else use_jax
    if use_jax:
        from jax.experimental import enable_x64
        with enable_x64():
            out = _jax_pareto()(value, latency, norm_latency, deadlines)
        return tuple(np.asarray(o) for o in out)
    return _pareto_body(np, value, latency, norm_latency, deadlines)


def partition_network(report, n_cores: int, method: str = "bb") -> Partition:
    """Distribute a simulated network (NetworkReport) across cores."""
    lat = report.layer_latencies
    fn = {"bb": bb_partition, "dp": dp_partition,
          "brute": brute_force_partition}[method]
    return fn(lat, n_cores)


# ---------------------------------------------------------------------------
# Heterogeneous layer→core scheduling: batch_partition generalised beyond
# same-type cores.  A problem is a (chip, network) pair — per-layer
# latencies on every core TYPE plus a core count per type.  The schedule:
#
# 1. **per-layer argmin** — each layer runs on the available type that
#    executes it fastest (ties → lower type index);
# 2. **per-core-count balancing** — each type's layer subsequence is split
#    contiguously over that type's cores; the pipeline bottleneck is the
#    max core load across ALL types, so feasibility of a bottleneck T is
#    the AND of the per-type greedy coverings and ONE bisection per
#    problem drives every (problem × type) greedy row at once.
#
# Masked prefix sums make stage 2 exact: a type's costs are written onto
# the FULL layer axis (other types' slots are 0.0 — adding zero is exact
# in fp), so segment sums are the same prefix differences dp_partition
# computes on the compacted subsequence, and the final bottleneck is
# bit-identical to max_t dp_partition(subseq_t, counts_t) — the oracle.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HeteroSchedule:
    """One network's layer→core schedule on a heterogeneous chip."""

    types: Tuple[int, ...]        # core → type index (type-major order)
    layer_type: Tuple[int, ...]   # layer → type index (per-layer argmin)
    layer_core: Tuple[int, ...]   # layer → global core id
    loads: Tuple[float, ...]      # per-core latency sums (idle cores 0.0)
    bottleneck: float             # pipeline latency = max(loads)
    speedup: float                # Σ assigned layer latency / bottleneck
    n_layers: int

    @property
    def n_cores(self) -> int:
        return len(self.types)


@dataclasses.dataclass(frozen=True)
class BatchHeteroResult:
    """Array-level output of :func:`batch_schedule_hetero` (B problems).

    Kept as arrays so mega-batch co-design sweeps never pay per-problem
    Python object construction for schedules nobody reads —
    :meth:`schedule` materialises a :class:`HeteroSchedule` on demand.
    """

    counts: np.ndarray            # [B, T] cores per type (as requested)
    n_layers: np.ndarray          # [B]
    layer_type: np.ndarray        # [B, L_pad] per-layer argmin type
    starts: np.ndarray            # [B, T, k_max] full-axis segment starts
    seg_counts: np.ndarray        # [B, T] segments actually opened
    loads: np.ndarray             # [B, T, k_max] per-segment latency sums
    bottleneck: np.ndarray        # [B] (+inf: infeasible, strict=False)
    total: np.ndarray             # [B] Σ assigned layer latency
    feasible: np.ndarray | None = None   # [B] False → no core available
    labels: Tuple[str, ...] | None = None   # per-problem names for errors

    def __len__(self) -> int:
        return int(self.bottleneck.shape[0])

    @property
    def speedup(self) -> np.ndarray:
        with np.errstate(divide="ignore"):
            return np.where(self.bottleneck > 0,
                            self.total / self.bottleneck, np.inf)

    def schedule(self, i: int) -> HeteroSchedule:
        if self.feasible is not None and not self.feasible[i]:
            lab = (self.labels[i] if self.labels is not None
                   else f"problem {i}")
            raise ValueError(
                f"{lab}: infeasible — every core type has count 0 (the "
                "fault scenario killed the whole chip); bottleneck is "
                "+inf and no schedule exists")
        n_t = self.counts.shape[1]
        L = int(self.n_layers[i])
        tt = self.layer_type[i, :L]
        counts = self.counts[i]
        core_off = np.concatenate([[0], np.cumsum(counts)])
        types = tuple(int(t) for t in np.repeat(np.arange(n_t), counts))
        loads = np.zeros(int(core_off[-1]))
        layer_core = np.zeros(L, dtype=np.intp)
        for t in range(n_t):
            if counts[t] == 0:
                continue
            kk = int(self.seg_counts[i, t])
            st = self.starts[i, t, :kk]
            ends = np.concatenate([st[1:], [L]])
            lt = np.flatnonzero(tt == t)
            if lt.size:
                layer_core[lt] = core_off[t] + np.searchsorted(
                    ends, lt, side="right")
            loads[core_off[t]:core_off[t] + kk] = self.loads[i, t, :kk]
        bott = float(self.bottleneck[i])
        total = float(self.total[i])
        return HeteroSchedule(
            types=types, layer_type=tuple(int(t) for t in tt),
            layer_core=tuple(int(c) for c in layer_core),
            loads=tuple(float(x) for x in loads),
            bottleneck=bott,
            speedup=total / bott if bott > 0 else float("inf"),
            n_layers=L)

    def schedules(self) -> List[HeteroSchedule]:
        return [self.schedule(i) for i in range(len(self))]


def schedule_hetero_oracle(latencies, counts) -> Dict[str, Any]:
    """Scalar reference for ONE (chip, network) problem.

    ``latencies``: [n_types, n_layers] per-layer latency on each core
    type; ``counts``: [n_types] cores per type.  Per-layer argmin over
    the available types, then ``dp_partition`` per type's subsequence —
    the exact semantics ``batch_schedule_hetero`` batches."""
    lat = np.asarray(latencies, dtype=np.float64)
    counts = np.asarray(counts, dtype=np.int64)
    n_types, n_layers = lat.shape
    if counts.shape[0] > n_types:        # zero-padded type slots are fine
        if (counts[n_types:] > 0).any():
            raise ValueError("counts for more types than latency rows")
        counts = counts[:n_types]
    if n_layers == 0:
        raise ValueError("schedule_hetero_oracle needs ≥ 1 layer")
    if not (counts > 0).any():
        raise ValueError("schedule_hetero_oracle needs ≥ 1 core")
    cost = np.where((counts > 0)[:, None], lat, np.inf)
    tt = np.argmin(cost, axis=0)
    bottleneck = 0.0
    for t in range(n_types):
        sub = lat[t, tt == t]
        if counts[t] <= 0 or sub.size == 0:
            continue
        p = dp_partition(sub, int(counts[t]))
        bottleneck = max(bottleneck, p.pipeline_latency)
    total = float(lat[tt, np.arange(n_layers)].sum())
    return dict(bottleneck=bottleneck, layer_type=tt, total=total,
                speedup=total / bottleneck if bottleneck > 0
                else float("inf"))


_B_BUCKET = 32     # problem-axis bucket of the jitted hetero solver

_jitted_hetero_stage1 = None


def _jax_hetero_stage1():
    """Fused stage 1 of the hetero solver: per-layer argmin assignment +
    masked per-type prefix sums + the per-type reductions (layer counts,
    max, total), one XLA program instead of ~6 full-tensor numpy passes
    over the [B, T, L] block.  Bit-identical to the numpy body (same
    first-minimum argmin, same cumsum order; adding exact zeros)."""
    global _jitted_hetero_stage1
    if _jitted_hetero_stage1 is None:
        import jax
        import jax.numpy as jnp

        def stage1(lat, avail, n_lens):
            n_types = lat.shape[1]
            l_idx = jnp.arange(lat.shape[2])
            valid = l_idx[None, :] < n_lens[:, None]          # [B, L]
            cost = jnp.where(avail[:, :, None], lat, jnp.inf)
            tt = jnp.argmin(cost, axis=1)                     # [B, L]
            tmask = ((tt[:, None, :] == jnp.arange(n_types)[None, :, None])
                     & valid[:, None, :])                     # [B, T, L]
            masked = jnp.where(tmask, lat, 0.0)
            # NOTE no cumsum here: XLA's scan is not bit-identical to
            # numpy's sequential one, and the solver's exactness-vs-dp
            # contract rides on identical prefix arithmetic — the prefix
            # sums stay on the host.
            return (masked, jnp.where(valid, tt, 0),
                    tmask.sum(axis=-1), masked.max(axis=-1))

        _jitted_hetero_stage1 = jax.jit(stage1)
    return _jitted_hetero_stage1


def batch_schedule_hetero(latencies, counts,
                          n_layers=None,
                          use_jax: bool | None = None,
                          *,
                          strict: bool = True,
                          labels=None,
                          ) -> BatchHeteroResult:
    """Solve every heterogeneous (chip, network) schedule in one call.

    ``latencies``: one ``[n_types, n_layers]`` per-layer latency matrix
    per problem — a sequence of such, or ONE dense ``[B, T, L]`` float64
    array (the DSE engine's ``per_layer=True`` tensors gathered per
    chip; the fast path — no per-problem Python work).  ``counts``: the
    matching per-type core counts (``[T]`` per problem, or ``[B, T]``).
    With a dense array, ``n_layers`` gives each problem's true layer
    count (default: the full ``L``) — entries past it are ignored.
    Types with count 0 (padding slots) never receive layers.  Returns a
    :class:`BatchHeteroResult`; bottlenecks are exactly
    :func:`schedule_hetero_oracle`'s (same prefix-difference arithmetic,
    ulp-tight bisection).  With jax available the bisection +
    segmentation run as ONE jitted dispatch over all (problem × type)
    rows; the numpy body is the reference fallback.

    **Fault-scenario axis.**  A dense ``[B, S, T, L]`` array adds a
    scenario axis (per-problem perturbed latencies — e.g. degraded PE
    arrays swap in slower type rows): scenarios are just more problem
    rows, flattened scenario-minor to ``B·S`` problems solved in the
    same single call.  ``counts`` may then be ``[B, S, T]`` (scenarios
    with killed cores), ``[B, T]`` (same counts every scenario) or
    ``[T]``; ``n_layers`` ``[B]`` or ``[B, S]``.  Problem ``b``'s
    scenario ``s`` is flat row ``b·S + s`` of the result.

    **Infeasibility.**  ``strict=True`` (default) raises when any
    problem's counts are all zero.  ``strict=False`` reports such
    problems (a scenario that killed every core) per-problem instead:
    ``bottleneck`` is +inf, ``feasible`` is False, and
    :meth:`BatchHeteroResult.schedule` raises naming the problem via
    ``labels`` (one string per flattened problem row).
    """
    if isinstance(latencies, np.ndarray) and latencies.ndim == 4:
        b0, n_s = latencies.shape[:2]
        latencies = latencies.reshape(b0 * n_s, *latencies.shape[2:])
        cnts_in = np.asarray(counts)
        if cnts_in.ndim == 3:
            counts = cnts_in.reshape(b0 * n_s, cnts_in.shape[2])
        elif cnts_in.ndim == 2:
            counts = np.repeat(cnts_in, n_s, axis=0)
        if n_layers is not None:
            nl = np.asarray(n_layers, dtype=np.int64)
            n_layers = (np.repeat(nl, n_s) if nl.ndim == 1
                        else nl.reshape(b0 * n_s))
    dense = isinstance(latencies, np.ndarray) and latencies.ndim == 3
    if dense:
        n_b, in_types, n_max = latencies.shape
        n_lens = (np.full(n_b, n_max, dtype=np.int64) if n_layers is None
                  else np.asarray(n_layers, dtype=np.int64))
    else:
        lats = [np.asarray(l, dtype=np.float64) for l in latencies]
        n_b = len(lats)
        in_types = max((l.shape[0] for l in lats), default=0)
        n_lens = np.array([l.shape[1] for l in lats], dtype=np.int64)
        n_max = int(n_lens.max()) if n_b else 0
    cnts = np.asarray(counts)
    if cnts.ndim == 1:
        cnts = np.broadcast_to(cnts, (n_b, cnts.shape[0]))
    cnts = cnts.astype(np.int64)
    if n_b == 0:
        return BatchHeteroResult(
            counts=np.zeros((0, 0), np.int64), n_layers=np.zeros(0, np.int64),
            layer_type=np.zeros((0, 0), np.int64),
            starts=np.zeros((0, 0, _K_MAX), np.int64),
            seg_counts=np.zeros((0, 0), np.int64),
            loads=np.zeros((0, 0, _K_MAX)), bottleneck=np.zeros(0),
            total=np.zeros(0), feasible=np.zeros(0, bool))
    if cnts.shape[0] != n_b:
        raise ValueError(f"counts rows {cnts.shape[0]} != problems {n_b}")
    if labels is not None:
        labels = tuple(str(x) for x in labels)
        if len(labels) != n_b:
            raise ValueError(
                f"labels has {len(labels)} entries for {n_b} problems")
    n_types = max(in_types, cnts.shape[1])
    if (n_lens == 0).any():
        raise ValueError("every problem needs ≥ 1 layer")
    # a positive count for a type slot beyond a problem's latency rows
    # would hand every layer to a phantom zero-latency type — reject it,
    # exactly like schedule_hetero_oracle does
    prob_types = (np.asarray([l.shape[0] for l in lats], dtype=np.int64)
                  if not dense else np.full(n_b, in_types, np.int64))
    ghost = np.arange(cnts.shape[1])[None, :] >= prob_types[:, None]
    if (cnts * ghost).any():
        raise ValueError("counts for more types than latency rows")

    if max(int(c) for c in cnts.max(axis=0)) > _K_MAX and use_jax is not False:
        use_jax = False                    # solver unrolls _K_MAX segments
    use_jax = (jax_available() if use_jax is None else use_jax)

    n_pad = _bucketed(n_max, _N_BUCKET) if use_jax else n_max
    b_pad = _bucketed(n_b, _B_BUCKET) if use_jax else n_b

    lat = np.zeros((b_pad, n_types, n_pad))
    if dense:
        lat[:n_b, :in_types, :n_max] = latencies
    else:
        for i, l in enumerate(lats):
            lat[i, :l.shape[0], :l.shape[1]] = l
    counts_p = np.ones((b_pad, n_types), dtype=np.int64)  # benign pad rows
    counts_p[:n_b] = 0
    counts_p[:n_b, :cnts.shape[1]] = cnts
    avail = counts_p > 0
    feas_b = avail[:n_b].any(axis=1)
    if not feas_b.all():
        if strict:
            raise ValueError(
                "every problem needs ≥ 1 core (counts all zero); pass "
                "strict=False to report per-problem infeasibility instead")
        # all-types-dead problems (a scenario that killed every core)
        # solve as benign single-core rows like the padding, then report
        # +inf below — the rest of the batch is unaffected
        avail[np.flatnonzero(~feas_b), 0] = True
    avail[n_b:] = False
    avail[n_b:, 0] = True                  # padded problems: 1 trivial core
    n_lens_p = np.concatenate([n_lens, np.ones(b_pad - n_b, np.int64)])

    # stage 1: per-layer argmin over the available types + masked per-type
    # prefix sums (fused on-device when jax runs the search below)
    l_idx = np.arange(n_pad)
    valid_l = l_idx[None, :] < n_lens_p[:, None]              # [B, L]
    if use_jax:
        from jax.experimental import enable_x64
        with enable_x64():
            masked, tt, n_t, mx = (
                np.asarray(o) for o in _jax_hetero_stage1()(
                    lat, avail, n_lens_p))
    else:
        cost = np.where(avail[:, :, None], lat, np.inf)
        tt = np.argmin(cost, axis=1)                          # [B, L]
        tt = np.where(valid_l, tt, 0)
        tmask = ((tt[:, None, :] == np.arange(n_types)[None, :, None])
                 & valid_l[:, None, :])
        masked = np.where(tmask, lat, 0.0)
        n_t = tmask.sum(axis=-1)                              # layers/type
        mx = masked.max(axis=-1)                              # [B, T]
    # prefix sums on the HOST: numpy's sequential cumsum is the exact
    # arithmetic of the dp oracle (see _jax_hetero_stage1's note)
    cum = np.cumsum(masked, axis=-1)                          # [B, T, L]
    pref = np.where(valid_l[:, None, :], cum, np.inf)
    P = np.full((b_pad * n_types, n_pad + 1), np.inf)
    P[:, 0] = 0.0
    P[:, 1:] = pref.reshape(b_pad * n_types, n_pad)
    kk = np.where(n_t > 0, np.minimum(counts_p, np.maximum(n_t, 1)), 1)
    kk = np.maximum(kk, 1)
    total_t = P[np.arange(b_pad * n_types),
                np.repeat(n_lens_p, n_types)].reshape(b_pad, n_types)

    # Per-(problem, type) solves: the global bottleneck is simply the MAX
    # of the independent per-type optima (feasibility decomposes over
    # types), so every row runs its OWN parametric search — the exact
    # machinery (and jit cache) of batch_partition, one row per
    # (problem, type).  Two row classes are CLOSED FORM and skip the
    # bisection entirely (in chip co-design sweeps they are the
    # majority — core counts are small):
    #   kk == 1     → one segment: T* = total_t, starts = [0, …]
    #   kk == n_t   → one layer per segment: T* = mx_t, starts = the
    #                 type's layer positions on the full axis
    # (kk = min(counts, n_t) never exceeds n_t, so these two plus the
    # bisected 2 ≤ kk < n_t rows are exhaustive.)
    rows = b_pad * n_types
    net_r = np.arange(rows, dtype=np.int64)
    n_arr_r = np.repeat(n_lens_p, n_types)
    kk_r = kk.reshape(rows)
    n_t_r = n_t.reshape(rows)
    k_out = max(_K_MAX, int(kk_r.max()))
    starts_r = np.broadcast_to(n_arr_r[:, None],
                               (rows, k_out)).copy()

    single = kk_r == 1
    starts_r[single, 0] = 0

    per_layer_rows = (~single) & (kk_r == n_t_r)
    if per_layer_rows.any():
        type_mask = ((tt[:, None, :] == np.arange(n_types)[None, :, None])
                     & valid_l[:, None, :]).reshape(rows, n_pad)
        sub = type_mask[per_layer_rows]
        occ = np.cumsum(sub, axis=1)
        for s in range(int(kk_r[per_layer_rows].max())):
            hit = sub & (occ == s + 1)
            pos = np.argmax(hit, axis=1)
            has = hit.any(axis=1)
            starts_r[np.flatnonzero(per_layer_rows)[has], s] = pos[has]

    # kk == 2 is closed form too: with A_j = P[j] (non-decreasing) and
    # B_j = P[n] − P[j] (non-increasing), T* = min_j max(A_j, B_j) sits at
    # the predicate crossing A_j ≤ B_j — one vectorised binary search per
    # row, then the two candidate cuts around it.  Same prefix-difference
    # arithmetic as the dp oracle, so still exact.
    halves = np.flatnonzero(~single & ~per_layer_rows & (kk_r == 2))
    if halves.size:
        net_h, n_h = net_r[halves], n_arr_r[halves]
        tot_h = P[net_h, n_h]
        lo_j = np.ones(halves.size, dtype=np.int64)
        hi_j = np.maximum(n_h - 1, 1)
        steps = int(np.ceil(np.log2(P.shape[1]))) + 1
        for _ in range(steps):
            mid = (lo_j + hi_j + 1) >> 1
            ok = P[net_h, mid] <= tot_h - P[net_h, mid]
            lo_j = np.where(ok, mid, lo_j)
            hi_j = np.where(ok, hi_j, mid - 1)
        j0 = np.clip(lo_j, 1, np.maximum(n_h - 1, 1))
        j1 = np.clip(lo_j + 1, 1, np.maximum(n_h - 1, 1))
        m0 = np.maximum(P[net_h, j0], tot_h - P[net_h, j0])
        m1 = np.maximum(P[net_h, j1], tot_h - P[net_h, j1])
        cut = np.where(m0 <= m1, j0, j1)
        starts_r[halves, 0] = 0
        starts_r[halves, 1] = cut

    need = np.flatnonzero(~single & ~per_layer_rows & (kk_r > 2))
    if need.size:
        lb = np.maximum(mx, total_t / kk).reshape(-1)[need]
        lo_n = np.nextafter(lb, -np.inf)
        hi_n = ((total_t / kk + mx).reshape(-1)[need]) * (1.0 + 1e-12)
        net_n, n_arr_n, kk_n = net_r[need], n_arr_r[need], kk_r[need]
        k_mx = int(kk_n.max())
        if use_jax:
            r_pad = _bucketed(need.size, _ROW_BUCKET)
            pad = r_pad - need.size
            netp = np.concatenate([net_n, np.zeros(pad, np.int64)])
            n_ap = np.concatenate([n_arr_n,
                                   np.full(pad, n_arr_r[0], np.int64)])
            kkp = np.concatenate([kk_n, np.ones(pad, np.int64)])
            lop = np.concatenate([lo_n, np.zeros(pad)])
            hip = np.concatenate([hi_n, np.ones(pad)])
            from jax.experimental import enable_x64
            with enable_x64():
                bs_steps = int(np.ceil(np.log2(n_pad + 1))) + 1
                starts_r[need, :k_mx] = np.asarray(_jax_solver()(
                    P, netp, n_ap, kkp, lop, hip, k_mx,
                    bs_steps))[:need.size]
        else:
            lo_b, hi_b = lo_n.copy(), hi_n.copy()
            for _ in range(_BISECT_ITERS):
                mid = 0.5 * (lo_b + hi_b)
                feas = _batch_greedy(P, net_n, n_arr_n, mid, kk_n, k_mx,
                                     exact=False)
                hi_b = np.where(feas, mid, hi_b)
                lo_b = np.where(feas, lo_b, mid)
            st = _batch_greedy(P, net_n, n_arr_n, hi_b, kk_n, k_mx,
                               exact=True)
            starts_r[need, :st.shape[1]] = st

    k_out = starts_r.shape[1]
    ends_r = np.concatenate(
        [starts_r[:, 1:], np.zeros((rows, 1), starts_r.dtype)], axis=1)
    ends_r[:, -1] = n_arr_r
    ends_r = np.minimum(np.maximum(ends_r, starts_r), n_arr_r[:, None])
    loads_r = P[net_r[:, None], ends_r] - P[net_r[:, None], starts_r]
    loads_r = np.where(np.isfinite(loads_r), loads_r, 0.0)

    loads = loads_r.reshape(b_pad, n_types, k_out)[:n_b]
    bottleneck = loads.max(axis=(1, 2))
    if not feas_b.all():
        loads = np.where(feas_b[:, None, None], loads, 0.0)
        bottleneck = np.where(feas_b, bottleneck, np.inf)
    return BatchHeteroResult(
        counts=np.asarray(cnts), n_layers=n_lens,
        layer_type=tt[:n_b], starts=starts_r.reshape(
            b_pad, n_types, k_out)[:n_b],
        seg_counts=kk[:n_b], loads=loads,
        bottleneck=bottleneck, total=total_t[:n_b].sum(axis=1),
        feasible=feas_b.copy(), labels=labels)
