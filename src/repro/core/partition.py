"""Model parallelism on homogeneous cores (§IV.B, Algorithm II).

A network's layers are distributed *contiguously* over k identical cores
forming a processing pipeline through off-chip DRAM (Fig. 11).  The pipeline
latency is the maximum per-core latency; the speedup of eq. (6) is

    speedup = sum(latencies) / max(core latency).

``bb_partition`` is the paper's branch-and-bound: walk layers accumulating
latency until the running sum crosses the balanced average, branch on
including/excluding the crossing layer, and bound any branch whose current
core latency already exceeds the best pipeline latency found so far.

``dp_partition`` is an exact oracle (classic linear-partition DP) and
``brute_force_partition`` enumerates all splits — both used by the tests to
verify the B&B lands on (near-)optimal pipelines, and by the TPU adaptation
(`parallel/pipeline.py`) to place transformer layers on pipeline stages.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Partition:
    """Contiguous layer → core assignment."""

    boundaries: Tuple[int, ...]   # start index of each core's slice
    loads: Tuple[float, ...]      # per-core total latency
    pipeline_latency: float       # max(loads)
    speedup: float                # eq. (6)
    n_layers: int = 0

    @property
    def n_cores(self) -> int:
        return len(self.loads)

    def table_row(self) -> List[Tuple[int, int]]:
        """(l_initial, n_C) tuples, 1-indexed like Tables 7–8."""
        bounds = list(self.boundaries) + [self.n_layers]
        return [(bounds[i] + 1, bounds[i + 1] - bounds[i])
                for i in range(len(self.boundaries))]


def _mk_partition(lat: Sequence[float], bounds: Sequence[int]) -> Partition:
    lat = list(lat)
    total = float(sum(lat))
    bounds = list(bounds)
    loads = []
    for i, start in enumerate(bounds):
        end = bounds[i + 1] if i + 1 < len(bounds) else len(lat)
        loads.append(float(sum(lat[start:end])))
    pipe = max(loads)
    return Partition(boundaries=tuple(bounds), loads=tuple(loads),
                     pipeline_latency=pipe,
                     speedup=total / pipe if pipe > 0 else float("inf"),
                     n_layers=len(lat))


def bb_partition(latencies: Sequence[float], n_cores: int) -> Partition:
    """Algorithm II: branch-and-bound layer distribution."""
    lat = [float(x) for x in latencies]
    n = len(lat)
    if n_cores <= 1 or n <= n_cores:
        bounds = list(range(min(n, n_cores)))
        return _mk_partition(lat, bounds)

    total = sum(lat)
    avg = total / n_cores
    suffix = np.concatenate([np.cumsum(lat[::-1])[::-1], [0.0]])

    best = {"pipe": float("inf"), "bounds": None}

    def rec(i: int, cores_left: int, cur_max: float, bounds: List[int]):
        # Assign layers [i:] to the remaining cores; bounds holds the start
        # index of every core opened so far.
        if cur_max >= best["pipe"]:
            return                      # bound condition
        if cores_left == 1:
            seg = float(suffix[i])
            pipe = max(cur_max, seg)
            if pipe < best["pipe"]:
                best["pipe"] = pipe
                best["bounds"] = bounds + [i]
            return
        # accumulate from layer i until the running sum crosses the average
        s = 0.0
        j = i
        while j < n - (cores_left - 1) and s + lat[j] < avg:
            s += lat[j]
            j += 1
        j = min(j, n - (cores_left - 1))
        # branch 1: include the crossing layer (segment sum ≥ avg)
        hi = min(j + 1, n - (cores_left - 1))
        s_hi = float(sum(lat[i:hi]))
        rec(hi, cores_left - 1, max(cur_max, s_hi), bounds + [i])
        # branch 2: exclude it (segment sum < avg)
        if j > i and j != hi:
            s_lo = float(sum(lat[i:j]))
            rec(j, cores_left - 1, max(cur_max, s_lo), bounds + [i])

    rec(0, n_cores, 0.0, [])
    assert best["bounds"] is not None
    return _mk_partition(lat, best["bounds"])


def dp_partition(latencies: Sequence[float], n_cores: int) -> Partition:
    """Exact minimal-bottleneck contiguous partition (DP oracle)."""
    lat = [float(x) for x in latencies]
    n = len(lat)
    k = min(n_cores, n) if n else 1
    prefix = np.concatenate([[0.0], np.cumsum(lat)])

    # dp[c][i] = minimal pipeline latency splitting lat[:i] into c cores
    NEG = float("inf")
    dp = np.full((k + 1, n + 1), NEG)
    cut = np.zeros((k + 1, n + 1), dtype=int)
    dp[0][0] = 0.0
    for c in range(1, k + 1):
        for i in range(c, n + 1):
            bestv, bestj = NEG, c - 1
            for j in range(c - 1, i):
                v = max(dp[c - 1][j], prefix[i] - prefix[j])
                if v < bestv:
                    bestv, bestj = v, j
            dp[c][i] = bestv
            cut[c][i] = bestj
    bounds: List[int] = []
    i = n
    for c in range(k, 0, -1):
        j = int(cut[c][i])
        bounds.append(j)
        i = j
    bounds.reverse()
    return _mk_partition(lat, bounds)


def brute_force_partition(latencies: Sequence[float], n_cores: int
                          ) -> Partition:
    """Enumerate every contiguous split (tests only; exponential)."""
    lat = [float(x) for x in latencies]
    n = len(lat)
    k = min(n_cores, n)
    best = None
    for cuts in itertools.combinations(range(1, n), k - 1):
        bounds = [0] + list(cuts)
        p = _mk_partition(lat, bounds)
        if best is None or p.pipeline_latency < best.pipeline_latency:
            best = p
    return best if best is not None else _mk_partition(lat, [0])


def partition_network(report, n_cores: int, method: str = "bb") -> Partition:
    """Distribute a simulated network (NetworkReport) across cores."""
    lat = report.layer_latencies
    fn = {"bb": bb_partition, "dp": dp_partition,
          "brute": brute_force_partition}[method]
    return fn(lat, n_cores)
