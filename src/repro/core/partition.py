"""Model parallelism on homogeneous cores (§IV.B, Algorithm II).

A network's layers are distributed *contiguously* over k identical cores
forming a processing pipeline through off-chip DRAM (Fig. 11).  The pipeline
latency is the maximum per-core latency; the speedup of eq. (6) is

    speedup = sum(latencies) / max(core latency).

``bb_partition`` is the paper's branch-and-bound: walk layers accumulating
latency until the running sum crosses the balanced average, branch on
including/excluding the crossing layer, and bound any branch whose current
core latency already exceeds the best pipeline latency found so far.

``dp_partition`` is an exact oracle (classic linear-partition DP) and
``brute_force_partition`` enumerates all splits — both used by the tests to
verify the B&B lands on (near-)optimal pipelines, and by the TPU adaptation
(`parallel/pipeline.py`) to place transformer layers on pipeline stages.

``batch_partition`` is the production hot path: a vectorized parametric
search that solves ALL (network × core-count) splits in one call — binary
search on the bottleneck latency T, with a ``searchsorted``-style greedy
feasibility check over prefix sums, batched over every (network, k) pair.
Segment sums are evaluated as prefix differences, the same arithmetic
``dp_partition`` uses, so the two agree exactly.

Array-shape conventions: per-network layer latencies arrive as 1-D
``[n_layers]`` vectors (``NetworkReport.layer_latencies`` from
:mod:`repro.core.energymodel`, in ns); the batch solver pads them to one
``[n_networks, n_pad]`` matrix (bucketed like the DSE engine's layer
axis, so repeated zoo-sized calls share one trace) with a validity mask,
and broadcasts the bisection over a ``[n_networks, n_k]`` problem grid.
A :class:`Partition` stores ``boundaries`` as the k+1 split indices into
the layer axis (``boundaries[0] == 0``, contiguous, monotone) and
``loads`` as the per-core latency sums — ``pipeline_latency =
max(loads)`` and eq. (6)'s ``speedup = sum / max``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .energymodel import _bucketed, jax_available


@dataclasses.dataclass(frozen=True)
class Partition:
    """Contiguous layer → core assignment."""

    boundaries: Tuple[int, ...]   # start index of each core's slice
    loads: Tuple[float, ...]      # per-core total latency
    pipeline_latency: float       # max(loads)
    speedup: float                # eq. (6)
    n_layers: int = 0

    @property
    def n_cores(self) -> int:
        return len(self.loads)

    def table_row(self) -> List[Tuple[int, int]]:
        """(l_initial, n_C) tuples, 1-indexed like Tables 7–8."""
        bounds = list(self.boundaries) + [self.n_layers]
        return [(bounds[i] + 1, bounds[i + 1] - bounds[i])
                for i in range(len(self.boundaries))]


def _mk_partition(lat: Sequence[float], bounds: Sequence[int]) -> Partition:
    lat = np.asarray(lat, dtype=np.float64)
    prefix = np.concatenate([[0.0], np.cumsum(lat)])
    starts = np.asarray(bounds, dtype=np.intp)
    ends = np.concatenate([starts[1:], [lat.size]])
    loads = prefix[ends] - prefix[starts]        # O(k), not O(k·n)
    total = float(prefix[-1])
    pipe = float(loads.max())
    return Partition(boundaries=tuple(int(b) for b in starts),
                     loads=tuple(float(x) for x in loads),
                     pipeline_latency=pipe,
                     speedup=total / pipe if pipe > 0 else float("inf"),
                     n_layers=int(lat.size))


def bb_partition(latencies: Sequence[float], n_cores: int) -> Partition:
    """Algorithm II: branch-and-bound layer distribution."""
    lat = [float(x) for x in latencies]
    n = len(lat)
    if n_cores <= 1 or n <= n_cores:
        bounds = list(range(min(n, n_cores)))
        return _mk_partition(lat, bounds)

    total = sum(lat)
    avg = total / n_cores
    suffix = np.concatenate([np.cumsum(lat[::-1])[::-1], [0.0]])

    best = {"pipe": float("inf"), "bounds": None}

    def rec(i: int, cores_left: int, cur_max: float, bounds: List[int]):
        # Assign layers [i:] to the remaining cores; bounds holds the start
        # index of every core opened so far.
        if cur_max >= best["pipe"]:
            return                      # bound condition
        if cores_left == 1:
            seg = float(suffix[i])
            pipe = max(cur_max, seg)
            if pipe < best["pipe"]:
                best["pipe"] = pipe
                best["bounds"] = bounds + [i]
            return
        # accumulate from layer i until the running sum crosses the average
        s = 0.0
        j = i
        while j < n - (cores_left - 1) and s + lat[j] < avg:
            s += lat[j]
            j += 1
        j = min(j, n - (cores_left - 1))
        # branch 1: include the crossing layer (segment sum ≥ avg)
        hi = min(j + 1, n - (cores_left - 1))
        s_hi = float(sum(lat[i:hi]))
        rec(hi, cores_left - 1, max(cur_max, s_hi), bounds + [i])
        # branch 2: exclude it (segment sum < avg)
        if j > i and j != hi:
            s_lo = float(sum(lat[i:j]))
            rec(j, cores_left - 1, max(cur_max, s_lo), bounds + [i])

    rec(0, n_cores, 0.0, [])
    assert best["bounds"] is not None
    return _mk_partition(lat, best["bounds"])


def dp_partition(latencies: Sequence[float], n_cores: int) -> Partition:
    """Exact minimal-bottleneck contiguous partition (DP oracle)."""
    lat = [float(x) for x in latencies]
    n = len(lat)
    k = min(n_cores, n) if n else 1
    prefix = np.concatenate([[0.0], np.cumsum(lat)])

    # dp[c][i] = minimal pipeline latency splitting lat[:i] into c cores.
    # The inner minimisation over the cut point j is vectorised with numpy
    # over prefix sums (argmin keeps the first minimum, matching the
    # original scalar loop's strict-improvement tie-breaking).
    NEG = float("inf")
    dp = np.full((k + 1, n + 1), NEG)
    cut = np.zeros((k + 1, n + 1), dtype=int)
    dp[0][0] = 0.0
    for c in range(1, k + 1):
        prev = dp[c - 1]
        for i in range(c, n + 1):
            j0 = c - 1
            cand = np.maximum(prev[j0:i], prefix[i] - prefix[j0:i])
            bj = int(np.argmin(cand))
            dp[c][i] = cand[bj]
            cut[c][i] = j0 + bj
    bounds: List[int] = []
    i = n
    for c in range(k, 0, -1):
        j = int(cut[c][i])
        bounds.append(j)
        i = j
    bounds.reverse()
    return _mk_partition(lat, bounds)


def brute_force_partition(latencies: Sequence[float], n_cores: int
                          ) -> Partition:
    """Enumerate every contiguous split (tests only; exponential)."""
    lat = [float(x) for x in latencies]
    n = len(lat)
    k = min(n_cores, n)
    best = None
    for cuts in itertools.combinations(range(1, n), k - 1):
        bounds = [0] + list(cuts)
        p = _mk_partition(lat, bounds)
        if best is None or p.pipeline_latency < best.pipeline_latency:
            best = p
    return best if best is not None else _mk_partition(lat, [0])


# ---------------------------------------------------------------------------
# Batched parametric search: all (network × k) splits in one vectorized call.
#
# Feasibility of a bottleneck T is monotone (feasible ⟺ T ≥ T*), so a
# bisection on T converges to the optimum; every bisection step runs ONE
# greedy maximal-jump segmentation for ALL (network, k) pairs at once, each
# jump a vectorized binary search over the per-network prefix-sum rows.
# _BISECT_ITERS halvings shrink the bracket below one ulp of T* (see the
# constant's note), and segment sums are prefix DIFFERENCES throughout
# (never ``prefix + T`` sums), so the final bottleneck is bit-identical to
# ``dp_partition``'s.
# ---------------------------------------------------------------------------

#: Bisection steps: the initial bracket is at most ~one bottleneck wide
#: (see the lb/hi seeding in batch_partition), so 56 halvings push the
#: bracket below one ulp of the optimum — the greedy segmentation at the
#: upper end then lands on it exactly.
_BISECT_ITERS = 56

#: Static-shape buckets for the jitted solver: padding the prefix axis and
#: the (network × k) row axis to these multiples keeps the module-level
#: compile cache warm across calls with nearby problem sizes.
_N_BUCKET = 64
_ROW_BUCKET = 32
_K_MAX = 8


def _row_searchsorted(P: np.ndarray, net: np.ndarray, pos: np.ndarray,
                      thr: np.ndarray) -> np.ndarray:
    """Per-row maximal jump: largest j with P[net, j] − P[net, pos] ≤ thr.

    ``P`` rows are non-decreasing (prefix sums padded with +inf), so the
    predicate is monotone in j and a batched binary search finds the last
    true position.  Comparisons subtract prefixes — the exact arithmetic of
    the DP oracle — rather than pre-adding ``thr`` to the base (which would
    round and admit off-by-one-ulp jumps)."""
    base = P[net, pos]
    lo = pos.copy()                       # predicate holds at pos (0 ≤ thr)
    hi = np.full_like(pos, P.shape[1] - 1)
    steps = int(np.ceil(np.log2(P.shape[1]))) + 1
    for _ in range(steps):
        mid = (lo + hi + 1) >> 1
        ok = P[net, mid] - base <= thr
        lo = np.where(ok, mid, lo)
        hi = np.where(ok, hi, mid - 1)
    return lo


def _batch_greedy(P: np.ndarray, net: np.ndarray, n_arr: np.ndarray,
                  thr: np.ndarray, kk: np.ndarray, k_max: int,
                  exact: bool):
    """Greedy maximal-jump segmentation at threshold ``thr`` for every row.

    ``exact=False``: feasibility — True where ≤ kk segments cover all
    layers with every segment sum ≤ thr.  ``exact=True``: returns the
    [rows, k_max] start indices of an exactly-kk segmentation (each of the
    remaining segments is guaranteed ≥ 1 layer), valid when thr ≥ T*.
    """
    rows = net.shape[0]
    pos = np.zeros(rows, dtype=np.intp)
    viol = np.zeros(rows, dtype=bool)
    starts = np.full((rows, k_max), 0, dtype=np.intp) if exact else None
    for s in range(k_max):
        active = (s < kk) & (pos < n_arr)
        j = _row_searchsorted(P, net, pos, thr)
        if exact:
            rem = kk - s                      # segments still to open
            j = np.minimum(j, n_arr - np.maximum(rem - 1, 0))
        j = np.maximum(j, pos + 1)            # force progress …
        j = np.minimum(j, n_arr)              # … but stay in bounds
        viol |= active & (P[net, j] - P[net, pos] > thr)
        if exact:
            starts[:, s] = np.where(s < kk, np.minimum(pos, n_arr), n_arr)
        pos = np.where(active, j, pos)
    if exact:
        return starts
    return (pos >= n_arr) & ~viol


_jitted_solver = None          # built lazily on first jax dispatch


def _jax_solver():
    """One fused XLA program for the whole parametric search: the bisection
    on the bottleneck latency (each step one greedy maximal-jump
    feasibility over all (network, k) rows) plus the final exact-k
    segmentation.  The inner binary search and the greedy segment loop are
    UNROLLED (static bs_steps / _K_MAX) so each bisection step is one
    straight-line fused body; only the bisection itself is a sequential
    device loop.  Jitted at module level, so the all-pairs solve is ONE
    device dispatch instead of thousands of tiny numpy ops."""
    global _jitted_solver
    if _jitted_solver is None:
        import jax
        import jax.numpy as jnp
        from jax import lax

        def solve(P, net, n_arr, kk, lo, hi, k_max, bs_steps):
            def rowsearch(pos, thr):
                base = P[net, pos]
                blo = pos
                bhi = jnp.full_like(pos, P.shape[1] - 1)
                for _ in range(bs_steps):
                    mid = (blo + bhi + 1) >> 1
                    ok = P[net, mid] - base <= thr
                    blo = jnp.where(ok, mid, blo)
                    bhi = jnp.where(ok, bhi, mid - 1)
                return blo

            def feasible(thr):
                pos = jnp.zeros_like(net)
                viol = jnp.zeros(net.shape, bool)
                for s in range(k_max):
                    active = (s < kk) & (pos < n_arr)
                    j = rowsearch(pos, thr)
                    j = jnp.minimum(jnp.maximum(j, pos + 1), n_arr)
                    viol = viol | (active & (P[net, j] - P[net, pos] > thr))
                    pos = jnp.where(active, j, pos)
                return (pos >= n_arr) & ~viol

            def bisect(_, lh):
                blo, bhi = lh
                mid = 0.5 * (blo + bhi)
                feas = feasible(mid)
                return (jnp.where(feas, blo, mid),
                        jnp.where(feas, mid, bhi))
            lo_f, hi_f = lax.fori_loop(0, _BISECT_ITERS, bisect, (lo, hi))

            starts = []
            pos = jnp.zeros_like(net)
            for s in range(_K_MAX):           # static unroll; kk masks
                starts.append(jnp.where(s < kk,
                                        jnp.minimum(pos, n_arr), n_arr))
                j = rowsearch(pos, hi_f)
                j = jnp.minimum(j, n_arr - jnp.maximum(kk - s - 1, 0))
                j = jnp.minimum(jnp.maximum(j, pos + 1), n_arr)
                pos = jnp.where((s < kk) & (pos < n_arr), j, pos)
            return jnp.stack(starts, axis=1)

        _jitted_solver = jax.jit(solve, static_argnums=(6, 7))
    return _jitted_solver


def batch_partition(latencies: Sequence[Sequence[float]],
                    n_cores: Sequence[int] | int,
                    use_jax: bool | None = None,
                    ) -> List[Dict[int, Partition]]:
    """Solve every (network, k) minimal-bottleneck split in one call.

    ``latencies`` is a sequence of per-network layer-latency sequences and
    ``n_cores`` an int or sequence of core counts; returns one
    ``{k: Partition}`` dict per network.  Pipeline latencies are exactly
    ``dp_partition``'s (same prefix-difference arithmetic): the
    ``_BISECT_ITERS``-step bisection shrinks the bracket below one ulp of
    the optimum, so the greedy segmentation at the upper bracket lands on
    it exactly.  With
    jax available the whole search is one jitted dispatch; the numpy body
    is the reference fallback.
    """
    lats = [np.asarray(l, dtype=np.float64) for l in latencies]
    ks = ((int(n_cores),) if isinstance(n_cores, (int, np.integer))
          else tuple(int(k) for k in n_cores))
    if not lats or not ks:
        return [dict() for _ in lats]
    if max(ks) > _K_MAX and use_jax is not False:
        use_jax = False                    # solver unrolls _K_MAX segments
    use_jax = (jax_available() if use_jax is None else use_jax)
    n_lens = np.array([l.size for l in lats], dtype=np.int64)
    n_max = int(n_lens.max())
    n_net = len(lats)

    n_pad = _bucketed(n_max, _N_BUCKET) if use_jax else n_max
    P = np.full((n_net, n_pad + 1), np.inf)
    mx = np.zeros(n_net)
    for i, l in enumerate(lats):
        P[i, 0] = 0.0
        P[i, 1:l.size + 1] = np.cumsum(l)
        mx[i] = l.max() if l.size else 0.0

    # one row per (network, requested k), clamped like dp_partition
    net = np.repeat(np.arange(n_net, dtype=np.int64), len(ks))
    k_req = np.tile(np.asarray(ks, dtype=np.int64), n_net)
    kk = np.minimum(np.maximum(k_req, 1), np.maximum(n_lens[net], 1))
    k_max = int(kk.max())
    n_arr = n_lens[net]
    n_rows = net.size

    total = P[net, n_arr]
    # Tight initial bracket: any bottleneck is ≥ max(max layer, total/k),
    # and the greedy bound gives T* ≤ total/k + max layer.  The tiny
    # relative slack absorbs the rounding of the bound itself; the
    # bisection count then only has to cover the ~2^53 floats inside.
    lb = np.maximum(mx[net], total / np.maximum(kk, 1))
    lo = np.nextafter(lb, -np.inf)
    hi = np.minimum(total, (total / np.maximum(kk, 1) + mx[net])
                    * (1.0 + 1e-12))

    if use_jax:
        r_pad = _bucketed(n_rows, _ROW_BUCKET)
        pad = r_pad - n_rows
        netp = np.concatenate([net, np.zeros(pad, np.int64)])
        n_ap = np.concatenate([n_arr, np.full(pad, n_lens[0], np.int64)])
        kkp = np.concatenate([kk, np.ones(pad, np.int64)])
        lop = np.concatenate([lo, np.full(pad, lo[0] if n_rows else 0.0)])
        hip = np.concatenate([hi, np.full(pad, hi[0] if n_rows else 1.0)])
        from jax.experimental import enable_x64
        with enable_x64():
            bs_steps = int(np.ceil(np.log2(n_pad + 1))) + 1
            starts = np.asarray(_jax_solver()(
                P, netp, n_ap, kkp, lop, hip, _K_MAX, bs_steps))[:n_rows]
    else:
        for _ in range(_BISECT_ITERS):
            mid = 0.5 * (lo + hi)
            feas = _batch_greedy(P, net, n_arr, mid, kk, k_max,
                                 exact=False)
            hi = np.where(feas, mid, hi)
            lo = np.where(feas, lo, mid)
        starts = _batch_greedy(P, net, n_arr, hi, kk, k_max, exact=True)

    # Vectorised load extraction, then plain-Python object construction
    # (no per-row numpy calls — they would dominate at 126 rows).
    ends = np.concatenate([starts[:, 1:],
                           np.full((n_rows, 1), 0, np.int64)], axis=1)
    ends[:, -1] = n_arr
    ends = np.minimum(np.maximum(ends, starts), n_arr[:, None])
    loads_all = (P[net[:, None], ends] - P[net[:, None], starts]).tolist()
    starts_l = starts.tolist()
    totals = total.tolist()
    out: List[Dict[int, Partition]] = [dict() for _ in lats]
    for r in range(n_rows):
        i, k, kr = int(net[r]), int(k_req[r]), int(kk[r])
        loads = loads_all[r][:kr]
        pipe = max(loads)
        out[i][k] = Partition(
            boundaries=tuple(starts_l[r][:kr]), loads=tuple(loads),
            pipeline_latency=pipe,
            speedup=totals[r] / pipe if pipe > 0 else float("inf"),
            n_layers=int(n_lens[i]))
    return out


def partition_network(report, n_cores: int, method: str = "bb") -> Partition:
    """Distribute a simulated network (NetworkReport) across cores."""
    lat = report.layer_latencies
    fn = {"bb": bb_partition, "dp": dp_partition,
          "brute": brute_force_partition}[method]
    return fn(lat, n_cores)
