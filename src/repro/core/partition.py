"""Model parallelism on homogeneous cores (§IV.B, Algorithm II).

A network's layers are distributed *contiguously* over k identical cores
forming a processing pipeline through off-chip DRAM (Fig. 11).  The pipeline
latency is the maximum per-core latency; the speedup of eq. (6) is

    speedup = sum(latencies) / max(core latency).

``bb_partition`` is the paper's branch-and-bound: walk layers accumulating
latency until the running sum crosses the balanced average, branch on
including/excluding the crossing layer, and bound any branch whose current
core latency already exceeds the best pipeline latency found so far.

``dp_partition`` is an exact oracle (classic linear-partition DP) and
``brute_force_partition`` enumerates all splits — both used by the tests to
verify the B&B lands on (near-)optimal pipelines, and by the TPU adaptation
(`parallel/pipeline.py`) to place transformer layers on pipeline stages.

``batch_partition`` is the production hot path: a vectorized parametric
search that solves ALL (network × core-count) splits in one call — binary
search on the bottleneck latency T, with a ``searchsorted``-style greedy
feasibility check over prefix sums, batched over every (network, k) pair.
Segment sums are evaluated as prefix differences, the same arithmetic
``dp_partition`` uses, so the two agree exactly.

Array-shape conventions: per-network layer latencies arrive as 1-D
``[n_layers]`` vectors (``NetworkReport.layer_latencies`` from
:mod:`repro.core.energymodel`, in ns); the batch solver pads them to one
``[n_networks, n_pad]`` matrix (bucketed like the DSE engine's layer
axis, so repeated zoo-sized calls share one trace) with a validity mask,
and broadcasts the bisection over a ``[n_networks, n_k]`` problem grid.
A :class:`Partition` stores ``boundaries`` as the k+1 split indices into
the layer axis (``boundaries[0] == 0``, contiguous, monotone) and
``loads`` as the per-core latency sums — ``pipeline_latency =
max(loads)`` and eq. (6)'s ``speedup = sum / max``.

``batch_schedule_hetero`` generalises the solver beyond same-type cores
(the heterogeneous-chip co-design of :func:`repro.core.hetero.co_design`):
each problem is a (chip, network) pair with per-layer latencies on every
core TYPE (``[n_types, n_layers]``, from the DSE engine's
``per_layer=True`` path) and a core count per type.  The schedule is
defined in two exact stages — (1) every layer goes to the available type
that runs it fastest (per-layer argmin, ties → lower type index); (2)
each type's layer subsequence is split contiguously over that type's
cores, all types balanced against ONE shared pipeline bottleneck.
Feasibility of a bottleneck T is the conjunction of the per-type greedy
coverings (each monotone in T), so a single bisection per problem drives
every (problem × type) greedy row at once, and the optimum is exactly
``max over types of dp_partition(type's subsequence, type's cores)`` —
the oracle :func:`schedule_hetero_oracle` the tests compare against.
With one type and count k this degenerates to ``batch_partition``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .energymodel import _bucketed, jax_available


@dataclasses.dataclass(frozen=True)
class Partition:
    """Contiguous layer → core assignment."""

    boundaries: Tuple[int, ...]   # start index of each core's slice
    loads: Tuple[float, ...]      # per-core total latency
    pipeline_latency: float       # max(loads)
    speedup: float                # eq. (6)
    n_layers: int = 0

    @property
    def n_cores(self) -> int:
        return len(self.loads)

    def table_row(self) -> List[Tuple[int, int]]:
        """(l_initial, n_C) tuples, 1-indexed like Tables 7–8."""
        bounds = list(self.boundaries) + [self.n_layers]
        return [(bounds[i] + 1, bounds[i + 1] - bounds[i])
                for i in range(len(self.boundaries))]


def _mk_partition(lat: Sequence[float], bounds: Sequence[int]) -> Partition:
    lat = np.asarray(lat, dtype=np.float64)
    prefix = np.concatenate([[0.0], np.cumsum(lat)])
    starts = np.asarray(bounds, dtype=np.intp)
    ends = np.concatenate([starts[1:], [lat.size]])
    loads = prefix[ends] - prefix[starts]        # O(k), not O(k·n)
    total = float(prefix[-1])
    pipe = float(loads.max())
    return Partition(boundaries=tuple(int(b) for b in starts),
                     loads=tuple(float(x) for x in loads),
                     pipeline_latency=pipe,
                     speedup=total / pipe if pipe > 0 else float("inf"),
                     n_layers=int(lat.size))


def bb_partition(latencies: Sequence[float], n_cores: int) -> Partition:
    """Algorithm II: branch-and-bound layer distribution."""
    lat = [float(x) for x in latencies]
    n = len(lat)
    if n_cores <= 1 or n <= n_cores:
        bounds = list(range(min(n, n_cores)))
        return _mk_partition(lat, bounds)

    total = sum(lat)
    avg = total / n_cores
    suffix = np.concatenate([np.cumsum(lat[::-1])[::-1], [0.0]])

    best = {"pipe": float("inf"), "bounds": None}

    def rec(i: int, cores_left: int, cur_max: float, bounds: List[int]):
        # Assign layers [i:] to the remaining cores; bounds holds the start
        # index of every core opened so far.
        if cur_max >= best["pipe"]:
            return                      # bound condition
        if cores_left == 1:
            seg = float(suffix[i])
            pipe = max(cur_max, seg)
            if pipe < best["pipe"]:
                best["pipe"] = pipe
                best["bounds"] = bounds + [i]
            return
        # accumulate from layer i until the running sum crosses the average
        s = 0.0
        j = i
        while j < n - (cores_left - 1) and s + lat[j] < avg:
            s += lat[j]
            j += 1
        j = min(j, n - (cores_left - 1))
        # branch 1: include the crossing layer (segment sum ≥ avg)
        hi = min(j + 1, n - (cores_left - 1))
        s_hi = float(sum(lat[i:hi]))
        rec(hi, cores_left - 1, max(cur_max, s_hi), bounds + [i])
        # branch 2: exclude it (segment sum < avg)
        if j > i and j != hi:
            s_lo = float(sum(lat[i:j]))
            rec(j, cores_left - 1, max(cur_max, s_lo), bounds + [i])

    rec(0, n_cores, 0.0, [])
    assert best["bounds"] is not None
    return _mk_partition(lat, best["bounds"])


def dp_partition(latencies: Sequence[float], n_cores: int) -> Partition:
    """Exact minimal-bottleneck contiguous partition (DP oracle)."""
    lat = [float(x) for x in latencies]
    n = len(lat)
    k = min(n_cores, n) if n else 1
    prefix = np.concatenate([[0.0], np.cumsum(lat)])

    # dp[c][i] = minimal pipeline latency splitting lat[:i] into c cores.
    # The inner minimisation over the cut point j is vectorised with numpy
    # over prefix sums (argmin keeps the first minimum, matching the
    # original scalar loop's strict-improvement tie-breaking).
    NEG = float("inf")
    dp = np.full((k + 1, n + 1), NEG)
    cut = np.zeros((k + 1, n + 1), dtype=int)
    dp[0][0] = 0.0
    for c in range(1, k + 1):
        prev = dp[c - 1]
        for i in range(c, n + 1):
            j0 = c - 1
            cand = np.maximum(prev[j0:i], prefix[i] - prefix[j0:i])
            bj = int(np.argmin(cand))
            dp[c][i] = cand[bj]
            cut[c][i] = j0 + bj
    bounds: List[int] = []
    i = n
    for c in range(k, 0, -1):
        j = int(cut[c][i])
        bounds.append(j)
        i = j
    bounds.reverse()
    return _mk_partition(lat, bounds)


def brute_force_partition(latencies: Sequence[float], n_cores: int
                          ) -> Partition:
    """Enumerate every contiguous split (tests only; exponential)."""
    lat = [float(x) for x in latencies]
    n = len(lat)
    k = min(n_cores, n)
    best = None
    for cuts in itertools.combinations(range(1, n), k - 1):
        bounds = [0] + list(cuts)
        p = _mk_partition(lat, bounds)
        if best is None or p.pipeline_latency < best.pipeline_latency:
            best = p
    return best if best is not None else _mk_partition(lat, [0])


# ---------------------------------------------------------------------------
# Batched parametric search: all (network × k) splits in one vectorized call.
#
# Feasibility of a bottleneck T is monotone (feasible ⟺ T ≥ T*), so a
# bisection on T converges to the optimum; every bisection step runs ONE
# greedy maximal-jump segmentation for ALL (network, k) pairs at once, each
# jump a vectorized binary search over the per-network prefix-sum rows.
# _BISECT_ITERS halvings shrink the bracket below one ulp of T* (see the
# constant's note), and segment sums are prefix DIFFERENCES throughout
# (never ``prefix + T`` sums), so the final bottleneck is bit-identical to
# ``dp_partition``'s.
# ---------------------------------------------------------------------------

#: Bisection steps: the initial bracket is at most ~one bottleneck wide
#: (see the lb/hi seeding in batch_partition), so 56 halvings push the
#: bracket below one ulp of the optimum — the greedy segmentation at the
#: upper end then lands on it exactly.
_BISECT_ITERS = 56

#: Static-shape buckets for the jitted solver: padding the prefix axis and
#: the (network × k) row axis to these multiples keeps the module-level
#: compile cache warm across calls with nearby problem sizes.
_N_BUCKET = 64
_ROW_BUCKET = 32
_K_MAX = 8


def _row_searchsorted(P: np.ndarray, net: np.ndarray, pos: np.ndarray,
                      thr: np.ndarray) -> np.ndarray:
    """Per-row maximal jump: largest j with P[net, j] − P[net, pos] ≤ thr.

    ``P`` rows are non-decreasing (prefix sums padded with +inf), so the
    predicate is monotone in j and a batched binary search finds the last
    true position.  Comparisons subtract prefixes — the exact arithmetic of
    the DP oracle — rather than pre-adding ``thr`` to the base (which would
    round and admit off-by-one-ulp jumps)."""
    base = P[net, pos]
    lo = pos.copy()                       # predicate holds at pos (0 ≤ thr)
    hi = np.full_like(pos, P.shape[1] - 1)
    steps = int(np.ceil(np.log2(P.shape[1]))) + 1
    for _ in range(steps):
        mid = (lo + hi + 1) >> 1
        ok = P[net, mid] - base <= thr
        lo = np.where(ok, mid, lo)
        hi = np.where(ok, hi, mid - 1)
    return lo


def _batch_greedy(P: np.ndarray, net: np.ndarray, n_arr: np.ndarray,
                  thr: np.ndarray, kk: np.ndarray, k_max: int,
                  exact: bool):
    """Greedy maximal-jump segmentation at threshold ``thr`` for every row.

    ``exact=False``: feasibility — True where ≤ kk segments cover all
    layers with every segment sum ≤ thr.  ``exact=True``: returns the
    [rows, k_max] start indices of an exactly-kk segmentation (each of the
    remaining segments is guaranteed ≥ 1 layer), valid when thr ≥ T*.
    """
    rows = net.shape[0]
    pos = np.zeros(rows, dtype=np.intp)
    viol = np.zeros(rows, dtype=bool)
    starts = np.full((rows, k_max), 0, dtype=np.intp) if exact else None
    for s in range(k_max):
        active = (s < kk) & (pos < n_arr)
        j = _row_searchsorted(P, net, pos, thr)
        if exact:
            rem = kk - s                      # segments still to open
            j = np.minimum(j, n_arr - np.maximum(rem - 1, 0))
        j = np.maximum(j, pos + 1)            # force progress …
        j = np.minimum(j, n_arr)              # … but stay in bounds
        viol |= active & (P[net, j] - P[net, pos] > thr)
        if exact:
            starts[:, s] = np.where(s < kk, np.minimum(pos, n_arr), n_arr)
        pos = np.where(active, j, pos)
    if exact:
        return starts
    return (pos >= n_arr) & ~viol


_jitted_solver = None          # built lazily on first jax dispatch


def _jax_solver():
    """One fused XLA program for the whole parametric search: the bisection
    on the bottleneck latency (each step one greedy maximal-jump
    feasibility over all (network, k) rows) plus the final exact-k
    segmentation.  The inner binary search and the greedy segment loop are
    UNROLLED (static bs_steps / _K_MAX) so each bisection step is one
    straight-line fused body; only the bisection itself is a sequential
    device loop.  Jitted at module level, so the all-pairs solve is ONE
    device dispatch instead of thousands of tiny numpy ops."""
    global _jitted_solver
    if _jitted_solver is None:
        import jax
        import jax.numpy as jnp
        from jax import lax

        def solve(P, net, n_arr, kk, lo, hi, k_max, bs_steps):
            def rowsearch(pos, thr):
                base = P[net, pos]
                blo = pos
                bhi = jnp.full_like(pos, P.shape[1] - 1)
                for _ in range(bs_steps):
                    mid = (blo + bhi + 1) >> 1
                    ok = P[net, mid] - base <= thr
                    blo = jnp.where(ok, mid, blo)
                    bhi = jnp.where(ok, bhi, mid - 1)
                return blo

            def feasible(thr):
                pos = jnp.zeros_like(net)
                viol = jnp.zeros(net.shape, bool)
                for s in range(k_max):
                    active = (s < kk) & (pos < n_arr)
                    j = rowsearch(pos, thr)
                    j = jnp.minimum(jnp.maximum(j, pos + 1), n_arr)
                    viol = viol | (active & (P[net, j] - P[net, pos] > thr))
                    pos = jnp.where(active, j, pos)
                return (pos >= n_arr) & ~viol

            def bisect(_, lh):
                blo, bhi = lh
                mid = 0.5 * (blo + bhi)
                feas = feasible(mid)
                return (jnp.where(feas, blo, mid),
                        jnp.where(feas, mid, bhi))
            lo_f, hi_f = lax.fori_loop(0, _BISECT_ITERS, bisect, (lo, hi))

            starts = []
            pos = jnp.zeros_like(net)
            for s in range(k_max):            # static unroll; kk masks
                starts.append(jnp.where(s < kk,
                                        jnp.minimum(pos, n_arr), n_arr))
                j = rowsearch(pos, hi_f)
                j = jnp.minimum(j, n_arr - jnp.maximum(kk - s - 1, 0))
                j = jnp.minimum(jnp.maximum(j, pos + 1), n_arr)
                pos = jnp.where((s < kk) & (pos < n_arr), j, pos)
            return jnp.stack(starts, axis=1)

        _jitted_solver = jax.jit(solve, static_argnums=(6, 7))
    return _jitted_solver


def batch_partition(latencies: Sequence[Sequence[float]],
                    n_cores: Sequence[int] | int,
                    use_jax: bool | None = None,
                    ) -> List[Dict[int, Partition]]:
    """Solve every (network, k) minimal-bottleneck split in one call.

    ``latencies`` is a sequence of per-network layer-latency sequences and
    ``n_cores`` an int or sequence of core counts; returns one
    ``{k: Partition}`` dict per network.  Pipeline latencies are exactly
    ``dp_partition``'s (same prefix-difference arithmetic): the
    ``_BISECT_ITERS``-step bisection shrinks the bracket below one ulp of
    the optimum, so the greedy segmentation at the upper bracket lands on
    it exactly.  With
    jax available the whole search is one jitted dispatch; the numpy body
    is the reference fallback.
    """
    lats = [np.asarray(l, dtype=np.float64) for l in latencies]
    ks = ((int(n_cores),) if isinstance(n_cores, (int, np.integer))
          else tuple(int(k) for k in n_cores))
    if not lats or not ks:
        return [dict() for _ in lats]
    if max(ks) > _K_MAX and use_jax is not False:
        use_jax = False                    # solver unrolls _K_MAX segments
    use_jax = (jax_available() if use_jax is None else use_jax)
    n_lens = np.array([l.size for l in lats], dtype=np.int64)
    n_max = int(n_lens.max())
    n_net = len(lats)

    n_pad = _bucketed(n_max, _N_BUCKET) if use_jax else n_max
    P = np.full((n_net, n_pad + 1), np.inf)
    mx = np.zeros(n_net)
    for i, l in enumerate(lats):
        P[i, 0] = 0.0
        P[i, 1:l.size + 1] = np.cumsum(l)
        mx[i] = l.max() if l.size else 0.0

    # one row per (network, requested k), clamped like dp_partition
    net = np.repeat(np.arange(n_net, dtype=np.int64), len(ks))
    k_req = np.tile(np.asarray(ks, dtype=np.int64), n_net)
    kk = np.minimum(np.maximum(k_req, 1), np.maximum(n_lens[net], 1))
    k_max = int(kk.max())
    n_arr = n_lens[net]
    n_rows = net.size

    total = P[net, n_arr]
    # Tight initial bracket: any bottleneck is ≥ max(max layer, total/k),
    # and the greedy bound gives T* ≤ total/k + max layer.  The tiny
    # relative slack absorbs the rounding of the bound itself; the
    # bisection count then only has to cover the ~2^53 floats inside.
    lb = np.maximum(mx[net], total / np.maximum(kk, 1))
    lo = np.nextafter(lb, -np.inf)
    hi = np.minimum(total, (total / np.maximum(kk, 1) + mx[net])
                    * (1.0 + 1e-12))

    if use_jax:
        r_pad = _bucketed(n_rows, _ROW_BUCKET)
        pad = r_pad - n_rows
        netp = np.concatenate([net, np.zeros(pad, np.int64)])
        n_ap = np.concatenate([n_arr, np.full(pad, n_lens[0], np.int64)])
        kkp = np.concatenate([kk, np.ones(pad, np.int64)])
        lop = np.concatenate([lo, np.full(pad, lo[0] if n_rows else 0.0)])
        hip = np.concatenate([hi, np.full(pad, hi[0] if n_rows else 1.0)])
        from jax.experimental import enable_x64
        with enable_x64():
            bs_steps = int(np.ceil(np.log2(n_pad + 1))) + 1
            starts = np.asarray(_jax_solver()(
                P, netp, n_ap, kkp, lop, hip, _K_MAX, bs_steps))[:n_rows]
    else:
        for _ in range(_BISECT_ITERS):
            mid = 0.5 * (lo + hi)
            feas = _batch_greedy(P, net, n_arr, mid, kk, k_max,
                                 exact=False)
            hi = np.where(feas, mid, hi)
            lo = np.where(feas, lo, mid)
        starts = _batch_greedy(P, net, n_arr, hi, kk, k_max, exact=True)

    # Vectorised load extraction, then plain-Python object construction
    # (no per-row numpy calls — they would dominate at 126 rows).
    ends = np.concatenate([starts[:, 1:],
                           np.full((n_rows, 1), 0, np.int64)], axis=1)
    ends[:, -1] = n_arr
    ends = np.minimum(np.maximum(ends, starts), n_arr[:, None])
    loads_all = (P[net[:, None], ends] - P[net[:, None], starts]).tolist()
    starts_l = starts.tolist()
    totals = total.tolist()
    out: List[Dict[int, Partition]] = [dict() for _ in lats]
    for r in range(n_rows):
        i, k, kr = int(net[r]), int(k_req[r]), int(kk[r])
        loads = loads_all[r][:kr]
        pipe = max(loads)
        out[i][k] = Partition(
            boundaries=tuple(starts_l[r][:kr]), loads=tuple(loads),
            pipeline_latency=pipe,
            speedup=totals[r] / pipe if pipe > 0 else float("inf"),
            n_layers=int(n_lens[i]))
    return out


# ---------------------------------------------------------------------------
# Latency-bound Pareto scoring: batch_schedule_hetero's chip scoring
# vectorised over a deadline axis.  A solved problem set gives every
# (chip, network) pair a scheduled (energy, latency) point; under a latency
# bound the score of a chip is its energy *subject to* the pipeline
# bottleneck meeting the deadline — infeasible schedules mask to +inf, so
# per-deadline argmins and the whole (chips × networks × deadlines) score
# block come out of ONE compiled call, with no python loop over deadlines.
# The (energy, latency) dominance masks (the Pareto fronts) ride along in
# the same program.
# ---------------------------------------------------------------------------


def _pareto_body(xp, value, latency, norm_latency, deadlines):
    """Traced body shared by the numpy and jitted paths.

    ``value``/``latency``/``norm_latency``: [C, N] per-(chip, network)
    score (normalised energy by convention), raw pipeline bottleneck, and
    normalised bottleneck; ``deadlines``: [N, D] absolute per-network
    latency bounds.  Returns

    * ``masked``  [C, N, D] — ``value`` where the schedule meets the
      deadline, +inf where it misses,
    * ``scores``  [C, D]   — per-chip mean over networks (one infeasible
      network poisons the chip: +inf propagates through the mean),
    * ``best``    [D]      — argmin chip per deadline (-1: none feasible),
    * ``best_net`` [N, D]  — per-network argmin chip per deadline,
    * ``net_front`` [C, N] — non-dominated (value, latency) chips per
      network (weak dominance: a point falls only to another that is ≤ in
      both coordinates and < in at least one),
    * ``chip_front`` [C]   — non-dominated chips on the network-mean
      (value, norm_latency) plane."""
    feas = latency[:, :, None] <= deadlines[None, :, :]
    masked = xp.where(feas, value[:, :, None], np.inf)
    scores = masked.mean(axis=1)                              # [C, D]
    best = xp.where(xp.isfinite(scores).any(axis=0),
                    xp.argmin(scores, axis=0), -1)
    best_net = xp.where(xp.isfinite(masked).any(axis=0),
                        xp.argmin(masked, axis=0), -1)        # [N, D]

    e1, e2 = value[:, None, :], value[None, :, :]
    l1, l2 = latency[:, None, :], latency[None, :, :]
    dom = (e2 <= e1) & (l2 <= l1) & ((e2 < e1) | (l2 < l1))
    net_front = ~dom.any(axis=1)                              # [C, N]

    mv, ml = value.mean(axis=1), norm_latency.mean(axis=1)
    domc = ((mv[None, :] <= mv[:, None]) & (ml[None, :] <= ml[:, None])
            & ((mv[None, :] < mv[:, None]) | (ml[None, :] < ml[:, None])))
    chip_front = ~domc.any(axis=1)                            # [C]
    return masked, scores, best, best_net, net_front, chip_front


_jitted_pareto = None


def _jax_pareto():
    global _jitted_pareto
    if _jitted_pareto is None:
        import jax
        import jax.numpy as jnp

        def kernel(value, latency, norm_latency, deadlines):
            return _pareto_body(jnp, value, latency, norm_latency,
                                deadlines)

        _jitted_pareto = jax.jit(kernel)
    return _jitted_pareto


def batch_pareto_scores(value, latency, deadlines,
                        norm_latency=None,
                        use_jax: bool | None = None):
    """Score a solved (chip × network) block against ALL deadlines at once.

    ``value``/``latency`` are [C, N] (scheduled score — normalised energy
    by convention — and pipeline bottleneck); ``deadlines`` is [N, D]
    absolute per-network bounds or [D] (broadcast to every network);
    ``norm_latency`` defaults to ``latency`` and only feeds the
    network-mean chip front.  Returns the 6-tuple of
    :func:`_pareto_body` as numpy arrays.  With jax available the whole
    block — masking, per-deadline argmins, both dominance fronts — is ONE
    jitted dispatch; the numpy body is the reference fallback."""
    value = np.asarray(value, dtype=np.float64)
    latency = np.asarray(latency, dtype=np.float64)
    deadlines = np.asarray(deadlines, dtype=np.float64)
    if deadlines.ndim == 1:
        deadlines = np.broadcast_to(deadlines[None, :],
                                    (value.shape[1], deadlines.shape[0]))
    norm_latency = (latency if norm_latency is None
                    else np.asarray(norm_latency, dtype=np.float64))
    use_jax = jax_available() if use_jax is None else use_jax
    if use_jax:
        from jax.experimental import enable_x64
        with enable_x64():
            out = _jax_pareto()(value, latency, norm_latency, deadlines)
        return tuple(np.asarray(o) for o in out)
    return _pareto_body(np, value, latency, norm_latency, deadlines)


def partition_network(report, n_cores: int, method: str = "bb") -> Partition:
    """Distribute a simulated network (NetworkReport) across cores."""
    lat = report.layer_latencies
    fn = {"bb": bb_partition, "dp": dp_partition,
          "brute": brute_force_partition}[method]
    return fn(lat, n_cores)


# ---------------------------------------------------------------------------
# Heterogeneous layer→core scheduling: batch_partition generalised beyond
# same-type cores.  A problem is a (chip, network) pair — per-layer
# latencies on every core TYPE plus a core count per type.  The schedule:
#
# 1. **per-layer argmin** — each layer runs on the available type that
#    executes it fastest (ties → lower type index);
# 2. **per-core-count balancing** — each type's layer subsequence is split
#    contiguously over that type's cores; the pipeline bottleneck is the
#    max core load across ALL types, so feasibility of a bottleneck T is
#    the AND of the per-type greedy coverings and ONE bisection per
#    problem drives every (problem × type) greedy row at once.
#
# Masked prefix sums make stage 2 exact: a type's costs are written onto
# the FULL layer axis (other types' slots are 0.0 — adding zero is exact
# in fp), so segment sums are the same prefix differences dp_partition
# computes on the compacted subsequence, and the final bottleneck is
# bit-identical to max_t dp_partition(subseq_t, counts_t) — the oracle.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HeteroSchedule:
    """One network's layer→core schedule on a heterogeneous chip."""

    types: Tuple[int, ...]        # core → type index (type-major order)
    layer_type: Tuple[int, ...]   # layer → type index (per-layer argmin)
    layer_core: Tuple[int, ...]   # layer → global core id
    loads: Tuple[float, ...]      # per-core latency sums (idle cores 0.0)
    bottleneck: float             # pipeline latency = max(loads)
    speedup: float                # Σ assigned layer latency / bottleneck
    n_layers: int

    @property
    def n_cores(self) -> int:
        return len(self.types)


@dataclasses.dataclass(frozen=True)
class BatchHeteroResult:
    """Array-level output of :func:`batch_schedule_hetero` (B problems).

    Kept as arrays so mega-batch co-design sweeps never pay per-problem
    Python object construction for schedules nobody reads —
    :meth:`schedule` materialises a :class:`HeteroSchedule` on demand.
    """

    counts: np.ndarray            # [B, T] cores per type (as requested)
    n_layers: np.ndarray          # [B]
    layer_type: np.ndarray        # [B, L_pad] per-layer argmin type
    starts: np.ndarray            # [B, T, k_max] full-axis segment starts
    seg_counts: np.ndarray        # [B, T] segments actually opened
    loads: np.ndarray             # [B, T, k_max] per-segment latency sums
    bottleneck: np.ndarray        # [B] (+inf: infeasible, strict=False)
    total: np.ndarray             # [B] Σ assigned layer latency
    feasible: np.ndarray | None = None   # [B] False → no core available
    labels: Tuple[str, ...] | None = None   # per-problem names for errors

    def __len__(self) -> int:
        return int(self.bottleneck.shape[0])

    @property
    def speedup(self) -> np.ndarray:
        with np.errstate(divide="ignore"):
            return np.where(self.bottleneck > 0,
                            self.total / self.bottleneck, np.inf)

    def schedule(self, i: int) -> HeteroSchedule:
        if self.feasible is not None and not self.feasible[i]:
            lab = (self.labels[i] if self.labels is not None
                   else f"problem {i}")
            raise ValueError(
                f"{lab}: infeasible — every core type has count 0 (the "
                "fault scenario killed the whole chip); bottleneck is "
                "+inf and no schedule exists")
        n_t = self.counts.shape[1]
        L = int(self.n_layers[i])
        tt = self.layer_type[i, :L]
        counts = self.counts[i]
        core_off = np.concatenate([[0], np.cumsum(counts)])
        types = tuple(int(t) for t in np.repeat(np.arange(n_t), counts))
        loads = np.zeros(int(core_off[-1]))
        layer_core = np.zeros(L, dtype=np.intp)
        for t in range(n_t):
            if counts[t] == 0:
                continue
            kk = int(self.seg_counts[i, t])
            st = self.starts[i, t, :kk]
            ends = np.concatenate([st[1:], [L]])
            lt = np.flatnonzero(tt == t)
            if lt.size:
                layer_core[lt] = core_off[t] + np.searchsorted(
                    ends, lt, side="right")
            loads[core_off[t]:core_off[t] + kk] = self.loads[i, t, :kk]
        bott = float(self.bottleneck[i])
        total = float(self.total[i])
        return HeteroSchedule(
            types=types, layer_type=tuple(int(t) for t in tt),
            layer_core=tuple(int(c) for c in layer_core),
            loads=tuple(float(x) for x in loads),
            bottleneck=bott,
            speedup=total / bott if bott > 0 else float("inf"),
            n_layers=L)

    def schedules(self) -> List[HeteroSchedule]:
        return [self.schedule(i) for i in range(len(self))]


def schedule_hetero_oracle(latencies, counts) -> Dict[str, Any]:
    """Scalar reference for ONE (chip, network) problem.

    ``latencies``: [n_types, n_layers] per-layer latency on each core
    type; ``counts``: [n_types] cores per type.  Per-layer argmin over
    the available types, then ``dp_partition`` per type's subsequence —
    the exact semantics ``batch_schedule_hetero`` batches."""
    lat = np.asarray(latencies, dtype=np.float64)
    counts = np.asarray(counts, dtype=np.int64)
    n_types, n_layers = lat.shape
    if counts.shape[0] > n_types:        # zero-padded type slots are fine
        if (counts[n_types:] > 0).any():
            raise ValueError("counts for more types than latency rows")
        counts = counts[:n_types]
    if n_layers == 0:
        raise ValueError("schedule_hetero_oracle needs ≥ 1 layer")
    if not (counts > 0).any():
        raise ValueError("schedule_hetero_oracle needs ≥ 1 core")
    cost = np.where((counts > 0)[:, None], lat, np.inf)
    tt = np.argmin(cost, axis=0)
    bottleneck = 0.0
    for t in range(n_types):
        sub = lat[t, tt == t]
        if counts[t] <= 0 or sub.size == 0:
            continue
        p = dp_partition(sub, int(counts[t]))
        bottleneck = max(bottleneck, p.pipeline_latency)
    total = float(lat[tt, np.arange(n_layers)].sum())
    return dict(bottleneck=bottleneck, layer_type=tt, total=total,
                speedup=total / bottleneck if bottleneck > 0
                else float("inf"))


_B_BUCKET = 32     # problem-axis bucket of the jitted hetero solver

_jitted_hetero_stage1 = None


def _jax_hetero_stage1():
    """Fused stage 1 of the hetero solver: per-layer argmin assignment +
    masked per-type prefix sums + the per-type reductions (layer counts,
    max, total), one XLA program instead of ~6 full-tensor numpy passes
    over the [B, T, L] block.  Bit-identical to the numpy body (same
    first-minimum argmin, same cumsum order; adding exact zeros)."""
    global _jitted_hetero_stage1
    if _jitted_hetero_stage1 is None:
        import jax
        import jax.numpy as jnp

        def stage1(lat, avail, n_lens):
            n_types = lat.shape[1]
            l_idx = jnp.arange(lat.shape[2])
            valid = l_idx[None, :] < n_lens[:, None]          # [B, L]
            cost = jnp.where(avail[:, :, None], lat, jnp.inf)
            tt = jnp.argmin(cost, axis=1)                     # [B, L]
            tmask = ((tt[:, None, :] == jnp.arange(n_types)[None, :, None])
                     & valid[:, None, :])                     # [B, T, L]
            masked = jnp.where(tmask, lat, 0.0)
            # NOTE no cumsum here: XLA's scan is not bit-identical to
            # numpy's sequential one, and the solver's exactness-vs-dp
            # contract rides on identical prefix arithmetic — the prefix
            # sums stay on the host.
            return (masked, jnp.where(valid, tt, 0),
                    tmask.sum(axis=-1), masked.max(axis=-1))

        _jitted_hetero_stage1 = jax.jit(stage1)
    return _jitted_hetero_stage1


def batch_schedule_hetero(latencies, counts,
                          n_layers=None,
                          use_jax: bool | None = None,
                          *,
                          strict: bool = True,
                          labels=None,
                          ) -> BatchHeteroResult:
    """Solve every heterogeneous (chip, network) schedule in one call.

    ``latencies``: one ``[n_types, n_layers]`` per-layer latency matrix
    per problem — a sequence of such, or ONE dense ``[B, T, L]`` float64
    array (the DSE engine's ``per_layer=True`` tensors gathered per
    chip; the fast path — no per-problem Python work).  ``counts``: the
    matching per-type core counts (``[T]`` per problem, or ``[B, T]``).
    With a dense array, ``n_layers`` gives each problem's true layer
    count (default: the full ``L``) — entries past it are ignored.
    Types with count 0 (padding slots) never receive layers.  Returns a
    :class:`BatchHeteroResult`; bottlenecks are exactly
    :func:`schedule_hetero_oracle`'s (same prefix-difference arithmetic,
    ulp-tight bisection).  With jax available the bisection +
    segmentation run as ONE jitted dispatch over all (problem × type)
    rows; the numpy body is the reference fallback.

    **Fault-scenario axis.**  A dense ``[B, S, T, L]`` array adds a
    scenario axis (per-problem perturbed latencies — e.g. degraded PE
    arrays swap in slower type rows): scenarios are just more problem
    rows, flattened scenario-minor to ``B·S`` problems solved in the
    same single call.  ``counts`` may then be ``[B, S, T]`` (scenarios
    with killed cores), ``[B, T]`` (same counts every scenario) or
    ``[T]``; ``n_layers`` ``[B]`` or ``[B, S]``.  Problem ``b``'s
    scenario ``s`` is flat row ``b·S + s`` of the result.

    **Infeasibility.**  ``strict=True`` (default) raises when any
    problem's counts are all zero.  ``strict=False`` reports such
    problems (a scenario that killed every core) per-problem instead:
    ``bottleneck`` is +inf, ``feasible`` is False, and
    :meth:`BatchHeteroResult.schedule` raises naming the problem via
    ``labels`` (one string per flattened problem row).
    """
    if isinstance(latencies, np.ndarray) and latencies.ndim == 4:
        b0, n_s = latencies.shape[:2]
        latencies = latencies.reshape(b0 * n_s, *latencies.shape[2:])
        cnts_in = np.asarray(counts)
        if cnts_in.ndim == 3:
            counts = cnts_in.reshape(b0 * n_s, cnts_in.shape[2])
        elif cnts_in.ndim == 2:
            counts = np.repeat(cnts_in, n_s, axis=0)
        if n_layers is not None:
            nl = np.asarray(n_layers, dtype=np.int64)
            n_layers = (np.repeat(nl, n_s) if nl.ndim == 1
                        else nl.reshape(b0 * n_s))
    dense = isinstance(latencies, np.ndarray) and latencies.ndim == 3
    if dense:
        n_b, in_types, n_max = latencies.shape
        n_lens = (np.full(n_b, n_max, dtype=np.int64) if n_layers is None
                  else np.asarray(n_layers, dtype=np.int64))
    else:
        lats = [np.asarray(l, dtype=np.float64) for l in latencies]
        n_b = len(lats)
        in_types = max((l.shape[0] for l in lats), default=0)
        n_lens = np.array([l.shape[1] for l in lats], dtype=np.int64)
        n_max = int(n_lens.max()) if n_b else 0
    cnts = np.asarray(counts)
    if cnts.ndim == 1:
        cnts = np.broadcast_to(cnts, (n_b, cnts.shape[0]))
    cnts = cnts.astype(np.int64)
    if n_b == 0:
        return BatchHeteroResult(
            counts=np.zeros((0, 0), np.int64), n_layers=np.zeros(0, np.int64),
            layer_type=np.zeros((0, 0), np.int64),
            starts=np.zeros((0, 0, _K_MAX), np.int64),
            seg_counts=np.zeros((0, 0), np.int64),
            loads=np.zeros((0, 0, _K_MAX)), bottleneck=np.zeros(0),
            total=np.zeros(0), feasible=np.zeros(0, bool))
    if cnts.shape[0] != n_b:
        raise ValueError(f"counts rows {cnts.shape[0]} != problems {n_b}")
    if labels is not None:
        labels = tuple(str(x) for x in labels)
        if len(labels) != n_b:
            raise ValueError(
                f"labels has {len(labels)} entries for {n_b} problems")
    n_types = max(in_types, cnts.shape[1])
    if (n_lens == 0).any():
        raise ValueError("every problem needs ≥ 1 layer")
    # a positive count for a type slot beyond a problem's latency rows
    # would hand every layer to a phantom zero-latency type — reject it,
    # exactly like schedule_hetero_oracle does
    prob_types = (np.asarray([l.shape[0] for l in lats], dtype=np.int64)
                  if not dense else np.full(n_b, in_types, np.int64))
    ghost = np.arange(cnts.shape[1])[None, :] >= prob_types[:, None]
    if (cnts * ghost).any():
        raise ValueError("counts for more types than latency rows")

    if max(int(c) for c in cnts.max(axis=0)) > _K_MAX and use_jax is not False:
        use_jax = False                    # solver unrolls _K_MAX segments
    use_jax = (jax_available() if use_jax is None else use_jax)

    n_pad = _bucketed(n_max, _N_BUCKET) if use_jax else n_max
    b_pad = _bucketed(n_b, _B_BUCKET) if use_jax else n_b

    lat = np.zeros((b_pad, n_types, n_pad))
    if dense:
        lat[:n_b, :in_types, :n_max] = latencies
    else:
        for i, l in enumerate(lats):
            lat[i, :l.shape[0], :l.shape[1]] = l
    counts_p = np.ones((b_pad, n_types), dtype=np.int64)  # benign pad rows
    counts_p[:n_b] = 0
    counts_p[:n_b, :cnts.shape[1]] = cnts
    avail = counts_p > 0
    feas_b = avail[:n_b].any(axis=1)
    if not feas_b.all():
        if strict:
            raise ValueError(
                "every problem needs ≥ 1 core (counts all zero); pass "
                "strict=False to report per-problem infeasibility instead")
        # all-types-dead problems (a scenario that killed every core)
        # solve as benign single-core rows like the padding, then report
        # +inf below — the rest of the batch is unaffected
        avail[np.flatnonzero(~feas_b), 0] = True
    avail[n_b:] = False
    avail[n_b:, 0] = True                  # padded problems: 1 trivial core
    n_lens_p = np.concatenate([n_lens, np.ones(b_pad - n_b, np.int64)])

    # stage 1: per-layer argmin over the available types + masked per-type
    # prefix sums (fused on-device when jax runs the search below)
    l_idx = np.arange(n_pad)
    valid_l = l_idx[None, :] < n_lens_p[:, None]              # [B, L]
    if use_jax:
        from jax.experimental import enable_x64
        with enable_x64():
            masked, tt, n_t, mx = (
                np.asarray(o) for o in _jax_hetero_stage1()(
                    lat, avail, n_lens_p))
    else:
        cost = np.where(avail[:, :, None], lat, np.inf)
        tt = np.argmin(cost, axis=1)                          # [B, L]
        tt = np.where(valid_l, tt, 0)
        tmask = ((tt[:, None, :] == np.arange(n_types)[None, :, None])
                 & valid_l[:, None, :])
        masked = np.where(tmask, lat, 0.0)
        n_t = tmask.sum(axis=-1)                              # layers/type
        mx = masked.max(axis=-1)                              # [B, T]
    # prefix sums on the HOST: numpy's sequential cumsum is the exact
    # arithmetic of the dp oracle (see _jax_hetero_stage1's note)
    cum = np.cumsum(masked, axis=-1)                          # [B, T, L]
    pref = np.where(valid_l[:, None, :], cum, np.inf)
    P = np.full((b_pad * n_types, n_pad + 1), np.inf)
    P[:, 0] = 0.0
    P[:, 1:] = pref.reshape(b_pad * n_types, n_pad)
    kk = np.where(n_t > 0, np.minimum(counts_p, np.maximum(n_t, 1)), 1)
    kk = np.maximum(kk, 1)
    total_t = P[np.arange(b_pad * n_types),
                np.repeat(n_lens_p, n_types)].reshape(b_pad, n_types)

    # Per-(problem, type) solves: the global bottleneck is simply the MAX
    # of the independent per-type optima (feasibility decomposes over
    # types), so every row runs its OWN parametric search — the exact
    # machinery (and jit cache) of batch_partition, one row per
    # (problem, type).  Two row classes are CLOSED FORM and skip the
    # bisection entirely (in chip co-design sweeps they are the
    # majority — core counts are small):
    #   kk == 1     → one segment: T* = total_t, starts = [0, …]
    #   kk == n_t   → one layer per segment: T* = mx_t, starts = the
    #                 type's layer positions on the full axis
    # (kk = min(counts, n_t) never exceeds n_t, so these two plus the
    # bisected 2 ≤ kk < n_t rows are exhaustive.)
    rows = b_pad * n_types
    net_r = np.arange(rows, dtype=np.int64)
    n_arr_r = np.repeat(n_lens_p, n_types)
    kk_r = kk.reshape(rows)
    n_t_r = n_t.reshape(rows)
    k_out = max(_K_MAX, int(kk_r.max()))
    starts_r = np.broadcast_to(n_arr_r[:, None],
                               (rows, k_out)).copy()

    single = kk_r == 1
    starts_r[single, 0] = 0

    per_layer_rows = (~single) & (kk_r == n_t_r)
    if per_layer_rows.any():
        type_mask = ((tt[:, None, :] == np.arange(n_types)[None, :, None])
                     & valid_l[:, None, :]).reshape(rows, n_pad)
        sub = type_mask[per_layer_rows]
        occ = np.cumsum(sub, axis=1)
        for s in range(int(kk_r[per_layer_rows].max())):
            hit = sub & (occ == s + 1)
            pos = np.argmax(hit, axis=1)
            has = hit.any(axis=1)
            starts_r[np.flatnonzero(per_layer_rows)[has], s] = pos[has]

    # kk == 2 is closed form too: with A_j = P[j] (non-decreasing) and
    # B_j = P[n] − P[j] (non-increasing), T* = min_j max(A_j, B_j) sits at
    # the predicate crossing A_j ≤ B_j — one vectorised binary search per
    # row, then the two candidate cuts around it.  Same prefix-difference
    # arithmetic as the dp oracle, so still exact.
    halves = np.flatnonzero(~single & ~per_layer_rows & (kk_r == 2))
    if halves.size:
        net_h, n_h = net_r[halves], n_arr_r[halves]
        tot_h = P[net_h, n_h]
        lo_j = np.ones(halves.size, dtype=np.int64)
        hi_j = np.maximum(n_h - 1, 1)
        steps = int(np.ceil(np.log2(P.shape[1]))) + 1
        for _ in range(steps):
            mid = (lo_j + hi_j + 1) >> 1
            ok = P[net_h, mid] <= tot_h - P[net_h, mid]
            lo_j = np.where(ok, mid, lo_j)
            hi_j = np.where(ok, hi_j, mid - 1)
        j0 = np.clip(lo_j, 1, np.maximum(n_h - 1, 1))
        j1 = np.clip(lo_j + 1, 1, np.maximum(n_h - 1, 1))
        m0 = np.maximum(P[net_h, j0], tot_h - P[net_h, j0])
        m1 = np.maximum(P[net_h, j1], tot_h - P[net_h, j1])
        cut = np.where(m0 <= m1, j0, j1)
        starts_r[halves, 0] = 0
        starts_r[halves, 1] = cut

    need = np.flatnonzero(~single & ~per_layer_rows & (kk_r > 2))
    if need.size:
        lb = np.maximum(mx, total_t / kk).reshape(-1)[need]
        lo_n = np.nextafter(lb, -np.inf)
        hi_n = ((total_t / kk + mx).reshape(-1)[need]) * (1.0 + 1e-12)
        net_n, n_arr_n, kk_n = net_r[need], n_arr_r[need], kk_r[need]
        k_mx = int(kk_n.max())
        if use_jax:
            r_pad = _bucketed(need.size, _ROW_BUCKET)
            pad = r_pad - need.size
            netp = np.concatenate([net_n, np.zeros(pad, np.int64)])
            n_ap = np.concatenate([n_arr_n,
                                   np.full(pad, n_arr_r[0], np.int64)])
            kkp = np.concatenate([kk_n, np.ones(pad, np.int64)])
            lop = np.concatenate([lo_n, np.zeros(pad)])
            hip = np.concatenate([hi_n, np.ones(pad)])
            from jax.experimental import enable_x64
            with enable_x64():
                bs_steps = int(np.ceil(np.log2(n_pad + 1))) + 1
                starts_r[need, :k_mx] = np.asarray(_jax_solver()(
                    P, netp, n_ap, kkp, lop, hip, k_mx,
                    bs_steps))[:need.size]
        else:
            lo_b, hi_b = lo_n.copy(), hi_n.copy()
            for _ in range(_BISECT_ITERS):
                mid = 0.5 * (lo_b + hi_b)
                feas = _batch_greedy(P, net_n, n_arr_n, mid, kk_n, k_mx,
                                     exact=False)
                hi_b = np.where(feas, mid, hi_b)
                lo_b = np.where(feas, lo_b, mid)
            st = _batch_greedy(P, net_n, n_arr_n, hi_b, kk_n, k_mx,
                               exact=True)
            starts_r[need, :st.shape[1]] = st

    k_out = starts_r.shape[1]
    ends_r = np.concatenate(
        [starts_r[:, 1:], np.zeros((rows, 1), starts_r.dtype)], axis=1)
    ends_r[:, -1] = n_arr_r
    ends_r = np.minimum(np.maximum(ends_r, starts_r), n_arr_r[:, None])
    loads_r = P[net_r[:, None], ends_r] - P[net_r[:, None], starts_r]
    loads_r = np.where(np.isfinite(loads_r), loads_r, 0.0)

    loads = loads_r.reshape(b_pad, n_types, k_out)[:n_b]
    bottleneck = loads.max(axis=(1, 2))
    if not feas_b.all():
        loads = np.where(feas_b[:, None, None], loads, 0.0)
        bottleneck = np.where(feas_b, bottleneck, np.inf)
    return BatchHeteroResult(
        counts=np.asarray(cnts), n_layers=n_lens,
        layer_type=tt[:n_b], starts=starts_r.reshape(
            b_pad, n_types, k_out)[:n_b],
        seg_counts=kk[:n_b], loads=loads,
        bottleneck=bottleneck, total=total_t[:n_b].sum(axis=1),
        feasible=feas_b.copy(), labels=labels)


# ---------------------------------------------------------------------------
# Energy-aware deadline-slack scheduling.
#
# Stage 1 of batch_schedule_hetero is latency-argmin only, so every
# frontier built on it is latency-optimal.  The slack pass starts from
# that schedule and greedily moves layers to LOWER-ENERGY types (largest
# energy saving first) while the pipeline still meets a deadline.
# Feasibility of a candidate assignment at a threshold is decided by a
# sequential greedy-covering SCAN over the layer axis (open a new
# segment when the running sum would exceed the threshold) — the same
# arithmetic in the scalar oracle, the numpy batch kernel and the jitted
# jax kernel, so the three stay bit-identical:
#
#     x    = lat[t, l] if tt[l] == t else 0.0     (exact zero-padding)
#     nxt  = run + x                              (computed ONCE, reused)
#     over = nxt > thr
#     viol |= over & (x > thr)
#     segs += over;  run = over ? x : nxt
#
# A type is coverable iff segs <= max(count, 1) and never viol.  After
# the greedy move loop the true bottleneck of the final assignment is
# recovered by bisecting the threshold (56 iterations, lo = 0, hi =
# min(deadline, per-type scan totals max) — both endpoints verified
# feasible, and hi is only ever replaced by a TESTED-feasible midpoint,
# so extraction at hi always succeeds and bottleneck <= deadline holds
# at the bit level).  Energy totals are summed by a SEQUENTIAL per-layer
# loop in both paths (np.sum's pairwise tree would differ between the
# oracle's [n_l] vector and the batch's padded rows).
# ---------------------------------------------------------------------------


def _oracle_slack_scan(lat, tt, thr, n_l):
    """Scalar greedy-covering scan for ONE problem (python loop).

    Returns (run [T] final running sums, segs [T], viol [T], peak [T]
    max completed-segment sum incl. the final running one)."""
    n_types = lat.shape[0]
    run = np.zeros(n_types)
    segs = np.ones(n_types, dtype=np.int64)
    viol = np.zeros(n_types, dtype=bool)
    peak = np.zeros(n_types)
    for l in range(n_l):
        t = int(tt[l])
        x = float(lat[t, l])
        nxt = run[t] + x
        if nxt > thr:
            if x > thr:
                viol[t] = True
            segs[t] += 1
            peak[t] = max(peak[t], run[t])
            run[t] = x
        else:
            run[t] = nxt
    peak = np.maximum(peak, run)
    return run, segs, viol, peak


def slack_schedule_oracle(latencies, energies, counts, deadline
                          ) -> Dict[str, Any]:
    """Scalar reference for ONE energy-aware slack schedule.

    ``latencies``/``energies``: [n_types, n_layers]; ``counts``:
    [n_types] cores per type; ``deadline``: absolute pipeline-latency
    budget.  Starts from :func:`schedule_hetero_oracle`'s latency-argmin
    schedule; when ``deadline`` leaves slack (deadline > T*), greedily
    re-assigns layers to the energy-argmin type (largest per-layer
    saving first, ties -> lower layer index), accepting each move iff
    the greedy-covering scan still fits every type's cores within the
    deadline.  Returns dict(bottleneck, layer_type, energy, n_moves,
    feasible) — the exact semantics :func:`batch_slack_schedule`
    batches (bit-identical arithmetic)."""
    lat = np.asarray(latencies, dtype=np.float64)
    en = np.asarray(energies, dtype=np.float64)
    base = schedule_hetero_oracle(lat, counts)
    n_types, n_l = lat.shape
    if en.shape != lat.shape:
        raise ValueError(
            f"energies shape {en.shape} != latencies shape {lat.shape}")
    cnt = np.asarray(counts, dtype=np.int64)[:n_types]
    deadline = float(deadline)
    tt0 = np.asarray(base["layer_type"], dtype=np.int64)
    t_star = float(base["bottleneck"])

    def _energy(tt):
        eng = 0.0                       # sequential: matches batch path
        for l in range(n_l):
            eng += en[tt[l], l]
        return eng

    def _base_copy():
        return dict(bottleneck=t_star, layer_type=tt0.copy(),
                    energy=_energy(tt0), n_moves=0,
                    feasible=bool(t_star <= deadline))

    if not (deadline > t_star):        # no slack (or infeasible): base
        return _base_copy()

    avail = cnt > 0
    te = np.argmin(np.where(avail[:, None], en, np.inf), axis=0)
    d_e = en[tt0, np.arange(n_l)] - en[te, np.arange(n_l)]
    cand = (te != tt0) & (d_e > 0)
    order = np.lexsort((np.arange(n_l), np.where(cand, -d_e, np.inf)))
    moves = order[:int(cand.sum())]

    kk = np.maximum(cnt, 1)
    tt = tt0.copy()
    n_moves = 0
    for l in moves:
        tt_try = tt.copy()
        tt_try[l] = te[l]
        _, segs, viol, _ = _oracle_slack_scan(lat, tt_try, deadline, n_l)
        if ((segs <= kk) & ~viol).all():
            tt = tt_try
            n_moves += 1
    if n_moves == 0:                   # ulp guard: keep the dp-exact T*
        return _base_copy()

    totals, _, _, _ = _oracle_slack_scan(lat, tt, np.inf, n_l)
    lo, hi = 0.0, min(deadline, float(totals.max()))
    for _ in range(_BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        _, segs, viol, _ = _oracle_slack_scan(lat, tt, mid, n_l)
        if ((segs <= kk) & ~viol).all():
            hi = mid
        else:
            lo = mid
    _, _, _, peak = _oracle_slack_scan(lat, tt, hi, n_l)
    return dict(bottleneck=float(peak.max()), layer_type=tt,
                energy=_energy(tt), n_moves=n_moves, feasible=True)


def _slack_x_rows(lat, tt):
    """Materialise every per-step scan input in ONE op: ``x_all[l]`` is
    exactly the ``x`` the reference scan builds at step ``l`` (the
    latency of layer ``l`` on its assigned type, 0.0 elsewhere).  Shape
    [L, B, D, T] so each step reads a contiguous slice."""
    t_ar = np.arange(lat.shape[1])
    return np.where(np.transpose(tt, (2, 0, 1))[..., None] == t_ar,
                    np.transpose(lat, (2, 0, 1))[:, :, None, :], 0.0)


def _slack_scan_rows(lat, tt, kk, thr, x_all=None, x_max=None):
    """Vectorised greedy-covering scan (numpy batch reference).

    ``lat`` [B, T, L]; ``tt`` [B, D, L]; ``kk`` [B, T]; ``thr`` [B, D].
    Returns (run [B, D, T] final running sums, feas [B, D]).  Element-
    wise arithmetic identical to :func:`_oracle_slack_scan` (types other
    than tt[l] add an exact 0.0; `over` can only fire for them once viol
    is already set, which never changes the feasibility verdict).
    ``x_all`` lets callers reuse :func:`_slack_x_rows` across scans that
    share the same assignment (the bisection re-scans the SAME ``tt``
    dozens of times with different thresholds)."""
    n_b, n_d, n_pad = tt.shape
    n_types = lat.shape[1]
    if x_all is None:
        x_all = _slack_x_rows(lat, tt)
    if x_max is None:
        x_max = x_all.max(axis=0)
    run = np.zeros((n_b, n_d, n_types))
    segs = np.ones((n_b, n_d, n_types), dtype=np.int64)
    # x > th forces `over` at that step (run >= 0), so viol — "a single
    # layer exceeds the threshold" — needs no scan state: it is just
    # max_l(x_l) > th, and the max is threshold-independent (callers
    # bisecting over thresholds pass it in once)
    viol = x_max > thr[:, :, None]
    th = np.broadcast_to(thr[:, :, None], run.shape)
    over = np.empty(run.shape, dtype=bool)
    for l in range(n_pad):
        x = x_all[l]
        np.add(run, x, out=run)
        np.greater(run, th, out=over)
        segs += over
        np.copyto(run, x, where=over)
    feas = ((segs <= kk[:, None, :]) & ~viol).all(axis=-1)
    return run, feas


def _np_slack_kernel(lat, tt0, kk, mv_layer, mv_to, mv_valid, gate, dl,
                     n_lens, k_out):
    """Numpy slack solver: greedy move loop + bisection + extraction.

    Shapes: lat [B, T, L]; tt0 [B, L]; kk [B, T]; mv_layer/mv_to/
    mv_valid [B, M]; gate/dl [B, D]; n_lens [B]; k_out static.  Returns
    (tt [B, D, L], n_moves [B, D], starts [B, D, T, k_out], loads
    [B, D, T, k_out], seg_counts [B, D, T], bottleneck [B, D]).

    Rows are independent, so the batch is split into depth buckets
    (power-of-two layer counts) and each bucket scans only its own
    depth — padding columns past a problem's true layer count are exact
    scan no-ops, so an 11-layer problem need not ride along through a
    126-step loop sized by the deepest problem in the batch.  The move
    loop also shrinks per bucket (shallow problems have few candidate
    moves)."""
    n_b, n_types, n_pad = lat.shape
    n_d = dl.shape[1]
    depth = np.maximum(n_lens, 1)
    if np.unique(depth).size <= 8:     # few distinct depths: exact cut
        buckets = depth
    else:
        buckets = 1 << np.ceil(np.log2(depth)).astype(np.int64)
        buckets = np.minimum(np.maximum(buckets, 8), n_pad)
    if n_b and buckets.min() < n_pad:
        tt = np.broadcast_to(tt0[:, None, :], (n_b, n_d, n_pad)).copy()
        n_moves = np.zeros((n_b, n_d), dtype=np.int64)
        starts = np.broadcast_to(
            n_lens[:, None, None, None],
            (n_b, n_d, n_types, k_out)).copy()
        starts[:, :, :, 0] = 0
        loads = np.zeros((n_b, n_d, n_types, k_out))
        segc = np.ones((n_b, n_d, n_types), dtype=np.int64)
        bott = np.full((n_b, n_d), np.inf)
        for bk in np.unique(buckets):
            idx = np.flatnonzero(buckets == bk)
            mv_v = mv_valid[idx]
            m_hi = int(mv_v.sum(axis=1).max(initial=0))
            out = _np_slack_rows(
                np.ascontiguousarray(lat[idx, :, :bk]), tt0[idx, :bk],
                kk[idx], mv_layer[idx, :m_hi], mv_to[idx, :m_hi],
                mv_v[:, :m_hi], gate[idx], dl[idx], n_lens[idx], k_out)
            tt[idx, :, :bk] = out[0]
            n_moves[idx] = out[1]
            starts[idx] = out[2]
            loads[idx] = out[3]
            segc[idx] = out[4]
            bott[idx] = out[5]
        return tt, n_moves, starts, loads, segc, bott
    return _np_slack_rows(lat, tt0, kk, mv_layer, mv_to, mv_valid, gate,
                          dl, n_lens, k_out)


def _np_slack_rows(lat, tt0, kk, mv_layer, mv_to, mv_valid, gate, dl,
                   n_lens, k_out):
    """One depth bucket of :func:`_np_slack_kernel` (same contract; the
    layer axis is the bucket depth, ``n_lens`` may be shorter)."""
    n_b, n_types, n_pad = lat.shape
    n_d = dl.shape[1]
    n_m = mv_layer.shape[1]
    tt = np.broadcast_to(tt0[:, None, :], (n_b, n_d, n_pad)).copy()
    n_moves = np.zeros((n_b, n_d), dtype=np.int64)
    x_cur = None
    if n_m:
        # The tentative scan per candidate runs on the DESTINATION lane
        # only.  On gated cells (dl > base bottleneck) the current
        # accepted assignment is always scan-feasible at the deadline
        # with no layer exceeding it: the base split's bottleneck
        # certifies it (greedy segment count is monotone in the
        # threshold), and every accepted move preserves it by
        # construction.  Greedy segment count is also monotone in the
        # element values, so zeroing the moved layer can never break
        # its OLD lane — both monotonicities hold exactly in float
        # arithmetic (sequential nonnegative adds are order-preserving),
        # so the full-assignment verdict the oracle computes reduces to
        # [new-lane scan feasible] AND [lat_new <= dl].  Candidates are
        # one per layer (its energy-argmin type), so a candidate layer
        # still sits on its base type when tried.  The new-lane
        # sequence is where-built per candidate in layer-major layout
        # from the cell-major tt/lat (sources keep the layer axis
        # contiguous, so the build streams), while x_cur [L, B, D, T]
        # is maintained by tiny accept-scatters purely for the bisect
        # stage below.  Every value written is a lat[] element or an
        # exact 0.0, so downstream scan arithmetic is bit-identical to
        # rebuilding x from the assignment.
        x_cur = _slack_x_rows(lat, tt)
        d_ar = np.arange(n_d)
        # the move axis is padded to the WORST problem's candidate
        # count — rows without move j (or without slack at all) are
        # excluded, keeping tt/n_moves unchanged, exactly as the dense
        # formulation would leave them
        live = gate.any(axis=1)
        for j in range(n_m):
            sel = np.flatnonzero(live & mv_valid[:, j])
            s = sel.size
            if s == 0:
                continue
            r_ix = sel[:, None]
            lyr = mv_layer[sel, j]
            l_ix = lyr[:, None]
            s_ar = np.arange(s)
            nt = mv_to[sel, j]                                # [s]
            nt_b = nt[:, None]                                # [s, 1]
            ot1 = tt0[sel, lyr]                               # [s]
            ot = ot1[:, None]
            lat_new = lat[sel, nt, lyr][:, None]              # [s, 1]
            x_old = lat[sel, ot1, lyr][:, None]               # [s, 1]
            dl_s = dl[sel]                                    # [s, D]
            cond = tt[sel] == nt[:, None, None]               # [s, D, L]
            xs = np.where(cond.transpose(2, 0, 1),
                          lat[sel, nt].T[:, :, None], 0.0)    # [L, s, D]
            xs[lyr, s_ar, :] = lat_new
            kk_nt = kk[sel, nt]                               # [s]
            run = np.zeros((s, n_d))
            segs = np.ones((s, n_d), dtype=np.int64)
            over = np.empty(run.shape, dtype=bool)
            for l in range(n_pad):
                x = xs[l]
                np.add(run, x, out=run)
                np.greater(run, dl_s, out=over)
                segs += over
                np.copyto(run, x, where=over)
            acc = ((segs <= kk_nt[:, None]) & (lat_new <= dl_s)
                   & gate[sel])                               # [s, D]
            x_cur[l_ix, r_ix, d_ar, ot] = np.where(acc, 0.0, x_old)
            x_cur[l_ix, r_ix, d_ar, nt_b] = np.where(
                acc, np.broadcast_to(lat_new, acc.shape), 0.0)
            tt[r_ix, d_ar, l_ix] = np.where(acc, nt_b, ot)
            n_moves[sel] += acc

    # rows with zero accepted moves carry the base schedule through
    # combine(), so bisection + extraction run on the moved rows only;
    # untouched rows get inert placeholders (overridden downstream)
    starts = np.broadcast_to(
        n_lens[:, None, None, None], (n_b, n_d, n_types, k_out)).copy()
    starts[:, :, :, 0] = 0
    loads = np.zeros((n_b, n_d, n_types, k_out))
    segc = np.ones((n_b, n_d, n_types), dtype=np.int64)
    bott = np.full((n_b, n_d), np.inf)
    rsel = np.flatnonzero((n_moves > 0).any(axis=1))
    if rsel.size == 0:
        return tt, n_moves, starts, loads, segc, bott
    lat_r, tt_r, kk_r, dl_r = lat[rsel], tt[rsel], kk[rsel], dl[rsel]
    # tt fixed from here on: one x tensor is shared by all scans (the
    # move loop left x_cur holding exactly _slack_x_rows(lat, tt))
    x_all = (x_cur[:, rsel] if x_cur is not None
             else _slack_x_rows(lat_r, tt_r))
    x_max = x_all.max(axis=0)
    totals, _ = _slack_scan_rows(lat_r, tt_r, kk_r,
                                 np.full_like(dl_r, np.inf), x_all, x_max)
    hi = np.minimum(dl_r, totals.max(axis=-1))
    lo = np.zeros_like(hi)
    for _ in range(_BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        _, feas = _slack_scan_rows(lat_r, tt_r, kk_r, mid, x_all, x_max)
        lo = np.where(feas, lo, mid)
        hi = np.where(feas, mid, hi)

    n_r = rsel.size
    k_ar = np.arange(k_out)
    run = np.zeros((n_r, n_d, n_types))
    seg = np.zeros((n_r, n_d, n_types), dtype=np.int64)
    starts_r = np.broadcast_to(
        n_lens[rsel][:, None, None, None],
        (n_r, n_d, n_types, k_out)).copy()
    starts_r[:, :, :, 0] = 0
    loads_r = np.zeros((n_r, n_d, n_types, k_out))
    th = hi[:, :, None]
    for l in range(n_pad):
        x = x_all[l]
        nxt = run + x
        over = nxt > th
        starts_r = np.where(
            over[..., None] & (k_ar == (seg + 1)[..., None]), l, starts_r)
        loads_r = np.where(over[..., None] & (k_ar == seg[..., None]),
                           run[..., None], loads_r)
        seg = seg + over
        run = np.where(over, x, nxt)
    loads_r = np.where(k_ar == seg[..., None], run[..., None], loads_r)
    starts[rsel] = starts_r
    loads[rsel] = loads_r
    segc[rsel] = seg + 1
    bott[rsel] = loads_r.max(axis=(-1, -2))
    return tt, n_moves, starts, loads, segc, bott


_jitted_slack = None


def _jax_slack_solver():
    """Jitted twin of :func:`_np_slack_kernel`: the greedy move loop,
    bisection and segment extraction run as ONE XLA program over every
    (problem x deadline) cell.  Same elementwise arithmetic (fori_loop
    bodies mirror the numpy loops statement for statement), so results
    are bit-identical to the numpy kernel and the scalar oracle."""
    global _jitted_slack
    if _jitted_slack is None:
        import jax
        import jax.numpy as jnp

        def scan_rows(lat, tt, kk, thr):
            n_b, n_d, n_pad = tt.shape
            n_types = lat.shape[1]
            t_ar = jnp.arange(n_types)
            th = thr[:, :, None]

            def body(l, st):
                run, segs, viol = st
                x = jnp.where(tt[:, :, l][:, :, None] == t_ar,
                              lat[:, None, :, l], 0.0)
                nxt = run + x
                over = nxt > th
                viol = viol | (over & (x > th))
                segs = segs + over
                run = jnp.where(over, x, nxt)
                return run, segs, viol

            run, segs, viol = jax.lax.fori_loop(
                0, n_pad, body,
                (jnp.zeros((n_b, n_d, n_types)),
                 jnp.ones((n_b, n_d, n_types), jnp.int64),
                 jnp.zeros((n_b, n_d, n_types), bool)))
            feas = ((segs <= kk[:, None, :]) & ~viol).all(axis=-1)
            return run, feas

        def solve(lat, tt0, kk, mv_layer, mv_to, mv_valid, gate, dl,
                  n_lens, k_out):
            n_b, n_types, n_pad = lat.shape
            n_d = dl.shape[1]
            n_m = mv_layer.shape[1]
            l_ar = jnp.arange(n_pad)
            tt = jnp.broadcast_to(tt0[:, None, :], (n_b, n_d, n_pad))
            n_moves = jnp.zeros((n_b, n_d), jnp.int64)

            def mv_body(j, st):
                tt, n_moves = st
                onehot = l_ar[None, :] == mv_layer[:, j][:, None]
                tt_new = jnp.where(onehot[:, None, :],
                                   mv_to[:, j][:, None, None], tt)
                _, feas = scan_rows(lat, tt_new, kk, dl)
                acc = feas & gate & mv_valid[:, j][:, None]
                tt = jnp.where(acc[:, :, None], tt_new, tt)
                return tt, n_moves + acc

            tt, n_moves = jax.lax.fori_loop(0, n_m, mv_body,
                                            (tt, n_moves))

            totals, _ = scan_rows(lat, tt, kk,
                                  jnp.full_like(dl, jnp.inf))
            hi = jnp.minimum(dl, totals.max(axis=-1))
            lo = jnp.zeros_like(hi)

            def bs_body(_, st):
                lo, hi = st
                mid = 0.5 * (lo + hi)
                _, feas = scan_rows(lat, tt, kk, mid)
                return jnp.where(feas, lo, mid), jnp.where(feas, mid, hi)

            lo, hi = jax.lax.fori_loop(0, _BISECT_ITERS, bs_body,
                                       (lo, hi))

            t_ar = jnp.arange(n_types)
            k_ar = jnp.arange(k_out)
            th = hi[:, :, None]
            starts0 = jnp.where(
                k_ar == 0, 0,
                jnp.broadcast_to(n_lens[:, None, None, None],
                                 (n_b, n_d, n_types, k_out)))

            def ex_body(l, st):
                run, seg, starts, loads = st
                x = jnp.where(tt[:, :, l][:, :, None] == t_ar,
                              lat[:, None, :, l], 0.0)
                nxt = run + x
                over = nxt > th
                starts = jnp.where(
                    over[..., None] & (k_ar == (seg + 1)[..., None]),
                    l, starts)
                loads = jnp.where(
                    over[..., None] & (k_ar == seg[..., None]),
                    run[..., None], loads)
                seg = seg + over
                run = jnp.where(over, x, nxt)
                return run, seg, starts, loads

            run, seg, starts, loads = jax.lax.fori_loop(
                0, n_pad, ex_body,
                (jnp.zeros((n_b, n_d, n_types)),
                 jnp.zeros((n_b, n_d, n_types), jnp.int64),
                 starts0, jnp.zeros((n_b, n_d, n_types, k_out))))
            loads = jnp.where(k_ar == seg[..., None],
                              run[..., None], loads)
            bott = loads.max(axis=(-1, -2))
            return tt, n_moves, starts, loads, seg + 1, bott

        _jitted_slack = jax.jit(solve, static_argnums=(9,))
    return _jitted_slack


@dataclasses.dataclass(frozen=True)
class BatchSlackResult:
    """Array-level output of :func:`batch_slack_schedule`.

    Every field with a leading ``[B, D]`` is indexed (problem,
    deadline); ``base`` is the latency-only :class:`BatchHeteroResult`
    the slack pass started from.  Cells without slack (deadline <= the
    latency-optimal bottleneck, or no accepted move) carry the base
    schedule unchanged, so the slack result weakly dominates the base
    everywhere by construction."""

    base: BatchHeteroResult
    deadlines: np.ndarray         # [B, D] absolute deadlines
    layer_type: np.ndarray        # [B, D, L_pad]
    starts: np.ndarray            # [B, D, T, k_out] full-axis starts
    seg_counts: np.ndarray        # [B, D, T]
    loads: np.ndarray             # [B, D, T, k_out]
    bottleneck: np.ndarray        # [B, D] (<= deadline wherever slack)
    total: np.ndarray             # [B, D] sum of assigned layer latency
    energy: np.ndarray            # [B, D] sum of assigned layer energy
    n_moves: np.ndarray           # [B, D] accepted energy moves
    feasible: np.ndarray          # [B, D] bottleneck <= deadline

    def __len__(self) -> int:
        return int(self.bottleneck.shape[0])

    @property
    def n_deadlines(self) -> int:
        return int(self.bottleneck.shape[1])

    def schedule(self, i: int, d: int = 0) -> HeteroSchedule:
        if not self.feasible[i, d]:
            lab = (self.base.labels[i] if self.base.labels is not None
                   else f"problem {i}")
            raise ValueError(
                f"{lab}: infeasible at deadline {self.deadlines[i, d]} "
                f"(latency-optimal bottleneck "
                f"{float(self.base.bottleneck[i])}) — no schedule meets "
                "the deadline")
        n_t = self.base.counts.shape[1]
        n_l = int(self.base.n_layers[i])
        tt = self.layer_type[i, d, :n_l]
        counts = self.base.counts[i]
        core_off = np.concatenate([[0], np.cumsum(counts)])
        types = tuple(int(t) for t in np.repeat(np.arange(n_t), counts))
        loads = np.zeros(int(core_off[-1]))
        layer_core = np.zeros(n_l, dtype=np.intp)
        for t in range(n_t):
            if counts[t] == 0:
                continue
            kk = int(self.seg_counts[i, d, t])
            st = self.starts[i, d, t, :kk]
            ends = np.concatenate([st[1:], [n_l]])
            lt = np.flatnonzero(tt == t)
            if lt.size:
                layer_core[lt] = core_off[t] + np.searchsorted(
                    ends, lt, side="right")
            loads[core_off[t]:core_off[t] + kk] = self.loads[i, d, t, :kk]
        bott = float(self.bottleneck[i, d])
        total = float(self.total[i, d])
        return HeteroSchedule(
            types=types, layer_type=tuple(int(t) for t in tt),
            layer_core=tuple(int(c) for c in layer_core),
            loads=tuple(float(x) for x in loads),
            bottleneck=bott,
            speedup=total / bott if bott > 0 else float("inf"),
            n_layers=n_l)


def batch_slack_schedule(latencies, energies, counts, deadlines,
                         n_layers=None,
                         use_jax: bool | None = None,
                         *,
                         strict: bool = True,
                         labels=None,
                         base: BatchHeteroResult | None = None,
                         ) -> BatchSlackResult:
    """Solve every energy-aware slack schedule in ONE call.

    ``latencies``/``energies``: per-problem ``[n_types, n_layers]``
    matrices — a sequence of such, or dense ``[B, T, L]`` (or
    ``[B, S, T, L]`` with a fault-scenario axis, flattened
    scenario-minor exactly like :func:`batch_schedule_hetero`).
    ``deadlines``: absolute pipeline-latency budgets — a scalar, a
    ``[D]`` vector shared by every problem, or ``[B, D]`` per-problem
    rows.  For each (problem, deadline) cell the latency-argmin
    schedule is computed first (``base``, reusable across calls), then
    layers are greedily moved to lower-energy types while the greedy-
    covering scan keeps the pipeline within the deadline — all cells in
    one jitted dispatch.  Bit-exact against
    :func:`slack_schedule_oracle` per cell.  ``strict``/``labels``
    follow :func:`batch_schedule_hetero` (used only when ``base`` is
    not supplied)."""
    lat_in, en_in = latencies, energies
    if isinstance(lat_in, np.ndarray) and lat_in.ndim == 4:
        en_in = np.asarray(en_in, dtype=np.float64)
        if en_in.shape != lat_in.shape:
            raise ValueError(
                f"energies shape {en_in.shape} != latencies shape "
                f"{lat_in.shape}")
        b0, n_s = lat_in.shape[:2]
        lat_in = lat_in.reshape(b0 * n_s, *lat_in.shape[2:])
        en_in = en_in.reshape(b0 * n_s, *en_in.shape[2:])
        cnts_in = np.asarray(counts)
        if cnts_in.ndim == 3:
            counts = cnts_in.reshape(b0 * n_s, cnts_in.shape[2])
        elif cnts_in.ndim == 2:
            counts = np.repeat(cnts_in, n_s, axis=0)
        if n_layers is not None:
            nl = np.asarray(n_layers, dtype=np.int64)
            n_layers = (np.repeat(nl, n_s) if nl.ndim == 1
                        else nl.reshape(b0 * n_s))
    dense = isinstance(lat_in, np.ndarray) and lat_in.ndim == 3
    if dense:
        n_b, in_types, n_max = lat_in.shape
        n_lens = (np.full(n_b, n_max, dtype=np.int64) if n_layers is None
                  else np.asarray(n_layers, dtype=np.int64))
        prob_types = np.full(n_b, in_types, np.int64)
    else:
        lats = [np.asarray(l, dtype=np.float64) for l in lat_in]
        ens = [np.asarray(e, dtype=np.float64) for e in en_in]
        if len(ens) != len(lats):
            raise ValueError(
                f"{len(ens)} energy matrices for {len(lats)} problems")
        for l, e in zip(lats, ens):
            if e.shape != l.shape:
                raise ValueError(
                    f"energies shape {e.shape} != latencies {l.shape}")
        n_b = len(lats)
        in_types = max((l.shape[0] for l in lats), default=0)
        n_lens = np.array([l.shape[1] for l in lats], dtype=np.int64)
        n_max = int(n_lens.max()) if n_b else 0
        prob_types = np.asarray([l.shape[0] for l in lats],
                                dtype=np.int64)
    cnts = np.asarray(counts)
    if cnts.ndim == 1:
        cnts = np.broadcast_to(cnts, (n_b, cnts.shape[0]))
    cnts = cnts.astype(np.int64)

    dl = np.asarray(deadlines, dtype=np.float64)
    if dl.ndim == 0:
        dl = dl.reshape(1)
    if dl.ndim == 1:
        dl = np.broadcast_to(dl, (max(n_b, 1), dl.shape[0]))
    if dl.ndim != 2 or (n_b and dl.shape[0] != n_b):
        raise ValueError(
            f"deadlines shape {np.asarray(deadlines).shape} is not "
            f"scalar, [D], or [B={n_b}, D]")
    n_d = dl.shape[1]

    if n_b == 0:
        empty_base = batch_schedule_hetero(
            np.zeros((0, 0, 0)), np.zeros((0, 0), np.int64))
        z = np.zeros((0, n_d))
        return BatchSlackResult(
            base=empty_base, deadlines=np.zeros((0, n_d)),
            layer_type=np.zeros((0, n_d, 0), np.int64),
            starts=np.zeros((0, n_d, 0, _K_MAX), np.int64),
            seg_counts=np.zeros((0, n_d, 0), np.int64),
            loads=np.zeros((0, n_d, 0, _K_MAX)),
            bottleneck=z.copy(), total=z.copy(), energy=z.copy(),
            n_moves=np.zeros((0, n_d), np.int64),
            feasible=np.zeros((0, n_d), bool))

    if cnts.shape[0] != n_b:
        raise ValueError(f"counts rows {cnts.shape[0]} != problems {n_b}")
    # counts on type slots past a problem's latency rows would hand
    # layers to a phantom zero-latency/zero-energy type once densified
    ghost = np.arange(cnts.shape[1])[None, :] >= prob_types[:, None]
    if (cnts * ghost).any():
        raise ValueError("counts for more types than latency rows")

    n_types = max(in_types, cnts.shape[1])
    counts2 = np.zeros((n_b, n_types), dtype=np.int64)
    counts2[:, :cnts.shape[1]] = cnts
    lat_d = np.zeros((n_b, n_types, n_max))
    en_d = np.zeros((n_b, n_types, n_max))
    if dense:
        lat_d[:, :in_types, :] = lat_in
        en_src = np.asarray(en_in, dtype=np.float64)
        if en_src.shape != np.asarray(lat_in).shape:
            raise ValueError(
                f"energies shape {en_src.shape} != latencies shape "
                f"{np.asarray(lat_in).shape}")
        en_d[:, :in_types, :] = en_src
    else:
        for i, (l, e) in enumerate(zip(lats, ens)):
            lat_d[i, :l.shape[0], :l.shape[1]] = l
            en_d[i, :e.shape[0], :e.shape[1]] = e
    # the scan and the sequential energy/total sums rely on EXACT zeros
    # past each problem's true layer count — scrub dense garbage columns
    valid_cols = np.arange(n_max)[None, :] < n_lens[:, None]
    lat_d = np.where(valid_cols[:, None, :], lat_d, 0.0)
    en_d = np.where(valid_cols[:, None, :], en_d, 0.0)

    if base is None:
        base = batch_schedule_hetero(lat_d, counts2, n_lens, use_jax,
                                     strict=strict, labels=labels)
    elif len(base) != n_b:
        raise ValueError(
            f"base has {len(base)} problems, inputs have {n_b}")

    use_jax = (jax_available() if use_jax is None else use_jax)

    # host precompute: energy argmin targets + move order per problem
    tt0 = base.layer_type[:, :n_max].astype(np.int64)
    avail = counts2 > 0
    te = np.argmin(np.where(avail[:, :, None], en_d, np.inf), axis=1)
    l_idx = np.arange(n_max)
    valid_l = l_idx[None, :] < n_lens[:, None]
    e_cur = np.take_along_axis(en_d, tt0[:, None, :], axis=1)[:, 0, :]
    e_new = np.take_along_axis(en_d, te[:, None, :], axis=1)[:, 0, :]
    d_e = e_cur - e_new
    cand = (te != tt0) & (d_e > 0) & valid_l
    key = np.where(cand, -d_e, np.inf)
    order = np.lexsort(
        (np.broadcast_to(l_idx, key.shape), key), axis=-1)
    n_mv = cand.sum(axis=1)
    n_m = int(n_mv.max()) if n_b else 0
    mv_layer = order[:, :n_m]
    mv_valid = np.arange(n_m)[None, :] < n_mv[:, None]
    mv_to = np.take_along_axis(te, mv_layer, axis=1) if n_m else \
        np.zeros((n_b, 0), np.int64)
    with np.errstate(invalid="ignore"):
        gate = dl > base.bottleneck[:, None]       # inf-bottleneck safe
    kk = np.maximum(counts2, 1)
    k_out = max(base.starts.shape[2],
                max(1, min(int(counts2.max(initial=1)), n_max)))

    if use_jax:
        b_pad = _bucketed(n_b, _ROW_BUCKET)
        l_pad = _bucketed(n_max, _N_BUCKET)
        m_pad = _bucketed(max(n_m, 1), 8)   # fori body traced even at 0
        lat_p = np.zeros((b_pad, n_types, l_pad))
        lat_p[:n_b, :, :n_max] = lat_d
        tt_p = np.zeros((b_pad, l_pad), np.int64)
        tt_p[:n_b, :n_max] = tt0
        kk_p = np.ones((b_pad, n_types), np.int64)
        kk_p[:n_b] = kk
        mvl_p = np.zeros((b_pad, m_pad), np.int64)
        mvl_p[:n_b, :n_m] = mv_layer
        mvt_p = np.zeros((b_pad, m_pad), np.int64)
        mvt_p[:n_b, :n_m] = mv_to
        mvv_p = np.zeros((b_pad, m_pad), bool)
        mvv_p[:n_b, :n_m] = mv_valid
        gate_p = np.zeros((b_pad, n_d), bool)
        gate_p[:n_b] = gate
        dl_p = np.ones((b_pad, n_d))
        dl_p[:n_b] = dl
        nl_p = np.ones(b_pad, np.int64)
        nl_p[:n_b] = n_lens
        from jax.experimental import enable_x64
        with enable_x64():
            out = _jax_slack_solver()(
                lat_p, tt_p, kk_p, mvl_p, mvt_p, mvv_p, gate_p, dl_p,
                nl_p, k_out)
        tt_s, n_moves, starts_s, loads_s, segc_s, bott_s = (
            np.asarray(o)[:n_b] for o in out)
        tt_s, starts_s, loads_s = (tt_s[:, :, :n_max],
                                   starts_s, loads_s)
    else:
        tt_s, n_moves, starts_s, loads_s, segc_s, bott_s = \
            _np_slack_kernel(lat_d, tt0, kk, mv_layer, mv_to, mv_valid,
                             gate, dl, n_lens, k_out)

    # combine: cells without slack (or with zero accepted moves) carry
    # the base schedule unchanged — weak dominance by construction
    use = gate & (n_moves > 0)
    layer_type = np.where(use[:, :, None], tt_s, tt0[:, None, :])
    k_b = base.starts.shape[2]
    base_starts = base.starts
    base_loads = base.loads
    if k_out > k_b:
        base_starts = np.concatenate(
            [base_starts, np.broadcast_to(
                n_lens[:, None, None],
                (n_b, base_starts.shape[1], k_out - k_b))], axis=2)
        base_loads = np.concatenate(
            [base_loads, np.zeros(
                (n_b, base_loads.shape[1], k_out - k_b))], axis=2)
    starts = np.where(use[:, :, None, None], starts_s,
                      base_starts[:, None])
    loads = np.where(use[:, :, None, None], loads_s,
                     base_loads[:, None])
    seg_counts = np.where(use[:, :, None], segc_s,
                          base.seg_counts[:, None])
    bottleneck = np.where(use, bott_s, base.bottleneck[:, None])
    n_moves = np.where(use, n_moves, 0)
    with np.errstate(invalid="ignore"):
        feasible = ((base.feasible[:, None]
                     if base.feasible is not None else True)
                    & (bottleneck <= dl))

    # totals + energies of the COMBINED assignment: sequential per-layer
    # loops (padded cells gather type 0 whose padding is exact 0.0)
    l_sel = np.take_along_axis(
        np.broadcast_to(lat_d[:, None], (n_b, n_d) + lat_d.shape[1:]),
        layer_type[:, :, None, :], axis=2)[:, :, 0, :]
    e_sel = np.take_along_axis(
        np.broadcast_to(en_d[:, None], (n_b, n_d) + en_d.shape[1:]),
        layer_type[:, :, None, :], axis=2)[:, :, 0, :]
    total = np.zeros((n_b, n_d))
    energy = np.zeros((n_b, n_d))
    for l in range(n_max):
        total = total + l_sel[:, :, l]
        energy = energy + e_sel[:, :, l]
    # base cells keep base.total bit-for-bit (its per-type prefix-sum
    # order differs from the sequential re-gather by ulps)
    total = np.where(use, total, base.total[:, None])

    return BatchSlackResult(
        base=base, deadlines=np.ascontiguousarray(dl),
        layer_type=layer_type, starts=starts, seg_counts=seg_counts,
        loads=loads, bottleneck=bottleneck, total=total, energy=energy,
        n_moves=n_moves, feasible=feasible)
