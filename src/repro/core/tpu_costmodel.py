"""Analytic per-layer TPU cost model — the paper's Tool, re-targeted.

The paper's simulator counts data movement through RF→GB→DRAM and MACs per
layer under a fixed dataflow; here the hierarchy is VMEM→HBM→ICI and the
"dataflow" is the sharding policy.  For each transformer-family layer we
produce the same three quantities the roofline consumes:

    flops_fwd       — dense matmul work per layer (per chip, after sharding)
    hbm_bytes       — parameter + activation traffic per layer
    ici_bytes       — collective payload implied by the sharding policy

These per-layer latency estimates feed (a) the B&B pipeline-stage
partitioner (exactly the role the Tool's per-layer latencies play in the
paper's Algorithm II), and (b) the sharding-policy DSE in ``autoshard.py``
(the analogue of the paper's GB/array design-space sweep).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from ..configs.base import ModelConfig
from ..launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """How the model is laid out on the mesh (the DSE decision variables)."""

    name: str
    dp: int = 1                 # data-parallel ways (batch)
    tp: int = 1                 # tensor-parallel ways (mlp/heads/experts)
    fsdp: int = 1               # parameter-sharding ways on top of dp
    microbatches: int = 1
    remat: bool = True
    seq_shard: int = 1          # sequence parallelism ways (long context)

    @property
    def chips(self) -> int:
        return self.dp * self.tp


@dataclasses.dataclass
class LayerCost:
    name: str
    flops: float                # per chip
    hbm_bytes: float            # per chip
    ici_bytes: float            # per chip

    @property
    def time_s(self) -> float:
        return max(self.flops / PEAK_FLOPS, self.hbm_bytes / HBM_BW,
                   self.ici_bytes / ICI_BW)


def _attn_layer(cfg: ModelConfig, pol: ShardingPolicy, tokens_per_chip: int,
                seq: int, bytes_per=2) -> Tuple[float, float, float]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    t = tokens_per_chip
    proj = 2.0 * t * d * (h * hd + 2 * kv * hd + h * hd) / pol.tp
    window = cfg.attn_window or seq
    eff = min(seq, window)
    sdpa = 2.0 * t * eff * hd * (h / pol.tp) * 2 / 2   # causal halves
    params = d * (2 * h * hd + 2 * kv * hd) * bytes_per / (pol.tp * pol.fsdp)
    act = t * d * bytes_per * 4
    # fsdp all-gather of the layer's params before use
    ici = params * (pol.fsdp - 1) / max(pol.fsdp, 1) if pol.fsdp > 1 else 0.0
    # tp: all-reduce of the attention output partial sums
    if pol.tp > 1:
        ici += 2.0 * t * d * bytes_per
    return proj + sdpa, params + act, ici


def _mlp_layer(cfg: ModelConfig, pol: ShardingPolicy, tokens_per_chip: int,
               d_ff: int, bytes_per=2) -> Tuple[float, float, float]:
    d = cfg.d_model
    t = tokens_per_chip
    mult = 3 if cfg.act == "swiglu" else 2
    flops = 2.0 * t * d * d_ff * mult / pol.tp
    params = mult * d * d_ff * bytes_per / (pol.tp * pol.fsdp)
    act = t * (d + d_ff / pol.tp) * bytes_per * 2
    ici = params * (pol.fsdp - 1) / max(pol.fsdp, 1) if pol.fsdp > 1 else 0.0
    if pol.tp > 1:
        ici += 2.0 * t * d * bytes_per
    return flops, params + act, ici


def _moe_layer(cfg: ModelConfig, pol: ShardingPolicy, tokens_per_chip: int,
               bytes_per=2) -> Tuple[float, float, float]:
    d, f = cfg.d_model, cfg.d_ff
    t = tokens_per_chip
    mult = 3
    # routed experts: top_k × expert mlp on each token; experts sharded tp-way
    flops = 2.0 * t * d * f * mult * cfg.top_k / 1.0
    flops += 2.0 * t * d * cfg.n_experts            # router
    if cfg.n_shared_experts:
        flops += 2.0 * t * d * f * cfg.n_shared_experts * mult
    if cfg.moe_dense_residual:
        flops += 2.0 * t * d * cfg.dense_residual_ff * mult
    flops /= pol.tp
    params = (cfg.n_experts + cfg.n_shared_experts) * mult * d * f \
        * bytes_per / (pol.tp * pol.fsdp)
    if cfg.moe_dense_residual:
        params += mult * d * cfg.dense_residual_ff * bytes_per / pol.fsdp
    act = t * d * bytes_per * (2 + cfg.top_k)
    # expert-parallel dispatch/combine ≈ all-to-all of top_k token copies
    ici = 2.0 * t * cfg.top_k * d * bytes_per * (pol.tp - 1) / max(pol.tp, 1)
    ici += params * (pol.fsdp - 1) / max(pol.fsdp, 1) if pol.fsdp > 1 else 0.0
    return flops, params + act, ici


def _ssm_layer(cfg: ModelConfig, pol: ShardingPolicy, tokens_per_chip: int,
               bytes_per=2) -> Tuple[float, float, float]:
    d = cfg.d_model
    di = d * cfg.ssm_expand
    n, q = cfg.ssm_state, cfg.ssm_chunk
    t = tokens_per_chip
    proj = 2.0 * t * d * (2 * di + 2 * cfg.ssm_groups * n + cfg.ssm_heads) \
        + 2.0 * t * di * d
    ssd = (2.0 * t * q * n * cfg.ssm_groups          # CB^T within chunk
           + 2.0 * t * q * di                        # L·X
           + 4.0 * t * n * di)                       # states in/out
    flops = (proj + ssd) / pol.tp
    params = (d * (2 * di + 2 * cfg.ssm_groups * n + cfg.ssm_heads)
              + di * d) * bytes_per / (pol.tp * pol.fsdp)
    act = t * (d + di) * bytes_per * 2
    ici = params * (pol.fsdp - 1) / max(pol.fsdp, 1) if pol.fsdp > 1 else 0.0
    if pol.tp > 1:
        ici += 2.0 * t * d * bytes_per
    return flops, params + act, ici


def _lru_layer(cfg: ModelConfig, pol: ShardingPolicy, tokens_per_chip: int,
               bytes_per=2) -> Tuple[float, float, float]:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    t = tokens_per_chip
    flops = (2.0 * t * d * w * 2 + 2.0 * t * w * w * 2
             + 2.0 * t * w * d) / pol.tp
    params = (d * w * 2 + w * w * 2 + w * d) * bytes_per \
        / (pol.tp * pol.fsdp)
    act = t * (d + w) * bytes_per * 2
    ici = params * (pol.fsdp - 1) / max(pol.fsdp, 1) if pol.fsdp > 1 else 0.0
    if pol.tp > 1:
        ici += 2.0 * t * d * bytes_per
    return flops, params + act, ici


def layer_costs(cfg: ModelConfig, pol: ShardingPolicy, *, seq_len: int,
                global_batch: int, training: bool = True
                ) -> List[LayerCost]:
    """Per-layer cost vector — the Tool's per-layer report, TPU edition.

    Training multiplies flops by 3 (fwd+bwd) + 1 more refwd under remat,
    and adds the DP gradient all-reduce amortised over layers.
    """
    tokens_per_chip = seq_len * global_batch // pol.dp
    mult = (4.0 if pol.remat else 3.0) if training else 1.0
    out: List[LayerCost] = []

    def add(name, fhi):
        f, h, i = fhi
        grad_ar = 0.0
        if training and pol.dp > 1:
            # ring all-reduce of this layer's grads across dp
            param_bytes = h  # params dominate h's param share; first order
            grad_ar = 2.0 * param_bytes
        out.append(LayerCost(name, f * mult, h * (2.0 if training else 1.0),
                             i * (2.0 if training else 1.0) + grad_ar))

    for li in range(cfg.n_layers):
        if cfg.family == "ssm":
            add(f"ssm{li}", _ssm_layer(cfg, pol, tokens_per_chip))
            continue
        if cfg.family == "hybrid":
            kind = cfg.block_pattern[li % len(cfg.block_pattern)]
            if kind == "rec":
                add(f"rec{li}", _lru_layer(cfg, pol, tokens_per_chip))
            else:
                add(f"attn{li}", _attn_layer(cfg, pol, tokens_per_chip,
                                             seq_len))
            add(f"mlp{li}", _mlp_layer(cfg, pol, tokens_per_chip, cfg.d_ff))
            continue
        add(f"attn{li}", _attn_layer(cfg, pol, tokens_per_chip, seq_len))
        if cfg.family == "moe":
            add(f"moe{li}", _moe_layer(cfg, pol, tokens_per_chip))
        else:
            add(f"mlp{li}", _mlp_layer(cfg, pol, tokens_per_chip, cfg.d_ff))

    # embedding / unembedding as boundary layers
    t = tokens_per_chip
    emb_flops = 2.0 * t * cfg.d_model * cfg.vocab / pol.tp
    emb_bytes = cfg.vocab * cfg.d_model * 2 / (pol.tp * pol.fsdp)
    out.append(LayerCost("unembed", emb_flops * (3.0 if training else 1.0),
                         emb_bytes + t * cfg.vocab * 2 / pol.tp, 0.0))
    return out


def step_time(cfg: ModelConfig, pol: ShardingPolicy, *, seq_len: int,
              global_batch: int, training: bool = True) -> Dict[str, float]:
    costs = layer_costs(cfg, pol, seq_len=seq_len, global_batch=global_batch,
                        training=training)
    f = sum(c.flops for c in costs)
    h = sum(c.hbm_bytes for c in costs)
    i = sum(c.ici_bytes for c in costs)
    return dict(
        compute_s=f / PEAK_FLOPS, memory_s=h / HBM_BW,
        collective_s=i / ICI_BW,
        step_s=max(f / PEAK_FLOPS, h / HBM_BW, i / ICI_BW),
        flops=f, hbm_bytes=h, ici_bytes=i)
