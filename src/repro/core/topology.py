"""Network-topology IR + builders for the paper's 18 benchmark CNNs.

§II.B.1: the Tool accepts a network as a list of typed layers —
``input / convolution / subsampling (pooling) / depth-convolution /
point-wise convolution`` (+ fully-connected).  Each layer carries the shape
parameters the row-stationary mapper needs: channels, filters, kernel size,
stride, padding and the (propagated) input feature-map size.

The 18 networks named in Tables 1–8 are provided as builders.  Structures
follow the public definitions (Keras Applications); for the two NASNet
variants — whose cell DAGs are enormous — we use a faithful separable-conv
approximation at the published channel/cell counts, which preserves the
per-layer compute/footprint distribution the simulator consumes.  Branch DAGs
(Inception/ResNet/DenseNet) are flattened in topological order: energy is
cumulative (§II.A.1), and the pipeline partitioner (Alg. II) operates on the
flattened layer latency vector exactly as the paper's tables do.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

KIND_INPUT = "input"
KIND_CONV = "conv"
KIND_DW = "depthwise"
KIND_PW = "pointwise"
KIND_POOL = "pool"
KIND_FC = "fc"


@dataclasses.dataclass(frozen=True)
class Layer:
    """One network layer, fully shape-resolved."""

    name: str
    kind: str
    c_in: int       # input channels (C)
    c_out: int      # filters (M); == c_in for pool/depthwise
    k: int          # square kernel size (Kx = Ky)
    stride: int
    pad: int
    h_in: int
    w_in: int

    @property
    def h_out(self) -> int:
        return (self.h_in - self.k + 2 * self.pad) // self.stride + 1

    @property
    def w_out(self) -> int:
        return (self.w_in - self.k + 2 * self.pad) // self.stride + 1

    @property
    def macs(self) -> int:
        """MAC count (Algorithm I loop product)."""
        if self.kind == KIND_POOL or self.kind == KIND_INPUT:
            return 0
        ho, wo = self.h_out, self.w_out
        if self.kind == KIND_DW:
            return self.c_in * ho * wo * self.k * self.k
        return self.c_out * self.c_in * ho * wo * self.k * self.k

    @property
    def ifmap_words(self) -> int:
        return self.c_in * self.h_in * self.w_in

    @property
    def ofmap_words(self) -> int:
        return self.c_out * self.h_out * self.w_out

    @property
    def weight_words(self) -> int:
        if self.kind in (KIND_POOL, KIND_INPUT):
            return 0
        if self.kind == KIND_DW:
            return self.c_in * self.k * self.k
        return self.c_out * self.c_in * self.k * self.k


class NetBuilder:
    """Shape-propagating builder producing a flat ``List[Layer]``."""

    def __init__(self, name: str, input_hw: int = 224, c: int = 3):
        self.name = name
        self.layers: List[Layer] = [
            Layer("input", KIND_INPUT, c, c, 1, 1, 0, input_hw, input_hw)]
        self.h = input_hw
        self.w = input_hw
        self.c = c
        self._n = 0

    # -- primitives ---------------------------------------------------------
    def _add(self, kind: str, m: int, k: int, s: int, p: int) -> None:
        self._n += 1
        lyr = Layer(f"{kind}{self._n}", kind, self.c, m, k, s, p, self.h, self.w)
        self.layers.append(lyr)
        self.h, self.w, self.c = lyr.h_out, lyr.w_out, m

    def conv(self, m: int, k: int = 3, s: int = 1, p: int | None = None):
        self._add(KIND_CONV, m, k, s, k // 2 if p is None else p)
        return self

    def dw(self, k: int = 3, s: int = 1, p: int | None = None):
        self._add(KIND_DW, self.c, k, s, k // 2 if p is None else p)
        return self

    def pw(self, m: int):
        self._add(KIND_PW, m, 1, 1, 0)
        return self

    def sep(self, m: int, k: int = 3, s: int = 1):
        """Depthwise-separable conv = depthwise k×k + pointwise 1×1."""
        return self.dw(k, s).pw(m)

    def pool(self, k: int = 2, s: int | None = None, p: int = 0):
        self._add(KIND_POOL, self.c, k, k if s is None else s, p)
        return self

    def gap(self):
        """Global average pool → 1×1 spatial."""
        self._add(KIND_POOL, self.c, self.h, self.h, 0)
        return self

    def fc(self, n: int):
        # FC == 1×1 conv over a 1×1 map with C=inputs, M=outputs.
        if self.h != 1 or self.w != 1:
            # implicit flatten: fold spatial extent into channels
            self.c, self.h, self.w = self.c * self.h * self.w, 1, 1
        self._add(KIND_FC, n, 1, 1, 0)
        return self

    def branches(self, *fns: Callable[["NetBuilder"], None]):
        """Parallel branches from the current tensor; channel-concat output.

        Layers are appended in branch order (topological flattening)."""
        h0, w0, c0 = self.h, self.w, self.c
        out_c, out_h, out_w = 0, None, None
        for fn in fns:
            self.h, self.w, self.c = h0, w0, c0
            fn(self)
            if out_h is None:
                out_h, out_w = self.h, self.w
            assert (self.h, self.w) == (out_h, out_w), \
                f"branch spatial mismatch in {self.name}"
            out_c += self.c
        self.h, self.w, self.c = out_h, out_w, out_c
        return self

    def set_channels(self, c: int):
        """Channel bookkeeping for residual-add merges (no compute)."""
        self.c = c
        return self

    def build(self) -> List[Layer]:
        return list(self.layers)


# ---------------------------------------------------------------------------
# The 18 benchmark networks (Tables 1–8).
# ---------------------------------------------------------------------------

def alexnet() -> List[Layer]:
    b = NetBuilder("AlexNet", 227)
    b.conv(96, 11, 4, 0).pool(3, 2)
    b.conv(256, 5, 1, 2).pool(3, 2)
    b.conv(384).conv(384).conv(256).pool(3, 2)
    b.fc(4096).fc(4096).fc(1000)
    return b.build()


def _vgg(cfg: Sequence[int | str], name: str) -> List[Layer]:
    b = NetBuilder(name, 224)
    for v in cfg:
        if v == "M":
            b.pool(2, 2)
        else:
            b.conv(int(v), 3)
    b.fc(4096).fc(4096).fc(1000)
    return b.build()


def vgg16() -> List[Layer]:
    return _vgg([64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
                 512, 512, 512, "M", 512, 512, 512, "M"], "VGG16")


def vgg19() -> List[Layer]:
    return _vgg([64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
                 512, 512, 512, 512, "M", 512, 512, 512, 512, "M"], "VGG19")


def _resnet(blocks: Sequence[int], name: str) -> List[Layer]:
    b = NetBuilder(name, 224)
    b.conv(64, 7, 2, 3).pool(3, 2, 1)
    width = 64
    for stage, n in enumerate(blocks):
        for i in range(n):
            s = 2 if (stage > 0 and i == 0) else 1
            b.conv(width, 1, s, 0).conv(width, 3).pw(width * 4)
            b.set_channels(width * 4)   # residual add merge
        width *= 2
    b.gap().fc(1000)
    return b.build()


def resnet50() -> List[Layer]:
    return _resnet([3, 4, 6, 3], "ResNet50")


def resnet50v2() -> List[Layer]:
    return _resnet([3, 4, 6, 3], "ResNet50V2")   # pre-act: same cost shape


def resnet101() -> List[Layer]:
    return _resnet([3, 4, 23, 3], "ResNet101")


def resnet152() -> List[Layer]:
    return _resnet([3, 8, 36, 3], "ResNet152")


def _densenet(blocks: Sequence[int], name: str, growth: int = 32) -> List[Layer]:
    b = NetBuilder(name, 224)
    b.conv(64, 7, 2, 3).pool(3, 2, 1)
    c = 64
    for bi, n in enumerate(blocks):
        for _ in range(n):
            b.set_channels(c)
            b.pw(4 * growth).conv(growth, 3)
            c += growth
        b.set_channels(c)
        if bi != len(blocks) - 1:
            c = c // 2
            b.pw(c).pool(2, 2)          # transition
    b.gap().fc(1000)
    return b.build()


def densenet121() -> List[Layer]:
    return _densenet([6, 12, 24, 16], "DenseNet121")


def densenet169() -> List[Layer]:
    return _densenet([6, 12, 32, 32], "DenseNet169")


def densenet201() -> List[Layer]:
    return _densenet([6, 12, 48, 32], "DenseNet201")


def googlenet() -> List[Layer]:
    b = NetBuilder("GoogleNet", 224)
    b.conv(64, 7, 2, 3).pool(3, 2, 1).pw(64).conv(192, 3).pool(3, 2, 1)

    def inception(bld, c1, c3r, c3, c5r, c5, cp):
        bld.branches(
            lambda x: x.pw(c1),
            lambda x: x.pw(c3r).conv(c3, 3),
            lambda x: x.pw(c5r).conv(c5, 5),
            lambda x: x.pool(3, 1, 1).pw(cp),
        )

    inception(b, 64, 96, 128, 16, 32, 32)
    inception(b, 128, 128, 192, 32, 96, 64)
    b.pool(3, 2, 1)
    inception(b, 192, 96, 208, 16, 48, 64)
    inception(b, 160, 112, 224, 24, 64, 64)
    inception(b, 128, 128, 256, 24, 64, 64)
    inception(b, 112, 144, 288, 32, 64, 64)
    inception(b, 256, 160, 320, 32, 128, 128)
    b.pool(3, 2, 1)
    inception(b, 256, 160, 320, 32, 128, 128)
    inception(b, 384, 192, 384, 48, 128, 128)
    b.gap().fc(1000)
    return b.build()


def inception_v3() -> List[Layer]:
    b = NetBuilder("InceptionV3", 299)
    b.conv(32, 3, 2, 0).conv(32, 3, 1, 0).conv(64, 3, 1, 1).pool(3, 2)
    b.conv(80, 1, 1, 0).conv(192, 3, 1, 0).pool(3, 2)

    def mixed5(bld, cp):   # 35×35 modules
        bld.branches(
            lambda x: x.pw(64),
            lambda x: x.pw(48).conv(64, 5),
            lambda x: x.pw(64).conv(96, 3).conv(96, 3),
            lambda x: x.pool(3, 1, 1).pw(cp))

    for cp in (32, 64, 64):
        mixed5(b, cp)
    # reduction A
    b.branches(
        lambda x: x.conv(384, 3, 2, 0),
        lambda x: x.pw(64).conv(96, 3).conv(96, 3, 2, 0),
        lambda x: x.pool(3, 2))

    def mixed6(bld, c7):   # 17×17 factorized-7 modules
        bld.branches(
            lambda x: x.pw(192),
            lambda x: x.pw(c7).conv(c7, 7, p=3).conv(192, 7, p=3),
            lambda x: (x.pw(c7).conv(c7, 7, p=3).conv(c7, 7, p=3)
                       .conv(c7, 7, p=3).conv(192, 7, p=3)),
            lambda x: x.pool(3, 1, 1).pw(192))

    for c7 in (128, 160, 160, 192):
        mixed6(b, c7)
    # reduction B
    b.branches(
        lambda x: x.pw(192).conv(320, 3, 2, 0),
        lambda x: x.pw(192).conv(192, 7, p=3).conv(192, 3, 2, 0),
        lambda x: x.pool(3, 2))

    def mixed7(bld):       # 8×8 modules
        bld.branches(
            lambda x: x.pw(320),
            lambda x: x.pw(384).conv(384, 3),
            lambda x: x.pw(448).conv(384, 3).conv(384, 3),
            lambda x: x.pool(3, 1, 1).pw(192))

    mixed7(b)
    mixed7(b)
    b.gap().fc(1000)
    return b.build()


def inception_resnet_v2() -> List[Layer]:
    b = NetBuilder("InceptionResNetV2", 299)
    b.conv(32, 3, 2, 0).conv(32, 3, 1, 0).conv(64, 3).pool(3, 2)
    b.conv(80, 1, 1, 0).conv(192, 3, 1, 0).pool(3, 2)
    # stem mixed
    b.branches(
        lambda x: x.pw(96),
        lambda x: x.pw(48).conv(64, 5),
        lambda x: x.pw(64).conv(96, 3).conv(96, 3),
        lambda x: x.pool(3, 1, 1).pw(64))
    c_a = b.c  # 320
    for _ in range(10):                       # block35 ×10 (residual)
        b.branches(
            lambda x: x.pw(32),
            lambda x: x.pw(32).conv(32, 3),
            lambda x: x.pw(32).conv(48, 3).conv(64, 3))
        b.pw(c_a).set_channels(c_a)
    # reduction A
    b.branches(
        lambda x: x.conv(384, 3, 2, 0),
        lambda x: x.pw(256).conv(256, 3).conv(384, 3, 2, 0),
        lambda x: x.pool(3, 2))
    c_b = b.c  # 1088
    for _ in range(20):                       # block17 ×20
        b.branches(
            lambda x: x.pw(192),
            lambda x: x.pw(128).conv(160, 7, p=3).conv(192, 7, p=3))
        b.pw(c_b).set_channels(c_b)
    # reduction B
    b.branches(
        lambda x: x.pw(256).conv(384, 3, 2, 0),
        lambda x: x.pw(256).conv(288, 3, 2, 0),
        lambda x: x.pw(256).conv(288, 3).conv(320, 3, 2, 0),
        lambda x: x.pool(3, 2))
    c_c = b.c  # 2080
    for _ in range(10):                       # block8 ×10
        b.branches(
            lambda x: x.pw(192),
            lambda x: x.pw(192).conv(224, 3).conv(256, 3))
        b.pw(c_c).set_channels(c_c)
    b.pw(1536).gap().fc(1000)
    return b.build()


def mobilenet() -> List[Layer]:
    b = NetBuilder("MobileNet", 224)
    b.conv(32, 3, 2)
    plan = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
            (512, 1), (512, 1), (512, 1), (512, 1), (512, 1),
            (1024, 2), (1024, 1)]
    for m, s in plan:
        b.sep(m, 3, s)
    b.gap().fc(1000)
    return b.build()


def mobilenet_v2() -> List[Layer]:
    b = NetBuilder("MobileNetV2", 224)
    b.conv(32, 3, 2)
    # (expansion t, out c, repeats n, stride s)
    plan = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
            (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    for t, c, n, s in plan:
        for i in range(n):
            hidden = b.c * t
            if t != 1:
                b.pw(hidden)
            b.dw(3, s if i == 0 else 1).pw(c)
    b.pw(1280).gap().fc(1000)
    return b.build()


def xception() -> List[Layer]:
    b = NetBuilder("Xception", 299)
    b.conv(32, 3, 2, 0).conv(64, 3, 1, 0)
    for m in (128, 256, 728):                 # entry flow
        b.sep(m).sep(m).pool(3, 2, 1)
    for _ in range(8):                        # middle flow
        b.sep(728).sep(728).sep(728)
    b.sep(728).sep(1024).pool(3, 2, 1)        # exit flow
    b.sep(1536).sep(2048).gap().fc(1000)
    return b.build()


def _nasnet(name: str, stem: int, filters: int, cells_per_stage: int,
            penultimate: int) -> List[Layer]:
    """Separable-conv approximation of the NASNet-A cell stacks.

    Each normal cell ≈ 5 separable ops (3×3 / 5×5) at the stage filter count;
    reduction cells halve spatial dims and double filters — matching the
    published filter schedule (Mobile: 12 cells @ N=4, penultimate 1056;
    Large: 18 cells @ N=6, penultimate 4032).
    """
    b = NetBuilder(name, 331 if name.endswith("Large") else 224)
    b.conv(stem, 3, 2, 0)
    # two stem reduction cells (spatial /4) before the first stack
    b.sep(filters // 2, 5, 2).sep(filters // 2, 3, 1)
    b.sep(filters, 5, 2).sep(filters, 3, 1)
    f = filters
    for stage in range(3):
        if stage > 0:
            b.sep(f, 5, 2).sep(f, 3, 1)       # reduction cell
        for _ in range(cells_per_stage):      # normal cells
            b.sep(f, 5).sep(f, 3).sep(f, 3).sep(f, 5).sep(f, 3)
        f *= 2
    b.pw(penultimate).gap().fc(1000)
    return b.build()


def nasnet_mobile() -> List[Layer]:
    return _nasnet("NASNetMobile", 32, 44, 4, 1056)


def nasnet_large() -> List[Layer]:
    return _nasnet("NASNetLarge", 96, 168, 6, 4032)


NETWORKS: Dict[str, Callable[[], List[Layer]]] = {
    "AlexNet": alexnet,
    "VGG16": vgg16,
    "VGG19": vgg19,
    "GoogleNet": googlenet,
    "InceptionV3": inception_v3,
    "InceptionResNetV2": inception_resnet_v2,
    "ResNet50": resnet50,
    "ResNet50V2": resnet50v2,
    "ResNet101": resnet101,
    "ResNet152": resnet152,
    "DenseNet121": densenet121,
    "DenseNet169": densenet169,
    "DenseNet201": densenet201,
    "MobileNet": mobilenet,
    "MobileNetV2": mobilenet_v2,
    "NASNetMobile": nasnet_mobile,
    "NASNetLarge": nasnet_large,
    "Xception": xception,
}

# The two heterogeneous categories of §IV (Table 5/6 discussion).
CATEGORY_1 = ("AlexNet", "DenseNet121", "DenseNet169", "DenseNet201",
              "ResNet50", "ResNet50V2", "ResNet101", "ResNet152")
CATEGORY_2 = ("VGG16", "VGG19", "GoogleNet", "MobileNet", "MobileNetV2",
              "NASNetLarge", "NASNetMobile", "Xception")
CATEGORY_EITHER = ("InceptionResNetV2", "InceptionV3")


def get_network(name: str) -> List[Layer]:
    return NETWORKS[name]()


def compute_layers(layers: Sequence[Layer]) -> List[Layer]:
    """Layers that perform MACs (what Alg. II distributes)."""
    return [l for l in layers if l.macs > 0]
