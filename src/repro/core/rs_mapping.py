"""Row-stationary spatial mapping (Eyeriss-style) for the simulator.

§II of the paper adopts the row-stationary (RS) dataflow [41]: every PE runs a
1-D convolution of one filter row against one ifmap row, producing one psum
row.  A *PE set* of (Ky filter rows) × (Oy_pass output rows) computes a 2-D
convolution plane; the physical array replicates PE sets vertically (channel
accumulation first — psums add in-array — then extra filters) and horizontally
(extra filters once all output rows fit).

"Processing capacity" in the paper = the number of ifmap channels the array
can take per pass (``cap_c`` here); Observation 2's breakpoints come from the
per-pass ifmap working set ``W_ifmap = cap_c · Ix · ((Oy_pass−1)·stride+Ky)``
crossing ``GB_ifmap``; Observation 1's from the per-pass psum working set
``W_psum = cap_m · Ox · Oy_pass`` crossing ``GB_psum``.

All formulas are written against an array-API module ``xp`` (numpy or
jax.numpy) and broadcast over arbitrary leading axes, so the same code path
serves the scalar per-layer report and the fully vectorised design-space
sweep (configs × layers in one shot).

jit-safety audit (the batched DSE engine traces this module):

* no data-dependent Python control flow — the only ``if`` is on
  ``gb_ifmap_words is None``, which is static at trace time;
* every op is an ``xp`` ufunc (``where`` / ``minimum`` / ``floor_divide``),
  so numpy and the jitted jax path produce bit-identical graphs;
* all quantities are exact in float64: the largest intermediate (layer MACs,
  ~1e10) is far below 2^53, so ``floor_divide`` on floats is exact and the
  numpy↔jax parity holds to machine epsilon.
"""

from __future__ import annotations

from typing import Any, Dict


def _fdiv(xp, a, b):
    return xp.floor_divide(a, b) if hasattr(xp, "floor_divide") else a // b


def _cdiv(xp, a, b):
    return -_fdiv(xp, -a, b)


def mapping(xp, *,
            rows, cols,                 # physical array [R, C]
            c_ch, m, ky, kx, stride,    # layer loop bounds
            ix, iy, oy, ox,             # ifmap row length/height, output rows/cols
            is_acc, is_dw, is_pool,     # layer-kind flags (0/1 arrays)
            gb_ifmap_words=None,
            rf_ifmap_words=12, rf_weight_words=224, rf_psum_words=16):
    """Return the RS mapping quantities for (config × layer) grids.

    All arguments are broadcastable integer arrays.  Output dict values are
    arrays of the broadcast shape.

    Spatial mapping: PE sets of (ky × oy_pass) PEs; vertical replication over
    channels (in-array psum accumulation) then filters; horizontal leftover
    replicates filters.  Temporal mapping (Eyeriss RF multiplexing): each PE
    interleaves ``q`` channels and ``p`` filters out of its scratch pads
    (weight RF holds p·q filter rows, psum RF holds p running rows), so the
    filters in flight per pass are ``cap_m = spatial · p`` and channels per
    accumulation round are ``cap_c = spatial · q``.
    """
    one = xp.ones_like(rows * c_ch)

    # A filter row taller than the array folds serially over ky_serial passes.
    ky_serial = _cdiv(xp, ky, rows)
    ky_map = _cdiv(xp, ky, ky_serial)            # PE-set height actually used

    fold = xp.maximum(one, _fdiv(xp, rows, ky_map))   # vertical PE-set slots

    oy_pass = xp.minimum(oy, cols)                    # output rows per pass
    col_rep = xp.maximum(one, _fdiv(xp, cols, oy_pass))  # leftover cols → filters

    # Vertical replication covers the remaining output-row blocks FIRST
    # ("processing capacity refers to the number of rows (or channels) of the
    # input image that can be loaded to the array", §III): only when the
    # array out-sizes the feature map does multi-channel processing start.
    sets_rows = xp.minimum(_cdiv(xp, oy, oy_pass), fold)
    fold2 = xp.maximum(one, _fdiv(xp, fold, sets_rows))

    # Channel accumulation (conv / pointwise / fc): psums of cap_c channels
    # add in-array.  Depthwise / pool: channels are independent planes.
    cap_c_sp = xp.where(is_acc, xp.minimum(c_ch, fold2), one)
    fold_m = xp.maximum(one, _fdiv(xp, fold2, cap_c_sp))  # leftover rows → filters

    plane_count = xp.where(is_acc, m, c_ch)
    cap_m_sp = xp.maximum(
        xp.minimum(plane_count, fold_m * col_rep), one)

    # --- RF temporal multiplexing (filters) ----------------------------------
    # Each PE interleaves p filters out of its weight/psum scratch pads
    # (Eyeriss: p = 16); channels are accumulated spatially only.
    q = one
    cap_c = cap_c_sp
    p_rf = xp.maximum(one, xp.minimum(
        rf_psum_words * one, _fdiv(xp, rf_weight_words * one, kx)))
    p = xp.minimum(p_rf, _cdiv(xp, plane_count, cap_m_sp))
    cap_m = xp.maximum(xp.minimum(plane_count, cap_m_sp * p), one)

    # --- GB_ifmap gating of the processing capacity (Observation 2) ---------
    # "If the GB_ifmap capacity is not sufficient to accommodate all the
    # channels the array needs for processing, [...] extra energy [is]
    # required to write the result of the processed channels back to the
    # buffer and re-read it to add it to those just processed" (§III).
    # Multi-channel processing buffers whole channel planes; the channels
    # feedable per accumulation round are capped by how many planes fit in
    # GB_ifmap.  Fewer channels per round ⇒ more rounds ⇒ more psum RMW
    # traffic.  (Single-channel row streaming needs no plane buffering, so
    # the gate never pushes capacity below one.)
    if gb_ifmap_words is not None:
        ch_fit = xp.maximum(one, _fdiv(xp, gb_ifmap_words, ix * iy))
        cap_c = xp.minimum(cap_c, ch_fit)
        cap_m = xp.where(is_acc, cap_m, xp.minimum(cap_m, ch_fit))
    ifmap_rows = (oy_pass - 1) * stride + ky

    n_c = xp.where(is_acc, _cdiv(xp, c_ch, cap_c), one)   # channel rounds
    n_m = _cdiv(xp, plane_count, cap_m)                   # filter blocks
    n_oy = _cdiv(xp, oy, oy_pass * sets_rows)             # output-row blocks

    # Per-pass working sets (words).
    ch_in_flight = xp.where(is_acc, cap_c, cap_m)
    w_ifmap = ch_in_flight * ix * ifmap_rows
    # psums persist in GB as full output planes for the filters in flight
    # across the n_c channel-accumulation rounds (loop order of Alg. I:
    # filters outer, channels next, spatial inner).
    w_psum = cap_m * ox * oy
    w_weight = cap_m * xp.where(is_acc, cap_c, one) * kx * ky

    # GB-gated capacity below the spatial capacity idles PEs (Obs. 2:
    # "reduced GB_ifmap storage space, in addition to reducing array
    # utilization, ...").
    cap_c_sp_eff = xp.minimum(cap_c_sp, cap_c)
    cap_m_sp_eff = xp.minimum(cap_m_sp, cap_m)
    active_pes = ky_map * oy_pass * sets_rows * xp.where(
        is_acc, cap_c_sp_eff * cap_m_sp_eff, cap_m_sp_eff)
    active_pes = xp.minimum(active_pes, rows * cols)

    return dict(
        ky_serial=ky_serial, ky_map=ky_map, fold=fold, cap_c=cap_c,
        fold_m=fold_m, oy_pass=oy_pass, col_rep=col_rep, cap_m=cap_m,
        n_c=n_c, n_m=n_m, n_oy=n_oy, w_ifmap=w_ifmap, w_psum=w_psum,
        w_weight=w_weight, active_pes=active_pes,
        ch_in_flight=ch_in_flight, q=q, p=p,
    )


def layer_struct(xp, layers) -> Dict[str, Any]:
    """Struct-of-arrays view of a ``List[Layer]`` for the vectorised path."""
    from .topology import KIND_CONV, KIND_DW, KIND_FC, KIND_POOL, KIND_PW

    def arr(fn, dtype=None):
        a = xp.asarray([fn(l) for l in layers])
        return a if dtype is None else a.astype(dtype)

    return dict(
        c_ch=arr(lambda l: l.c_in),
        m=arr(lambda l: l.c_out),
        ky=arr(lambda l: l.k),
        kx=arr(lambda l: l.k),
        stride=arr(lambda l: l.stride),
        ix=arr(lambda l: l.w_in),
        iy=arr(lambda l: l.h_in),
        oy=arr(lambda l: l.h_out),
        ox=arr(lambda l: l.w_out),
        macs=arr(lambda l: l.macs),
        weight_words=arr(lambda l: l.weight_words),
        ifmap_words=arr(lambda l: l.ifmap_words),
        ofmap_words=arr(lambda l: l.ofmap_words),
        is_acc=arr(lambda l: l.kind in (KIND_CONV, KIND_PW, KIND_FC)),
        is_dw=arr(lambda l: l.kind == KIND_DW),
        is_pool=arr(lambda l: l.kind == KIND_POOL),
    )
