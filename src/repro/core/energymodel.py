"""The Tool (§II): first-order energy & latency estimation of an array-based
accelerator executing a network under the row-stationary dataflow.

Energy is cumulative (§II.A.1): every data movement at every hierarchy level
(eq. (1)) and every MAC is counted.  Latency (§II.A.2) follows the paper's
controller assumption — *"processing does not start unless the last processing
element responsible for the pass receives its data"* (Fig. 4) — so per-pass
time is delivery + compute + writeback, serialised with the DRAM interface
time (latency is **not** cumulative across hierarchy levels in general, but
this controller gives the serial composition the paper describes).

The two mechanisms behind the paper's Observations are modelled explicitly:

* **psum spill** (Obs. 1/3): with ``n_c`` channel-accumulation rounds, the
  per-pass psum working set is read-modify-written ``n_c−1`` times.  The
  fraction exceeding ``GB_psum`` travels to off-chip DRAM instead of the
  global buffer.
* **ifmap re-fetch** (Obs. 2/4): when the per-pass ifmap working set exceeds
  ``GB_ifmap`` the block cannot persist across the ``n_m`` filter blocks and
  is re-read from DRAM for each of them.

Global-buffer access energy/latency scales with the configured partition
capacity (CACTI-like √capacity), so oversizing a buffer costs energy — the
right-hand tails of Fig. 5/6.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence

import numpy as np

from .accelerator import AcceleratorConfig
from . import rs_mapping
from .topology import Layer

_POOL_OP_ENERGY = 0.2      # a pooling compare/add relative to a MAC


@dataclasses.dataclass(frozen=True)
class LayerReport:
    """Per-layer outputs of the Tool (§II.B.2)."""

    name: str
    energy: float            # pJ
    latency: float           # ns
    macs: float
    dram_reads: float
    dram_writes: float
    gb_reads: float
    gb_writes: float
    rf_accesses: float
    utilization: float       # active PEs / total PEs (compute-time weighted)
    mem_time: float          # ns spent on the memory hierarchy
    array_time: float        # ns spent computing in the array
    psum_spilled: float      # words of psum traffic that went to DRAM
    ifmap_refetched: float   # extra ifmap words re-read from DRAM


@dataclasses.dataclass(frozen=True)
class NetworkReport:
    name: str
    energy: float
    latency: float
    layers: List[LayerReport]

    @property
    def edp(self) -> float:
        return self.energy * self.latency

    @property
    def layer_latencies(self) -> np.ndarray:
        return np.array([l.latency for l in self.layers])

    @property
    def layer_energies(self) -> np.ndarray:
        return np.array([l.energy for l in self.layers])


def _counts(xp, cfg: Dict[str, Any], lay: Dict[str, Any]) -> Dict[str, Any]:
    """Access counts + time terms; broadcast over (configs × layers)."""
    mp = rs_mapping.mapping(
        xp,
        rows=cfg["rows"], cols=cfg["cols"],
        c_ch=lay["c_ch"], m=lay["m"], ky=lay["ky"], kx=lay["kx"],
        stride=lay["stride"], ix=lay["ix"], iy=lay["iy"],
        oy=lay["oy"], ox=lay["ox"],
        is_acc=lay["is_acc"], is_dw=lay["is_dw"], is_pool=lay["is_pool"],
        gb_ifmap_words=cfg["gb_ifmap_words"],
        rf_ifmap_words=cfg["rf_ifmap_words"],
        rf_weight_words=cfg["rf_weight_words"],
        rf_psum_words=cfg["rf_psum_words"])

    n_c, n_m, n_oy = mp["n_c"], mp["n_m"], mp["n_oy"]
    w_psum = mp["w_psum"]
    ky_serial = mp["ky_serial"]

    ifmap_vol = lay["ifmap_words"]
    ofmap = lay["ofmap_words"]
    weights = lay["weight_words"]
    macs = lay["macs"]
    is_pool = lay["is_pool"]
    pool_ops = lay["c_ch"] * lay["ox"] * lay["oy"] * lay["kx"] * lay["ky"]

    # ---- ifmap traffic (Observation 2) -------------------------------------
    # Channel rounds partition the channel set, so every ifmap word streams
    # DRAM→GB exactly once (compulsory traffic — the Eyeriss-RS reuse ideal).
    # GB_ifmap capacity acts through the mapping instead: fewer channels held
    # per round ⇒ more accumulation rounds ⇒ more psum RMW traffic below.
    # Within a round the resident channel planes are re-delivered GB→array
    # for each of the n_m filter blocks (cheap on-chip reads).
    ifmap_dram_reads = ifmap_vol * xp.ones_like(n_m)
    ifmap_refetched = ifmap_vol * 0.0
    gb_ifmap_writes = ifmap_dram_reads                 # DRAM → GB
    gb_ifmap_reads = ifmap_vol * xp.where(lay["is_acc"], n_m, 1)

    # ---- weight traffic ---------------------------------------------------
    # GB_weight is provisioned for the in-flight working set (§III); weights
    # stream from DRAM once, land in the PE weight RFs once per use phase and
    # are reused across the spatial loop from there.
    wt_dram_reads = weights
    gb_wt_writes = weights
    gb_wt_reads = weights * ky_serial

    # ---- psum traffic (Observation 1) --------------------------------------
    # The psum planes of the in-flight filter block (w_psum = cap_m·Ox·Oy
    # words) are read-modify-written on every channel-accumulation round
    # after the first; the slice exceeding GB_psum makes the round trip to
    # off-chip DRAM instead (write + re-read, §III).
    inter_rounds = xp.maximum(n_c * ky_serial - 1, 0)
    overflow = xp.maximum(w_psum - cfg["gb_psum_words"], 0.0)
    held = xp.minimum(w_psum, cfg["gb_psum_words"] * xp.ones_like(w_psum))
    psum_dram_writes = inter_rounds * overflow
    psum_dram_reads = psum_dram_writes
    psum_gb_inter = inter_rounds * held
    gb_psum_writes = psum_gb_inter + ofmap             # + final results
    gb_psum_reads = psum_gb_inter + ofmap              # reload + writeback
    ofmap_dram_writes = ofmap

    # ---- totals -------------------------------------------------------------
    dram_reads = ifmap_dram_reads + wt_dram_reads + psum_dram_reads
    dram_writes = ofmap_dram_writes + psum_dram_writes
    gb_reads = gb_ifmap_reads + gb_wt_reads + gb_psum_reads
    gb_writes = gb_ifmap_writes + gb_wt_writes + gb_psum_writes

    words_into_array = gb_ifmap_reads + gb_wt_reads + psum_gb_inter + psum_dram_reads
    words_out_of_array = gb_psum_writes + psum_dram_writes

    ops = xp.where(is_pool, pool_ops, macs)
    rf_accesses = (4.0 * ops) + words_into_array + words_out_of_array

    return dict(
        mp=mp, ops=ops, macs=macs, pool_ops=pool_ops,
        dram_reads=dram_reads, dram_writes=dram_writes,
        gb_ifmap_reads=gb_ifmap_reads, gb_ifmap_writes=gb_ifmap_writes,
        gb_wt_reads=gb_wt_reads, gb_wt_writes=gb_wt_writes,
        gb_psum_reads=gb_psum_reads, gb_psum_writes=gb_psum_writes,
        gb_reads=gb_reads, gb_writes=gb_writes,
        rf_accesses=rf_accesses,
        words_into_array=words_into_array,
        words_out_of_array=words_out_of_array,
        psum_spilled=psum_dram_writes + psum_dram_reads,
        ifmap_refetched=ifmap_refetched,
    )


def _energy_latency(xp, cfg: Dict[str, Any], lay: Dict[str, Any],
                    ct: Dict[str, Any]) -> Dict[str, Any]:
    e = cfg  # per-access constants pre-flattened into the cfg dict
    mp = ct["mp"]

    gb_e_if, gb_e_ps, gb_e_wt = e["gb_e_ifmap"], e["gb_e_psum"], e["gb_e_wt"]
    mac_e = xp.where(lay["is_pool"], e["e_mac"] * _POOL_OP_ENERGY, e["e_mac"])

    noc_hops = (cfg["rows"] + cfg["cols"]) / 2.0
    # idle PEs still burn clock/leakage power for the whole layer occupancy
    idle_cycles = (cfg["rows"] * cfg["cols"] - mp["active_pes"]) \
        * ct["ops"] / mp["active_pes"]
    energy = (
        ct["dram_reads"] * e["e_dram_r"] + ct["dram_writes"] * e["e_dram_w"]
        + (ct["gb_ifmap_reads"] + ct["gb_ifmap_writes"]) * gb_e_if
        + (ct["gb_psum_reads"] + ct["gb_psum_writes"]) * gb_e_ps
        + (ct["gb_wt_reads"] + ct["gb_wt_writes"]) * gb_e_wt
        + ct["rf_accesses"] * e["e_rf"]
        + ct["ops"] * mac_e
        + idle_cycles * e["e_pe_idle"]
        + (ct["words_into_array"] + ct["words_out_of_array"])
        * e["e_noc_hop"] * noc_hops
    )

    # Latency: GB→array delivery is paced by the NoC *and* by the access time
    # of the partition it drains (bigger buffer ⇒ slower access, Fig. 9).
    lat_if = e["gb_t_ifmap"] / e["gb_t_base"]
    lat_ps = e["gb_t_psum"] / e["gb_t_base"]
    delivery_cy = (
        (ct["gb_ifmap_reads"] + ct["gb_wt_reads"]) * lat_if
        + (ct["gb_psum_reads"]) * lat_ps
    ) / e["noc_wpc"]
    writeback_cy = ct["words_out_of_array"] * lat_ps / e["noc_wpc"]
    compute_cy = ct["ops"] / mp["active_pes"] * e["mac_t_cy"]
    array_cy = delivery_cy + compute_cy + writeback_cy

    dram_words = ct["dram_reads"] + ct["dram_writes"]
    dram_cy = dram_words / e["dram_wpc"]

    array_time = array_cy * e["cycle_ns"]
    mem_time = dram_cy * e["cycle_ns"] + (delivery_cy + writeback_cy) * e["cycle_ns"]
    latency = (array_cy + dram_cy) * e["cycle_ns"]

    total_pes = cfg["rows"] * cfg["cols"]
    utilization = xp.where(
        array_cy > 0, (compute_cy / xp.maximum(array_cy, 1e-30))
        * mp["active_pes"] / total_pes, 0.0)

    return dict(energy=energy, latency=latency, array_time=array_time,
                mem_time=mem_time, utilization=utilization)


def _cfg_struct(xp, cfg: AcceleratorConfig) -> Dict[str, Any]:
    et = cfg.energy
    return dict(
        rows=xp.asarray(cfg.array_rows), cols=xp.asarray(cfg.array_cols),
        gb_ifmap_words=xp.asarray(cfg.gb_ifmap_words()),
        gb_psum_words=xp.asarray(cfg.gb_psum_words()),
        rf_ifmap_words=xp.asarray(cfg.rf_ifmap_words),
        rf_weight_words=xp.asarray(cfg.rf_weight_words),
        rf_psum_words=xp.asarray(cfg.rf_psum_words),
        e_rf=xp.asarray(et.rf_read),
        e_dram_r=xp.asarray(et.dram_read), e_dram_w=xp.asarray(et.dram_write),
        e_mac=xp.asarray(et.mac), e_noc_hop=xp.asarray(et.noc_hop),
        e_pe_idle=xp.asarray(et.pe_idle),
        gb_e_ifmap=xp.asarray(et.gb_energy(cfg.gb_ifmap_kb)),
        gb_e_psum=xp.asarray(et.gb_energy(cfg.gb_psum_kb)),
        gb_e_wt=xp.asarray(et.gb_energy(cfg.gb_weight_kb)),
        gb_t_ifmap=xp.asarray(et.gb_latency(cfg.gb_ifmap_kb)),
        gb_t_psum=xp.asarray(et.gb_latency(cfg.gb_psum_kb)),
        gb_t_base=xp.asarray(et.gb_t),
        noc_wpc=xp.asarray(cfg.noc_words_per_cycle),
        dram_wpc=xp.asarray(cfg.dram_words_per_cycle),
        mac_t_cy=xp.asarray(et.mac_t / cfg.cycle_ns),
        cycle_ns=xp.asarray(cfg.cycle_ns),
    )


def simulate_network(cfg: AcceleratorConfig, layers: Sequence[Layer],
                     name: str = "net") -> NetworkReport:
    """Scalar (per-network, per-config) entry point → full layer reports."""
    xp = np
    compute = [l for l in layers if l.kind != "input"]
    lay = rs_mapping.layer_struct(xp, compute)
    lay = {k: np.asarray(v, dtype=np.float64) for k, v in lay.items()}
    cfgs = _cfg_struct(xp, cfg)
    cfgs = {k: v.astype(np.float64) for k, v in cfgs.items()}

    ct = _counts(xp, cfgs, lay)
    el = _energy_latency(xp, cfgs, lay, ct)

    reports = []
    for i, l in enumerate(compute):
        reports.append(LayerReport(
            name=l.name,
            energy=float(el["energy"][i]), latency=float(el["latency"][i]),
            macs=float(lay["macs"][i]),
            dram_reads=float(ct["dram_reads"][i]),
            dram_writes=float(ct["dram_writes"][i]),
            gb_reads=float(ct["gb_reads"][i]), gb_writes=float(ct["gb_writes"][i]),
            rf_accesses=float(ct["rf_accesses"][i]),
            utilization=float(el["utilization"][i]),
            mem_time=float(el["mem_time"][i]),
            array_time=float(el["array_time"][i]),
            psum_spilled=float(ct["psum_spilled"][i]),
            ifmap_refetched=float(ct["ifmap_refetched"][i]),
        ))
    return NetworkReport(
        name=name,
        energy=float(el["energy"].sum()),
        latency=float(el["latency"].sum()),
        layers=reports)


def simulate_grid(configs: Sequence[AcceleratorConfig],
                  layers: Sequence[Layer], use_jax: bool = False):
    """Vectorised sweep: returns (energy, latency) arrays of shape [n_cfg].

    ``use_jax=True`` evaluates the whole design space inside one jitted
    program under 64-bit mode (counts exceed float32's integer range).
    """
    compute = [l for l in layers if l.kind != "input"]

    if use_jax:
        import jax
        import jax.numpy as jnp
        with jax.enable_x64(True):
            lay = rs_mapping.layer_struct(np, compute)
            lay = {k: jnp.asarray(np.asarray(v, dtype=np.float64))[None, :]
                   for k, v in lay.items()}
            cfg_rows = [_cfg_struct(np, c) for c in configs]
            cfgs = {k: jnp.asarray(
                np.stack([np.float64(c[k]) for c in cfg_rows]))[:, None]
                for k in cfg_rows[0]}

            @jax.jit
            def run(cfgs, lay):
                ct = _counts(jnp, cfgs, lay)
                el = _energy_latency(jnp, cfgs, lay, ct)
                return el["energy"].sum(-1), el["latency"].sum(-1)

            e, t = run(cfgs, lay)
            return np.asarray(e), np.asarray(t)

    lay = rs_mapping.layer_struct(np, compute)
    lay = {k: np.asarray(v, dtype=np.float64)[None, :] for k, v in lay.items()}
    cfg_rows = [_cfg_struct(np, c) for c in configs]
    cfgs = {k: np.stack([np.float64(c[k]) for c in cfg_rows])[:, None]
            for k in cfg_rows[0]}
    ct = _counts(np, cfgs, lay)
    el = _energy_latency(np, cfgs, lay, ct)
    return el["energy"].sum(-1), el["latency"].sum(-1)
