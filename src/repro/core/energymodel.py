"""The Tool (§II): first-order energy & latency estimation of an array-based
accelerator executing a network under the row-stationary dataflow.

Energy is cumulative (§II.A.1): every data movement at every hierarchy level
(eq. (1)) and every MAC is counted.  Latency (§II.A.2) follows the paper's
controller assumption — *"processing does not start unless the last processing
element responsible for the pass receives its data"* (Fig. 4) — so per-pass
time is delivery + compute + writeback, serialised with the DRAM interface
time (latency is **not** cumulative across hierarchy levels in general, but
this controller gives the serial composition the paper describes).

The two mechanisms behind the paper's Observations are modelled explicitly:

* **psum spill** (Obs. 1/3): with ``n_c`` channel-accumulation rounds, the
  per-pass psum working set is read-modify-written ``n_c−1`` times.  The
  fraction exceeding ``GB_psum`` travels to off-chip DRAM instead of the
  global buffer.
* **ifmap re-fetch** (Obs. 2/4): when the per-pass ifmap working set exceeds
  ``GB_ifmap`` the block cannot persist across the ``n_m`` filter blocks and
  is re-read from DRAM for each of them.

Global-buffer access energy/latency scales with the configured partition
capacity (CACTI-like √capacity), so oversizing a buffer costs energy — the
right-hand tails of Fig. 5/6.

Array-shape conventions of the batched engine (see also
``docs/architecture.md``):

* Struct-of-arrays everywhere: a "config" is a dict of equal-length float64
  columns (:class:`repro.core.accelerator.ConfigGrid.fields`), a "layer
  struct" a dict of per-layer columns (``rs_mapping.layer_struct``).
* The heavy stage broadcasts ``[n_unique, 1]`` config columns against
  ``[1, n_layers]`` layer columns → ``[n_unique, n_layers]`` tiles, where
  ``n_unique`` is the **two-level dedup** of the grid: the RS mapping runs
  on the mapping-unique rows (``_MAPPING_COLUMNS``), access counts on the
  count-unique rows (``_COUNT_COLUMNS``), and ``inv`` / ``inv_m`` int32
  indices gather back out (grid point → count row → mapping row).
* All networks share ONE concatenated, bucket-padded layer axis;
  ``segments`` is the static tuple of per-network (start, stop) slices on
  it (the segment ids of the per-network reduction), so energy/latency —
  linear in the 14 count terms of :func:`_count_terms` (eq. (1) unrolled)
  — reduce to ``[n_unique, n_networks]`` partial sums before any
  per-config coefficient is applied.
* ``per_layer=True`` keeps the layer axis instead of segment-summing:
  the same heavy stage emits the raw per-layer terms, the coefficient
  combine broadcasts over the concatenated axis, and the result is
  re-split into a padded ``[n_cfg, n_networks, n_layer]`` tensor
  (``n_layer`` = longest network; shorter networks zero-padded).  This
  is the input of the heterogeneous layer→core co-design solver
  (:func:`repro.core.partition.batch_schedule_hetero`).

Three interchangeable backends evaluate the heavy stage (selected by
``backend=`` on the public entry points, auto-fallback order
pallas → jax → numpy): the jitted jax kernel, the fused Pallas
count-terms kernel (:mod:`repro.kernels.count_terms`), and the numpy
reference.  An unavailable choice degrades silently at the result level
but emits ONE :class:`RuntimeWarning` per process per degradation edge
(see :func:`resolve_backend`); :func:`last_backend` always reports what
actually executed.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Mapping, Sequence, Tuple

import numpy as np

from .accelerator import AcceleratorConfig, ConfigGrid
from . import rs_mapping
from .topology import Layer

_POOL_OP_ENERGY = 0.2      # a pooling compare/add relative to a MAC


def _mapping(xp, cfg: Dict[str, Any], lay: Dict[str, Any]) -> Dict[str, Any]:
    """RS mapping over (configs × layers) from struct-of-arrays inputs."""
    return rs_mapping.mapping(
        xp,
        rows=cfg["rows"], cols=cfg["cols"],
        c_ch=lay["c_ch"], m=lay["m"], ky=lay["ky"], kx=lay["kx"],
        stride=lay["stride"], ix=lay["ix"], iy=lay["iy"],
        oy=lay["oy"], ox=lay["ox"],
        is_acc=lay["is_acc"], is_dw=lay["is_dw"], is_pool=lay["is_pool"],
        gb_ifmap_words=cfg["gb_ifmap_words"],
        rf_ifmap_words=cfg["rf_ifmap_words"],
        rf_weight_words=cfg["rf_weight_words"],
        rf_psum_words=cfg["rf_psum_words"])


@dataclasses.dataclass(frozen=True)
class LayerReport:
    """Per-layer outputs of the Tool (§II.B.2)."""

    name: str
    energy: float            # pJ
    latency: float           # ns
    macs: float
    dram_reads: float
    dram_writes: float
    gb_reads: float
    gb_writes: float
    rf_accesses: float
    utilization: float       # active PEs / total PEs (compute-time weighted)
    mem_time: float          # ns spent on the memory hierarchy
    array_time: float        # ns spent computing in the array
    psum_spilled: float      # words of psum traffic that went to DRAM
    ifmap_refetched: float   # extra ifmap words re-read from DRAM


@dataclasses.dataclass(frozen=True)
class NetworkReport:
    name: str
    energy: float
    latency: float
    layers: List[LayerReport]

    @property
    def edp(self) -> float:
        return self.energy * self.latency

    @property
    def layer_latencies(self) -> np.ndarray:
        return np.array([l.latency for l in self.layers])

    @property
    def layer_energies(self) -> np.ndarray:
        return np.array([l.energy for l in self.layers])


def _counts(xp, cfg: Dict[str, Any], lay: Dict[str, Any],
            mp: Dict[str, Any] | None = None) -> Dict[str, Any]:
    """Access counts + time terms; broadcast over (configs × layers).

    ``mp`` lets callers pass a precomputed RS mapping (the batched engine
    evaluates it on the smaller mapping-unique config set and gathers)."""
    if mp is None:
        mp = _mapping(xp, cfg, lay)

    n_c, n_m, n_oy = mp["n_c"], mp["n_m"], mp["n_oy"]
    w_psum = mp["w_psum"]
    ky_serial = mp["ky_serial"]

    ifmap_vol = lay["ifmap_words"]
    ofmap = lay["ofmap_words"]
    weights = lay["weight_words"]
    macs = lay["macs"]
    is_pool = lay["is_pool"]
    pool_ops = lay["c_ch"] * lay["ox"] * lay["oy"] * lay["kx"] * lay["ky"]

    # ---- ifmap traffic (Observation 2) -------------------------------------
    # Channel rounds partition the channel set, so every ifmap word streams
    # DRAM→GB exactly once (compulsory traffic — the Eyeriss-RS reuse ideal).
    # GB_ifmap capacity acts through the mapping instead: fewer channels held
    # per round ⇒ more accumulation rounds ⇒ more psum RMW traffic below.
    # Within a round the resident channel planes are re-delivered GB→array
    # for each of the n_m filter blocks (cheap on-chip reads).
    ifmap_dram_reads = ifmap_vol * xp.ones_like(n_m)
    ifmap_refetched = ifmap_vol * 0.0
    gb_ifmap_writes = ifmap_dram_reads                 # DRAM → GB
    gb_ifmap_reads = ifmap_vol * xp.where(lay["is_acc"], n_m, 1)

    # ---- weight traffic ---------------------------------------------------
    # GB_weight is provisioned for the in-flight working set (§III); weights
    # stream from DRAM once, land in the PE weight RFs once per use phase and
    # are reused across the spatial loop from there.
    wt_dram_reads = weights
    gb_wt_writes = weights
    gb_wt_reads = weights * ky_serial

    # ---- psum traffic (Observation 1) --------------------------------------
    # The psum planes of the in-flight filter block (w_psum = cap_m·Ox·Oy
    # words) are read-modify-written on every channel-accumulation round
    # after the first; the slice exceeding GB_psum makes the round trip to
    # off-chip DRAM instead (write + re-read, §III).
    inter_rounds = xp.maximum(n_c * ky_serial - 1, 0)
    overflow = xp.maximum(w_psum - cfg["gb_psum_words"], 0.0)
    held = xp.minimum(w_psum, cfg["gb_psum_words"] * xp.ones_like(w_psum))
    psum_dram_writes = inter_rounds * overflow
    psum_dram_reads = psum_dram_writes
    psum_gb_inter = inter_rounds * held
    gb_psum_writes = psum_gb_inter + ofmap             # + final results
    gb_psum_reads = psum_gb_inter + ofmap              # reload + writeback
    ofmap_dram_writes = ofmap

    # ---- totals -------------------------------------------------------------
    dram_reads = ifmap_dram_reads + wt_dram_reads + psum_dram_reads
    dram_writes = ofmap_dram_writes + psum_dram_writes
    gb_reads = gb_ifmap_reads + gb_wt_reads + gb_psum_reads
    gb_writes = gb_ifmap_writes + gb_wt_writes + gb_psum_writes

    words_into_array = gb_ifmap_reads + gb_wt_reads + psum_gb_inter + psum_dram_reads
    words_out_of_array = gb_psum_writes + psum_dram_writes

    ops = xp.where(is_pool, pool_ops, macs)
    rf_accesses = (4.0 * ops) + words_into_array + words_out_of_array

    return dict(
        mp=mp, ops=ops, macs=macs, pool_ops=pool_ops,
        dram_reads=dram_reads, dram_writes=dram_writes,
        gb_ifmap_reads=gb_ifmap_reads, gb_ifmap_writes=gb_ifmap_writes,
        gb_wt_reads=gb_wt_reads, gb_wt_writes=gb_wt_writes,
        gb_psum_reads=gb_psum_reads, gb_psum_writes=gb_psum_writes,
        gb_reads=gb_reads, gb_writes=gb_writes,
        rf_accesses=rf_accesses,
        words_into_array=words_into_array,
        words_out_of_array=words_out_of_array,
        psum_spilled=psum_dram_writes + psum_dram_reads,
        ifmap_refetched=ifmap_refetched,
    )


def _energy_latency(xp, cfg: Dict[str, Any], lay: Dict[str, Any],
                    ct: Dict[str, Any]) -> Dict[str, Any]:
    e = cfg  # per-access constants pre-flattened into the cfg dict
    mp = ct["mp"]

    gb_e_if, gb_e_ps, gb_e_wt = e["gb_e_ifmap"], e["gb_e_psum"], e["gb_e_wt"]
    mac_e = xp.where(lay["is_pool"], e["e_mac"] * _POOL_OP_ENERGY, e["e_mac"])

    noc_hops = (cfg["rows"] + cfg["cols"]) / 2.0
    # idle PEs still burn clock/leakage power for the whole layer occupancy
    idle_cycles = (cfg["rows"] * cfg["cols"] - mp["active_pes"]) \
        * ct["ops"] / mp["active_pes"]
    energy = (
        ct["dram_reads"] * e["e_dram_r"] + ct["dram_writes"] * e["e_dram_w"]
        + (ct["gb_ifmap_reads"] + ct["gb_ifmap_writes"]) * gb_e_if
        + (ct["gb_psum_reads"] + ct["gb_psum_writes"]) * gb_e_ps
        + (ct["gb_wt_reads"] + ct["gb_wt_writes"]) * gb_e_wt
        + ct["rf_accesses"] * e["e_rf"]
        + ct["ops"] * mac_e
        + idle_cycles * e["e_pe_idle"]
        + (ct["words_into_array"] + ct["words_out_of_array"])
        * e["e_noc_hop"] * noc_hops
    )

    # Latency: GB→array delivery is paced by the NoC *and* by the access time
    # of the partition it drains (bigger buffer ⇒ slower access, Fig. 9).
    lat_if = e["gb_t_ifmap"] / e["gb_t_base"]
    lat_ps = e["gb_t_psum"] / e["gb_t_base"]
    delivery_cy = (
        (ct["gb_ifmap_reads"] + ct["gb_wt_reads"]) * lat_if
        + (ct["gb_psum_reads"]) * lat_ps
    ) / e["noc_wpc"]
    writeback_cy = ct["words_out_of_array"] * lat_ps / e["noc_wpc"]
    compute_cy = ct["ops"] / mp["active_pes"] * e["mac_t_cy"]
    array_cy = delivery_cy + compute_cy + writeback_cy

    dram_words = ct["dram_reads"] + ct["dram_writes"]
    dram_cy = dram_words / e["dram_wpc"]

    array_time = array_cy * e["cycle_ns"]
    mem_time = dram_cy * e["cycle_ns"] + (delivery_cy + writeback_cy) * e["cycle_ns"]
    latency = (array_cy + dram_cy) * e["cycle_ns"]

    total_pes = cfg["rows"] * cfg["cols"]
    utilization = xp.where(
        array_cy > 0, (compute_cy / xp.maximum(array_cy, 1e-30))
        * mp["active_pes"] / total_pes, 0.0)

    return dict(energy=energy, latency=latency, array_time=array_time,
                mem_time=mem_time, utilization=utilization)


def _cfg_struct(xp, cfg: AcceleratorConfig) -> Dict[str, Any]:
    et = cfg.energy
    return dict(
        rows=xp.asarray(cfg.array_rows), cols=xp.asarray(cfg.array_cols),
        gb_ifmap_words=xp.asarray(cfg.gb_ifmap_words()),
        gb_psum_words=xp.asarray(cfg.gb_psum_words()),
        rf_ifmap_words=xp.asarray(cfg.rf_ifmap_words),
        rf_weight_words=xp.asarray(cfg.rf_weight_words),
        rf_psum_words=xp.asarray(cfg.rf_psum_words),
        e_rf=xp.asarray(et.rf_read),
        e_dram_r=xp.asarray(et.dram_read), e_dram_w=xp.asarray(et.dram_write),
        e_mac=xp.asarray(et.mac), e_noc_hop=xp.asarray(et.noc_hop),
        e_pe_idle=xp.asarray(et.pe_idle),
        gb_e_ifmap=xp.asarray(et.gb_energy(cfg.gb_ifmap_kb)),
        gb_e_psum=xp.asarray(et.gb_energy(cfg.gb_psum_kb)),
        gb_e_wt=xp.asarray(et.gb_energy(cfg.gb_weight_kb)),
        gb_t_ifmap=xp.asarray(et.gb_latency(cfg.gb_ifmap_kb)),
        gb_t_psum=xp.asarray(et.gb_latency(cfg.gb_psum_kb)),
        gb_t_base=xp.asarray(et.gb_t),
        noc_wpc=xp.asarray(cfg.noc_words_per_cycle),
        dram_wpc=xp.asarray(cfg.dram_words_per_cycle),
        mac_t_cy=xp.asarray(et.mac_t / cfg.cycle_ns),
        cycle_ns=xp.asarray(cfg.cycle_ns),
    )


def simulate_network(cfg: AcceleratorConfig, layers: Sequence[Layer],
                     name: str = "net") -> NetworkReport:
    """Scalar (per-network, per-config) entry point → full layer reports."""
    xp = np
    compute = [l for l in layers if l.kind != "input"]
    lay = rs_mapping.layer_struct(xp, compute)
    lay = {k: np.asarray(v, dtype=np.float64) for k, v in lay.items()}
    cfgs = _cfg_struct(xp, cfg)
    cfgs = {k: v.astype(np.float64) for k, v in cfgs.items()}

    ct = _counts(xp, cfgs, lay)
    el = _energy_latency(xp, cfgs, lay, ct)

    reports = []
    for i, l in enumerate(compute):
        reports.append(LayerReport(
            name=l.name,
            energy=float(el["energy"][i]), latency=float(el["latency"][i]),
            macs=float(lay["macs"][i]),
            dram_reads=float(ct["dram_reads"][i]),
            dram_writes=float(ct["dram_writes"][i]),
            gb_reads=float(ct["gb_reads"][i]), gb_writes=float(ct["gb_writes"][i]),
            rf_accesses=float(ct["rf_accesses"][i]),
            utilization=float(el["utilization"][i]),
            mem_time=float(el["mem_time"][i]),
            array_time=float(el["array_time"][i]),
            psum_spilled=float(ct["psum_spilled"][i]),
            ifmap_refetched=float(ct["ifmap_refetched"][i]),
        ))
    return NetworkReport(
        name=name,
        energy=float(el["energy"].sum()),
        latency=float(el["latency"].sum()),
        layers=reports)


# ---------------------------------------------------------------------------
# Batched, jit-cached design-space engine.
#
# The whole (configs × networks × layers) evaluation runs as ONE program.
# Two structural facts keep it fast at multi-thousand-point scale:
#
# * **Count dedup** — the RS mapping and access counts depend on a config
#   only through (array, GB words, RF words); knobs like the NoC width or
#   per-access energies don't change counts.  The grid is deduplicated on
#   those columns (5,400 extended-space points → 1,800 unique count rows)
#   and the heavy (unique × layers) math runs once per unique row.
# * **Early layer reduction** — per-network energy/latency are LINEAR in
#   the per-layer count terms with config-only coefficients, so the layer
#   axis is summed per network (static segment slices of the concatenated
#   layer axis) *before* the coefficients are applied: the expensive
#   [points × layers] stage collapses to [unique × networks] partial sums,
#   and the coefficient combine runs on tiny [points × networks] arrays.
#
# The jitted kernel lives at module level, so its compile cache persists
# across sweeps: jax.jit keys on input shapes, and the layer axis is padded
# to multiples of _LAYER_BUCKET, so every network (all 18 paper benchmarks
# are ≤ 251 layers) shares one trace per grid size.  The kernel needs 64-bit
# floats (access counts exceed float32's exact-integer range); jax ≥ 0.4
# removed ``jax.enable_x64`` so the x64 scope comes from
# ``jax.experimental.enable_x64`` and wraps both trace and execution.
# ---------------------------------------------------------------------------

_LAYER_BUCKET = 256

#: Compile/trace statistics of the module-level kernel — ``traces`` counts
#: actual retraces, ``calls`` every dispatch; a warm engine has
#: calls ≫ traces.  (Read via :func:`jit_cache_stats`.)
_JIT_STATS = {"traces": 0, "calls": 0}


def jit_cache_stats() -> Dict[str, int]:
    return dict(_JIT_STATS)


def _cfg_struct_from_grid(xp, grid) -> Dict[str, Any]:
    """Vectorised twin of :func:`_cfg_struct`: derives the per-access model
    columns for every grid point at once (float64, shape [n]).  Accepts a
    ConfigGrid or a bare column dict (the chunked paths slice columns)."""
    fields = grid.fields if isinstance(grid, ConfigGrid) else grid
    f = {k: np.asarray(v, dtype=np.float64) for k, v in fields.items()}
    bpw = f["bitwidth"] / 8.0
    ref = f["gb_ref_kb"]

    def gb_e(kb):
        return f["gb_e_ref"] * np.sqrt(np.maximum(kb, 1.0) / ref)

    def gb_t(kb):
        return f["gb_t_ref"] * np.sqrt(np.sqrt(np.maximum(kb, 1.0) / ref))

    return dict(
        rows=f["rows"], cols=f["cols"],
        gb_ifmap_words=np.floor(f["gb_ifmap_kb"] * 1024 / bpw),
        gb_psum_words=np.floor(f["gb_psum_kb"] * 1024 / bpw),
        rf_ifmap_words=f["rf_ifmap_words"],
        rf_weight_words=f["rf_weight_words"],
        rf_psum_words=f["rf_psum_words"],
        e_rf=f["e_rf"], e_dram_r=f["e_dram_r"], e_dram_w=f["e_dram_w"],
        e_mac=f["e_mac"], e_noc_hop=f["e_noc_hop"], e_pe_idle=f["e_pe_idle"],
        gb_e_ifmap=gb_e(f["gb_ifmap_kb"]),
        gb_e_psum=gb_e(f["gb_psum_kb"]),
        gb_e_wt=gb_e(f["gb_weight_kb"]),
        gb_t_ifmap=gb_t(f["gb_ifmap_kb"]),
        gb_t_psum=gb_t(f["gb_psum_kb"]),
        gb_t_base=f["gb_t_ref"],
        noc_wpc=f["noc_wpc"], dram_wpc=f["dram_wpc"],
        mac_t_cy=f["mac_t"] / f["cycle_ns"], cycle_ns=f["cycle_ns"],
    )


# A benign do-nothing layer: unit shapes keep every mapping quantity ≥ 1
# (no division hazards) while zero macs/words make its energy and latency
# exactly 0.0, so padding is invisible even before the one-hot masking.
_PAD_LAYER_ROW = dict(
    c_ch=1.0, m=1.0, ky=1.0, kx=1.0, stride=1.0, ix=1.0, iy=1.0,
    oy=1.0, ox=1.0, macs=0.0, weight_words=0.0, ifmap_words=0.0,
    ofmap_words=0.0, is_acc=1.0, is_dw=0.0, is_pool=0.0)


def _bucketed(n: int, bucket: int) -> int:
    return max(bucket, -(-n // bucket) * bucket)


#: Layer-struct columns that must be ≥ 1 — shapes/strides act as tile
#: divisors in the RS mapping — vs. counts that only need to be ≥ 0.
_LAYER_DIM_COLUMNS = ("c_ch", "m", "ky", "kx", "stride", "ix", "iy",
                      "oy", "ox")


def _validate_layer_struct(name: str, struct: Dict[str, np.ndarray]):
    """Reject NaN/inf/non-positive layer parameters at the engine boundary,
    naming the network, layer index and field (the layer-axis analogue of
    :func:`repro.core.accelerator.validate_fields`)."""
    for k, v in struct.items():
        bad = ~np.isfinite(v)
        if bad.any():
            i = int(np.argmax(bad))
            raise ValueError(
                f"network {name!r}: layer {i} field {k!r} is non-finite "
                f"({v[i]!r})")
        floor = 1 if k in _LAYER_DIM_COLUMNS else 0
        bad = v < floor
        if bad.any():
            i = int(np.argmax(bad))
            raise ValueError(
                f"network {name!r}: layer {i} field {k!r} must be >= "
                f"{floor}, got {v[i]!r}")


def _stack_networks(networks: Mapping[str, Sequence[Layer]],
                    bucket: int = _LAYER_BUCKET,
                    absorb_pad: bool = True):
    """Concatenate all networks' compute layers along one padded axis.

    Returns ``(lay, segments)``: ``lay`` values have shape [L_pad] and
    ``segments`` is a static tuple of per-network (start, stop) on that
    axis.  With ``absorb_pad`` the LAST segment extends to L_pad — pad
    layers contribute exactly zero (see ``_PAD_LAYER_ROW``), and
    absorbing them into the last segment makes the static jit key depend
    only on the bucketed length: every single-network sweep of a
    ≤ ``bucket``-layer network shares the one ``((0, bucket),)`` trace,
    rather than retracing per layer count.  The per-layer path passes
    ``absorb_pad=False`` — it needs the TRUE per-network lengths to size
    the padded ``n_layer`` output axis (at the cost of one extra trace
    per distinct length multiset).
    """
    if not networks:
        raise ValueError("evaluate_networks needs at least one network")
    structs = []
    seg_lens = []
    for name, layers in networks.items():
        compute = [l for l in layers if l.kind != "input"]
        s = rs_mapping.layer_struct(np, compute)
        s = {k: np.asarray(v, dtype=np.float64) for k, v in s.items()}
        _validate_layer_struct(name, s)
        structs.append(s)
        seg_lens.append(len(compute))
    total = int(np.sum(seg_lens))
    l_pad = _bucketed(total, bucket)

    lay = {}
    for k in structs[0]:
        col = np.full(l_pad, _PAD_LAYER_ROW[k], dtype=np.float64)
        col[:total] = np.concatenate([s[k] for s in structs])
        lay[k] = col
    offs = np.concatenate([[0], np.cumsum(seg_lens)]).astype(int)
    if absorb_pad:
        offs[-1] = l_pad                    # zero-energy pad → last segment
    segments = tuple((int(a), int(b)) for a, b in zip(offs[:-1], offs[1:]))
    return lay, segments


def network_layer_counts(networks: Mapping[str, Sequence[Layer]]
                         ) -> np.ndarray:
    """Per-network compute-layer counts, ordered like ``networks`` — the
    valid lengths of the per-layer path's padded ``n_layer`` axis."""
    return np.array([sum(1 for l in layers if l.kind != "input")
                     for layers in networks.values()], dtype=np.int64)


#: Config columns the RS mapping / access counts depend on.  Everything
#: else (per-access energies, NoC width, DRAM width, clock) only scales the
#: counts linearly and is applied after the layer reduction.
_COUNT_COLUMNS = ("rows", "cols", "gb_ifmap_words", "gb_psum_words",
                  "rf_ifmap_words", "rf_weight_words", "rf_psum_words")

#: Subset of _COUNT_COLUMNS the RS mapping itself depends on — GB_psum only
#: enters the spill accounting in `_counts`, never the mapping, so on the
#: extended space the mapping runs on 180 unique rows, not 1,800.
_MAPPING_COLUMNS = ("rows", "cols", "gb_ifmap_words",
                    "rf_ifmap_words", "rf_weight_words", "rf_psum_words")

#: Mapping outputs `_counts` / `_count_terms` consume (gathered back to the
#: count-unique axis after the mapping-unique evaluation).
_MAPPING_KEYS = ("n_c", "n_m", "n_oy", "w_psum", "ky_serial", "active_pes")


def _dedup_rows(cfgs: Dict[str, np.ndarray], columns):
    """→ (unique column dict [n_u], inverse index [n]) over ``columns``."""
    key = np.stack([cfgs[k] for k in columns], axis=1)
    uniq, inv = np.unique(key, axis=0, return_inverse=True)
    return dict(zip(columns, uniq.T.copy())), inv.astype(np.int32)


def _dedup_count_rows(cfgs: Dict[str, np.ndarray]):
    return _dedup_rows(cfgs, _COUNT_COLUMNS)


def _count_terms(xp, cfg_u: Dict[str, Any], lay: Dict[str, Any],
                 mp: Dict[str, Any] | None = None):
    """The 14 per-layer count terms that energy/latency are linear in.

    ``cfg_u`` holds the [n_u, 1] unique count columns; returns a tuple of
    [n_u, L] (or [1, L] for config-independent) arrays.  Kept as separate
    arrays — stacking them into one [14, n_u, L] tile would materialise
    hundreds of MB that the segment reduction immediately collapses.
    """
    ct = _counts(xp, cfg_u, lay, mp)
    active = ct["mp"]["active_pes"]
    ops = ct["ops"]
    is_pool = lay["is_pool"]
    return (
        ct["dram_reads"],                                   # 0 e_dram_r
        ct["dram_writes"],                                  # 1 e_dram_w
        ct["gb_ifmap_reads"] + ct["gb_ifmap_writes"],       # 2 gb_e_ifmap
        ct["gb_psum_reads"] + ct["gb_psum_writes"],         # 3 gb_e_psum
        ct["gb_wt_reads"] + ct["gb_wt_writes"],             # 4 gb_e_wt
        ct["rf_accesses"],                                  # 5 e_rf
        xp.where(is_pool, 0.0, ct["macs"]),                 # 6 e_mac
        xp.where(is_pool, ct["pool_ops"], 0.0),             # 7 e_mac·pool
        (cfg_u["rows"] * cfg_u["cols"] - active) * ops / active,  # 8 idle
        ct["words_into_array"] + ct["words_out_of_array"],  # 9 noc energy
        ct["gb_ifmap_reads"] + ct["gb_wt_reads"],           # 10 delivery@if
        ct["gb_psum_reads"],                                # 11 delivery@ps
        ct["words_out_of_array"],                           # 12 writeback
        ops / active,                                       # 13 compute cy
    )


def _combine_reduced(xp, S, coefs: Dict[str, Any]):
    """14 × [n_cfg, n_net] reduced sums × per-config coefficients →
    (energy, latency), both [n_cfg, n_net].  Mirrors `_energy_latency`."""
    C = {k: v[:, None] for k, v in coefs.items()}
    (d_r, d_w, gb_if, gb_ps, gb_wt, rf, ops_mac, ops_pool, idle,
     words_noc, dlv_if, dlv_ps, wout, ops_pe) = S
    energy = (
        d_r * C["e_dram_r"] + d_w * C["e_dram_w"]
        + gb_if * C["gb_e_ifmap"] + gb_ps * C["gb_e_psum"]
        + gb_wt * C["gb_e_wt"] + rf * C["e_rf"]
        + ops_mac * C["e_mac"] + ops_pool * (C["e_mac"] * _POOL_OP_ENERGY)
        + idle * C["e_pe_idle"]
        + words_noc * C["e_noc_hop"] * C["noc_hops"])
    lat_if = C["gb_t_ifmap"] / C["gb_t_base"]
    lat_ps = C["gb_t_psum"] / C["gb_t_base"]
    array_cy = ((dlv_if * lat_if + dlv_ps * lat_ps + wout * lat_ps)
                / C["noc_wpc"] + ops_pe * C["mac_t_cy"])
    dram_cy = (d_r + d_w) / C["dram_wpc"]
    latency = (array_cy + dram_cy) * C["cycle_ns"]
    return energy, latency


def _coef_struct(cfgs: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    keys = ("e_dram_r", "e_dram_w", "gb_e_ifmap", "gb_e_psum", "gb_e_wt",
            "e_rf", "e_mac", "e_pe_idle", "e_noc_hop", "gb_t_ifmap",
            "gb_t_psum", "gb_t_base", "noc_wpc", "mac_t_cy", "dram_wpc",
            "cycle_ns")
    out = {k: cfgs[k] for k in keys}
    out["noc_hops"] = (cfgs["rows"] + cfgs["cols"]) / 2.0
    return out


def _term_sums_body(xp, segments, cfg_m, cfg_u, lay, inv_m):
    """Mapping on the mapping-unique rows, counts on the count-unique rows,
    per-network segment sums: tuple of [n_u, n_net] (or [1, n_net] for the
    two config-independent terms).  This is the heavy stage — and the one
    the sharded kernel splits along the unique-config axis."""
    mp_m = _mapping(xp, cfg_m, lay)
    mp = {k: mp_m[k][inv_m] for k in _MAPPING_KEYS}
    terms = _count_terms(xp, cfg_u, lay, mp)
    return tuple(
        xp.stack([t[..., a:b].sum(-1) for a, b in segments], axis=-1)
        for t in terms)


def _gather_combine_body(xp, S, inv, coefs):
    """Gather the reduced sums back to the full config axis and apply the
    per-config coefficients — the cheap [n_cfg, n_net] stage."""
    gathered = []
    for s in S:
        if s.shape[0] == 1:                  # config-independent term
            g = xp.broadcast_to(s, (inv.shape[0], s.shape[1]))
        else:
            g = s[inv]
        gathered.append(g)
    return _combine_reduced(xp, tuple(gathered), coefs)


def _pallas_term_sums(segments, cfg_u, lay):
    """Fused Pallas twin of :func:`_term_sums_body`: mapping + 14 terms +
    segment reduction in one pass over the [unique × layers] tiles (see
    ``repro/kernels/count_terms``).  Runs on the count-unique rows only —
    the mapping-level dedup is folded into the tile program."""
    from repro.kernels.count_terms import count_term_sums
    return count_term_sums(cfg_u, lay, segments)


# ---------------------------------------------------------------------------
# Per-layer path: the SAME heavy stage without the early segment reduction.
# The 14 terms stay [n_u, L] (the one-hot matmul of the fused kernel is
# skipped), the coefficient combine broadcasts over the concatenated layer
# axis, and the result is re-split per network onto a padded n_layer axis.
# ---------------------------------------------------------------------------


def _term_layers_body(xp, cfg_m, cfg_u, lay, inv_m):
    """Per-layer twin of :func:`_term_sums_body`: the raw 14 count terms,
    each [n_u, L] ([1, L] for the config-independent two) — no segment
    reduction."""
    mp_m = _mapping(xp, cfg_m, lay)
    mp = {k: mp_m[k][inv_m] for k in _MAPPING_KEYS}
    return _count_terms(xp, cfg_u, lay, mp)


def _pallas_term_layers(cfg_u, lay):
    """Fused Pallas per-layer heavy stage: same tile program with the
    one-hot segment matmul skipped — emits the [14, n_u, L] per-layer
    partials directly (see ``repro.kernels.count_terms.count_term_layers``)."""
    from repro.kernels.count_terms import count_term_layers
    return count_term_layers(cfg_u, lay)


def _layer_axis_len(segments) -> int:
    """Padded n_layer of the per-layer output: the longest segment."""
    return max(b - a for a, b in segments)


def _split_layers(xp, arr, segments):
    """[n, L_concat] → [n, n_net, n_layer]: slice each network's segment
    off the concatenated axis and zero-pad to the longest one (pad rows
    of shorter networks are exactly 0 — see ``_PAD_LAYER_ROW``)."""
    n_layer = _layer_axis_len(segments)
    outs = []
    for a, b in segments:
        seg = arr[:, a:b]
        if b - a < n_layer:
            seg = xp.pad(seg, ((0, 0), (0, n_layer - (b - a))))
        outs.append(seg)
    return xp.stack(outs, axis=1)


def _grid_kernel_body(xp, segments, cfg_m, cfg_u, lay, inv_m, inv, coefs,
                      backend: str = "jax", per_layer: bool = False):
    """Shared numpy/jax/pallas kernel: mapping on the mapping-unique rows,
    counts on the count-unique rows, segment-reduce, then coefficient
    combine.  ``backend="pallas"`` swaps the heavy stage for the fused
    count-terms kernel (same operands, same [n_u, n_net] partial sums).
    ``per_layer=True`` skips the segment reduction: the combine runs on
    the [*, L] terms and the outputs are re-split to [n, n_net, n_layer]."""
    if per_layer:
        if backend == "pallas":
            S = _pallas_term_layers(cfg_u, lay)
        else:
            S = _term_layers_body(xp, cfg_m, cfg_u, lay, inv_m)
        e, t = _gather_combine_body(xp, S, inv, coefs)
        return _split_layers(xp, e, segments), _split_layers(xp, t, segments)
    if backend == "pallas":
        S = _pallas_term_sums(segments, cfg_u, lay)
    else:
        S = _term_sums_body(xp, segments, cfg_m, cfg_u, lay, inv_m)
    return _gather_combine_body(xp, S, inv, coefs)


def _np_grid_kernel(segments, cfg_m, cfg_u, lay, inv_m, inv, coefs,
                    per_layer: bool = False):
    return _grid_kernel_body(np, segments, cfg_m, cfg_u, lay, inv_m, inv,
                             coefs, per_layer=per_layer)


_jitted_grid_kernels: Dict[Tuple[str, bool], Any] = {}  # per (backend, mode)


def _jax_grid_kernel(backend: str = "jax", per_layer: bool = False):
    key = (backend, per_layer)
    if key not in _jitted_grid_kernels:
        import jax
        import jax.numpy as jnp

        def kernel(segments, cfg_m, cfg_u, lay, inv_m, inv, coefs):
            _JIT_STATS["traces"] += 1        # runs only while tracing
            return _grid_kernel_body(jnp, segments, cfg_m, cfg_u, lay,
                                     inv_m, inv, coefs, backend=backend,
                                     per_layer=per_layer)

        _jitted_grid_kernels[key] = jax.jit(kernel, static_argnums=0)
    return _jitted_grid_kernels[key]


#: Indices in the `_count_terms` tuple that do not depend on the config
#: (shape [1, L]): pure-MAC and pooling op counts.
_CFG_INDEP_TERMS = (6, 7)

_jitted_sharded_kernels: Dict[Tuple[str, bool], Any] = {}
_sharded_kernel_ndev = 0


def _jax_sharded_kernel(backend: str = "jax", per_layer: bool = False):
    """Sharded twin of :func:`_jax_grid_kernel`, built on ``shard_map``:
    the count-unique config rows are split along a 1-D device mesh, each
    device runs the heavy (rows × layers) stage on its slice, and the tiny
    [n_u, n_net] partial sums are all-gathered before the replicated
    gather/combine.  Explicit specs — GSPMD's auto-partitioning of the
    same program chooses badly on CPU meshes."""
    global _jitted_sharded_kernels, _sharded_kernel_ndev
    import jax

    mesh = _cfg_mesh()
    if _sharded_kernel_ndev != mesh.devices.size:
        _jitted_sharded_kernels = {}         # device count changed: rebuild
        _sharded_kernel_ndev = mesh.devices.size
    key = (backend, per_layer)
    if key not in _jitted_sharded_kernels:
        def kernel(segments, cfg_m, cfg_u, lay, inv_m, inv, coefs):
            _JIT_STATS["traces"] += 1        # runs only while tracing
            return _sharded_grid_body(segments, cfg_m, cfg_u, lay, inv_m,
                                      inv, coefs, backend=backend,
                                      per_layer=per_layer)

        _jitted_sharded_kernels[key] = jax.jit(kernel, static_argnums=0)
    return _jitted_sharded_kernels[key]


def _sharded_grid_body(segments, cfg_m, cfg_u, lay, inv_m, inv, coefs,
                       backend: str = "jax", per_layer: bool = False):
    """Traced body of the sharded kernel (shared with the stream step).

    In per-layer mode the all-gathered partials are [n_u, L] instead of
    [n_u, n_net] — heavier across the mesh, but the split along the
    unique-config axis (and the replicated combine) is identical."""
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh = _cfg_mesh()
    row2, row1, rep = P("cfg", None), P("cfg"), P()

    def local(cfg_m_, cfg_u_, lay_, inv_m_):
        if per_layer:
            if backend == "pallas":
                S = _pallas_term_layers(cfg_u_, lay_)
                return tuple(lax.all_gather(s, "cfg", axis=0, tiled=True)
                             for s in S)
            S = _term_layers_body(jnp, cfg_m_, cfg_u_, lay_, inv_m_)
            return tuple(
                s if i in _CFG_INDEP_TERMS
                else lax.all_gather(s, "cfg", axis=0, tiled=True)
                for i, s in enumerate(S))
        if backend == "pallas":
            # the fused kernel emits every term per count-unique row (the
            # config-independent ones broadcast), so all 14 gather
            S = _pallas_term_sums(segments, cfg_u_, lay_)
            return tuple(lax.all_gather(s, "cfg", axis=0, tiled=True)
                         for s in S)
        S = _term_sums_body(jnp, segments, cfg_m_, cfg_u_, lay_, inv_m_)
        return tuple(
            s if i in _CFG_INDEP_TERMS
            else lax.all_gather(s, "cfg", axis=0, tiled=True)
            for i, s in enumerate(S))

    S = shard_map(
        local, mesh=mesh,
        in_specs=({k: rep for k in cfg_m}, {k: row2 for k in cfg_u},
                  {k: rep for k in lay}, row1),
        out_specs=tuple(rep for _ in range(14)),
        check_rep=False)(cfg_m, cfg_u, lay, inv_m)
    if per_layer:
        e, t = _gather_combine_body(jnp, S, inv, coefs)
        return (_split_layers(jnp, e, segments),
                _split_layers(jnp, t, segments))
    return _gather_combine_body(jnp, S, inv, coefs)


def jax_available() -> bool:
    try:
        import jax                                     # noqa: F401
        return True
    except Exception:                                  # pragma: no cover
        return False


def pallas_available() -> bool:
    """Whether the fused count-terms Pallas kernel can run (interpret
    mode, which works on any jax backend — a native TPU/GPU lowering is
    opt-in, see ``repro.kernels.count_terms.count_term_sums``)."""
    if not jax_available():
        return False                                   # pragma: no cover
    try:
        from jax.experimental import pallas            # noqa: F401
        return True
    except Exception:                                  # pragma: no cover
        return False


#: Selectable heavy-stage backends, in auto-fallback order.
BACKENDS = ("pallas", "jax", "numpy")

_LAST_BACKEND: str | None = None

#: (requested, resolved) degradation edges already warned about — the
#: auto-fallback warns exactly ONCE per process per edge, never per call
#: (a mega-grid chunked sweep resolves the backend thousands of times).
_FALLBACK_WARNED: set = set()


def last_backend() -> str | None:
    """Backend the most recent engine dispatch actually ran on
    (``"pallas" | "jax" | "numpy"``), after auto-fallback — ``None``
    before the first call.  Lets callers report truthfully what executed
    (see ``examples/dse_hetero.py``)."""
    return _LAST_BACKEND


def _warn_fallback(requested: str, resolved: str) -> None:
    key = (requested, resolved)
    if key in _FALLBACK_WARNED:
        return
    _FALLBACK_WARNED.add(key)
    warnings.warn(
        f"engine backend {requested!r} is unavailable on this host; "
        f"falling back to {resolved!r} (check energymodel.last_backend() "
        "for what each dispatch ran on; this warning fires once per "
        "process)", RuntimeWarning, stacklevel=3)


def resolve_backend(backend: str | None = None,
                    use_jax: bool | None = None) -> str:
    """Resolve the requested backend with auto-fallback.

    Explicit ``backend`` wins over the legacy ``use_jax`` tri-state; an
    unavailable choice degrades (pallas → jax → numpy) instead of
    raising, so ``backend="pallas"`` is safe on hosts without Pallas.
    Each degradation edge emits one ``RuntimeWarning`` per process (not
    per call); the silent paths are only the auto-selections where
    nothing was requested."""
    if backend is None:
        if use_jax is None:
            backend = "jax" if jax_available() else "numpy"
        else:
            backend = "jax" if use_jax else "numpy"
        requested = None                     # auto-selection: never warn
    else:
        requested = backend
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, "
                         f"got {backend!r}")
    if backend == "pallas" and not pallas_available():
        backend = "jax"
    if backend == "jax" and not jax_available():
        backend = "numpy"
    if requested is not None and backend != requested:
        _warn_fallback(requested, backend)
    return backend


# ---------------------------------------------------------------------------
# Device sharding: the deduped config axis is the embarrassingly-parallel
# axis of the engine — the heavy (unique-rows × layers) math partitions
# cleanly across host devices, and only the tiny [unique, networks] reduced
# sums cross device boundaries (one all-gather before the coefficient
# combine).  Multiple host devices come from XLA's
# ``--xla_force_host_platform_device_count`` flag, which MUST be set in
# XLA_FLAGS before jax first initialises its backend (see launch/dryrun.py
# and benchmarks/run.py for the pattern).
# ---------------------------------------------------------------------------

#: Bucket sizes for the unique axes under chunked evaluation: padding the
#: deduped rows (duplicates of row 0 — valid math, never gathered back) to
#: these multiples keeps jit input shapes stable across chunks, so a whole
#: chunked sweep shares a handful of traces.
_UNIQUE_BUCKET = 256
_MAPPING_BUCKET = 64


def host_device_count() -> int:
    """Number of (possibly XLA-forced) host devices; 1 without jax."""
    if not jax_available():
        return 1
    import jax
    return len(jax.devices())


def request_host_devices(n: int) -> bool:
    """Append ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS.

    Must run before anything imports jax (the backend locks the device
    count on first init); returns False — and changes nothing — if jax is
    already imported."""
    import os
    import sys
    if "jax" in sys.modules:
        return False
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={int(n)}")
    return True


_MESH = None


def _cfg_mesh():
    global _MESH
    import jax
    devs = np.array(jax.devices())
    if _MESH is None or _MESH.devices.size != devs.size:
        from jax.sharding import Mesh
        _MESH = Mesh(devs, ("cfg",))
    return _MESH


def _device_put_sharded(cfg_m, cfg_u, lay, inv_m, inv, coefs):
    """Place kernel inputs: unique-config rows split along the mesh, the
    small mapping rows / layer axis / coefficients replicated."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec
    mesh = _cfg_mesh()
    row = NamedSharding(mesh, PartitionSpec("cfg"))
    rep = NamedSharding(mesh, PartitionSpec())
    put = jax.device_put
    return ({k: put(v, rep) for k, v in cfg_m.items()},
            {k: put(v, row) for k, v in cfg_u.items()},
            {k: put(v, rep) for k, v in lay.items()},
            put(inv_m, row), put(inv, rep),
            {k: put(v, rep) for k, v in coefs.items()})


def _pad_rows(arr: np.ndarray, n_to: int) -> np.ndarray:
    """Pad axis 0 to ``n_to`` by repeating row 0 (benign duplicate)."""
    if arr.shape[0] >= n_to:
        return arr
    reps = np.broadcast_to(arr[:1], (n_to - arr.shape[0],) + arr.shape[1:])
    return np.concatenate([arr, reps], axis=0)


def _prepare_fields(fields: Dict[str, np.ndarray],
                    u_bucket: int | None = None,
                    m_bucket: int | None = None,
                    n_dev: int = 1,
                    backend: str = "jax"):
    """Grid columns → two-level-deduped kernel inputs, with the unique
    axes optionally padded to bucket multiples (and to a device-count
    multiple so the shard along the mesh is even).  The fused Pallas
    backend recomputes the mapping per count-unique row inside the tile
    program, so its mapping-level operands are never read — feed
    stable-shape placeholders instead of running the dedup."""
    cfgs = _cfg_struct_from_grid(np, fields)
    coefs = _coef_struct(cfgs)
    cfg_u, inv = _dedup_count_rows(cfgs)            # counts level
    if backend == "pallas":
        cfg_m = {k: cfg_u[k][:1].copy() for k in _MAPPING_COLUMNS}
        inv_m = np.zeros(next(iter(cfg_u.values())).shape[0], np.int32)
    else:
        cfg_m, inv_m = _dedup_rows(cfg_u, _MAPPING_COLUMNS)  # mapping lvl
    n_u = inv_m.shape[0]
    if u_bucket is not None or n_dev > 1:
        tgt = _bucketed(n_u, u_bucket) if u_bucket else n_u
        tgt = -(-tgt // n_dev) * n_dev
        if tgt > n_u:
            cfg_u = {k: _pad_rows(v, tgt) for k, v in cfg_u.items()}
            inv_m = np.concatenate(
                [inv_m, np.zeros(tgt - n_u, inv_m.dtype)])
    if m_bucket is not None:
        n_m = next(iter(cfg_m.values())).shape[0]
        cfg_m = {k: _pad_rows(v, _bucketed(n_m, m_bucket))
                 for k, v in cfg_m.items()}
    cfg_u = {k: v[:, None] for k, v in cfg_u.items()}
    cfg_m = {k: v[:, None] for k, v in cfg_m.items()}
    return cfg_m, cfg_u, inv_m, inv, coefs


def _eval_fields(fields, lay, segments, backend: str, shard: bool,
                 u_bucket: int | None = None,
                 m_bucket: int | None = None,
                 per_layer: bool = False):
    """Evaluate one batch of grid columns → ([n, n_net], [n, n_net])
    (or [n, n_net, n_layer] pairs in per-layer mode)."""
    use_jax = backend != "numpy"
    n_dev = host_device_count() if (shard and use_jax) else 1
    cfg_m, cfg_u, inv_m, inv, coefs = _prepare_fields(
        fields, u_bucket, m_bucket, n_dev, backend)
    if not use_jax:
        e, t = _np_grid_kernel(segments, cfg_m, cfg_u, lay, inv_m, inv,
                               coefs, per_layer=per_layer)
        return np.asarray(e), np.asarray(t)
    from jax.experimental import enable_x64
    with enable_x64():
        args = (cfg_m, cfg_u, lay, inv_m, inv, coefs)
        if n_dev > 1:
            args = _device_put_sharded(*args)
            kern = _jax_sharded_kernel(backend, per_layer)
        else:
            kern = _jax_grid_kernel(backend, per_layer)
        _JIT_STATS["calls"] += 1
        e, t = kern(segments, *args)
        return np.asarray(e), np.asarray(t)


def _dispatch_chunk(fc, lay, segments, device=None, backend: str = "jax",
                    per_layer: bool = False):
    """Async-dispatch one padded chunk on ``device`` (jax path): returns
    uncollected device arrays so the host can prepare the next chunk — and
    other devices can compute — while this one runs."""
    import jax
    cfg_m, cfg_u, inv_m, inv, coefs = _prepare_fields(
        fc, _UNIQUE_BUCKET, _MAPPING_BUCKET, backend=backend)
    args = (cfg_m, cfg_u, lay, inv_m, inv, coefs)
    if device is not None:
        args = jax.device_put(args, device)
    _JIT_STATS["calls"] += 1
    return _jax_grid_kernel(backend, per_layer)(segments, *args)


def _eval_chunked(fields, lay, segments, backend: str, shard: bool,
                  chunk_size: int, n: int, n_net: int,
                  per_layer: bool = False):
    """Chunked evaluation of the full grid → dense [n, n_net] outputs
    ([n, n_net, n_layer] in per-layer mode).

    With ``shard=True`` and several host devices, whole chunks round-robin
    across the devices: each device runs the complete two-level-dedup
    kernel on its chunks (no duplicated mapping work, no collectives), and
    asynchronous dispatch keeps every device busy while the host dedups
    the next chunk.  In-flight chunks are bounded to 2 per device."""
    shape = ((n, n_net, _layer_axis_len(segments)) if per_layer
             else (n, n_net))
    e = np.empty(shape)
    t = np.empty(shape)

    def chunks():
        for ci, start in enumerate(range(0, n, chunk_size)):
            stop = min(start + chunk_size, n)
            fc = {k: _pad_rows(v[start:stop], chunk_size)
                  for k, v in fields.items()}
            yield ci, start, stop, fc

    if backend == "numpy":
        for _, start, stop, fc in chunks():
            ec, tc = _eval_fields(fc, lay, segments, "numpy", False,
                                  _UNIQUE_BUCKET, _MAPPING_BUCKET,
                                  per_layer=per_layer)
            e[start:stop] = ec[:stop - start]
            t[start:stop] = tc[:stop - start]
        return e, t

    import jax
    from jax.experimental import enable_x64
    devs = jax.devices()
    n_dev = len(devs) if shard else 1
    pending: list = []

    def drain(item):
        start, stop, ec, tc = item
        e[start:stop] = np.asarray(ec)[:stop - start]
        t[start:stop] = np.asarray(tc)[:stop - start]

    with enable_x64():
        for ci, start, stop, fc in chunks():
            dev = devs[ci % n_dev] if n_dev > 1 else None
            ec, tc = _dispatch_chunk(fc, lay, segments, dev, backend,
                                     per_layer)
            pending.append((start, stop, ec, tc))
            if len(pending) > 2 * n_dev:
                drain(pending.pop(0))
        for item in pending:
            drain(item)
    return e, t


def evaluate_networks(grid: ConfigGrid,
                      networks: Mapping[str, Sequence[Layer]],
                      use_jax: bool | None = None,
                      *,
                      backend: str | None = None,
                      shard: bool = False,
                      chunk_size: int | None = None,
                      per_layer: bool = False,
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Evaluate every network against every grid point.

    Returns ``(energy, latency)`` float64 arrays of shape
    ``[grid.n, len(networks)]``, columns ordered like ``networks``.
    ``backend`` selects the heavy-stage kernel — ``"pallas"`` (fused
    count-terms kernel), ``"jax"`` (jitted term chains), ``"numpy"``
    (reference) — with auto-fallback when the choice is unavailable; the
    legacy ``use_jax`` tri-state maps onto it (None auto-selects).
    ``shard=True`` splits the deduped config axis across all host devices
    (see :func:`request_host_devices`); ``chunk_size`` evaluates the grid
    in fixed-shape chunks so the heavy (unique-rows × layers)
    intermediates stay bounded — mega-scale spaces would otherwise
    materialise multi-GB tiles.

    ``per_layer=True`` keeps the layer axis: the outputs become
    ``[grid.n, len(networks), n_layer]`` where ``n_layer`` is the longest
    network's compute-layer count (shorter networks zero-padded — see
    :func:`network_layer_counts` for the valid lengths).  Summing the
    last axis reproduces the default outputs exactly (the default path
    merely performs that sum earlier, before the coefficients).  This is
    the input of the heterogeneous layer→core co-design stack
    (:func:`repro.core.hetero.co_design`).
    """
    global _LAST_BACKEND
    backend = resolve_backend(backend, use_jax)
    _LAST_BACKEND = backend
    lay, segments = _stack_networks(networks, absorb_pad=not per_layer)
    lay = {k: v[None, :] for k, v in lay.items()}
    fields = grid.fields if isinstance(grid, ConfigGrid) else dict(grid)
    n = int(next(iter(fields.values())).shape[0])

    if chunk_size is not None and n > chunk_size:
        return _eval_chunked(fields, lay, segments, backend, shard,
                             chunk_size, n, len(networks),
                             per_layer=per_layer)

    return _eval_fields(fields, lay, segments, backend, shard,
                        per_layer=per_layer)


# ---------------------------------------------------------------------------
# Streaming evaluation: chunked sweep with on-device running reductions.
#
# A mega-scale sweep does not need the full [n_cfg, n_net] energy/latency
# matrices — the paper's §III/§IV consumers want per-network minima
# (Tables 1–4), the ≤bound boundary sets (Table 5 / chip design), and a
# handful of near-optimal cells.  ``stream_networks`` evaluates the grid
# chunk by chunk and folds each chunk into a running reduction ON DEVICE
# (min / argmin / top-k via one jitted step that fuses the grid kernel
# with the reducer); only per-chunk boundary candidates cross to the host,
# pruned against the running minimum (monotone ⇒ no false negatives).
# ---------------------------------------------------------------------------


def _metric_of(metric: str, e, t):
    if metric == "edp":
        return e * t
    return e if metric == "energy" else t


@dataclasses.dataclass(frozen=True)
class StreamResult:
    """Running reductions of a streamed sweep (flat grid indices)."""

    networks: Tuple[str, ...]
    n_cfg: int
    metric: str
    bound: float
    min_energy: np.ndarray          # [n_net]
    min_latency: np.ndarray         # [n_net]
    min_metric: np.ndarray          # [n_net]
    argmin: np.ndarray              # [n_net] flat grid index of metric min
    topk_idx: np.ndarray            # [k, n_net] flat indices, best first
    topk_metric: np.ndarray         # [k, n_net]
    boundary_idx: Dict[str, np.ndarray]      # per net, sorted by metric
    boundary_energy: Dict[str, np.ndarray]
    boundary_latency: Dict[str, np.ndarray]

    def boundary_metric(self, name: str) -> np.ndarray:
        return _metric_of(self.metric, self.boundary_energy[name],
                          self.boundary_latency[name])


# ---------------------------------------------------------------------------
# Crash-safe resumable streaming.  Both streamed sweeps are a fold over a
# deterministic chunk schedule; everything the fold carries (the reduction
# state tuple plus the boundary candidate triples) is exportable after every
# chunk, so a run killed at chunk i restarts from chunk i and — because the
# (value, flat index) tie-break discipline makes the fold independent of how
# the rows were chunked or where the fold was split — produces results
# bit-identical to an uninterrupted run.  A content hash over (grid columns,
# network layer structs, metric, bound, topk, chunk schedule) is stamped
# into every exported state; resuming against changed inputs is rejected
# instead of silently folding incompatible partial results.
# ---------------------------------------------------------------------------


class StreamStateError(ValueError):
    """Resume state incompatible with the requested stream: wrong stream
    kind, inputs changed since the state was exported, or a truncated /
    corrupt payload."""


class ChunkCorruption(RuntimeError):
    """Non-finite energy/latency detected in a streamed chunk.

    Raised by the per-chunk NaN/inf guard BEFORE the chunk is folded, so
    the running state is never poisoned; carries chunk provenance
    (``chunk``, grid row range ``start:stop``, affected ``networks``)."""

    def __init__(self, msg: str, *, chunk: int, start: int, stop: int,
                 networks: Sequence[str] = ()):
        super().__init__(msg)
        self.chunk = int(chunk)
        self.start = int(start)
        self.stop = int(stop)
        self.networks = tuple(networks)


#: Fault-injection seam: when set, called as ``hook(chunk_index, e, t)`` on
#: every chunk's raw evaluation right before it is folded (both backends,
#: both streamed sweeps) and must return the possibly-modified ``(e, t)``.
#: ``repro.ft.faults.inject_chunk_faults`` installs a deterministic
#: :class:`repro.ft.faults.FaultPlan` here; production code leaves it None.
_CHUNK_HOOK = None


def _apply_chunk_hook(ci, e, t):
    if _CHUNK_HOOK is None:
        return e, t
    return _CHUNK_HOOK(ci, e, t)


def _guard_chunk(ci, start, stop, es, ts, names):
    """NaN/inf guard with chunk provenance.

    ``es``/``ts`` are the [chunk, n_net] aggregates; only the valid rows
    (< stop-start) are checked — padded rows are legitimately +inf."""
    m = stop - start
    esn = np.asarray(es)[:m]
    tsn = np.asarray(ts)[:m]
    bad = ~np.isfinite(esn) | ~np.isfinite(tsn)
    if bad.any():
        nets = [names[j] for j in np.unique(np.nonzero(bad)[1])]
        raise ChunkCorruption(
            f"non-finite energy/latency in streamed chunk {ci} (grid rows "
            f"{start}:{stop}, networks {nets}); the fold state was NOT "
            f"updated with this chunk — retry the chunk or resume from the "
            f"last exported state", chunk=ci, start=start, stop=stop,
            networks=nets)


def stream_input_hash(grid: ConfigGrid | Mapping[str, Any],
                      networks: Mapping[str, Sequence[Layer]],
                      *, kind: str, metric: str, bound: float | None,
                      topk: int, chunk: int) -> str:
    """Content hash of everything that determines a streamed fold.

    Covers the grid columns byte-for-byte, each network's layer struct,
    and the reduction parameters including the effective chunk schedule —
    two streams with equal hashes fold identical chunk sequences, which
    is the precondition for bit-exact resume."""
    import hashlib
    h = hashlib.sha256()
    h.update(repr((kind, metric,
                   None if bound is None else float(bound),
                   int(topk), int(chunk))).encode())
    fields = grid.fields if isinstance(grid, ConfigGrid) else dict(grid)
    for k in sorted(fields):
        h.update(k.encode())
        h.update(np.ascontiguousarray(
            np.asarray(fields[k], dtype=np.float64)).tobytes())
    for nm in networks:
        h.update(nm.encode())
        struct = rs_mapping.layer_struct(
            np, [l for l in networks[nm] if l.kind != "input"])
        for sk in sorted(struct):
            h.update(sk.encode())
            h.update(np.ascontiguousarray(
                np.asarray(struct[sk], dtype=np.float64)).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class StreamFoldState:
    """Serializable fold state of a streamed sweep after ``next_chunk``
    chunks.

    Emitted via the ``on_chunk=`` callback of :func:`stream_networks` /
    :func:`stream_layer_topk` after every folded chunk and accepted back
    through ``resume_from=``; :meth:`export_state` flattens it to plain
    numpy arrays (device buffers materialised to host) and
    :meth:`save`/:meth:`load` persist that export crash-safely (write to
    a temp file, then atomic rename)."""

    kind: str                       # "networks" | "layer_topk"
    input_hash: str
    next_chunk: int                 # chunks [0, next_chunk) are folded
    n_chunks: int
    chunk_size: int                 # effective chunk row count
    n_cfg: int
    networks: Tuple[str, ...]
    metric: str
    bound: float | None
    topk: int
    state: tuple                    # reduction state arrays (may be device)
    cand: Dict[str, list]           # boundary triples (idx, e, t) per net

    @property
    def complete(self) -> bool:
        return self.next_chunk >= self.n_chunks

    def export_state(self) -> Dict[str, Any]:
        """Flatten to a ``{name: np.ndarray}`` dict (+ a ``meta`` JSON
        string) — npz-serializable, no pickling."""
        import json
        out: Dict[str, Any] = {}
        for i, s in enumerate(self.state):
            out[f"state_{i}"] = np.array(np.asarray(s), copy=True)
        for j, nm in enumerate(self.networks):
            entries = self.cand.get(nm, [])
            if entries:
                out[f"cand{j}_idx"] = np.concatenate(
                    [np.asarray(c[0], np.int64) for c in entries])
                out[f"cand{j}_e"] = np.concatenate(
                    [np.asarray(c[1], np.float64) for c in entries])
                out[f"cand{j}_t"] = np.concatenate(
                    [np.asarray(c[2], np.float64) for c in entries])
            else:
                out[f"cand{j}_idx"] = np.zeros(0, np.int64)
                out[f"cand{j}_e"] = np.zeros(0)
                out[f"cand{j}_t"] = np.zeros(0)
        out["meta"] = json.dumps(dict(
            kind=self.kind, input_hash=self.input_hash,
            next_chunk=int(self.next_chunk), n_chunks=int(self.n_chunks),
            chunk_size=int(self.chunk_size), n_cfg=int(self.n_cfg),
            networks=list(self.networks), metric=self.metric,
            bound=self.bound, topk=int(self.topk),
            n_state=len(self.state)))
        return out

    @classmethod
    def from_export(cls, d: Mapping[str, Any]) -> "StreamFoldState":
        import json
        try:
            meta_raw = d["meta"]
            if not isinstance(meta_raw, str):
                meta_raw = str(np.asarray(meta_raw)[()])
            meta = json.loads(meta_raw)
            state = tuple(np.asarray(d[f"state_{i}"])
                          for i in range(int(meta["n_state"])))
            cand: Dict[str, list] = {}
            for j, nm in enumerate(meta["networks"]):
                idx = np.asarray(d[f"cand{j}_idx"], np.int64)
                cand[nm] = ([(idx, np.asarray(d[f"cand{j}_e"]),
                              np.asarray(d[f"cand{j}_t"]))]
                            if idx.size else [])
        except (KeyError, ValueError, TypeError) as e:
            raise StreamStateError(
                f"truncated or corrupt stream fold-state payload: {e}")
        return cls(kind=meta["kind"], input_hash=meta["input_hash"],
                   next_chunk=int(meta["next_chunk"]),
                   n_chunks=int(meta["n_chunks"]),
                   chunk_size=int(meta["chunk_size"]),
                   n_cfg=int(meta["n_cfg"]),
                   networks=tuple(meta["networks"]), metric=meta["metric"],
                   bound=meta["bound"], topk=int(meta["topk"]),
                   state=state, cand=cand)

    def save(self, path) -> None:
        """Crash-safe persist: write the npz to ``path + '.tmp'``, fsync,
        then atomically rename over ``path``."""
        import os
        d = self.export_state()
        tmp = str(path) + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **d)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, str(path))

    @classmethod
    def load(cls, path) -> "StreamFoldState":
        with np.load(str(path), allow_pickle=False) as z:
            d = {k: z[k] for k in z.files}
        return cls.from_export(d)


def _resume_fold(resume_from, *, kind, ihash, names):
    """Validate a resume payload against the live call and unpack it."""
    fs = (resume_from if isinstance(resume_from, StreamFoldState)
          else StreamFoldState.from_export(resume_from))
    if fs.kind != kind:
        raise StreamStateError(
            f"resume_from carries a {fs.kind!r} fold state but this is a "
            f"{kind!r} stream")
    if fs.input_hash != ihash:
        raise StreamStateError(
            "resume_from was exported from different inputs — the (grid, "
            "networks, metric, bound, topk, chunk schedule) content hash "
            "does not match; refusing to resume because the folded result "
            "would not be bit-identical")
    state = tuple(np.asarray(s) for s in fs.state)
    cand = {nm: list(fs.cand.get(nm, [])) for nm in names}
    return state, cand, int(fs.next_chunk)


def _stream_reduce_body(xp, metric, topk, e, t, base, m_valid, bound,
                        state):
    """Fold one [chunk, n_net] evaluation into the running state.

    Padded chunk rows (row index ≥ m_valid) are masked to +inf so they
    never win a reduction; the returned boundary mask compares against the
    *updated* running minimum, a superset of the final boundary set."""
    min_e, min_t, min_m, argm, top_v, top_i = state
    rows = xp.arange(e.shape[0])
    invalid = (rows >= m_valid)[:, None]
    e_m = xp.where(invalid, np.inf, e)
    t_m = xp.where(invalid, np.inf, t)
    v = _metric_of(metric, e_m, t_m)
    min_e = xp.minimum(min_e, e_m.min(axis=0))
    min_t = xp.minimum(min_t, t_m.min(axis=0))
    cmin = v.min(axis=0)
    better = cmin < min_m
    min_m = xp.where(better, cmin, min_m)
    argm = xp.where(better, base + xp.argmin(v, axis=0), argm)
    idx = xp.broadcast_to((base + rows)[:, None], v.shape)
    all_v = xp.concatenate([top_v, v], axis=0)
    all_i = xp.concatenate([top_i, idx], axis=0)
    # Ties at the top-k boundary break by LOWER flat config index — NOT by
    # fold order (a stable sort on the value alone keeps whichever tied row
    # entered the state first, which depends on the chunk size).  Lexsort
    # on (value, index) makes the streamed top-k chunk-size-invariant; the
    # +inf initial state rows carry index -1, so they still sort ahead of
    # masked padding rows and the sentinel survives under-filled states.
    order = xp.lexsort((all_i, all_v), axis=0)[:topk]
    top_v = xp.take_along_axis(all_v, order, axis=0)
    top_i = xp.take_along_axis(all_i, order, axis=0)
    mask = v <= min_m[None, :] * (1.0 + bound)
    return (min_e, min_t, min_m, argm, top_v, top_i), mask


_jitted_reduce_step = None


def _jax_reduce_step():
    """Jitted running reduction: a chunk's [chunk, n_net] energies are
    folded into the state on device — only the small state, the boundary
    mask, and the masked candidate rows ever leave it."""
    global _jitted_reduce_step
    if _jitted_reduce_step is None:
        import jax
        import jax.numpy as jnp

        def red(metric, topk, e, t, state, base, m_valid, bound):
            _JIT_STATS["traces"] += 1        # runs only while tracing
            return _stream_reduce_body(jnp, metric, topk, e, t, base,
                                       m_valid, bound, state)

        _jitted_reduce_step = jax.jit(red, static_argnums=(0, 1))
    return _jitted_reduce_step


def stream_networks(grid: ConfigGrid,
                    networks: Mapping[str, Sequence[Layer]],
                    *,
                    chunk_size: int = 4096,
                    use_jax: bool | None = None,
                    backend: str | None = None,
                    shard: bool = False,
                    bound: float = 0.05,
                    metric: str = "edp",
                    topk: int = 16,
                    resume_from: "StreamFoldState | Mapping | None" = None,
                    on_chunk=None,
                    nan_guard: bool = True,
                    verify=None) -> StreamResult:
    """Chunked streaming sweep with on-device running reductions.

    Never materialises the full ``[n_cfg, n_net]`` matrices: each chunk is
    evaluated (optionally sharded across host devices) and folded into
    per-network running minima, top-k cells, and ≤``bound`` boundary
    candidate sets.  Equivalent to reducing :func:`evaluate_networks`'s
    output, at bounded memory.  ``backend`` routes the per-chunk kernel
    like :func:`evaluate_networks` (pallas / jax / numpy, auto-fallback).

    Crash-safety: ``on_chunk`` receives a :class:`StreamFoldState` after
    every folded chunk; pass one back as ``resume_from=`` to restart from
    the first unfolded chunk — the resumed result is bit-identical to an
    uninterrupted run, and a state exported from different inputs is
    rejected (:class:`StreamStateError`).  ``nan_guard`` checks every
    chunk for NaN/inf before folding (:class:`ChunkCorruption`).

    ``verify=`` accepts a :class:`repro.ft.verify.StreamVerifier` (duck-
    typed: ``bind`` / ``check_resume`` / ``check_chunk`` / ``check_fold``)
    — fold-invariant checks and sampled numpy-reference shadow recomputes
    run per chunk BEFORE the new state commits, so a finite silent
    corruption raises instead of poisoning the fold.
    """
    global _LAST_BACKEND
    backend = resolve_backend(backend, use_jax)
    _LAST_BACKEND = backend
    use_jax = backend != "numpy"
    names = tuple(networks)
    n_net = len(names)
    lay, segments = _stack_networks(networks)
    lay = {k: v[None, :] for k, v in lay.items()}
    fields = grid.fields if isinstance(grid, ConfigGrid) else dict(grid)
    n = int(next(iter(fields.values())).shape[0])
    chunk = max(1, min(chunk_size, n))
    n_dev = host_device_count() if (shard and use_jax) else 1
    n_chunks = -(-n // chunk)
    ihash = stream_input_hash(fields, networks, kind="networks",
                              metric=metric, bound=bound, topk=topk,
                              chunk=chunk)

    state = (np.full(n_net, np.inf), np.full(n_net, np.inf),
             np.full(n_net, np.inf), np.full(n_net, -1, np.int64),
             np.full((topk, n_net), np.inf),
             np.full((topk, n_net), -1, np.int64))
    cand: Dict[str, list] = {nm: [] for nm in names}
    done = 0
    if resume_from is not None:
        state, cand, done = _resume_fold(resume_from, kind="networks",
                                         ihash=ihash, names=names)
    if verify is not None:
        verify.bind(kind="networks", names=names, metric=metric,
                    topk=topk, bound=bound, backend=backend,
                    ref_eval=lambda fc: _eval_fields(
                        fc, lay, segments, "numpy", False,
                        _UNIQUE_BUCKET, _MAPPING_BUCKET))
        if resume_from is not None:
            verify.check_resume(state, cand)

    def emit(ci):
        if on_chunk is None:
            return
        on_chunk(StreamFoldState(
            kind="networks", input_hash=ihash, next_chunk=ci + 1,
            n_chunks=n_chunks, chunk_size=chunk, n_cfg=n, networks=names,
            metric=metric, bound=bound, topk=topk, state=state,
            cand={nm: list(v) for nm, v in cand.items()}))

    def collect(mask, e, t, start):
        rows_i, cols_i = np.nonzero(mask)
        for j in range(n_net):
            sel = rows_i[cols_i == j]
            if sel.size:
                cand[names[j]].append((start + sel, e[sel, j], t[sel, j]))

    def chunks():
        for ci, start in enumerate(range(0, n, chunk)):
            if ci < done:
                continue
            stop = min(start + chunk, n)
            fc = {k: _pad_rows(v[start:stop], chunk)
                  for k, v in fields.items()}
            yield ci, start, stop, fc

    if not use_jax:
        for ci, start, stop, fc in chunks():
            cfg_m, cfg_u, inv_m, inv, coefs = _prepare_fields(
                fc, _UNIQUE_BUCKET, _MAPPING_BUCKET)
            e, t = _np_grid_kernel(segments, cfg_m, cfg_u, lay, inv_m,
                                   inv, coefs)
            e, t = _apply_chunk_hook(ci, e, t)
            if nan_guard:
                _guard_chunk(ci, start, stop, e, t, names)
            if verify is not None:      # raises BEFORE the fold commits
                verify.check_chunk(ci, start, stop, fc, e, t)
            new_state, mask = _stream_reduce_body(
                np, metric, topk, e, t, start, stop - start, bound, state)
            if verify is not None:
                verify.check_fold(ci, start, stop, state, new_state,
                                  es=e, ts=t, mask=mask)
            state = new_state
            collect(mask, e, t, start)
            emit(ci)
    else:
        # Round-robin the chunk kernels across devices (async dispatch);
        # the cheap stateful reduction runs in chunk order on device 0.
        import jax
        from jax.experimental import enable_x64
        devs = jax.devices()
        pending: list = []

        with enable_x64():
            def reduce_one(item):
                nonlocal state
                ci, start, stop, e_d, t_d, fc = item
                if n_dev > 1:
                    e_d = jax.device_put(e_d, devs[0])
                    t_d = jax.device_put(t_d, devs[0])
                e_d, t_d = _apply_chunk_hook(ci, e_d, t_d)
                if nan_guard:
                    _guard_chunk(ci, start, stop, e_d, t_d, names)
                if verify is not None:  # raises BEFORE the fold commits
                    verify.check_chunk(ci, start, stop, fc, e_d, t_d)
                _JIT_STATS["calls"] += 1
                new_state, mask = _jax_reduce_step()(
                    metric, topk, e_d, t_d, state, np.int64(start),
                    np.int64(stop - start), float(bound))
                if verify is not None:
                    verify.check_fold(ci, start, stop, state, new_state,
                                      es=np.asarray(e_d),
                                      ts=np.asarray(t_d),
                                      mask=np.asarray(mask))
                state = new_state
                # only the boundary mask and the hit rows cross to the
                # host — the [chunk, n_net] matrices stay on device
                rows_i, cols_i = np.nonzero(np.asarray(mask))
                if rows_i.size:
                    urows = np.unique(rows_i)
                    e_h = np.asarray(e_d[urows, :])
                    t_h = np.asarray(t_d[urows, :])
                    pos = np.searchsorted(urows, rows_i)
                    for j in range(n_net):
                        m = cols_i == j
                        if m.any():
                            cand[names[j]].append(
                                (start + rows_i[m], e_h[pos[m], j],
                                 t_h[pos[m], j]))
                emit(ci)

            for ci, start, stop, fc in chunks():
                dev = devs[ci % n_dev] if n_dev > 1 else None
                e_d, t_d = _dispatch_chunk(fc, lay, segments, dev, backend)
                pending.append((ci, start, stop, e_d, t_d,
                                fc if verify is not None else None))
                if len(pending) > 2 * n_dev:
                    reduce_one(pending.pop(0))
            for item in pending:
                reduce_one(item)

    min_e, min_t, min_m, argm, top_v, top_i = (
        np.asarray(s) for s in state)

    b_idx, b_e, b_t = {}, {}, {}
    for j, nm in enumerate(names):
        if cand[nm]:
            idx = np.concatenate([c[0] for c in cand[nm]])
            ee = np.concatenate([c[1] for c in cand[nm]])
            tt = np.concatenate([c[2] for c in cand[nm]])
        else:                                          # pragma: no cover
            idx, ee, tt = (np.zeros(0, np.int64),) + (np.zeros(0),) * 2
        v = _metric_of(metric, ee, tt)
        keep = v <= min_m[j] * (1.0 + bound)   # prune to the final min
        idx, ee, tt, v = idx[keep], ee[keep], tt[keep], v[keep]
        order = np.argsort(v, kind="stable")
        b_idx[nm], b_e[nm], b_t[nm] = idx[order], ee[order], tt[order]

    return StreamResult(
        networks=names, n_cfg=n, metric=metric, bound=bound,
        min_energy=min_e, min_latency=min_t, min_metric=min_m,
        argmin=argm, topk_idx=top_i, topk_metric=top_v,
        boundary_idx=b_idx, boundary_energy=b_e, boundary_latency=b_t)


# ---------------------------------------------------------------------------
# Streaming per-layer reduction: the per-layer tensors of a mega-scale sweep
# are far too large to keep ([n_cfg, n_net, n_layer] at 49k points × 18 nets
# × 256 layers ≈ 1.8 GB each), but the co-design consumers only ever need
# the per-layer rows of the few near-optimal configs per network plus the
# ≤bound boundary candidate sets.  This variant evaluates chunk by chunk in
# per-layer mode and folds each chunk ON DEVICE into (a) a running
# per-network top-k that KEEPS the [n_layer] energy/latency rows of the
# current top-k configs only, (b) running per-network minima of energy /
# latency / EDP / the selected metric, (c) running per-(network, layer)
# metric minima, and (d) — with ``bound=`` — the ≤bound threshold mask
# whose hits become the per-network boundary sets
# ``repro.core.hetero.codesign_problems_streaming`` builds its candidate
# pool from.  One mega-grid pass therefore emits exactly the co-design
# candidate pool without ever materialising [n_cfg, n_net, n_layer].
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerTopK:
    """Running reductions of a streamed per-layer sweep.

    The boundary-set fields are ``None`` unless the sweep ran with a
    ``bound=``; everything else is always populated.  ``topk_idx`` ranks
    by (metric, flat index) — ties break toward the LOWER grid index, so
    the result is invariant to the chunk size."""

    networks: Tuple[str, ...]
    n_cfg: int
    metric: str
    layer_counts: np.ndarray        # [n_net] valid lengths of the layer axis
    topk_idx: np.ndarray            # [k, n_net] flat grid indices, best first
    topk_metric: np.ndarray         # [k, n_net]
    layer_energy: np.ndarray        # [k, n_net, n_layer]
    layer_latency: np.ndarray       # [k, n_net, n_layer]
    # -- aggregate running minima (refs for co-design chip scoring) --------
    min_energy: np.ndarray | None = None      # [n_net]
    min_latency: np.ndarray | None = None     # [n_net]
    min_edp: np.ndarray | None = None         # [n_net]
    min_metric: np.ndarray | None = None      # [n_net]
    argmin: np.ndarray | None = None          # [n_net] flat index of min
    # -- per-(network, layer) running minima -------------------------------
    layer_min_metric: np.ndarray | None = None   # [n_net, n_layer]
    layer_argmin: np.ndarray | None = None       # [n_net, n_layer]
    # -- ≤bound boundary sets (None when bound was not requested) ----------
    bound: float | None = None
    boundary_idx: Dict[str, np.ndarray] | None = None   # sorted by metric
    boundary_energy: Dict[str, np.ndarray] | None = None
    boundary_latency: Dict[str, np.ndarray] | None = None

    def boundary_metric(self, name: str) -> np.ndarray:
        if self.boundary_energy is None:
            raise ValueError("this stream carries no boundary sets — "
                             "run stream_layer_topk with bound=")
        return _metric_of(self.metric, self.boundary_energy[name],
                          self.boundary_latency[name])


def _layer_reduce_body(xp, metric, topk, e, t, base, m_valid, bound,
                       lay_valid, state):
    """Fold one [chunk, n_net, n_layer] per-layer evaluation into the
    running state; returns ``(state, mask, es, ts)`` where ``mask`` is
    the ≤bound threshold mask against the *updated* running minimum (a
    superset of the final boundary set — pruned at the end) and
    ``es``/``ts`` are the layer-summed [chunk, n_net] aggregates the
    boundary collection reads.  Padded chunk rows (index ≥ ``m_valid``)
    are masked to +inf so they never win a reduction; ``lay_valid`` masks
    each network's zero-padded layer tail out of the per-layer minima."""
    (top_v, top_i, top_e, top_t, min_e, min_t, min_edp, min_m, argm,
     lmin, larg) = state
    rows = xp.arange(e.shape[0])
    invalid = (rows >= m_valid)[:, None]
    es = xp.where(invalid, np.inf, e.sum(-1))
    ts = xp.where(invalid, np.inf, t.sum(-1))
    v = _metric_of(metric, es, ts)
    min_e = xp.minimum(min_e, es.min(axis=0))
    min_t = xp.minimum(min_t, ts.min(axis=0))
    min_edp = xp.minimum(min_edp, xp.where(invalid, np.inf,
                                           es * ts).min(axis=0))
    cmin = v.min(axis=0)
    better = cmin < min_m
    min_m = xp.where(better, cmin, min_m)
    argm = xp.where(better, base + xp.argmin(v, axis=0), argm)

    # per-(network, layer) metric minima; strict < keeps the earlier
    # (lower-index) config on ties, so this too is chunk-size-invariant
    vl = _metric_of(metric, e, t)
    vl = xp.where(invalid[:, :, None] | ~lay_valid[None, :, :], np.inf, vl)
    clmin = vl.min(axis=0)
    lbetter = clmin < lmin
    lmin = xp.where(lbetter, clmin, lmin)
    larg = xp.where(lbetter, base + xp.argmin(vl, axis=0), larg)

    # top-k fold with the per-layer rows gathered alongside; the same
    # (value, index) lexsort tie-break as _stream_reduce_body
    idx = xp.broadcast_to((base + rows)[:, None], v.shape)
    all_v = xp.concatenate([top_v, v], axis=0)
    all_i = xp.concatenate([top_i, idx], axis=0)
    order = xp.lexsort((all_i, all_v), axis=0)[:topk]
    top_v = xp.take_along_axis(all_v, order, axis=0)
    top_i = xp.take_along_axis(all_i, order, axis=0)
    all_e = xp.concatenate([top_e, e], axis=0)
    all_t = xp.concatenate([top_t, t], axis=0)
    top_e = xp.take_along_axis(all_e, order[:, :, None], axis=0)
    top_t = xp.take_along_axis(all_t, order[:, :, None], axis=0)

    mask = v <= min_m[None, :] * (1.0 + bound)
    state = (top_v, top_i, top_e, top_t, min_e, min_t, min_edp, min_m,
             argm, lmin, larg)
    return state, mask, es, ts


_jitted_layer_reduce = None


def _jax_layer_reduce_step():
    """Jitted streaming per-layer reduction: a chunk's
    [chunk, n_net, n_layer] tensors fold into the state on device — only
    the small state, the boundary mask, and the [chunk, n_net] aggregates
    ever cross to the host."""
    global _jitted_layer_reduce
    if _jitted_layer_reduce is None:
        import jax

        def red(metric, topk, e, t, state, base, m_valid, bound,
                lay_valid):
            _JIT_STATS["traces"] += 1        # runs only while tracing
            import jax.numpy as jnp
            return _layer_reduce_body(jnp, metric, topk, e, t, base,
                                      m_valid, bound, lay_valid, state)

        _jitted_layer_reduce = jax.jit(red, static_argnums=(0, 1))
    return _jitted_layer_reduce


def stream_layer_topk(grid: ConfigGrid,
                      networks: Mapping[str, Sequence[Layer]],
                      *,
                      topk: int = 8,
                      chunk_size: int = 4096,
                      use_jax: bool | None = None,
                      backend: str | None = None,
                      shard: bool = False,
                      metric: str = "edp",
                      bound: float | None = None,
                      resume_from: "StreamFoldState | Mapping | None" = None,
                      on_chunk=None,
                      nan_guard: bool = True,
                      verify=None) -> LayerTopK:
    """Streamed per-layer sweep: one pass, every co-design reduction.

    Equivalent to ``evaluate_networks(..., per_layer=True)`` followed by
    per-network reductions on the layer-summed metric — at bounded
    memory: only one chunk's ``[chunk, n_net, n_layer]`` tensors are ever
    alive (the jax path folds each chunk on device through one jitted
    step), and the state carries ``k`` per-layer rows per network plus
    the running aggregate / per-(network, layer) minima.  With
    ``bound=``, the ≤bound threshold mask is maintained alongside and the
    result carries the per-network boundary candidate sets (flat indices
    + aggregate energy/latency, metric-sorted) — exactly the candidate
    pool inputs :func:`repro.core.hetero.codesign_problems_streaming`
    consumes, so a 49,000-point mega grid feeds the co-design search
    without materialising ``[n_cfg, n_net, n_layer]``.  Ties rank by
    lower flat grid index everywhere (chunk-size-invariant).

    Crash-safety: ``on_chunk`` receives a :class:`StreamFoldState` after
    every folded chunk; pass one back as ``resume_from=`` to restart from
    the first unfolded chunk — the resumed result is bit-identical to an
    uninterrupted run, and a state exported from different inputs is
    rejected (:class:`StreamStateError`).  ``nan_guard`` checks every
    chunk's layer-summed aggregates for NaN/inf before the fold commits
    (:class:`ChunkCorruption` with chunk provenance); ``verify=`` takes a
    :class:`repro.ft.verify.StreamVerifier` for the finite-corruption
    rungs — per-chunk fold-invariant checks and sampled numpy-reference
    shadow recomputes, both raising BEFORE the poisoned state commits."""
    global _LAST_BACKEND
    backend = resolve_backend(backend, use_jax)
    _LAST_BACKEND = backend
    names = tuple(networks)
    n_net = len(names)
    lay, segments = _stack_networks(networks, absorb_pad=False)
    lay = {k: v[None, :] for k, v in lay.items()}
    n_layer = _layer_axis_len(segments)
    fields = grid.fields if isinstance(grid, ConfigGrid) else dict(grid)
    n = int(next(iter(fields.values())).shape[0])
    chunk = max(1, min(chunk_size, n))
    lay_counts = network_layer_counts(networks)
    lay_valid = np.arange(n_layer)[None, :] < lay_counts[:, None]

    k = int(topk)
    state = (np.full((k, n_net), np.inf),              # top_v
             np.full((k, n_net), -1, np.int64),        # top_i
             np.zeros((k, n_net, n_layer)),            # top_e
             np.zeros((k, n_net, n_layer)),            # top_t
             np.full(n_net, np.inf),                   # min_energy
             np.full(n_net, np.inf),                   # min_latency
             np.full(n_net, np.inf),                   # min_edp
             np.full(n_net, np.inf),                   # min_metric
             np.full(n_net, -1, np.int64),             # argmin
             np.full((n_net, n_layer), np.inf),        # layer_min_metric
             np.full((n_net, n_layer), -1, np.int64))  # layer_argmin
    b = 0.0 if bound is None else float(bound)
    cand: Dict[str, list] = {nm: [] for nm in names}
    n_chunks = -(-n // chunk)
    ihash = stream_input_hash(fields, networks, kind="layer_topk",
                              metric=metric, bound=bound, topk=k,
                              chunk=chunk)
    done = 0
    if resume_from is not None:
        state, cand, done = _resume_fold(resume_from, kind="layer_topk",
                                         ihash=ihash, names=names)
    if verify is not None:
        verify.bind(kind="layer_topk", names=names, metric=metric,
                    topk=k, bound=bound, backend=backend,
                    ref_eval=lambda fc: _eval_fields(
                        fc, lay, segments, "numpy", False,
                        _UNIQUE_BUCKET, _MAPPING_BUCKET, per_layer=True))
        if resume_from is not None:
            verify.check_resume(state, cand)

    def emit(ci):
        if on_chunk is None:
            return
        on_chunk(StreamFoldState(
            kind="layer_topk", input_hash=ihash, next_chunk=ci + 1,
            n_chunks=n_chunks, chunk_size=chunk, n_cfg=n, networks=names,
            metric=metric, bound=bound, topk=k, state=state,
            cand={nm: list(v) for nm, v in cand.items()}))

    def collect(mask, es, ts, start):
        if bound is None:
            return
        rows_i, cols_i = np.nonzero(np.asarray(mask))
        if not rows_i.size:
            return
        es, ts = np.asarray(es), np.asarray(ts)
        for j in range(n_net):
            sel = rows_i[cols_i == j]
            if sel.size:
                cand[names[j]].append((start + sel, es[sel, j],
                                       ts[sel, j]))

    def chunks():
        for ci, start in enumerate(range(0, n, chunk)):
            if ci < done:
                continue
            stop = min(start + chunk, n)
            fc = {k_: _pad_rows(v[start:stop], chunk)
                  for k_, v in fields.items()}
            yield ci, start, stop, fc

    if backend == "numpy":
        for ci, start, stop, fc in chunks():
            ec, tc = _eval_fields(fc, lay, segments, "numpy", False,
                                  _UNIQUE_BUCKET, _MAPPING_BUCKET,
                                  per_layer=True)
            ec, tc = _apply_chunk_hook(ci, ec, tc)
            if nan_guard:     # raises BEFORE the fold commits
                _guard_chunk(ci, start, stop, ec.sum(axis=2),
                             tc.sum(axis=2), names)
            if verify is not None:
                verify.check_chunk(ci, start, stop, fc, ec, tc)
            new_state, mask, es, ts = _layer_reduce_body(
                np, metric, k, ec, tc, start, stop - start, b,
                lay_valid, state)
            if verify is not None:
                verify.check_fold(ci, start, stop, state, new_state,
                                  es=es, ts=ts, mask=mask)
            state = new_state
            collect(mask, es, ts, start)
            emit(ci)
    else:
        import jax
        from jax.experimental import enable_x64
        devs = jax.devices()
        n_dev = host_device_count() if shard else 1
        pending: list = []
        with enable_x64():
            def reduce_one(item):
                nonlocal state
                ci, start, stop, e_d, t_d, fc = item
                if n_dev > 1:
                    e_d = jax.device_put(e_d, devs[0])
                    t_d = jax.device_put(t_d, devs[0])
                e_d, t_d = _apply_chunk_hook(ci, e_d, t_d)
                _JIT_STATS["calls"] += 1
                new_state, mask, es, ts = _jax_layer_reduce_step()(
                    metric, k, e_d, t_d, state, np.int64(start),
                    np.int64(stop - start), float(b), lay_valid)
                if nan_guard:     # raises BEFORE the fold commits
                    _guard_chunk(ci, start, stop, es, ts, names)
                if verify is not None:
                    verify.check_chunk(ci, start, stop, fc,
                                       np.asarray(e_d), np.asarray(t_d))
                    verify.check_fold(ci, start, stop, state, new_state,
                                      es=np.asarray(es),
                                      ts=np.asarray(ts),
                                      mask=np.asarray(mask))
                state = new_state
                collect(mask, es, ts, start)
                emit(ci)

            for ci, start, stop, fc in chunks():
                dev = devs[ci % n_dev] if n_dev > 1 else None
                ec, tc = _dispatch_chunk(fc, lay, segments, dev, backend,
                                         per_layer=True)
                pending.append((ci, start, stop, ec, tc,
                                fc if verify is not None else None))
                if len(pending) > 2 * n_dev:
                    reduce_one(pending.pop(0))
            for item in pending:
                reduce_one(item)

    (top_v, top_i, top_e, top_t, min_e, min_t, min_edp, min_m, argm,
     lmin, larg) = (np.asarray(s) for s in state)

    b_idx = b_e = b_t = None
    if bound is not None:
        b_idx, b_e, b_t = {}, {}, {}
        for j, nm in enumerate(names):
            if cand[nm]:
                idx = np.concatenate([c[0] for c in cand[nm]])
                ee = np.concatenate([c[1] for c in cand[nm]])
                tt = np.concatenate([c[2] for c in cand[nm]])
            else:                                      # pragma: no cover
                idx, ee, tt = (np.zeros(0, np.int64),) + (np.zeros(0),) * 2
            v = _metric_of(metric, ee, tt)
            keep = v <= min_m[j] * (1.0 + b)   # prune to the final min
            idx, ee, tt, v = idx[keep], ee[keep], tt[keep], v[keep]
            order = np.lexsort((idx, v))       # metric, then lower index
            b_idx[nm], b_e[nm], b_t[nm] = idx[order], ee[order], tt[order]

    return LayerTopK(
        networks=names, n_cfg=n, metric=metric,
        layer_counts=lay_counts,
        topk_idx=top_i, topk_metric=top_v,
        layer_energy=top_e, layer_latency=top_t,
        min_energy=min_e, min_latency=min_t, min_edp=min_edp,
        min_metric=min_m, argmin=argm,
        layer_min_metric=lmin, layer_argmin=larg,
        bound=bound, boundary_idx=b_idx,
        boundary_energy=b_e, boundary_latency=b_t)


def _shift_idx(idx: np.ndarray, offset: int) -> np.ndarray:
    """Shift flat grid indices by ``offset``, preserving -1 sentinels."""
    idx = np.asarray(idx)
    return np.where(idx >= 0, idx + offset, idx)


def merge_layer_topk(a: LayerTopK, b: LayerTopK) -> LayerTopK:
    """Fold two completed streamed sweeps over consecutive grid-row ranges.

    ``a`` covers rows ``[0, a.n_cfg)`` of some grid and ``b`` the APPENDED
    rows ``[a.n_cfg, a.n_cfg + b.n_cfg)`` streamed as a standalone grid
    (its flat indices are local, so they are shifted by ``a.n_cfg`` here).
    Because every streamed reduction tie-breaks by (value, flat index),
    the fold is split-point-invariant: the merge is BIT-identical to
    re-streaming the concatenated grid from scratch — this is the
    incremental-grid-delta entry point
    :meth:`repro.serving.dse_service.DSEService.extend_grid` folds
    appended config rows through.

    Boundary-set exactness: each part's sets were pruned against its own
    running minimum; the merged threshold ``min(a_min, b_min)·(1+bound)``
    is no looser than either part's, so every merged-boundary row was
    already retained by its part — nothing pruned early is ever needed.
    """
    if a.networks != b.networks:
        raise ValueError(
            f"cannot merge streams over different network sets "
            f"{a.networks} vs {b.networks}")
    if a.metric != b.metric or a.bound != b.bound:
        raise ValueError(
            f"cannot merge streams with different reduction parameters: "
            f"(metric, bound) = ({a.metric!r}, {a.bound}) vs "
            f"({b.metric!r}, {b.bound})")
    if a.topk_idx.shape != b.topk_idx.shape:
        raise ValueError(
            f"cannot merge streams with different top-k sizes "
            f"{a.topk_idx.shape[0]} vs {b.topk_idx.shape[0]}")
    off = int(a.n_cfg)
    k = a.topk_idx.shape[0]

    # -- top-k with the per-layer rows gathered alongside ------------------
    all_v = np.concatenate([a.topk_metric, b.topk_metric], axis=0)
    all_i = np.concatenate([a.topk_idx, _shift_idx(b.topk_idx, off)],
                           axis=0)
    order = np.lexsort((all_i, all_v), axis=0)[:k]
    top_v = np.take_along_axis(all_v, order, axis=0)
    top_i = np.take_along_axis(all_i, order, axis=0)
    all_e = np.concatenate([a.layer_energy, b.layer_energy], axis=0)
    all_t = np.concatenate([a.layer_latency, b.layer_latency], axis=0)
    top_e = np.take_along_axis(all_e, order[:, :, None], axis=0)
    top_t = np.take_along_axis(all_t, order[:, :, None], axis=0)

    # -- aggregate minima: strict < keeps the LOWER-index (a) side on ties
    better = b.min_metric < a.min_metric
    min_m = np.where(better, b.min_metric, a.min_metric)
    argm = np.where(better, _shift_idx(b.argmin, off), a.argmin)
    lbetter = b.layer_min_metric < a.layer_min_metric
    lmin = np.where(lbetter, b.layer_min_metric, a.layer_min_metric)
    larg = np.where(lbetter, _shift_idx(b.layer_argmin, off),
                    a.layer_argmin)

    b_idx = b_e = b_t = None
    if a.bound is not None:
        bd = float(a.bound)
        b_idx, b_e, b_t = {}, {}, {}
        for j, nm in enumerate(a.networks):
            idx = np.concatenate([a.boundary_idx[nm],
                                  b.boundary_idx[nm] + off])
            ee = np.concatenate([a.boundary_energy[nm],
                                 b.boundary_energy[nm]])
            tt = np.concatenate([a.boundary_latency[nm],
                                 b.boundary_latency[nm]])
            v = _metric_of(a.metric, ee, tt)
            keep = v <= min_m[j] * (1.0 + bd)   # prune to the merged min
            idx, ee, tt, v = idx[keep], ee[keep], tt[keep], v[keep]
            order = np.lexsort((idx, v))        # metric, then lower index
            b_idx[nm], b_e[nm], b_t[nm] = idx[order], ee[order], tt[order]

    return LayerTopK(
        networks=a.networks, n_cfg=off + int(b.n_cfg), metric=a.metric,
        layer_counts=a.layer_counts,
        topk_idx=top_i, topk_metric=top_v,
        layer_energy=top_e, layer_latency=top_t,
        min_energy=np.minimum(a.min_energy, b.min_energy),
        min_latency=np.minimum(a.min_latency, b.min_latency),
        min_edp=np.minimum(a.min_edp, b.min_edp),
        min_metric=min_m, argmin=argm,
        layer_min_metric=lmin, layer_argmin=larg,
        bound=a.bound, boundary_idx=b_idx,
        boundary_energy=b_e, boundary_latency=b_t)


def simulate_grid(configs: Sequence[AcceleratorConfig] | ConfigGrid,
                  layers: Sequence[Layer], use_jax: bool = False,
                  backend: str | None = None):
    """Vectorised sweep: returns (energy, latency) arrays of shape [n_cfg].

    ``use_jax=True`` evaluates the whole design space inside the batched,
    module-level jit-cached engine under 64-bit mode (counts exceed
    float32's integer range); repeated same-shape sweeps reuse the compile.
    ``backend`` overrides the kernel choice (pallas / jax / numpy).
    """
    grid = (configs if isinstance(configs, ConfigGrid)
            else ConfigGrid.from_configs(configs))
    e, t = evaluate_networks(grid, {"net": layers}, use_jax=use_jax,
                             backend=backend)
    return e[:, 0], t[:, 0]
