"""Accelerator configuration for the array-based DNN accelerator simulator.

This mirrors §II.B.1 of the paper ("Tool's inputs"): PE-array geometry, the
global-buffer partition (GB_ifmap / GB_psum / GB_weight), register-file sizes,
per-access energy & latency for every memory level, MAC energy/latency, NoC
delivery bandwidth, and the storage/compute bit width.

The paper calibrates per-access numbers with CACTI and a synthesized MAC; the
absolute values are therefore foundry/library-specific.  What the paper *does*
pin down (§II, "the energy cost of the memory hierarchy from register files to
DRAM is incremental ... DRAM ≈ several tens of RF, GB ≈ 5–10× RF") is the
*ratio structure*, which is what all of its observations and tables depend on.
``EnergyTable.cacti_like`` reproduces that structure with a capacity-dependent
global-buffer model (energy/latency grow ~sqrt(capacity), the usual SRAM
scaling CACTI reports to first order).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

# The exact sweep values used throughout §III / §IV of the paper.
GB_SIZES_KB: Tuple[int, ...] = (13, 27, 54, 108, 216)
ARRAY_SIZES: Tuple[Tuple[int, int], ...] = (
    (12, 14), (16, 16), (32, 32), (64, 64), (128, 128), (256, 256))


@dataclasses.dataclass(frozen=True)
class EnergyTable:
    """Per-access energy (pJ) and latency (ns) for each memory level.

    All values are *per word* of the configured bit width (the tool counts
    word-granularity accesses; wider interfaces are modelled by the
    ``words_per_cycle`` fields on :class:`AcceleratorConfig`).
    """

    rf_read: float = 1.0           # register file (scratch pad) read
    rf_write: float = 1.0
    gb_read: float = 6.0           # global buffer @ reference capacity
    gb_write: float = 6.0
    dram_read: float = 200.0       # off-chip DRAM (Eyeriss-published ratio;
    dram_write: float = 200.0      # the paper says "several tens of" RF)
    mac: float = 1.0               # one multiply-accumulate
    pe_idle: float = 0.02          # per-PE per-cycle clock/leakage energy
    noc_hop: float = 0.05          # per-word-per-hop transfer energy

    rf_t: float = 1.0              # ns per access
    gb_t: float = 2.0
    dram_t: float = 20.0
    mac_t: float = 1.0             # ns per MAC (pipelined PEs: 1/cycle)

    gb_ref_kb: float = 54.0        # capacity at which gb_read/gb_write hold

    def gb_energy(self, size_kb: float) -> float:
        """Capacity-scaled GB access energy (CACTI first-order ~sqrt(cap))."""
        return self.gb_read * math.sqrt(max(size_kb, 1.0) / self.gb_ref_kb)

    def gb_latency(self, size_kb: float) -> float:
        return self.gb_t * math.sqrt(math.sqrt(max(size_kb, 1.0) / self.gb_ref_kb))


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """One processing core (Fig. 2): PE array + RF / GB / DRAM hierarchy."""

    array_rows: int = 16
    array_cols: int = 16
    gb_ifmap_kb: float = 54.0      # GB partition for input feature maps
    gb_psum_kb: float = 54.0       # GB partition for partial sums
    gb_weight_kb: float = 108.0    # assumed "large enough" (§III) — held fixed
    rf_ifmap_words: int = 12       # per-PE scratch pad shares (Eyeriss-like)
    rf_weight_words: int = 224
    rf_psum_words: int = 24
    bitwidth: int = 16             # storage & compute bit width
    noc_words_per_cycle: float = 4.0   # GB->array delivery bandwidth (words/cy)
    dram_words_per_cycle: float = 1.0  # DRAM<->GB interface bandwidth
    cycle_ns: float = 1.0
    energy: EnergyTable = dataclasses.field(default_factory=EnergyTable)

    @property
    def num_pes(self) -> int:
        return self.array_rows * self.array_cols

    @property
    def bytes_per_word(self) -> float:
        return self.bitwidth / 8.0

    def gb_ifmap_words(self) -> int:
        return int(self.gb_ifmap_kb * 1024 / self.bytes_per_word)

    def gb_psum_words(self) -> int:
        return int(self.gb_psum_kb * 1024 / self.bytes_per_word)

    def replace(self, **kw) -> "AcceleratorConfig":
        return dataclasses.replace(self, **kw)

    def label(self) -> str:
        return (f"[{self.array_rows},{self.array_cols}]"
                f" psum={self.gb_psum_kb:g}KB ifmap={self.gb_ifmap_kb:g}KB")


def config_grid(
    gb_psum_kb=GB_SIZES_KB,
    gb_ifmap_kb=GB_SIZES_KB,
    arrays=ARRAY_SIZES,
    base: AcceleratorConfig | None = None,
) -> Dict[Tuple[float, float, Tuple[int, int]], AcceleratorConfig]:
    """The paper's search space: |psum| × |ifmap| × |array| configs.

    With the default arguments this is the 5 × 5 × 6 = 150-point space of §IV.
    """
    base = base or AcceleratorConfig()
    grid = {}
    for p in gb_psum_kb:
        for i in gb_ifmap_kb:
            for (r, c) in arrays:
                grid[(p, i, (r, c))] = base.replace(
                    gb_psum_kb=float(p), gb_ifmap_kb=float(i),
                    array_rows=r, array_cols=c)
    return grid
