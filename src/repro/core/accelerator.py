"""Accelerator configuration for the array-based DNN accelerator simulator.

This mirrors §II.B.1 of the paper ("Tool's inputs"): PE-array geometry, the
global-buffer partition (GB_ifmap / GB_psum / GB_weight), register-file sizes,
per-access energy & latency for every memory level, MAC energy/latency, NoC
delivery bandwidth, and the storage/compute bit width.

The paper calibrates per-access numbers with CACTI and a synthesized MAC; the
absolute values are therefore foundry/library-specific.  What the paper *does*
pin down (§II, "the energy cost of the memory hierarchy from register files to
DRAM is incremental ... DRAM ≈ several tens of RF, GB ≈ 5–10× RF") is the
*ratio structure*, which is what all of its observations and tables depend on.
``EnergyTable.cacti_like`` reproduces that structure with a capacity-dependent
global-buffer model (energy/latency grow ~sqrt(capacity), the usual SRAM
scaling CACTI reports to first order).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Sequence, Tuple

import numpy as np

# The exact sweep values used throughout §III / §IV of the paper.
GB_SIZES_KB: Tuple[int, ...] = (13, 27, 54, 108, 216)
ARRAY_SIZES: Tuple[Tuple[int, int], ...] = (
    (12, 14), (16, 16), (32, 32), (64, 64), (128, 128), (256, 256))

# Finer GB grid for the extended (≥5,000-point) design space: the paper's
# five sizes plus geometric midpoints, so the engine can resolve the
# Observation-1/2 breakpoints between the paper's coarse steps.
EXTENDED_GB_SIZES_KB: Tuple[int, ...] = (
    9, 13, 20, 27, 40, 54, 80, 108, 160, 216)
# Extended per-PE psum scratch-pad sizes (Eyeriss uses 24) and NoC delivery
# widths — the two non-GB knobs §II.B.1 lists as Tool inputs.
RF_PSUM_SIZES: Tuple[int, ...] = (16, 24, 32)
NOC_WIDTHS: Tuple[float, ...] = (2.0, 4.0, 8.0)

# The mega space (~49k points) for the sharded/chunked streaming engine:
# the full EXTENDED_GB_SIZES_KB cross continued past 216KB (the Fig. 5/6
# right-hand tails), intermediate/larger arrays, and wider RF/NoC ranges.
MEGA_GB_SIZES_KB: Tuple[int, ...] = EXTENDED_GB_SIZES_KB + (320, 432, 648, 864)
MEGA_ARRAY_SIZES: Tuple[Tuple[int, int], ...] = ARRAY_SIZES + (
    (24, 24), (48, 48), (96, 96), (192, 192))
MEGA_RF_PSUM_SIZES: Tuple[int, ...] = (8, 16, 24, 32, 48)
MEGA_NOC_WIDTHS: Tuple[float, ...] = (1.0, 2.0, 4.0, 8.0, 16.0)


@dataclasses.dataclass(frozen=True)
class EnergyTable:
    """Per-access energy (pJ) and latency (ns) for each memory level.

    All values are *per word* of the configured bit width (the tool counts
    word-granularity accesses; wider interfaces are modelled by the
    ``words_per_cycle`` fields on :class:`AcceleratorConfig`).
    """

    rf_read: float = 1.0           # register file (scratch pad) read
    rf_write: float = 1.0
    gb_read: float = 6.0           # global buffer @ reference capacity
    gb_write: float = 6.0
    dram_read: float = 200.0       # off-chip DRAM (Eyeriss-published ratio;
    dram_write: float = 200.0      # the paper says "several tens of" RF)
    mac: float = 1.0               # one multiply-accumulate
    pe_idle: float = 0.02          # per-PE per-cycle clock/leakage energy
    noc_hop: float = 0.05          # per-word-per-hop transfer energy

    rf_t: float = 1.0              # ns per access
    gb_t: float = 2.0
    dram_t: float = 20.0
    mac_t: float = 1.0             # ns per MAC (pipelined PEs: 1/cycle)

    gb_ref_kb: float = 54.0        # capacity at which gb_read/gb_write hold

    def gb_energy(self, size_kb: float) -> float:
        """Capacity-scaled GB access energy (CACTI first-order ~sqrt(cap))."""
        return self.gb_read * math.sqrt(max(size_kb, 1.0) / self.gb_ref_kb)

    def gb_latency(self, size_kb: float) -> float:
        return self.gb_t * math.sqrt(math.sqrt(max(size_kb, 1.0) / self.gb_ref_kb))


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """One processing core (Fig. 2): PE array + RF / GB / DRAM hierarchy."""

    array_rows: int = 16
    array_cols: int = 16
    gb_ifmap_kb: float = 54.0      # GB partition for input feature maps
    gb_psum_kb: float = 54.0       # GB partition for partial sums
    gb_weight_kb: float = 108.0    # assumed "large enough" (§III) — held fixed
    rf_ifmap_words: int = 12       # per-PE scratch pad shares (Eyeriss-like)
    rf_weight_words: int = 224
    rf_psum_words: int = 24
    bitwidth: int = 16             # storage & compute bit width
    noc_words_per_cycle: float = 4.0   # GB->array delivery bandwidth (words/cy)
    dram_words_per_cycle: float = 1.0  # DRAM<->GB interface bandwidth
    cycle_ns: float = 1.0
    energy: EnergyTable = dataclasses.field(default_factory=EnergyTable)

    @property
    def num_pes(self) -> int:
        return self.array_rows * self.array_cols

    @property
    def bytes_per_word(self) -> float:
        return self.bitwidth / 8.0

    def gb_ifmap_words(self) -> int:
        return int(self.gb_ifmap_kb * 1024 / self.bytes_per_word)

    def gb_psum_words(self) -> int:
        return int(self.gb_psum_kb * 1024 / self.bytes_per_word)

    def replace(self, **kw) -> "AcceleratorConfig":
        return dataclasses.replace(self, **kw)

    def label(self) -> str:
        return (f"[{self.array_rows},{self.array_cols}]"
                f" psum={self.gb_psum_kb:g}KB ifmap={self.gb_ifmap_kb:g}KB")


# ---------------------------------------------------------------------------
# Vectorised design spaces: the batched DSE engine consumes a struct-of-arrays
# ConfigGrid, never per-point AcceleratorConfig objects.
# ---------------------------------------------------------------------------

#: Primitive per-config columns of a ConfigGrid, in canonical order.  Derived
#: quantities (GB words, capacity-scaled GB energy/latency) are computed by
#: the energy model from these.
GRID_COLUMNS: Tuple[str, ...] = (
    "rows", "cols", "gb_ifmap_kb", "gb_psum_kb", "gb_weight_kb",
    "rf_ifmap_words", "rf_weight_words", "rf_psum_words", "bitwidth",
    "noc_wpc", "dram_wpc", "cycle_ns",
    "e_rf", "e_dram_r", "e_dram_w", "e_mac", "e_pe_idle", "e_noc_hop",
    "gb_e_ref", "gb_t_ref", "gb_ref_kb", "mac_t")


#: Columns that must be strictly positive: geometry, capacities, bandwidths
#: and reference latencies act as divisors or multiplicative scales in the
#: energy model — zero or negative values silently produce garbage (or
#: divide-by-zero) energies instead of an error.
_POSITIVE_COLUMNS: Tuple[str, ...] = (
    "rows", "cols", "gb_ifmap_kb", "gb_psum_kb", "gb_weight_kb",
    "rf_ifmap_words", "rf_weight_words", "rf_psum_words", "bitwidth",
    "noc_wpc", "dram_wpc", "cycle_ns", "gb_t_ref", "gb_ref_kb", "mac_t")
#: Per-access energy coefficients: zero is a legitimate ablation, negative
#: energy is not.
_NONNEGATIVE_COLUMNS: Tuple[str, ...] = (
    "e_rf", "e_dram_r", "e_dram_w", "e_mac", "e_pe_idle", "e_noc_hop",
    "gb_e_ref")


def validate_fields(fields: Dict[str, np.ndarray], *,
                    context: str = "ConfigGrid") -> None:
    """Reject NaN/inf/non-positive config parameters at the engine boundary.

    Raises ``ValueError`` naming the offending column and row index — the
    alternative is a silent garbage energy surfacing many layers later in
    a reduction or a Pareto frontier."""
    for k in GRID_COLUMNS:
        v = np.asarray(fields[k])
        bad = ~np.isfinite(v)
        if bad.any():
            i = int(np.argmax(bad))
            raise ValueError(
                f"{context}: column {k!r} row {i} is non-finite "
                f"({v.reshape(-1)[i]!r}); the energy model would silently "
                f"propagate it into every reduction")
        if k in _POSITIVE_COLUMNS:
            bad = v <= 0
            if bad.any():
                i = int(np.argmax(bad))
                raise ValueError(
                    f"{context}: column {k!r} row {i} must be > 0, got "
                    f"{v.reshape(-1)[i]!r}")
        elif k in _NONNEGATIVE_COLUMNS:
            bad = v < 0
            if bad.any():
                i = int(np.argmax(bad))
                raise ValueError(
                    f"{context}: column {k!r} row {i} must be >= 0, got "
                    f"{v.reshape(-1)[i]!r}")


def _config_row(cfg: AcceleratorConfig) -> Tuple[float, ...]:
    et = cfg.energy
    return (cfg.array_rows, cfg.array_cols, cfg.gb_ifmap_kb, cfg.gb_psum_kb,
            cfg.gb_weight_kb, cfg.rf_ifmap_words, cfg.rf_weight_words,
            cfg.rf_psum_words, cfg.bitwidth, cfg.noc_words_per_cycle,
            cfg.dram_words_per_cycle, cfg.cycle_ns,
            et.rf_read, et.dram_read, et.dram_write, et.mac, et.pe_idle,
            et.noc_hop, et.gb_read, et.gb_t, et.gb_ref_kb, et.mac_t)


@dataclasses.dataclass(frozen=True)
class ConfigGrid:
    """A design space as parallel float64 columns of length ``n``.

    This is the input format of the batched DSE engine
    (:func:`repro.core.energymodel.evaluate_networks`): the cross product is
    built directly as arrays, so a 5,000+-point space costs a handful of
    numpy ops instead of 5,000 dataclass constructions.
    """

    fields: Dict[str, np.ndarray]        # column name -> float64 [n]

    def __post_init__(self):
        n = {v.shape for v in self.fields.values()}
        if len(n) != 1:
            raise ValueError(f"ragged ConfigGrid columns: {n}")
        missing = set(GRID_COLUMNS) - set(self.fields)
        if missing:
            raise ValueError(f"ConfigGrid missing columns: {sorted(missing)}")
        validate_fields(self.fields)

    @property
    def n(self) -> int:
        return int(next(iter(self.fields.values())).shape[0])

    def __len__(self) -> int:
        return self.n

    def config_at(self, i: int, base: AcceleratorConfig | None = None
                  ) -> AcceleratorConfig:
        """Materialise one grid point as a config object (reports/labels).

        All model-relevant energy-table columns round-trip too, so
        ``simulate_network(grid.config_at(i))`` agrees with the batched
        engine even for non-default energy tables."""
        base = base or AcceleratorConfig()
        f = self.fields
        et = dataclasses.replace(
            base.energy,
            rf_read=float(f["e_rf"][i]),
            dram_read=float(f["e_dram_r"][i]),
            dram_write=float(f["e_dram_w"][i]),
            mac=float(f["e_mac"][i]), pe_idle=float(f["e_pe_idle"][i]),
            noc_hop=float(f["e_noc_hop"][i]),
            gb_read=float(f["gb_e_ref"][i]), gb_t=float(f["gb_t_ref"][i]),
            gb_ref_kb=float(f["gb_ref_kb"][i]), mac_t=float(f["mac_t"][i]))
        return base.replace(
            energy=et,
            array_rows=int(f["rows"][i]), array_cols=int(f["cols"][i]),
            gb_ifmap_kb=float(f["gb_ifmap_kb"][i]),
            gb_psum_kb=float(f["gb_psum_kb"][i]),
            gb_weight_kb=float(f["gb_weight_kb"][i]),
            rf_ifmap_words=int(f["rf_ifmap_words"][i]),
            rf_weight_words=int(f["rf_weight_words"][i]),
            rf_psum_words=int(f["rf_psum_words"][i]),
            bitwidth=int(f["bitwidth"][i]),
            noc_words_per_cycle=float(f["noc_wpc"][i]),
            dram_words_per_cycle=float(f["dram_wpc"][i]),
            cycle_ns=float(f["cycle_ns"][i]))

    def take(self, idx) -> "ConfigGrid":
        """Subset grid at the given flat indices (order preserved) — the
        streaming engine's chunking and the boundary-set consumers pull
        slices of a design space through this."""
        idx = np.asarray(idx)
        return ConfigGrid({k: v[idx] for k, v in self.fields.items()})

    def slice_rows(self, start: int, stop: int) -> "ConfigGrid":
        """Contiguous [start:stop) slice (no copy of untouched columns)."""
        return ConfigGrid({k: v[start:stop] for k, v in self.fields.items()})

    def with_columns(self, **cols) -> "ConfigGrid":
        """Copy of the grid with the named columns replaced (scalar or
        full-length array values).  The fault-scenario layer builds
        degraded core types through this — e.g. a PE array with disabled
        rows is the same config row with a shrunk ``rows`` column — and
        the constructor re-validates, so a transform can never smuggle a
        zero/NaN geometry past the engine boundary."""
        unknown = set(cols) - set(GRID_COLUMNS)
        if unknown:
            raise ValueError(f"unknown ConfigGrid columns: {sorted(unknown)}")
        fields = dict(self.fields)
        for k, v in cols.items():
            fields[k] = np.broadcast_to(
                np.asarray(v, dtype=np.float64), (self.n,)).copy()
        return ConfigGrid(fields)

    @staticmethod
    def concat(grids: Sequence["ConfigGrid"]) -> "ConfigGrid":
        """Row-wise concatenation (column order preserved) — the scenario
        expansion glues nominal chip rows and their degraded variants into
        one union grid so a single engine call evaluates them all."""
        grids = list(grids)
        if not grids:
            raise ValueError("ConfigGrid.concat needs >= 1 grid")
        return ConfigGrid({k: np.concatenate(
            [g.fields[k] for g in grids]) for k in GRID_COLUMNS})

    @classmethod
    def from_configs(cls, configs: Sequence[AcceleratorConfig]
                     ) -> "ConfigGrid":
        rows = np.asarray([_config_row(c) for c in configs], dtype=np.float64)
        return cls(dict(zip(GRID_COLUMNS, rows.T.copy())))

    @classmethod
    def product(cls,
                arrays: Sequence[Tuple[int, int]] = ARRAY_SIZES,
                gb_psum_kb: Sequence[float] = GB_SIZES_KB,
                gb_ifmap_kb: Sequence[float] = GB_SIZES_KB,
                rf_psum_words: Sequence[int] | None = None,
                noc_words_per_cycle: Sequence[float] | None = None,
                base: AcceleratorConfig | None = None) -> "ConfigGrid":
        """Cross product over (array × psum × ifmap [× rf_psum × noc]).

        Axis order (outer→inner) matches the classic ``sweep_network`` loop
        so results reshape onto the paper's [array, psum, ifmap] cube.  With
        the defaults this is the 150-point space of §IV; passing
        ``EXTENDED_GB_SIZES_KB`` / ``RF_PSUM_SIZES`` / ``NOC_WIDTHS`` grows
        it to 5,400 points.
        """
        base = base or AcceleratorConfig()
        rf_psum = ((base.rf_psum_words,) if rf_psum_words is None
                   else tuple(rf_psum_words))
        noc = ((base.noc_words_per_cycle,) if noc_words_per_cycle is None
               else tuple(noc_words_per_cycle))
        arr = np.asarray(arrays, dtype=np.float64)          # [nA, 2]
        axes = (np.arange(len(arr)), np.asarray(gb_psum_kb, np.float64),
                np.asarray(gb_ifmap_kb, np.float64),
                np.asarray(rf_psum, np.float64), np.asarray(noc, np.float64))
        ai, ps, ifm, rf, nw = [g.ravel() for g in
                               np.meshgrid(*axes, indexing="ij")]
        n = ai.size
        fields = dict(zip(GRID_COLUMNS,
                          np.tile(np.asarray(_config_row(base),
                                             np.float64)[:, None], (1, n))))
        fields["rows"] = arr[ai.astype(np.intp), 0]
        fields["cols"] = arr[ai.astype(np.intp), 1]
        fields["gb_psum_kb"] = ps
        fields["gb_ifmap_kb"] = ifm
        fields["rf_psum_words"] = rf
        fields["noc_wpc"] = nw
        return cls(fields)


def extended_grid(base: AcceleratorConfig | None = None) -> ConfigGrid:
    """The 5,400-point extended space: 6 arrays × 10² GB sizes × 3 RF_psum
    × 3 NoC widths (§II.B.1's knobs beyond the paper's 150 points)."""
    return ConfigGrid.product(
        arrays=ARRAY_SIZES, gb_psum_kb=EXTENDED_GB_SIZES_KB,
        gb_ifmap_kb=EXTENDED_GB_SIZES_KB, rf_psum_words=RF_PSUM_SIZES,
        noc_words_per_cycle=NOC_WIDTHS, base=base)


def mega_grid(base: AcceleratorConfig | None = None) -> ConfigGrid:
    """The 49,000-point mega space: 10 arrays × 14² GB sizes × 5 RF_psum
    × 5 NoC widths.  Built for the chunked/sharded streaming engine —
    evaluating it in one unchunked call would materialise multi-GB
    (unique-row × layer) intermediates."""
    return ConfigGrid.product(
        arrays=MEGA_ARRAY_SIZES, gb_psum_kb=MEGA_GB_SIZES_KB,
        gb_ifmap_kb=MEGA_GB_SIZES_KB, rf_psum_words=MEGA_RF_PSUM_SIZES,
        noc_words_per_cycle=MEGA_NOC_WIDTHS, base=base)


def config_grid(
    gb_psum_kb=GB_SIZES_KB,
    gb_ifmap_kb=GB_SIZES_KB,
    arrays=ARRAY_SIZES,
    base: AcceleratorConfig | None = None,
) -> Dict[Tuple[float, float, Tuple[int, int]], AcceleratorConfig]:
    """The paper's search space: |psum| × |ifmap| × |array| configs.

    With the default arguments this is the 5 × 5 × 6 = 150-point space of §IV.
    """
    base = base or AcceleratorConfig()
    grid = {}
    for p in gb_psum_kb:
        for i in gb_ifmap_kb:
            for (r, c) in arrays:
                grid[(p, i, (r, c))] = base.replace(
                    gb_psum_kb=float(p), gb_ifmap_kb=float(i),
                    array_rows=r, array_cols=c)
    return grid
