"""B&B-staged pipeline parallelism (the paper's §IV.B on a TPU mesh).

The paper distributes a network's layers across homogeneous cores with a
branch-and-bound search balancing per-core latency (Algorithm II); the
pipeline flows DRAM→core→DRAM.  Here the *same* algorithm
(`core.partition.bb_partition`) places transformer layers onto mesh pipeline
stages using per-layer latency estimates from the TPU cost model, and the
runtime is a GPipe schedule under ``shard_map``: activations move stage→
stage over ``collective-permute`` (the ICI analogue of the paper's
DRAM hand-off), microbatches fill the pipe, and the bubble fraction is
(S−1)/(M+S−1).

Stages hold *contiguous, possibly unequal* layer slices — exactly what B&B
produces — padded to the max stage depth with masked identity layers so the
program stays SPMD.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
try:
    from jax import shard_map                      # jax >= 0.6
except ImportError:                                # pragma: no cover
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..core.partition import Partition, bb_partition


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    n_stages: int
    boundaries: Tuple[int, ...]        # start layer of each stage
    stage_sizes: Tuple[int, ...]
    max_depth: int
    partition: Partition

    @property
    def bubble_fraction(self) -> float:
        return 0.0

    def bubble(self, n_microbatches: int) -> float:
        s = self.n_stages
        return (s - 1) / (n_microbatches + s - 1)


def plan_stages(layer_latencies, n_stages: int) -> PipelinePlan:
    """Algorithm II over per-layer latency estimates → stage plan."""
    part = bb_partition(list(layer_latencies), n_stages)
    bounds = list(part.boundaries)
    n = len(list(layer_latencies))
    sizes = [
        (bounds[i + 1] if i + 1 < len(bounds) else n) - bounds[i]
        for i in range(len(bounds))]
    return PipelinePlan(n_stages=n_stages, boundaries=tuple(bounds),
                        stage_sizes=tuple(sizes), max_depth=max(sizes),
                        partition=part)


def stage_params(stacked_params, plan: PipelinePlan):
    """[L, ...] param tree → ([S, D_max, ...] tree, mask [S, D_max]).

    Pads each stage's slice to the max depth; the mask disables the padded
    layers (identity)."""
    s, dmax = plan.n_stages, plan.max_depth
    bounds = list(plan.boundaries)
    n_layers = jax.tree.leaves(stacked_params)[0].shape[0]

    def per_leaf(x):
        outs = []
        for i in range(s):
            start = bounds[i]
            size = plan.stage_sizes[i]
            sl = x[start:start + size]
            pad = [(0, dmax - size)] + [(0, 0)] * (x.ndim - 1)
            outs.append(jnp.pad(sl, pad))
        return jnp.stack(outs)                    # [S, D_max, ...]

    mask = jnp.zeros((s, dmax), bool)
    for i in range(s):
        mask = mask.at[i, : plan.stage_sizes[i]].set(True)
    return jax.tree.map(per_leaf, stacked_params), mask


def pipeline_forward(staged_params, mask, x_micro, *, mesh: Mesh,
                     stage_axis: str, layer_fn: Callable,
                     data_axes: Tuple[str, ...] = ()):
    """GPipe schedule under shard_map.

    staged_params: [S, D_max, ...] tree (sharded on ``stage_axis`` dim 0)
    mask:          [S, D_max] layer validity
    x_micro:       [M, B_m, T, D] microbatch queue (replicated over stages,
                   optionally sharded on batch over ``data_axes``)
    layer_fn:      (layer_params, x) -> x  (one transformer block)
    Returns y_micro [M, B_m, T, D] — outputs of the final stage.
    """
    s = mesh.shape[stage_axis]
    m = x_micro.shape[0]
    ticks = m + s - 1

    def per_stage(params_blk, mask_blk, xq):
        # local blocks carry a leading length-1 stage dim
        params_blk = jax.tree.map(lambda a: a[0], params_blk)
        mask_blk = mask_blk[0]
        stage_id = jax.lax.axis_index(stage_axis)

        def apply_stage(x):
            def body(h, lp_m):
                lp, valid = lp_m
                out = layer_fn(lp, h)
                return jnp.where(valid, out, h), None

            y, _ = jax.lax.scan(body, x, (params_blk, mask_blk))
            return y

        bm, t, d = xq.shape[1:]
        zero = jnp.zeros((bm, t, d), xq.dtype)
        ys = jnp.zeros((m, bm, t, d), xq.dtype)

        def tick_fn(carry, tick):
            recv, ys = carry
            inject = jax.lax.dynamic_index_in_dim(
                xq, jnp.minimum(tick, m - 1), 0, keepdims=False)
            x_in = jnp.where(stage_id == 0, inject, recv)
            out = apply_stage(x_in)
            # stage s-1 emits its output for microbatch (tick - (s-1))
            emit_idx = jnp.clip(tick - (s - 1), 0, m - 1)
            do_emit = (stage_id == s - 1) & (tick >= s - 1)
            ys = jax.lax.dynamic_update_index_in_dim(
                ys, jnp.where(do_emit,
                              out,
                              jax.lax.dynamic_index_in_dim(
                                  ys, emit_idx, 0, keepdims=False)),
                emit_idx, 0)
            nxt = jax.lax.ppermute(
                out, stage_axis,
                [(i, (i + 1) % s) for i in range(s)])
            return (nxt, ys), None

        (_, ys), _ = jax.lax.scan(
            tick_fn, (zero, ys), jnp.arange(ticks))
        # broadcast final outputs from the last stage so the result is
        # replicated over the stage axis
        ys = jax.lax.psum(
            jnp.where(stage_id == s - 1, ys, jnp.zeros_like(ys)),
            stage_axis)
        return ys

    pspecs_params = jax.tree.map(lambda _: P(stage_axis), staged_params)
    batch_spec = P(None, data_axes if data_axes else None)
    try:
        fn = shard_map(
            per_stage, mesh=mesh,
            in_specs=(pspecs_params, P(stage_axis), batch_spec),
            out_specs=batch_spec, check_vma=False)
    except TypeError:                                  # older jax
        fn = shard_map(
            per_stage, mesh=mesh,
            in_specs=(pspecs_params, P(stage_axis), batch_spec),
            out_specs=batch_spec, check_rep=False)
    return fn(staged_params, mask, x_micro)
