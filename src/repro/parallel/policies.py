"""Named sharding-policy overrides for the perf hillclimbs (§Perf).

Each policy is a partial override of ``shardings.DEFAULT_RULES``; the
dry-run accepts ``--policy <name>`` and records it per cell, so every
hypothesis→change→measure iteration is reproducible from the CLI.
"""

from __future__ import annotations

from typing import Dict, Optional

from .shardings import Rule

POLICIES: Dict[str, Optional[Dict[str, Rule]]] = {
    # the default rules (FSDP over data on embed dims; TP over model on
    # mlp/heads/experts; kv_seq sequence-parallel for decode caches)
    "baseline": None,

    # MoE expert weights stationary: fully shard [E/model, d, f/data] so no
    # per-layer parameter all-gather is needed — the (much smaller) expert
    # activations reshard instead.  Hypothesis for the collective-bound
    # arctic-480b train cell.
    "expert_stationary": {
        "expert_embed": None,
        "expert_mlp": ("data",),
    },

    # Embedding table sharded on the feature dim instead of vocab: token
    # gathers become shard-local (no involuntary SPMD rematerialisation);
    # the unembedding projection keeps its own vocab-sharded weight.
    # Hypothesis for recurrentgemma-9b (256k vocab).
    "embed_dsharded": {
        "vocab_in": None,
        "embed_lookup": ("model",),
    },

    # Pure tensor-parallel params (no FSDP all-gathers; params live on the
    # model axis only).  Trades parameter memory for zero gather traffic —
    # viable for ≤35B-param models.
    "tp_only": {
        "embed_fsdp": None,
        "expert_embed": None,
    },

    # Combination used by the optimized arctic cell.
    "arctic_opt": {
        "expert_embed": None,
        "expert_mlp": ("data",),
        "vocab": ("model",),
    },

    # FSDP-only (no tensor parallelism): weights shard over ('data','model')
    # on their embed dims and are all-gathered per layer; removes the
    # per-layer TP activation all-reduces (2× ring factor) in exchange for
    # 1×-factor weight gathers.  Wins when weight bytes/layer < 2× the
    # activation bytes — the ≤10B-param archs.
    "fsdp_only": {
        "mlp": None,
        "heads": None,
        "kv_heads": None,
        "embed_fsdp": ("data", "model"),
        "embed_lookup": ("data", "model"),
    },
}


def get_policy(name: str) -> Optional[Dict[str, Rule]]:
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; have {sorted(POLICIES)}")
    return POLICIES[name]
