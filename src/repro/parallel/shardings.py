"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Models annotate parameters and activations with *logical* axis names
('embed', 'mlp', 'heads', 'experts', 'batch', ...).  A rule table maps each
logical name to zero or more *mesh* axes.  ``spec_for`` resolves a logical
axes tuple into a ``PartitionSpec``, dropping mesh axes that do not divide
the dimension (GSPMD would pad — we prefer clean layouts and let the
autoshard DSE decide when padding is worth it) and never using one mesh axis
twice within a spec.

The active (mesh, rules) pair is installed by the launcher / trainer via
``sharding_context``; model code calls ``shard(x, 'batch', 'seq', 'embed')``
which is a no-op outside a context, so pure-CPU smoke tests run unchanged.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Rule = Union[None, str, Tuple[str, ...]]

# Baseline policy: batch data-parallel over (pod, data); big contraction dims
# tensor-parallel over 'model'; embed FSDP-sharded over 'data' at rest.
DEFAULT_RULES: Dict[str, Rule] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "embed_fsdp": ("data",),          # used for parameter dim-0 FSDP
    "embed_lookup": ("data",),        # embedding-table feature dim
    "vocab": ("model",),
    "vocab_in": ("model",),           # embedding-table row dim (lookups)
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "experts": ("model",),
    "expert_cap": None,
    # expert weight dims get their own logical names so serving/perf
    # policies can re-lay them out without touching dense layers.
    # expert_mlp's 'model' only engages when the expert-count dim could not
    # take it (e.g. qwen2-moe's 60 experts on a 16-way model axis).
    "expert_embed": ("data",),
    "expert_mlp": ("model",),
    "layers": None,
    "state": None,
    "conv": None,
    "frames": None,
    # decode KV caches: kv_heads rarely divide the model axis (GQA kv=8 vs
    # 16-way TP), so the cache length is the tensor-parallel dim instead —
    # sequence-parallel KV, each shard scores its slice and GSPMD stitches
    # the softmax reductions.
    "kv_seq": ("model",),
}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, Rule]] = None


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: Optional[Dict[str, Rule]] = None):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def active_rules() -> Dict[str, Rule]:
    return _CTX.rules or DEFAULT_RULES


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 0


def spec_for(shape: Sequence[int],
             axes: Sequence[Optional[str]],
             mesh: Mesh,
             rules: Optional[Dict[str, Rule]] = None) -> PartitionSpec:
    """Resolve logical axes → PartitionSpec with divisibility fallback."""
    rules = rules or active_rules()
    used: set = set()
    entries = []
    for dim, logical in zip(shape, axes):
        rule = rules.get(logical) if logical else None
        if rule is None:
            entries.append(None)
            continue
        names = (rule,) if isinstance(rule, str) else tuple(rule)
        names = [n for n in names if n in mesh.shape and n not in used]
        # longest prefix of the rule whose product divides the dim
        chosen: Tuple[str, ...] = ()
        prod = 1
        for n in names:
            if dim % (prod * mesh.shape[n]) == 0:
                chosen = chosen + (n,)
                prod *= mesh.shape[n]
            else:
                break
        used.update(chosen)
        if not chosen:
            entries.append(None)
        elif len(chosen) == 1:
            entries.append(chosen[0])
        else:
            entries.append(chosen)
    return PartitionSpec(*entries)


def named_sharding(shape, axes, mesh=None, rules=None) -> NamedSharding:
    mesh = mesh or _CTX.mesh
    return NamedSharding(mesh, spec_for(shape, axes, mesh, rules))


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Activation sharding constraint; no-op outside a sharding context."""
    if _CTX.mesh is None:
        return x
    s = named_sharding(x.shape, axes)
    return jax.lax.with_sharding_constraint(x, s)


def tree_shardings(spec_tree, axes_tree, mesh=None, rules=None):
    """Spec/array tree + logical-axes tree → NamedSharding tree."""
    mesh = mesh or _CTX.mesh

    def mk(spec, axes):
        shape = getattr(spec, "shape")
        return NamedSharding(mesh, spec_for(shape, axes, mesh, rules))

    return jax.tree.map(mk, spec_tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))
