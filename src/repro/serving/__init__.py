from .engine import ServeEngine  # noqa: F401
from .dse_service import DSEService  # noqa: F401
from .store import DurableStore, Journal  # noqa: F401
