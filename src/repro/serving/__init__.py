from .engine import ServeEngine  # noqa: F401
from .dse_service import DSEService  # noqa: F401
