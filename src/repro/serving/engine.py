"""Batched serving engine: prefill + decode with continuous batching (lite).

The engine owns a fixed-capacity batch of sequence *slots*.  Requests queue
up; free slots are filled by prefilling the prompt (one forward over the
prompt, writing the KV cache region for that slot), then all active slots
decode in lock-step single-token steps (the classic batched-decode loop —
what ``serve_step`` lowers in the dry-run).  Finished sequences free their
slot for the next queued request ("continuous batching" at slot
granularity).

For the recurrent families the cache is the O(1) state tree, and prefill is
a scan over the prompt (state carried) — same engine API.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model_zoo as Z
from ..models import params as P


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [T] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_seq: int = 256, eos_id: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.finished: List[Request] = []
        self.cache = P.init_tree(
            Z.cache_spec(cfg, batch_slots, max_seq), jax.random.key(0))
        self._decode = jax.jit(
            lambda p, t, c: Z.decode_step(p, cfg, t, c))

    # -- admission ------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.active[slot] = req
                self._prefill(slot, req)

    def _prefill(self, slot: int, req: Request):
        """Sequential prefill through decode_step (slot-isolated writes).

        Lock-step engine: prompt tokens stream through the same decode path
        that serving lowers; production prefill fuses this into one forward
        (see launch.steps.build_prefill_step, exercised by the dry-run).
        """
        for tok in req.prompt:
            t = jnp.zeros((self.slots, 1), jnp.int32).at[slot, 0].set(
                int(tok))
            logits, self.cache = self._decode(self.params, t, self.cache)

    # -- decode loop ------------------------------------------------------------
    def step(self) -> Dict[int, int]:
        """One lock-step decode across all active slots → {rid: token}."""
        self._admit()
        if not any(r is not None for r in self.active):
            return {}
        last = jnp.zeros((self.slots, 1), jnp.int32)
        for s, req in enumerate(self.active):
            if req is not None and req.out_tokens:
                last = last.at[s, 0].set(req.out_tokens[-1])
            elif req is not None and len(req.prompt):
                last = last.at[s, 0].set(int(req.prompt[-1]))
        logits, self.cache = self._decode(self.params, last, self.cache)
        toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        emitted = {}
        for s, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(toks[s])
            req.out_tokens.append(tok)
            emitted[req.rid] = tok
            if (len(req.out_tokens) >= req.max_new_tokens
                    or (self.eos_id is not None and tok == self.eos_id)):
                req.done = True
                self.finished.append(req)
                self.active[s] = None       # slot freed → continuous batching
        return emitted

    def run_until_drained(self, max_steps: int = 1000,
                          timeout_s: Optional[float] = None
                          ) -> "DrainResult":
        """Step until queue and slots empty; never silently truncates.

        Stops early at ``max_steps`` or after ``timeout_s`` seconds of
        wall clock; either way the return value is the list of finished
        requests SO FAR with ``drained`` telling whether the engine
        actually emptied — callers that previously assumed a plain list
        still work (DrainResult is one)."""
        t0 = time.monotonic()
        drained = False
        for _ in range(max_steps):
            if not self.queue and all(a is None for a in self.active):
                drained = True
                break
            if timeout_s is not None and time.monotonic() - t0 > timeout_s:
                break
            self.step()
        else:
            drained = (not self.queue
                       and all(a is None for a in self.active))
        return DrainResult(self.finished, drained)


class DrainResult(List[Request]):
    """``run_until_drained``'s finished requests + a ``drained`` flag
    (False: stopped at max_steps/timeout with work still queued)."""

    def __init__(self, finished: List[Request], drained: bool):
        super().__init__(finished)
        self.drained = drained
