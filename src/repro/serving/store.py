"""Durable state for the DSE service: persistent cache + request journal.

Everything the service keeps warm in memory — streamed mega-grid
reductions, per-request answers, mid-stream checkpoints — dies with the
process; this module is the disk tier that survives it.  Three pieces:

* :class:`DurableStore` — a content-addressed npz cache under
  ``root/entries``.  Keys are nested tuples whose FIRST element is the
  invalidation group (the service uses the grid content hash), so
  ``invalidate_group`` can drop every entry of a superseded grid without
  touching the rest.  Every entry carries a schema version and a
  checksum over its full payload; anything that fails to load, verify,
  or parse is *quarantined* — atomically moved to ``root/quarantine``
  and counted — never crashing the reader and never serving garbage:
  ``get`` returns ``None`` and the caller recomputes.  Writes follow the
  PR-6 crash-safety discipline (temp file, fsync, ``os.replace``), so a
  concurrent reader sees either the old complete entry or the new one.
  :meth:`DurableStore.scrub` goes beyond the checksum (which only
  protects against damage AFTER the write): it re-audits decoded
  entries through a caller-supplied domain checker — the service wires
  :func:`repro.ft.verify.scrub_layer_topk` in — and quarantines entries
  that were poisoned BEFORE they were written.

* :class:`Journal` — a write-ahead request log (JSONL, one fsync'd line
  per record).  ``submit`` records are appended BEFORE the request
  enters the service queue and ``done`` records when its answer is
  delivered; :meth:`Journal.replay` returns the accepted-but-unanswered
  records in admission order so a restarted service re-admits each
  exactly once (by request id).  A torn final line — the crash happened
  mid-append — is detected and dropped, not fatal.

* :func:`stream_payload` / :func:`stream_from_payload` — flatten a
  completed :class:`repro.core.energymodel.LayerTopK` to plain numpy
  arrays + JSON meta and back, bit-identically, so warm stream tiers
  can live in the store.

JSON NOTE: answers cached through :meth:`DurableStore.put`'s ``meta``
side come back with lists where the freshly-computed answer had tuples
(JSON has no tuple).  The service accepts that asymmetry — comparators
in the durability tests treat tuples and lists as equal — rather than
normalising computed answers and breaking their pinned types.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from pathlib import Path
from typing import Any, Dict, Iterator, List, Mapping, Optional, Tuple

import numpy as np

from ..core import rs_mapping
from ..core.accelerator import ConfigGrid
from ..core.energymodel import LayerTopK, StreamFoldState, StreamStateError

#: Bump when the on-disk entry layout changes; older entries quarantine.
SCHEMA_VERSION = 1


def grid_hash(grid: ConfigGrid) -> str:
    """Content hash of a config grid (column bytes, order-independent)."""
    h = hashlib.sha256()
    for k in sorted(grid.fields):
        h.update(k.encode())
        h.update(np.ascontiguousarray(
            np.asarray(grid.fields[k], dtype=np.float64)).tobytes())
    return h.hexdigest()


def networks_hash(networks: Mapping[str, Any]) -> str:
    """Content hash of a network set (names + layer structs)."""
    h = hashlib.sha256()
    for nm in sorted(networks):
        h.update(nm.encode())
        struct = rs_mapping.layer_struct(
            np, [l for l in networks[nm] if l.kind != "input"])
        for sk in sorted(struct):
            h.update(sk.encode())
            h.update(np.ascontiguousarray(
                np.asarray(struct[sk], dtype=np.float64)).tobytes())
    return h.hexdigest()


def _checksum(arrays: Mapping[str, np.ndarray], meta_json: str) -> str:
    """Checksum over every array's (name, dtype, shape, bytes) + meta."""
    h = hashlib.sha256()
    for k in sorted(arrays):
        a = np.ascontiguousarray(np.asarray(arrays[k]))
        h.update(k.encode())
        h.update(str(a.dtype).encode())
        h.update(repr(a.shape).encode())
        h.update(a.tobytes())
    h.update(meta_json.encode())
    return h.hexdigest()


def _atomic_savez(path: Path, payload: Dict[str, Any]) -> None:
    """PR-6 discipline: temp file in the same dir, fsync, os.replace."""
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _json_scalar(z, name: str) -> str:
    v = z[name]
    return str(np.asarray(v)[()])


class DurableStore:
    """Disk-backed content-addressed cache with quarantine-on-corruption.

    ``key`` is any nested tuple of JSON-ish scalars; ``key[0]`` is the
    invalidation group.  The filename embeds both the group hash and the
    full key hash, so group invalidation is a directory scan, not an
    index."""

    def __init__(self, root, *, schema: int = SCHEMA_VERSION):
        self.root = Path(root)
        self.schema = int(schema)
        self.entries = self.root / "entries"
        self.quarantine = self.root / "quarantine"
        self.ckpt_dir = self.root / "ckpt"
        for d in (self.root, self.entries, self.quarantine, self.ckpt_dir):
            d.mkdir(parents=True, exist_ok=True)
        self.stats: Dict[str, int] = dict(
            puts=0, hits=0, misses=0, quarantined=0, invalidated=0,
            ckpt_saved=0, ckpt_loaded=0, ckpt_deleted=0,
            scrub_entries=0, scrubbed_bad=0)

    # -- key addressing ----------------------------------------------------

    @staticmethod
    def _group_hash(group) -> str:
        return hashlib.sha256(repr(group).encode()).hexdigest()[:16]

    def _path(self, key: tuple) -> Path:
        g = self._group_hash(key[0])
        k = hashlib.sha256(repr(key).encode()).hexdigest()[:32]
        return self.entries / f"{g}_{k}.npz"

    # -- entries -----------------------------------------------------------

    def put(self, key: tuple, *,
            arrays: Optional[Mapping[str, np.ndarray]] = None,
            meta: Any = None) -> Path:
        """Write (or overwrite) one entry atomically."""
        arrays = {f"a_{k}": np.asarray(v)
                  for k, v in (arrays or {}).items()}
        meta_json = json.dumps(meta, sort_keys=True)
        head = json.dumps(dict(
            schema=self.schema, key=repr(key),
            checksum=_checksum(arrays, meta_json)), sort_keys=True)
        path = self._path(key)
        _atomic_savez(path, dict(arrays, __head__=head,
                                 __meta__=meta_json))
        self.stats["puts"] += 1
        return path

    def _load_entry(self, path: Path
                    ) -> Tuple[str, Dict[str, np.ndarray], Any]:
        """Load + integrity-check one entry file.

        Returns ``(key_repr, arrays, meta)`` with the ``a_`` prefixes
        stripped; raises on any damage (unreadable npz, missing members,
        schema or checksum mismatch).  Shared by :meth:`get` and
        :meth:`scrub`."""
        with np.load(path, allow_pickle=False) as z:
            head = json.loads(_json_scalar(z, "__head__"))
            meta_json = _json_scalar(z, "__meta__")
            arrays = {k: z[k] for k in z.files
                      if k not in ("__head__", "__meta__")}
        if int(head["schema"]) != self.schema:
            raise StreamStateError(
                f"schema {head['schema']} != {self.schema}")
        if head["checksum"] != _checksum(arrays, meta_json):
            raise StreamStateError("checksum mismatch")
        return (head["key"], {k[2:]: v for k, v in arrays.items()},
                json.loads(meta_json))

    def get(self, key: tuple
            ) -> Optional[Tuple[Dict[str, np.ndarray], Any]]:
        """Load one entry, or ``None`` (miss, or quarantined on damage).

        EVERY failure mode — unreadable npz, missing members, schema or
        key mismatch, checksum mismatch — quarantines the file and falls
        through to a miss; the caller recomputes."""
        path = self._path(key)
        if not path.exists():
            self.stats["misses"] += 1
            return None
        try:
            key_repr, arrays, meta = self._load_entry(path)
            if key_repr != repr(key):
                raise StreamStateError("key mismatch (hash collision or "
                                       "tampered entry)")
        except Exception as e:
            self._quarantine(path, reason=str(e))
            self.stats["misses"] += 1
            return None
        self.stats["hits"] += 1
        return arrays, meta

    def scrub(self, checker=None, *, max_entries: Optional[int] = None,
              cursor: Optional[str] = None) -> Dict[str, Any]:
        """Audit cached entries beyond what the checksum can see.

        The checksum protects against damage AFTER the write; an entry
        whose payload was silently corrupted BEFORE ``put`` verifies
        clean forever.  ``scrub`` walks entry files (integrity check
        first) and hands each decoded entry to ``checker(key_repr,
        arrays, meta)`` — a domain auditor returning a quarantine-reason
        string or ``None``/falsy (the service wires
        :func:`repro.ft.verify.scrub_layer_topk` in here).  Bad entries
        are quarantined-with-reason; the caller recomputes on the next
        miss.

        ``cursor``/``max_entries`` support incremental idle-time passes:
        pass the returned ``cursor`` back in to continue the walk
        (wrapping around), bound each pass with ``max_entries``.
        Returns ``dict(scanned=..., bad=..., bad_keys=[key_repr | None,
        ...], cursor=...)``."""
        names = sorted(p.name for p in self.entries.glob("*.npz"))
        if cursor is not None:
            after = [nm for nm in names if nm > cursor]
            names = after + [nm for nm in names if nm <= cursor]
        if max_entries is not None:
            names = names[:max(0, int(max_entries))]
        scanned = bad = 0
        bad_keys: List[Optional[str]] = []
        for nm in names:
            path = self.entries / nm
            if not path.exists():      # racing invalidation
                continue               # pragma: no cover
            scanned += 1
            try:
                key_repr, arrays, meta = self._load_entry(path)
            except Exception as e:
                self._quarantine(path, reason=f"scrub: {e}")
                bad += 1
                bad_keys.append(None)  # key unrecoverable from the file
                continue
            reason = checker(key_repr, arrays, meta) if checker else None
            if reason:
                self._quarantine(path, reason=f"scrub: {reason}")
                bad += 1
                bad_keys.append(key_repr)
        self.stats["scrub_entries"] += scanned
        self.stats["scrubbed_bad"] += bad
        return dict(scanned=scanned, bad=bad, bad_keys=bad_keys,
                    cursor=names[-1] if names else cursor)

    def _quarantine(self, path: Path, *, reason: str = "") -> None:
        """Atomically move a damaged file aside (never delete evidence)."""
        dest = self.quarantine / path.name
        i = 0
        while dest.exists():
            i += 1
            dest = self.quarantine / f"{path.name}.{i}"
        try:
            os.replace(path, dest)
            with open(str(dest) + ".reason", "w") as f:
                f.write(reason + "\n")
        except OSError:        # a concurrent reader beat us to the move
            pass               # pragma: no cover
        self.stats["quarantined"] += 1

    def invalidate_group(self, group) -> int:
        """Delete every entry whose ``key[0]`` equals ``group``."""
        g = self._group_hash(group)
        n = 0
        for p in self.entries.glob(f"{g}_*.npz"):
            try:
                p.unlink()
                n += 1
            except OSError:    # pragma: no cover
                pass
        self.stats["invalidated"] += n
        return n

    # -- mid-stream checkpoints --------------------------------------------

    def ckpt_path(self, input_hash: str) -> Path:
        return self.ckpt_dir / f"ckpt_{input_hash}.npz"

    def save_ckpt(self, fs: StreamFoldState) -> Path:
        """Spill a fold state, keyed by its ``stream_input_hash``."""
        path = self.ckpt_path(fs.input_hash)
        fs.save(path)
        self.stats["ckpt_saved"] += 1
        return path

    def iter_ckpts(self) -> Iterator[Tuple[Path, StreamFoldState]]:
        """Yield every loadable checkpoint; unloadable files quarantine."""
        for p in sorted(self.ckpt_dir.glob("ckpt_*.npz")):
            try:
                fs = StreamFoldState.load(p)
            except Exception as e:
                self._quarantine(p, reason=str(e))
                continue
            self.stats["ckpt_loaded"] += 1
            yield p, fs

    def drop_ckpt(self, input_hash: str) -> bool:
        path = self.ckpt_path(input_hash)
        try:
            path.unlink()
        except OSError:
            return False
        self.stats["ckpt_deleted"] += 1
        return True

    # -- introspection -----------------------------------------------------

    def health(self) -> Dict[str, int]:
        out = dict(self.stats)
        out["n_entries"] = sum(1 for _ in self.entries.glob("*.npz"))
        out["n_quarantined_files"] = sum(
            1 for _ in self.quarantine.glob("*.npz*")
            if not str(_).endswith(".reason"))
        out["n_ckpt_files"] = sum(
            1 for _ in self.ckpt_dir.glob("ckpt_*.npz"))
        return out


# ---------------------------------------------------------------------------
# Write-ahead request journal
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ReplayResult:
    """What :meth:`Journal.replay` recovered from a journal file."""

    pending: List[dict]            # unanswered submit records, in order
    next_rid: int                  # first rid a restarted service may issue
    n_done: int                    # answered requests found
    n_torn: int                    # undecodable (torn-write) lines dropped


class Journal:
    """Append-only fsync'd JSONL write-ahead log of service requests.

    One record per line: ``{"op": "submit", "rid": ..., ...request
    fields...}`` when a request is admitted, ``{"op": "done", "rid":
    ...}`` when its answer is handed back.  The file is opened in append
    mode, so a replayed journal keeps extending — recovery state and new
    traffic share one log."""

    def __init__(self, path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f = open(self.path, "a", encoding="utf-8")

    def append(self, record: Mapping[str, Any]) -> None:
        self._f.write(json.dumps(record, sort_keys=True) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())

    def submit(self, rid: int, fields: Mapping[str, Any]) -> None:
        self.append(dict(fields, op="submit", rid=int(rid)))

    def done(self, rid: int) -> None:
        self.append(dict(op="done", rid=int(rid)))

    def close(self) -> None:
        try:
            self._f.close()
        except OSError:        # pragma: no cover
            pass

    @staticmethod
    def replay(path) -> ReplayResult:
        """Read a journal (possibly from a killed process) back.

        A line that fails to decode is a torn write: the crash happened
        mid-append, before the fsync returned, so the record was never
        acknowledged — it is dropped and counted, never fatal."""
        path = Path(path)
        pending: Dict[int, dict] = {}
        next_rid, n_done, n_torn = 0, 0, 0
        if not path.exists():
            return ReplayResult([], 0, 0, 0)
        with open(path, "r", encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                    op, rid = rec["op"], int(rec["rid"])
                except (ValueError, KeyError, TypeError):
                    n_torn += 1
                    continue
                next_rid = max(next_rid, rid + 1)
                if op == "submit":
                    pending[rid] = rec
                elif op == "done":
                    if pending.pop(rid, None) is not None:
                        n_done += 1
                else:
                    n_torn += 1
        return ReplayResult(
            pending=[pending[r] for r in sorted(pending)],
            next_rid=next_rid, n_done=n_done, n_torn=n_torn)


# ---------------------------------------------------------------------------
# LayerTopK <-> store payload
# ---------------------------------------------------------------------------

_STREAM_ARRAYS = (
    "layer_counts", "topk_idx", "topk_metric", "layer_energy",
    "layer_latency", "min_energy", "min_latency", "min_edp", "min_metric",
    "argmin", "layer_min_metric", "layer_argmin")


def stream_payload(st: LayerTopK
                   ) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Flatten a COMPLETED streamed sweep to (arrays, meta) for the store."""
    arrays = {k: np.asarray(getattr(st, k)) for k in _STREAM_ARRAYS}
    if st.bound is not None:
        for j, nm in enumerate(st.networks):
            arrays[f"bnd{j}_idx"] = np.asarray(st.boundary_idx[nm])
            arrays[f"bnd{j}_e"] = np.asarray(st.boundary_energy[nm])
            arrays[f"bnd{j}_t"] = np.asarray(st.boundary_latency[nm])
    meta = dict(networks=list(st.networks), n_cfg=int(st.n_cfg),
                metric=st.metric,
                bound=None if st.bound is None else float(st.bound))
    return arrays, meta


def stream_from_payload(arrays: Mapping[str, np.ndarray],
                        meta: Mapping[str, Any]) -> LayerTopK:
    """Inverse of :func:`stream_payload`, bit-identical round trip."""
    nets = tuple(meta["networks"])
    bound = meta["bound"]
    kw: Dict[str, Any] = {k: np.asarray(arrays[k]) for k in _STREAM_ARRAYS}
    b_idx = b_e = b_t = None
    if bound is not None:
        b_idx, b_e, b_t = {}, {}, {}
        for j, nm in enumerate(nets):
            b_idx[nm] = np.asarray(arrays[f"bnd{j}_idx"])
            b_e[nm] = np.asarray(arrays[f"bnd{j}_e"])
            b_t[nm] = np.asarray(arrays[f"bnd{j}_t"])
    return LayerTopK(networks=nets, n_cfg=int(meta["n_cfg"]),
                     metric=str(meta["metric"]), bound=bound,
                     boundary_idx=b_idx, boundary_energy=b_e,
                     boundary_latency=b_t, **kw)
