"""DSE-as-a-service: a long-lived, fault-tolerant co-design query server.

ROADMAP item 1 made the case: the DSE engine's costs are front-loaded (jit
traces, streamed mega-grid folds, candidate pools), so amortising them
demands a resident process answering many queries — and a resident process
must bound its queue, meet deadlines, and survive backend faults.
:class:`DSEService` is that process's core, deliberately step-driven (no
threads — like :class:`repro.serving.engine.ServeEngine`'s lock-step decode
loop) so every fault-injection test is deterministic.

**Query model.**  Four kinds, submitted via :meth:`DSEService.submit`:
``best_config`` (per-network sweep argmin under a metric), ``best_chip``
(best heterogeneous chip under a relative latency deadline ``d``),
``pareto`` (one network's non-dominated (chip, latency, energy) front),
and ``reschedule`` (a deployed chip suffered a hardware fault — a
:class:`repro.ft.hw_faults.FaultScenario` — and every network's layers
must be re-mapped across the survivors).  :meth:`DSEService.step` pops
every queued request of the head request's family (config / chip /
resched) and metric and serves them from ONE shared computation —
concurrent deadline queries coalesce into a single
``pareto_codesign(points=...)`` call scoring all their deadlines at once,
and concurrent reschedule queries coalesce into ONE union-grid engine
evaluation + ONE ``batch_schedule_hetero(strict=False)`` solve over all
their (chip, scenario, network) problems.

**Fault events.**  :meth:`DSEService.fault_event` is the push path: a
hardware fault report invalidates every cached schedule of the affected
chip and enqueues the re-schedule query — the service answers it through
the same coalescing / retry / budget machinery, without a restart.
Scenarios that kill every core come back ``feasible=False`` per network
(the solver reports +inf bottlenecks instead of raising).

**Robustness ladder** (each rung independently testable):

1. *Bounded admission*: the queue holds ``max_queue`` requests; overflow is
   rejected immediately with a ``retry_after_s`` estimate — never unbounded
   growth.
2. *Deadlines degrade, never hang*: each request carries a wall-clock
   budget ``deadline_s``.  A request whose remaining budget cannot cover
   the projected exact sweep (calibrated from a measured subsampled-grid
   sweep, extrapolated by point count) — or whose exact sweep runs out of
   budget mid-stream — is answered from the subsampled grid and flagged
   ``degraded=True``.
3. *Retry with exponential backoff*: transient backend failures re-run the
   computation after ``backoff_s · 2^attempt``, walking down the engine's
   pallas → jax → numpy fallback chain after repeated failures.
4. *Checkpoint/resume*: every streamed sweep exports its
   :class:`repro.core.energymodel.StreamFoldState` after each chunk; a
   retry resumes from the last folded chunk instead of restarting, and a
   budget-aborted exact sweep leaves its checkpoint behind for the next
   query with budget to finish.
5. *Observability*: :meth:`DSEService.health` snapshots queue depth, cache
   hits, fault/retry/fallback/resume counters, and p50/p99 latency.
6. *Durability* (``state_dir=``): a :class:`repro.serving.store.Journal`
   write-ahead log makes admission survive process death — every accepted
   request is journalled before it enters the queue and marked done when
   its answer is delivered, so a restarted service over the same
   ``state_dir`` replays exactly the accepted-but-unanswered requests (by
   rid) and drains to bit-identical answers.  A
   :class:`repro.serving.store.DurableStore` persists the warm tiers:
   completed streamed sweeps (content-addressed on grid/network hashes),
   exact per-request answers, and mid-stream checkpoints (``_ckpt``
   spills through :meth:`repro.core.energymodel.StreamFoldState.save`
   keyed by ``stream_input_hash``; stale checkpoint files are
   garbage-collected on startup).  The in-memory re-schedule cache stays
   memory-only: its ``fault_event`` invalidation enumerates keys by chip
   identity, which a content-addressed store cannot do.
7. *Incremental grid deltas*: :meth:`DSEService.extend_grid` folds ONLY
   the appended config rows into every completed stream via
   :func:`repro.core.energymodel.merge_layer_topk` — bit-identical to
   re-streaming the grown grid from scratch — and invalidates exactly
   the store groups whose grid hash changed.
8. *Silent-corruption defense* (``verify=True``, the default): every
   streamed sweep runs under a :class:`repro.ft.verify.StreamVerifier`
   — per-chunk fold-invariant checks plus a seeded
   ``verify_fraction``-sampled numpy shadow recompute — so a FINITE
   wrong value (bit-flip, kernel miscompile) raises before the poisoned
   chunk commits and the normal retry/resume ladder recomputes it.
   :meth:`DSEService.scrub` (also run incrementally from idle
   :meth:`step` ticks) audits at-rest store entries through
   :func:`repro.ft.verify.scrub_layer_topk`, quarantines-with-reason,
   and recomputes; ``health()`` exposes ``shadow_checks``,
   ``invariant_violations``, ``scrub_entries``, ``scrubbed_bad``.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..core import energymodel, hetero, partition
from ..core.accelerator import ConfigGrid
from ..core.topology import Layer
from ..ft import hw_faults
from ..ft import verify as ft_verify
from . import store as store_mod


class ServiceFault(RuntimeError):
    """A computation failed after exhausting every retry and backend."""


class _BudgetExhausted(RuntimeError):
    """Internal: the wall-clock budget ran out mid-computation."""


@dataclasses.dataclass
class DSERequest:
    rid: int
    kind: str     # "best_config" | "best_chip" | "pareto" | "reschedule"
    metric: str = "edp"
    network: Optional[str] = None   # best_config: None = all networks
    deadline: float = 2.0           # relative latency deadline (chip family)
    deadline_s: Optional[float] = None   # wall-clock answer budget
    submitted_at: float = 0.0
    # reschedule family: the deployed chip and what broke on it
    chip_types: Optional[Tuple[int, ...]] = None   # flat grid rows
    chip_counts: Optional[Tuple[int, ...]] = None
    scenario: Optional[hw_faults.FaultScenario] = None


@dataclasses.dataclass
class DSEResponse:
    rid: int
    kind: str
    ok: bool
    degraded: bool
    deadline_missed: bool
    answer: Dict[str, Any]
    error: Optional[str]
    latency_s: float
    backend: Optional[str]


@dataclasses.dataclass
class SubmitResult:
    accepted: bool
    rid: Optional[int]
    queue_depth: int
    retry_after_s: Optional[float] = None


class DSEService:
    """Step-driven DSE query server over one (grid, networks) design space.

    All heavy state is lazy and cached per metric: the streamed per-layer
    sweep (:func:`repro.core.energymodel.stream_layer_topk` with boundary
    sets), the co-design problem set built on it, and the solved raw
    (energy, latency) chip points that make every later deadline re-sweep
    a compiled-scoring-only call.  A parallel set of caches covers the
    ``degrade_stride``-subsampled grid — the degraded-answer tier, and the
    calibration source for projecting exact-sweep cost."""

    def __init__(self, grid: ConfigGrid,
                 networks: Mapping[str, Sequence[Layer]], *,
                 metric_bound: float = 0.05,
                 pool_size: int = 4,
                 m_cores: int = 4,
                 max_types: int = 2,
                 topk: int = 8,
                 chunk_size: int = 1024,
                 max_queue: int = 64,
                 degrade_stride: int = 8,
                 backend: str | None = None,
                 max_retries: int = 3,
                 backoff_s: float = 0.05,
                 safety_factor: float = 2.0,
                 state_dir=None,
                 lat_window: int = 4096,
                 ckpt_every: int = 4,
                 clock=time.monotonic,
                 sleep=time.sleep,
                 verify: bool = True,
                 verify_fraction: float = 1.0 / 16.0,
                 verify_seed: int = 0,
                 scrub_rows: int = 2,
                 idle_scrub: bool = True):
        self.grid = grid
        self.networks = dict(networks)
        self.names = tuple(self.networks)
        self.bound = float(metric_bound)
        self.pool_size = int(pool_size)
        self.m_cores = int(m_cores)
        self.max_types = int(max_types)
        self.topk = max(int(topk), int(pool_size))
        self.chunk_size = int(chunk_size)
        self.max_queue = int(max_queue)
        self.backend = backend
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.safety = float(safety_factor)
        self.ckpt_every = max(int(ckpt_every), 1)
        self._clock = clock
        self._sleep = sleep
        self.verify = bool(verify)
        self.verify_fraction = float(verify_fraction)
        self.verify_seed = int(verify_seed)
        self._scrub_rows = int(scrub_rows)
        self._idle_scrub = bool(idle_scrub)
        self._scrub_cursor: Optional[str] = None
        self._stride = max(1, min(int(degrade_stride), grid.n))
        self._sub_idx = np.arange(0, grid.n, self._stride)
        self._sub_grid = grid.take(self._sub_idx)

        self._queue: List[DSERequest] = []
        self.responses: List[DSEResponse] = []
        self._next_rid = 0
        self._t0 = self._clock()
        # tier ("exact"|"sub") × metric caches
        self._streams: Dict[Tuple[str, str], energymodel.LayerTopK] = {}
        self._points: Dict[Tuple[str, str], tuple] = {}
        self._ckpt: Dict[tuple, energymodel.StreamFoldState] = {}
        self._cost: Dict[tuple, float] = {}     # measured seconds, EMA
        # bounded ring buffer: p50/p99 over the last `lat_window` samples,
        # O(window) memory no matter how long the service lives
        self.lat_window = max(int(lat_window), 1)
        self._lat: collections.deque = collections.deque(
            maxlen=self.lat_window)
        self.stats: Dict[str, int] = dict(
            submitted=0, accepted=0, rejected=0, completed=0, degraded=0,
            deadline_missed=0, errors=0, faults=0, retries=0,
            backend_fallbacks=0, resumes=0, budget_aborts=0,
            sweep_cache_hits=0, sweep_cache_misses=0,
            points_cache_hits=0, points_cache_misses=0,
            coalesced_batches=0,
            fault_events=0, reschedules=0, schedule_invalidations=0,
            resched_cache_hits=0, resched_cache_misses=0,
            store_hits=0, store_misses=0, answer_hits=0,
            replayed=0, replay_dropped=0, ckpt_gc=0,
            grid_extensions=0, delta_folds=0, cache_invalidated=0,
            shadow_checks=0, shadow_mismatches=0,
            invariant_checks=0, invariant_violations=0,
            scrub_entries=0, scrubbed_bad=0, scrub_recomputed=0)
        # (chip_types, chip_counts, scenario.key(), metric) → answer dict
        self._resched: Dict[tuple, Dict[str, Any]] = {}

        # -- durable state (all no-ops when state_dir is None) -------------
        self.state_dir = None if state_dir is None else str(state_dir)
        self._grid_hash = store_mod.grid_hash(self.grid)
        self._sub_hash = store_mod.grid_hash(self._sub_grid)
        self._nets_hash = store_mod.networks_hash(self.networks)
        self.store: Optional[store_mod.DurableStore] = None
        self._journal: Optional[store_mod.Journal] = None
        if self.state_dir is not None:
            self.store = store_mod.DurableStore(self.state_dir)
            self._recover()

    # -- durable state -----------------------------------------------------
    def _journal_path(self) -> str:
        return str(self.store.root / "journal.jsonl")

    def _params_key(self) -> tuple:
        """Service parameters every cached artifact depends on."""
        return ("params", self.bound, self.pool_size, self.m_cores,
                self.max_types, self.topk)

    def _tier_hash(self, tier: str) -> str:
        return self._grid_hash if tier == "exact" else self._sub_hash

    def _stream_key(self, tier: str, metric: str) -> tuple:
        return (self._tier_hash(tier), self._nets_hash, "stream", metric,
                ("params", self.bound, self.topk))

    def _answer_key(self, r: DSERequest, metric: str) -> tuple:
        """Store key of one EXACT answer.  best_config answers do not
        depend on the deadline; chip-family answers do (best_chip is
        scored at it, pareto's slack front is widened by it)."""
        dl = (float(r.deadline)
              if r.kind in ("best_chip", "pareto") else None)
        return (self._grid_hash, self._nets_hash, "answer", r.kind,
                metric, r.network, dl, self._params_key())

    def _expected_ckpt_hash(self, tier: str, fs) -> str:
        """The ``stream_input_hash`` a live stream of ``tier`` at the
        checkpoint's (metric, bound, topk) would carry — a checkpoint
        matches iff its own hash equals this."""
        _, grid, _ = self._tier(tier == "exact")
        chunk = max(1, min(self.chunk_size, grid.n))
        return energymodel.stream_input_hash(
            grid, self.networks, kind=fs.kind, metric=fs.metric,
            bound=fs.bound, topk=fs.topk, chunk=chunk)

    def _recover(self) -> None:
        """Restart path: replay the journal's unanswered requests in
        admission order, garbage-collect stale checkpoint files, and
        register live ones for resume — then reopen the journal for
        append so recovered and new traffic share one log."""
        rr = store_mod.Journal.replay(self._journal_path())
        self._next_rid = max(self._next_rid, rr.next_rid)
        pending = rr.pending
        self._journal = store_mod.Journal(self._journal_path())
        for rec in pending:
            try:
                self._queue.append(self._request_from_journal(rec))
                self.stats["replayed"] += 1
            except Exception:
                self.stats["replay_dropped"] += 1
        # checkpoint GC: a file is live iff its input hash matches what a
        # stream of one of our tiers would compute right now
        for path, fs in self.store.iter_ckpts():
            tier = next((t for t in ("exact", "sub")
                         if fs.input_hash == self._expected_ckpt_hash(
                             t, fs)), None)
            if tier is None:
                self.store.drop_ckpt(fs.input_hash)
                self.stats["ckpt_gc"] += 1
            else:
                self._ckpt[("stream", tier, fs.metric)] = fs

    def _request_from_journal(self, rec: Mapping[str, Any]) -> DSERequest:
        """Rebuild a journalled request; ``submitted_at`` is refreshed —
        monotonic clocks do not survive the process they came from."""
        sc = rec.get("scenario")
        return DSERequest(
            rid=int(rec["rid"]), kind=rec["kind"], metric=rec["metric"],
            network=rec.get("network"),
            deadline=float(rec.get("deadline", 2.0)),
            deadline_s=rec.get("deadline_s"),
            submitted_at=self._clock(),
            chip_types=(None if rec.get("chip_types") is None
                        else tuple(int(t) for t in rec["chip_types"])),
            chip_counts=(None if rec.get("chip_counts") is None
                         else tuple(int(c) for c in rec["chip_counts"])),
            scenario=(None if sc is None
                      else hw_faults.scenario_from_json(sc)))

    def _journal_submit(self, r: DSERequest) -> None:
        if self._journal is None:
            return
        self._journal.submit(r.rid, dict(
            kind=r.kind, metric=r.metric, network=r.network,
            deadline=r.deadline, deadline_s=r.deadline_s,
            chip_types=(None if r.chip_types is None
                        else list(r.chip_types)),
            chip_counts=(None if r.chip_counts is None
                         else list(r.chip_counts)),
            scenario=(None if r.scenario is None
                      else hw_faults.scenario_to_json(r.scenario))))

    def _drop_ckpt(self, key: tuple) -> None:
        """Forget a checkpoint in memory AND on disk."""
        fs = self._ckpt.pop(key, None)
        if fs is not None and self.store is not None:
            self.store.drop_ckpt(fs.input_hash)

    def close(self) -> None:
        """Release the journal file handle (the store is handle-free)."""
        if self._journal is not None:
            self._journal.close()
            self._journal = None

    # -- admission ---------------------------------------------------------
    @staticmethod
    def _family(kind: str) -> str:
        if kind == "reschedule":
            return "resched"
        return "chip" if kind in ("best_chip", "pareto") else "config"

    def submit(self, kind: str, *, network: Optional[str] = None,
               metric: str = "edp", deadline: float = 2.0,
               deadline_s: Optional[float] = None,
               chip_types: Optional[Sequence[int]] = None,
               chip_counts: Optional[Sequence[int]] = None,
               scenario: Optional[hw_faults.FaultScenario] = None
               ) -> SubmitResult:
        """Enqueue a query; reject-with-retry-after when the queue is full."""
        if kind not in ("best_config", "best_chip", "pareto", "reschedule"):
            raise ValueError(f"unknown query kind {kind!r}")
        if network is not None and network not in self.networks:
            raise ValueError(f"unknown network {network!r}")
        if kind == "pareto" and network is None:
            raise ValueError("pareto queries name one network")
        if kind == "reschedule":
            if chip_types is None or chip_counts is None:
                raise ValueError(
                    "reschedule queries name the chip: chip_types "
                    "(flat grid rows) and chip_counts")
            if scenario is None:
                raise ValueError("reschedule queries carry a FaultScenario")
            chip_types = tuple(int(t) for t in chip_types)
            chip_counts = tuple(int(c) for c in chip_counts)
            if len(chip_types) != len(chip_counts):
                raise ValueError(
                    f"{len(chip_types)} chip types but "
                    f"{len(chip_counts)} counts")
            bad = [t for t in chip_types if not 0 <= t < self.grid.n]
            if bad:
                raise ValueError(
                    f"chip_types {bad} out of range for a "
                    f"{self.grid.n}-row grid")
            if any(c < 0 for c in chip_counts):
                raise ValueError("chip_counts must be >= 0")
            # range-check the scenario's type indices up front
            hw_faults.apply_counts(chip_counts, scenario)
        self.stats["submitted"] += 1
        if len(self._queue) >= self.max_queue:
            self.stats["rejected"] += 1
            return SubmitResult(accepted=False, rid=None,
                                queue_depth=len(self._queue),
                                retry_after_s=self._drain_estimate())
        rid = self._next_rid
        self._next_rid += 1
        req = DSERequest(
            rid=rid, kind=kind, metric=metric, network=network,
            deadline=float(deadline), deadline_s=deadline_s,
            submitted_at=self._clock(), chip_types=chip_types,
            chip_counts=chip_counts, scenario=scenario)
        # write-ahead: the fsync'd journal line lands BEFORE the request
        # is queued, so a kill after this point still replays it
        self._journal_submit(req)
        self._queue.append(req)
        self.stats["accepted"] += 1
        return SubmitResult(accepted=True, rid=rid,
                            queue_depth=len(self._queue))

    def _drain_estimate(self) -> float:
        per = self._cost.get(("request",), 0.5)
        return max(per * (len(self._queue) + 1), 0.1)

    # -- retry / backoff / resume core ------------------------------------
    def _backend_ladder(self) -> List[str | None]:
        resolved = energymodel.resolve_backend(self.backend)
        chain = list(energymodel.BACKENDS)
        return chain[chain.index(resolved):] or ["numpy"]

    def _with_retries(self, run, *, key: tuple,
                      budget_end: Optional[float]):
        """``run(backend, resume_from)`` with exponential backoff, backend
        fallback, and checkpoint-resume.  ``_BudgetExhausted`` (raised by
        the budget watchdog inside ``run``) propagates — it is a deadline,
        not a fault."""
        ladder = self._backend_ladder()
        bi = 0
        attempt = 0
        while True:
            resume = self._ckpt.get(key)
            if resume is not None:
                self.stats["resumes"] += 1
            try:
                return run(ladder[bi], resume)
            except _BudgetExhausted:
                self.stats["budget_aborts"] += 1
                raise
            except energymodel.StreamStateError:
                # stale checkpoint (inputs changed) — drop it, count the
                # wasted attempt, start the stream over
                self._drop_ckpt(key)
                attempt += 1
            except Exception as e:
                self.stats["faults"] += 1
                attempt += 1
                if attempt > self.max_retries:
                    raise ServiceFault(
                        f"{key} failed after {attempt} attempts across "
                        f"backends {ladder[:bi + 1]}: {e}") from e
                if attempt >= 2 and bi + 1 < len(ladder):
                    bi += 1
                    self.stats["backend_fallbacks"] += 1
                delay = self.backoff_s * (2.0 ** (attempt - 1))
                if (budget_end is not None
                        and self._clock() + delay > budget_end):
                    raise _BudgetExhausted(
                        f"{key}: backoff would exceed the request budget")
                self.stats["retries"] += 1
                self._sleep(delay)

    # -- silent-corruption defense -----------------------------------------
    def _make_verifier(self) -> Optional[ft_verify.StreamVerifier]:
        if not self.verify:
            return None
        return ft_verify.StreamVerifier(
            verify_fraction=self.verify_fraction, seed=self.verify_seed)

    def _harvest_verify(self, v: Optional[ft_verify.StreamVerifier]):
        """Fold a per-run verifier's counters into the service stats —
        in a finally block, so counts from a detected-and-raised
        corruption are kept too."""
        if v is None:
            return
        for k, n in v.stats.items():
            self.stats[k] = self.stats.get(k, 0) + n

    def scrub(self, *, max_entries: Optional[int] = None,
              cursor: Optional[str] = None,
              recompute: bool = True) -> Dict[str, Any]:
        """Audit at-rest store entries for silent corruption.

        Walks (a slice of) the durable store through
        :meth:`repro.serving.store.DurableStore.scrub`, with cached
        stream payloads re-derived through
        :func:`repro.ft.verify.scrub_layer_topk` (structural invariants
        + ``scrub_rows`` sampled rows recomputed on the numpy reference
        path).  Poisoned entries are quarantined-with-reason, evicted
        from the warm caches, and — with ``recompute=True`` — rebuilt
        immediately so the next query is served clean.  Answer entries
        are covered by the integrity check only (they are JSON meta
        derived from stream payloads, which ARE re-derived)."""
        if self.store is None:
            return dict(scanned=0, bad=0, bad_keys=[], recomputed=0,
                        cursor=cursor)
        import ast

        def parse_stream_key(key_repr):
            """(tier, metric) of a CURRENT stream entry, else None."""
            try:
                key = ast.literal_eval(key_repr)
            except (ValueError, SyntaxError):
                return None
            if not (isinstance(key, tuple) and len(key) >= 4
                    and key[2] == "stream"):
                return None
            tier = ("exact" if key[0] == self._grid_hash else
                    "sub" if key[0] == self._sub_hash else None)
            if tier is None or key[1] != self._nets_hash:
                return None      # superseded entry; invalidation reaps it
            return tier, str(key[3])

        def checker(key_repr, arrays, meta):
            tm = parse_stream_key(key_repr)
            if tm is None:
                return None
            tier, _ = tm
            grid = self.grid if tier == "exact" else self._sub_grid
            try:
                st = store_mod.stream_from_payload(arrays, meta)
            except Exception as e:
                return f"stream payload does not decode: {e}"
            return ft_verify.scrub_layer_topk(
                st, grid, self.networks, rows=self._scrub_rows,
                seed=self.verify_seed)

        res = self.store.scrub(checker, max_entries=max_entries,
                               cursor=cursor)
        self.stats["scrub_entries"] += res["scanned"]
        self.stats["scrubbed_bad"] += res["bad"]
        recomputed = 0
        for key_repr in res["bad_keys"]:
            tm = parse_stream_key(key_repr) if key_repr else None
            if tm is None:
                continue
            tier, metric = tm
            self._streams.pop((tier, metric), None)
            self._points.pop((tier, metric), None)
            if recompute:
                self._get_stream(metric, exact=(tier == "exact"))
                recomputed += 1
        self.stats["scrub_recomputed"] += recomputed
        return dict(res, recomputed=recomputed)

    # -- cached artifacts --------------------------------------------------
    def _tier(self, exact: bool):
        if exact:
            return "exact", self.grid, np.arange(self.grid.n)
        return "sub", self._sub_grid, self._sub_idx

    def _get_stream(self, metric: str, *, exact: bool,
                    budget_end: Optional[float] = None
                    ) -> energymodel.LayerTopK:
        tier, grid, _ = self._tier(exact)
        ck = (tier, metric)
        if ck in self._streams:
            self.stats["sweep_cache_hits"] += 1
            return self._streams[ck]
        if self.store is not None:
            got = self.store.get(self._stream_key(tier, metric))
            if got is not None:
                self.stats["store_hits"] += 1
                self.stats["sweep_cache_hits"] += 1
                st = store_mod.stream_from_payload(*got)
                self._streams[ck] = st
                return st
            self.stats["store_misses"] += 1
        self.stats["sweep_cache_misses"] += 1
        key = ("stream", tier, metric)

        def on_chunk(fs):
            self._ckpt[key] = fs
            # durable spill is throttled: an fsync'd npz per chunk would
            # tax the stream ~2×; every `ckpt_every` chunks bounds the
            # re-fold after a process kill at ckpt_every-1 chunks while
            # keeping the tax small.  In-process retries still resume
            # from the PER-CHUNK in-memory state above.
            if (self.store is not None
                    and fs.next_chunk % self.ckpt_every == 0):
                self.store.save_ckpt(fs)
            if budget_end is not None and self._clock() > budget_end:
                raise _BudgetExhausted(
                    f"stream {key} out of budget at chunk {fs.next_chunk}"
                    f"/{fs.n_chunks}; checkpoint retained")

        def run(backend, resume):
            t0 = self._clock()
            v = self._make_verifier()
            try:
                st = energymodel.stream_layer_topk(
                    grid, self.networks, topk=self.topk, bound=self.bound,
                    metric=metric, chunk_size=self.chunk_size,
                    backend=backend, resume_from=resume, on_chunk=on_chunk,
                    verify=v)
            finally:
                self._harvest_verify(v)
            if resume is None:
                self._record_cost(key, self._clock() - t0)
            return st

        st = self._with_retries(run, key=key, budget_end=budget_end)
        self._drop_ckpt(key)
        self._streams[ck] = st
        self._persist_stream(tier, metric, st)
        return st

    def _persist_stream(self, tier: str, metric: str,
                        st: energymodel.LayerTopK) -> None:
        if self.store is None:
            return
        arrays, meta = store_mod.stream_payload(st)
        self.store.put(self._stream_key(tier, metric),
                       arrays=arrays, meta=meta)

    def _get_points(self, metric: str, *, exact: bool,
                    budget_end: Optional[float] = None) -> tuple:
        """(problems, raw energy [n_chips, n_net], raw latency, solved
        BatchHeteroResult) for one tier — the solved chip points every
        deadline re-sweep reuses; the result feeds the energy-aware
        slack pass without re-solving."""
        tier, grid, _ = self._tier(exact)
        ck = (tier, metric)
        if ck in self._points:
            self.stats["points_cache_hits"] += 1
            return self._points[ck]
        self.stats["points_cache_misses"] += 1
        stream = self._get_stream(metric, exact=exact,
                                  budget_end=budget_end)
        key = ("points", tier, metric)

        def run(backend, resume):
            t0 = self._clock()
            probs = hetero.codesign_problems_streaming(
                grid, self.networks, self.m_cores,
                max_types=self.max_types,
                pool_size=min(self.pool_size, grid.n), bound=self.bound,
                metric=metric, backend=backend, stream=stream)
            res = partition.batch_schedule_hetero(
                probs.lat_dense, probs.counts, n_layers=probs.n_layers_b)
            base = hetero.pareto_codesign(probs, res, n_deadlines=2)
            self._record_cost(key, self._clock() - t0)
            return probs, base.energy, base.latency, res

        out = self._with_retries(run, key=key, budget_end=budget_end)
        self._points[ck] = out
        return out

    # -- incremental grid deltas -------------------------------------------
    def extend_grid(self, new_rows: ConfigGrid) -> Dict[str, Any]:
        """Append config rows to the design space WITHOUT re-streaming it.

        Every completed streamed sweep folds just the appended rows via
        :func:`repro.core.energymodel.merge_layer_topk` — bit-identical
        to a from-scratch stream over the grown grid, because all
        streamed reductions tie-break by (value, flat index).  The
        subsampled tier keeps the same stride, and ``arange(0, n,
        stride)`` is a prefix of ``arange(0, n + k, stride)``, so it
        delta-folds too.  Only the store groups keyed on the two
        superseded grid hashes are invalidated; solved chip points and
        in-flight checkpoints are dropped (their inputs changed), and the
        merged streams are re-persisted under the new hashes."""
        if sorted(new_rows.fields) != sorted(self.grid.fields):
            raise ValueError(
                f"extend_grid: column mismatch — grid has "
                f"{sorted(self.grid.fields)}, new rows have "
                f"{sorted(new_rows.fields)}")
        old_n = self.grid.n
        old_sub_n = int(self._sub_idx.size)
        old_hashes = (self._grid_hash, self._sub_hash)

        new_grid = ConfigGrid.concat([self.grid, new_rows])
        new_sub_idx = np.arange(0, new_grid.n, self._stride)
        delta_sub = new_sub_idx[old_sub_n:] - old_n  # rows INTO new_rows

        merged: Dict[Tuple[str, str], energymodel.LayerTopK] = {}
        n_folds = 0
        for (tier, metric), st in self._streams.items():
            if tier == "exact":
                drows = new_rows
            elif delta_sub.size:
                drows = new_rows.take(delta_sub)
            else:                  # no new stride multiple: tier unchanged
                merged[(tier, metric)] = st
                continue
            v = self._make_verifier()
            try:
                delta = energymodel.stream_layer_topk(
                    drows, self.networks, topk=self.topk, bound=self.bound,
                    metric=metric, chunk_size=self.chunk_size,
                    backend=self.backend, verify=v)
            finally:
                self._harvest_verify(v)
            merged[(tier, metric)] = energymodel.merge_layer_topk(
                st, delta)
            n_folds += 1

        self.grid = new_grid
        self._sub_idx = new_sub_idx
        self._sub_grid = new_grid.take(new_sub_idx)
        self._grid_hash = store_mod.grid_hash(self.grid)
        self._sub_hash = store_mod.grid_hash(self._sub_grid)
        self._streams = merged
        self._points.clear()           # candidate pools may change
        for key in list(self._ckpt):   # mid-stream state is now stale
            self._drop_ckpt(key)
        invalidated = 0
        if self.store is not None:
            for h in old_hashes:
                invalidated += self.store.invalidate_group(h)
            for (tier, metric), st in self._streams.items():
                self._persist_stream(tier, metric, st)
        self.stats["grid_extensions"] += 1
        self.stats["delta_folds"] += n_folds
        self.stats["cache_invalidated"] += invalidated
        return dict(added=int(new_rows.n), n_cfg=int(self.grid.n),
                    n_cfg_degraded=int(self._sub_grid.n),
                    delta_folds=n_folds, invalidated=invalidated)

    def _record_cost(self, key: tuple, dt: float):
        prev = self._cost.get(key)
        self._cost[key] = dt if prev is None else 0.5 * prev + 0.5 * dt

    def _projected_exact_cost(self, metric: str, chip_family: bool
                              ) -> Optional[float]:
        """Projected seconds for the exact artifact: measured cost if
        known, else the subsampled tier's measured cost scaled by point
        ratio — None when neither has run yet."""
        scale = self.grid.n / max(self._sub_grid.n, 1)
        total = 0.0
        known = False
        stages = ["stream", "points"] if chip_family else ["stream"]
        for stage in stages:
            k_ex = (stage, "exact", metric)
            k_sub = (stage, "sub", metric)
            if k_ex in self._cost:
                total += self._cost[k_ex]
                known = True
            elif k_sub in self._cost:
                total += self._cost[k_sub] * scale * self.safety
                known = True
        return total if known else None

    # -- serving -----------------------------------------------------------
    def step(self) -> List[DSEResponse]:
        """Serve ONE coalesced batch: every queued request sharing the
        head request's family and metric.

        An idle tick (empty queue) spends itself on the background
        scrubber instead: ONE store entry is audited per tick, the
        cursor carrying across ticks, so a service that keeps stepping
        while idle eventually re-verifies its whole cache."""
        if not self._queue:
            if (self._idle_scrub and self.verify
                    and self.store is not None):
                res = self.scrub(max_entries=1,
                                 cursor=self._scrub_cursor)
                self._scrub_cursor = res["cursor"]
            return []
        head = self._queue[0]
        family = self._family(head.kind)
        batch = [r for r in self._queue
                 if self._family(r.kind) == family
                 and r.metric == head.metric]
        ids = {id(r) for r in batch}
        self._queue = [r for r in self._queue if id(r) not in ids]
        if len(batch) > 1:
            self.stats["coalesced_batches"] += 1
        t0 = self._clock()
        if family == "resched":
            out = self._serve_resched(batch, head.metric)
        else:
            out = self._serve_batch(batch, head.metric, family == "chip")
        self._record_cost(("request",),
                          (self._clock() - t0) / max(len(batch), 1))
        self.responses.extend(out)
        return out

    def _serve_batch(self, batch, metric, chip_family):
        # persistent answer tier: a request whose EXACT answer is already
        # in the store is served without touching a single sweep — the
        # warm-restart path costs one npz read per query
        served = []
        if self.store is not None:
            rest = []
            for r in batch:
                got = self.store.get(self._answer_key(r, metric))
                if got is not None:
                    self.stats["answer_hits"] += 1
                    served.append(self._respond(r, ok=True, degraded=False,
                                                answer=got[1]))
                else:
                    rest.append(r)
            if not rest:
                return served
            batch = rest
        now = self._clock()

        def rem(r):
            if r.deadline_s is None:
                return None
            return r.deadline_s - (now - r.submitted_at)

        exact_ready = (("exact", metric) in
                       (self._points if chip_family else self._streams))
        if exact_ready:
            exact_grp, degraded_grp = list(batch), []
        else:
            # the sub tier is cheap, always useful (degraded answers) and
            # calibrates the exact-cost projection — build it first
            try:
                self._ensure_tier(metric, chip_family, exact=False,
                                  budget_end=None)
            except ServiceFault as e:
                return served + [self._respond(r, ok=False, degraded=True,
                                               answer={}, error=str(e))
                                 for r in batch]
            proj = self._projected_exact_cost(metric, chip_family)
            exact_grp, degraded_grp = [], []
            for r in batch:
                budget = rem(r)
                if budget is not None and (
                        budget <= 0 or (proj is not None and budget < proj)):
                    degraded_grp.append(r)
                else:
                    exact_grp.append(r)
        if exact_grp and not exact_ready:
            ends = [r.submitted_at + r.deadline_s for r in exact_grp
                    if r.deadline_s is not None]
            budget_end = (None if len(ends) < len(exact_grp)
                          else max(ends))
            try:
                self._ensure_tier(metric, chip_family, exact=True,
                                  budget_end=budget_end)
            except (_BudgetExhausted, ServiceFault):
                # budget ran out mid-stream (checkpoint retained for the
                # next caller) or the backend chain is exhausted — degrade
                degraded_grp.extend(exact_grp)
                exact_grp = []
        out = []
        for grp, degraded in ((exact_grp, False), (degraded_grp, True)):
            if not grp:
                continue
            try:
                out.extend(self._answer_group(grp, metric, chip_family,
                                              degraded=degraded))
            except ServiceFault as e:        # pragma: no cover
                out.extend(self._respond(r, ok=False, degraded=degraded,
                                         answer={}, error=str(e))
                           for r in grp)
        return served + out

    # -- hardware-fault re-scheduling --------------------------------------
    def fault_event(self, chip_types: Sequence[int],
                    chip_counts: Sequence[int],
                    scenario: hw_faults.FaultScenario, *,
                    metric: str = "edp",
                    deadline_s: Optional[float] = None) -> SubmitResult:
        """A hardware fault was reported on a deployed chip: invalidate
        every cached schedule of that chip (nominal included — its
        hardware is no longer what those schedules assumed) and enqueue
        the re-schedule query.  Returns the :class:`SubmitResult`; the
        answer arrives through the normal :meth:`step` loop."""
        self.stats["fault_events"] += 1
        ct = tuple(int(t) for t in chip_types)
        cc = tuple(int(c) for c in chip_counts)
        stale = [k for k in self._resched if k[0] == ct and k[1] == cc]
        for k in stale:
            del self._resched[k]
        self.stats["schedule_invalidations"] += len(stale)
        return self.submit("reschedule", metric=metric,
                           deadline_s=deadline_s, chip_types=ct,
                           chip_counts=cc, scenario=scenario)

    @staticmethod
    def _resched_key(r: DSERequest, metric: str) -> tuple:
        return (r.chip_types, r.chip_counts, r.scenario.key(), metric)

    def _serve_resched(self, batch, metric):
        now = self._clock()
        out, misses = [], []
        for r in batch:
            ans = self._resched.get(self._resched_key(r, metric))
            if ans is not None:
                self.stats["resched_cache_hits"] += 1
                out.append(self._respond(r, ok=True, degraded=False,
                                         answer=ans))
            else:
                self.stats["resched_cache_misses"] += 1
                misses.append(r)
        if not misses:
            return out
        # degradation rung: a request whose remaining budget cannot cover
        # the projected solve is answered from the chip's cached NOMINAL
        # schedule (flagged degraded) when one exists; with no fallback it
        # computes anyway and the deadline_missed flag tells the story.
        proj = self._cost.get(("resched", metric))
        compute, late = [], set()
        for r in misses:
            budget = (None if r.deadline_s is None
                      else r.deadline_s - (now - r.submitted_at))
            if budget is not None and (
                    budget <= 0 or (proj is not None and budget < proj)):
                nom = self._resched.get(
                    (r.chip_types, r.chip_counts, (), metric))
                if nom is not None:
                    out.append(self._respond(
                        r, ok=True, degraded=True,
                        answer=dict(nom, scenario=r.scenario.name,
                                    nominal_only=True)))
                    continue
                late.add(r.rid)
            compute.append(r)
        if not compute:
            return out
        ends = [r.submitted_at + r.deadline_s for r in compute
                if r.deadline_s is not None]
        budget_end = max(ends) if len(ends) == len(compute) else None
        key = ("resched", metric)

        def run(backend, resume):
            t0 = self._clock()
            answers = self._solve_resched(compute, metric, backend)
            self._record_cost(key,
                              (self._clock() - t0) / len(compute))
            return answers

        try:
            answers = self._with_retries(run, key=key,
                                         budget_end=budget_end)
        except (_BudgetExhausted, ServiceFault) as e:
            out.extend(self._respond(r, ok=False, degraded=True,
                                     answer={}, error=str(e))
                       for r in compute)
            return out
        for r, (nom_ans, ans) in zip(compute, answers):
            self._resched[(r.chip_types, r.chip_counts, (),
                           metric)] = nom_ans
            self._resched[self._resched_key(r, metric)] = ans
            self.stats["reschedules"] += 1
            out.append(self._respond(r, ok=True,
                                     degraded=r.rid in late, answer=ans))
        return out

    def _solve_resched(self, reqs, metric, backend):
        """Coalesced fault re-schedule: ONE union-grid engine evaluation
        and ONE ``batch_schedule_hetero(strict=False)`` call cover every
        (request, {nominal, fault}, network) problem; returns one
        ``(nominal answer, fault answer)`` pair per request."""
        batches = [hw_faults.expand_scenarios(
            self.grid, r.chip_types, r.chip_counts, [r.scenario],
            include_nominal=True) for r in reqs]
        union = ConfigGrid.concat([b.grid for b in batches])
        e_l, t_l = energymodel.evaluate_networks(
            union, self.networks, backend=backend, per_layer=True)
        lens = energymodel.network_layer_counts(self.networks)
        n_net = len(self.names)
        t_max = max(b.n_types for b in batches)
        lats, cnts, nls, ens, labels = [], [], [], [], []
        off = 0
        for r, b in zip(reqs, batches):
            lat, cnt, nl, en = hw_faults.scenario_problems(
                b, e_l[off:off + b.grid.n], t_l[off:off + b.grid.n], lens)
            off += b.grid.n
            pad = t_max - lat.shape[1]
            if pad:
                lat = np.pad(lat, ((0, 0), (0, pad), (0, 0)))
                en = np.pad(en, ((0, 0), (0, pad), (0, 0)))
                cnt = np.pad(cnt, ((0, 0), (0, pad)))
            lats.append(lat)
            cnts.append(cnt)
            nls.append(nl)
            ens.append(en)
            labels.extend(f"rid{r.rid}:{sn}:{nm}"
                          for sn in b.names for nm in self.names)
        res = partition.batch_schedule_hetero(
            np.concatenate(lats), np.concatenate(cnts),
            n_layers=np.concatenate(nls), strict=False,
            labels=labels)
        en_all = np.concatenate(ens)

        def one(i, nl_i):
            feas = bool(res.feasible[i])
            tt = res.layer_type[i, :nl_i]
            energy = float(np.take_along_axis(
                en_all[i][:, :nl_i], tt[None, :],
                axis=0)[0].sum()) if feas else float("inf")
            return dict(feasible=feas,
                        bottleneck=float(res.bottleneck[i]),
                        energy=energy,
                        layer_type=tt.tolist() if feas else None)

        out = []
        ro = 0
        for r, b in zip(reqs, batches):
            nets_nom, nets_f = {}, {}
            for j, nm in enumerate(self.names):
                nl_i = int(lens[j])
                nom = one(ro + j, nl_i)
                fl = one(ro + n_net + j, nl_i)
                nom["overhead"] = 1.0 if nom["feasible"] else float("inf")
                fl["overhead"] = (
                    fl["bottleneck"] / nom["bottleneck"]
                    if fl["feasible"] and nom["bottleneck"] > 0
                    else float("inf"))
                nets_nom[nm], nets_f[nm] = nom, fl
            base = dict(chip_types=list(r.chip_types),
                        chip_counts=list(r.chip_counts))
            nom_ans = dict(base, scenario="nominal",
                           counts_after=list(r.chip_counts),
                           feasible=all(v["feasible"]
                                        for v in nets_nom.values()),
                           networks=nets_nom)
            ans = dict(base, scenario=r.scenario.name,
                       counts_after=[int(c) for c in b.counts[1]],
                       feasible=all(v["feasible"]
                                    for v in nets_f.values()),
                       networks=nets_f)
            out.append((nom_ans, ans))
            ro += 2 * n_net
        return out

    def _ensure_tier(self, metric, chip_family, *, exact, budget_end):
        if chip_family:
            self._get_points(metric, exact=exact, budget_end=budget_end)
        else:
            self._get_stream(metric, exact=exact, budget_end=budget_end)

    def _answer_group(self, grp, metric, chip_family, *, degraded):
        tier_exact = not degraded
        _, _, idx_map = self._tier(tier_exact)
        if not chip_family:
            stream = self._get_stream(metric, exact=tier_exact)
            out = []
            for r in grp:
                ans = self._config_answer(r, stream, idx_map)
                self._cache_answer(r, metric, ans, degraded=degraded)
                out.append(self._respond(r, ok=True, degraded=degraded,
                                         answer=ans))
            return out
        probs, pts_e, pts_l, res = self._get_points(metric,
                                                    exact=tier_exact)
        deadlines = sorted({float(r.deadline) for r in grp})
        par = hetero.pareto_codesign(probs, res,
                                     deadlines=np.asarray(deadlines),
                                     points=(pts_e, pts_l), slack=True)
        out = []
        for r in grp:
            di = deadlines.index(float(r.deadline))
            if r.kind == "best_chip":
                ans = self._chip_answer(par, probs, di, idx_map)
            else:
                # the slack union is restricted to THIS request's deadline
                # so the answer is independent of the coalesced batch's
                # other deadlines — a precondition for caching it and for
                # restart-replay bit-parity (the restarted batch is a
                # subset of the original one)
                ans = dict(network=r.network,
                           frontier=par.frontier(r.network),
                           slack_frontier=par.slack_frontier(
                               r.network, deadline_index=di),
                           pool=[int(idx_map[p]) for p in probs.pool])
            self._cache_answer(r, metric, ans, degraded=degraded)
            out.append(self._respond(r, ok=True, degraded=degraded,
                                     answer=ans))
        return out

    def _cache_answer(self, r, metric, ans, *, degraded):
        """Persist one EXACT answer (degraded ones are budget artefacts,
        not functions of the design space — never cached).  The JSON
        round trip returns lists where the computed answer had tuples;
        see the note in :mod:`repro.serving.store`."""
        if self.store is None or degraded:
            return
        self.store.put(self._answer_key(r, metric), meta=ans)

    def _config_answer(self, r, stream, idx_map):
        def one(j):
            return dict(
                idx=int(idx_map[stream.argmin[j]]),
                metric=float(stream.min_metric[j]),
                energy=float(stream.min_energy[j]),
                latency=float(stream.min_latency[j]))
        if r.network is not None:
            return one(self.names.index(r.network))
        return {nm: one(j) for j, nm in enumerate(self.names)}

    def _chip_answer(self, par, probs, di, idx_map):
        ci = int(par.best_chip[di])
        if ci < 0:
            return dict(feasible=False, deadline=float(par.deadlines[di]))
        ans = dict(
            feasible=True, deadline=float(par.deadlines[di]),
            chip_types=[int(idx_map[probs.pool[p]])
                        for p in par.chip_types[ci]],
            chip_counts=[int(c) for c in par.chip_counts[ci]],
            score=float(par.scores[ci, di]))
        if par.slack_scores is not None:
            cs = int(par.best_chip_slack[di])
            ans["slack"] = dict(
                chip_types=[int(idx_map[probs.pool[p]])
                            for p in par.chip_types[cs]],
                chip_counts=[int(c) for c in par.chip_counts[cs]],
                score=float(par.slack_scores[cs, di]),
                moves=int(par.slack_moves[cs, :, di].sum()),
                energy_saved_pct=float(
                    (1.0 - par.slack_scores[cs, di] / par.scores[cs, di])
                    * 100.0))
        return ans

    def _respond(self, r, *, ok, degraded, answer, error=None):
        lat = self._clock() - r.submitted_at
        missed = r.deadline_s is not None and lat > r.deadline_s
        self.stats["completed"] += 1
        self.stats["degraded"] += int(degraded and ok)
        self.stats["deadline_missed"] += int(missed)
        self.stats["errors"] += int(not ok)
        self._lat.append(lat)          # deque(maxlen=) bounds the window
        if self._journal is not None:
            self._journal.done(r.rid)  # answered — replay skips this rid
        return DSEResponse(rid=r.rid, kind=r.kind, ok=ok,
                           degraded=degraded, deadline_missed=missed,
                           answer=answer, error=error, latency_s=lat,
                           backend=energymodel.last_backend())

    def run_until_drained(self, max_steps: int = 1000,
                          timeout_s: Optional[float] = None
                          ) -> Tuple[List[DSEResponse], bool]:
        """Step until the queue empties; ``(responses, drained)`` where
        ``drained=False`` means max_steps/timeout stopped it early."""
        out: List[DSEResponse] = []
        t0 = self._clock()
        for _ in range(max_steps):
            if not self._queue:
                return out, True
            if timeout_s is not None and self._clock() - t0 > timeout_s:
                return out, False
            out.extend(self.step())
        return out, not self._queue

    # -- observability -----------------------------------------------------
    def health(self) -> Dict[str, Any]:
        lat = sorted(self._lat)

        def pct(p):
            if not lat:
                return 0.0
            return float(lat[min(int(p * (len(lat) - 1)), len(lat) - 1)])
        return dict(
            uptime_s=self._clock() - self._t0,
            queue_depth=len(self._queue),
            max_queue=self.max_queue,
            n_cfg=self.grid.n,
            n_cfg_degraded=self._sub_grid.n,
            checkpoints=len(self._ckpt),
            last_backend=energymodel.last_backend(),
            jit=energymodel.jit_cache_stats(),
            p50_s=pct(0.50), p99_s=pct(0.99), n_lat=len(lat),
            lat_window=self.lat_window,
            state_dir=self.state_dir,
            store=None if self.store is None else self.store.health(),
            **self.stats)
