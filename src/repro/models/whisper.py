"""Whisper (arXiv:2212.04356): encoder-decoder transformer backbone.

The conv/mel frontend is a stub per the assignment — ``input_specs`` feeds
precomputed frame embeddings [B, n_frames, d] (30 s → 1500 frames).  The
encoder is bidirectional self-attention over frames with learned positions;
the decoder is causal self-attention + cross-attention to encoder states.

Decode caches: per-layer self-attention KV + the cross-attention K/V
computed once from the encoder output.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..parallel.shardings import shard
from . import layers as L
from .params import Spec
from .transformer import stack_specs


def enc_block_spec(cfg) -> Dict[str, Any]:
    return {
        "attn_norm": L.norm_spec(cfg),
        "attn": L.attention_spec(cfg),
        "mlp_norm": L.norm_spec(cfg),
        "mlp": L.mlp_spec(cfg),
    }


def dec_block_spec(cfg) -> Dict[str, Any]:
    return {
        "self_norm": L.norm_spec(cfg),
        "self_attn": L.attention_spec(cfg),
        "cross_norm": L.norm_spec(cfg),
        "cross_q": L.attention_spec(cfg),       # wq/wo used; wk/wv = enc side
        "mlp_norm": L.norm_spec(cfg),
        "mlp": L.mlp_spec(cfg),
    }


def spec(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "enc_pos": Spec((cfg.n_audio_frames, d), ("frames", "embed"),
                        scale=0.01),
        "enc_layers": stack_specs(enc_block_spec(cfg), cfg.n_encoder_layers),
        "enc_norm": L.norm_spec(cfg),
        "embed": L.embed_spec(cfg),
        "dec_pos": Spec((4096, d), ("seq", "embed"), scale=0.01),
        "dec_layers": stack_specs(dec_block_spec(cfg), cfg.n_layers),
        "dec_norm": L.norm_spec(cfg),
    }


def encode(params, cfg, frames: jax.Array) -> jax.Array:
    """frames: [B, F, d] precomputed embeddings (frontend stub)."""
    f = frames.shape[1]
    x = frames + params["enc_pos"][None, :f]
    x = shard(x, "batch", "seq", "embed")

    def body(h, lp):
        a, _ = L.mha(lp["attn"], cfg,
                     L.apply_norm(lp["attn_norm"], cfg, h),
                     positions=jnp.arange(f)[None], mask_mode="full",
                     apply_rope=False)
        h = h + a
        h = h + L.apply_mlp(lp["mlp"], cfg,
                            L.apply_norm(lp["mlp_norm"], cfg, h))
        return shard(h, "batch", "seq", "embed"), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.apply_norm(params["enc_norm"], cfg, x)


def _dec_block(lp, cfg, x, enc_out, *, positions, cache=None):
    a, nc = L.mha(lp["self_attn"], cfg,
                  L.apply_norm(lp["self_norm"], cfg, x),
                  positions=positions, cache=cache,
                  apply_rope=False)
    x = x + a
    xq = L.apply_norm(lp["cross_norm"], cfg, x)
    ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_q"]["wk"])
    cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_q"]["wv"])
    c, _ = L.mha(lp["cross_q"], cfg, xq, positions=positions,
                 cross_kv=(ck, cv))
    x = x + c
    x = x + L.apply_mlp(lp["mlp"], cfg, L.apply_norm(lp["mlp_norm"], cfg, x))
    return shard(x, "batch", "seq", "embed"), nc


def forward(params, cfg, batch: Dict[str, jax.Array]) -> jax.Array:
    """Train: batch = {frames [B,F,d], tokens [B,T]} → logits [B,T,V]."""
    enc_out = encode(params, cfg, batch["frames"].astype(jnp.bfloat16))
    tokens = batch["tokens"]
    b, t = tokens.shape
    # learned positions; indices wrap for sequences beyond the table (the
    # real model caps decoder length at 448 — the 32k shapes stress the
    # backbone, not the positional table)
    table = params["dec_pos"].shape[0]
    pos_emb = jnp.take(params["dec_pos"], jnp.arange(t) % table, axis=0)
    x = L.embed(params["embed"], cfg, tokens) + pos_emb[None]
    positions = jnp.arange(t, dtype=jnp.int32)[None]

    def body(h, lp):
        out, _ = _dec_block(lp, cfg, h, enc_out, positions=positions)
        return out, None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.apply_norm(params["dec_norm"], cfg, x)
    return L.unembed(params["embed"], cfg, x)


def cache_spec(cfg, batch_size: int, seq_len: int) -> Dict[str, Any]:
    kvh, hd, nl = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    f = cfg.n_audio_frames
    kv = Spec((nl, batch_size, seq_len, kvh, hd),
              ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
              init="zeros")
    cross = Spec((nl, batch_size, f, kvh, hd),
                 ("layers", "batch", "frames", "kv_heads", "head_dim"),
                 init="zeros")
    return {"k": kv, "v": kv, "cross_k": cross, "cross_v": cross,
            "length": Spec((), (), init="zeros", dtype=jnp.int32)}


def init_cross_cache(params, cfg, frames: jax.Array):
    """Precompute the per-layer cross-attention K/V from the encoder."""
    enc_out = encode(params, cfg, frames.astype(jnp.bfloat16))

    def per_layer(lp):
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_q"]["wk"])
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_q"]["wv"])
        return ck, cv

    ck, cv = jax.vmap(per_layer)(params["dec_layers"])
    return ck, cv


def decode_step(params, cfg, tokens: jax.Array, cache: Dict[str, Any]
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    length = cache["length"]
    pos_row = jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], jnp.mod(length, params["dec_pos"].shape[0]), 1)
    x = L.embed(params["embed"], cfg, tokens) + pos_row[None, 0]
    positions = length[None, None] * jnp.ones((1, 1), jnp.int32)

    def body(h, xs):
        lp, ck_self, cv_self, ck, cv = xs
        a, nc = L.mha(lp["self_attn"], cfg,
                      L.apply_norm(lp["self_norm"], cfg, h),
                      positions=positions,
                      cache=dict(k=ck_self, v=cv_self, length=length),
                      apply_rope=False)
        h = h + a
        xq = L.apply_norm(lp["cross_norm"], cfg, h)
        c, _ = L.mha(lp["cross_q"], cfg, xq, positions=positions,
                     cross_kv=(ck, cv))
        h = h + c
        h = h + L.apply_mlp(lp["mlp"], cfg,
                            L.apply_norm(lp["mlp_norm"], cfg, h))
        return h, (nc["k"], nc["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = L.apply_norm(params["dec_norm"], cfg, x)
    logits = L.unembed(params["embed"], cfg, x)
    new_cache = dict(cache)
    new_cache.update(k=nk, v=nv, length=length + tokens.shape[1])
    return logits, new_cache
