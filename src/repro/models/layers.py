"""Shared transformer building blocks: norms, RoPE / M-RoPE, GQA attention
(with KV cache and local windows), dense MLPs.

All functions are pure; parameters come in as dict subtrees created from the
Spec trees in each model module.  Activation sharding uses logical names via
``parallel.shardings.shard`` (no-op outside a mesh context).
"""

from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.shardings import shard
from .params import Spec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_spec(cfg, d: Optional[int] = None) -> Dict[str, Spec]:
    d = d or cfg.d_model
    s = {"scale": Spec((d,), ("embed",), init="ones", dtype=jnp.float32)}
    if cfg.norm == "ln":
        s["bias"] = Spec((d,), ("embed",), init="zeros", dtype=jnp.float32)
    return s


def apply_norm(p, cfg, x: jax.Array) -> jax.Array:
    # f32 is confined to fused reductions / per-row scalars: materialising a
    # full f32 copy of x here makes XLA hoist the convert outside the layer
    # scan and stack f32 carries (observed +5 GiB/device on the dry-run).
    if cfg.norm == "ln":
        mu = x.astype(jnp.float32).mean(-1, keepdims=True)
        var = jnp.square(x.astype(jnp.float32) - mu).mean(-1, keepdims=True)
        inv = jax.lax.rsqrt(var + 1e-6)
        y = ((x - mu.astype(x.dtype)) * inv.astype(x.dtype)
             * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype))
    else:
        ms = jnp.square(x.astype(jnp.float32)).mean(-1, keepdims=True)
        inv = jax.lax.rsqrt(ms + 1e-6)
        y = x * inv.astype(x.dtype) * p["scale"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, T, H, D]; positions: [B, T] (or [T])."""
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)                      # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, T, D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def mrope(x: jax.Array, positions3: jax.Array, theta: float,
          sections: Tuple[int, int, int]) -> jax.Array:
    """M-RoPE (Qwen2-VL): 3 position streams over frequency sections.

    x: [B, T, H, D]; positions3: [3, B, T] (temporal, height, width ids).
    ``sections`` partitions the D/2 frequency slots.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = _rope_freqs(d, theta)                      # [D/2]
    # per-frequency-slot stream selector
    sel = jnp.repeat(jnp.arange(3), jnp.array(sections),
                     total_repeat_length=d // 2)       # [D/2]
    pos = positions3.astype(jnp.float32)               # [3, B, T]
    ang = pos[..., None] * freqs                       # [3, B, T, D/2]
    onehot = jax.nn.one_hot(sel, 3, dtype=jnp.float32)  # [D/2, 3]
    ang = jnp.einsum("sbtf,fs->btf", ang, onehot)      # stream per freq slot
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_spec(cfg, d: Optional[int] = None) -> Dict[str, Spec]:
    d = d or cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": Spec((d, h, hd), ("embed_fsdp", "heads", "head_dim")),
        "wk": Spec((d, kv, hd), ("embed_fsdp", "kv_heads", "head_dim")),
        "wv": Spec((d, kv, hd), ("embed_fsdp", "kv_heads", "head_dim")),
        "wo": Spec((h, hd, d), ("heads", "head_dim", "embed_fsdp")),
    }
    if cfg.qkv_bias:
        s["bq"] = Spec((h, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = Spec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = Spec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    return s


def _qkv(p, cfg, x, xkv=None):
    xkv = x if xkv is None else xkv
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def _sdpa(cfg, q, k, v, mask) -> jax.Array:
    """Grouped-query attention core.  q: [B,T,H,D]; k,v: [B,S,KV,D]."""
    b, t, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q = q.reshape(b, t, kvh, g, hd)
    scores = jnp.einsum("btkgd,bskd->bkgts", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(b, t, h, hd)


def causal_mask(t: int, s: int, window: int = 0,
                offset: int = 0) -> jax.Array:
    """[1,1,1,t,s] boolean mask; query i attends keys ≤ i+offset, within
    ``window`` when nonzero."""
    qpos = jnp.arange(t)[:, None] + offset
    kpos = jnp.arange(s)[None, :]
    m = kpos <= qpos
    if window:
        m = m & (kpos > qpos - window)
    return m[None, None, None]


_BLOCK_Q = 512
_BLOCK_KV = 512


def _sdpa_blockwise(cfg, q, k, v, *, causal: bool, window: int = 0,
                    bq: int = _BLOCK_Q, bkv: int = _BLOCK_KV) -> jax.Array:
    """Flash-style blockwise attention in pure XLA (online softmax).

    Bounds the live score tensor to [B, KV, G, bq, bkv] instead of
    [B, KV, G, T, S]; for causal masks each query block only sweeps the KV
    blocks up to its diagonal (a *static* bound per unrolled q block), so
    no phantom FLOPs are spent above the diagonal.  This mirrors the
    Pallas kernel in ``repro.kernels.flash_attention`` — the TPU target —
    and is the portable XLA fallback the dry-run compiles.
    """
    b, t, h, hd = q.shape
    s = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)
    nq = t // bq
    q = q.reshape(b, nq, bq, kvh, g, hd)
    # pad keys/values to a kv-block multiple; kpos < s masks the tail
    pad = (-s) % bkv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def q_block_sweep(q_blk, k, v, *, q_start: int, nkv: int):
        def kv_step(carry, kj):
            m_run, l_run, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, kj * bkv, bkv, 1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, kj * bkv, bkv, 1)
            sc = jnp.einsum("bqkgd,bskd->bkgqs", q_blk,
                            k_blk).astype(jnp.float32)
            qpos = q_start + jnp.arange(bq)[:, None]
            kpos = kj * bkv + jnp.arange(bkv)[None, :]
            valid = kpos < s
            if causal:
                valid = valid & (kpos <= qpos)
            if window:
                valid = valid & (kpos > qpos - window)
            sc = jnp.where(valid[None, None, None], sc, -1e30)
            m_new = jnp.maximum(m_run, sc.max(-1))
            # NOTE (§Perf, qwen2.5-32b iterations 2–3): streaming the prob
            # block in bf16 (exp fused into a convert, or exp recomputed
            # inside the row-sum reduction) measured *worse* under the XLA
            # fusion-boundary accounting (194.6 → 202/204 s).  The f32
            # [bq, bkv] prob stream is eliminated for real by the Pallas
            # flash kernel (kernels/flash_attention), which keeps p in VMEM
            # scratch — the projected memory term is in EXPERIMENTS.md.
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            acc = (acc * corr[..., None]
                   + jnp.einsum("bkgqs,bskd->bkgqd",
                                p.astype(v_blk.dtype), v_blk)
                   .astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kvh, g, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, bq), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, bq, hd), jnp.float32)
        # remat the kv step: backward recomputes each block's probs instead
        # of stacking [nkv, B, KV, G, bq, bkv] f32 saves.
        (m_f, l_f, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step,
                           policy=jax.checkpoint_policies.nothing_saveable),
            (m0, l0, a0), jnp.arange(nkv))
        return acc / jnp.maximum(l_f, 1e-30)[..., None]

    out_blocks = []
    for qi in range(nq):
        q_blk = q[:, qi] * scale                       # [B, bq, KV, G, hd]
        q_start = qi * bq
        # causal: only KV blocks intersecting [0, q_start + bq) matter
        kv_end = min(s + pad, q_start + bq) if causal else s + pad
        nkv = -(-kv_end // bkv)
        o = q_block_sweep(q_blk, k, v, q_start=q_start, nkv=nkv)
        # [B, KV, G, bq, hd] -> [B, bq, H, hd]
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, bq, h, hd)
        out_blocks.append(o.astype(v.dtype))
    return jnp.concatenate(out_blocks, axis=1)


def mha(p, cfg, x, *, positions, mask_mode="causal", window=0,
        cache=None, mrope_pos=None, cross_kv=None, apply_rope=True):
    """Full attention path.

    Train/prefill: cache is None → self-attention over x (mask_mode =
    'causal' or 'full'; window > 0 adds a sliding window).
    Decode: cache = dict(k, v, length); x is the new token(s), k/v appended.
    Cross-attention: cross_kv = (k, v) precomputed from the encoder.
    Returns (out, new_cache).
    """
    if cross_kv is not None:
        b, t, _ = x.shape
        q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
        if "bq" in p:
            q = q + p["bq"]
        k, v = cross_kv
        if t >= 2 * _BLOCK_Q and t % _BLOCK_Q == 0:
            out = _sdpa_blockwise(cfg, q, k, v, causal=False)
        else:
            out = _sdpa(cfg, q, k, v, None)
        out = jnp.einsum("bthk,hkd->btd", out, p["wo"])
        return out, None

    q, k, v = _qkv(p, cfg, x)
    if apply_rope:
        if mrope_pos is not None:
            q = mrope(q, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
            k = mrope(k, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None:
        t = x.shape[1]
        k = shard(k, "batch", "seq", "kv_heads", None)
        v = shard(v, "batch", "seq", "kv_heads", None)
        causal = mask_mode == "causal"
        if (t >= 2 * _BLOCK_Q and t % _BLOCK_Q == 0
                and t % _BLOCK_KV == 0):
            out = _sdpa_blockwise(cfg, q, k, v, causal=causal,
                                  window=window)
        else:
            mask = causal_mask(t, t, window) if causal else None
            out = _sdpa(cfg, q, k, v, mask)
    else:
        # single-token decode against a prefilled cache
        length = cache["length"]                       # int32 scalar
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, length, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, length, 0, 0))
        new_cache = dict(k=ck, v=cv, length=length + x.shape[1])
        s = ck.shape[1]
        kpos = jnp.arange(s)
        valid = kpos[None, :] <= length                # [1, S]
        if window:
            valid = valid & (kpos[None, :] > length - window)
        mask = valid[None, None, None, :, :] * jnp.ones(
            (1, 1, 1, x.shape[1], 1), bool)
        out = _sdpa(cfg, q, ck, cv, mask)
    out = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return out, new_cache


def cross_kv_spec(cfg) -> Dict[str, Spec]:
    d, kv, hd = cfg.d_model, cfg.n_kv_heads, cfg.head_dim
    return {
        "wk": Spec((d, kv, hd), ("embed_fsdp", "kv_heads", "head_dim")),
        "wv": Spec((d, kv, hd), ("embed_fsdp", "kv_heads", "head_dim")),
    }


def make_cross_kv(p, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def mlp_spec(cfg, d_ff: Optional[int] = None) -> Dict[str, Spec]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "wi_gate": Spec((d, f), ("embed_fsdp", "mlp")),
            "wi_up": Spec((d, f), ("embed_fsdp", "mlp")),
            "wo": Spec((f, d), ("mlp", "embed_fsdp")),
        }
    return {
        "wi": Spec((d, f), ("embed_fsdp", "mlp")),
        "wo": Spec((f, d), ("mlp", "embed_fsdp")),
    }


def apply_mlp(p, cfg, x: jax.Array) -> jax.Array:
    if "wi_gate" in p:
        g = jnp.einsum("btd,df->btf", x, p["wi_gate"])
        u = jnp.einsum("btd,df->btf", x, p["wi_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jnp.einsum("btd,df->btf", x, p["wi"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", "seq", "mlp")
    return jnp.einsum("btf,fd->btd", h, p["wo"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_spec(cfg) -> Dict[str, Spec]:
    # untied tables get their own logical name for the feature dim so perf
    # policies can shard lookups on d (local gathers) while the unembedding
    # projection keeps vocab sharding; tied tables must share the layout.
    lookup_axis = "embed_fsdp" if cfg.tie_embeddings else "embed_lookup"
    vocab_axis = "vocab" if cfg.tie_embeddings else "vocab_in"
    s = {"tok": Spec((cfg.vocab, cfg.d_model), (vocab_axis, lookup_axis),
                     scale=1.0)}
    if not cfg.tie_embeddings:
        s["unembed"] = Spec((cfg.d_model, cfg.vocab),
                            ("embed_fsdp", "vocab"))
    return s


def embed(p, cfg, tokens: jax.Array) -> jax.Array:
    x = p["tok"][tokens]
    return shard(x, "batch", "seq", "embed")


def unembed(p, cfg, x: jax.Array) -> jax.Array:
    """Logits stay in bf16; the loss upcasts inside fused reductions only."""
    w = p.get("unembed")
    if w is None:
        w = p["tok"].T
    logits = jnp.einsum("btd,dv->btv", x, w)
    return shard(logits, "batch", "seq", "vocab")
