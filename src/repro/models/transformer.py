"""Decoder-only transformer family: dense (qwen2.5/stablelm/phi3/qwen2-0.5b),
VLM backbone (qwen2-vl, M-RoPE + patch-embedding stub) and the MoE variants
(qwen2-moe, arctic) via the pluggable FFN from ``moe.py``.

Layers are stacked (leading 'layers' dim) and executed with ``jax.lax.scan``
so HLO size and compile time stay flat in depth; the scan body is optionally
rematerialised.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.shardings import shard
from . import layers as L
from . import moe as moe_mod
from .params import Spec


def stack_specs(tree, n: int):
    """Add a leading stacked-layers dim to every Spec in the tree."""
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.axes, init=s.init,
                       scale=s.scale, dtype=s.dtype),
        tree, is_leaf=lambda x: isinstance(x, Spec))


def block_spec(cfg) -> Dict[str, Any]:
    s = {
        "attn_norm": L.norm_spec(cfg),
        "attn": L.attention_spec(cfg),
        "mlp_norm": L.norm_spec(cfg),
    }
    if cfg.family == "moe":
        s["ffn"] = moe_mod.moe_spec(cfg)
    else:
        s["ffn"] = L.mlp_spec(cfg)
    return s


def spec(cfg) -> Dict[str, Any]:
    return {
        "embed": L.embed_spec(cfg),
        "layers": stack_specs(block_spec(cfg), cfg.n_layers),
        "final_norm": L.norm_spec(cfg),
    }


def _ffn(p, cfg, x):
    if cfg.family == "moe":
        return moe_mod.apply_moe(p, cfg, x)
    return L.apply_mlp(p, cfg, x)


def _block(p, cfg, x, *, positions, cache=None, mrope_pos=None):
    h, new_cache = L.mha(p["attn"], cfg, L.apply_norm(p["attn_norm"], cfg, x),
                         positions=positions, cache=cache,
                         mrope_pos=mrope_pos)
    x = x + h
    x = x + _ffn(p["ffn"], cfg, L.apply_norm(p["mlp_norm"], cfg, x))
    x = shard(x, "batch", "seq", "embed")
    return x, new_cache


def _scan_layers(params, cfg, x, body):
    """scan over the stacked layer params; body(x, layer_params) -> x."""
    def f(carry, lp):
        out = body(carry, lp)
        return out, None

    if cfg.remat:
        f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(f, x, params["layers"])
    return x


def mrope_positions(cfg, b: int, v: int, t: int) -> jax.Array:
    """[3, B, V+T] position ids: vision tokens on an h/w grid, text serial."""
    side = max(int(v ** 0.5), 1)
    vis_t = jnp.zeros((v,), jnp.int32)
    vis_h = (jnp.arange(v) // side).astype(jnp.int32)
    vis_w = (jnp.arange(v) % side).astype(jnp.int32)
    start = (jnp.maximum(jnp.maximum(vis_h.max(initial=0),
                                     vis_w.max(initial=0)), 0) + 1
             if v else jnp.int32(0))
    txt = jnp.arange(t, dtype=jnp.int32) + start
    p3 = jnp.stack([jnp.concatenate([vis_t, txt]),
                    jnp.concatenate([vis_h, txt]),
                    jnp.concatenate([vis_w, txt])])       # [3, V+T]
    return jnp.broadcast_to(p3[:, None, :], (3, b, v + t))


def forward(params, cfg, batch: Dict[str, jax.Array]) -> jax.Array:
    """Train / prefill forward → logits [B, T(+V), vocab]."""
    tokens = batch["tokens"]
    b, t = tokens.shape
    x = L.embed(params["embed"], cfg, tokens)

    mrope_pos = None
    if cfg.family == "vlm" and "vision_embeds" in batch:
        vis = batch["vision_embeds"].astype(x.dtype)     # stub frontend
        x = jnp.concatenate([vis, x], axis=1)
        v = vis.shape[1]
        mrope_pos = mrope_positions(cfg, b, v, t)
    x = shard(x, "batch", "seq", "embed")

    seq = x.shape[1]
    positions = jnp.arange(seq, dtype=jnp.int32)[None, :]

    def body(h, lp):
        out, _ = _block(lp, cfg, h, positions=positions,
                        mrope_pos=mrope_pos)
        return out

    x = _scan_layers(params, cfg, x, body)
    x = L.apply_norm(params["final_norm"], cfg, x)
    return L.unembed(params["embed"], cfg, x)


# ---------------------------------------------------------------------------
# Decode (single new token against a prefilled KV cache)
# ---------------------------------------------------------------------------

def cache_spec(cfg, batch_size: int, seq_len: int) -> Dict[str, Any]:
    kvh, hd, nl = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    kv = Spec((nl, batch_size, seq_len, kvh, hd),
              ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
              init="zeros")
    return {"k": kv, "v": kv,
            "length": Spec((), (), init="zeros", dtype=jnp.int32)}


def decode_step(params, cfg, tokens: jax.Array, cache: Dict[str, Any]
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """tokens: [B, 1] new token ids; cache: stacked per-layer KV.

    Uses fori_loop with the full stacked cache in the carry (in-place
    dynamic updates) instead of scan xs/ys — scan would double-buffer the
    multi-GiB KV stack, fori carries alias to a single buffer."""
    x = L.embed(params["embed"], cfg, tokens)
    length = cache["length"]
    positions = jnp.full((1, 1), length, jnp.int32)
    mrope_pos = None
    if cfg.family == "vlm":
        mrope_pos = jnp.broadcast_to(
            positions[None], (3, tokens.shape[0], 1)).astype(jnp.int32)

    def body(l, carry):
        h, ck, cv = carry
        lp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False),
            params["layers"])
        lk = jax.lax.dynamic_index_in_dim(ck, l, 0, keepdims=False)
        lv = jax.lax.dynamic_index_in_dim(cv, l, 0, keepdims=False)
        out, nc = _block(lp, cfg, h, positions=positions,
                         cache=dict(k=lk, v=lv, length=length),
                         mrope_pos=mrope_pos)
        ck = jax.lax.dynamic_update_index_in_dim(ck, nc["k"], l, 0)
        cv = jax.lax.dynamic_update_index_in_dim(cv, nc["v"], l, 0)
        return (out, ck, cv)

    x, nk, nv = jax.lax.fori_loop(
        0, cfg.n_layers, body, (x, cache["k"], cache["v"]))
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.unembed(params["embed"], cfg, x)
    new_cache = dict(k=nk, v=nv, length=length + tokens.shape[1])
    return logits, new_cache
