"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060), attention-free.

Training/prefill uses the chunked SSD algorithm: intra-chunk (quadratic
within a small chunk — MXU-shaped matmuls) + inter-chunk linear state
recurrence.  Decode is the O(1) recurrent update against a [B, H, P, N]
state — which is why this family runs the 500k long-context shape.

Block layout (Mamba-2 paper): fused in-projection → (z | x | B | C | dt),
causal depthwise conv over (x|B|C), SSD core, gated RMSNorm, out-projection.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..parallel.shardings import shard
from . import layers as L
from .params import Spec


def _dims(cfg):
    di = cfg.d_model * cfg.ssm_expand         # inner width
    h = di // cfg.ssm_head_dim                # heads
    g, n = cfg.ssm_groups, cfg.ssm_state
    conv_dim = di + 2 * g * n
    return di, h, g, n, conv_dim


def block_spec(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    di, h, g, n, conv_dim = _dims(cfg)
    proj_out = 2 * di + 2 * g * n + h          # z, x, B, C, dt
    return {
        "norm": L.norm_spec(cfg),
        "in_proj": Spec((d, proj_out), ("embed_fsdp", "mlp")),
        "conv_w": Spec((cfg.conv_kernel, conv_dim), ("conv", "mlp")),
        "conv_b": Spec((conv_dim,), ("mlp",), init="zeros"),
        "A_log": Spec((h,), ("heads",), init="zeros", dtype=jnp.float32),
        "D": Spec((h,), ("heads",), init="ones", dtype=jnp.float32),
        "dt_bias": Spec((h,), ("heads",), init="zeros", dtype=jnp.float32),
        "gate_norm": Spec((di,), ("mlp",), init="ones", dtype=jnp.float32),
        "out_proj": Spec((di, d), ("mlp", "embed_fsdp")),
    }


def spec(cfg) -> Dict[str, Any]:
    from .transformer import stack_specs
    return {
        "embed": L.embed_spec(cfg),
        "layers": stack_specs(block_spec(cfg), cfg.n_layers),
        "final_norm": L.norm_spec(cfg),
    }


def _split_proj(cfg, zxbcdt):
    di, h, g, n, _ = _dims(cfg)
    z, xin, bmat, cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    return z, xin, bmat, cmat, dt


def _causal_conv(x, w, b):
    """x: [B, T, C]; depthwise causal conv, kernel K."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(x.dtype)


def _segsum(a):
    """a: [..., Q] → lower-triangular pairwise cumulative sums
    L[..., i, j] = sum(a[j+1..i]) for j < i (−inf above diagonal)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, -1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(cfg, xh, bmat, cmat, dt, A, init_state=None):
    """SSD core (chunked scan).

    xh:   [B, T, H, P]    (dt-premultiplied inputs)
    bmat: [B, T, G, N], cmat: [B, T, G, N]
    dt:   [B, T, H]  (softplus'd), A: [H] (negative)
    Returns (y [B, T, H, P], final_state [B, H, P, N]).
    """
    b, t, h, p = xh.shape
    g, n = bmat.shape[2], bmat.shape[3]
    q = min(cfg.ssm_chunk, t)
    nc = t // q
    assert nc * q == t, (t, q)
    rep = h // g

    def cshape(a):
        return a.reshape(a.shape[0], nc, q, *a.shape[2:])

    xc, bc, cc = cshape(xh), cshape(bmat), cshape(cmat)
    da = cshape(dt * A[None, None, :])                   # [B, nc, Q, H]

    da_cum = jnp.cumsum(da, axis=2)                      # [B, nc, Q, H]
    da_total = da_cum[:, :, -1]                          # [B, nc, H]

    # intra-chunk (diagonal blocks)
    lmat = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))    # [B, nc, H, Q, Q]
    cb = jnp.einsum("bcqgn,bckgn->bcgqk", cc, bc)        # [B, nc, G, Q, Q]
    cb = jnp.repeat(cb, rep, axis=2)                     # [B, nc, H, Q, Q]
    y_diag = jnp.einsum("bchqk,bchqk,bckhp->bcqhp",
                        cb, lmat.astype(cb.dtype), xc)

    # chunk states: contribution of each chunk to its final state
    decay_out = jnp.exp(da_total[:, :, None, :] - da_cum)     # [B, nc, Q, H]
    states = jnp.einsum("bcqgn,bcqh,bcqhp->bchpn",
                        bc, decay_out.astype(bc.dtype), xc
                        ).astype(jnp.float32)                 # [B,nc,H,P,N]

    # inter-chunk recurrence over nc (f32 carry for numerical stability)
    def step(carry, inp):
        s_prev = carry
        s_c, da_tot = inp
        s_new = s_prev * jnp.exp(da_tot)[..., None, None] + s_c
        return s_new.astype(jnp.float32), s_prev

    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4), da_total.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # [B, nc, H, P, N]

    # inter-chunk (off-diagonal) output
    decay_in = jnp.exp(da_cum)                           # [B, nc, Q, H]
    crep = jnp.repeat(cc, rep, axis=3) if rep > 1 else cc
    y_off = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                       crep, decay_in.astype(cc.dtype),
                       prev_states.astype(cc.dtype))
    y = (y_diag + y_off.astype(y_diag.dtype)).reshape(b, t, h, p)
    return y, final


def _block(p, cfg, x, *, state=None, conv_state=None, decode=False):
    """One Mamba-2 block.  Returns (y, new_state, new_conv_state)."""
    di, h, g, n, conv_dim = _dims(cfg)
    res = x
    x = L.apply_norm(p["norm"], cfg, x)
    zxbcdt = jnp.einsum("btd,dk->btk", x, p["in_proj"])
    z, xin, bmat, cmat, dt = _split_proj(cfg, zxbcdt)

    conv_in = jnp.concatenate([xin, bmat, cmat], -1)     # [B, T, conv_dim]
    if decode:
        # rotate the conv state buffer [B, K-1, conv_dim]
        buf = jnp.concatenate([conv_state, conv_in], axis=1)
        new_conv_state = buf[:, 1:]
        k = p["conv_w"].shape[0]
        out = sum(buf[:, i:i + 1] * p["conv_w"][i] for i in range(k))
        conv_out = jax.nn.silu(
            (out + p["conv_b"]).astype(jnp.float32)).astype(x.dtype)
    else:
        conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
        new_conv_state = conv_in[:, -(p["conv_w"].shape[0] - 1):]

    xin, bmat, cmat = jnp.split(conv_out, [di, di + g * n], axis=-1)
    bsz, t = x.shape[0], x.shape[1]
    xh = xin.reshape(bsz, t, h, di // h)
    bmat = bmat.reshape(bsz, t, g, n)
    cmat = cmat.reshape(bsz, t, g, n)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                             # [H] negative

    xh = shard(xh, "batch", "seq", "heads", None)
    if decode:
        da = jnp.exp(dtf[:, 0, :] * A)                   # [B, H]
        upd = jnp.einsum("bgn,bh,bhp->bhpn",
                         bmat[:, 0].astype(jnp.float32),
                         dtf[:, 0], xh[:, 0].astype(jnp.float32))
        new_state = state * da[..., None, None] + upd
        crep = jnp.repeat(cmat[:, 0], h // g, axis=1) if g != h \
            else cmat[:, 0]
        y = jnp.einsum("bhn,bhpn->bhp", crep.astype(jnp.float32), new_state)
        y = (y[:, None]
             + p["D"][None, None, :, None] * xh.astype(jnp.float32))
        y = y.astype(x.dtype)
    else:
        xdt = xh * dtf[..., None].astype(xh.dtype)
        y, new_state = ssd_chunked(cfg, xdt, bmat, cmat, dtf, A,
                                   init_state=state)
        y = y + p["D"][None, None, :, None].astype(y.dtype) * xh

    y = y.reshape(bsz, t, di)
    # gated RMSNorm (norm(y * silu(z)))
    gated = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    gf = gated.astype(jnp.float32)
    gf = gf * jax.lax.rsqrt((gf * gf).mean(-1, keepdims=True) + 1e-6)
    gated = (gf * p["gate_norm"]).astype(y.dtype)
    out = jnp.einsum("btk,kd->btd", gated, p["out_proj"])
    return res + out, new_state, new_conv_state


def forward(params, cfg, batch: Dict[str, jax.Array]) -> jax.Array:
    tokens = batch["tokens"]
    x = L.embed(params["embed"], cfg, tokens)

    def body(h, lp):
        out, _, _ = _block(lp, cfg, h)
        return out, None

    f = body
    if cfg.remat:
        f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(f, x, params["layers"])
    x = L.apply_norm(params["final_norm"], cfg, x)
    return L.unembed(params["embed"], cfg, x)


def cache_spec(cfg, batch_size: int, seq_len: int) -> Dict[str, Any]:
    """Recurrent caches are O(1) in seq_len — the long_500k point."""
    di, h, g, n, conv_dim = _dims(cfg)
    nl, k = cfg.n_layers, cfg.conv_kernel
    return {
        "state": Spec((nl, batch_size, h, di // h, n),
                      ("layers", "batch", "heads", None, "state"),
                      init="zeros", dtype=jnp.float32),
        "conv": Spec((nl, batch_size, k - 1, conv_dim),
                     ("layers", "batch", "conv", "mlp"), init="zeros"),
        "length": Spec((), (), init="zeros", dtype=jnp.int32),
    }


def decode_step(params, cfg, tokens: jax.Array, cache: Dict[str, Any]
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    x = L.embed(params["embed"], cfg, tokens)

    def body(h, lp_cache):
        lp, st, cv = lp_cache
        out, ns, ncv = _block(lp, cfg, h, state=st, conv_state=cv,
                              decode=True)
        return out, (ns, ncv)

    x, (ns, ncv) = jax.lax.scan(
        body, x, (params["layers"], cache["state"], cache["conv"]))
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.unembed(params["embed"], cfg, x)
    return logits, dict(state=ns, conv=ncv,
                        length=cache["length"] + tokens.shape[1])
