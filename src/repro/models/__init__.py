from . import model_zoo  # noqa: F401
