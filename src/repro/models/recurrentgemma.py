"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU recurrent blocks and
local (sliding-window) attention blocks in a (rec, rec, attn) pattern.

Training runs the RG-LRU with ``jax.lax.associative_scan`` (log-depth linear
recurrence — the TPU-native way to parallelise h_t = a_t·h_{t−1} + b_t);
decode is the O(1) state update + a fixed 2048-token ring-buffer KV cache,
which is why this family runs the 500k long-context shape.

Layers scan over (rec, rec, attn) super-blocks; the pattern remainder
(38 = 12·3 + 2) is unrolled.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..parallel.shardings import shard
from . import layers as L
from .params import Spec


_C_RGLRU = 8.0     # Griffin's fixed recurrence-sharpness constant


def _w(cfg):
    return cfg.lru_width or cfg.d_model


def rec_block_spec(cfg) -> Dict[str, Any]:
    d, w = cfg.d_model, _w(cfg)
    return {
        "norm": L.norm_spec(cfg),
        "in_x": Spec((d, w), ("embed_fsdp", "mlp")),
        "in_gate": Spec((d, w), ("embed_fsdp", "mlp")),
        "conv_w": Spec((4, w), ("conv", "mlp")),
        "conv_b": Spec((w,), ("mlp",), init="zeros"),
        "wa": Spec((w, w), ("mlp", None)),         # recurrence gate
        "ba": Spec((w,), ("mlp",), init="zeros"),
        "wi": Spec((w, w), ("mlp", None)),         # input gate
        "bi": Spec((w,), ("mlp",), init="zeros"),
        "a_param": Spec((w,), ("mlp",), init="lru_a", dtype=jnp.float32),
        "out": Spec((w, d), ("mlp", "embed_fsdp")),
    }


def attn_block_spec(cfg) -> Dict[str, Any]:
    return {"norm": L.norm_spec(cfg), "attn": L.attention_spec(cfg)}


def mlp_block_spec(cfg) -> Dict[str, Any]:
    return {"norm": L.norm_spec(cfg), "mlp": L.mlp_spec(cfg)}


def superblock_spec(cfg) -> Dict[str, Any]:
    """(rec, rec, attn), each followed by an MLP block."""
    return {
        "rec0": rec_block_spec(cfg), "mlp0": mlp_block_spec(cfg),
        "rec1": rec_block_spec(cfg), "mlp1": mlp_block_spec(cfg),
        "attn": attn_block_spec(cfg), "mlp2": mlp_block_spec(cfg),
    }


def layout(cfg) -> Tuple[int, int]:
    """(#scanned super-blocks, #remainder rec layers)."""
    n_super = cfg.n_layers // len(cfg.block_pattern)
    rem = cfg.n_layers - n_super * len(cfg.block_pattern)
    return n_super, rem


def spec(cfg) -> Dict[str, Any]:
    from .transformer import stack_specs
    n_super, rem = layout(cfg)
    s = {
        "embed": L.embed_spec(cfg),
        "super": stack_specs(superblock_spec(cfg), n_super),
        "final_norm": L.norm_spec(cfg),
    }
    for i in range(rem):
        s[f"tail{i}"] = {"rec": rec_block_spec(cfg),
                         "mlp": mlp_block_spec(cfg)}
    return s


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _rg_lru_scan(a: jax.Array, b: jax.Array, h0=None) -> jax.Array:
    """h_t = a_t · h_{t−1} + b_t over axis 1 via associative scan."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def apply_rec_block(p, cfg, x, *, state=None, conv_state=None,
                    decode=False):
    """Griffin recurrent block.  Returns (out, state, conv_state)."""
    res = x
    x = L.apply_norm(p["norm"], cfg, x)
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["in_gate"])
                       .astype(jnp.float32)).astype(x.dtype)
    xb = jnp.einsum("btd,dw->btw", x, p["in_x"])

    # temporal conv (kernel 4, causal)
    k = p["conv_w"].shape[0]
    if decode:
        buf = jnp.concatenate([conv_state, xb], axis=1)
        new_conv_state = buf[:, 1:]
        xc = sum(buf[:, i:i + 1] * p["conv_w"][i] for i in range(k))
        xc = xc + p["conv_b"]
    else:
        pad = jnp.pad(xb, ((0, 0), (k - 1, 0), (0, 0)))
        xc = sum(pad[:, i:i + xb.shape[1]] * p["conv_w"][i]
                 for i in range(k)) + p["conv_b"]
        new_conv_state = xb[:, -(k - 1):]

    # RG-LRU gates
    r = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xc, p["wa"])
                       .astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xc, p["wi"])
                       .astype(jnp.float32) + p["bi"])
    log_a = -_C_RGLRU * r * jax.nn.softplus(p["a_param"])
    a = jnp.exp(log_a)                                    # [B, T, W]
    gated_in = (i * xc.astype(jnp.float32)) * jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))

    if decode:
        h = a[:, 0] * state + gated_in[:, 0]              # [B, W]
        new_state = h
        y = h[:, None]
    else:
        h = _rg_lru_scan(a, gated_in,
                         h0=state if state is not None else None)
        new_state = h[:, -1]
        y = h
    y = (y.astype(x.dtype) * gate)
    out = jnp.einsum("btw,wd->btd", y, p["out"])
    return res + out, new_state, new_conv_state


def apply_attn_block(p, cfg, x, *, positions, cache=None):
    res = x
    h, new_cache = L.mha(p["attn"], cfg, L.apply_norm(p["norm"], cfg, x),
                         positions=positions, window=cfg.attn_window,
                         cache=cache)
    return res + h, new_cache


def apply_mlp_block(p, cfg, x):
    return x + L.apply_mlp(p["mlp"], cfg,
                           L.apply_norm(p["norm"], cfg, x))


def _superblock(sp, cfg, x, *, positions, caches=None):
    """caches: dict(rec0=(h, conv), rec1=(h, conv), attn=kv) or None."""
    nc = {}
    c = caches or {}
    x, h0, cv0 = apply_rec_block(
        sp["rec0"], cfg, x, decode=caches is not None,
        state=c.get("rec0", (None, None))[0],
        conv_state=c.get("rec0", (None, None))[1])
    x = apply_mlp_block(sp["mlp0"], cfg, x)
    x, h1, cv1 = apply_rec_block(
        sp["rec1"], cfg, x, decode=caches is not None,
        state=c.get("rec1", (None, None))[0],
        conv_state=c.get("rec1", (None, None))[1])
    x = apply_mlp_block(sp["mlp1"], cfg, x)
    x, kv = apply_attn_block(sp["attn"], cfg, x, positions=positions,
                             cache=c.get("attn"))
    x = apply_mlp_block(sp["mlp2"], cfg, x)
    x = shard(x, "batch", "seq", "embed")
    nc = dict(rec0=(h0, cv0), rec1=(h1, cv1), attn=kv)
    return x, nc


def forward(params, cfg, batch: Dict[str, jax.Array]) -> jax.Array:
    tokens = batch["tokens"]
    x = L.embed(params["embed"], cfg, tokens)
    t = tokens.shape[1]
    positions = jnp.arange(t, dtype=jnp.int32)[None, :]

    def body(h, sp):
        out, _ = _superblock(sp, cfg, h, positions=positions)
        return out, None

    f = body
    if cfg.remat:
        f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(f, x, params["super"])
    n_super, rem = layout(cfg)
    for i in range(rem):
        tp = params[f"tail{i}"]
        x, _, _ = apply_rec_block(tp["rec"], cfg, x)
        x = apply_mlp_block(tp["mlp"], cfg, x)
    x = L.apply_norm(params["final_norm"], cfg, x)
    return L.unembed(params["embed"], cfg, x)


# ---------------------------------------------------------------------------
# Decode: O(1) recurrent state + fixed-window ring KV
# ---------------------------------------------------------------------------

def cache_spec(cfg, batch_size: int, seq_len: int) -> Dict[str, Any]:
    n_super, rem = layout(cfg)
    w = _w(cfg)
    win = min(cfg.attn_window, seq_len)
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    k = 4
    s = {
        "rec_h": Spec((n_super, 2, batch_size, w),
                      ("layers", None, "batch", "mlp"), init="zeros",
                      dtype=jnp.float32),
        "rec_conv": Spec((n_super, 2, batch_size, k - 1, w),
                         ("layers", None, "batch", "conv", "mlp"),
                         init="zeros"),
        "attn_k": Spec((n_super, batch_size, win, kvh, hd),
                       ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                       init="zeros"),
        "attn_v": Spec((n_super, batch_size, win, kvh, hd),
                       ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                       init="zeros"),
        "length": Spec((), (), init="zeros", dtype=jnp.int32),
    }
    for i in range(rem):
        s[f"tail{i}_h"] = Spec((batch_size, w), ("batch", "mlp"),
                               init="zeros", dtype=jnp.float32)
        s[f"tail{i}_conv"] = Spec((batch_size, k - 1, w),
                                  ("batch", "conv", "mlp"), init="zeros")
    return s


def decode_step(params, cfg, tokens: jax.Array, cache: Dict[str, Any]
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    x = L.embed(params["embed"], cfg, tokens)
    length = cache["length"]
    win = cache["attn_k"].shape[2]
    # Window cache: exact while length < window; once full, the newest token
    # overwrites the final slot (first-order approximation of a ring buffer —
    # the window mask in L.mha keeps attention scoped either way).
    positions = length[None, None] * jnp.ones((1, 1), jnp.int32)

    def body(h, xs):
        sp, rec_h, rec_conv, ak, av = xs
        caches = dict(
            rec0=(rec_h[0], rec_conv[0]),
            rec1=(rec_h[1], rec_conv[1]),
            attn=dict(k=ak, v=av, length=jnp.minimum(length, win - 1)))
        out, nc = _superblock(sp, cfg, h, positions=positions,
                              caches=caches)
        new_rec_h = jnp.stack([nc["rec0"][0], nc["rec1"][0]])
        new_rec_conv = jnp.stack([nc["rec0"][1], nc["rec1"][1]])
        return out, (new_rec_h, new_rec_conv, nc["attn"]["k"],
                     nc["attn"]["v"])

    x, (nh, ncv, nk, nv) = jax.lax.scan(
        body, x, (params["super"], cache["rec_h"], cache["rec_conv"],
                  cache["attn_k"], cache["attn_v"]))

    new_cache = dict(cache)
    new_cache.update(rec_h=nh, rec_conv=ncv, attn_k=nk, attn_v=nv,
                     length=length + tokens.shape[1])
    n_super, rem = layout(cfg)
    for i in range(rem):
        tp = params[f"tail{i}"]
        x, hs, cs = apply_rec_block(
            tp["rec"], cfg, x, state=cache[f"tail{i}_h"],
            conv_state=cache[f"tail{i}_conv"], decode=True)
        x = apply_mlp_block(tp["mlp"], cfg, x)
        new_cache[f"tail{i}_h"] = hs
        new_cache[f"tail{i}_conv"] = cs
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.unembed(params["embed"], cfg, x)
    return logits, new_cache
