"""Unified model API over all assigned architecture families.

``spec(cfg)`` → parameter Spec tree; ``forward`` → logits; ``cache_spec`` /
``decode_step`` → serving path.  ``train_step``/``serve_step`` in
``launch.steps`` build on these.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import mamba2, recurrentgemma, transformer, whisper
from . import params as P

_FAMILY = {
    "dense": transformer,
    "vlm": transformer,
    "moe": transformer,
    "audio": whisper,
    "ssm": mamba2,
    "hybrid": recurrentgemma,
}


def module_for(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def spec(cfg: ModelConfig):
    return module_for(cfg).spec(cfg)


def init(cfg: ModelConfig, key: jax.Array):
    return P.init_tree(spec(cfg), key)


def axes(cfg: ModelConfig):
    return P.axes_tree(spec(cfg))


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    return module_for(cfg).forward(params, cfg, batch)


def cache_spec(cfg: ModelConfig, batch_size: int, seq_len: int):
    return module_for(cfg).cache_spec(cfg, batch_size, seq_len)


def decode_step(params, cfg: ModelConfig, tokens, cache):
    return module_for(cfg).decode_step(params, cfg, tokens, cache)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def next_token_loss(logits: jax.Array, tokens: jax.Array,
                    text_offset: int = 0) -> jax.Array:
    """Mean cross-entropy of predicting tokens[:, 1:] from logits.

    ``text_offset`` skips a non-text prefix (vision tokens) in the logits.
    Implemented with a position mask instead of slicing so no [B, T−1, V]
    logits copy is materialised, and with f32 confined to fused reductions
    (logits arrive in bf16).
    """
    if text_offset:
        logits = jax.lax.dynamic_slice_in_dim(
            logits, text_offset, logits.shape[1] - text_offset, 1)
    b, t, v = logits.shape
    # shifted targets; final position masked out
    tgt = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((b, 1), tokens.dtype)], axis=1)
    mask = (jnp.arange(t) < t - 1).astype(jnp.float32)[None, :]
    m = jax.lax.stop_gradient(
        logits.astype(jnp.float32).max(-1, keepdims=True))
    logz = (m[..., 0] + jnp.log(
        jnp.exp(logits.astype(jnp.float32) - m).sum(-1)))
    gold = jnp.take_along_axis(logits, tgt[..., None], -1)[..., 0]
    nll = (logz - gold.astype(jnp.float32)) * mask
    return nll.sum() / mask.sum() / b


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits = forward(params, cfg, batch)
    offset = 0
    if cfg.family == "vlm" and "vision_embeds" in batch:
        offset = batch["vision_embeds"].shape[1]
    loss = next_token_loss(logits, batch["tokens"], text_offset=offset)
    aux = {"loss": loss}
    return loss, aux


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct) for the dry-run — no allocation.
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, *, seq_len: int, global_batch: int,
                kind: str) -> Dict[str, Any]:
    """Stand-ins for every model input of the given shape cell.

    kind='train'   → {tokens, labels(-free: next-token), +frontend stubs}
    kind='prefill' → same tensor shapes as train (loss not taken)
    kind='decode'  → {tokens: [B, 1], cache: prefilled to seq_len}
    """
    f32, i32, bf16 = jnp.float32, jnp.int32, jnp.bfloat16
    b, t = global_batch, seq_len

    if kind in ("train", "prefill"):
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, t), i32)}
        if cfg.family == "vlm":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_tokens, cfg.d_model), bf16)
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.n_audio_frames, cfg.d_model), bf16)
        return specs

    assert kind == "decode", kind
    cache = P.abstract_tree(cache_spec(cfg, b, t))
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32), "cache": cache}


def make_batch(cfg: ModelConfig, *, seq_len: int, global_batch: int,
               key: jax.Array) -> Dict[str, jax.Array]:
    """Concrete random batch matching ``input_specs`` (smoke tests/examples)."""
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(
        ks[0], (global_batch, seq_len), 0, cfg.vocab, jnp.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            ks[1], (global_batch, cfg.vision_tokens, cfg.d_model),
            jnp.float32).astype(jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (global_batch, cfg.n_audio_frames, cfg.d_model),
            jnp.float32).astype(jnp.bfloat16)
    return batch
