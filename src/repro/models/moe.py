"""Mixture-of-experts FFN (qwen2-moe: shared + routed top-4; arctic:
128-expert top-2 + dense residual MLP).

Dispatch is gather-based with a fixed per-expert capacity, *grouped by batch
row* so the slot-ranking cumsum stays local to each data shard: tokens are
ranked into expert slots per group, gathered into [B, E, C, d] blocks,
processed with stacked expert weights (einsum — real FLOPs only, no one-hot
phantom matmuls that would poison the roofline's useful-FLOPs ratio) and
combined back with the routing weights.  Overflowing tokens drop (standard
capacity-factor semantics); the router is softmax-then-top-k with
renormalised weights.

The expert dim is a first-class logical axis ('experts' → 'model' by default
= expert parallelism); the group dim stays on ('pod','data'), so GSPMD
lowers the dispatch/combine boundary into the expected expert-parallel
collectives, visible in the dry-run HLO.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..parallel.shardings import shard
from .params import Spec


def moe_spec(cfg) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    s: Dict[str, Any] = {
        "router": Spec((d, e), ("embed_fsdp", "experts"), dtype=jnp.float32),
        "wi_gate": Spec((e, d, f), ("experts", "expert_embed", "expert_mlp")),
        "wi_up": Spec((e, d, f), ("experts", "expert_embed", "expert_mlp")),
        "wo": Spec((e, f, d), ("experts", "expert_mlp", "expert_embed")),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        s["shared"] = {
            "wi_gate": Spec((d, fs), ("embed_fsdp", "mlp")),
            "wi_up": Spec((d, fs), ("embed_fsdp", "mlp")),
            "wo": Spec((fs, d), ("mlp", "embed_fsdp")),
        }
    if cfg.moe_dense_residual:
        fr = cfg.dense_residual_ff
        s["dense"] = {
            "wi_gate": Spec((d, fr), ("embed_fsdp", "mlp")),
            "wi_up": Spec((d, fr), ("embed_fsdp", "mlp")),
            "wo": Spec((fr, d), ("mlp", "embed_fsdp")),
        }
    return s


def _swiglu(x, w):
    g = jnp.einsum("btd,df->btf", x, w["wi_gate"])
    u = jnp.einsum("btd,df->btf", x, w["wi_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("btf,fd->btd", h, w["wo"])


def route(p, cfg, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [B, T, d] → (expert ids [B, T, K], weights [B, T, K])."""
    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)    # renormalise
    return ids, w.astype(x.dtype)


def capacity(cfg, tokens_per_group: int) -> int:
    cap = int(math.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor
                        / cfg.n_experts))
    # MXU-aligned capacity avoids ragged tiles downstream
    return max(8, -(-cap // 8) * 8)


def dispatch_plan(cfg, ids: jax.Array, cap: int):
    """Per-group slotting.  ids: [B, T, K] →
    (tok4slot [B, E, C], keep [B, T, K], slot_of [B, T, K])."""
    b, t, k = ids.shape
    e = cfg.n_experts
    flat = ids.reshape(b, t * k)
    onehot = jax.nn.one_hot(flat, e, dtype=jnp.int32)        # [B, TK, E]
    pos = jnp.cumsum(onehot, axis=1) - 1
    rank = jnp.take_along_axis(pos, flat[..., None], 2)[..., 0]  # [B, TK]
    keep = rank < cap
    slot_key = jnp.where(keep, flat * cap + rank, e * cap)   # overflow bin
    token_ids = (jnp.arange(t * k, dtype=jnp.int32) // k)[None, :]
    tok4slot = jnp.zeros((b, e * cap + 1), jnp.int32).at[
        jnp.arange(b)[:, None], slot_key].set(
        jnp.broadcast_to(token_ids, (b, t * k)), mode="drop")
    tok4slot = tok4slot[:, :-1].reshape(b, e, cap)
    return (tok4slot, keep.reshape(b, t, k),
            jnp.where(keep, rank, 0).reshape(b, t, k))


def apply_moe(p, cfg, x: jax.Array) -> jax.Array:
    """x: [B, T, d] → [B, T, d] (B = dispatch groups, data-sharded)."""
    b, t, d = x.shape
    e = cfg.n_experts
    ids, w = route(p, cfg, x)
    cap = capacity(cfg, t)
    tok4slot, keep, slot_of = dispatch_plan(cfg, ids, cap)

    # gather tokens into expert blocks (group-local)
    bidx = jnp.arange(b)[:, None]
    expert_in = x[bidx, tok4slot.reshape(b, e * cap)]        # [B, EC, d]
    expert_in = expert_in.reshape(b, e, cap, d)
    expert_in = shard(expert_in, "batch", "experts", "expert_cap", "embed")

    g = jnp.einsum("becd,edf->becf", expert_in, p["wi_gate"])
    u = jnp.einsum("becd,edf->becf", expert_in, p["wi_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = jnp.einsum("becf,efd->becd", h, p["wo"])
    # Re-shard expert outputs to group-local BEFORE the combine gather: the
    # gather's slot indices span every expert, so gathering from an
    # E/model-sharded operand makes GSPMD replicate + all-reduce the full
    # [B, T·K, d] result (measured 6.3 TB/chip/step on arctic-480b).  An
    # explicit all-gather of h over the model axis is ~25× smaller and the
    # combine becomes shard-local.
    h = shard(h, "batch", None, "expert_cap", "embed")

    # combine: read each (token, k)'s slot back, weight, and sum over k
    flat_slots = (ids * cap + slot_of).reshape(b, t * cfg.top_k)
    gathered = h.reshape(b, e * cap, d)[bidx, flat_slots]
    gathered = gathered.reshape(b, t, cfg.top_k, d)
    gathered = jnp.where(keep[..., None], gathered, 0)
    out = jnp.einsum("btkd,btk->btd", gathered, w)

    if cfg.n_shared_experts:
        out = out + _swiglu(x, p["shared"])
    if cfg.moe_dense_residual:
        out = out + _swiglu(x, p["dense"])
    return out


def load_balance_loss(p, cfg, x: jax.Array) -> jax.Array:
    """Auxiliary loss (Switch-style): E · Σ_e f_e · p̄_e."""
    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, -1)
    _, ids = jax.lax.top_k(probs, cfg.top_k)
    f = jnp.mean(jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32),
                 axis=(0, 1, 2))
    pbar = probs.mean((0, 1))
    return cfg.n_experts * jnp.sum(f * pbar)
