"""Parameter specs: shape + logical sharding axes + initializer, in one tree.

A model describes its parameters once as a tree of :class:`Spec`; from that
single description we derive (a) initialized arrays (``init_tree``), (b) the
logical-axis tree used by ``parallel.shardings`` to build NamedShardings
(``axes_tree``), and (c) ShapeDtypeStructs for allocation-free dry-runs
(``abstract_tree``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis name per dim
    init: str = "normal"                 # normal | zeros | ones | lru_a
    scale: float = 0.02
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _leaves(tree):
    return jax.tree.leaves(tree, is_leaf=is_spec)


def init_tree(tree, key: jax.Array):
    """Materialise a Spec tree into arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))

    def mk(spec: Spec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        if spec.init == "lru_a":
            # RG-LRU "a" parameter: initialised so a = sigmoid(x)^(c) spreads
            # decays in (0.9, 0.999) — standard Griffin init.
            u = jax.random.uniform(k, spec.shape, jnp.float32, 0.9, 0.999)
            x = jnp.log(u ** (1.0 / 8.0) / (1 - u ** (1.0 / 8.0)))
            return x.astype(spec.dtype)
        return (jax.random.normal(k, spec.shape, jnp.float32)
                * spec.scale).astype(spec.dtype)

    out = [mk(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def axes_tree(tree):
    """Spec tree → tree of logical-axis tuples (same structure)."""
    return jax.tree.map(lambda s: s.axes, tree, is_leaf=is_spec)


def abstract_tree(tree):
    """Spec tree → ShapeDtypeStruct tree (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree,
        is_leaf=is_spec)


def count_params(tree) -> int:
    return sum(int(np.prod(s.shape)) for s in _leaves(tree))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if a.dtype != dtype else a, tree)
