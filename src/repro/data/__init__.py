from .pipeline import DataPipeline, SyntheticLM  # noqa: F401
