"""Deterministic, resumable, shardable data pipeline.

Production features the trainer relies on:

* **Deterministic indexing** — sample content is a pure function of
  (seed, step, index); restarting from a checkpoint replays the exact
  stream from the recorded step, so fault recovery is bit-exact.
* **Shardable** — each data-parallel host reads only its slice
  (``host_id / num_hosts``); no coordination needed.
* **Prefetch** — a small background thread keeps ``prefetch`` batches ahead
  (on CPU this is a bounded queue; on TPU the device transfer overlaps).

``SyntheticLM`` generates token streams with a Zipfian unigram distribution
plus Markov bigram structure — enough signal for loss-goes-down smoke
training without external data.  A memmap-backed corpus source with the
same interface is provided for real token files.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLM:
    """Deterministic synthetic LM token source."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.seed = seed
        rng = np.random.default_rng(seed)
        # Zipfian unigram distribution
        ranks = np.arange(1, min(vocab, 4096) + 1)
        p = 1.0 / ranks
        self.p = p / p.sum()
        self.support = rng.permutation(min(vocab, 4096))

    def sample(self, step: int, index: int, seq_len: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 1_000_003 + index)
        base = rng.choice(self.support, size=seq_len, p=self.p)
        # Markov-ish bigram structure: every even position repeats a shifted
        # copy of the previous token (learnable signal).
        base[1::2] = (base[0::2][: len(base[1::2])] + 1) % self.vocab
        return base.astype(np.int32)


class MemmapCorpus:
    """Token-file source with the same (step, index) interface."""

    def __init__(self, path: str, seq_len_hint: int = 4096):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.n = len(self.tokens)

    def sample(self, step: int, index: int, seq_len: int) -> np.ndarray:
        start = ((step * 2_654_435_761 + index * 40_503) %
                 max(self.n - seq_len - 1, 1))
        return np.asarray(self.tokens[start:start + seq_len],
                          dtype=np.int32)


class DataPipeline:
    def __init__(self, source, *, global_batch: int, seq_len: int,
                 host_id: int = 0, num_hosts: int = 1, start_step: int = 0,
                 prefetch: int = 2, extras: Optional[Dict] = None):
        assert global_batch % num_hosts == 0
        self.source = source
        self.global_batch = global_batch
        self.local_batch = global_batch // num_hosts
        self.seq_len = seq_len
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.step = start_step
        self.extras = extras or {}
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _make_batch(self, step: int) -> Dict[str, np.ndarray]:
        rows = []
        for i in range(self.local_batch):
            index = self.host_id * self.local_batch + i
            rows.append(self.source.sample(step, index, self.seq_len))
        batch = {"tokens": np.stack(rows)}
        for name, fn in self.extras.items():
            batch[name] = fn(step, self.local_batch)
        return batch

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put(self._make_batch(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self._q.get()
        self.step += 1
        return batch

    def state(self) -> Dict:
        """Checkpointable position (replayable after restart)."""
        return dict(step=self.step, host_id=self.host_id,
                    num_hosts=self.num_hosts)

    def close(self):
        self._stop.set()
