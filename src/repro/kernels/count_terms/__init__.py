from .ops import count_term_layers, count_term_sums  # noqa: F401
from .ref import count_term_layers_ref, count_term_sums_ref  # noqa: F401
