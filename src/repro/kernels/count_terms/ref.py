"""Pure-jnp oracle for the fused count-terms kernel.

Mirrors ``energymodel._term_sums_body`` without the two-level dedup: the
RS mapping runs directly on the count-unique rows and the layer axis is
collapsed with static per-network segment slices — the exact arithmetic
the Pallas kernel fuses, in the engine's original reduction order.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import energymodel


def count_term_sums_ref(cfg_u, lay, segments) -> jnp.ndarray:
    """cfg_u: dict of [n_u, 1] count columns; lay: dict of [1, L] layer
    columns; segments: static ((start, stop), ...) per network, the last
    stop == L.  Returns the stacked [N_TERMS, n_u, n_net] partial sums
    (config-independent terms broadcast along the unique axis)."""
    terms = energymodel._count_terms(jnp, cfg_u, lay)
    n_u = cfg_u[next(iter(cfg_u))].shape[0]
    out = []
    for t in terms:
        s = jnp.stack([t[..., a:b].sum(-1) for a, b in segments], axis=-1)
        out.append(jnp.broadcast_to(s, (n_u, len(segments))))
    return jnp.stack(out)


def count_term_layers_ref(cfg_u, lay) -> jnp.ndarray:
    """Per-layer oracle: the raw [N_TERMS, n_u, L] term stack (the
    config-independent terms broadcast along the unique axis), i.e. the
    segment-reduction-free twin of :func:`count_term_sums_ref`."""
    terms = energymodel._count_terms(jnp, cfg_u, lay)
    n_u = cfg_u[next(iter(cfg_u))].shape[0]
    l_tot = lay[next(iter(lay))].shape[-1]
    return jnp.stack([jnp.broadcast_to(t, (n_u, l_tot)) for t in terms])
