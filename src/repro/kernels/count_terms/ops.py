"""Padding/stacking wrapper around the fused count-terms Pallas kernel.

Takes the engine's native operands — the [n_u, 1] count-unique config
columns, the [1, L] stacked layer columns, and the static per-network
``segments`` tuple — pads both tiled axes to block multiples, builds the
one-hot segment matrix, and returns the tuple of 14 [n_u, n_net] partial
sums ``energymodel._gather_combine_body`` consumes.  Traceable under
``jax.jit`` (all shapes static at trace time).

Two engine paths consume the per-layer variant
(:func:`count_term_layers`, no segment reduction):
``evaluate_networks(..., per_layer=True)`` for dense per-layer tensors,
and the streamed per-layer reduction
(:func:`repro.core.energymodel.stream_layer_topk`) which dispatches one
``count_term_layers`` call per fixed-shape chunk — the chunk padding
upstream keeps ``n_u`` stable so the whole stream shares one trace.

The interpret-mode default can be overridden process-wide with
``REPRO_PALLAS_NATIVE=1`` (see :func:`default_interpret`) on hosts where
a native Mosaic/Triton lowering of the tile program has been validated;
explicit ``interpret=`` arguments always win.
"""

from __future__ import annotations

import os

import numpy as np
import jax.numpy as jnp

from repro.core.energymodel import _PAD_LAYER_ROW
from .kernel import (CFG_COLUMNS, LAYER_FIELDS, N_TERMS,
                     count_layers_kernel, count_terms_kernel)


def default_interpret() -> bool:
    """Whether the Pallas kernels run in interpret mode by default.

    True everywhere unless ``REPRO_PALLAS_NATIVE=1`` opts into a native
    lowering — the tile program is float64 with an n_net-wide innermost
    dimension, which violates TPU/Mosaic tiling constraints as written,
    so the opt-in is for hosts where a lowering has been validated
    (see docs/architecture.md)."""
    return os.environ.get("REPRO_PALLAS_NATIVE", "") != "1"


def _pad_operands(cfg_u, lay, block_u: int, block_l: int):
    """Stack the struct-of-arrays operands into the kernel's 2-D layout
    and pad both tiled axes to block multiples (config rows repeat row 0
    — a benign valid config; layer columns get ``_PAD_LAYER_ROW``, whose
    terms are exactly zero)."""
    cfg = jnp.concatenate(
        [jnp.asarray(cfg_u[k]).reshape(1, -1) for k in CFG_COLUMNS], axis=0)
    laym = jnp.concatenate(
        [jnp.asarray(lay[k]).reshape(1, -1) for k in LAYER_FIELDS], axis=0)
    n_u = cfg.shape[1]
    l_tot = laym.shape[1]

    bu = min(block_u, max(8, n_u))
    pad_u = (-n_u) % bu
    if pad_u:
        cfg = jnp.concatenate([cfg, jnp.broadcast_to(
            cfg[:, :1], (cfg.shape[0], pad_u))], axis=1)
    bl = min(block_l, l_tot)
    pad_l = (-l_tot) % bl
    if pad_l:
        pad_col = np.array([[_PAD_LAYER_ROW[k]] for k in LAYER_FIELDS])
        laym = jnp.concatenate([laym, jnp.broadcast_to(
            jnp.asarray(pad_col, laym.dtype),
            (laym.shape[0], pad_l))], axis=1)
    return cfg, laym.astype(cfg.dtype), n_u, l_tot, bu, bl, pad_l


def _segment_onehot(segments, l_pad: int) -> np.ndarray:
    """Static one-hot [l_pad, n_net] segment matrix: rows past the last
    segment's stop stay all-zero, so layer padding is annihilated by the
    in-kernel reduction regardless of its term values."""
    seg = np.zeros((l_pad, len(segments)))
    for j, (a, b) in enumerate(segments):
        seg[a:b, j] = 1.0
    return seg


def count_term_sums(cfg_u, lay, segments, *, block_u: int = 128,
                    block_l: int = 128, interpret: bool | None = None):
    """Fused mapping → 14 count terms → per-network segment reduction.

    cfg_u: dict of [n_u, 1] arrays keyed by ``_COUNT_COLUMNS``;
    lay: dict of [1, L] arrays keyed like ``rs_mapping.layer_struct``;
    segments: static ((start, stop), ...).  Returns a 14-tuple of
    [n_u, n_net] float64 arrays, drop-in for ``_term_sums_body``'s output
    (config-independent terms arrive broadcast along the unique axis).

    ``interpret=True`` (the default on every platform) runs the Pallas
    interpreter, still XLA-jitted end to end.  A native lowering is NOT
    enabled by default: the tile program is float64 (access counts exceed
    float32's exact-integer range) with an n_net-wide last dimension,
    both of which violate TPU/Mosaic tiling constraints as written —
    opting in via ``interpret=False`` is for hosts where a lowering has
    been validated.
    """
    if interpret is None:
        interpret = default_interpret()
    cfg, laym, n_u, l_tot, bu, bl, pad_l = _pad_operands(
        cfg_u, lay, block_u, block_l)
    seg = jnp.asarray(_segment_onehot(segments, l_tot + pad_l), cfg.dtype)

    out = count_terms_kernel(cfg, laym, seg,
                             block_u=bu, block_l=bl, interpret=interpret)
    out = out[:, :n_u, :]
    return tuple(out[i] for i in range(N_TERMS))


def count_term_layers(cfg_u, lay, *, block_u: int = 128,
                      block_l: int = 128, interpret: bool | None = None):
    """Fused mapping → 14 PER-LAYER count terms (no segment reduction).

    Same operands as :func:`count_term_sums` minus ``segments``; returns
    a 14-tuple of [n_u, L] float64 arrays, drop-in for
    ``energymodel._term_layers_body``'s output (config-independent terms
    arrive per-row, which the consumer treats as already gathered).  The
    engine routes here when ``backend="pallas"`` in per-layer mode — both
    the dense ``per_layer=True`` path and the streamed per-layer
    reduction (``stream_layer_topk``), which calls once per fixed-shape
    chunk."""
    if interpret is None:
        interpret = default_interpret()
    cfg, laym, n_u, l_tot, bu, bl, _ = _pad_operands(
        cfg_u, lay, block_u, block_l)
    out = count_layers_kernel(cfg, laym, block_u=bu, block_l=bl,
                              interpret=interpret)
    out = out[:, :n_u, :l_tot]
    return tuple(out[i] for i in range(N_TERMS))
