"""Fused Pallas count-terms kernel: one pass over the [unique × layers] tile.

The DSE engine's heavy stage (``energymodel._term_sums_body``) evaluates the
14 per-layer access-count terms the energy/latency model is linear in, then
collapses the layer axis with per-network segment sums.  The stock jax path
materialises each [n_unique, n_layers] term before its ``sum`` chain — 14
full tiles in flight.  This kernel fuses both steps: a grid over
(unique-row blocks × layer blocks) loads one [block_u, block_l] tile's
inputs into VMEM, computes the RS mapping + all 14 terms in registers, and
folds the segment reduction into the same pass as a matmul against a
one-hot [block_l, n_net] segment matrix, accumulating the
``[14, n_unique, n_networks]`` partial-sum stack directly — no per-term
[unique, layers] intermediate ever reaches HBM.

The arithmetic is exactly ``energymodel._count_terms`` (the kernel calls
it with ``xp=jnp``), so parity with the jax/numpy engines is machine-eps;
only the reduction order differs (one-hot dot vs slice sums), both f64.

The mapping is recomputed per count-unique row instead of being gathered
from the mapping-unique rows (the two-level dedup of the jax path): a
cross-block gather is awkward inside a Pallas grid, and the mapping is
cheap elementwise integer math — recomputing it keeps the kernel a pure
tile program.

The one-hot segment matmul is OPTIONAL: the per-layer variant
(:func:`count_layers_kernel`, backing the engine's ``per_layer=True``
path) runs the same tile program without the reduction, each grid step
writing its ``[N_TERMS, block_u, block_l]`` partials straight into its
own slot of the ``[N_TERMS, n_u, L]`` output — still no per-term
intermediates beyond the one live tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import energymodel
from repro.core.energymodel import _COUNT_COLUMNS as CFG_COLUMNS

#: Row order of the stacked layer-struct operand (matches
#: ``rs_mapping.layer_struct`` keys).
LAYER_FIELDS = ("c_ch", "m", "ky", "kx", "stride", "ix", "iy", "oy", "ox",
                "macs", "weight_words", "ifmap_words", "ofmap_words",
                "is_acc", "is_dw", "is_pool")

#: Number of count terms (see ``energymodel._count_terms``).
N_TERMS = 14


def _count_terms_kernel(cfg_ref, lay_ref, seg_ref, o_ref):
    """One (unique-block, layer-block) grid step.

    cfg_ref: [len(CFG_COLUMNS), block_u]   count-unique config columns
    lay_ref: [len(LAYER_FIELDS), block_l]  layer-struct columns
    seg_ref: [block_l, n_net]              one-hot segment matrix slice
    o_ref:   [N_TERMS, block_u, n_net]     accumulated partial sums
    """
    cfg = {k: cfg_ref[i, :][:, None] for i, k in enumerate(CFG_COLUMNS)}
    lay = {k: lay_ref[i, :][None, :] for i, k in enumerate(LAYER_FIELDS)}

    terms = energymodel._count_terms(jnp, cfg, lay)
    seg = seg_ref[...]
    block_u = cfg[CFG_COLUMNS[0]].shape[0]
    block_l = seg.shape[0]
    part = jnp.stack([
        jnp.dot(jnp.broadcast_to(t, (block_u, block_l)), seg)
        for t in terms])                       # [N_TERMS, block_u, n_net]

    l_step = pl.program_id(1)

    @pl.when(l_step == 0)
    def _init():
        o_ref[...] = part

    @pl.when(l_step != 0)
    def _acc():
        o_ref[...] += part


def count_terms_kernel(cfg: jax.Array, lay: jax.Array, seg: jax.Array, *,
                       block_u: int = 128, block_l: int = 128,
                       interpret: bool = True) -> jax.Array:
    """cfg: [n_cfg_cols, n_u]; lay: [n_lay_cols, L]; seg: [L, n_net].

    ``n_u`` must be a multiple of ``block_u`` and ``L`` of ``block_l``
    (the ops wrapper pads).  Returns [N_TERMS, n_u, n_net] float64 partial
    sums; the layer grid axis is innermost so each output block is
    accumulated in place before the grid moves to the next row block.
    """
    n_cols, n_u = cfg.shape
    n_lay, l_tot = lay.shape
    n_net = seg.shape[1]
    assert n_u % block_u == 0, (n_u, block_u)
    assert l_tot % block_l == 0, (l_tot, block_l)
    return pl.pallas_call(
        _count_terms_kernel,
        grid=(n_u // block_u, l_tot // block_l),
        in_specs=[
            pl.BlockSpec((n_cols, block_u), lambda i, l: (0, i)),
            pl.BlockSpec((n_lay, block_l), lambda i, l: (0, l)),
            pl.BlockSpec((block_l, n_net), lambda i, l: (l, 0)),
        ],
        out_specs=pl.BlockSpec((N_TERMS, block_u, n_net),
                               lambda i, l: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((N_TERMS, n_u, n_net), cfg.dtype),
        interpret=interpret,
    )(cfg, lay, seg)


def _count_layers_kernel(cfg_ref, lay_ref, o_ref):
    """Per-layer grid step: identical term math, NO segment reduction.

    cfg_ref: [len(CFG_COLUMNS), block_u]   count-unique config columns
    lay_ref: [len(LAYER_FIELDS), block_l]  layer-struct columns
    o_ref:   [N_TERMS, block_u, block_l]   this step's per-layer partials
    """
    cfg = {k: cfg_ref[i, :][:, None] for i, k in enumerate(CFG_COLUMNS)}
    lay = {k: lay_ref[i, :][None, :] for i, k in enumerate(LAYER_FIELDS)}

    terms = energymodel._count_terms(jnp, cfg, lay)
    block_u = cfg[CFG_COLUMNS[0]].shape[0]
    block_l = lay[LAYER_FIELDS[0]].shape[1]
    o_ref[...] = jnp.stack([
        jnp.broadcast_to(t, (block_u, block_l)) for t in terms])


def count_layers_kernel(cfg: jax.Array, lay: jax.Array, *,
                        block_u: int = 128, block_l: int = 128,
                        interpret: bool = True) -> jax.Array:
    """cfg: [n_cfg_cols, n_u]; lay: [n_lay_cols, L] → [N_TERMS, n_u, L].

    The per-layer twin of :func:`count_terms_kernel`: the one-hot segment
    operand and the in-place accumulation disappear — every
    (row-block, layer-block) step owns a disjoint output block, so the
    grid order is free.  Pad layers (``_PAD_LAYER_ROW``) produce exactly
    zero in every term, so layer padding needs no masking here either.
    """
    n_cols, n_u = cfg.shape
    n_lay, l_tot = lay.shape
    assert n_u % block_u == 0, (n_u, block_u)
    assert l_tot % block_l == 0, (l_tot, block_l)
    return pl.pallas_call(
        _count_layers_kernel,
        grid=(n_u // block_u, l_tot // block_l),
        in_specs=[
            pl.BlockSpec((n_cols, block_u), lambda i, l: (0, i)),
            pl.BlockSpec((n_lay, block_l), lambda i, l: (0, l)),
        ],
        out_specs=pl.BlockSpec((N_TERMS, block_u, block_l),
                               lambda i, l: (0, i, l)),
        out_shape=jax.ShapeDtypeStruct((N_TERMS, n_u, l_tot), cfg.dtype),
        interpret=interpret,
    )(cfg, lay)
