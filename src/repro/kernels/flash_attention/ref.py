"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q: [B, H, T, D]; k, v: [B, H, S, D] → [B, H, T, D] (fp32 math)."""
    b, h, t, d = q.shape
    s = k.shape[2]
    scores = jnp.einsum("bhtd,bhsd->bhts",
                        q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    qpos = jnp.arange(t)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
