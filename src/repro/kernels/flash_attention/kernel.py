"""Flash attention as a Pallas TPU kernel.

Grid: (batch·kv_heads·q_groups, num_q_blocks, num_kv_blocks) — the kv block
index is innermost, so on TPU's sequential grid the VMEM scratch
(m, l, acc) persists across the kv sweep of one q block (the standard TPU
flash pattern).  Blocks are MXU-aligned (block_q × head_dim, block_kv ×
head_dim; head_dim is zero-padded to 128 by the wrapper when needed).

Causal masking is done with block-index arithmetic; kv blocks entirely
above the diagonal are skipped with ``pl.when`` (no MXU work issued — this
is the FLOP saving the XLA fallback in ``models.layers`` reproduces with
its triangular q-block schedule).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *,
                  block_q: int, block_kv: int, causal: bool, window: int,
                  sm_scale: float, num_kv_blocks: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_kv

    # is any (q, k) pair in this block pair visible?
    diag_ok = (not causal) or (k_start <= q_start + block_q - 1)
    win_ok = (window == 0) or (k_start + block_kv > q_start - window + 1)

    run = jnp.logical_and(jnp.asarray(diag_ok), jnp.asarray(win_ok))

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale       # [bq, d]
        k = k_ref[0].astype(jnp.float32)                  # [bkv, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # [bq, bkv]

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len                        # padded keys never score
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_kv: int = DEFAULT_BLOCK_KV,
                           kv_len: int = 0,
                           interpret: bool = True) -> jax.Array:
    """q: [BH, T, D]; k, v: [BH, S, D] → [BH, T, D].

    BH is the flattened batch·heads dim (the wrapper handles GQA layout).
    T % block_q == 0 and S % block_kv == 0 are required (wrapper pads);
    ``kv_len`` masks the padded key tail (defaults to S).
    """
    bh, t, d = q.shape
    s = k.shape[1]
    assert t % block_q == 0 and s % block_kv == 0, (t, s)
    nq, nkv = t // block_q, s // block_kv
    sm_scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_kv=block_kv, causal=causal,
        window=window, sm_scale=sm_scale, num_kv_blocks=nkv,
        kv_len=kv_len or s)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max m
            pltpu.VMEM((block_q,), jnp.float32),       # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),     # fp32 accumulator
        ],
        interpret=interpret,
    )(q, k, v)
