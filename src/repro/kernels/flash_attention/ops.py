"""Jitted wrapper: GQA layout handling + padding around the flash kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: [B, T, H, D]; k, v: [B, S, KV, D] (GQA) → [B, T, H, D].

    Repeats are handled by flattening (B, KV, G) into the kernel's BH dim;
    T/S are zero-padded to block multiples (masked out by causal/window
    logic plus the final unpad slice).
    """
    b, t, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh

    tp = (-t) % block_q
    sp = (-s) % block_kv
    if tp:
        q = jnp.pad(q, ((0, 0), (0, tp), (0, 0), (0, 0)))
    if sp:
        k = jnp.pad(k, ((0, 0), (0, sp), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp), (0, 0), (0, 0)))
    tt, ss = t + tp, s + sp

    # [B, T, KV, G, D] -> [B·KV·G, T, D]
    qf = q.reshape(b, tt, kvh, g, d).transpose(0, 2, 3, 1, 4)
    qf = qf.reshape(b * kvh * g, tt, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1)    # [B, KV·G, S, D]
    kf = kf.reshape(b * kvh * g, ss, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1)
    vf = vf.reshape(b * kvh * g, ss, d)

    o = flash_attention_kernel(qf, kf, vf, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               kv_len=s, interpret=interpret)
    o = o.reshape(b, kvh, g, tt, d).transpose(0, 3, 1, 2, 4)
    o = o.reshape(b, tt, h, d)
    return o[:, :t]
