"""Mamba-2 SSD chunked-scan Pallas kernel.

Grid: (batch·heads, num_chunks) with the chunk index innermost — the
[P, N] state scratch persists across the sequential chunk sweep (same
VMEM-carry pattern as the flash kernel).  Each step computes, for one
(batch, head) and one Q-length chunk:

    intra-chunk:  Y_diag = (C Bᵀ ⊙ L_decay) · (dt·X)        (MXU matmuls)
    chunk state:  S_c    = Σ_q decay_out_q · dt_q B_q x_qᵀ
    inter-chunk:  Y_off  = decay_in · C · S_prev
    carry:        S      = exp(ΣdA) · S_prev + S_c

Tiles are [Q, P] / [Q, N] with Q, P, N multiples of the MXU dim (the
assigned mamba2 config: Q=256, P=64, N=128)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)        # [Q]
    a = a_ref[0, 0]                           # scalar A_h (negative)
    b = b_ref[0].astype(jnp.float32)          # [Q, N]
    c = c_ref[0].astype(jnp.float32)          # [Q, N]

    da = dt * a                               # [Q]
    cum = jnp.cumsum(da)                      # [Q]
    total = cum[-1]

    xdt = x * dt[:, None]                     # [Q, P]

    # intra-chunk: L[q, k] = exp(cum_q - cum_k) for k <= q
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(ki <= qi, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    y_diag = jax.lax.dot_general(cb * lmat, xdt,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # inter-chunk: read previous state
    s_prev = s_ref[...]                       # [P, N]
    decay_in = jnp.exp(cum)                   # [Q]
    y_off = jax.lax.dot_general(c, s_prev,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [Q, P]
    y_off = y_off * decay_in[:, None]

    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    # chunk state + carry update
    decay_out = jnp.exp(total - cum)          # [Q]
    s_c = jax.lax.dot_general(xdt * decay_out[:, None], b,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [P, N]
    s_ref[...] = s_prev * jnp.exp(total) + s_c


def ssd_scan_kernel(x: jax.Array, dt: jax.Array, a: jax.Array,
                    b: jax.Array, c: jax.Array, *, chunk: int = 256,
                    interpret: bool = True) -> jax.Array:
    """x: [BH, T, P]; dt: [BH, T]; a: [BH]; b, c: [BH, T, N] → [BH, T, P].

    BH = flattened batch·heads (groups pre-broadcast by the wrapper);
    T % chunk == 0 (wrapper pads)."""
    bh, t, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk), lambda i, ci: (i, ci)),
            pl.BlockSpec((1, 1), lambda i, ci: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, p), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((p, n), jnp.float32),       # carried SSM state
        ],
        interpret=interpret,
    )(x, dt, a.reshape(bh, 1), b, c)
