"""Jitted wrapper around the SSD scan kernel: head flattening + padding."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 256,
             interpret: bool = True) -> jax.Array:
    """x: [B, T, H, P]; dt: [B, T, H]; a: [H]; b, c: [B, T, G, N] → y like x.

    Groups are broadcast to heads; (B, H) flatten into the kernel grid dim.
    Padded timesteps carry dt=0 ⇒ exp(0)=1, zero update (exact)."""
    bsz, t, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g

    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tt = t + pad

    xf = x.transpose(0, 2, 1, 3).reshape(bsz * h, tt, p)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * h, tt)
    bb = jnp.repeat(b.transpose(0, 2, 1, 3), rep, axis=1)
    bb = bb.reshape(bsz * h, tt, n)
    cc = jnp.repeat(c.transpose(0, 2, 1, 3), rep, axis=1)
    cc = cc.reshape(bsz * h, tt, n)
    af = jnp.broadcast_to(a[None, :], (bsz, h)).reshape(bsz * h)

    y = ssd_scan_kernel(xf, dtf, af, bb, cc, chunk=min(chunk, tt),
                        interpret=interpret)
    y = y.reshape(bsz, h, tt, p).transpose(0, 2, 1, 3)
    return y[:, :t]
