"""Pure-jnp oracle for the Mamba-2 SSD scan kernel: the exact sequential
recurrence h_t = exp(dA_t)·h_{t-1} + dt_t·B_t x_tᵀ ; y_t = C_t·h_t."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, b: jax.Array,
            c: jax.Array) -> jax.Array:
    """x: [B, T, H, P]; dt: [B, T, H]; A: [H]; b, c: [B, T, H, N]
    (groups pre-broadcast to heads) → y [B, T, H, P], fp32 math."""
    bs, t, h, p = x.shape
    n = b.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)

    def step(hstate, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt * A)[..., None, None]          # [B, H, 1, 1]
        upd = jnp.einsum("bhn,bh,bhp->bhpn", bt, dtt, xt)
        hstate = hstate * da + upd
        y = jnp.einsum("bhn,bhpn->bhp", ct, hstate)
        return hstate, y

    h0 = jnp.zeros((bs, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
         bf.transpose(1, 0, 2, 3), cf.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
