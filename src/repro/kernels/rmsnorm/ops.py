"""Jitted wrapper: flattening + padding around the RMSNorm kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import rmsnorm_kernel


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = True) -> jax.Array:
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    block = min(block_rows, n)
    pad = (-n) % block
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = rmsnorm_kernel(x2, scale, eps=eps, block_rows=block,
                         interpret=interpret)
    return out[:n].reshape(shape)
