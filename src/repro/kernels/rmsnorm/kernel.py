"""Fused RMSNorm Pallas kernel: single pass over VMEM row blocks.

Grid over row blocks; each step loads a [block_rows, d] tile, reduces the
mean-square in fp32 on the VPU, scales, and writes back — one HBM read +
one write per element (the XLA path reads x twice: reduce then scale)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_kernel(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
                   block_rows: int = 256,
                   interpret: bool = True) -> jax.Array:
    """x: [N, D] (wrapper flattens leading dims); scale: [D]."""
    n, d = x.shape
    assert n % block_rows == 0, (n, block_rows)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, scale)
