from .ops import rmsnorm  # noqa: F401
