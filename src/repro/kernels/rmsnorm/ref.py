"""Pure-jnp oracle for the fused RMSNorm kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, scale: jax.Array,
                eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
