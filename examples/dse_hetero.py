"""The full §IV flow: DSE over 18 CNNs → heterogeneous chip → cross-core
penalties (Table 6) → Algorithm II distribution (Tables 7–8) — and the TPU
adaptation: the same search over sharding policies for the 10 assigned LM
architectures (fleet design).

    PYTHONPATH=src python examples/dse_hetero.py
"""

import collections

import numpy as np

from repro.configs import ARCHS
from repro.core import autoshard, dse, energymodel, hetero, partition
from repro.core import accelerator, topology


def main():
    # --- paper: 18 CNNs, 150-point space, 5% boundary, greedy cover ------
    # one batched, jit-cached call evaluates all networks × the whole grid
    sweeps = dse.sweep_networks(
        {n: topology.get_network(n) for n in topology.NETWORKS})
    # self-describing output: what the engine actually executed on
    print(f"engine backend: {energymodel.last_backend()} "
          f"({energymodel.host_device_count()} host device(s); "
          f"pallas available: {energymodel.pallas_available()})")
    chip = hetero.design_chip(sweeps, bound=0.05, max_cores=3)
    groups = collections.defaultdict(list)
    for net, i in chip.assignment.items():
        groups[i].append(net)
    print("=== heterogeneous chip (paper §IV.A) ===")
    for i in sorted(groups):
        print(f"core type {i} {chip.core_label(i)}: "
              f"{', '.join(sorted(groups[i]))}")
    sav = hetero.savings_summary(chip)
    es = [v["energy_saved"] for v in sav.values()]
    ed = [v["edp_saved"] for v in sav.values()]
    print(f"savings vs worst single core: energy up to {max(es):.0f}% "
          f"(mean {np.mean(es):.0f}%), EDP up to {max(ed):.0f}% "
          f"(mean {np.mean(ed):.0f}%)  [paper: up to 36% / 67%]")

    # --- same chip designed from the streaming engine (no full cubes) ----
    grid = accelerator.ConfigGrid.product()
    nets = {n: topology.get_network(n) for n in topology.NETWORKS}
    stream = dse.stream_grid(nets, grid, chunk_size=50, bound=0.05)
    schip = hetero.design_chip_streaming(stream, grid, nets, max_cores=3)
    shape = next(iter(sweeps.values())).edp.shape
    same = schip.core_cells(shape) == chip.core_types
    print(f"\nstreaming design_chip reproduces the cover: {same} "
          f"(boundary sets only, no [n_cfg, n_net] matrices)")

    # --- per-layer co-design: which chip AND which layer→core schedule ---
    # one per_layer=True engine call + one batched hetero-schedule solve
    # evaluates every candidate (type multiset × core counts) chip
    print("\n=== per-layer chip + schedule co-design (§IV.A × §IV.B) ===")
    cd = hetero.co_design(grid, nets, m_cores=4, max_types=3, pool_size=6)
    print(f"co-designed chip ({cd.n_chips} candidates searched): "
          f"{cd.summary(grid)}")
    print(f"mean normalized EDP {cd.score:.3f} vs best homogeneous "
          f"{cd.homogeneous_score:.3f} "
          f"({(1 - cd.score / cd.homogeneous_score) * 100:.1f}% better)")
    for net in ("ResNet50", "MobileNetV2", "VGG16"):
        s = cd.schedules[net]
        moves = sum(1 for a, b in zip(s.layer_core, s.layer_core[1:])
                    if a != b)
        print(f"  {net}: {s.n_layers} layers over {s.n_cores} cores "
              f"({len(set(s.layer_type))} type(s)), pipeline speedup "
              f"{s.speedup:.2f}x, {moves} core hand-offs")

    # --- latency-bound Pareto sweep: one compiled call, ALL deadlines ----
    # the streamed problem set (boundary sets from one chunked pass, no
    # dense [n_cfg, n_net] matrices) feeds the same batched solve, then
    # every chip is scored against the whole deadline grid at once
    print("\n=== latency-bound Pareto co-design (streamed pool) ===")
    probs = hetero.codesign_problems_streaming(grid, nets, m_cores=4,
                                               max_types=3, pool_size=6,
                                               chunk_size=50)
    pc = hetero.pareto_codesign(probs, n_deadlines=8)
    print(f"{pc.n_chips} chips x {len(nets)} networks x "
          f"{pc.deadlines.size} deadlines (x min single-core latency):")
    for di, (d, c) in enumerate(zip(pc.deadlines, pc.best_chip)):
        if c < 0:
            print(f"  deadline {d:.2f}: no chip feasible")
        else:
            print(f"  deadline {d:.2f}: chip {int(c)} "
                  f"({pc.chip_summary(int(c), grid)}), "
                  f"mean norm energy {pc.scores[int(c), di]:.3f}")
    net = "ResNet50"
    print(f"Pareto frontier for {net} (latency ns, energy pJ):")
    for c, lat, en in pc.frontier(net)[:5]:
        print(f"  chip {c}: latency {lat:.3e}, energy {en:.3e}")

    # --- energy-aware deadline slack: spend latency headroom on energy ---
    # the same problem set re-scored with slack=True: layers migrate to
    # lower-energy core types while the pipeline stays under each
    # deadline (bit-exact vs partition.slack_schedule_oracle)
    print("\n=== energy-aware deadline-slack scheduling ===")
    ps = hetero.pareto_codesign(probs, n_deadlines=8, slack=True)
    moved = int(ps.slack_moves.sum())
    saved = 100.0 * (1.0 - np.nanmean(
        np.where(np.isfinite(ps.slack_energy)
                 & np.isfinite(ps.energy)[:, :, None],
                 ps.slack_energy / ps.energy[:, :, None], np.nan)))
    print(f"{moved} layer moves across "
          f"{ps.n_chips} chips x {len(nets)} networks x "
          f"{ps.deadlines.size} deadlines; mean energy saved {saved:.2f}% "
          f"(never worse than the latency-argmin schedule)")
    for di in (0, ps.deadlines.size - 1):
        c = int(ps.best_chip_slack[di])
        tag = (f"chip {c}, mean norm energy {ps.slack_scores[c, di]:.3f}"
               if c >= 0 else "no chip feasible")
        print(f"  deadline {ps.deadlines[di]:.2f}x: {tag}")

    # --- Algorithm II on each group's core type ---------------------------
    # one batch_partition call solves every (network, k) split at once
    print("\n=== model parallelism on homogeneous cores (§IV.B) ===")
    show = ("ResNet50", "GoogleNet", "VGG16")
    lats = []
    for net in show:
        cell = chip.core_types[chip.assignment[net]]
        a, p, i = cell
        sw = sweeps[net]
        cfg = accelerator.AcceleratorConfig(
            array_rows=sw.arrays[a][0], array_cols=sw.arrays[a][1],
            gb_psum_kb=sw.psum_kb[p], gb_ifmap_kb=sw.ifmap_kb[i])
        rep = energymodel.simulate_network(cfg, topology.get_network(net))
        lats.append(rep.layer_latencies)
    batch = partition.batch_partition(lats, (3, 4))
    for j, net in enumerate(show):
        for k in (3, 4):
            print(f"  {net} on {k} cores: speedup {batch[j][k].speedup:.2f}x")

    # --- TPU adaptation: fleet design over sharding policies ---------------
    print("\n=== TPU fleet design (Table-5 analogue over shardings) ===")
    fleet = autoshard.design_fleet(dict(ARCHS), n_chips=256, seq_len=4096,
                                   global_batch=256, max_policies=3)
    for pol in fleet["policies"]:
        archs = [a for a, p in fleet["assignment"].items() if p == pol]
        print(f"policy {pol}: {', '.join(sorted(archs))}")


if __name__ == "__main__":
    main()
