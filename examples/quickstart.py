"""Quickstart: the paper's pipeline in 40 lines.

1. Simulate a CNN on an array-based accelerator (the Tool, §II).
2. Sweep the design space and find the near-optimal configs (§III/Table 5).
3. Distribute the layers over homogeneous cores with Algorithm II (§IV.B).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import accelerator, dse, energymodel, partition, topology

# --- 1. simulate ResNet50 on a [16,16] core ------------------------------
cfg = accelerator.AcceleratorConfig(array_rows=16, array_cols=16,
                                    gb_psum_kb=54, gb_ifmap_kb=54)
layers = topology.get_network("ResNet50")
report = energymodel.simulate_network(cfg, layers, "ResNet50")
print(f"ResNet50 on {cfg.label()}:")
print(f"  energy  {report.energy:.3e} pJ")
print(f"  latency {report.latency:.3e} ns")
print(f"  EDP     {report.edp:.3e}")

# --- 2. design-space exploration ------------------------------------------
sweep = dse.sweep_network(layers, "ResNet50")
best = sweep.argmin_cell("edp")
print(f"\n150-point DSE minimum (EDP): {sweep.cell_label(best)}")
boundary = dse.boundary_configs(sweep, bound=0.05)
print(f"configs within 5% of optimum: {len(boundary)}")
mean, mx = dse.edp_spread(sweep)
print(f"EDP spread over the space: mean +{mean:.0f}%, max +{mx:.0f}% "
      "(Table 4)")

# --- 3. Algorithm II: distribute layers over 3 homogeneous cores -----------
p = partition.partition_network(report, 3)
print(f"\nAlgorithm II over 3 cores: speedup {p.speedup:.2f}x "
      f"(ideal 3.0x)")
print("  (l_initial, n_C):", p.table_row())
