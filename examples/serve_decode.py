"""Batched serving with continuous batching: 6 requests through 3 slots.

    PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model_zoo as Z
from repro.serving import ServeEngine
from repro.serving.engine import Request


def main():
    cfg = get_config("qwen2-0.5b").smoke()
    params = Z.init(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params, batch_slots=3, max_seq=96)

    rng = np.random.default_rng(0)
    for rid in range(6):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(3, 9))
        engine.submit(Request(rid=rid, prompt=prompt.astype(np.int32),
                              max_new_tokens=8))

    t0 = time.time()
    done = engine.run_until_drained()
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on CPU, {engine.slots} slots)")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req {r.rid}: prompt {list(r.prompt)} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
