"""B&B-staged pipeline parallelism (Algorithm II on a TPU mesh).

Plans stages for a transformer from TPU-cost-model layer latencies, then
runs the GPipe schedule on 4 emulated devices and checks it against the
sequential execution.  Must be the first jax user in the process (forces 4
host devices).

    PYTHONPATH=src python examples/pipeline_partition.py
"""

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")

import jax                                             # noqa: E402
import jax.numpy as jnp                                # noqa: E402
import numpy as np                                     # noqa: E402

from repro.configs import get_config                   # noqa: E402
from repro.core.tpu_costmodel import (ShardingPolicy,  # noqa: E402
                                      layer_costs)
from repro.parallel import pipeline as PP              # noqa: E402


def main():
    # --- stage planning from the cost model (the paper's Alg. II role) ----
    cfg = get_config("recurrentgemma-9b")
    costs = layer_costs(cfg, ShardingPolicy("p", dp=64, tp=4),
                        seq_len=4096, global_batch=256)
    lat = [c.time_s for c in costs]
    plan = PP.plan_stages(lat, 4)
    print(f"{cfg.name}: {len(lat)} layers -> 4 stages "
          f"{plan.stage_sizes}, speedup {plan.partition.speedup:.2f}x, "
          f"bubble {plan.bubble(8):.1%} at 8 microbatches")

    # --- run the GPipe schedule on a toy stack, verify vs sequential ------
    L, D, M, BM, T = 8, 32, 8, 2, 16
    ks = jax.random.split(jax.random.key(0), 3)
    stacked = {"w": jax.random.normal(ks[0], (L, D, D)) * 0.3,
               "b": jax.random.normal(ks[1], (L, D)) * 0.1}
    x = jax.random.normal(ks[2], (M, BM, T, D))

    def layer_fn(lp, h):
        return jnp.tanh(h @ lp["w"] + lp["b"])

    def seq(xi):
        h = xi
        for l in range(L):
            h = layer_fn({k: v[l] for k, v in stacked.items()}, h)
        return h

    ref = jax.vmap(seq)(x)
    mesh = jax.make_mesh((4,), ("stage",))
    plan = PP.plan_stages([1.0] * L, 4)
    staged, mask = PP.stage_params(stacked, plan)
    out = PP.pipeline_forward(staged, mask, x, mesh=mesh,
                              stage_axis="stage", layer_fn=layer_fn)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"pipeline vs sequential max err: {err:.2e} "
          f"({'OK' if err < 1e-4 else 'MISMATCH'})")
    print(f"bubble fraction at M={M}: {plan.bubble(M):.1%}")


if __name__ == "__main__":
    main()
