"""End-to-end LM training driver.

Default: a ~10M-parameter dense LM for 300 steps on CPU (minutes).  The
``--recipe 100m`` flag selects the ~100M-parameter recipe the driver runs on
real hardware (same code path; the dry-run proves the production-mesh
sharding compiles).  Checkpoints + fault-tolerant supervisor included — try
``--inject-failure-at 120`` to watch a mid-run failure replay exactly.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--recipe", choices=["10m", "100m"], default="10m")
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    args = ap.parse_args()

    if args.recipe == "100m":
        seq, gb = 512, 16
        argv = ["--arch", "qwen2-0.5b",          # 0.5B at full size; the
                "--steps", str(args.steps),       # driver shards it on the
                "--seq-len", str(seq),            # production mesh
                "--global-batch", str(gb)]
    else:
        argv = ["--arch", "stablelm-1.6b", "--smoke",
                "--steps", str(args.steps), "--seq-len", "128",
                "--global-batch", "8", "--lr", "3e-3"]
    if args.inject_failure_at >= 0:
        argv += ["--inject-failure-at", str(args.inject_failure_at)]

    losses = T.main(argv)
    print(f"\nfinal loss {losses[-1]:.4f} (from {losses[0]:.4f}) — "
          f"{'LEARNING' if losses[-1] < 0.8 * losses[0] else 'check setup'}")


if __name__ == "__main__":
    main()
