"""Algorithm II (branch-and-bound layer distribution): property tests."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import accelerator, energymodel, partition, topology

from oracles import dp_partition_loop

lat_lists = st.lists(st.floats(0.01, 100.0), min_size=2, max_size=14)
cores = st.integers(2, 5)


@given(lat_lists, cores)
@settings(max_examples=200, deadline=None)
def test_dp_matches_bruteforce(lat, k):
    k = min(k, len(lat))
    dp = partition.dp_partition(lat, k)
    bf = partition.brute_force_partition(lat, k)
    assert dp.pipeline_latency == pytest.approx(bf.pipeline_latency)


@given(lat_lists, cores)
@settings(max_examples=200, deadline=None)
def test_bb_is_valid_and_near_optimal(lat, k):
    k = min(k, len(lat))
    bb = partition.bb_partition(lat, k)
    dp = partition.dp_partition(lat, k)
    # valid contiguous partition
    assert bb.boundaries[0] == 0
    assert list(bb.boundaries) == sorted(set(bb.boundaries))
    assert len(bb.loads) <= k
    assert sum(bb.loads) == pytest.approx(sum(lat))
    # never better than optimal; near-optimal in the paper's sense
    assert bb.pipeline_latency >= dp.pipeline_latency - 1e-9
    assert bb.pipeline_latency <= dp.pipeline_latency * 1.5 + 1e-9


@given(lat_lists, cores)
@settings(max_examples=100, deadline=None)
def test_speedup_bounds(lat, k):
    k = min(k, len(lat))
    p = partition.bb_partition(lat, k)
    assert 1.0 - 1e-9 <= p.speedup <= k + 1e-9


@given(lat_lists)
@settings(max_examples=50, deadline=None)
def test_single_core_identity(lat):
    p = partition.bb_partition(lat, 1)
    assert p.speedup == pytest.approx(1.0)
    assert p.pipeline_latency == pytest.approx(sum(lat))


def test_tables_7_8_scenario():
    """Tables 7–8: near-ideal speedups on the paper's two core configs."""
    cfg3 = accelerator.AcceleratorConfig(array_rows=32, array_cols=32,
                                         gb_psum_kb=54, gb_ifmap_kb=54)
    cfg4 = accelerator.AcceleratorConfig(array_rows=12, array_cols=14,
                                         gb_psum_kb=216, gb_ifmap_kb=54)
    for net, cfg, k, smin in [
            ("ResNet50", cfg3, 3, 2.5), ("DenseNet121", cfg3, 3, 2.5),
            ("GoogleNet", cfg4, 4, 3.0), ("MobileNetV2", cfg4, 4, 3.0)]:
        rep = energymodel.simulate_network(cfg, topology.get_network(net))
        p = partition.partition_network(rep, k)
        assert p.speedup >= smin, (net, p.speedup)
        rows = p.table_row()
        assert rows[0][0] == 1                      # 1-indexed first layer
        assert sum(r[1] for r in rows) == len(rep.layers)


def test_bb_equals_dp_on_benchmarks():
    cfg = accelerator.AcceleratorConfig()
    for net in ("VGG16", "ResNet50", "MobileNet"):
        rep = energymodel.simulate_network(cfg, topology.get_network(net))
        bb = partition.partition_network(rep, 4)
        dp = partition.partition_network(rep, 4, "dp")
        assert bb.pipeline_latency <= dp.pipeline_latency * 1.05


@given(st.lists(lat_lists, min_size=1, max_size=4), st.sets(cores,
                                                            min_size=1))
@settings(max_examples=150, deadline=None)
def test_batch_partition_matches_dp(lat_groups, ks):
    """The vectorized parametric search is EXACT: identical pipeline
    latencies to the dp oracle for every (network, k) pair at once.
    (numpy backend here — the jit backend is covered on the model zoo in
    test_stream_engine.py without per-example dispatch overhead.)"""
    ks = sorted(ks)
    res = partition.batch_partition(lat_groups, ks, use_jax=False)
    want = dp_partition_loop(lat_groups, ks)
    for i, lat in enumerate(lat_groups):
        for k in ks:
            assert res[i][k].pipeline_latency == \
                want[i, k].pipeline_latency
            assert res[i][k].boundaries[0] == 0
            assert sum(res[i][k].loads) == pytest.approx(sum(lat))
