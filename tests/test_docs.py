"""Docs tree health: every relative link under docs/ (and README.md)
resolves to a real file, and every dotted ``repro.*`` / ``benchmarks.*``
symbol or backticked repo path a doc references still exists — so the
prose can't silently rot as the code moves."""

import importlib
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = sorted((ROOT / "docs").glob("**/*.md")) + [ROOT / "README.md"]

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SYMBOL_RE = re.compile(r"`((?:repro|benchmarks)(?:\.\w+)+)(?:\(\))?`")
_PATH_RE = re.compile(r"`([\w][\w./-]*\.(?:py|md|json|yml|txt|ini))`")


def _doc_ids(files):
    return [str(p.relative_to(ROOT)) for p in files]


@pytest.fixture(scope="module", autouse=True)
def _docs_exist():
    assert (ROOT / "docs" / "architecture.md").is_file()
    assert (ROOT / "docs" / "bench_schema.md").is_file()


@pytest.mark.parametrize("doc", DOC_FILES, ids=_doc_ids(DOC_FILES))
def test_relative_links_resolve(doc):
    dead = []
    for m in _LINK_RE.finditer(doc.read_text()):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path = target.split("#", 1)[0]
        if not path:                       # pure in-page anchor
            continue
        if not (doc.parent / path).exists():
            dead.append(target)
    assert not dead, f"{doc.name}: dead relative links {dead}"


def _resolve(dotted: str) -> bool:
    """Import the longest module prefix, then getattr the rest."""
    parts = dotted.split(".")
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
        except ImportError:
            continue
        try:
            for attr in parts[i:]:
                obj = getattr(obj, attr)
            return True
        except AttributeError:
            return False
    return False


@pytest.mark.parametrize("doc", DOC_FILES, ids=_doc_ids(DOC_FILES))
def test_referenced_symbols_exist(doc):
    missing = [s for s in sorted(set(_SYMBOL_RE.findall(doc.read_text())))
               if not _resolve(s)]
    assert not missing, (
        f"{doc.name} references symbols that no longer exist: {missing}")


@pytest.mark.parametrize("doc", DOC_FILES, ids=_doc_ids(DOC_FILES))
def test_referenced_paths_exist(doc):
    missing = []
    for p in sorted(set(_PATH_RE.findall(doc.read_text()))):
        if "*" in p or "<" in p:
            continue
        # CI-regenerated artifacts (BENCH_*.quick.json) are legitimately
        # absent in a fresh checkout — the docs may still describe them
        if p.endswith(".quick.json"):
            continue
        if not ((ROOT / p).exists() or (doc.parent / p).exists()):
            missing.append(p)
    assert not missing, (
        f"{doc.name} references repo paths that do not exist: {missing}")
