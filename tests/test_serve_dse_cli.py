"""`repro.launch.serve_dse` CLI: arg parsing, query-family routing, the
health snapshot output, and the --fault-event re-schedule path — all on a
tiny injected grid with a fake clock, so the launcher is testable without
wall-clock time or the full design space."""

import json

import pytest

from repro.core.accelerator import ConfigGrid
from repro.launch import serve_dse


@pytest.fixture(scope="module")
def tiny_grid():
    return ConfigGrid.product(arrays=((16, 16), (32, 32), (64, 64)),
                              gb_psum_kb=(13, 54, 216),
                              gb_ifmap_kb=(27, 108))


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, dt):
        self.t += dt


def _health_json(captured: str) -> dict:
    """The CLI prints human lines then one indented JSON blob — parse it."""
    return json.loads(captured[captured.index("{"):])


def test_serves_seeded_mix_and_prints_health(tiny_grid, capsys):
    clk = FakeClock()
    responses = serve_dse.main(
        ["--requests", "6", "--networks", "AlexNet", "MobileNet",
         "--chunk-size", "5"],
        clock=clk, sleep=clk.sleep, grid=tiny_grid)
    out = capsys.readouterr().out
    assert len(responses) == 6
    assert all(r.ok for r in responses)
    # the seeded mix routes through both query families
    kinds = {r.kind for r in responses}
    assert kinds <= {"best_config", "best_chip", "pareto"}
    assert len(kinds) >= 2
    assert "served 6 responses" in out
    h = _health_json(out)
    assert h["completed"] == 6 and h["errors"] == 0
    assert h["n_cfg"] == tiny_grid.n
    assert h["fault_events"] == 0


def test_fault_event_flag_reschedules(tiny_grid, capsys):
    clk = FakeClock()
    responses = serve_dse.main(
        ["--requests", "4", "--networks", "AlexNet", "MobileNet",
         "--chunk-size", "5", "--fault-event"],
        clock=clk, sleep=clk.sleep, grid=tiny_grid)
    out = capsys.readouterr().out
    resched = [r for r in responses if r.kind == "reschedule"]
    assert len(resched) == 1 and resched[0].ok
    assert "fault-event core_loss_t0" in out
    h = _health_json(out)
    assert h["fault_events"] == 1 and h["reschedules"] == 1
    assert h["errors"] == 0


def test_chaos_seed_still_answers_everything(tiny_grid, capsys):
    clk = FakeClock()
    responses = serve_dse.main(
        ["--requests", "3", "--networks", "AlexNet", "MobileNet",
         "--chunk-size", "5", "--chaos", "0", "--fault-event"],
        clock=clk, sleep=clk.sleep, grid=tiny_grid)
    out = capsys.readouterr().out
    assert all(r.ok for r in responses)
    h = _health_json(out)
    assert h["errors"] == 0
    assert h["fault_events"] == 1


def test_deadline_s_flag_threads_through(tiny_grid):
    clk = FakeClock()
    responses = serve_dse.main(
        ["--requests", "2", "--networks", "AlexNet", "MobileNet",
         "--chunk-size", "5", "--deadline-s", "1e9"],
        clock=clk, sleep=clk.sleep, grid=tiny_grid)
    assert all(r.ok and not r.deadline_missed for r in responses)


def test_unknown_network_errors(tiny_grid):
    with pytest.raises(KeyError):
        serve_dse.main(["--requests", "1", "--networks", "NoSuchNet"],
                       grid=tiny_grid)


def test_state_dir_replays_unanswered_requests(tiny_grid, tmp_path, capsys):
    """A killed earlier launch left an accepted-but-unanswered request in
    the journal; the next launch replays and answers it FIRST."""
    from repro.core import topology
    from repro.serving.dse_service import DSEService
    nets = {n: topology.get_network(n) for n in ("AlexNet", "MobileNet")}
    dead = DSEService(tiny_grid, nets, chunk_size=5,
                      state_dir=tmp_path)
    dead.submit("pareto", network="AlexNet", deadline=2.0)
    # no drain, no close: the process died here

    clk = FakeClock()
    responses = serve_dse.main(
        ["--requests", "2", "--networks", "AlexNet", "MobileNet",
         "--chunk-size", "5", "--state-dir", str(tmp_path)],
        clock=clk, sleep=clk.sleep, grid=tiny_grid)
    out = capsys.readouterr().out
    assert "replayed 1 unanswered requests" in out
    assert len(responses) == 3                       # 1 replayed + 2 new
    assert responses[0].kind == "pareto"             # replayed drains first
    assert all(r.ok for r in responses)
    h = _health_json(out)
    assert h["replayed"] == 1 and h["errors"] == 0


def test_install_graceful_drains_and_exits_zero(tiny_grid, tmp_path):
    """The handler closes admission, drains, closes the journal, and
    exits 0 — invoked directly, no real signal needed."""
    from repro.core import topology
    from repro.serving.dse_service import DSEService
    nets = {n: topology.get_network(n) for n in ("AlexNet", "MobileNet")}
    svc = DSEService(tiny_grid, nets, chunk_size=5, state_dir=tmp_path)
    svc.submit("best_config")
    svc.submit("best_chip", deadline=2.0)
    handler = serve_dse.install_graceful(svc, signals=())
    with pytest.raises(SystemExit) as ei:
        handler(None, None)
    assert ei.value.code == 0
    assert svc.health()["queue_depth"] == 0          # drained, not dropped
    assert len(svc.responses) == 2
    assert all(r.ok for r in svc.responses)
    assert svc._journal is None                      # journal closed
    assert not svc.submit("best_config").accepted    # admission stays shut
    # nothing left to replay: the drain answered everything it accepted
    s2 = DSEService(tiny_grid, nets, chunk_size=5, state_dir=tmp_path)
    assert s2.stats["replayed"] == 0
    s2.close()
