"""Crash-safe resumable streaming: bit-exact resume across kill points ×
chunk sizes × backends, input-hash rejection, and npz round-trips.

The contract under test: a stream killed after any chunk and resumed from
the exported :class:`repro.core.energymodel.StreamFoldState` produces
results BIT-identical to the uninterrupted run — the (value, flat index)
tie-break discipline makes the fold independent of where it was split."""

import numpy as np
import pytest

from repro.core import energymodel, hetero, topology
from repro.core.accelerator import ConfigGrid
from repro.ft.faults import FaultPlan, StreamKill, inject_chunk_faults

NETS = ("AlexNet", "MobileNet")
CHUNKS = (3, 5, 7)              # 18 points -> 6 / 4 / 3 chunks
BACKENDS = ("numpy", "jax")


@pytest.fixture(scope="module")
def networks():
    return {n: topology.get_network(n) for n in NETS}


@pytest.fixture(scope="module")
def grid():
    return ConfigGrid.product(arrays=((16, 16), (32, 32), (64, 64)),
                              gb_psum_kb=(13, 54, 216),
                              gb_ifmap_kb=(27, 108))


def _run(grid, networks, *, chunk, backend, **kw):
    return energymodel.stream_layer_topk(
        grid, networks, topk=4, bound=0.05, chunk_size=chunk,
        backend=backend, **kw)


def _assert_same(res, ref, networks):
    np.testing.assert_array_equal(res.topk_idx, ref.topk_idx)
    np.testing.assert_array_equal(res.topk_metric, ref.topk_metric)
    np.testing.assert_array_equal(res.layer_energy, ref.layer_energy)
    np.testing.assert_array_equal(res.layer_latency, ref.layer_latency)
    np.testing.assert_array_equal(res.min_energy, ref.min_energy)
    np.testing.assert_array_equal(res.min_latency, ref.min_latency)
    np.testing.assert_array_equal(res.min_metric, ref.min_metric)
    np.testing.assert_array_equal(res.argmin, ref.argmin)
    np.testing.assert_array_equal(res.layer_min_metric,
                                  ref.layer_min_metric)
    np.testing.assert_array_equal(res.layer_argmin, ref.layer_argmin)
    for nm in networks:
        np.testing.assert_array_equal(res.boundary_idx[nm],
                                      ref.boundary_idx[nm])
        np.testing.assert_array_equal(res.boundary_energy[nm],
                                      ref.boundary_energy[nm])
        np.testing.assert_array_equal(res.boundary_latency[nm],
                                      ref.boundary_latency[nm])


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("chunk", CHUNKS)
def test_resume_bit_exact_every_kill_point(grid, networks, backend, chunk):
    """Kill after EVERY chunk boundary; each resume must be bit-exact
    (covers >= 3 kill points x >= 3 chunk sizes x both backends)."""
    ref = _run(grid, networks, chunk=chunk, backend=backend)
    states = []
    _run(grid, networks, chunk=chunk, backend=backend,
         on_chunk=states.append)
    assert len(states) == -(-grid.n // chunk)
    assert states[-1].complete
    for fs in states:
        # resume through the serialized export, not the live object
        res = _run(grid, networks, chunk=chunk, backend=backend,
                   resume_from=fs.export_state())
        _assert_same(res, ref, networks)


@pytest.mark.parametrize("backend", BACKENDS)
def test_killed_stream_resumes_exactly(grid, networks, backend):
    """A FaultPlan mid-stream kill loses nothing: resume from the last
    on_chunk export reproduces the uninterrupted result bit-for-bit."""
    chunk = 5
    ref = _run(grid, networks, chunk=chunk, backend=backend)
    states = []
    plan = FaultPlan(kill_at=2)
    with inject_chunk_faults(plan):
        with pytest.raises(StreamKill):
            _run(grid, networks, chunk=chunk, backend=backend,
                 on_chunk=states.append)
    assert plan.fired == [(2, "kill")]
    assert len(states) == 2                 # chunks 0,1 folded before kill
    res = _run(grid, networks, chunk=chunk, backend=backend,
               resume_from=states[-1])
    _assert_same(res, ref, networks)


def test_resume_rejects_changed_inputs(grid, networks):
    states = []
    _run(grid, networks, chunk=5, backend="numpy", on_chunk=states.append)
    fs = states[0]
    with pytest.raises(energymodel.StreamStateError):
        _run(grid, networks, chunk=7, backend="numpy", resume_from=fs)
    with pytest.raises(energymodel.StreamStateError):
        _run(grid.take(np.arange(grid.n - 1)), networks, chunk=5,
             backend="numpy", resume_from=fs)
    with pytest.raises(energymodel.StreamStateError):
        energymodel.stream_layer_topk(
            grid, networks, topk=4, bound=0.10, chunk_size=5,
            backend="numpy", resume_from=fs)
    with pytest.raises(energymodel.StreamStateError):
        energymodel.stream_layer_topk(
            grid, networks, topk=4, bound=0.05, metric="energy",
            chunk_size=5, backend="numpy", resume_from=fs)
    # wrong stream kind
    with pytest.raises(energymodel.StreamStateError):
        energymodel.stream_networks(grid, networks, chunk_size=5,
                                    backend="numpy", resume_from=fs)


def test_export_npz_roundtrip(tmp_path, grid, networks):
    states = []
    ref = _run(grid, networks, chunk=5, backend="numpy",
               on_chunk=states.append)
    path = tmp_path / "fold.npz"
    states[1].save(path)
    assert path.exists() and not (tmp_path / "fold.npz.tmp").exists()
    fs = energymodel.StreamFoldState.load(path)
    assert fs.next_chunk == 2 and fs.input_hash == states[1].input_hash
    res = _run(grid, networks, chunk=5, backend="numpy", resume_from=fs)
    _assert_same(res, ref, networks)


def test_resume_from_complete_state(grid, networks):
    states = []
    ref = _run(grid, networks, chunk=5, backend="numpy",
               on_chunk=states.append)
    res = _run(grid, networks, chunk=5, backend="numpy",
               resume_from=states[-1])
    _assert_same(res, ref, networks)


@pytest.mark.parametrize("kill_at", (1, 2))
def test_stream_networks_resume(grid, networks, kill_at):
    ref = energymodel.stream_networks(grid, networks, chunk_size=5,
                                      backend="numpy")
    states = []
    with inject_chunk_faults(FaultPlan(kill_at=kill_at)):
        with pytest.raises(StreamKill):
            energymodel.stream_networks(grid, networks, chunk_size=5,
                                        backend="numpy",
                                        on_chunk=states.append)
    res = energymodel.stream_networks(grid, networks, chunk_size=5,
                                      backend="numpy",
                                      resume_from=states[-1])
    np.testing.assert_array_equal(res.topk_idx, ref.topk_idx)
    np.testing.assert_array_equal(res.topk_metric, ref.topk_metric)
    np.testing.assert_array_equal(res.argmin, ref.argmin)
    np.testing.assert_array_equal(res.min_metric, ref.min_metric)
    for nm in networks:
        np.testing.assert_array_equal(res.boundary_idx[nm],
                                      ref.boundary_idx[nm])


def test_resume_with_duplicated_grid_rows(grid, networks):
    """A grid with DUPLICATED rows (exact metric ties at every duplicate)
    still resumes bit-exactly from any chunk boundary: the (value, flat
    index) tie-break keeps the fold split-invariant even when the values
    alone cannot order the candidates."""
    idx = np.array([0, 1, 1, 2, 5, 5, 5, 9, 9, 3])
    dup = grid.take(idx)
    assert dup.n == idx.size
    ref = _run(dup, networks, chunk=3, backend="numpy")
    states = []
    _run(dup, networks, chunk=3, backend="numpy", on_chunk=states.append)
    for fs in states:
        res = _run(dup, networks, chunk=3, backend="numpy",
                   resume_from=fs.export_state())
        _assert_same(res, ref, networks)
    # duplicated winners: ties broke toward the LOWER flat index, so a
    # duplicate of the winner never displaces it
    for col in range(ref.topk_idx.shape[1]):
        ti = [i for i in ref.topk_idx[:, col] if i >= 0]
        assert len(set(ti)) == len(ti)          # no index repeats
        assert ti == sorted(ti, key=lambda i: (ref.topk_metric[
            list(ref.topk_idx[:, col]).index(i), col], i))


def test_empty_boundary_set_on_zero_row_grid(grid, networks):
    """bound=... against a zero-row grid: the stream completes with an
    EMPTY boundary set (not a crash), +inf minima and -1 top-k
    sentinels, and a complete resumable state."""
    empty = grid.take(np.array([], dtype=np.int64))
    assert empty.n == 0
    states = []
    res = _run(empty, networks, chunk=5, backend="numpy",
               on_chunk=states.append)
    for nm in networks:
        assert res.boundary_idx[nm].size == 0
        assert res.boundary_energy[nm].size == 0
        assert res.boundary_latency[nm].size == 0
    assert np.isinf(res.min_metric).all()
    assert (res.topk_idx == -1).all()
    # zero chunks -> zero on_chunk callbacks, but a fresh resume from
    # nothing still reproduces the same (empty) result
    assert states == []
    res2 = _run(empty, networks, chunk=5, backend="numpy")
    _assert_same(res2, res, networks)


def test_codesign_pool_survives_kill(grid, networks):
    """hetero.codesign_problems_streaming passthrough: a pool build killed
    mid-sweep and resumed yields the identical pool and problem set."""
    kw = dict(m_cores=3, max_types=2, pool_size=3, chunk_size=5,
              backend="numpy")
    ref = hetero.codesign_problems_streaming(grid, networks, **kw)
    states = []
    with inject_chunk_faults(FaultPlan(kill_at=2)):
        with pytest.raises(StreamKill):
            hetero.codesign_problems_streaming(
                grid, networks, on_chunk=states.append, **kw)
    res = hetero.codesign_problems_streaming(
        grid, networks, resume_from=states[-1], **kw)
    assert res.pool == ref.pool
    assert res.chips == ref.chips
    np.testing.assert_array_equal(res.lat_dense, ref.lat_dense)
    np.testing.assert_array_equal(res.e_layer, ref.e_layer)
    np.testing.assert_array_equal(res.min_energy, ref.min_energy)
