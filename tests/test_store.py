"""`repro.serving.store` durability: content-addressed entry round trips,
every corruption mode quarantining (truncated npz, flipped checksum byte,
wrong schema version, key collision, torn concurrent write) instead of
crashing or serving garbage, group invalidation, the fsync'd write-ahead
journal's exactly-once replay (torn final line dropped), and the
bit-identical LayerTopK <-> payload round trip."""

import json
import os

import numpy as np
import pytest

from repro.core import energymodel, topology
from repro.core.accelerator import ConfigGrid
from repro.serving import store as store_mod
from repro.serving.store import DurableStore, Journal

KEY = ("g0", "nets", "answer", "best_config", "edp")
KEY2 = ("g0", "nets", "stream", "edp")
OTHER_GROUP = ("g1", "nets", "answer", "best_config", "edp")


def _put_one(st, key=KEY):
    return st.put(key, arrays={"x": np.arange(6.0).reshape(2, 3)},
                  meta={"answer": [1, 2.5, "s"], "ok": True})


# -- entries ---------------------------------------------------------------


def test_round_trip_and_stats(tmp_path):
    st = DurableStore(tmp_path)
    _put_one(st)
    assert st.get(("g0", "missing")) is None          # miss
    arrays, meta = st.get(KEY)                        # hit
    np.testing.assert_array_equal(arrays["x"],
                                  np.arange(6.0).reshape(2, 3))
    assert meta == {"answer": [1, 2.5, "s"], "ok": True}
    h = st.health()
    assert h["puts"] == 1 and h["hits"] == 1 and h["misses"] == 1
    assert h["n_entries"] == 1 and h["n_quarantined_files"] == 0


def test_reopen_sees_entries(tmp_path):
    _put_one(DurableStore(tmp_path))
    st2 = DurableStore(tmp_path)                      # fresh handle
    arrays, _ = st2.get(KEY)
    np.testing.assert_array_equal(arrays["x"],
                                  np.arange(6.0).reshape(2, 3))


def _assert_quarantined(st, key, *, reason_contains=None):
    """The damaged entry must fall through to a miss, move aside with a
    .reason file, and never resurface on the next read."""
    assert st.get(key) is None
    assert st.stats["quarantined"] == 1
    assert st.health()["n_quarantined_files"] == 1
    assert not st._path(key).exists()                 # moved, not left
    reasons = list(st.quarantine.glob("*.reason"))
    assert len(reasons) == 1
    if reason_contains is not None:
        assert reason_contains in reasons[0].read_text()
    assert st.get(key) is None                        # clean miss now
    assert st.stats["quarantined"] == 1               # no double count


def test_truncated_npz_quarantines(tmp_path):
    st = DurableStore(tmp_path)
    path = _put_one(st)
    data = path.read_bytes()
    path.write_bytes(data[:len(data) // 2])           # crash mid-write
    _assert_quarantined(st, KEY)


def test_flipped_checksum_byte_quarantines(tmp_path):
    """Flip one array byte but keep the npz container valid: only the
    store's own checksum can catch this."""
    st = DurableStore(tmp_path)
    path = _put_one(st)
    with np.load(path, allow_pickle=False) as z:
        payload = {k: z[k] for k in z.files}
    x = np.array(payload["a_x"], copy=True)
    x.reshape(-1)[0] += 1.0                           # silent bit damage
    payload["a_x"] = x
    with open(path, "wb") as f:                       # rewrite, valid zip
        np.savez(f, **payload)
    _assert_quarantined(st, KEY, reason_contains="checksum")


def test_wrong_schema_version_quarantines(tmp_path):
    writer = DurableStore(tmp_path, schema=999)       # a future layout
    _put_one(writer)
    st = DurableStore(tmp_path)                       # current reader
    _assert_quarantined(st, KEY, reason_contains="schema")


def test_key_collision_quarantines(tmp_path):
    """An entry renamed onto another key's path (hash collision or
    tampering) fails the stored-key check."""
    st = DurableStore(tmp_path)
    path = _put_one(st)
    os.replace(path, st._path(KEY2))
    _assert_quarantined(st, KEY2, reason_contains="key mismatch")


def test_torn_concurrent_replace_quarantines(tmp_path):
    """A concurrent writer died between opening the temp file and the
    os.replace: the reader finds garbage bytes at the entry path."""
    st = DurableStore(tmp_path)
    path = st._path(KEY)
    path.write_bytes(b"PK\x03\x04 torn half-write, not a real zip")
    _assert_quarantined(st, KEY)


def test_overwrite_is_atomic_and_last_wins(tmp_path):
    st = DurableStore(tmp_path)
    st.put(KEY, meta={"v": 1})
    st.put(KEY, meta={"v": 2})
    _, meta = st.get(KEY)
    assert meta == {"v": 2}
    assert st.health()["n_entries"] == 1
    assert not list(st.entries.glob("*.tmp"))         # no temp droppings


def test_invalidate_group_spares_other_groups(tmp_path):
    st = DurableStore(tmp_path)
    _put_one(st, KEY)
    _put_one(st, KEY2)
    _put_one(st, OTHER_GROUP)
    assert st.invalidate_group("g0") == 2
    assert st.get(KEY) is None and st.get(KEY2) is None
    assert st.get(OTHER_GROUP) is not None            # untouched
    assert st.stats["invalidated"] == 2


# -- write-ahead journal ---------------------------------------------------


def test_journal_replay_exactly_once_with_torn_tail(tmp_path):
    p = tmp_path / "journal.jsonl"
    j = Journal(p)
    j.submit(0, dict(kind="best_config", metric="edp"))
    j.submit(1, dict(kind="pareto", metric="edp", network="AlexNet"))
    j.done(0)
    j.submit(2, dict(kind="best_chip", metric="edp"))
    j.close()
    with open(p, "a") as f:                           # crash mid-append
        f.write('{"op": "submit", "rid": 3, "kin')
    rr = Journal.replay(p)
    assert [r["rid"] for r in rr.pending] == [1, 2]   # admission order
    assert rr.pending[0]["network"] == "AlexNet"
    assert rr.next_rid == 3                           # rid 3 never acked
    assert rr.n_done == 1 and rr.n_torn == 1


def test_journal_reopen_extends_one_log(tmp_path):
    p = tmp_path / "journal.jsonl"
    j = Journal(p)
    j.submit(0, dict(kind="best_config", metric="edp"))
    j.close()
    j2 = Journal(p)                                   # restart appends
    j2.done(0)
    j2.submit(1, dict(kind="best_config", metric="edp"))
    j2.close()
    rr = Journal.replay(p)
    assert [r["rid"] for r in rr.pending] == [1]
    assert rr.n_done == 1 and rr.next_rid == 2


def test_journal_replay_missing_file_is_empty(tmp_path):
    rr = Journal.replay(tmp_path / "nope.jsonl")
    assert rr.pending == [] and rr.next_rid == 0
    assert rr.n_done == 0 and rr.n_torn == 0


def test_journal_unknown_op_counts_torn(tmp_path):
    p = tmp_path / "journal.jsonl"
    p.write_text(json.dumps({"op": "frobnicate", "rid": 0}) + "\n")
    assert Journal.replay(p).n_torn == 1


# -- stream payload round trip + checkpoints -------------------------------


@pytest.fixture(scope="module")
def stream():
    grid = ConfigGrid.product(arrays=((16, 16), (32, 32)),
                              gb_psum_kb=(13, 54),
                              gb_ifmap_kb=(27,))
    nets = {n: topology.get_network(n) for n in ("AlexNet", "MobileNet")}
    return energymodel.stream_layer_topk(grid, nets, topk=4, bound=0.05,
                                         chunk_size=3)


def test_stream_payload_round_trip_bit_identical(stream, tmp_path):
    st = DurableStore(tmp_path)
    st.put(KEY2, arrays=store_mod.stream_payload(stream)[0],
           meta=store_mod.stream_payload(stream)[1])
    arrays, meta = st.get(KEY2)
    back = store_mod.stream_from_payload(arrays, meta)
    assert back.networks == stream.networks
    assert back.n_cfg == stream.n_cfg
    assert back.metric == stream.metric and back.bound == stream.bound
    for k in store_mod._STREAM_ARRAYS:
        np.testing.assert_array_equal(getattr(back, k), getattr(stream, k))
    for nm in stream.networks:
        np.testing.assert_array_equal(back.boundary_idx[nm],
                                      stream.boundary_idx[nm])
        np.testing.assert_array_equal(back.boundary_energy[nm],
                                      stream.boundary_energy[nm])
        np.testing.assert_array_equal(back.boundary_latency[nm],
                                      stream.boundary_latency[nm])


def test_ckpt_save_iter_drop_and_quarantine(tmp_path):
    grid = ConfigGrid.product(arrays=((16, 16), (32, 32)),
                              gb_psum_kb=(13, 54),
                              gb_ifmap_kb=(27,))
    nets = {"AlexNet": topology.get_network("AlexNet")}
    states = []
    energymodel.stream_layer_topk(grid, nets, topk=4, bound=0.05,
                                  chunk_size=2, on_chunk=states.append)
    st = DurableStore(tmp_path)
    fs = states[0]
    st.save_ckpt(fs)
    (tmp_path / "ckpt" / "ckpt_deadbeef.npz").write_bytes(b"not an npz")
    loaded = list(st.iter_ckpts())                    # bad one quarantines
    assert len(loaded) == 1
    assert loaded[0][1].input_hash == fs.input_hash
    assert st.stats["quarantined"] == 1
    assert st.drop_ckpt(fs.input_hash)
    assert not st.drop_ckpt(fs.input_hash)            # already gone
    h = st.health()
    assert h["ckpt_saved"] == 1 and h["ckpt_deleted"] == 1
    assert h["n_ckpt_files"] == 0
