"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.models import model_zoo as Z
from repro.models import params as P

pytestmark = pytest.mark.slow      # full-model end-to-end runs

KEY = jax.random.key(0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).smoke()
    params = Z.init(cfg, KEY)
    batch = Z.make_batch(cfg, seq_len=32, global_batch=2, key=KEY)
    logits = Z.forward(params, cfg, batch)
    extra = cfg.vision_tokens if cfg.family == "vlm" else 0
    assert logits.shape == (2, 32 + extra, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_one_train_step_no_nans(arch):
    cfg = get_config(arch).smoke()
    params = Z.init(cfg, KEY)
    batch = Z.make_batch(cfg, seq_len=32, global_batch=2, key=KEY)

    def loss_fn(p):
        return Z.loss_fn(p, cfg, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no gradients produced"
    for g in leaves:
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step_shapes(arch):
    cfg = get_config(arch).smoke()
    params = Z.init(cfg, KEY)
    cache = P.init_tree(Z.cache_spec(cfg, 2, 48), KEY)
    if cfg.family == "audio":
        from repro.models import whisper
        frames = jax.random.normal(
            KEY, (2, cfg.n_audio_frames, cfg.d_model)).astype(jnp.bfloat16)
        ck, cv = whisper.init_cross_cache(params, cfg, frames)
        cache = dict(cache, cross_k=ck, cross_v=cv)
    toks = jnp.ones((2, 1), jnp.int32)
    logits, cache = Z.decode_step(params, cfg, toks, cache)
    logits, cache = Z.decode_step(params, cfg, toks, cache)
    assert logits.shape == (2, 1, cfg.vocab)
    assert int(cache["length"]) == 2
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_param_counts_full_configs():
    """Full (non-smoke) configs land near their nameplate sizes."""
    expect = {
        "qwen2-0.5b": (0.3e9, 0.8e9),
        "stablelm-1.6b": (1.2e9, 2.2e9),
        "mamba2-2.7b": (2.0e9, 3.4e9),
        "phi3-mini-3.8b": (3.2e9, 4.4e9),
        "recurrentgemma-9b": (7.5e9, 11e9),
        "qwen2.5-32b": (28e9, 36e9),
        "qwen2-vl-72b": (65e9, 80e9),
        "arctic-480b": (420e9, 520e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),     # 14.3B total, 2.7B active
        "whisper-base": (0.05e9, 0.12e9),
    }
    for arch, (lo, hi) in expect.items():
        n = P.count_params(Z.spec(get_config(arch)))
        assert lo < n < hi, (arch, f"{n:,}")


def test_active_params_moe():
    cfg = get_config("qwen2-moe-a2.7b")
    assert cfg.active_param_count() < 0.45 * cfg.param_count()
    arctic = get_config("arctic-480b")
    assert arctic.active_param_count() < 0.1 * arctic.param_count()


def test_shape_applicability_rules():
    runs, _ = shape_applicable("mamba2-2.7b", "long_500k")
    assert runs
    runs, reason = shape_applicable("qwen2.5-32b", "long_500k")
    assert not runs and "attention" in reason
    for arch in ARCHS:
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(arch, shape)[0]
