import os

# Smoke tests and benches must see the real (single-CPU) device set; only
# launch/dryrun.py forces 512 host devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
