"""Cross-validation: the analytic TPU cost model vs the trip-count-aware
HLO parse of the compiled dry-run cells (when available).

The analytic model feeds the autoshard DSE and B&B pipeline staging; it
should land within an order of magnitude of the compiled FLOPs (the HLO
adds remat re-forward, attention, CPU f32 promotion) — this test pins that
relationship so silent drift in either side gets caught.
"""

import json
from pathlib import Path

import pytest

from repro.configs import get_config
from repro.core.tpu_costmodel import ShardingPolicy, step_time

CELLS = Path("experiments/dryrun")


def _cell(arch, shape="train_4k"):
    f = CELLS / f"{arch}__{shape}__single.json"
    if not f.exists():
        pytest.skip("dry-run cells not generated")
    r = json.loads(f.read_text())
    if r.get("status") != "ok":
        pytest.skip(f"cell not ok: {r.get('status')}")
    return r


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "qwen2-0.5b",
                                  "phi3-mini-3.8b", "mamba2-2.7b"])
def test_analytic_flops_within_order_of_magnitude(arch):
    r = _cell(arch)
    hlo_flops = r["roofline"]["flops"]          # per chip
    pol = ShardingPolicy("baseline", dp=16, tp=16, fsdp=16)
    st = step_time(get_config(arch), pol, seq_len=4096, global_batch=256)
    analytic = st["flops"] / 1                  # per chip (dp×tp = 256)
    ratio = hlo_flops / analytic
    assert 0.1 < ratio < 30.0, (arch, ratio)


def test_model_flops_lower_bounds_hlo():
    """6·N·D can never exceed what the compiler actually scheduled."""
    for arch in ("qwen2.5-32b", "phi3-mini-3.8b", "stablelm-1.6b"):
        r = _cell(arch)
        rl = r["roofline"]
        assert rl["model_flops"] <= rl["flops"] * 1.05, arch
        assert 0.0 < rl["useful_flops_ratio"] <= 1.05, arch


def test_decode_cells_are_light_for_recurrent_archs():
    """The long_500k O(1)-state claim, quantitatively."""
    for arch in ("mamba2-2.7b", "recurrentgemma-9b"):
        r = _cell(arch, "long_500k")
        assert r["per_device_gib"] < 1.0, (arch, r["per_device_gib"])
