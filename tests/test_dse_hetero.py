"""DSE (Tables 1–5) and the heterogeneous chip scheme (§IV.A)."""

import numpy as np
import pytest

from repro.core import dse, hetero, topology


@pytest.fixture(scope="module")
def sweeps():
    return {name: dse.sweep_network(topology.get_network(name), name)
            for name in ("VGG16", "GoogleNet", "ResNet50", "MobileNet",
                         "AlexNet", "Xception")}


def test_sweep_shape_and_positivity(sweeps):
    sw = sweeps["VGG16"]
    assert sw.energy.shape == (6, 5, 5)
    assert (sw.energy > 0).all() and (sw.latency > 0).all()


def test_mu_delta_structure(sweeps):
    """Table 1 vs Table 2: psum sweeps move energy at least as much as
    ifmap sweeps for the large-psum-sensitivity nets."""
    for net in ("VGG16", "GoogleNet"):
        t1 = dse.mu_delta(sweeps[net], swept="ifmap")
        t2 = dse.mu_delta(sweeps[net], swept="psum")
        for arr in t1:
            mu1, d1 = t1[arr]
            mu2, d2 = t2[arr]
            assert mu1 >= 0 and d1 >= mu1 - 1e-9
            assert mu2 >= 0 and d2 >= mu2 - 1e-9
        # at [16,16] the psum effect dominates (paper's headline contrast)
        assert t2[(16, 16)][1] > t1[(16, 16)][1]


def test_delta_whole_space_ge_line_sweeps(sweeps):
    for net, sw in sweeps.items():
        d3 = dse.delta_whole_space(sw)
        t2 = dse.mu_delta(sw, swept="psum")
        for arr in d3:
            assert d3[arr] >= t2[arr][1] - 1e-9


def test_edp_spread_positive(sweeps):
    mean, mx = dse.edp_spread(sweeps["VGG16"])
    assert 0 < mean < mx


def test_boundary_configs_contains_min(sweeps):
    for sw in sweeps.values():
        cells = dse.boundary_configs(sw, bound=0.05)
        assert sw.argmin_cell() == cells[0]
        edp = sw.edp
        mn = edp[cells[0]]
        for c in cells:
            assert edp[c] <= mn * 1.05 + 1e-9


def test_chip_design_covers_everything(sweeps):
    chip = hetero.design_chip(sweeps, bound=0.05, max_cores=3)
    assert set(chip.assignment) == set(sweeps)
    assert 1 <= len(chip.core_types) <= 3
    sav = hetero.savings_summary(chip)
    for name, s in sav.items():
        assert s["energy_saved"] >= -1e-9
        assert s["edp_saved"] >= -1e-9


def test_cross_penalty_nonnegative_own_core(sweeps):
    chip = hetero.design_chip(sweeps, bound=0.05, max_cores=2)
    if len(chip.core_types) < 2:
        pytest.skip("single common config covers all")
    for name in chip.assignment:
        own = chip.assignment[name]
        other = 1 - own
        pen = hetero.cross_penalty(chip, name, other)
        # running on the other core can't beat the assigned one by much
        # (assignment picks the near-optimal core)
        assert pen["dEDP"] >= -5.0
