"""DSE (Tables 1–5), the heterogeneous chip scheme (§IV.A), and the
batched per-layer chip + schedule co-design (§IV.A × §IV.B)."""

import numpy as np
import pytest

from repro.core import accelerator, dse, energymodel, hetero, partition, \
    topology


@pytest.fixture(scope="module")
def sweeps():
    return {name: dse.sweep_network(topology.get_network(name), name)
            for name in ("VGG16", "GoogleNet", "ResNet50", "MobileNet",
                         "AlexNet", "Xception")}


def test_sweep_shape_and_positivity(sweeps):
    sw = sweeps["VGG16"]
    assert sw.energy.shape == (6, 5, 5)
    assert (sw.energy > 0).all() and (sw.latency > 0).all()


def test_mu_delta_structure(sweeps):
    """Table 1 vs Table 2: psum sweeps move energy at least as much as
    ifmap sweeps for the large-psum-sensitivity nets."""
    for net in ("VGG16", "GoogleNet"):
        t1 = dse.mu_delta(sweeps[net], swept="ifmap")
        t2 = dse.mu_delta(sweeps[net], swept="psum")
        for arr in t1:
            mu1, d1 = t1[arr]
            mu2, d2 = t2[arr]
            assert mu1 >= 0 and d1 >= mu1 - 1e-9
            assert mu2 >= 0 and d2 >= mu2 - 1e-9
        # at [16,16] the psum effect dominates (paper's headline contrast)
        assert t2[(16, 16)][1] > t1[(16, 16)][1]


def test_delta_whole_space_ge_line_sweeps(sweeps):
    for net, sw in sweeps.items():
        d3 = dse.delta_whole_space(sw)
        t2 = dse.mu_delta(sw, swept="psum")
        for arr in d3:
            assert d3[arr] >= t2[arr][1] - 1e-9


def test_edp_spread_positive(sweeps):
    mean, mx = dse.edp_spread(sweeps["VGG16"])
    assert 0 < mean < mx


def test_boundary_configs_contains_min(sweeps):
    for sw in sweeps.values():
        cells = dse.boundary_configs(sw, bound=0.05)
        assert sw.argmin_cell() == cells[0]
        edp = sw.edp
        mn = edp[cells[0]]
        for c in cells:
            assert edp[c] <= mn * 1.05 + 1e-9


def test_chip_design_covers_everything(sweeps):
    chip = hetero.design_chip(sweeps, bound=0.05, max_cores=3)
    assert set(chip.assignment) == set(sweeps)
    assert 1 <= len(chip.core_types) <= 3
    sav = hetero.savings_summary(chip)
    for name, s in sav.items():
        assert s["energy_saved"] >= -1e-9
        assert s["edp_saved"] >= -1e-9


# ---------------------------------------------------------------------------
# co_design: batched chip + per-layer schedule search
# ---------------------------------------------------------------------------

CODESIGN_NETS = ("AlexNet", "VGG16", "MobileNet", "GoogleNet")


@pytest.fixture(scope="module")
def codesign_result():
    nets = {n: topology.get_network(n) for n in CODESIGN_NETS}
    grid = accelerator.ConfigGrid.product()
    cd = hetero.co_design(grid, nets, m_cores=4, max_types=3, pool_size=5)
    return grid, nets, cd


def test_co_design_structure(codesign_result):
    grid, nets, cd = codesign_result
    assert sum(cd.core_counts) == cd.m_cores == 4
    assert 1 <= len(cd.core_types) <= 3
    assert all(0 <= c < grid.n for c in cd.core_types)
    assert set(cd.core_types) <= set(cd.pool)
    assert set(cd.schedules) == set(nets)
    # candidate enumeration covers every type-subset × composition once
    assert len(cd.chip_types) == len(set(
        (t, c) for t, c in zip(cd.chip_types, cd.chip_counts)))
    assert cd.n_chips == len(cd.chip_scores)
    assert cd.summary(grid)                  # label rendering works


def test_co_design_beats_or_matches_homogeneous(codesign_result):
    """The chip enumeration contains every single-type chip, so the
    winner can never score worse than the best homogeneous candidate."""
    _, _, cd = codesign_result
    assert cd.score <= cd.homogeneous_score + 1e-12
    assert cd.score == pytest.approx(float(cd.chip_scores.min()))


def test_co_design_schedules_match_oracle(codesign_result):
    """Every winning-chip schedule reproduces the scalar oracle exactly,
    and its per-layer energies/latencies tie back to the engine's
    per-layer tensors."""
    grid, nets, cd = codesign_result
    names = list(nets)
    lens = energymodel.network_layer_counts(nets)
    e_l, t_l = energymodel.evaluate_networks(
        grid.take(cd.core_types), nets, use_jax=False, per_layer=True)
    for j, nm in enumerate(names):
        lat = t_l[:, j, :lens[j]]
        oracle = partition.schedule_hetero_oracle(lat, cd.core_counts)
        s = cd.schedules[nm]
        assert s.bottleneck == oracle["bottleneck"]
        assert cd.latency[nm] == oracle["bottleneck"]
        assert tuple(s.layer_type) == tuple(oracle["layer_type"])
        want_e = e_l[oracle["layer_type"], j,
                     np.arange(lens[j])].sum()
        assert cd.energy[nm] == pytest.approx(want_e, rel=1e-12)
        assert cd.edp(nm) == pytest.approx(want_e * s.bottleneck,
                                           rel=1e-12)


def test_co_design_metric_variants():
    nets = {n: topology.get_network(n) for n in ("AlexNet", "MobileNet")}
    grid = accelerator.ConfigGrid.product(
        arrays=((16, 16), (32, 32), (64, 64)), gb_psum_kb=(13, 54, 216),
        gb_ifmap_kb=(27, 108))
    for metric in ("edp", "energy", "latency"):
        cd = hetero.co_design(grid, nets, m_cores=2, max_types=2,
                              pool_size=3, metric=metric)
        assert cd.metric == metric
        assert sum(cd.core_counts) == 2
        assert cd.score <= cd.homogeneous_score + 1e-12


def test_codesign_problems_shapes():
    nets = {n: topology.get_network(n) for n in ("AlexNet", "VGG16")}
    grid = accelerator.ConfigGrid.product()
    probs = hetero.codesign_problems(grid, nets, 3, max_types=2,
                                     pool_size=3)
    n_net = 2
    assert probs.n_problems == len(probs.chips) * n_net
    assert probs.lat_dense.shape[0] == probs.n_problems
    assert probs.counts.shape == (probs.n_problems,
                                  probs.lat_dense.shape[1])
    assert len(probs.pool) == 3 == len(set(probs.pool))
    # per-problem views agree with the dense tensor
    lats = probs.lats
    for i in (0, probs.n_problems - 1):
        np.testing.assert_array_equal(
            lats[i], probs.lat_dense[i, :, :probs.n_layers_b[i]])


def test_cross_penalty_nonnegative_own_core(sweeps):
    chip = hetero.design_chip(sweeps, bound=0.05, max_cores=2)
    if len(chip.core_types) < 2:
        pytest.skip("single common config covers all")
    for name in chip.assignment:
        own = chip.assignment[name]
        other = 1 - own
        pen = hetero.cross_penalty(chip, name, other)
        # running on the other core can't beat the assigned one by much
        # (assignment picks the near-optimal core)
        assert pen["dEDP"] >= -5.0
