"""Streaming co-design path: `stream_layer_topk`'s full reduction set
(top-k + minima + per-layer minima + ≤bound boundary sets), its
chunk-size-invariant index tie-breaking (regression: duplicated config
rows), and `co_design_streaming == co_design` parity on small grids
(every backend × chunked × sharded) and on the extended 5,400-point
space."""

import numpy as np
import pytest

from repro.core import accelerator, energymodel, hetero, partition, \
    topology

NETS = ("AlexNet", "VGG16", "MobileNet")


@pytest.fixture(scope="module")
def networks():
    return {n: topology.get_network(n) for n in NETS}


@pytest.fixture(scope="module")
def grid():
    return accelerator.ConfigGrid.product(
        arrays=((16, 16), (32, 32), (64, 64)), gb_psum_kb=(13, 54, 216),
        gb_ifmap_kb=(27, 108))


@pytest.fixture(scope="module")
def dense(networks, grid):
    el, tl = energymodel.evaluate_networks(grid, networks, use_jax=False,
                                           per_layer=True)
    return el, tl


def _dup_grid(grid):
    """Grid with every row duplicated (dup of row i at index n + i):
    every metric value ties exactly with its twin."""
    n = grid.n
    idx = np.concatenate([np.arange(n), np.arange(n)])
    return accelerator.ConfigGrid(
        {k: v[idx] for k, v in grid.fields.items()}), n


def test_stream_layer_topk_tie_regression(networks, grid):
    """Duplicated latency/energy rows: the top-k must keep the LOWER
    flat index of each tied pair, identically at every chunk size."""
    dgrid, n = _dup_grid(grid)
    k = 6
    ref = None
    for use_jax in (False, True):
        for chunk in (3, 7, 16, dgrid.n):
            lt = energymodel.stream_layer_topk(
                dgrid, networks, topk=k, chunk_size=chunk,
                use_jax=use_jax)
            if ref is None:
                ref, ref_v = lt.topk_idx, lt.topk_metric
            np.testing.assert_array_equal(
                lt.topk_idx, ref, err_msg=f"jax={use_jax} chunk={chunk}")
    # ties (every value has an exact twin) order by ascending index: the
    # best entry is always a low twin, and each tied run is idx-sorted
    assert (ref[0] < n).all()
    tied = ref_v[:-1] == ref_v[1:]
    assert (ref[:-1][tied] < ref[1:][tied]).all()


def test_stream_networks_topk_tie_regression(networks, grid):
    """Same regression through stream_networks' aggregate top-k."""
    dgrid, n = _dup_grid(grid)
    ref = None
    for use_jax in (False, True):
        for chunk in (5, 11, dgrid.n):
            sr = energymodel.stream_networks(
                dgrid, networks, topk=5, chunk_size=chunk,
                use_jax=use_jax)
            if ref is None:
                ref, ref_v = sr.topk_idx, sr.topk_metric
            np.testing.assert_array_equal(
                sr.topk_idx, ref, err_msg=f"jax={use_jax} chunk={chunk}")
    assert (ref[0] < n).all()
    tied = ref_v[:-1] == ref_v[1:]
    assert (ref[:-1][tied] < ref[1:][tied]).all()


def test_stream_layer_reductions_match_dense(networks, grid, dense):
    """Minima, argmins, per-layer minima, and boundary sets all equal the
    dense per-layer reference, for every chunk size and backend."""
    el, tl = dense
    es, ts = el.sum(-1), tl.sum(-1)
    edp = es * ts
    lens = energymodel.network_layer_counts(networks)
    bound = 0.10
    for kw in (dict(use_jax=False), dict(use_jax=True),
               dict(use_jax=True, shard=True)):
        for chunk in (7, grid.n):
            lt = energymodel.stream_layer_topk(
                grid, networks, topk=4, chunk_size=chunk, bound=bound,
                **kw)
            np.testing.assert_allclose(lt.min_energy, es.min(0),
                                       rtol=1e-9)
            np.testing.assert_allclose(lt.min_latency, ts.min(0),
                                       rtol=1e-9)
            np.testing.assert_allclose(lt.min_edp, edp.min(0), rtol=1e-9)
            np.testing.assert_allclose(lt.min_metric, edp.min(0),
                                       rtol=1e-9)
            np.testing.assert_array_equal(lt.argmin, edp.argmin(0))
            for j, nm in enumerate(networks):
                L = lens[j]
                lm = el[:, j, :L] * tl[:, j, :L]
                np.testing.assert_allclose(
                    lt.layer_min_metric[j, :L], lm.min(0), rtol=1e-9)
                np.testing.assert_array_equal(
                    lt.layer_argmin[j, :L], lm.argmin(0))
                # padded layer tail: +inf metric, -1 argmin
                assert np.all(np.isinf(lt.layer_min_metric[j, L:]))
                assert np.all(lt.layer_argmin[j, L:] == -1)
                # boundary set == dense threshold set, metric-sorted
                want = np.flatnonzero(edp[:, j]
                                      <= edp[:, j].min() * (1 + bound))
                assert set(lt.boundary_idx[nm]) == set(want), (kw, chunk)
                v = lt.boundary_metric(nm)
                assert (np.diff(v) >= 0).all()
                np.testing.assert_allclose(
                    v, edp[lt.boundary_idx[nm], j], rtol=1e-9)


def test_stream_layer_topk_without_bound(networks, grid):
    lt = energymodel.stream_layer_topk(grid, networks, topk=3,
                                       chunk_size=8, use_jax=False)
    assert lt.bound is None and lt.boundary_idx is None
    assert lt.min_energy is not None          # minima always maintained


def test_codesign_problems_streaming_parity(networks, grid):
    """Streamed problem sets equal dense ones — pool, solver tensors, and
    scoring references — for every backend, chunked and sharded."""
    dense_p = hetero.codesign_problems(grid, networks, 3, max_types=2,
                                       pool_size=4)
    combos = [dict(use_jax=False), dict(use_jax=True),
              dict(use_jax=True, shard=True)]
    if energymodel.pallas_available():
        combos.append(dict(backend="pallas"))
    for kw in combos:
        for chunk in (7, grid.n):
            sp = hetero.codesign_problems_streaming(
                grid, networks, 3, max_types=2, pool_size=4,
                chunk_size=chunk, **kw)
            assert sp.pool == dense_p.pool, (kw, chunk)
            assert sp.chips == dense_p.chips
            np.testing.assert_allclose(sp.lat_dense, dense_p.lat_dense,
                                       rtol=1e-9)
            np.testing.assert_allclose(sp.min_energy, dense_p.min_energy,
                                       rtol=1e-9)
            np.testing.assert_allclose(sp.min_latency,
                                       dense_p.min_latency, rtol=1e-9)
            np.testing.assert_allclose(sp.min_edp, dense_p.min_edp,
                                       rtol=1e-9)


def test_codesign_problems_streaming_reuses_stream(networks, grid):
    lt = energymodel.stream_layer_topk(grid, networks, topk=4, bound=0.05,
                                       chunk_size=16, use_jax=False)
    sp = hetero.codesign_problems_streaming(
        grid, networks, 3, max_types=2, pool_size=4, stream=lt,
        use_jax=False)
    dense_p = hetero.codesign_problems(grid, networks, 3, max_types=2,
                                       pool_size=4, use_jax=False)
    assert sp.pool == dense_p.pool
    # a stream without boundary sets is rejected
    bare = energymodel.stream_layer_topk(grid, networks, topk=4,
                                         chunk_size=16, use_jax=False)
    with pytest.raises(ValueError, match="boundary"):
        hetero.codesign_problems_streaming(grid, networks, 3, stream=bare)
    # a stream with too small a top-k is rejected
    small = energymodel.stream_layer_topk(grid, networks, topk=2,
                                          bound=0.05, chunk_size=16,
                                          use_jax=False)
    with pytest.raises(ValueError, match="top-k too small"):
        hetero.codesign_problems_streaming(grid, networks, 3,
                                           pool_size=4, stream=small)


def test_candidate_pool_dedups_identical_rows(networks, grid):
    """A duplicated grid row can never occupy two pool slots — and the
    pool of the duplicated grid maps 1:1 onto the original's (low-index
    twins), streamed and dense alike."""
    dgrid, n = _dup_grid(grid)
    base = hetero.codesign_problems(grid, networks, 3, max_types=2,
                                    pool_size=4, use_jax=False)
    dup = hetero.codesign_problems(dgrid, networks, 3, max_types=2,
                                   pool_size=4, use_jax=False)
    assert [p % n for p in dup.pool] == base.pool
    assert all(p < n for p in dup.pool)        # low twins win ties
    sdup = hetero.codesign_problems_streaming(
        dgrid, networks, 3, max_types=2, pool_size=4, chunk_size=13,
        use_jax=False)
    assert sdup.pool == dup.pool


def test_streaming_topk_saturation_warns_and_topk_recovers(networks,
                                                           grid):
    """A grid whose rows are duplicated 5× can saturate the per-network
    top-k with copies of one row, hiding distinct rows the dense top-up
    would reach: the streamed builder must WARN about the short pool,
    and a larger topk= must restore dense-pool equivalence."""
    import warnings as _warnings
    n = grid.n
    idx = np.concatenate([np.arange(n)] * 5)
    dgrid = accelerator.ConfigGrid(
        {k: v[idx] for k, v in grid.fields.items()})
    dense_p = hetero.codesign_problems(dgrid, networks, 3, max_types=2,
                                       pool_size=4, bound=1e-9,
                                       use_jax=False)
    with _warnings.catch_warnings(record=True) as rec:
        _warnings.simplefilter("always")
        hetero.codesign_problems_streaming(
            dgrid, networks, 3, max_types=2, pool_size=4, bound=1e-9,
            chunk_size=13, use_jax=False)
    # the saturation precondition (a top-k with < pool_size distinct
    # rows) holds here whatever the pool length came out as — it MUST
    # have been flagged
    assert any("saturate" in str(w.message) for w in rec)
    # remedy: a top-k deep enough to see past the copies — no warning
    with _warnings.catch_warnings(record=True) as rec:
        _warnings.simplefilter("always")
        full = hetero.codesign_problems_streaming(
            dgrid, networks, 3, max_types=2, pool_size=4, bound=1e-9,
            chunk_size=13, use_jax=False, topk=4 * 5)
    assert not any("saturate" in str(w.message) for w in rec)
    assert full.pool == dense_p.pool


def test_streaming_rejects_mismatched_stream(networks, grid):
    lt = energymodel.stream_layer_topk(grid, networks, topk=4, bound=0.05,
                                       chunk_size=16, use_jax=False)
    other = accelerator.ConfigGrid(
        {k: np.concatenate([v, v]) for k, v in grid.fields.items()})
    with pytest.raises(ValueError, match="wrong grid"):
        hetero.codesign_problems_streaming(other, networks, 3,
                                           pool_size=4, stream=lt)
    with pytest.raises(ValueError, match="bound, metric"):
        hetero.codesign_problems_streaming(grid, networks, 3, pool_size=4,
                                           bound=0.10, stream=lt)


def test_co_design_streaming_matches_dense_small(networks, grid):
    cd = hetero.co_design(grid, networks, m_cores=3, max_types=2,
                          pool_size=4)
    cs = hetero.co_design_streaming(grid, networks, m_cores=3,
                                    max_types=2, pool_size=4,
                                    chunk_size=11)
    assert cs.pool == cd.pool
    assert cs.core_types == cd.core_types
    assert cs.core_counts == cd.core_counts
    assert cs.schedules == cd.schedules
    assert cs.energy == cd.energy and cs.latency == cd.latency
    assert cs.score == pytest.approx(cd.score, rel=1e-9)
    assert cs.homogeneous_score == pytest.approx(cd.homogeneous_score,
                                                 rel=1e-9)


@pytest.mark.slow
def test_co_design_streaming_extended_grid_parity(networks):
    """ISSUE 5 acceptance: streamed co-design reproduces the dense path
    on the extended 5,400-point space — every backend, chunked and
    chunked+sharded.  Steps 2–4 are shared code, so pool equality makes
    the winning chip and every schedule bit-identical."""
    egrid = accelerator.extended_grid()
    cd = hetero.co_design(egrid, networks, m_cores=4, max_types=3,
                          pool_size=6)
    combos = [dict(use_jax=False), dict(use_jax=True),
              dict(use_jax=True, shard=True)]
    if energymodel.pallas_available():
        combos.append(dict(backend="pallas"))
    for kw in combos:
        cs = hetero.co_design_streaming(egrid, networks, m_cores=4,
                                        max_types=3, pool_size=6,
                                        chunk_size=1024, **kw)
        assert cs.pool == cd.pool, kw
        assert cs.core_types == cd.core_types, kw
        assert cs.core_counts == cd.core_counts, kw
        assert cs.schedules == cd.schedules, kw
        assert cs.energy == cd.energy, kw
        assert cs.score == pytest.approx(cd.score, rel=1e-9)


@pytest.mark.slow
def test_pareto_codesign_streaming_vs_dense_problems(networks, grid):
    """The Pareto sweep is agnostic to how the problem set was built:
    streamed and dense problems give identical frontiers and winners."""
    dp = hetero.codesign_problems(grid, networks, 3, max_types=2,
                                  pool_size=4)
    sp = hetero.codesign_problems_streaming(grid, networks, 3,
                                            max_types=2, pool_size=4,
                                            chunk_size=9)
    res_d = partition.batch_schedule_hetero(dp.lat_dense, dp.counts,
                                            n_layers=dp.n_layers_b)
    res_s = partition.batch_schedule_hetero(sp.lat_dense, sp.counts,
                                            n_layers=sp.n_layers_b)
    deadlines = np.linspace(0.3, 1.2, 8)
    pd_ = hetero.pareto_codesign(dp, res_d, deadlines=deadlines)
    ps = hetero.pareto_codesign(sp, res_s, deadlines=deadlines)
    np.testing.assert_array_equal(pd_.best_chip, ps.best_chip)
    np.testing.assert_array_equal(pd_.net_frontier, ps.net_frontier)
    np.testing.assert_allclose(pd_.scores, ps.scores, rtol=1e-9)
