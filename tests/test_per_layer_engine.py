"""The per-layer metric path (`evaluate_networks(per_layer=True)`): parity
with the scalar per-layer reports and the aggregate path, across every
engine variant (numpy/jax/pallas × chunked × sharded), the streaming
per-layer top-k, and the warn-once backend-fallback contract."""

import warnings

import numpy as np
import pytest

from repro.core import accelerator, dse, energymodel, topology

NETS = ("AlexNet", "VGG16", "MobileNet")


@pytest.fixture(scope="module")
def networks():
    return {n: topology.get_network(n) for n in NETS}


@pytest.fixture(scope="module")
def grid():
    return accelerator.ConfigGrid.product(
        arrays=((16, 16), (32, 32), (64, 64)), gb_psum_kb=(13, 54, 216),
        gb_ifmap_kb=(27, 108))


@pytest.fixture(scope="module")
def per_layer_np(networks, grid):
    return energymodel.evaluate_networks(grid, networks, use_jax=False,
                                         per_layer=True)


def test_shape_and_zero_padding(networks, grid, per_layer_np):
    el, tl = per_layer_np
    lens = energymodel.network_layer_counts(networks)
    assert el.shape == tl.shape == (grid.n, len(networks), lens.max())
    for j, nm in enumerate(networks):
        assert np.all(el[:, j, lens[j]:] == 0.0), nm
        assert np.all(tl[:, j, lens[j]:] == 0.0), nm
        assert np.all(el[:, j, :lens[j]] > 0.0), nm


def test_matches_scalar_layer_reports(networks, grid, per_layer_np):
    """Per-layer rows ≡ simulate_network's LayerReport values (the scalar
    §II.B.2 path), config by config."""
    el, tl = per_layer_np
    for i in (0, grid.n - 1):
        for j, (nm, layers) in enumerate(networks.items()):
            rep = energymodel.simulate_network(grid.config_at(i), layers,
                                               nm)
            np.testing.assert_allclose(
                el[i, j, :len(rep.layers)],
                [l.energy for l in rep.layers], rtol=1e-12)
            np.testing.assert_allclose(
                tl[i, j, :len(rep.layers)],
                [l.latency for l in rep.layers], rtol=1e-12)


def test_layer_sums_reproduce_aggregate_path(networks, grid, per_layer_np):
    """Summing the layer axis reproduces the default early-reduction path
    exactly — the two paths differ only in WHEN the sum happens."""
    el, tl = per_layer_np
    e0, t0 = energymodel.evaluate_networks(grid, networks, use_jax=False)
    np.testing.assert_allclose(el.sum(-1), e0, rtol=1e-12)
    np.testing.assert_allclose(tl.sum(-1), t0, rtol=1e-12)


def test_jax_chunked_sharded_parity(networks, grid, per_layer_np):
    """per_layer=True through the jitted, chunked, sharded, and
    chunked+sharded paths all agree with the numpy reference."""
    el, tl = per_layer_np
    for kw in (dict(), dict(chunk_size=7), dict(shard=True),
               dict(shard=True, chunk_size=7)):
        e1, t1 = energymodel.evaluate_networks(grid, networks,
                                               use_jax=True,
                                               per_layer=True, **kw)
        np.testing.assert_allclose(e1, el, rtol=1e-9, err_msg=str(kw))
        np.testing.assert_allclose(t1, tl, rtol=1e-9, err_msg=str(kw))


def test_pallas_per_layer_parity(networks, grid, per_layer_np):
    if not energymodel.pallas_available():              # pragma: no cover
        pytest.skip("pallas unavailable")
    el, tl = per_layer_np
    for kw in (dict(), dict(chunk_size=7), dict(shard=True)):
        e1, t1 = energymodel.evaluate_networks(grid, networks,
                                               backend="pallas",
                                               per_layer=True, **kw)
        np.testing.assert_allclose(e1, el, rtol=1e-9, err_msg=str(kw))
        np.testing.assert_allclose(t1, tl, rtol=1e-9, err_msg=str(kw))
        assert energymodel.last_backend() == "pallas"


def test_dse_layer_metrics_wrapper(networks, grid, per_layer_np):
    el, tl = per_layer_np
    e1, t1 = dse.layer_metrics(networks, grid, use_jax=False)
    np.testing.assert_array_equal(e1, el)
    np.testing.assert_array_equal(t1, tl)


def test_stream_layer_topk_matches_dense(networks, grid, per_layer_np):
    """The streaming top-k keeps exactly the k best configs' per-layer
    rows, for every chunk size and backend."""
    el, tl = per_layer_np
    edp = el.sum(-1) * tl.sum(-1)
    k = 4
    for kw in (dict(use_jax=False), dict(use_jax=True),
               dict(use_jax=True, shard=True)):
        for chunk in (5, 16, grid.n):
            lt = energymodel.stream_layer_topk(grid, networks, topk=k,
                                               chunk_size=chunk, **kw)
            assert lt.n_cfg == grid.n
            for j, nm in enumerate(networks):
                want = np.argsort(edp[:, j], kind="stable")[:k]
                assert np.array_equal(lt.topk_idx[:, j], want), (kw, chunk)
                np.testing.assert_allclose(lt.layer_energy[:, j],
                                           el[want, j], rtol=1e-9)
                np.testing.assert_allclose(lt.layer_latency[:, j],
                                           tl[want, j], rtol=1e-9)
                np.testing.assert_allclose(
                    lt.topk_metric[:, j], edp[want, j], rtol=1e-9)


def test_aggregate_trace_sharing_unaffected(networks):
    """The default path still shares one trace across single-network
    sweeps (per_layer uses its own cache key and true segment lengths)."""
    grid = accelerator.ConfigGrid.product()
    dse.sweep_network(networks["AlexNet"], "AlexNet", use_jax=True)
    before = energymodel.jit_cache_stats()["traces"]
    dse.sweep_network(networks["VGG16"], "VGG16", use_jax=True)
    assert energymodel.jit_cache_stats()["traces"] == before


# ---------------------------------------------------------------------------
# warn-once auto-fallback + last_backend under forced fallback
# ---------------------------------------------------------------------------


def test_fallback_warns_exactly_once_per_process(networks, monkeypatch):
    """A degraded explicit backend warns ONCE per process per edge — not
    per call — and last_backend() reports what actually ran."""
    monkeypatch.setattr(energymodel, "pallas_available", lambda: False)
    monkeypatch.setattr(energymodel, "_FALLBACK_WARNED", set())
    grid = accelerator.ConfigGrid.product(arrays=((16, 16),),
                                          gb_psum_kb=(54,),
                                          gb_ifmap_kb=(54,))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        energymodel.evaluate_networks(grid, networks, backend="pallas")
        assert energymodel.last_backend() == "jax"
        energymodel.evaluate_networks(grid, networks, backend="pallas")
        energymodel.stream_networks(grid, networks, backend="pallas",
                                    chunk_size=8)
    ours = [w for w in rec if issubclass(w.category, RuntimeWarning)
            and "falling back" in str(w.message)]
    assert len(ours) == 1, [str(w.message) for w in rec]
    assert "'pallas'" in str(ours[0].message)
    assert energymodel.last_backend() == "jax"


def test_fallback_warning_keyed_per_edge(monkeypatch):
    monkeypatch.setattr(energymodel, "_FALLBACK_WARNED", set())
    monkeypatch.setattr(energymodel, "pallas_available", lambda: False)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert energymodel.resolve_backend("pallas") == "jax"
        assert energymodel.resolve_backend("pallas") == "jax"
        monkeypatch.setattr(energymodel, "jax_available", lambda: False)
        assert energymodel.resolve_backend("pallas") == "numpy"
        assert energymodel.resolve_backend("jax") == "numpy"
        assert energymodel.resolve_backend("jax") == "numpy"
        # auto-selection (no explicit request) must never warn
        assert energymodel.resolve_backend(None) == "numpy"
    msgs = [str(w.message) for w in rec
            if issubclass(w.category, RuntimeWarning)]
    assert len(msgs) == 3                    # pallas→jax, pallas→numpy,
    assert len(set(msgs)) == 3               # jax→numpy: one each
