"""Shared differential-test harness: ONE seeded instance generator and
the brute-force oracles that were previously copy-pasted across
`test_partition.py`, `test_schedule_hetero.py` and
`test_pareto_codesign.py` (and now also back the energy-aware slack
suite in `test_slack_schedule.py`).

Everything here is deliberately SLOW and OBVIOUS — python loops,
exhaustive enumeration, no vectorisation — so the production solvers
have an independent reference to be bit-exact (or approx-equal, where
the test says so) against.
"""

import numpy as np

from repro.core import partition


# ---------------------------------------------------------------------------
# Seeded instance generators (the non-hypothesis twins always run)
# ---------------------------------------------------------------------------


def seeded_hetero_instances(seed, n, *, max_types=3, max_layers=8,
                            max_count=3, lat_range=(0.01, 100.0)):
    """``n`` random (lat [T, L], counts [T]) heterogeneous-schedule
    instances from one seed; at least one core is always available."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        t = int(rng.integers(1, max_types + 1))
        L = int(rng.integers(1, max_layers + 1))
        lat = rng.uniform(*lat_range, size=(t, L))
        counts = rng.integers(0, max_count + 1, size=t)
        if counts.sum() == 0:
            counts[int(rng.integers(t))] = 1
        out.append((lat, counts))
    return out


def seeded_slack_instances(seed, n, *, max_types=3, max_layers=10,
                           max_count=3, tie_values=(0.5, 1.0, 1.5, 2.0,
                                                    3.0)):
    """``n`` random (lat, energy, counts) slack-schedule instances.
    Values are drawn from a SMALL set so exact ties (the hardest case
    for deterministic tie-breaking) occur constantly."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        t = int(rng.integers(1, max_types + 1))
        L = int(rng.integers(1, max_layers + 1))
        lat = rng.choice(tie_values, size=(t, L))
        en = rng.choice(tie_values, size=(t, L))
        counts = rng.integers(0, max_count + 1, size=t)
        if counts.sum() == 0:
            counts[int(rng.integers(t))] = 1
        out.append((lat, en, counts))
    return out


# ---------------------------------------------------------------------------
# Oracle 1: the per-(network, k) dp partition loop
# ---------------------------------------------------------------------------


def dp_partition_loop(lat_groups, ks):
    """Python-loop twin of `partition.batch_partition`: one
    `dp_partition` call per (network, k) pair.  Returns
    ``{(i, k): Partition}``."""
    return {(i, k): partition.dp_partition(lat, k)
            for i, lat in enumerate(lat_groups) for k in ks}


# ---------------------------------------------------------------------------
# Oracle 2: brute-force heterogeneous schedule (argmin + enumeration)
# ---------------------------------------------------------------------------


def brute_force_hetero(lat, counts):
    """Brute-force oracle within the solver's semantics: per-layer argmin
    type assignment, then EVERY contiguous segmentation of each type's
    subsequence enumerated (`brute_force_partition`), bottleneck = max
    over types.  <=8 layers / <=3 types keeps this trivial."""
    lat = np.asarray(lat, dtype=np.float64)
    counts = np.asarray(counts, dtype=np.int64)
    cost = np.where((counts > 0)[:, None], lat, np.inf)
    tt = np.argmin(cost, axis=0)
    bottleneck = 0.0
    for t in range(lat.shape[0]):
        sub = lat[t, tt == t]
        if counts[t] <= 0 or sub.size == 0:
            continue
        p = partition.brute_force_partition(sub, int(counts[t]))
        bottleneck = max(bottleneck, p.pipeline_latency)
    return bottleneck


def assert_schedule_valid(s, lat, counts):
    """A HeteroSchedule is internally consistent: per-type core budgets,
    load recompute, bottleneck = max load, per-core contiguity."""
    import pytest
    lat = np.asarray(lat, dtype=np.float64)
    counts = np.asarray(counts, dtype=np.int64)
    assert s.n_cores == counts.sum()
    assert len(s.layer_type) == len(s.layer_core) == lat.shape[1]
    used = {}
    for ty, co in zip(s.layer_type, s.layer_core):
        assert counts[ty] > 0
        assert s.types[co] == ty
        used.setdefault(ty, set()).add(co)
    for ty, cores in used.items():
        assert len(cores) <= counts[ty]
    loads = np.zeros(len(s.types))
    for l in range(lat.shape[1]):
        loads[s.layer_core[l]] += lat[s.layer_type[l], l]
    np.testing.assert_allclose(loads, s.loads, rtol=1e-12, atol=1e-12)
    assert s.bottleneck == pytest.approx(max(s.loads))
    for ty, cores in used.items():
        seq = [s.layer_core[l] for l in range(lat.shape[1])
               if s.layer_type[l] == ty]
        assert seq == sorted(seq)


# ---------------------------------------------------------------------------
# Oracle 3: per-deadline pareto scoring loop + dominance filter
# ---------------------------------------------------------------------------


def brute_frontier(value, latency):
    """O(C^2) dominance filter: point c survives unless some other point
    is <= in both coordinates and < in at least one."""
    C = value.shape[0]
    keep = np.ones(C, dtype=bool)
    for c in range(C):
        for o in range(C):
            if (value[o] <= value[c] and latency[o] <= latency[c]
                    and (value[o] < value[c] or latency[o] < latency[c])):
                keep[c] = False
                break
    return keep


def loop_pareto_scores(value, latency, deadlines):
    """Per-deadline python loop twin of `partition.batch_pareto_scores`:
    returns (best [D], best_net [N, D])."""
    C, N = value.shape
    D = deadlines.shape[1]
    best = np.full(D, -1, dtype=np.int64)
    best_net = np.full((N, D), -1, dtype=np.int64)
    for d in range(D):
        best_s = np.inf
        net_s = np.full(N, np.inf)
        for c in range(C):
            feas = latency[c] <= deadlines[:, d]
            if feas.all() and value[c].mean() < best_s:
                best_s, best[d] = value[c].mean(), c
            for j in np.flatnonzero(feas):
                if value[c, j] < net_s[j]:
                    net_s[j], best_net[j, d] = value[c, j], c
    return best, best_net
