"""The Tool (§II): invariants and the paper's Observations 1–4."""

import numpy as np
import pytest

from repro.core import accelerator, energymodel, topology

SIZES = (13, 27, 54, 108, 216)


def _cfg(rows=16, cols=16, ps=54, ifm=54):
    return accelerator.AcceleratorConfig(
        array_rows=rows, array_cols=cols, gb_psum_kb=ps, gb_ifmap_kb=ifm)


@pytest.fixture(scope="module")
def vgg16():
    return topology.get_network("VGG16")


def test_energy_is_cumulative(vgg16):
    rep = energymodel.simulate_network(_cfg(), vgg16)
    assert rep.energy == pytest.approx(sum(l.energy for l in rep.layers))
    assert rep.latency == pytest.approx(sum(l.latency for l in rep.layers))


def test_all_networks_simulate_positive():
    cfg = _cfg()
    for name in topology.NETWORKS:
        rep = energymodel.simulate_network(cfg, topology.get_network(name))
        assert rep.energy > 0 and rep.latency > 0, name
        assert all(l.energy >= 0 and l.latency > 0 for l in rep.layers)


def test_mac_counts_match_known_values(vgg16):
    gmacs = sum(l.macs for l in vgg16) / 1e9
    assert 14.5 < gmacs < 16.5          # published VGG16 ≈ 15.5 GMACs
    resnet = topology.get_network("ResNet50")
    assert 3.0 < sum(l.macs for l in resnet) / 1e9 < 4.3


def test_scalar_matches_vectorised(vgg16):
    grid = list(accelerator.config_grid().values())[:10]
    e_vec, t_vec = energymodel.simulate_grid(grid, vgg16)
    for i in (0, 3, 7):
        rep = energymodel.simulate_network(grid[i], vgg16)
        assert rep.energy == pytest.approx(e_vec[i], rel=1e-12)
        assert rep.latency == pytest.approx(t_vec[i], rel=1e-12)


def test_jax_path_matches_numpy(vgg16):
    grid = list(accelerator.config_grid().values())[:25]
    e_np, t_np = energymodel.simulate_grid(grid, vgg16)
    e_jx, t_jx = energymodel.simulate_grid(grid, vgg16, use_jax=True)
    np.testing.assert_allclose(e_np, e_jx, rtol=1e-9)
    np.testing.assert_allclose(t_np, t_jx, rtol=1e-9)


def test_observation1_interior_minimum(vgg16):
    """Obs 1: at fixed GB_ifmap, energy vs GB_psum has an interior or
    boundary minimum away from the smallest size (spill cost dominates)."""
    es = [energymodel.simulate_network(_cfg(ps=ps, ifm=216), vgg16).energy
          for ps in SIZES]
    assert np.argmin(es) > 0            # 13KB is never the best
    assert max(es) / min(es) > 1.05     # and the spread is material


def test_observation2_more_rounds_cost_energy(vgg16):
    """Starving GB_ifmap must not reduce energy (rounds inflation)."""
    e_small = energymodel.simulate_network(_cfg(ifm=13, ps=54,
                                                rows=64, cols=64),
                                           vgg16).energy
    e_big = energymodel.simulate_network(_cfg(ifm=216, ps=54,
                                              rows=64, cols=64),
                                         vgg16).energy
    assert e_small >= e_big * 0.99


def test_observation3_psum_size_gates_latency(vgg16):
    """Obs 3: larger array only pays off with commensurate GB_psum."""
    t13 = energymodel.simulate_network(
        _cfg(rows=32, cols=32, ps=13, ifm=216), vgg16).latency
    t108 = energymodel.simulate_network(
        _cfg(rows=32, cols=32, ps=108, ifm=216), vgg16).latency
    assert t13 > t108


def test_array_growth_reduces_compute_time(vgg16):
    """Fig. 8: array compute time decreases (sub-linearly) with array."""
    t = {}
    for r in (16, 32, 64):
        rep = energymodel.simulate_network(_cfg(rows=r, cols=r, ps=216,
                                                ifm=216), vgg16)
        t[r] = sum(l.array_time for l in rep.layers)
    assert t[16] > t[32] > t[64]


def test_psum_spill_tracking(vgg16):
    rep13 = energymodel.simulate_network(_cfg(ps=13, ifm=216), vgg16)
    rep216 = energymodel.simulate_network(_cfg(ps=216, ifm=216), vgg16)
    assert sum(l.psum_spilled for l in rep13.layers) > \
        sum(l.psum_spilled for l in rep216.layers)


def test_utilization_bounded(vgg16):
    rep = energymodel.simulate_network(_cfg(), vgg16)
    for l in rep.layers:
        assert 0.0 <= l.utilization <= 1.0 + 1e-9
