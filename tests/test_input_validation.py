"""Engine-boundary validation: poisoned ConfigGrid columns and broken
layer shapes are rejected with errors naming the exact column/field and
row/layer index — they never reach the reductions, where a NaN would
silently lose every (value, index) comparison and vanish."""

import dataclasses

import numpy as np
import pytest

from repro.core import energymodel, topology
from repro.core.accelerator import ConfigGrid


def _grid():
    return ConfigGrid.product(arrays=((16, 16), (32, 32), (64, 64)),
                              gb_psum_kb=(13, 54, 216))


def _poison(grid, column, row, value):
    fields = {k: v.copy() for k, v in grid.fields.items()}
    fields[column][row] = value
    return ConfigGrid(fields=fields)


def test_nan_grid_row_names_column_and_row():
    with pytest.raises(ValueError,
                       match=r"column 'gb_psum_kb' row 4 is non-finite"):
        _poison(_grid(), "gb_psum_kb", 4, np.nan)


def test_inf_grid_row_names_column_and_row():
    with pytest.raises(ValueError,
                       match=r"column 'e_mac' row 2 is non-finite"):
        _poison(_grid(), "e_mac", 2, np.inf)


def test_zero_rows_rejected():
    with pytest.raises(ValueError,
                       match=r"column 'rows' row 3 must be > 0"):
        _poison(_grid(), "rows", 3, 0.0)


def test_negative_energy_coefficient_rejected():
    with pytest.raises(ValueError,
                       match=r"column 'e_mac' row 0 must be >= 0"):
        _poison(_grid(), "e_mac", 0, -1.0)


def test_zero_energy_coefficient_allowed():
    # e_* are scale factors, not divisors: zero is a legal ablation
    g = _poison(_grid(), "e_pe_idle", 0, 0.0)
    assert g.n == _grid().n


def test_poisoned_grid_never_reaches_stream():
    """Regression: the old behavior let a NaN row flow into the fold and
    silently drop out of the top-k; now construction itself fails."""
    grid = _grid()
    nets = {"AlexNet": topology.get_network("AlexNet")}
    fields = {k: v.copy() for k, v in grid.fields.items()}
    fields["gb_ifmap_kb"][1] = np.nan
    with pytest.raises(ValueError, match=r"'gb_ifmap_kb' row 1"):
        bad = ConfigGrid(fields=fields)
        energymodel.stream_layer_topk(bad, nets, topk=2, chunk_size=3)


def _nets_with(layer):
    base = topology.get_network("AlexNet")
    return {"Broken": list(base[:1]) + [layer]}


def test_zero_channel_layer_names_network_layer_field():
    bad = dataclasses.replace(topology.get_network("AlexNet")[1], c_in=0)
    with pytest.raises(ValueError,
                       match=r"network 'Broken': layer \d+ field 'c_ch'"):
        energymodel.evaluate_networks(_grid(), _nets_with(bad))


def test_nan_layer_shape_rejected():
    bad = dataclasses.replace(topology.get_network("AlexNet")[1],
                              h_in=np.nan)
    with pytest.raises(ValueError, match=r"network 'Broken':.*non-finite"):
        energymodel.evaluate_networks(_grid(), _nets_with(bad))


def test_zero_kernel_rejected():
    # stride=0 already dies in Layer.h_out; k=0 survives shape derivation
    # and must be stopped by the boundary validator instead
    bad = dataclasses.replace(topology.get_network("AlexNet")[1], k=0)
    with pytest.raises(ValueError,
                       match=r"field '(ky|kx)' must be >= 1"):
        energymodel.evaluate_networks(_grid(), _nets_with(bad))


def test_good_inputs_still_pass():
    energy, latency = energymodel.evaluate_networks(
        _grid(), {"AlexNet": topology.get_network("AlexNet")})
    assert np.isfinite(energy).all() and np.isfinite(latency).all()
