"""End-to-end behaviour tests: training loop (loss ↓, FT recovery replays
exactly) and the batched serving engine."""

import numpy as np
import pytest

from repro.launch import train as T

pytestmark = pytest.mark.slow      # full-model end-to-end runs


def test_training_loss_decreases(tmp_path):
    losses = T.main(["--arch", "qwen2-0.5b", "--smoke", "--steps", "30",
                     "--seq-len", "64", "--global-batch", "4",
                     "--ckpt-dir", str(tmp_path / "ck")])
    assert losses[-1] < 0.5 * losses[0]


def test_training_failure_recovery_is_exact(tmp_path):
    """A run with an injected worker failure must land on the same losses
    as a clean run (checkpoint/restore + deterministic data replay)."""
    common = ["--arch", "stablelm-1.6b", "--smoke", "--steps", "24",
              "--seq-len", "32", "--global-batch", "4",
              "--ckpt-every", "8"]
    clean = T.main(common + ["--ckpt-dir", str(tmp_path / "a")])
    faulty = T.main(common + ["--ckpt-dir", str(tmp_path / "b"),
                              "--inject-failure-at", "13"])
    # the faulty run replays steps 8..13; its final recorded losses match
    assert faulty[-1] == pytest.approx(clean[-1], rel=1e-4)


def test_serving_engine_continuous_batching():
    import jax
    from repro.configs import get_config
    from repro.models import model_zoo as Z
    from repro.serving import ServeEngine
    from repro.serving.engine import Request

    cfg = get_config("qwen2-0.5b").smoke()
    params = Z.init(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=64)
    for rid in range(4):                     # 4 requests > 2 slots
        eng.submit(Request(rid=rid,
                           prompt=np.array([1, 2, 3 + rid]),
                           max_new_tokens=4))
    done = eng.run_until_drained(max_steps=60)
    assert done.drained is True
    assert len(done) == 4
    for r in done:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)

    # truncation is reported, not silent: one step can't finish a request
    eng.submit(Request(rid=99, prompt=np.array([1, 2]), max_new_tokens=4))
    partial = eng.run_until_drained(max_steps=1)
    assert partial.drained is False
    assert eng.run_until_drained(max_steps=60).drained is True
