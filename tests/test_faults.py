"""Fault-injection suite: detection, recovery, degradation.

Proves the three robustness claims with the deterministic
:class:`repro.ft.faults.FaultPlan` harness:

* **detection** — NaN/inf-corrupted chunk outputs raise
  :class:`repro.core.energymodel.ChunkCorruption` with chunk provenance
  BEFORE the fold commits (the running state is never poisoned);
* **recovery** — a corrupted/killed stream resumed from its last exported
  fold state finishes bit-exactly;
* **degradation** — a :class:`repro.serving.dse_service.DSEService` under
  a seeded random fault plan + queue overflow never hangs or crashes:
  every accepted query gets exactly one answer (exact or degraded), every
  overflow submit gets a reject-with-retry-after.

The CI chaos job replays this file over a fixed seed matrix via
``REPRO_CHAOS_SEEDS`` (comma-separated; default "0,1,2")."""

import os

import numpy as np
import pytest

from repro.core import energymodel, topology
from repro.core.accelerator import ConfigGrid
from repro.ft.faults import (BackendFault, FaultPlan, StreamKill,
                             inject_chunk_faults)
from repro.ft.verify import ShadowMismatchError, StreamVerifier
from repro.serving.dse_service import DSEService

SEEDS = tuple(int(s) for s in
              os.environ.get("REPRO_CHAOS_SEEDS", "0,1,2").split(","))
NETS = ("AlexNet", "MobileNet")


@pytest.fixture(scope="module")
def networks():
    return {n: topology.get_network(n) for n in NETS}


@pytest.fixture(scope="module")
def grid():
    return ConfigGrid.product(arrays=((16, 16), (32, 32), (64, 64)),
                              gb_psum_kb=(13, 54, 216),
                              gb_ifmap_kb=(27, 108))


def _stream(grid, networks, **kw):
    kw.setdefault("backend", "numpy")
    return energymodel.stream_layer_topk(
        grid, networks, topk=4, bound=0.05, chunk_size=5, **kw)


# -- detection -------------------------------------------------------------

@pytest.mark.parametrize("kind", ("nan", "inf"))
@pytest.mark.parametrize("backend", ("numpy", "jax"))
def test_corruption_detected_with_provenance(grid, networks, kind,
                                             backend):
    plan = FaultPlan(corrupt_at={2: kind}, seed=5)
    with inject_chunk_faults(plan):
        with pytest.raises(energymodel.ChunkCorruption) as ei:
            _stream(grid, networks, backend=backend)
    err = ei.value
    assert err.chunk == 2
    assert (err.start, err.stop) == (10, 15)      # chunk 2 of size 5
    assert err.networks and set(err.networks) <= set(NETS)
    assert "chunk 2" in str(err) and "10:15" in str(err)
    assert plan.fired == [(2, kind)]


def test_corrupted_chunk_never_poisons_state(grid, networks):
    """The guard fires before the fold: resuming PAST the corruption from
    the last good checkpoint is bit-identical to a clean run."""
    ref = _stream(grid, networks)
    states = []
    with inject_chunk_faults(FaultPlan(corrupt_at={2: "nan"}, seed=7)):
        with pytest.raises(energymodel.ChunkCorruption):
            _stream(grid, networks, on_chunk=states.append)
    assert len(states) == 2                       # chunks 0,1 committed
    res = _stream(grid, networks, resume_from=states[-1])
    np.testing.assert_array_equal(res.topk_idx, ref.topk_idx)
    np.testing.assert_array_equal(res.topk_metric, ref.topk_metric)
    np.testing.assert_array_equal(res.argmin, ref.argmin)
    for nm in NETS:
        np.testing.assert_array_equal(res.boundary_idx[nm],
                                      ref.boundary_idx[nm])


def test_nan_guard_opt_out(grid, networks):
    """nan_guard=False documents the escape hatch: the stream completes,
    silently — a NaN row loses every (value, index) comparison, so the
    corrupted config simply vanishes from the reductions, which is
    exactly the silent-garbage mode the default guard exists to stop."""
    with inject_chunk_faults(FaultPlan(corrupt_at={0: "nan"}, seed=1)):
        res = _stream(grid, networks, nan_guard=False)
    assert isinstance(res, energymodel.LayerTopK)
    assert np.isfinite(res.min_metric).all()


def test_backend_fault_and_kill_raise(grid, networks):
    with inject_chunk_faults(FaultPlan(fail_at={1: 1})):
        with pytest.raises(BackendFault):
            _stream(grid, networks)
    with inject_chunk_faults(FaultPlan(kill_at=1)):
        with pytest.raises(StreamKill):
            _stream(grid, networks)


def test_fault_plan_is_deterministic():
    a = FaultPlan.random(3, 20)
    b = FaultPlan.random(3, 20)
    assert (a.fail_at, a.corrupt_at, a.target) == \
        (b.fail_at, b.corrupt_at, b.target)
    assert FaultPlan.random(4, 20).fail_at != a.fail_at or \
        FaultPlan.random(4, 20).corrupt_at != a.corrupt_at


@pytest.mark.parametrize("kind", ("nan", "inf"))
def test_latency_corruption_detected(grid, networks, kind):
    """target="t" corrupts the LATENCY tensor — the guard checks both
    tensors, so detection and provenance are identical to the energy
    side."""
    plan = FaultPlan(corrupt_at={2: kind}, seed=5, target="t")
    with inject_chunk_faults(plan):
        with pytest.raises(energymodel.ChunkCorruption) as ei:
            _stream(grid, networks)
    assert ei.value.chunk == 2
    assert plan.fired == [(2, kind)]


def test_corruption_target_validated_and_seeded():
    with pytest.raises(ValueError, match="'e' or 't'"):
        FaultPlan(target="x")
    # the seeded coin flip lands on both tensors across the seed range,
    # so the chaos matrix exercises the latency-side guard path too
    targets = {FaultPlan.random(s, 20).target for s in range(16)}
    assert targets == {"e", "t"}


def test_corruption_mutates_only_the_chosen_tensor():
    e = np.ones((4, 3))
    t = np.ones((4, 3))
    plan = FaultPlan(corrupt_at={0: "nan"}, seed=9, target="t")
    e2, t2 = plan(0, e, t)
    assert np.isfinite(np.asarray(e2)).all()
    assert np.isnan(np.asarray(t2)).sum() == 1
    assert np.isfinite(t).all()            # input never mutated in place


# -- finite (silent) corruption: only the verifier can see it --------------

@pytest.mark.parametrize("seed", SEEDS)
def test_finite_corruption_detection_rate_is_one(grid, networks, seed):
    """The seeded finite-corruption matrix: EVERY perturbed chunk — both
    targets, every chunk index, padded last chunk included — raises
    ShadowMismatchError with chunk provenance, and the service-style
    resume-retry recovers an answer bit-identical to the clean run."""
    ref = _stream(grid, networks)
    n_chunks = -(-grid.n // 5)
    for target in ("e", "t"):
        for ci in range(n_chunks):
            plan = FaultPlan(perturb_at={ci: 1e-3}, seed=seed,
                             target=target)
            states = []
            with inject_chunk_faults(plan):
                with pytest.raises(ShadowMismatchError) as ei:
                    _stream(grid, networks,
                            verify=StreamVerifier(verify_fraction=1.0),
                            on_chunk=states.append)
                # the poisoned chunk never committed; the retry re-runs
                # it (perturb_at pops once) from the last good state
                res = _stream(
                    grid, networks,
                    verify=StreamVerifier(verify_fraction=1.0),
                    resume_from=states[-1] if states else None)
            err = ei.value
            assert err.chunk == ci
            assert (err.start, err.stop) == (5 * ci, min(5 * ci + 5,
                                                         grid.n))
            assert err.mismatches and \
                err.mismatches[0]["network"] in NETS
            assert plan.fired == [(ci, "perturb")]
            assert len(states) == ci      # exactly the chunks before it
            np.testing.assert_array_equal(res.topk_idx, ref.topk_idx)
            np.testing.assert_array_equal(res.topk_metric,
                                          ref.topk_metric)
            np.testing.assert_array_equal(res.argmin, ref.argmin)


def test_finite_corruption_silent_without_verification(grid, networks,
                                                       tmp_path):
    """DOCUMENTED FAILURE MODE: with verification off, a finite
    perturbation sails through the NaN/inf guard, the WRONG answer is
    served, and the durable store caches it behind a VALID checksum —
    then a later scrub() catches, quarantines, and recomputes it.
    (seed=0, chunk=2 is a combination whose perturbed element lands in
    a served top-k row; see the detection-rate test for the proof that
    verification catches every such combination.)"""
    clean_svc = DSEService(grid, networks, chunk_size=5, verify=False)
    clean_svc.submit("best_config")
    clean_svc.run_until_drained(max_steps=10)
    ref = clean_svc._streams[("exact", "edp")]
    svc = DSEService(grid, networks, chunk_size=5, verify=False,
                     scrub_rows=999, state_dir=tmp_path)
    with inject_chunk_faults(FaultPlan(perturb_at={2: 1e-3}, seed=0)):
        svc.submit("best_config")
        (r,), drained = svc.run_until_drained(max_steps=10)
    assert drained and r.ok and not r.degraded
    poisoned = svc._streams[("exact", "edp")]
    assert poisoned.topk_metric.shape == ref.topk_metric.shape
    assert not np.array_equal(poisoned.topk_metric, ref.topk_metric)
    assert svc.health()["shadow_checks"] == 0      # nothing was watching
    # the store serves the poisoned entry back — its checksum is VALID
    # (it protects the write, not the data that went into it)
    got = svc.store.get(svc._stream_key("exact", "edp"))
    assert got is not None
    assert not np.array_equal(got[0]["topk_metric"], ref.topk_metric)
    # the scrubber is the backstop: quarantine + recompute
    res = svc.scrub()
    assert res["bad"] == 1 and res["recomputed"] == 1
    clean = svc._streams[("exact", "edp")]
    np.testing.assert_array_equal(clean.topk_metric, ref.topk_metric)
    assert svc.health()["scrubbed_bad"] == 1
    svc.close()


@pytest.mark.parametrize("seed", SEEDS)
def test_service_recovers_from_finite_corruption(grid, networks, seed):
    """Verification-on service under a perturb-only plan: detection
    counters tick, the retry ladder recomputes, and every answer equals
    the clean service's bit-for-bit (pop-once perturbations retry on the
    same backend, so no cross-backend tolerance is needed)."""
    def ask(svc):
        svc.submit("best_config")
        svc.submit("best_chip", deadline=2.0)
        svc.submit("pareto", network=list(networks)[0], deadline=3.0)
        out, drained = svc.run_until_drained(max_steps=50)
        assert drained
        return {r.rid: r for r in out}

    clean = ask(DSEService(grid, networks, chunk_size=5, verify=False))
    svc = DSEService(grid, networks, chunk_size=5, verify_fraction=1.0,
                     max_retries=30, backoff_s=1e-4)
    n_chunks = -(-grid.n // 5)
    plan = FaultPlan.random(seed, n_chunks, p_fail=0.0, p_corrupt=0.0,
                            p_perturb=0.5)
    with inject_chunk_faults(plan):
        chaotic = ask(svc)
    h = svc.health()
    n_perturbed = sum(1 for _, k in plan.fired if k == "perturb")
    assert h["shadow_mismatches"] == n_perturbed
    assert h["faults"] >= n_perturbed     # each detection surfaced
    for rid, r in chaotic.items():
        assert r.ok and not r.degraded
        assert repr(r.answer) == repr(clean[rid].answer)


def test_random_plan_perturb_knob_and_backcompat():
    a = FaultPlan.random(5, 20, p_perturb=0.4)
    b = FaultPlan.random(5, 20, p_perturb=0.4)
    assert a.perturb_at == b.perturb_at and a.perturb_at
    assert not (set(a.perturb_at) & set(a.corrupt_at))
    # p_perturb draws come AFTER the legacy ones: plans built without
    # the knob are bit-identical to pre-knob plans
    old = FaultPlan.random(5, 20)
    assert (old.fail_at, old.corrupt_at, old.target) == \
        (a.fail_at, a.corrupt_at, a.target)
    assert old.perturb_at == {}


def test_perturb_mutates_one_nonzero_element():
    e = np.zeros((3, 2, 4))
    e[:, :, :2] = 7.0                     # layer tail zero-padded
    t = np.full((3, 2, 4), 3.0)
    plan = FaultPlan(perturb_at={0: 1e-3}, seed=11)
    e2, t2 = plan(0, e, t)
    assert np.array_equal(t2, t)
    changed = np.asarray(e2) != e
    assert changed.sum() == 1
    assert e[changed][0] != 0.0           # never a padding zero
    assert np.isclose(np.asarray(e2)[changed][0],
                      e[changed][0] * 1.001)
    assert plan.fired == [(0, "perturb")]
    assert np.all(e[:, :, 2:] == 0.0)     # input untouched


# -- degradation: the service stays live under chaos ----------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_service_survives_chaos(grid, networks, seed):
    """Seeded fault plan + queue overflow: the service must answer every
    accepted query (exact or degraded) and reject the rest with a
    retry-after — never hang, never crash, never drop a request."""
    svc = DSEService(grid, networks, max_queue=5, chunk_size=5,
                     degrade_stride=4, max_retries=30, backoff_s=1e-4)
    n_chunks = -(-grid.n // 5)
    plan = FaultPlan.random(seed, n_chunks, p_fail=0.3, p_corrupt=0.2)
    plan.kill_at = n_chunks // 2
    rng = np.random.default_rng(seed)
    names = list(networks)
    accepted, rejected = [], 0
    with inject_chunk_faults(plan):
        for _ in range(8):
            kind = ("best_config", "best_chip",
                    "pareto")[int(rng.integers(3))]
            sub = svc.submit(
                kind,
                network=(names[int(rng.integers(len(names)))]
                         if kind != "best_config" else None),
                deadline=float(rng.choice([1.5, 2.0, 3.0])))
            if sub.accepted:
                accepted.append(sub.rid)
            else:
                rejected += 1
                assert sub.retry_after_s is not None
                assert sub.retry_after_s > 0
        responses, drained = svc.run_until_drained(max_steps=100)
    assert drained
    assert sorted(r.rid for r in responses) == sorted(accepted)
    assert all(r.ok for r in responses)
    h = svc.health()
    assert h["completed"] == len(accepted)
    assert h["rejected"] == rejected == 8 - len(accepted)
    assert h["queue_depth"] == 0


@pytest.mark.parametrize("seed", SEEDS)
def test_service_chaos_answers_match_clean_run(grid, networks, seed):
    """Non-degraded chaos answers equal the fault-free service's answers
    (recovery is exact, not merely 'an' answer)."""
    def ask(svc):
        svc.submit("best_config")
        svc.submit("best_chip", deadline=2.0)
        # loose deadlines leave real slack, so these answers carry the
        # energy-aware slack block (moves, energy_saved_pct) and the
        # pareto answer its slack_frontier — chaos recovery must
        # reproduce the slack-scheduled numbers too, not just the
        # latency-only ones
        svc.submit("best_chip", network=list(networks)[0], deadline=4.0)
        svc.submit("pareto", network=list(networks)[0], deadline=3.0)
        out, drained = svc.run_until_drained(max_steps=50)
        assert drained
        for r in out:
            if r.ok and not r.degraded and "slack" in (r.answer or {}):
                assert r.answer["slack"]["score"] <= \
                    r.answer["score"] * (1.0 + 1e-9)
        return {r.rid: r for r in out}

    def close(a, b):
        # answers survive a mid-flight backend fallback, so floats agree
        # to the repo's cross-backend parity (1e-6 rel), ints exactly
        if isinstance(a, dict):
            return a.keys() == b.keys() and all(close(a[k], b[k])
                                                for k in a)
        if isinstance(a, (list, tuple)):
            return len(a) == len(b) and all(close(x, y)
                                            for x, y in zip(a, b))
        if isinstance(a, float):
            return bool(np.isclose(a, b, rtol=1e-6))
        return a == b

    clean = ask(DSEService(grid, networks, chunk_size=5))
    svc = DSEService(grid, networks, chunk_size=5, max_retries=30,
                     backoff_s=1e-4)
    plan = FaultPlan.random(seed, -(-grid.n // 5), p_fail=0.3,
                            p_corrupt=0.2)
    with inject_chunk_faults(plan):
        chaotic = ask(svc)
    for rid, r in chaotic.items():
        assert r.ok
        if not r.degraded:
            assert close(r.answer, clean[rid].answer), \
                (r.answer, clean[rid].answer)
