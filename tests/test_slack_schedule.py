"""Energy-aware deadline-slack scheduler
(`partition.batch_slack_schedule` / `partition.slack_schedule_oracle`):
bit-exactness of the batched path against the scalar oracle on seeded
tie-heavy instances (both backends), the three slack laws — (a) the
slack schedule weakly dominates the latency-only one, (b) every emitted
schedule meets its deadline, (c) deadline=inf reproduces the pure
energy argmin and deadline=bottleneck reproduces the base schedule
bit-for-bit — plus input-validation and broadcast/scenario-axis edges."""

import numpy as np
import pytest

from repro.core import partition

# Guarded per-test (not module-level importorskip) so the deterministic
# seeded twins below always run.
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    _HAS_HYPOTHESIS = False

    def _skip_property(f):
        return pytest.mark.skip(
            reason="property test needs hypothesis "
            "(pip install -r requirements-dev.txt)")(f)

from oracles import assert_schedule_valid, seeded_slack_instances


def _pad_counts(cnts):
    """Zero-pad ragged per-problem counts to a rectangular [B, T_max]
    array (zero-count padding slots are legal)."""
    t_max = max(c.shape[0] for c in cnts)
    out = np.zeros((len(cnts), t_max), dtype=np.int64)
    for i, c in enumerate(cnts):
        out[i, :c.shape[0]] = c
    return out


def _deadline_grid(t_star):
    """The interesting deadline neighbourhood of the latency-optimal
    bottleneck T*: infeasible, exact, one-ulp slack, loose, infinite."""
    return (0.5 * t_star, t_star, t_star * (1.0 + 1e-12),
            1.5 * t_star, 3.0 * t_star, np.inf)


def _energy_argmin_energy(lat, en, counts):
    """Sequential sum of each layer's cheapest AVAILABLE energy — what
    deadline=inf must reproduce."""
    avail = np.asarray(counts) > 0
    te = np.argmin(np.where(avail[:, None], en, np.inf), axis=0)
    eng = 0.0
    for l in range(lat.shape[1]):
        eng += en[te[l], l]
    return eng


def _check_cell(lat, en, counts, deadline, res, d):
    """One (instance, deadline) cell of a batch result vs the scalar
    oracle — bit-exact fields, dominance, deadline-met, validity."""
    want = partition.slack_schedule_oracle(lat, en, counts, deadline)
    base = partition.schedule_hetero_oracle(lat, counts)
    n_l = lat.shape[1]
    assert res.bottleneck[0, d] == want["bottleneck"]
    assert res.energy[0, d] == want["energy"]
    assert res.n_moves[0, d] == want["n_moves"]
    assert bool(res.feasible[0, d]) == want["feasible"]
    np.testing.assert_array_equal(res.layer_type[0, d, :n_l],
                                  want["layer_type"])
    # (a) weak dominance: never worse than the latency-only schedule on
    # either axis (rtol: sequential vs per-type sums differ in ulps)
    base_energy = partition.slack_schedule_oracle(
        lat, en, counts, base["bottleneck"])["energy"]
    assert want["energy"] <= base_energy * (1.0 + 1e-9)
    assert want["bottleneck"] <= max(deadline, base["bottleneck"])
    if want["feasible"]:
        # (b) the deadline is met AT BIT LEVEL and the extracted
        # schedule is internally consistent
        assert res.bottleneck[0, d] <= deadline
        assert_schedule_valid(res.schedule(0, d), lat, counts)
    else:
        with pytest.raises(ValueError, match="infeasible"):
            res.schedule(0, d)


def test_oracle_matches_batch_seeded():
    """Non-hypothesis twin (always runs): 60 seeded tie-heavy instances
    x 6 deadlines, oracle == numpy == jit on every field."""
    for lat, en, counts in seeded_slack_instances(2024, 60):
        t_star = partition.schedule_hetero_oracle(lat, counts)[
            "bottleneck"]
        dls = np.array(_deadline_grid(t_star))
        res_np = partition.batch_slack_schedule([lat], [en], [counts],
                                                dls, use_jax=False)
        res_jx = partition.batch_slack_schedule([lat], [en], [counts],
                                                dls, use_jax=True)
        for d, deadline in enumerate(dls):
            _check_cell(lat, en, counts, deadline, res_np, d)
        for f in ("bottleneck", "energy", "n_moves", "layer_type",
                  "feasible", "total"):
            np.testing.assert_array_equal(
                getattr(res_np, f), getattr(res_jx, f), err_msg=f)


def test_inf_deadline_is_pure_energy_argmin_seeded():
    """(c1) deadline=inf: every candidate move is accepted, so the total
    energy equals the per-layer energy-argmin lower bound."""
    for lat, en, counts in seeded_slack_instances(77, 40):
        want = _energy_argmin_energy(lat, en, counts)
        res = partition.batch_slack_schedule([lat], [en], [counts],
                                             np.inf, use_jax=False)
        assert res.energy[0, 0] == pytest.approx(want, rel=1e-12)
        got = partition.slack_schedule_oracle(lat, en, counts, np.inf)
        assert got["energy"] == pytest.approx(want, rel=1e-12)


def test_deadline_at_bottleneck_reproduces_base_bitwise_seeded():
    """(c2) deadline == T* leaves zero slack: the slack result carries
    the latency-argmin base schedule bit-for-bit."""
    for lat, en, counts in seeded_slack_instances(5, 40):
        base = partition.batch_schedule_hetero([lat], [counts],
                                               use_jax=False)
        t_star = float(base.bottleneck[0])
        res = partition.batch_slack_schedule([lat], [en], [counts],
                                             t_star, use_jax=False,
                                             base=base)
        n_l = lat.shape[1]
        assert res.n_moves[0, 0] == 0
        assert res.bottleneck[0, 0] == t_star
        assert res.total[0, 0] == base.total[0]
        assert bool(res.feasible[0, 0])
        np.testing.assert_array_equal(res.layer_type[0, 0, :n_l],
                                      base.layer_type[0, :n_l])
        s_slack = res.schedule(0, 0)
        s_base = base.schedule(0)
        assert s_slack.layer_core == s_base.layer_core
        assert s_slack.loads == s_base.loads


if _HAS_HYPOTHESIS:
    _vals = st.sampled_from([0.5, 1.0, 1.5, 2.0, 3.0])
    _matrix = st.integers(1, 3).flatmap(
        lambda t: st.integers(1, 8).flatmap(
            lambda n: st.lists(
                st.lists(_vals, min_size=n, max_size=n),
                min_size=t, max_size=t)))

    def _slack_property(f):
        return settings(max_examples=120, deadline=None)(
            given(_matrix, _matrix, st.data())(f))
else:                                                  # pragma: no cover
    _slack_property = _skip_property


@_slack_property
def test_slack_laws_property(lat, en, data):
    """Random tie-heavy instances (exact ties constantly): oracle/batch
    bit-exactness + all three slack laws on a drawn deadline."""
    lat = np.asarray(lat)
    en = np.asarray(en)
    if en.shape != lat.shape:
        en = np.resize(en, lat.shape)
    counts = np.asarray([data.draw(st.integers(0, 3))
                         for _ in range(lat.shape[0])])
    if counts.sum() == 0:
        counts[0] = 1
    t_star = partition.schedule_hetero_oracle(lat, counts)["bottleneck"]
    factor = data.draw(st.sampled_from(
        [0.5, 1.0, 1.0 + 1e-12, 1.25, 2.0, np.inf]), label="factor")
    deadline = t_star * factor if np.isfinite(factor) else np.inf
    use_jax = data.draw(st.booleans(), label="use_jax")
    res = partition.batch_slack_schedule(
        [lat], [en], [counts], np.array([deadline]), use_jax=use_jax)
    _check_cell(lat, en, counts, deadline, res, 0)
    if not np.isfinite(deadline):
        assert res.energy[0, 0] == pytest.approx(
            _energy_argmin_energy(lat, en, counts), rel=1e-12)
    if factor == 1.0:
        assert res.n_moves[0, 0] == 0
        assert res.bottleneck[0, 0] == t_star


# ---------------------------------------------------------------------------
# Broadcast / scenario-axis / validation edges
# ---------------------------------------------------------------------------


def test_deadline_broadcast_shapes_agree():
    """Scalar, [D] and [B, D] deadline inputs give identical cells."""
    insts = seeded_slack_instances(11, 3)
    lats = [i[0] for i in insts]
    ens = [i[1] for i in insts]
    cnts = _pad_counts([i[2] for i in insts])
    dls = np.array([1.0, 4.0, np.inf])
    shared = partition.batch_slack_schedule(lats, ens, cnts, dls,
                                            use_jax=False)
    per_prob = partition.batch_slack_schedule(
        lats, ens, cnts, np.tile(dls, (3, 1)), use_jax=False)
    np.testing.assert_array_equal(shared.energy, per_prob.energy)
    np.testing.assert_array_equal(shared.layer_type, per_prob.layer_type)
    scalar = partition.batch_slack_schedule(lats, ens, cnts, 4.0,
                                            use_jax=False)
    np.testing.assert_array_equal(scalar.energy[:, 0], shared.energy[:, 1])


def test_scenario_axis_matches_flattened():
    """[B, S, T, L] input == the same problems pre-flattened to
    [B*S, T, L] (scenario-minor), exactly like batch_schedule_hetero."""
    rng = np.random.default_rng(42)
    B, S, T, L = 2, 3, 2, 5
    lat4 = rng.uniform(0.1, 5.0, size=(B, S, T, L))
    en4 = rng.uniform(0.1, 5.0, size=(B, S, T, L))
    cnts = rng.integers(1, 3, size=(B, T))
    dl = np.array([[3.0], [8.0]])
    r4 = partition.batch_slack_schedule(
        lat4, en4, cnts, np.repeat(dl, S, axis=0).reshape(B * S, 1),
        use_jax=False)
    r3 = partition.batch_slack_schedule(
        lat4.reshape(B * S, T, L), en4.reshape(B * S, T, L),
        np.repeat(cnts, S, axis=0),
        np.repeat(dl, S, axis=0).reshape(B * S, 1), use_jax=False)
    for f in ("bottleneck", "energy", "n_moves", "layer_type",
              "feasible"):
        np.testing.assert_array_equal(getattr(r4, f), getattr(r3, f),
                                      err_msg=f)


def test_base_reuse_is_bit_identical():
    """Passing a pre-solved base in reproduces the fresh solve exactly
    (the DSE service reuses its latency-only result this way)."""
    insts = seeded_slack_instances(9, 4)
    lats = [i[0] for i in insts]
    ens = [i[1] for i in insts]
    cnts = _pad_counts([i[2] for i in insts])
    dls = np.array([2.0, np.inf])
    fresh = partition.batch_slack_schedule(lats, ens, cnts, dls,
                                           use_jax=False)
    base = partition.batch_schedule_hetero(lats, cnts, use_jax=False)
    reused = partition.batch_slack_schedule(lats, ens, cnts, dls,
                                            use_jax=False, base=base)
    for f in ("bottleneck", "energy", "n_moves", "layer_type",
              "feasible", "total"):
        np.testing.assert_array_equal(getattr(fresh, f),
                                      getattr(reused, f), err_msg=f)


def test_strict_false_infeasible_label_and_errors():
    lat = np.array([[1.0, 2.0]])
    en = np.array([[1.0, 1.0]])
    res = partition.batch_slack_schedule(
        [lat, lat], [en, en], [[1], [0]], 10.0, use_jax=False,
        strict=False, labels=("ok", "dead-chip"))
    assert bool(res.feasible[0, 0]) and not bool(res.feasible[1, 0])
    assert np.isinf(res.bottleneck[1, 0])
    assert_schedule_valid(res.schedule(0, 0), lat, [1])
    with pytest.raises(ValueError, match="dead-chip"):
        res.schedule(1, 0)
    # strict=True (default) raises on the all-zero-counts problem
    with pytest.raises(ValueError):
        partition.batch_slack_schedule([lat], [en], [[0]], 10.0)


def test_input_validation():
    lat = np.array([[1.0, 2.0]])
    en_bad = np.array([[1.0, 2.0, 3.0]])
    with pytest.raises(ValueError, match="energies"):
        partition.batch_slack_schedule([lat], [en_bad], [[1]], 1.0)
    with pytest.raises(ValueError, match="energies"):
        partition.slack_schedule_oracle(lat, en_bad, [1], 1.0)
    with pytest.raises(ValueError):
        partition.batch_slack_schedule([lat], [lat, lat], [[1]], 1.0)
    # ghost type: a positive count for a type slot with no latency row
    with pytest.raises(ValueError):
        partition.batch_slack_schedule([lat], [lat], [[1, 1]], 1.0)
    # deadlines shape must broadcast
    with pytest.raises(ValueError):
        partition.batch_slack_schedule([lat], [lat], [[1]],
                                       np.ones((3, 2)))
    assert len(partition.batch_slack_schedule([], [], [], 1.0)) == 0
