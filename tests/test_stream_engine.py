"""Device-sharded, chunked streaming DSE engine: chunk/shard/stream parity
with the one-call engine, streaming chip design equivalence, and the
batched (networks × cores) partition solver vs the DP oracle."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (accelerator, dse, energymodel, hetero, partition,
                        topology)

NETS = ("AlexNet", "VGG16", "MobileNet")


@pytest.fixture(scope="module")
def networks():
    return {n: topology.get_network(n) for n in NETS}


@pytest.fixture(scope="module")
def grid150():
    return accelerator.ConfigGrid.product()


@pytest.fixture(scope="module")
def full150(networks, grid150):
    return energymodel.evaluate_networks(grid150, networks, use_jax=False)


# ---------------------------------------------------------------------------
# chunked evaluation
# ---------------------------------------------------------------------------

def test_chunked_matches_one_call_numpy(networks, grid150, full150):
    """Per-chunk dedup + bucket padding is invisible: bit-identical to the
    unchunked numpy engine (same per-row arithmetic)."""
    e0, t0 = full150
    for chunk in (32, 64, 150):
        e1, t1 = energymodel.evaluate_networks(
            grid150, networks, use_jax=False, chunk_size=chunk)
        np.testing.assert_allclose(e1, e0, rtol=1e-12)
        np.testing.assert_allclose(t1, t0, rtol=1e-12)


def test_chunked_matches_one_call_jax(networks, grid150, full150):
    e0, t0 = full150
    e1, t1 = energymodel.evaluate_networks(grid150, networks, use_jax=True,
                                           chunk_size=64)
    np.testing.assert_allclose(e1, e0, rtol=1e-12)
    np.testing.assert_allclose(t1, t0, rtol=1e-12)


def test_grid_take_and_slice(grid150):
    idx = np.array([3, 17, 149, 0])
    sub = grid150.take(idx)
    assert sub.n == 4
    for k, v in sub.fields.items():
        np.testing.assert_array_equal(v, grid150.fields[k][idx])
    sl = grid150.slice_rows(10, 20)
    assert sl.n == 10
    assert sl.config_at(0).label() == grid150.config_at(10).label()


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------

def test_sharded_matches_unsharded(networks, grid150, full150):
    """shard=True must agree with the numpy reference for any device count
    (a 1-device mesh degenerates to the plain kernel)."""
    e0, t0 = full150
    e1, t1 = energymodel.evaluate_networks(grid150, networks, use_jax=True,
                                           shard=True)
    np.testing.assert_allclose(e1, e0, rtol=1e-12)
    np.testing.assert_allclose(t1, t0, rtol=1e-12)
    e2, t2 = energymodel.evaluate_networks(grid150, networks, use_jax=True,
                                           shard=True, chunk_size=64)
    np.testing.assert_allclose(e2, e0, rtol=1e-12)
    np.testing.assert_allclose(t2, t0, rtol=1e-12)


@pytest.mark.slow
def test_sharded_multi_device_subprocess():
    """Real multi-device parity: a fresh process forced to 4 host devices
    must reproduce the numpy reference through both sharded paths."""
    script = textwrap.dedent("""
        import numpy as np
        from repro.core import accelerator, energymodel, topology
        import jax
        assert len(jax.devices()) == 4, jax.devices()
        nets = {n: topology.get_network(n) for n in ("AlexNet", "VGG16")}
        grid = accelerator.ConfigGrid.product(
            rf_psum_words=accelerator.RF_PSUM_SIZES)
        e0, t0 = energymodel.evaluate_networks(grid, nets, use_jax=False)
        e1, t1 = energymodel.evaluate_networks(grid, nets, use_jax=True,
                                               shard=True)
        np.testing.assert_allclose(e1, e0, rtol=1e-9)
        np.testing.assert_allclose(t1, t0, rtol=1e-9)
        e2, t2 = energymodel.evaluate_networks(grid, nets, use_jax=True,
                                               shard=True, chunk_size=128)
        np.testing.assert_allclose(e2, e0, rtol=1e-9)
        if energymodel.pallas_available():
            # fused-kernel shard_map path: all 14 terms all-gather
            e3, t3 = energymodel.evaluate_networks(grid, nets,
                                                   backend="pallas",
                                                   shard=True)
            np.testing.assert_allclose(e3, e0, rtol=1e-9)
            np.testing.assert_allclose(t3, t0, rtol=1e-9)
        sr = energymodel.stream_networks(grid, nets, chunk_size=128,
                                         use_jax=True, shard=True)
        edp = e0 * t0
        np.testing.assert_allclose(sr.min_metric, edp.min(0), rtol=1e-9)
        assert np.array_equal(sr.argmin, edp.argmin(0))
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = (os.path.abspath("src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_request_host_devices_after_jax_import():
    """jax is already initialised in-process: the helper must refuse (the
    flag can no longer take effect) and leave XLA_FLAGS untouched."""
    import jax                                          # noqa: F401
    before = os.environ.get("XLA_FLAGS")
    assert energymodel.request_host_devices(4) is False
    assert os.environ.get("XLA_FLAGS") == before


# ---------------------------------------------------------------------------
# streaming reductions
# ---------------------------------------------------------------------------

def _check_stream_against_full(sr, e0, t0, metric="edp", bound=0.05):
    val = energymodel._metric_of(metric, e0, t0)
    np.testing.assert_allclose(sr.min_energy, e0.min(0), rtol=1e-12)
    np.testing.assert_allclose(sr.min_latency, t0.min(0), rtol=1e-12)
    np.testing.assert_allclose(sr.min_metric, val.min(0), rtol=1e-12)
    assert np.array_equal(sr.argmin, val.argmin(0))
    for j, nm in enumerate(sr.networks):
        mn = val[:, j].min()
        want = np.flatnonzero(val[:, j] <= mn * (1.0 + bound))
        assert np.array_equal(np.sort(sr.boundary_idx[nm]), want)
        # boundary arrays are metric-sorted, best cell first
        bm = sr.boundary_metric(nm)
        assert np.all(np.diff(bm) >= 0)
        assert sr.boundary_idx[nm][0] == val[:, j].argmin()
        # top-k values equal the k smallest of the full column
        k = sr.topk_metric.shape[0]
        want_top = np.sort(val[:, j])[:k]
        np.testing.assert_allclose(sr.topk_metric[:, j], want_top,
                                   rtol=1e-12)


def test_stream_matches_full_numpy(networks, grid150, full150):
    e0, t0 = full150
    sr = energymodel.stream_networks(grid150, networks, chunk_size=32,
                                     use_jax=False)
    assert sr.n_cfg == grid150.n
    _check_stream_against_full(sr, e0, t0)


def test_stream_matches_full_jax(networks, grid150, full150):
    e0, t0 = full150
    sr = energymodel.stream_networks(grid150, networks, chunk_size=64,
                                     use_jax=True)
    _check_stream_against_full(sr, e0, t0)


def test_stream_other_metric(networks, grid150, full150):
    e0, t0 = full150
    sr = energymodel.stream_networks(grid150, networks, chunk_size=64,
                                     use_jax=False, metric="energy")
    assert np.array_equal(sr.argmin, e0.argmin(0))
    np.testing.assert_allclose(sr.min_metric, e0.min(0), rtol=1e-12)


# ---------------------------------------------------------------------------
# streaming chip design ≡ full design_chip
# ---------------------------------------------------------------------------

def test_design_chip_streaming_equivalence():
    names = ("VGG16", "GoogleNet", "ResNet50", "MobileNet", "AlexNet",
             "Xception")
    nets = {n: topology.get_network(n) for n in names}
    sweeps = dse.sweep_networks(nets, use_jax=False)
    grid = accelerator.ConfigGrid.product()
    shape = next(iter(sweeps.values())).edp.shape

    for max_cores in (2, 3):
        chip = hetero.design_chip(sweeps, bound=0.05, max_cores=max_cores)
        sr = dse.stream_grid(nets, grid, chunk_size=50, use_jax=False,
                             bound=0.05)
        schip = hetero.design_chip_streaming(sr, grid, nets,
                                             max_cores=max_cores,
                                             use_jax=False)
        assert schip.core_cells(shape) == chip.core_types
        assert schip.assignment == chip.assignment
        for nm in names:
            want = [int(np.ravel_multi_index(c, shape))
                    for c in chip.candidate_sets[nm]]
            assert schip.candidate_sets[nm] == want


# ---------------------------------------------------------------------------
# batched partition solver vs the DP oracle (non-hypothesis path; the
# property test lives in test_partition.py)
# ---------------------------------------------------------------------------

def test_batch_partition_matches_dp_on_zoo():
    """All (18 networks × k∈2..8) pairs, both solver backends: pipeline
    latencies identical to dp_partition."""
    cfg = accelerator.AcceleratorConfig()
    lats = [energymodel.simulate_network(
        cfg, topology.get_network(n), n).layer_latencies
        for n in topology.NETWORKS]
    ks = tuple(range(2, 9))
    dp = [{k: partition.dp_partition(lat, k) for k in ks} for lat in lats]
    for use_jax in (False, True):
        res = partition.batch_partition(lats, ks, use_jax=use_jax)
        for i in range(len(lats)):
            for k in ks:
                got, want = res[i][k], dp[i][k]
                assert got.pipeline_latency == want.pipeline_latency, (
                    topology.NETWORKS[i], k, use_jax)
                # a valid contiguous partition of everything
                assert got.boundaries[0] == 0
                assert list(got.boundaries) == sorted(set(got.boundaries))
                assert sum(got.loads) == pytest.approx(sum(lats[i]))
                assert got.speedup == pytest.approx(
                    sum(lats[i]) / got.pipeline_latency)


def test_batch_partition_edges():
    res = partition.batch_partition([[5.0]], [1, 3], use_jax=False)[0]
    assert res[1].loads == (5.0,) and res[3].loads == (5.0,)
    res = partition.batch_partition([[1.0, 2.0, 3.0]], [2, 7])[0]
    assert res[2].pipeline_latency == pytest.approx(3.0)
    assert res[7].n_cores == 3          # clamped to n_layers
    lat = np.arange(1.0, 11.0)
    got = partition.batch_partition([lat], [4])[0][4]
    assert got.pipeline_latency == partition.dp_partition(lat, 4).pipeline_latency
