"""Batched heterogeneous layer→core schedule solver
(`partition.batch_schedule_hetero`): exactness against the scalar oracle
(per-layer argmin + per-type dp), against a BRUTE-FORCE segmentation
enumeration on small instances, schedule validity, and the degeneracy to
`batch_partition` when there is a single core type."""

import numpy as np
import pytest

from repro.core import partition

# Guarded per-test (not module-level importorskip) so the deterministic
# oracle/degeneracy tests below always run.
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    _HAS_HYPOTHESIS = False

    def _skip_property(f):
        return pytest.mark.skip(
            reason="property test needs hypothesis "
            "(pip install -r requirements-dev.txt)")(f)

# Shared differential harness (tests/oracles.py): brute-force oracle,
# schedule validity checker, seeded instance generator.
from oracles import (assert_schedule_valid, brute_force_hetero,
                     seeded_hetero_instances)


if _HAS_HYPOTHESIS:
    lat_matrix = st.integers(1, 3).flatmap(
        lambda t: st.integers(1, 8).flatmap(
            lambda n: st.lists(
                st.lists(st.floats(0.01, 100.0), min_size=n, max_size=n),
                min_size=t, max_size=t)))

    def _bruteforce_property(f):
        return settings(max_examples=150, deadline=None)(
            given(lat_matrix, st.data())(f))

    def _degeneracy_property(f):
        return settings(max_examples=50, deadline=None)(given(
            st.lists(st.lists(st.floats(0.01, 50.0), min_size=2,
                              max_size=12), min_size=1, max_size=5),
            st.integers(1, 5))(f))
else:                                                  # pragma: no cover
    _bruteforce_property = _degeneracy_property = _skip_property


@_bruteforce_property
def test_matches_bruteforce_oracle(lat, data):
    """The batched solver (both backends) lands EXACTLY on the brute-force
    optimum on every random ≤(3 types × 8 layers) instance."""
    lat = np.asarray(lat)
    counts = np.asarray([data.draw(st.integers(0, 3))
                         for _ in range(lat.shape[0])])
    if counts.sum() == 0:
        counts[0] = 1
    want = brute_force_hetero(lat, counts)
    oracle = partition.schedule_hetero_oracle(lat, counts)
    assert oracle["bottleneck"] == pytest.approx(want, rel=1e-12)
    for use_jax in (False, True):
        res = partition.batch_schedule_hetero([lat], [counts],
                                              use_jax=use_jax)
        assert res.bottleneck[0] == oracle["bottleneck"], use_jax
        assert_schedule_valid(res.schedule(0), lat, counts)


@_degeneracy_property
def test_single_type_degenerates_to_batch_partition(lat_groups, k):
    """T=1 with k cores ≡ the homogeneous batch_partition pipeline."""
    res = partition.batch_schedule_hetero(
        [np.asarray(l)[None, :] for l in lat_groups],
        [[k]] * len(lat_groups), use_jax=False)
    bp = partition.batch_partition(lat_groups, k, use_jax=False)
    for i, lat in enumerate(lat_groups):
        assert res.bottleneck[i] == bp[i][k].pipeline_latency
        assert res.speedup[i] == pytest.approx(bp[i][k].speedup)


def test_bruteforce_oracle_deterministic_seeded():
    """Non-hypothesis twin of the property test (always runs): 120 seeded
    random ≤(3 × 8) instances vs the brute-force enumeration."""
    for lat, counts in seeded_hetero_instances(123, 120):
        want = brute_force_hetero(lat, counts)
        for use_jax in (False, True):
            res = partition.batch_schedule_hetero([lat], [counts],
                                                  use_jax=use_jax)
            assert res.bottleneck[0] == pytest.approx(want, rel=1e-12)
            assert_schedule_valid(res.schedule(0), lat, counts)


def test_batched_many_problems_both_backends():
    """A mixed batch (ragged T and L, zero-count padding types) solves to
    the oracle on every problem, with identical results across backends."""
    problems = seeded_hetero_instances(7, 40, max_layers=29,
                                       lat_range=(0.01, 10.0))
    lats = [p[0] for p in problems]
    counts = np.zeros((len(problems), 3), dtype=np.int64)
    for i, (lat, cn) in enumerate(problems):
        counts[i, :cn.shape[0]] = cn
    res_np = partition.batch_schedule_hetero(lats, counts, use_jax=False)
    res_jx = partition.batch_schedule_hetero(lats, counts, use_jax=True)
    for i, (lat, cn) in enumerate(problems):
        want = partition.schedule_hetero_oracle(lat, cn)["bottleneck"]
        assert res_np.bottleneck[i] == want, i
        assert res_jx.bottleneck[i] == want, i
    w = min(res_np.layer_type.shape[1], res_jx.layer_type.shape[1])
    np.testing.assert_array_equal(res_np.layer_type[:, :w],
                                  res_jx.layer_type[:, :w])


def test_layer_argmin_assignment_and_ties():
    """Stage 1 semantics: every layer on the fastest AVAILABLE type, ties
    broken toward the lower type index."""
    lat = np.array([[2.0, 5.0, 3.0],
                    [2.0, 1.0, 9.0],
                    [9.0, 9.0, 1.0]])
    res = partition.batch_schedule_hetero([lat], [[1, 1, 1]],
                                          use_jax=False)
    assert tuple(res.schedule(0).layer_type) == (0, 1, 2)   # tie → type 0
    # type 0 unavailable: its layers move to the next-fastest type
    res = partition.batch_schedule_hetero([lat], [[0, 1, 1]],
                                          use_jax=False)
    assert tuple(res.schedule(0).layer_type) == (1, 1, 2)


def test_more_cores_than_layers_and_idle_cores():
    lat = np.array([[4.0, 6.0]])
    res = partition.batch_schedule_hetero([lat], [[5]], use_jax=False)
    s = res.schedule(0)
    assert s.n_cores == 5
    assert s.bottleneck == pytest.approx(6.0)       # one layer per core
    assert sorted(s.loads, reverse=True)[:2] == [6.0, 4.0]
    assert sum(1 for x in s.loads if x == 0.0) == 3  # idle cores are real


def test_input_validation():
    with pytest.raises(ValueError):
        partition.batch_schedule_hetero([np.zeros((1, 0))], [[1]])
    with pytest.raises(ValueError):
        partition.batch_schedule_hetero([np.ones((2, 3))], [[0, 0]])
    with pytest.raises(ValueError):
        partition.schedule_hetero_oracle(np.ones((1, 3)), [0])
    assert len(partition.batch_schedule_hetero([], [])) == 0


def test_rejects_counts_for_phantom_types():
    """A positive count for a type slot with no latency row would hand
    every layer to a phantom zero-latency type — both the oracle and the
    batch solver (list and dense inputs) must reject it; zero-count
    padding slots stay legal."""
    lat = np.array([[1.0, 2.0, 3.0]])
    with pytest.raises(ValueError):
        partition.batch_schedule_hetero([lat], [[1, 1]])
    with pytest.raises(ValueError):
        partition.schedule_hetero_oracle(lat, [1, 1])
    # ragged batch: the wide counts row only fits the 2-type problem
    with pytest.raises(ValueError):
        partition.batch_schedule_hetero(
            [lat, np.ones((2, 4))], np.array([[1, 2], [1, 1]]))
    # zero-count padding beyond the latency rows is fine
    res = partition.batch_schedule_hetero([lat], [[2, 0]])
    assert res.bottleneck[0] == 3.0
    assert partition.schedule_hetero_oracle(lat, [2, 0])["bottleneck"] \
        == 3.0


def test_large_counts_fall_back_to_numpy():
    """counts beyond the jitted unroll (_K_MAX) still solve exactly."""
    lat = np.abs(np.sin(np.arange(40.0)))[None, :] + 0.1
    res = partition.batch_schedule_hetero([lat], [[12]])
    want = partition.dp_partition(lat[0], 12).pipeline_latency
    assert res.bottleneck[0] == want
