"""Logical-axis rule resolution: divisibility fallback properties."""

import os
import subprocess
import sys

import jax
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec

from repro.launch.mesh import make_debug_mesh
from repro.parallel import shardings as S


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh()


def test_divisible_dims_get_sharded(mesh):
    spec = S.spec_for((16, 32), ("batch", "mlp"), mesh)
    # debug mesh on 1 device: axes exist but may be size 1 — still valid
    assert isinstance(spec, PartitionSpec)


def test_indivisible_dim_falls_back_to_none(mesh):
    # 'model' axis size divides 32 but not 7
    spec = S.spec_for((7,), ("mlp",), mesh)
    model = mesh.shape["model"]
    if model > 1:
        assert spec[0] is None


def test_axis_never_used_twice(mesh):
    spec = S.spec_for((32, 32), ("mlp", "mlp"), mesh)
    used = [e for e in spec if e is not None]
    flat = []
    for e in used:
        flat.extend(e if isinstance(e, tuple) else [e])
    assert len(flat) == len(set(flat))


@given(st.integers(1, 512), st.integers(1, 512))
@settings(max_examples=80, deadline=None)
def test_spec_respects_divisibility(d0, d1):
    mesh = make_debug_mesh()
    spec = S.spec_for((d0, d1), ("mlp", "embed_fsdp"), mesh)
    for dim, entry in zip((d0, d1), spec):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for n in names:
            prod *= mesh.shape[n]
        assert dim % prod == 0


def test_shard_noop_outside_context():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert S.shard(x, "batch", None) is x


def test_production_mesh_subprocess():
    """make_production_mesh builds both meshes with 512 forced devices."""
    code = (
        'import os; '
        'os.environ["XLA_FLAGS"]="--xla_force_host_platform_device_count=512"; '
        'from repro.launch.mesh import make_production_mesh; '
        'm1 = make_production_mesh(); m2 = make_production_mesh(multi_pod=True); '
        'assert m1.devices.size == 256 and m1.axis_names == ("data", "model"); '
        'assert m2.devices.size == 512 and m2.axis_names == ("pod", "data", "model"); '
        'print("MESH-OK")')
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=120,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "MESH-OK" in r.stdout, r.stdout + r.stderr
