"""Checkpoint manager + fault-tolerance supervisor behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.ft import FaultInjector, Supervisor
from repro.ft.supervisor import Preemption


def _state(x=0.0):
    return {"w": jnp.full((4, 4), x), "step": jnp.asarray(x, jnp.float32)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    st = _state(3.5)
    mgr.save(7, st, extra={"note": "hi"}, blocking=True)
    assert mgr.available() == [7]
    restored, extra = mgr.restore(st)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(st["w"]))
    assert extra["note"] == "hi"


def test_atomic_commit_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (10, 20, 30, 40):
        mgr.save(s, _state(float(s)), blocking=True)
    assert mgr.available() == [30, 40]         # keep=2
    # partial directory without COMMITTED must be invisible
    (tmp_path / "step_00000050").mkdir()
    assert mgr.latest_step() == 40


def test_elastic_restore_dtype(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.ones((8,), jnp.float32)}, blocking=True)
    target = {"w": jnp.zeros((8,), jnp.bfloat16)}
    restored, _ = mgr.restore(target)
    assert restored["w"].dtype == jnp.bfloat16


def _run(tmp_path, injector=None, steps=20, every=5):
    mgr = CheckpointManager(tmp_path, keep=10)
    sup = Supervisor(mgr, checkpoint_every=every)
    trace = []

    def step_fn(state, step):
        new = {"w": state["w"] + 1.0,
               "step": state["step"] + 1.0}
        trace.append(float(new["w"].ravel()[0]))
        return new

    final = sup.run(state=_state(0.0), step_fn=step_fn, num_steps=steps,
                    injector=injector)
    return final, trace, sup


def test_supervisor_failure_recovery(tmp_path):
    inj = FaultInjector({12: "fail"})
    final, trace, sup = _run(tmp_path / "a", inj)
    # failure at 12 → restore from checkpoint 10 → final state identical to
    # an uninterrupted run
    clean, _, _ = _run(tmp_path / "b")
    np.testing.assert_array_equal(np.asarray(final["w"]),
                                  np.asarray(clean["w"]))
    assert any(e.startswith("failure@12") for e in sup.events)
    assert any(e.startswith("restore@") for e in sup.events)


def test_supervisor_straggler_redispatch(tmp_path):
    inj = FaultInjector({15: "slow"}, slow_s=0.2)
    sup = Supervisor(CheckpointManager(tmp_path), checkpoint_every=100,
                     straggler_factor=3.0)
    def step_fn(state, step):
        if FaultInjector is not None:
            inj.check(step)
        return {"w": state["w"] + 1.0, "step": state["step"] + 1.0}
    final = sup.run(state=_state(0.0), step_fn=step_fn, num_steps=20)
    # straggler step re-dispatched; state still exact
    assert float(final["w"].ravel()[0]) == 20.0
    assert any(e.startswith("straggler@") for e in sup.events)


class FakeClock:
    """Deterministic injectable time source: every ``clock()`` call
    advances a fixed small tick, and ``sleep(s)`` advances by ``s`` —
    so straggler detection depends only on the injected plan, never on
    host timing."""

    def __init__(self, tick=0.01):
        self.now = 0.0
        self.tick = tick

    def __call__(self):
        self.now += self.tick
        return self.now

    def sleep(self, s):
        self.now += s


def _run_clocked(tmp_path, plan, *, reexecute=True, steps=20,
                 factor=3.0, slow_s=1.0):
    clk = FakeClock()
    inj = FaultInjector(plan, slow_s=slow_s, sleep=clk.sleep)
    sup = Supervisor(CheckpointManager(tmp_path), checkpoint_every=100,
                     straggler_factor=factor,
                     reexecute_stragglers=reexecute, clock=clk)

    def step_fn(state, step):
        inj.check(step)
        return {"w": state["w"] + 1.0, "step": state["step"] + 1.0}

    final = sup.run(state=_state(0.0), step_fn=step_fn, num_steps=steps)
    return final, sup


def test_straggler_detection_is_deterministic(tmp_path):
    """With the injected clock the straggler event is guaranteed (a real
    sleep raced the host scheduler): both timings recorded, state exact."""
    final, sup = _run_clocked(tmp_path, {15: "slow"})
    assert float(final["w"].ravel()[0]) == 20.0
    assert sup.stragglers and sup.stragglers[0][0] == 15
    step, dt, dt2 = sup.stragglers[0]
    assert dt > dt2                        # slow attempt vs re-execution
    assert dt == pytest.approx(1.0 + 0.01)     # sleep + one clock tick
    # the event string carries BOTH timings (slow -> re-executed)
    ev = next(e for e in sup.events if e.startswith("straggler@15"))
    assert "->" in ev and f"{dt:.3f}s" in ev and f"{dt2:.3f}s" in ev


def test_straggler_samples_excluded_from_p50_window(tmp_path):
    """A burst of stragglers must not inflate the p50 deadline they are
    measured against: with the slow samples excluded, EVERY slow step in
    the burst is detected — the old behaviour (appending them) let later
    ones hide under the poisoned median."""
    burst = {s: "slow" for s in range(10, 16)}
    final, sup = _run_clocked(tmp_path, burst, reexecute=False)
    assert float(final["w"].ravel()[0]) == 20.0
    assert [s for s, _, _ in sup.stragglers] == list(range(10, 16))
    # reexecute=False: flagged, NOT re-run, and no second timing
    assert all(dt2 is None for _, _, dt2 in sup.stragglers)
    assert all("->" not in e for e in sup.events
               if e.startswith("straggler@"))


def test_straggler_reexecution_feeds_clean_sample(tmp_path):
    """reexecute=True appends the RE-EXECUTED time (a clean sample), so
    the window keeps sliding on honest data."""
    _, sup = _run_clocked(tmp_path, {8: "slow", 14: "slow"})
    assert [s for s, _, _ in sup.stragglers] == [8, 14]
    assert all(dt2 is not None and dt2 < dt
               for _, dt, dt2 in sup.stragglers)


def test_supervisor_wallclock_defaults():
    """The injectable knobs default to real wall-clock functions."""
    import time
    assert Supervisor.__dataclass_fields__["clock"].default \
        is time.perf_counter
    assert FaultInjector.__dataclass_fields__["sleep"].default \
        is time.sleep


def test_supervisor_preemption_checkpoints(tmp_path):
    inj = FaultInjector({8: "preempt"})
    mgr = CheckpointManager(tmp_path)
    sup = Supervisor(mgr, checkpoint_every=100)
    with pytest.raises(Preemption):
        sup.run(state=_state(0.0),
                step_fn=lambda s, i: {"w": s["w"] + 1, "step": s["step"] + 1},
                num_steps=20, injector=inj)
    # a committed checkpoint at the preemption point exists → restartable
    assert mgr.latest_step() == 8
    restored, _ = mgr.restore(_state(0.0))
    assert float(restored["w"].ravel()[0]) == 8.0
