"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd import ssd_scan
from repro.kernels.ssd.ref import ssd_ref

KEY = jax.random.key(3)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,t,h,kv,d", [
    (1, 128, 2, 2, 64), (2, 256, 4, 2, 64), (1, 384, 8, 1, 128),
    (2, 200, 4, 4, 64),                                     # padded tail
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 96)])
def test_flash_attention_sweep(b, t, h, kv, d, dtype, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, kv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, kv, d), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    g = h // kv
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1)
    ref = attention_ref(q.transpose(0, 2, 1, 3), kr, vr, causal=causal,
                        window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype))


@pytest.mark.parametrize("shape", [(8, 128), (3, 5, 256), (1, 37, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(KEY, shape, jnp.float32).astype(dtype)
    s = jax.random.normal(KEY, shape[-1:], jnp.float32)
    out = rmsnorm(x, s)
    ref = rmsnorm_ref(x, s)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype))


@pytest.mark.parametrize("b,t,h,p,g,n,chunk", [
    (1, 64, 4, 16, 2, 32, 16), (2, 48, 2, 8, 1, 16, 16),
    (1, 100, 4, 16, 4, 32, 32),                              # padded tail
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(b, t, h, p, g, n, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = (jax.random.normal(ks[0], (b, t, h, p)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = (jax.random.normal(ks[3], (b, t, g, n)) * 0.3).astype(dtype)
    cc = (jax.random.normal(ks[4], (b, t, g, n)) * 0.3).astype(dtype)
    y = ssd_scan(x, dt, a, bb, cc, chunk=chunk)
    br = jnp.repeat(bb, h // g, axis=2)
    cr = jnp.repeat(cc, h // g, axis=2)
    yr = ssd_ref(x, dt, a, br, cr)
    tol = dict(rtol=6e-2, atol=6e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), **tol)


def test_flash_matches_model_blockwise_path():
    """The XLA fallback in models.layers and the Pallas kernel agree."""
    from repro.models import layers as L

    class C:
        pass

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    blk = L._sdpa_blockwise(C, q, k, v, causal=True, bq=128, bkv=128)
    pal = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(pal),
                               rtol=2e-5, atol=2e-5)
