"""`DSEService.extend_grid`: folding ONLY the appended config rows into
every completed stream via `repro.core.energymodel.merge_layer_topk` must
be bit-identical to re-streaming the grown grid from scratch — both tiers,
including the case where the append lands a NEW subsampled-tier stride
multiple and the case where it lands none — and the durable store must
invalidate exactly the superseded grid-hash groups while re-persisting the
merged streams under the new hashes."""

import numpy as np
import pytest

from repro.core import energymodel, topology
from repro.core.accelerator import ConfigGrid
from repro.serving import store as store_mod
from repro.serving.dse_service import DSEService

NETS = ("AlexNet", "MobileNet")
CHUNK = 5
STRIDE = 8


@pytest.fixture(scope="module")
def networks():
    return {n: topology.get_network(n) for n in NETS}


@pytest.fixture(scope="module")
def big_grid():
    # 27 rows; rows [0:18) seed the service, the tail arrives later
    return ConfigGrid.product(arrays=((16, 16), (32, 32), (64, 64)),
                              gb_psum_kb=(13, 54, 216),
                              gb_ifmap_kb=(27, 108, 216))


def _split(big, n_base):
    return (big.take(np.arange(n_base)),
            big.take(np.arange(n_base, big.n)))


def _assert_same(res, ref, networks):
    for k in store_mod._STREAM_ARRAYS:
        np.testing.assert_array_equal(np.asarray(getattr(res, k)),
                                      np.asarray(getattr(ref, k)),
                                      err_msg=k)
    assert res.n_cfg == ref.n_cfg
    for nm in networks:
        np.testing.assert_array_equal(res.boundary_idx[nm],
                                      ref.boundary_idx[nm])
        np.testing.assert_array_equal(res.boundary_energy[nm],
                                      ref.boundary_energy[nm])
        np.testing.assert_array_equal(res.boundary_latency[nm],
                                      ref.boundary_latency[nm])


def _warm_service(base, networks, **kw):
    svc = DSEService(base, networks, chunk_size=CHUNK,
                     degrade_stride=STRIDE, **kw)
    svc.submit("best_config")                 # warms exact + sub streams
    svc.submit("best_chip", deadline=2.0)     # and the solved chip points
    out, drained = svc.run_until_drained()
    assert drained and all(r.ok for r in out)
    return svc


@pytest.mark.parametrize("metric", ["edp", "energy"])
def test_delta_fold_bit_exact_vs_full_restream(big_grid, networks, metric):
    """18 -> 27 rows: row 24 is a NEW stride-8 multiple, so BOTH tiers
    must delta-fold and match a from-scratch stream of the grown grid."""
    base, new_rows = _split(big_grid, 18)
    svc = DSEService(base, networks, chunk_size=CHUNK,
                     degrade_stride=STRIDE)
    svc.submit("best_config", metric=metric)
    svc.run_until_drained()
    summary = svc.extend_grid(new_rows)
    assert summary["added"] == 9 and summary["n_cfg"] == 27
    assert summary["n_cfg_degraded"] == 4     # 0, 8, 16, 24
    assert summary["delta_folds"] == 2        # exact AND sub folded

    for tier, rows in (("exact", np.arange(27)),
                       ("sub", np.arange(0, 27, STRIDE))):
        ref = energymodel.stream_layer_topk(
            big_grid.take(rows), networks, topk=svc.topk, bound=svc.bound,
            metric=metric, chunk_size=CHUNK)
        _assert_same(svc._streams[(tier, metric)], ref, NETS)


def test_extend_without_new_stride_multiple(big_grid, networks):
    """18 -> 22 rows: arange(0, 22, 8) == arange(0, 18, 8), so the sub
    tier is reused untouched while the exact tier folds the delta."""
    base, tail = _split(big_grid, 18)
    new_rows = tail.take(np.arange(4))
    svc = DSEService(base, networks, chunk_size=CHUNK,
                     degrade_stride=STRIDE)
    svc.submit("best_config")
    svc.run_until_drained()
    sub_before = svc._streams[("sub", "edp")]
    summary = svc.extend_grid(new_rows)
    assert summary["delta_folds"] == 1        # exact only
    assert summary["n_cfg_degraded"] == 3
    assert svc._streams[("sub", "edp")] is sub_before
    ref = energymodel.stream_layer_topk(
        big_grid.take(np.arange(22)), networks, topk=svc.topk,
        bound=svc.bound, metric="edp", chunk_size=CHUNK)
    _assert_same(svc._streams[("exact", "edp")], ref, NETS)


def test_answers_after_extend_match_fresh_service(big_grid, networks):
    base, new_rows = _split(big_grid, 18)
    svc = _warm_service(base, networks)
    svc.extend_grid(new_rows)
    for q in (dict(kind="best_config", network=None, deadline=2.0),
              dict(kind="best_chip", network=None, deadline=2.0),
              dict(kind="pareto", network="AlexNet", deadline=2.0)):
        svc.submit(q["kind"], network=q["network"], deadline=q["deadline"])
    grown, drained = svc.run_until_drained()
    assert drained

    fresh = DSEService(big_grid, networks, chunk_size=CHUNK,
                       degrade_stride=STRIDE)
    for r in grown:
        fresh.submit(r.kind, network=r.answer.get("network")
                     if r.kind == "pareto" else None, deadline=2.0)
    ref, _ = fresh.run_until_drained()
    for a, b in zip(grown, ref):
        assert a.kind == b.kind
        assert a.answer == b.answer           # same types: both computed


def test_store_invalidation_and_repersist(big_grid, networks, tmp_path):
    base, new_rows = _split(big_grid, 18)
    svc = _warm_service(base, networks, state_dir=tmp_path)
    old_stream_key = svc._stream_key("exact", "edp")
    assert svc.store.get(old_stream_key) is not None

    summary = svc.extend_grid(new_rows)
    # old grid-hash groups (streams AND answers) are gone...
    assert summary["invalidated"] >= 2        # >= exact stream + answers
    assert svc.stats["cache_invalidated"] == summary["invalidated"]
    assert svc.store.get(old_stream_key) is None
    assert svc.store.stats["quarantined"] == 0
    # ...and the merged streams re-persisted under the NEW hashes
    for tier in ("exact", "sub"):
        assert svc.store.get(svc._stream_key(tier, "edp")) is not None
    svc.close()

    # a restart over the same dir with the grown grid streams from disk
    s2 = DSEService(big_grid, networks, chunk_size=CHUNK,
                    degrade_stride=STRIDE, state_dir=tmp_path)
    s2.submit("best_config")
    (r,), _ = s2.run_until_drained()
    h = s2.health()
    s2.close()
    assert r.ok and h["sweep_cache_misses"] == 0 and h["store_hits"] >= 2
    ref = energymodel.stream_layer_topk(
        big_grid, networks, topk=s2.topk, bound=s2.bound,
        metric="edp", chunk_size=CHUNK)
    for nm in NETS:
        j = list(NETS).index(nm)
        assert r.answer[nm]["idx"] == int(ref.argmin[j])
        assert r.answer[nm]["metric"] == float(ref.min_metric[j])


def test_extend_rejects_column_mismatch(big_grid, networks):
    base, new_rows = _split(big_grid, 18)
    svc = DSEService(base, networks, chunk_size=CHUNK)
    bad = object.__new__(ConfigGrid)          # skip validation on purpose
    object.__setattr__(bad, "fields",
                       {k: v for k, v in new_rows.fields.items()
                        if k != "gb_psum_kb"})
    with pytest.raises(ValueError, match="column mismatch"):
        svc.extend_grid(bad)


def test_extend_drops_stale_checkpoints(big_grid, networks, tmp_path):
    """A mid-stream checkpoint's input hash references the OLD grid; the
    extension must drop it (memory and disk), not resume from it."""
    from repro.ft.faults import FaultPlan, ProcessKill, inject_chunk_faults
    base, new_rows = _split(big_grid, 18)
    svc = DSEService(base, networks, chunk_size=CHUNK,
                     degrade_stride=STRIDE, state_dir=tmp_path,
                     ckpt_every=1)
    svc.submit("best_config")
    with inject_chunk_faults(FaultPlan(pkill_at=2)):
        with pytest.raises(ProcessKill):
            svc.run_until_drained()
    s2 = DSEService(base, networks, chunk_size=CHUNK,
                    degrade_stride=STRIDE, state_dir=tmp_path,
                    ckpt_every=1)
    assert s2.health()["checkpoints"] >= 1
    s2.extend_grid(new_rows)
    h = s2.health()
    assert h["checkpoints"] == 0 and h["store"]["n_ckpt_files"] == 0
    out, drained = s2.run_until_drained()     # the replayed query, fresh
    s2.close()
    assert drained and all(r.ok for r in out)
    ref = energymodel.stream_layer_topk(
        big_grid, networks, topk=s2.topk, bound=s2.bound,
        metric="edp", chunk_size=CHUNK)
    for r in out:
        for j, nm in enumerate(NETS):
            assert r.answer[nm]["idx"] == int(ref.argmin[j])
