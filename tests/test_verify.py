"""Silent-data-corruption defense: verifier invariants + scrubber.

Each fold invariant is individually violated against a hand-built
corrupted state and must be individually caught, with chunk / network /
row provenance asserted — plus the shadow-recompute mismatch path, the
at-rest payload checks, and the store scrubber's quarantine/recompute
loop (:mod:`repro.ft.verify`, ISSUE 10)."""

import json
import os

import numpy as np
import pytest

from repro.core import energymodel, topology
from repro.core.accelerator import ConfigGrid
from repro.ft.verify import (SHADOW_RTOL, FoldInvariantError,
                             ShadowMismatchError, StreamVerifier,
                             VerifyConfig, check_layer_topk_result,
                             scrub_layer_topk)
from repro.serving import store as store_mod

NAMES = ("NetA", "NetB")


def _verifier(kind="layer_topk", **kw):
    v = StreamVerifier(verify_fraction=0.0, **kw)
    v.bind(kind=kind, names=NAMES, metric="edp", topk=2, bound=0.1,
           backend="numpy")
    return v


def _layer_state():
    """A small SELF-CONSISTENT layer_topk fold state (2 nets, 2 layers,
    k=2): per-layer rows sum to the aggregates the rows were ranked by,
    top-k is lex-sorted, minima agree with the best top-k value."""
    top_e = np.array([[[1.0, 1.0], [1.5, 0.5]],
                      [[2.0, 1.0], [2.0, 2.0]]])     # [k, net, layer]
    top_t = np.array([[[1.0, 1.0], [1.0, 1.0]],
                      [[1.0, 1.0], [1.0, 1.0]]])
    es = top_e.sum(-1)                               # [k, net]
    ts = top_t.sum(-1)
    top_v = es * ts                                  # edp: [[4, 4], [6, 8]]
    top_i = np.array([[0, 5], [3, 7]])
    min_e = es.min(0)
    min_t = ts.min(0)
    min_edp = top_v.min(0)
    min_m = top_v[0].copy()
    argm = top_i[0].copy()
    lmin = np.array([[0.9, 0.9], [1.4, 0.4]])        # [net, layer]
    larg = np.array([[0, 0], [5, 5]])
    return [top_v, top_i, top_e, top_t, min_e, min_t, min_edp, min_m,
            argm, lmin, larg]


def _networks_state():
    top_v = np.array([[4.0, 4.0], [6.0, 8.0]])
    top_i = np.array([[0, 5], [3, 7]])
    min_e = np.array([2.0, 2.0])
    min_t = np.array([2.0, 2.0])
    min_m = top_v[0].copy()
    argm = top_i[0].copy()
    return [min_e, min_t, min_m, argm, top_v, top_i]


def _fold(v, prev, new, **kw):
    v.check_fold(3, 15, 20, prev, new, **kw)


# -- each invariant individually violated → individually caught ------------

def test_clean_states_pass():
    v = _verifier()
    _fold(v, _layer_state(), _layer_state())
    vn = _verifier(kind="networks")
    _fold(vn, _networks_state(), _networks_state())
    assert v.stats["invariant_violations"] == 0
    assert vn.stats["invariant_violations"] == 0
    assert v.stats["invariant_checks"] == 1


@pytest.mark.parametrize("slot,label", ((4, "min_energy"),
                                        (5, "min_latency"),
                                        (6, "min_edp"),
                                        (9, "layer_min_metric")))
def test_monotone_minima_caught(slot, label):
    v = _verifier()
    new = _layer_state()
    new[slot] = np.asarray(new[slot]) + 0.5       # a running min went UP
    with pytest.raises(FoldInvariantError) as ei:
        _fold(v, _layer_state(), new)
    err = ei.value
    assert err.invariant == "monotone_min"
    assert err.chunk == 3 and (err.start, err.stop) == (15, 20)
    assert err.network in NAMES
    assert label in str(err)
    assert v.stats["invariant_violations"] == 1


def test_monotone_min_metric_caught_networks_kind():
    v = _verifier(kind="networks")
    new = _networks_state()
    new[2] = new[2] + 1.0
    new[4] = new[4] + 1.0                 # keep min == top_v[0] consistent
    with pytest.raises(FoldInvariantError) as ei:
        _fold(v, _networks_state(), new)
    assert ei.value.invariant == "monotone_min"
    assert ei.value.network == "NetA"


def test_topk_sort_violation_caught():
    v = _verifier()
    new = _layer_state()
    new[0] = np.array([[4.0, 4.0], [3.0, 8.0]])   # NetA rank-1 beats rank-0
    with pytest.raises(FoldInvariantError) as ei:
        _fold(v, _layer_state(), new)
    assert ei.value.invariant == "topk_sorted"
    assert ei.value.network == "NetA"
    assert ei.value.row == 3                      # the out-of-order row


def test_topk_lex_tiebreak_violation_caught():
    """Equal values must still be index-sorted (the fold's lexsort)."""
    v = _verifier()
    new = _layer_state()
    new[0] = np.array([[4.0, 4.0], [4.0, 8.0]])   # tie on value ...
    new[1] = np.array([[3, 5], [0, 7]])           # ... but indices reversed
    new[7] = new[0][0].copy()
    new[8] = new[1][0].copy()
    with pytest.raises(FoldInvariantError) as ei:
        _fold(v, _layer_state(), new)
    assert ei.value.invariant == "topk_sorted"


def test_topk_duplicate_index_caught():
    v = _verifier()
    new = _layer_state()
    new[1] = np.array([[0, 5], [0, 7]])           # grid row 0 twice in NetA
    with pytest.raises(FoldInvariantError) as ei:
        _fold(v, _layer_state(), new)
    assert ei.value.invariant == "topk_unique"
    assert ei.value.network == "NetA"
    assert ei.value.row == 0


def test_unfilled_sentinel_slots_allowed():
    """-1 index sentinels carry +inf and may repeat — not duplicates."""
    v = _verifier()
    st = _layer_state()
    st[0] = np.array([[4.0, 4.0], [np.inf, np.inf]])
    st[1] = np.array([[0, 5], [-1, -1]])
    _fold(v, st, [np.array(a, copy=True) for a in st])
    assert v.stats["invariant_violations"] == 0


def test_min_not_equal_top_caught():
    v = _verifier()
    new = _layer_state()
    new[7] = new[7] * 0.5                 # min_m drifted from top_v[0]
    with pytest.raises(FoldInvariantError) as ei:
        _fold(v, _layer_state(), new)
    assert ei.value.invariant == "min_equals_top"


def test_layer_sum_aggregate_mismatch_caught():
    """A corrupted per-layer row no longer reproduces the aggregate the
    fold ranked that config by — the invariant that catches finite
    corruption of the CARRIED top-k payload."""
    v = _verifier()
    new = _layer_state()
    new[2] = np.array(new[2], copy=True)
    new[2][1, 0, 0] *= 1.001              # NetA's rank-1 energy row
    with pytest.raises(FoldInvariantError) as ei:
        _fold(v, _layer_state(), new)
    err = ei.value
    assert err.invariant == "layer_sum_aggregate"
    assert err.network == "NetA"
    assert err.row == 3                   # flat grid row of the bad config


def test_boundary_hit_outside_bound_caught():
    v = _verifier()
    st = _layer_state()
    es = np.array([[10.0, 2.0]])          # NetA row metric 10*1=10 > 4*1.1
    ts = np.array([[1.0, 1.0]])
    mask = np.array([[True, False]])
    with pytest.raises(FoldInvariantError) as ei:
        _fold(v, st, [np.array(a, copy=True) for a in st],
              es=es, ts=ts, mask=mask)
    err = ei.value
    assert err.invariant == "boundary_bound"
    assert err.network == "NetA"
    assert err.row == 15                  # start + local row 0


def test_boundary_hit_below_min_caught():
    """A hit BELOW the running minimum means the min fold missed it."""
    v = _verifier()
    st = _layer_state()
    es = np.array([[1.0, 2.0]])           # metric 1 < min_m 4
    ts = np.array([[1.0, 1.0]])
    mask = np.array([[True, False]])
    with pytest.raises(FoldInvariantError) as ei:
        _fold(v, st, [np.array(a, copy=True) for a in st],
              es=es, ts=ts, mask=mask)
    assert ei.value.invariant == "boundary_bound"


def test_resume_state_nan_caught():
    v = _verifier()
    st = _layer_state()
    st[2][0, 0, 0] = np.nan
    with pytest.raises(FoldInvariantError) as ei:
        v.check_resume(st, {nm: [] for nm in NAMES})
    assert ei.value.invariant == "state_finite"
    assert ei.value.chunk is None         # resume provenance, not a chunk


def test_resume_candidate_below_min_caught():
    v = _verifier()
    cand = {"NetA": [(np.array([2]), np.array([1.0]), np.array([1.0]))],
            "NetB": []}
    with pytest.raises(FoldInvariantError) as ei:
        v.check_resume(_layer_state(), cand)
    err = ei.value
    assert err.invariant == "boundary_bound"
    assert err.network == "NetA" and err.row == 2


def test_invariants_opt_out():
    v = StreamVerifier(VerifyConfig(invariants=False, verify_fraction=0.0))
    v.bind(kind="layer_topk", names=NAMES, metric="edp", topk=2,
           bound=0.1, backend="numpy")
    bad = _layer_state()
    bad[7] = bad[7] * 0.5
    _fold(v, _layer_state(), bad)         # does not raise
    assert v.stats["invariant_checks"] == 0


# -- shadow recompute ------------------------------------------------------

def _shadow_verifier(ref_eval, **kw):
    v = StreamVerifier(verify_fraction=1.0, **kw)
    v.bind(kind="layer_topk", names=NAMES, metric="edp", topk=2,
           bound=0.1, backend="numpy", ref_eval=ref_eval)
    return v


def test_shadow_mismatch_provenance():
    e = np.ones((3, 2, 2))
    t = np.ones((3, 2, 2))
    e_ref = np.array(e, copy=True)
    e_ref[1, 0, 1] *= 1.0 + 1e-9          # fast path diverges there
    v = _shadow_verifier(lambda fc: (e_ref, t))
    with pytest.raises(ShadowMismatchError) as ei:
        v.check_chunk(2, 10, 13, None, e, t)
    err = ei.value
    assert err.chunk == 2 and (err.start, err.stop) == (10, 13)
    assert err.mismatches == [dict(row=11, network="NetA",
                                   term="energy[layer 1]",
                                   got=1.0, want=1.0 + 1e-9)]
    assert v.stats["shadow_mismatches"] == 1


def test_shadow_bitexact_on_numpy_cross_rtol_on_jax():
    """backend="numpy" compares bit-exactly; jax within SHADOW_RTOL, so
    ulp-level cross-backend noise never false-positives."""
    e = np.ones((2, 2, 2))
    t = np.ones((2, 2, 2))
    e_ref = e * (1.0 + 1e-15)             # one ulp-ish off
    v_np = _shadow_verifier(lambda fc: (e_ref, t))
    with pytest.raises(ShadowMismatchError):
        v_np.check_chunk(0, 0, 2, None, e, t)
    v_jax = StreamVerifier(verify_fraction=1.0)
    v_jax.bind(kind="layer_topk", names=NAMES, metric="edp", topk=2,
               bound=0.1, backend="jax", ref_eval=lambda fc: (e_ref, t))
    v_jax.check_chunk(0, 0, 2, None, e, t)        # within SHADOW_RTOL
    assert v_jax.stats["shadow_mismatches"] == 0
    assert v_jax._rtol == SHADOW_RTOL and v_np._rtol == 0.0


def test_shadow_catches_padding_row_corruption():
    """Padded rows are deterministic duplicates of the chunk's first row;
    corruption landing there is compared (and flagged) too."""
    e = np.ones((4, 2, 2))
    t = np.ones((4, 2, 2))
    e_bad = np.array(e, copy=True)
    e_bad[3, 1, 0] *= 1.001               # row 3 is padding (stop-start=2)
    v = _shadow_verifier(lambda fc: (e, t))
    with pytest.raises(ShadowMismatchError) as ei:
        v.check_chunk(0, 0, 2, None, e_bad, t)
    m = ei.value.mismatches[0]
    assert m["row"] == 0 and "padding" in m["term"]


def test_sampling_is_deterministic_and_fractional():
    picks = [StreamVerifier(verify_fraction=0.25, seed=7).sampled(ci)
             for ci in range(64)]
    again = [StreamVerifier(verify_fraction=0.25, seed=7).sampled(ci)
             for ci in range(64)]
    assert picks == again                 # (seed, chunk) alone decides
    assert 0 < sum(picks) < 64
    assert all(StreamVerifier(verify_fraction=1.0).sampled(ci)
               for ci in range(8))
    assert not any(StreamVerifier(verify_fraction=0.0).sampled(ci)
                   for ci in range(8))


def test_evidence_dump(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY_EVIDENCE_DIR", str(tmp_path))
    e = np.ones((2, 2, 2))
    t = np.ones((2, 2, 2))
    v = _shadow_verifier(lambda fc: (e * 1.001, t))
    with pytest.raises(ShadowMismatchError):
        v.check_chunk(1, 5, 7, None, e, t)
    files = list(tmp_path.glob("shadow_mismatch_*.json"))
    assert len(files) == 1
    ev = json.loads(files[0].read_text())
    assert ev["chunk"] == 1
    assert ev["mismatches"][0]["network"] in NAMES


# -- at-rest checks + scrubber ---------------------------------------------

@pytest.fixture(scope="module")
def space():
    grid = ConfigGrid.product(arrays=((16, 16), (32, 32), (64, 64)),
                              gb_psum_kb=(13, 54, 216),
                              gb_ifmap_kb=(27, 108))
    networks = {n: topology.get_network(n)
                for n in ("AlexNet", "MobileNet")}
    st = energymodel.stream_layer_topk(grid, networks, topk=4, bound=0.05,
                                       chunk_size=6)
    return grid, networks, st


def _poisoned(st, rel=1.001):
    """Copy ``st`` with one top-k row's layer_energy cell scaled AND its
    ranking aggregate recomputed to match — a finite, SELF-CONSISTENT,
    checksum-proof corruption (the model of a fold poisoned by a wrong
    chunk evaluation, where value and rows corrupt together)."""
    arrays, meta = store_mod.stream_payload(st)
    for k, j in np.argwhere(np.asarray(st.topk_idx) >= 0):
        if k == 0:
            continue               # rank 0 would drag min_metric along too
        a = {kk: np.array(v, copy=True) for kk, v in arrays.items()}
        li = np.nonzero(a["layer_energy"][k, j])[0][0]
        a["layer_energy"][k, j, li] *= rel
        a["topk_metric"][k, j] = energymodel._metric_of(
            st.metric, a["layer_energy"][k, j].sum(),
            a["layer_latency"][k, j].sum())
        bad = store_mod.stream_from_payload(a, meta)
        if check_layer_topk_result(bad) is None:   # still sorted etc.
            return bad
    raise AssertionError("no poisonable self-consistent cell found")


def test_clean_result_passes_at_rest_checks(space):
    grid, networks, st = space
    assert check_layer_topk_result(st) is None
    assert scrub_layer_topk(st, grid, networks, rows=999) is None


def test_at_rest_structural_violations(space):
    _, _, st = space
    arrays, meta = store_mod.stream_payload(st)
    bad = {k: np.array(v, copy=True) for k, v in arrays.items()}
    bad["topk_metric"][0, 0], bad["topk_metric"][1, 0] = \
        bad["topk_metric"][1, 0], bad["topk_metric"][0, 0]
    reason = check_layer_topk_result(
        store_mod.stream_from_payload(bad, meta))
    assert reason is not None and "lex sorted" in reason

    bad2 = {k: np.array(v, copy=True) for k, v in arrays.items()}
    bad2["min_metric"][0] *= 0.5
    reason2 = check_layer_topk_result(
        store_mod.stream_from_payload(bad2, meta))
    assert reason2 is not None and "min_metric" in reason2


def test_scrub_catches_selfconsistent_poison(space):
    """The deep rung: a poisoned-but-SELF-CONSISTENT payload (both the
    ranking value and its per-layer rows corrupted together) passes every
    structural check and is only caught by re-deriving rows through the
    reference path."""
    grid, networks, st = space
    bad = _poisoned(st)
    assert check_layer_topk_result(bad) is None     # structure can't see it
    reason = scrub_layer_topk(bad, grid, networks, rows=999)
    assert reason is not None
    assert "diverges from the reference" in reason


def test_store_scrub_quarantines_with_reason(tmp_path, space):
    grid, networks, st = space
    store = store_mod.DurableStore(tmp_path)
    arrays, meta = store_mod.stream_payload(st)
    store.put(("g", "clean"), arrays=arrays, meta=meta)
    store.put(("g", "bad"), arrays=arrays, meta=dict(meta, poison=True))

    def checker(key_repr, a, m):
        return "injected reason" if m.get("poison") else None

    res = store.scrub(checker)
    assert res["scanned"] == 2 and res["bad"] == 1
    assert res["bad_keys"] == [repr(("g", "bad"))]
    assert store.get(("g", "bad")) is None          # gone (quarantined)
    assert store.get(("g", "clean")) is not None    # untouched
    reasons = list(store.quarantine.glob("*.reason"))
    assert len(reasons) == 1
    assert "injected reason" in reasons[0].read_text()
    assert store.stats["scrub_entries"] == 2
    assert store.stats["scrubbed_bad"] == 1


def test_store_scrub_integrity_and_cursor(tmp_path, space):
    _, _, st = space
    store = store_mod.DurableStore(tmp_path)
    arrays, meta = store_mod.stream_payload(st)
    for i in range(3):
        store.put(("g", i), arrays=arrays, meta=meta)
    # bit-rot one file on disk: the integrity rung (no checker) quarantines
    victim = sorted(store.entries.glob("*.npz"))[1]
    victim.write_bytes(victim.read_bytes()[:-7])
    seen, cursor = 0, None
    for _ in range(3):                     # one-entry incremental passes
        res = store.scrub(max_entries=1, cursor=cursor)
        seen += res["scanned"]
        cursor = res["cursor"]
    assert seen == 3
    assert store.stats["scrubbed_bad"] == 1
    assert sum(1 for _ in store.entries.glob("*.npz")) == 2
