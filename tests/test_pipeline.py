"""B&B-staged GPipe pipeline: planning + numerical equivalence with the
sequential execution on a CPU debug mesh."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import dp_partition
from repro.parallel import pipeline as PP


def test_plan_stages_balances():
    lat = [5.0, 1.0, 1.0, 1.0, 4.0, 4.0]
    plan = PP.plan_stages(lat, 3)
    assert plan.n_stages == 3
    assert sum(plan.stage_sizes) == len(lat)
    dp = dp_partition(lat, 3)
    assert plan.partition.pipeline_latency <= dp.pipeline_latency * 1.5


def test_bubble_fraction():
    plan = PP.plan_stages([1.0] * 8, 4)
    assert plan.bubble(4) == pytest.approx(3 / 7)
    assert plan.bubble(16) < plan.bubble(4)


def test_stage_params_padding():
    stacked = {"w": jnp.arange(10.0).reshape(5, 2)}
    plan = PP.plan_stages([1, 1, 1, 3, 3], 2)     # e.g. sizes (3, 2) or (4,1)
    staged, mask = PP.stage_params(stacked, plan)
    assert staged["w"].shape == (2, plan.max_depth, 2)
    assert mask.shape == (2, plan.max_depth)
    assert int(mask.sum()) == 5


@pytest.mark.skipif(len(jax.devices()) > 1, reason="needs host re-init")
def test_pipeline_matches_sequential():
    # build a tiny 4-stage mesh out of forced host devices in a subprocess-
    # free way: reuse the current single device only if forced count is set.
    if len(jax.devices()) < 4:
        pytest.skip("single-device session; covered by test_multidev below")


def _mlp_layer(lp, x):
    return jnp.tanh(x @ lp["w"] + lp["b"])


def test_pipeline_multidev_subprocess():
    """Run the equivalence check in a subprocess with 4 host devices."""
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel import pipeline as PP

        L, D, M, BM, T = 6, 16, 4, 2, 8
        key = jax.random.key(0)
        ks = jax.random.split(key, 3)
        stacked = {
            "w": jax.random.normal(ks[0], (L, D, D)) * 0.3,
            "b": jax.random.normal(ks[1], (L, D)) * 0.1,
        }
        x = jax.random.normal(ks[2], (M, BM, T, D))

        def layer_fn(lp, h):
            return jnp.tanh(h @ lp["w"] + lp["b"])

        # sequential reference
        def seq(x):
            h = x
            for l in range(L):
                lp = {k: v[l] for k, v in stacked.items()}
                h = layer_fn(lp, h)
            return h
        ref = jax.vmap(seq)(x)

        mesh = jax.make_mesh((4,), ("stage",))
        lat = [1.0] * L
        plan = PP.plan_stages(lat, 4)
        staged, mask = PP.stage_params(stacked, plan)
        out = PP.pipeline_forward(staged, mask, x, mesh=mesh,
                                  stage_axis="stage", layer_fn=layer_fn)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("PIPELINE-OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       env=env, timeout=300)
    assert "PIPELINE-OK" in r.stdout, r.stdout + r.stderr
