"""DSEService behavior: answer correctness vs. direct engine calls, query
coalescing, bounded admission, deadline degradation (fake clock), budget
abort + checkpoint resume, and the health snapshot."""

import numpy as np
import pytest

from repro.core import energymodel, hetero, topology
from repro.core.accelerator import ConfigGrid
from repro.ft.faults import inject_chunk_faults
from repro.serving.dse_service import DSEService

NETS = ("AlexNet", "MobileNet")


@pytest.fixture(scope="module")
def networks():
    return {n: topology.get_network(n) for n in NETS}


@pytest.fixture(scope="module")
def grid():
    return ConfigGrid.product(arrays=((16, 16), (32, 32), (64, 64)),
                              gb_psum_kb=(13, 54, 216),
                              gb_ifmap_kb=(27, 108))


class FakeClock:
    """Deterministic service time: advances only when told to."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, dt):
        self.t += dt

    def per_chunk_hook(self, seconds):
        def hook(ci, e, t):
            self.t += seconds
            return e, t
        return hook


def test_best_config_matches_direct_stream(grid, networks):
    svc = DSEService(grid, networks, chunk_size=5)
    svc.submit("best_config")
    (r,), drained = svc.run_until_drained()
    assert drained and r.ok and not r.degraded
    ref = energymodel.stream_layer_topk(grid, networks, topk=8,
                                        bound=0.05, chunk_size=5)
    for j, nm in enumerate(NETS):
        assert r.answer[nm]["idx"] == int(ref.argmin[j])
        assert r.answer[nm]["metric"] == float(ref.min_metric[j])
        assert r.answer[nm]["energy"] == float(ref.min_energy[j])


def test_best_chip_matches_direct_codesign(grid, networks):
    svc = DSEService(grid, networks, chunk_size=5, pool_size=4,
                     m_cores=4, max_types=2)
    svc.submit("best_chip", deadline=2.0)
    svc.submit("pareto", network="AlexNet", deadline=2.0)
    out, drained = svc.run_until_drained()
    assert drained and all(r.ok and not r.degraded for r in out)
    chip = next(r for r in out if r.kind == "best_chip")
    probs = hetero.codesign_problems_streaming(
        grid, networks, 4, max_types=2, pool_size=4, bound=0.05,
        metric="edp", chunk_size=5)
    par = hetero.pareto_codesign(probs, deadlines=np.asarray([2.0]))
    ci = int(par.best_chip[0])
    assert chip.answer["feasible"] == (ci >= 0)
    if ci >= 0:
        assert chip.answer["chip_types"] == [
            int(probs.pool[p]) for p in par.chip_types[ci]]
        assert chip.answer["chip_counts"] == list(par.chip_counts[ci])
    frontier = next(r for r in out if r.kind == "pareto")
    assert frontier.answer["frontier"] == par.frontier("AlexNet")


def test_coalescing_one_sweep_many_queries(grid, networks):
    svc = DSEService(grid, networks, chunk_size=5)
    for nm in (None, "AlexNet", "MobileNet", None, "AlexNet"):
        svc.submit("best_config", network=nm)
    out = svc.step()                      # ONE step answers the batch
    assert len(out) == 5
    h = svc.health()
    assert h["coalesced_batches"] == 1
    # one exact + one calibration (subsampled) sweep, never five
    assert h["sweep_cache_misses"] == 2
    assert h["queue_depth"] == 0


def test_coalesced_deadlines_one_scoring_call(grid, networks):
    svc = DSEService(grid, networks, chunk_size=5)
    for d in (1.2, 2.0, 3.0, 2.0):
        svc.submit("best_chip", deadline=d)
    out = svc.step()
    assert len(out) == 4
    assert {r.answer["deadline"] for r in out} == {1.2, 2.0, 3.0}
    assert svc.health()["points_cache_misses"] == 2   # exact + sub


def test_queue_overflow_rejects_with_retry_after(grid, networks):
    svc = DSEService(grid, networks, max_queue=3, chunk_size=5)
    results = [svc.submit("best_config") for _ in range(5)]
    assert [s.accepted for s in results] == [True] * 3 + [False] * 2
    for s in results[3:]:
        assert s.rid is None and s.retry_after_s > 0
    out, drained = svc.run_until_drained()
    assert drained and len(out) == 3
    h = svc.health()
    assert h["rejected"] == 2 and h["accepted"] == 3


def test_expired_deadline_gets_degraded_answer(grid, networks):
    svc = DSEService(grid, networks, chunk_size=5, degrade_stride=4)
    svc.submit("best_config", deadline_s=0.0)     # already expired
    (r,), drained = svc.run_until_drained()
    assert drained and r.ok and r.degraded and r.deadline_missed
    # degraded answers index into the ORIGINAL grid, via the subsample map
    for nm in NETS:
        assert 0 <= r.answer[nm]["idx"] < grid.n
        assert r.answer[nm]["idx"] % 4 == 0       # stride-4 subsample
    assert svc.health()["degraded"] == 1


def test_tight_budget_projects_to_degraded(grid, networks):
    """Projection path: the measured subsampled sweep extrapolates the
    exact cost; a budget below it degrades WITHOUT attempting the exact
    sweep (no checkpoint left behind)."""
    clk = FakeClock()
    svc = DSEService(grid, networks, chunk_size=5, degrade_stride=4,
                     safety_factor=2.0, clock=clk, sleep=clk.sleep)
    with inject_chunk_faults(clk.per_chunk_hook(1.0)):
        svc.submit("best_config", deadline_s=3.0)
        (r,), drained = svc.run_until_drained()
    assert drained and r.ok and r.degraded
    assert svc.health()["checkpoints"] == 0
    assert svc.health()["budget_aborts"] == 0


def test_budget_abort_checkpoints_then_next_query_resumes(grid, networks):
    """Degradation ladder rung 4: an exact sweep that runs out of budget
    mid-stream answers degraded, leaves its checkpoint, and the next
    query with budget RESUMES it instead of restarting."""
    clk = FakeClock()
    svc = DSEService(grid, networks, chunk_size=5, degrade_stride=4,
                     safety_factor=0.1, clock=clk, sleep=clk.sleep)
    with inject_chunk_faults(clk.per_chunk_hook(1.0)):
        # sub sweep: 1 chunk -> cost 1s; projection 0.1 * (18/5) ~ 0.36s;
        # exact sweep needs 4 chunks = 4s > remaining budget -> abort
        svc.submit("best_config", deadline_s=3.0)
        (r1,), _ = svc.run_until_drained()
        assert r1.ok and r1.degraded
        h = svc.health()
        assert h["budget_aborts"] == 1 and h["checkpoints"] == 1
        svc.submit("best_config")                 # unbounded budget
        (r2,), _ = svc.run_until_drained()
    assert r2.ok and not r2.degraded
    assert svc.health()["resumes"] >= 1
    ref = energymodel.stream_layer_topk(grid, networks, topk=8,
                                        bound=0.05, chunk_size=5)
    for j, nm in enumerate(NETS):
        assert r2.answer[nm]["idx"] == int(ref.argmin[j])


def test_health_snapshot_shape(grid, networks):
    svc = DSEService(grid, networks, chunk_size=5)
    svc.submit("best_config")
    svc.run_until_drained()
    h = svc.health()
    for key in ("uptime_s", "queue_depth", "max_queue", "p50_s", "p99_s",
                "submitted", "accepted", "rejected", "completed",
                "degraded", "faults", "retries", "backend_fallbacks",
                "resumes", "sweep_cache_hits", "sweep_cache_misses",
                "last_backend", "jit"):
        assert key in h
    assert h["p99_s"] >= h["p50_s"] >= 0.0
    assert h["completed"] == 1


def test_run_until_drained_reports_not_drained(grid, networks):
    svc = DSEService(grid, networks, chunk_size=5)
    svc.submit("best_config")
    svc.submit("best_chip")                       # second family: 2 steps
    out, drained = svc.run_until_drained(max_steps=1)
    assert not drained and len(out) == 1
    out2, drained2 = svc.run_until_drained()
    assert drained2 and len(out2) == 1


def test_submit_validates_inputs(grid, networks):
    svc = DSEService(grid, networks)
    with pytest.raises(ValueError):
        svc.submit("nonsense")
    with pytest.raises(ValueError):
        svc.submit("best_config", network="NotANet")
    with pytest.raises(ValueError):
        svc.submit("pareto")                      # needs a network
