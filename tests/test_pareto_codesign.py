"""Batched latency-bound Pareto sweep (`partition.batch_pareto_scores`
/ `hetero.pareto_codesign`): the frontier equals a brute-force dominance
filter, deadline scoring equals the per-deadline loop, and the co-design
wrapper's invariants (winner feasibility, monotone scores, EDP-winner
membership) hold on real problem sets."""

import numpy as np
import pytest

from repro.core import accelerator, hetero, partition, topology

# Guarded per-test (not module-level importorskip) so the deterministic
# tests below always run.
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    _HAS_HYPOTHESIS = False

    def _skip_property(f):
        return pytest.mark.skip(
            reason="property test needs hypothesis "
            "(pip install -r requirements-dev.txt)")(f)

# Shared differential harness (tests/oracles.py): O(C²) dominance filter
# + per-deadline scoring loop.
from oracles import brute_frontier, loop_pareto_scores


def _check_instance(value, latency, deadlines, use_jax):
    masked, scores, best, best_net, net_front, chip_front = \
        partition.batch_pareto_scores(value, latency, deadlines,
                                      use_jax=use_jax)
    C, N = value.shape
    # masked/scores against the definition
    feas = latency[:, :, None] <= deadlines[None, :, :]
    want_masked = np.where(feas, value[:, :, None], np.inf)
    np.testing.assert_array_equal(masked, want_masked)
    np.testing.assert_array_equal(scores, want_masked.mean(axis=1))
    # per-deadline argmins against the python loop
    l_best, l_best_net = loop_pareto_scores(value, latency, deadlines)
    np.testing.assert_array_equal(best, l_best)
    np.testing.assert_array_equal(best_net, l_best_net)
    # frontier per network against the brute-force dominance filter
    for j in range(N):
        np.testing.assert_array_equal(
            net_front[:, j], brute_frontier(value[:, j], latency[:, j]),
            err_msg=f"net {j}")
    np.testing.assert_array_equal(
        chip_front, brute_frontier(value.mean(axis=1),
                                   latency.mean(axis=1)))


def test_pareto_scores_small_deterministic():
    value = np.array([[1.0, 2.0], [2.0, 1.0], [3.0, 3.0], [1.0, 2.0]])
    lat = np.array([[4.0, 1.0], [1.0, 4.0], [0.5, 0.5], [4.0, 1.0]])
    deadlines = np.array([[0.4, 1.0, 5.0], [0.4, 1.0, 5.0]])
    for use_jax in (False, True):
        _check_instance(value, lat, deadlines, use_jax)
    # duplicated points (rows 0 and 3) both survive weak dominance
    _, _, _, _, net_front, _ = partition.batch_pareto_scores(
        value, lat, deadlines, use_jax=False)
    assert net_front[0, 0] and net_front[3, 0]


def test_pareto_all_infeasible_and_broadcast():
    value = np.array([[1.0], [2.0]])
    lat = np.array([[5.0], [6.0]])
    masked, scores, best, best_net, _, _ = partition.batch_pareto_scores(
        value, lat, np.array([1.0, 5.5]), use_jax=False)   # [D] broadcast
    assert np.all(np.isinf(masked[:, :, 0]))
    assert best[0] == -1 and best_net[0, 0] == -1
    assert best[1] == 0 and best_net[0, 1] == 0


if _HAS_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def test_pareto_matches_brute_force_property(data):
        """Frontier == brute-force dominance filter and per-deadline
        argmins == the python loop, on random instances with deliberate
        ties, through BOTH the numpy and jitted paths."""
        C = data.draw(st.integers(2, 12), label="chips")
        N = data.draw(st.integers(1, 4), label="nets")
        D = data.draw(st.integers(1, 5), label="deadlines")
        # few distinct values → frequent exact ties
        val = st.sampled_from([0.5, 1.0, 1.5, 2.0, 3.0])
        value = np.array(data.draw(
            st.lists(st.lists(val, min_size=N, max_size=N),
                     min_size=C, max_size=C), label="value"))
        latency = np.array(data.draw(
            st.lists(st.lists(val, min_size=N, max_size=N),
                     min_size=C, max_size=C), label="latency"))
        dl = np.array(data.draw(
            st.lists(st.lists(val, min_size=D, max_size=D),
                     min_size=N, max_size=N), label="dl"))
        use_jax = data.draw(st.booleans(), label="use_jax")
        _check_instance(value, latency, dl, use_jax)
else:                                                  # pragma: no cover
    @_skip_property
    def test_pareto_matches_brute_force_property():
        pass


# ---------------------------------------------------------------------------
# pareto_codesign on a real problem set
# ---------------------------------------------------------------------------

PARETO_NETS = ("AlexNet", "VGG16", "MobileNet")


@pytest.fixture(scope="module")
def pareto_result():
    nets = {n: topology.get_network(n) for n in PARETO_NETS}
    grid = accelerator.ConfigGrid.product()
    probs = hetero.codesign_problems(grid, nets, 4, max_types=3,
                                     pool_size=5)
    res = partition.batch_schedule_hetero(probs.lat_dense, probs.counts,
                                          n_layers=probs.n_layers_b)
    pc = hetero.pareto_codesign(probs, res, n_deadlines=9)
    return grid, nets, probs, res, pc


def test_pareto_codesign_structure(pareto_result):
    grid, nets, probs, res, pc = pareto_result
    n_chips, n_net = pc.n_chips, len(nets)
    assert pc.energy.shape == pc.latency.shape == (n_chips, n_net)
    assert pc.scores.shape == (n_chips, pc.deadlines.size)
    assert pc.deadlines.size == 9
    assert pc.best_chip.shape == (9,)
    assert pc.best_chip_net.shape == (n_net, 9)
    assert pc.net_frontier.shape == (n_chips, n_net)
    # normalisation is by the per-network single-config minimum; a
    # heterogeneous schedule may well beat it (different layers on
    # different core types), but never by more than the per-layer-argmin
    # lower bound — and everything is strictly positive
    assert (pc.norm_energy > 0).all() and (pc.norm_latency > 0).all()
    # every network has a non-empty frontier and a rendering chip summary
    for nm in PARETO_NETS:
        front = pc.frontier(nm)
        assert front
        lats = [f[1] for f in front]
        assert lats == sorted(lats)
    assert pc.chip_summary(int(pc.best_chip[-1]), grid)


def test_pareto_codesign_deadline_semantics(pareto_result):
    _, nets, probs, res, pc = pareto_result
    D = pc.deadlines.size
    # per chip: feasibility is monotone in the deadline (once feasible,
    # stays feasible) and the finite score is the deadline-independent
    # mean normalised energy
    for c in range(pc.n_chips):
        s = pc.scores[c]
        fin = np.isfinite(s)
        assert not (fin[:-1] & ~fin[1:]).any()
        if fin.any():
            np.testing.assert_allclose(s[fin], s[fin][0], rtol=1e-12)
    # the widest deadline spans the whole observed range → all feasible
    assert np.isfinite(pc.scores[:, -1]).all()
    # per-deadline winners are feasible and minimal
    dl_abs = probs.min_latency[:, None] * pc.deadlines[None, :]
    for d in range(D):
        c = int(pc.best_chip[d])
        if c < 0:
            assert not np.isfinite(pc.scores[:, d]).any()
            continue
        assert (pc.latency[c] <= dl_abs[:, d]).all()
        assert pc.scores[c, d] == pc.scores[:, d].min()
    # winners can only improve (lower mean energy) as deadlines loosen
    win = [pc.scores[int(c), d] for d, c in enumerate(pc.best_chip)
           if int(c) >= 0]
    assert (np.diff(win) <= 1e-12).all()


def test_pareto_codesign_contains_edp_winner(pareto_result):
    """The EDP co-design winner is (a) on some network's frontier or
    dominated only by other candidates present in the same enumeration,
    and (b) the loosest-deadline best chip minimises mean normalised
    energy over ALL chips."""
    _, nets, probs, res, pc = pareto_result
    cd = hetero.score_codesign(probs, res, metric="edp", m_cores=4)
    # the CoDesign winner exists in the pareto enumeration with the same
    # energies/latencies
    wi = [i for i, (ty, cn) in enumerate(zip(pc.chip_types, pc.chip_counts))
          if [probs.pool[p] for p in ty] == cd.core_types
          and list(cn) == cd.core_counts]
    assert len(wi) == 1
    for j, nm in enumerate(pc.names):
        assert pc.energy[wi[0], j] == pytest.approx(cd.energy[nm],
                                                    rel=1e-12)
        assert pc.latency[wi[0], j] == pytest.approx(cd.latency[nm],
                                                     rel=1e-12)
    c = int(pc.best_chip[-1])
    # the jitted mean and numpy's may differ in the last ulp
    assert pc.scores[c, -1] == pytest.approx(
        pc.norm_energy.mean(axis=1).min(), rel=1e-12)
    assert pc.scores[c, -1] == pc.scores[:, -1].min()


def test_pareto_codesign_solves_when_res_missing(pareto_result):
    _, _, probs, res, pc = pareto_result
    pc2 = hetero.pareto_codesign(probs, deadlines=pc.deadlines)
    np.testing.assert_array_equal(pc2.best_chip, pc.best_chip)
    np.testing.assert_array_equal(pc2.scores, pc.scores)


def test_pareto_codesign_points_reuse(pareto_result):
    """The deadline re-sweep path (solved points passed back in) is
    bit-identical to the full build, and rejects wrong shapes."""
    _, _, probs, res, pc = pareto_result
    new_dl = np.linspace(pc.deadlines[0], pc.deadlines[-1], 5)
    full = hetero.pareto_codesign(probs, res, deadlines=new_dl)
    fast = hetero.pareto_codesign(probs, deadlines=new_dl,
                                  points=(pc.energy, pc.latency))
    np.testing.assert_array_equal(full.scores, fast.scores)
    np.testing.assert_array_equal(full.best_chip, fast.best_chip)
    np.testing.assert_array_equal(full.net_frontier, fast.net_frontier)
    with pytest.raises(ValueError, match="points"):
        hetero.pareto_codesign(probs, points=(pc.energy[:2], pc.latency[:2]))
