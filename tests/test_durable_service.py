"""Durable `DSEService` (state_dir=): process-kill at EVERY chunk of the
seeded mix must restart-replay to answers bit-identical to the clean run
with zero duplicate responses (`FaultPlan.pkill_at` raising `ProcessKill`
— a BaseException the retry ladder cannot swallow); a second restart
replays nothing; a warm re-launch answers from the persistent store
without recomputing a single sweep; stale checkpoints garbage-collect on
startup while live ones register for resume; the latency window stays
bounded.  `REPRO_CHAOS_SEEDS` / `REPRO_CHAOS_STATE_DIR` mirror the CI
chaos job (artifact-able state dirs on failure)."""

import os
import re
from pathlib import Path

import numpy as np
import pytest

from repro.core import topology
from repro.core.accelerator import ConfigGrid
from repro.ft import hw_faults
from repro.ft.faults import FaultPlan, ProcessKill, inject_chunk_faults
from repro.serving.dse_service import DSEService
from repro.serving.store import Journal

NETS = ("AlexNet", "MobileNet")
SEEDS = [int(s) for s in
         os.environ.get("REPRO_CHAOS_SEEDS", "0,1,2").split(",")]
CHUNK = 5          # 18-row grid -> 4 exact chunks (5+5+5+3), 1 sub chunk
N_KILL_POINTS = 4


@pytest.fixture(scope="module")
def networks():
    return {n: topology.get_network(n) for n in NETS}


@pytest.fixture(scope="module")
def grid():
    return ConfigGrid.product(arrays=((16, 16), (32, 32), (64, 64)),
                              gb_psum_kb=(13, 54, 216),
                              gb_ifmap_kb=(27, 108))


@pytest.fixture
def state_root(tmp_path, request):
    """Per-test state root; under REPRO_CHAOS_STATE_DIR when set so a CI
    failure uploads the journal + quarantine evidence as an artifact."""
    base = os.environ.get("REPRO_CHAOS_STATE_DIR")
    if not base:
        return tmp_path
    d = Path(base) / re.sub(r"[^\w.-]+", "_", request.node.name)
    d.mkdir(parents=True, exist_ok=True)
    return d


def _mk(grid, networks, state_dir, **kw):
    kw.setdefault("chunk_size", CHUNK)
    kw.setdefault("degrade_stride", 8)
    kw.setdefault("ckpt_every", 1)     # spill every chunk: worst-case tax,
    return DSEService(grid, networks,   # best-case restart resume coverage
                      state_dir=state_dir, **kw)


def _mix(seed, n=6):
    kinds = ("best_config", "best_chip", "pareto")
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        kind = kinds[int(rng.integers(len(kinds)))]
        out.append(dict(
            kind=kind, metric=("edp", "energy")[int(rng.integers(2))],
            network=(None if kind == "best_config"
                     else NETS[int(rng.integers(len(NETS)))]),
            deadline=float(rng.choice([1.5, 2.0, 3.0]))))
    return out


def _submit(svc, mix):
    for q in mix:
        assert svc.submit(q["kind"], network=q["network"],
                          metric=q["metric"], deadline=q["deadline"]).accepted


def _eq(a, b):
    """Structural equality with tuple == list (the JSON note in
    repro.serving.store) and NaN == NaN."""
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return len(a) == len(b) and all(_eq(x, y) for x, y in zip(a, b))
    if isinstance(a, dict) and isinstance(b, dict):
        return a.keys() == b.keys() and all(_eq(a[k], b[k]) for k in a)
    if isinstance(a, float) and isinstance(b, float):
        return a == b or (np.isnan(a) and np.isnan(b))
    return type(a) is type(b) and a == b


# -- kill-restart parity matrix --------------------------------------------


@pytest.mark.parametrize("seed", SEEDS)
def test_kill_restart_parity_every_chunk(grid, networks, state_root, seed):
    mix = _mix(seed)
    clean = _mk(grid, networks, state_root / "clean")
    _submit(clean, mix)
    clean_out, drained = clean.run_until_drained()
    clean.close()
    assert drained and all(r.ok and not r.degraded for r in clean_out)
    by_rid = {r.rid: r for r in clean_out}

    for kill in range(N_KILL_POINTS):
        sd = state_root / f"kill{kill}"
        s1 = _mk(grid, networks, sd)
        _submit(s1, mix)
        with inject_chunk_faults(FaultPlan(pkill_at=kill)) as plan:
            with pytest.raises(ProcessKill):
                s1.run_until_drained()
        assert (kill, "pkill") in plan.fired
        killed_out = list(s1.responses)     # s1 is now a dead process

        s2 = _mk(grid, networks, sd)        # restart over the same dir
        assert s2.stats["replayed"] == len(mix) - len(killed_out)
        restart_out, drained = s2.run_until_drained()
        s2.close()
        assert drained

        rids = [r.rid for r in killed_out + restart_out]
        assert len(rids) == len(set(rids)) == len(mix)   # exactly-once
        for r in killed_out + restart_out:
            ref = by_rid[r.rid]
            assert (r.kind, r.ok, r.degraded) == (
                ref.kind, ref.ok, ref.degraded)
            assert _eq(r.answer, ref.answer), (
                f"kill={kill} rid={r.rid}: {r.answer!r} != {ref.answer!r}")


def test_second_restart_replays_nothing(grid, networks, state_root):
    svc = _mk(grid, networks, state_root)
    _submit(svc, _mix(0))
    svc.run_until_drained()
    svc.close()
    s2 = _mk(grid, networks, state_root)
    assert s2.stats["replayed"] == 0
    assert s2.health()["queue_depth"] == 0
    s2.close()


def test_reschedule_request_survives_restart(grid, networks, state_root):
    scen = hw_faults.all_single_core_failures((2, 2))[0]
    svc = _mk(grid, networks, state_root)
    svc.submit("reschedule", chip_types=(0, 1), chip_counts=(2, 2),
               scenario=scen)
    # killed before any step: the journal is the only trace
    s2 = _mk(grid, networks, state_root)
    assert s2.stats["replayed"] == 1
    (r,), drained = s2.run_until_drained()
    s2.close()
    assert drained and r.ok and r.kind == "reschedule"
    assert r.answer["scenario"] == scen.name
    # the replayed request round-tripped its scenario through JSON
    ref = _mk(grid, networks, state_root / "ref")
    ref.submit("reschedule", chip_types=(0, 1), chip_counts=(2, 2),
               scenario=scen)
    (rr,), _ = ref.run_until_drained()
    ref.close()
    assert _eq(r.answer, rr.answer)


# -- warm restart ----------------------------------------------------------


def test_warm_restart_answers_from_store(grid, networks, state_root):
    mix = _mix(1)
    s1 = _mk(grid, networks, state_root)
    _submit(s1, mix)
    first, _ = s1.run_until_drained()
    s1.close()

    s2 = _mk(grid, networks, state_root)
    _submit(s2, mix)
    warm, drained = s2.run_until_drained()
    assert drained and len(warm) == len(mix)
    h = s2.health()
    s2.close()
    assert h["answer_hits"] == len(mix)      # every query: one npz read
    assert h["sweep_cache_misses"] == 0      # not a single sweep re-run
    assert h["store"]["n_quarantined_files"] == 0
    # same submission order -> same answers (modulo the JSON tuple note)
    for r, ref in zip(sorted(warm, key=lambda r: r.rid),
                      sorted(first, key=lambda r: r.rid)):
        assert _eq(r.answer, ref.answer)


def test_warm_restart_streams_from_store_on_new_queries(grid, networks,
                                                        state_root):
    s1 = _mk(grid, networks, state_root)
    s1.submit("best_config")
    s1.run_until_drained()
    s1.close()
    s2 = _mk(grid, networks, state_root)
    s2.submit("best_config", network="AlexNet")  # different query,
    (r,), _ = s2.run_until_drained()             # same streams
    h = s2.health()
    s2.close()
    assert r.ok and h["answer_hits"] == 0
    assert h["store_hits"] == 2                  # exact + sub tiers
    assert h["sweep_cache_misses"] == 0


# -- checkpoint GC / registration ------------------------------------------


def _kill_mid_stream(grid, networks, sd, *, kill=2):
    svc = _mk(grid, networks, sd)
    svc.submit("best_config")
    with inject_chunk_faults(FaultPlan(pkill_at=kill)):
        with pytest.raises(ProcessKill):
            svc.run_until_drained()


def test_live_checkpoint_registers_for_resume(grid, networks, state_root):
    _kill_mid_stream(grid, networks, state_root)
    s2 = _mk(grid, networks, state_root)
    assert s2.health()["checkpoints"] >= 1       # registered, not GC'd
    assert s2.stats["ckpt_gc"] == 0
    (r,), drained = s2.run_until_drained()
    assert drained and r.ok
    assert s2.stats["resumes"] >= 1              # folded from the spill
    s2.close()


def test_stale_checkpoint_gcs_on_startup(grid, networks, state_root):
    _kill_mid_stream(grid, networks, state_root)
    other = grid.take(np.arange(12))             # the design space moved on
    s2 = _mk(other, networks, state_root)
    h = s2.health()
    s2.close()
    assert h["ckpt_gc"] >= 1 and h["checkpoints"] == 0
    assert h["store"]["n_ckpt_files"] == 0


# -- admission / journal discipline ----------------------------------------


def test_rejected_requests_never_journalled(grid, networks, state_root):
    svc = _mk(grid, networks, state_root, max_queue=1)
    assert svc.submit("best_config").accepted
    assert not svc.submit("best_config", network="AlexNet").accepted
    rr = Journal.replay(svc._journal_path())
    svc.close()
    assert len(rr.pending) == 1                  # overflow left no trace


def test_rids_continue_across_restarts(grid, networks, state_root):
    s1 = _mk(grid, networks, state_root)
    _submit(s1, _mix(2, n=3))
    s1.run_until_drained()
    s1.close()
    s2 = _mk(grid, networks, state_root)
    sub = s2.submit("best_config")
    s2.close()
    assert sub.rid == 3                          # fresh, never reused


# -- bounded latency window ------------------------------------------------


def test_latency_window_is_bounded(grid, networks):
    svc = DSEService(grid, networks, chunk_size=CHUNK, lat_window=4)
    for _ in range(9):
        svc.submit("best_config")
    out, drained = svc.run_until_drained()
    assert drained and len(out) == 9
    h = svc.health()
    assert h["n_lat"] == 4 and h["lat_window"] == 4
    assert len(svc._lat) == 4
