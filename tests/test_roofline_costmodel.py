"""HLO cost parser exactness + analytic TPU cost model / autoshard DSE."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import autoshard
from repro.core.tpu_costmodel import ShardingPolicy, layer_costs, step_time
from repro.launch import roofline as R


def test_hlo_parser_scan_trip_counts():
    def body(c, w):
        return c @ w, None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 256, 256), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    hc = R.hlo_costs(c.as_text())
    assert hc["flops"] == pytest.approx(7 * 2 * 256 ** 3, rel=1e-6)


def test_hlo_parser_nested_scans():
    def inner(c, w):
        return c @ w, None

    def outer(c, ws):
        y, _ = jax.lax.scan(inner, c, ws)
        return y, None

    def f(x, ws):
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 5, 128, 128), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    hc = R.hlo_costs(c.as_text())
    assert hc["flops"] == pytest.approx(15 * 2 * 128 ** 3, rel=1e-6)


def test_collective_parse_shape_bytes():
    text = ("  %ag = bf16[2048,1408]{1,0} all-gather(%x), dimensions={0}\n"
            "  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%add\n")
    out = R.collective_bytes("ENTRY %main (p: f32[1]) -> f32[1] {\n"
                             + text + "}\n")
    assert out["all-gather"] == 2048 * 1408 * 2
    assert out["all-reduce"] == 1024 * 4


def test_roofline_terms_and_bottleneck():
    rl = R.Roofline(flops=197e12, hbm_bytes=819e9 * 2, coll_bytes={},
                    n_chips=1, model_flops=100e12)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(2.0)
    assert rl.bottleneck == "memory"
    assert rl.mfu == pytest.approx(100e12 / 197e12 / 2.0)


def test_costmodel_tp_reduces_per_chip_flops():
    cfg = get_config("qwen2.5-32b")
    base = step_time(cfg, ShardingPolicy("a", dp=256, tp=1),
                     seq_len=4096, global_batch=256)
    tp = step_time(cfg, ShardingPolicy("b", dp=16, tp=16),
                   seq_len=4096, global_batch=256)
    # same chip count; tp=16 splits weights but dp=16 raises tokens/chip —
    # the *model* is internally consistent: flops scale with tokens/tp
    assert base["flops"] > 0 and tp["flops"] > 0
    assert tp["collective_s"] > base["collective_s"] * 0  # defined


def test_costmodel_layer_vector_feeds_partitioner():
    from repro.core.partition import bb_partition
    cfg = get_config("recurrentgemma-9b")
    costs = layer_costs(cfg, ShardingPolicy("p", dp=64, tp=4),
                        seq_len=4096, global_batch=256)
    lat = [c.time_s for c in costs]
    part = bb_partition(lat, 4)
    assert part.speedup > 2.0


def test_autoshard_boundary_contains_best():
    cfg = get_config("qwen2-0.5b")
    scored = autoshard.sweep(cfg, n_chips=256, seq_len=4096,
                             global_batch=256)
    names = autoshard.boundary_set(cfg, n_chips=256, seq_len=4096,
                                   global_batch=256)
    assert scored[0][0].name in names


def test_design_fleet_covers_all():
    archs = {n: get_config(n) for n in
             ("qwen2-0.5b", "qwen2.5-32b", "mamba2-2.7b", "arctic-480b")}
    fleet = autoshard.design_fleet(archs, n_chips=256, seq_len=4096,
                                   global_batch=256, max_policies=3)
    assert set(fleet["assignment"]) == set(archs)
    assert 1 <= len(fleet["policies"]) <= 3
