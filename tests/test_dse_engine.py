"""The batched, jit-cached DSE engine: compile-cache behaviour, padding
invisibility, and batched-vs-per-network equivalence."""

import numpy as np
import pytest

from repro.core import accelerator, dse, energymodel, topology

NETS = ("AlexNet", "VGG16", "MobileNet")


@pytest.fixture(scope="module")
def networks():
    return {n: topology.get_network(n) for n in NETS}


def test_jit_cache_hit_on_same_shape(networks):
    """A second same-shape sweep must reuse the compiled kernel."""
    grid = accelerator.ConfigGrid.product()
    energymodel.evaluate_networks(grid, networks)          # warm (or trace)
    before = energymodel.jit_cache_stats()
    e1, t1 = energymodel.evaluate_networks(grid, networks)
    e2, t2 = energymodel.evaluate_networks(grid, networks)
    after = energymodel.jit_cache_stats()
    assert after["traces"] == before["traces"]             # no retrace
    assert after["calls"] == before["calls"] + 2
    np.testing.assert_array_equal(e1, e2)
    np.testing.assert_array_equal(t1, t2)


def test_networks_share_one_trace(networks):
    """Different networks bucket to the same padded layer count AND the
    same static segment key, so single-network sweeps share one compiled
    program per grid size — sweeping a new network must not retrace."""
    dse.sweep_network(networks["AlexNet"], "AlexNet", use_jax=True)
    before = energymodel.jit_cache_stats()
    for name in ("VGG16", "MobileNet"):        # 21 / 29 layers vs 11
        dse.sweep_network(networks[name], name, use_jax=True)
    dse.sweep_network(topology.get_network("ResNet50"), "ResNet50",
                      use_jax=True)            # 52 layers, never swept yet
    assert energymodel.jit_cache_stats()["traces"] == before["traces"]


def test_padding_contributes_zero(networks):
    """The benign pad layer yields exactly zero energy and latency, and the
    padded evaluation matches the unpadded scalar reference."""
    lay = {k: np.asarray([v], dtype=np.float64)
           for k, v in energymodel._PAD_LAYER_ROW.items()}
    grid = accelerator.ConfigGrid.product()
    cfgs = energymodel._cfg_struct_from_grid(np, grid)
    cfgs = {k: v[:, None] for k, v in cfgs.items()}
    ct = energymodel._counts(np, cfgs, {k: v[None, :] for k, v in lay.items()})
    el = energymodel._energy_latency(
        np, cfgs, {k: v[None, :] for k, v in lay.items()}, ct)
    assert np.all(el["energy"] == 0.0)
    assert np.all(el["latency"] == 0.0)

    # and bucketed padding is invisible end-to-end: the padded batched
    # result equals the per-config scalar simulation
    vgg = networks["VGG16"]
    small = accelerator.ConfigGrid.product(
        arrays=((16, 16), (32, 32)), gb_psum_kb=(54,), gb_ifmap_kb=(54,))
    e, t = energymodel.evaluate_networks(small, {"VGG16": vgg}, use_jax=False)
    for i in range(small.n):
        rep = energymodel.simulate_network(small.config_at(i), vgg)
        assert rep.energy == pytest.approx(e[i, 0], rel=1e-12)
        assert rep.latency == pytest.approx(t[i, 0], rel=1e-12)


def test_sweep_networks_matches_per_network(networks):
    batched = dse.sweep_networks(networks)
    for name, layers in networks.items():
        single = dse.sweep_network(layers, name)
        np.testing.assert_allclose(batched[name].energy, single.energy,
                                   rtol=1e-12)
        np.testing.assert_allclose(batched[name].latency, single.latency,
                                   rtol=1e-12)
        assert batched[name].network == name


def test_jax_numpy_parity_extended_space(networks):
    """The jit engine matches the numpy reference on the extended grid
    (RF/NoC axes exercised) to ≤1e-6 relative error."""
    grid = accelerator.ConfigGrid.product(
        arrays=((16, 16), (64, 64)), gb_psum_kb=(13, 54),
        gb_ifmap_kb=(27, 216), rf_psum_words=(16, 32),
        noc_words_per_cycle=(2.0, 8.0))
    e_j, t_j = energymodel.evaluate_networks(grid, networks, use_jax=True)
    e_n, t_n = energymodel.evaluate_networks(grid, networks, use_jax=False)
    np.testing.assert_allclose(e_j, e_n, rtol=1e-6)
    np.testing.assert_allclose(t_j, t_n, rtol=1e-6)


def test_config_grid_product_matches_objects():
    """Array-built cross product ≡ the per-point object construction."""
    grid = accelerator.ConfigGrid.product()
    objs = list(accelerator.config_grid().values())
    assert grid.n == len(objs) == 150
    # config_grid iterates (psum, ifmap, array); product iterates
    # (array, psum, ifmap) — compare as sets of parameter tuples
    got = {(grid.fields["rows"][i], grid.fields["cols"][i],
            grid.fields["gb_psum_kb"][i], grid.fields["gb_ifmap_kb"][i])
           for i in range(grid.n)}
    want = {(c.array_rows, c.array_cols, c.gb_psum_kb, c.gb_ifmap_kb)
            for c in objs}
    assert got == want


def test_dedup_count_rows_roundtrip():
    grid = accelerator.extended_grid()
    cfgs = energymodel._cfg_struct_from_grid(np, grid)
    cfg_u, inv = energymodel._dedup_count_rows(cfgs)
    # NoC width doesn't influence counts → 3x dedup on the extended space
    assert len(inv) == 5400
    assert inv.max() + 1 == 1800
    for k in energymodel._COUNT_COLUMNS:
        np.testing.assert_array_equal(cfg_u[k][inv], cfgs[k])
